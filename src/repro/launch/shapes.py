"""Assigned input shapes and their batch ShapeDtypeStructs.

Shapes (assignment):
  train_4k     seq=4,096    global_batch=256   -> train_step
  prefill_32k  seq=32,768   global_batch=32    -> prefill (full forward)
  decode_32k   seq=32,768   global_batch=128   -> serve_step (1 token, KV cache)
  long_500k    seq=524,288  global_batch=1     -> serve_step, sub-quadratic only

Applicability policy (DESIGN §6): long_500k runs for ssm/hybrid natively and
for every attention arch through a sliding-window(4096) variant -- except
whisper (enc-dec; skipped, see DESIGN).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.spec import batch_spec
from repro.launch.mesh import num_workers, worker_axes
from repro.models.config import ModelConfig

SDS = jax.ShapeDtypeStruct

LONG_CTX_WINDOW = 4096


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def adapt_config(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[Optional[ModelConfig], str]:
    """Returns (possibly-adapted config, note) or (None, skip reason)."""
    if shape.name != "long_500k":
        return cfg, ""
    if cfg.family == "encdec":
        return None, "skip: enc-dec decoder is not a 500k-token generator (DESIGN §6)"
    if cfg.family in ("ssm",):
        return cfg, "native sub-quadratic (recurrent state)"
    # attention families: sliding-window variant
    if cfg.attn_window == 0:
        cfg = dataclasses.replace(cfg, attn_window=LONG_CTX_WINDOW,
                                  name=cfg.name + "-swa")
        return cfg, f"sliding-window({LONG_CTX_WINDOW}) variant"
    return cfg, "windowed"


def _maybe_worker_sharded(mesh, dim0: int) -> P:
    """Shard the leading batch dim over the worker axes when divisible."""
    return batch_spec(mesh) if dim0 % num_workers(mesh) == 0 else P()


def batch_struct(cfg: ModelConfig, shape: ShapeSpec, mesh) -> Dict[str, SDS]:
    """ShapeDtypeStructs (with shardings) for the train/prefill global batch."""
    B, S = shape.global_batch, shape.seq
    sh = lambda spec: NamedSharding(mesh, spec)
    bspec = _maybe_worker_sharded(mesh, B)
    out: Dict[str, SDS] = {}

    S_text = S
    if cfg.family == "vlm":
        S_text = S - cfg.vision_patches
        out["vision_embeds"] = SDS((B, cfg.vision_patches, cfg.d_model),
                                   jnp.float32, sharding=sh(bspec))
    if cfg.family == "encdec":
        out["frames"] = SDS((B, cfg.encoder_frames, cfg.d_model), jnp.float32,
                            sharding=sh(bspec))
    out["tokens"] = SDS((B, S_text), jnp.int32, sharding=sh(bspec))
    if shape.kind == "train":
        out["labels"] = SDS((B, S_text), jnp.int32, sharding=sh(bspec))
    return out


def decode_structs(cfg: ModelConfig, shape: ShapeSpec, mesh, model):
    """(cache SDS tree, token SDS, pos SDS) for serve_step lowering."""
    B, S = shape.global_batch, shape.seq
    sh = lambda spec: NamedSharding(mesh, spec)
    bspec = _maybe_worker_sharded(mesh, B)

    cache_shapes = jax.eval_shape(lambda: model.init_cache(B, S))
    cache_specs = model.cache_specs()

    waxes = worker_axes(mesh)

    def lift(sds, spec):
        # cache leaves: (L, B, ...): shard B over workers when divisible
        parts = list(spec) + [None] * (len(sds.shape) - len(spec))
        if sds.shape[1] % num_workers(mesh) == 0 and parts[1] is None:
            parts[1] = waxes
        return SDS(sds.shape, sds.dtype, sharding=sh(P(*parts)))

    def lift_tree(shapes, specs):
        return jax.tree.map(
            lambda sds, spec: lift(sds, spec), shapes, specs,
            is_leaf=lambda x: isinstance(x, SDS))

    # match spec tree structure to cache structure (specs are per-leaf-group)
    if cfg.family in ("dense", "vlm", "moe"):
        cache = {k: lift(cache_shapes[k], cache_specs[k]) for k in cache_shapes}
    elif cfg.family == "ssm":
        cache = {k: lift(cache_shapes[k], cache_specs[k]) for k in cache_shapes}
    elif cfg.family == "hybrid":
        cache = {
            "mamba": {k: lift(cache_shapes["mamba"][k], cache_specs["mamba"][k])
                      for k in cache_shapes["mamba"]},
            "shared": {k: lift(cache_shapes["shared"][k], cache_specs["shared"][k])
                       for k in cache_shapes["shared"]},
        }
    elif cfg.family == "encdec":
        cache = {
            "self": {k: lift(cache_shapes["self"][k], cache_specs["self"][k])
                     for k in cache_shapes["self"]},
            "cross_k": lift(cache_shapes["cross_k"], cache_specs["cross_k"]),
            "cross_v": lift(cache_shapes["cross_v"], cache_specs["cross_v"]),
        }
    else:
        raise ValueError(cfg.family)

    token = SDS((B, 1), jnp.int32, sharding=sh(bspec))
    pos = SDS((), jnp.int32, sharding=sh(P()))
    return cache, token, pos
