"""Shared test helpers.

NOTE (per the dry-run spec): XLA_FLAGS / device-count forcing is NEVER set
globally here -- single-device tests must see 1 device.  Multi-device tests
spawn subprocesses with their own XLA_FLAGS via run_with_devices().
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_with_devices(code: str, n_devices: int, timeout: int = 900) -> str:
    """Run python code in a subprocess with n fake XLA host devices.
    Returns stdout; raises on nonzero exit."""
    prog = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={n_devices}'\n"
        + textwrap.dedent(code)
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=timeout, env=env)
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed (exit {res.returncode}):\n--- stdout ---\n"
            f"{res.stdout}\n--- stderr ---\n{res.stderr[-4000:]}")
    return res.stdout
