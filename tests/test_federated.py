"""Federated execution mode: per-round client sampling + stochastic local
gradients (docs/algorithms.md#partial-participation--stochastic-gradients).

Two families of guarantees are pinned here:

* full participation is a *bitwise* no-op: every masked op (m * d,
  where(m > 0, h', h), codec.mask_message) reduces to its unmasked twin at
  m = 1, so p = 1 trajectories equal the pre-federated ones exactly;
* under randomized masks the algebraic invariants hold (absent workers'
  h_i verbatim stale, h_avg = (1/n) sum h_i preserved, dense/sparse wire
  agreement) and the differential harness extends the
  oracle == interpret pinning of the fused kernels to random-participation
  trajectories.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from harness import (assert_bit_identical, codec_impls, quadratic_grads,
                     run_codec_trajectory, run_federated_trajectory)
from repro.core import (
    BlockTopK, EFBV, Natural, Participation, QSGD, RandK, SignNorm, TopK,
    run_reference, theory, tune, tune_for, tune_partial,
)
from repro.core.compressors import MNice
from repro.core.efbv import participation_key
from repro.distributed import wire
from repro.distributed.aggregate import efbv_aggregate_reference
from repro.problems import LogReg, make_synthetic

KEY = jax.random.key(0)


# ---------------------------------------------------------------------------
# Participation specs and masks
# ---------------------------------------------------------------------------

def test_participation_parse_and_masks():
    full = Participation.parse("full")
    assert full.is_full and full.fraction(8) == 1.0
    assert Participation.parse("bernoulli:1.0").is_full

    fx = Participation.parse("fixed:3")
    m = fx.sample_mask(KEY, 8)
    assert m.dtype == jnp.float32 and m.shape == (8,)
    assert float(m.sum()) == 3.0
    assert fx.fraction(8) == 3 / 8

    bp = Participation.parse("bernoulli:0.5")
    masks = jax.vmap(lambda k: bp.sample_mask(k, 16))(
        jax.random.split(KEY, 64))
    assert set(np.unique(np.asarray(masks))) <= {0.0, 1.0}
    assert 0.3 < float(masks.mean()) < 0.7  # ~p on average
    assert bp.fraction(16) == 0.5

    with pytest.raises(ValueError):
        Participation.parse("bernoulli:0.0")
    with pytest.raises(ValueError):
        Participation.parse("fixed:0")
    with pytest.raises(ValueError):
        Participation.parse("sometimes")
    with pytest.raises(ValueError):
        Participation.parse("fixed:9").sample_mask(KEY, 8)


# ---------------------------------------------------------------------------
# full participation == existing trajectories, bit for bit
# ---------------------------------------------------------------------------

def test_step_federated_full_mask_is_bitwise_step():
    grad_fn = quadratic_grads(8, 16)
    algo = EFBV(TopK(3), lam=0.7, nu=0.9)
    x = jnp.zeros(16)
    st_a = st_b = algo.init(x, 8)
    ones = jnp.ones((8,), jnp.float32)
    for t in range(6):
        k = jax.random.fold_in(KEY, t)
        g_a, st_a = algo.step(k, grad_fn(x), st_a)
        g_b, st_b = algo.step_federated(k, grad_fn(x), st_b, ones)
        assert_bit_identical(g_a, g_b, f"g @ {t}")
        assert_bit_identical(tuple(st_a), tuple(st_b), f"state @ {t}")
        x = x - 0.05 * g_a


def test_run_reference_all_present_mask_equals_fast_path_bitwise():
    """fixed:n participation samples an all-ones mask, so the masked
    step_federated path must reproduce the unmasked EFBV.step fast path
    bit-for-bit over a whole trajectory."""
    grad_fn = quadratic_grads(8, 16, seed=3)
    algo = EFBV(RandK(4), lam=0.5, nu=0.8)
    kw = dict(algo=algo, grad_fn=lambda k, x: grad_fn(x), x0=jnp.zeros(16),
              gamma=0.03, steps=25, key=KEY, n=8,
              record=lambda x: jnp.sum(x * x))
    a = run_reference(participation=Participation.parse("full"), **kw)
    b = run_reference(participation=Participation.parse("fixed:8"), **kw)
    assert_bit_identical(a.x, b.x, "x")
    assert_bit_identical(tuple(a.state), tuple(b.state), "state")
    assert_bit_identical(a.metrics, b.metrics, "metrics")


@pytest.mark.parametrize("mode", ["dense_psum", "sparse_allgather"])
@pytest.mark.parametrize("comp", [BlockTopK(16, 4), TopK(5), QSGD(16),
                                  Natural(), SignNorm()],
                         ids=["block_topk", "topk", "qsgd", "natural", "sign"])
def test_masked_aggregate_all_ones_is_bitwise_unmasked(mode, comp):
    """masks=ones must take the gated code path and still match mask=None
    exactly -- the m = 1 bitwise-identity claim, per codec."""
    n, d = 4, 96
    algo = EFBV(comp, lam=0.8, nu=0.9)
    grads = jax.random.normal(KEY, (n, d))
    h = jax.random.normal(jax.random.fold_in(KEY, 1), (n, d)) * 0.1
    h_avg = jnp.mean(h, 0)
    keys = jax.random.split(KEY, n)  # repro: noqa(prng-reuse) -- deterministic fixture, draws need not be independent
    ref = efbv_aggregate_reference(algo, keys, grads, h, h_avg, mode=mode)
    got = efbv_aggregate_reference(algo, keys, grads, h, h_avg, mode=mode,
                                   masks=jnp.ones((n,), jnp.float32))
    assert_bit_identical(ref, got, f"{mode}/{comp}")


# ---------------------------------------------------------------------------
# randomized masks: stale-h semantics, invariants, wire agreement
# ---------------------------------------------------------------------------

def test_absent_workers_keep_stale_h_and_invariant():
    n, d = 8, 16
    grad_fn = quadratic_grads(n, d, seed=1)
    algo = EFBV(TopK(4), lam=0.6, nu=0.8)
    part = Participation.parse("bernoulli:0.5")
    x = jnp.zeros(d)
    st = algo.init(x, n)
    for t in range(8):
        k = jax.random.fold_in(KEY, t)
        mask = part.sample_mask(participation_key(k), n)
        h_before = st.h
        g, st = algo.step_federated(k, grad_fn(x), st, mask)
        # absent workers: h_i verbatim stale
        for i in range(n):
            if float(mask[i]) == 0.0:
                np.testing.assert_array_equal(np.asarray(st.h[i]),
                                              np.asarray(h_before[i]))
        # master invariant: h_avg tracks (1/n) sum_i h_i through sampling
        np.testing.assert_allclose(np.asarray(jnp.mean(st.h, 0)),
                                   np.asarray(st.h_avg), rtol=1e-5, atol=1e-6)
        x = x - 0.05 * g


@pytest.mark.parametrize("comp", [BlockTopK(16, 4), TopK(5), QSGD(16),
                                  Natural(), SignNorm()],
                         ids=["block_topk", "topk", "qsgd", "natural", "sign"])
def test_masked_wire_modes_agree(comp):
    """Random mask: the dense all-reduce and the masked sparse wire produce
    the same aggregate and the same (stale-gated) control variates."""
    n, d = 8, 96
    algo = EFBV(comp, lam=0.7, nu=0.9)
    grads = jax.random.normal(KEY, (n, d))
    h = jnp.zeros((n, d))
    h_avg = jnp.zeros(d)
    keys = jax.random.split(KEY, n)  # repro: noqa(prng-reuse) -- deterministic fixture, draws need not be independent
    mask = Participation.parse("fixed:3").sample_mask(jax.random.key(7), n)
    outs = {m: efbv_aggregate_reference(algo, keys, grads, h, h_avg, mode=m,
                                        masks=mask)
            for m in ["dense_psum", "sparse_allgather"]}
    for a, b in zip(jax.tree.leaves(outs["dense_psum"]),
                    jax.tree.leaves(outs["sparse_allgather"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


@pytest.mark.parametrize("comp", [BlockTopK(128, 8), RandK(16), QSGD(16)],
                         ids=["block_topk", "randk", "qsgd"])
def test_federated_trajectory_backends_bit_identical(comp):
    """The differential harness over RANDOMIZED participation: every pack
    backend (jnp oracle, Pallas interpret; compiled on TPU) produces the
    bit-identical federated trajectory."""
    part = Participation.parse("bernoulli:0.5")
    codec = wire.codec_of(comp, (256,), 256)
    runs = {impl: run_federated_trajectory(
        impl, compressor=comp, steps=4, n=4, d=256, lam=0.6, nu=0.8,
        gamma=0.05, participation=part) for impl in codec_impls(codec)}
    ref = runs.pop("oracle")
    assert 0.0 < float(ref["masks"].mean()) < 1.0  # genuinely partial
    for impl, out in runs.items():
        assert_bit_identical({"x": ref["x"], "h": ref["h"]},
                             {"x": out["x"], "h": out["h"]}, impl)
        assert_bit_identical(ref["masks"], out["masks"], impl)


def test_federated_trajectory_p1_pins_existing_harness():
    """p = 1 federated trajectory == the pre-federated codec trajectory."""
    comp = QSGD(16)
    a = run_codec_trajectory("oracle", compressor=comp, steps=5, n=4, d=256,
                             lam=0.6, nu=0.8, gamma=0.05)
    b = run_federated_trajectory("oracle", compressor=comp, steps=5, n=4,
                                 d=256, lam=0.6, nu=0.8, gamma=0.05,
                                 participation=Participation.parse("bernoulli:1.0"))
    assert_bit_identical({"x": a["x"], "h": a["h"]},
                         {"x": b["x"], "h": b["h"]}, "p=1")


def test_federated_round_bits_accounting():
    """Wire bits of a federated round: whole-word mask bitmap + exactly
    |S_t| payloads."""
    fmt = wire.format_for(BlockTopK(16, 4), jnp.zeros(96))
    per = fmt.bits_per_round()
    assert fmt.bits_per_round(n_workers=8) == 8 * per
    assert fmt.bits_per_round(n_workers=8, participants=3) == 32 + 3 * per
    # 40 workers -> two uint32 bitmap words
    assert fmt.bits_per_round(n_workers=40, participants=5) == 64 + 5 * per
    mask = np.array([1, 0, 1, 0, 0, 0, 1, 0], np.float32)
    assert wire.federated_round_bits(fmt, mask) == 32 + 3 * per
    # expected (fractional) accounting for bernoulli
    exp = fmt.bits_per_round(n_workers=8, participants=0.5 * 8)
    assert exp == 32 + 4 * per


def test_mask_message_zeroes_decode_for_all_codecs():
    for comp in [BlockTopK(16, 4), TopK(5), RandK(9), QSGD(16), Natural(),
                 SignNorm()]:
        codec = wire.codec_of(comp, (96,), 96)
        payload = codec.encode(jax.random.key(5),
                               jax.random.normal(KEY, (96,)))  # repro: noqa(prng-reuse) -- deterministic fixture, draws need not be independent
        gated = codec.mask_message(payload, jnp.float32(0.0))
        np.testing.assert_array_equal(np.asarray(codec.decode(gated)),
                                      np.zeros(96), err_msg=str(comp))
        kept = codec.mask_message(payload, jnp.float32(1.0))
        assert_bit_identical(tuple(payload), tuple(kept), str(comp))


def test_joint_compressor_rejects_participation_mask():
    algo = EFBV(MNice(4, 2), lam=1.0, nu=1.0)
    st = algo.init(jnp.zeros(8), 4)
    with pytest.raises(ValueError):
        algo.step_federated(KEY, jnp.zeros((4, 8)), st, jnp.ones(4))


# ---------------------------------------------------------------------------
# sampled-regime tuning (theory.tune_partial)
# ---------------------------------------------------------------------------

def test_participation_constants():
    # p = 1: participation is a no-op on the certified constants
    assert theory.participation_eta(1.0, 0.3) == 0.3
    assert theory.participation_omega(1.0, 0.3, 2.0) == 2.0
    # p -> small: bias approaches 1 (mostly skipping), still < 1
    assert abs(theory.participation_eta(0.01, 0.0) - 0.99) < 1e-12
    assert theory.participation_eta(0.01, 0.5) < 1.0
    # contractive-only compressor gains variance from the sampling itself
    assert theory.participation_omega(0.5, 0.5, 0.0) == 0.5 * 0.5 * 2.25
    with pytest.raises(ValueError):
        theory.participation_eta(0.0, 0.3)
    with pytest.raises(ValueError):
        theory.participation_omega(1.5, 0.3, 1.0)


def test_tune_partial_reduces_to_tune_at_p1():
    t0 = tune(0.4, 3.0, n=64, L=1.0, Ltilde=1.2, mu=0.1)
    t1 = tune_partial(0.4, 3.0, 1.0, n=64, L=1.0, Ltilde=1.2, mu=0.1)
    assert t0 == t1


def test_tune_partial_gamma_monotone_in_p():
    gammas = [tune_partial(0.3, 2.0, p, n=100, L=1.0, Ltilde=1.0).gamma
              for p in [1.0, 0.75, 0.5, 0.25, 0.1]]
    assert all(a >= b - 1e-15 for a, b in zip(gammas, gammas[1:])), gammas
    assert all(g > 0 for g in gammas)


def test_tune_for_participation_routes():
    comp = TopK(4)
    t_full = tune_for(comp, 16, 8)
    assert tune_for(comp, 16, 8, participation=1.0) == t_full
    t_half = tune_for(comp, 16, 8, participation=0.5)
    assert t_half.eta > t_full.eta  # sampling adds bias
    assert t_half != t_full


# ---------------------------------------------------------------------------
# convergence in the sampled / stochastic regimes
# ---------------------------------------------------------------------------

def _quad(n=8, d=16, seed=0):
    key = jax.random.key(seed)
    A = jax.random.normal(key, (n, d, d)) / jnp.sqrt(d)
    Q = jnp.einsum("nij,nkj->nik", A, A) + 0.5 * jnp.eye(d)
    b = jax.random.normal(jax.random.key(seed + 1), (n, d))
    x_star = jnp.linalg.solve(jnp.mean(Q, 0), jnp.mean(b, 0))

    def grads(x):
        return jnp.einsum("nij,j->ni", Q, x) - b

    L = float(jnp.linalg.eigvalsh(jnp.mean(Q, 0))[-1])
    Li = jax.vmap(lambda q: jnp.linalg.eigvalsh(q)[-1])(Q)
    return grads, x_star, L, float(jnp.sqrt(jnp.mean(Li ** 2)))


def test_federated_convergence_bernoulli_half():
    """Client sampling at p = 0.5 with tune_partial stepsizes still drives
    the quadratic to its solution (exact local gradients)."""
    grads, x_star, L, Lt = _quad()
    comp = TopK(4)
    t = tune_partial(comp.eta(16), comp.omega(16), 0.5, n=8, L=L, Ltilde=Lt)
    algo = EFBV(comp, lam=t.lam, nu=t.nu)
    m = run_reference(
        algo=algo, grad_fn=lambda k, x: grads(x), x0=jnp.zeros(16),
        gamma=t.gamma, steps=25000, key=KEY, n=8,
        participation=Participation.parse("bernoulli:0.5"),
        record=lambda x: jnp.sum((x - x_star) ** 2)).metrics
    # exact solution: with exact local gradients the messages C(grad_i - h_i)
    # vanish at the fixed point, so sampling noise vanishes with them
    assert float(m[-1]) < 1e-5 * float(jnp.sum(x_star ** 2)), float(m[-1])


def test_minibatch_grads_unbiased_and_converges():
    d = 24
    A, b = make_synthetic(jax.random.key(2), N=480, d=d)
    prob = LogReg.split(A, b, n=16, mu_reg=0.1)
    x = jax.random.normal(KEY, (d,)) * 0.1
    # unbiasedness: averaging many minibatch draws approaches the full grads
    draws = jax.vmap(lambda k: prob.minibatch_grads(k, x, 8))(
        jax.random.split(KEY, 1024))  # repro: noqa(prng-reuse) -- deterministic fixture, draws need not be independent
    np.testing.assert_allclose(np.asarray(jnp.mean(draws, 0)),
                               np.asarray(prob.grads(x)), atol=0.1)
    # end to end: sampled clients + minibatch gradients reach the
    # neighborhood of the optimum
    _, fstar = prob.solve()
    comp = TopK(6)
    t = tune_partial(comp.eta(d), comp.omega(d), 0.5, n=prob.n,
                     L=prob.L(), Ltilde=prob.L_tilde())
    algo = EFBV(comp, lam=t.lam, nu=t.nu)
    m = run_reference(
        algo=algo, grad_fn=lambda k, x: prob.minibatch_grads(k, x, 8),
        x0=jnp.zeros(d), gamma=t.gamma, steps=20000, key=KEY, n=prob.n,
        participation=Participation.parse("bernoulli:0.5"),
        record=lambda x: prob.f(x) - fstar).metrics
    f0 = float(prob.f(jnp.zeros(d)) - fstar)
    assert float(jnp.mean(m[-100:])) < 0.15 * f0, (float(jnp.mean(m[-100:])), f0)


# ---------------------------------------------------------------------------
# data pipeline: local-shard minibatch resampling
# ---------------------------------------------------------------------------

def test_synthetic_lm_shard_resampling():
    from repro.data import SyntheticLM
    ds = SyntheticLM(vocab=64, seq_len=12, global_batch=8, n_workers=4,
                     seed=3, resample_from_shard=True, shard_size=16)
    b0, b0_again, b1 = ds.batch(0), ds.batch(0), ds.batch(1)
    np.testing.assert_array_equal(b0["tokens"], b0_again["tokens"])  # determinstic
    assert not np.array_equal(b0["tokens"], b1["tokens"])  # fresh draw per round
    # every sampled row comes from the worker's FIXED shard
    per_w = 8 // 4
    for w in range(4):
        shard = {r.tobytes() for r in ds._shards[w].astype(np.int32)}
        for row in b0["tokens"][w * per_w:(w + 1) * per_w]:
            assert row.tobytes() in shard
    # streaming mode is untouched by the new fields (same rng consumption)
    a = SyntheticLM(vocab=64, seq_len=12, global_batch=8, n_workers=4, seed=3)
    np.testing.assert_array_equal(a.batch(0)["tokens"],
                                  SyntheticLM(vocab=64, seq_len=12,
                                              global_batch=8, n_workers=4,
                                              seed=3).batch(0)["tokens"])
