"""Compressed cross-worker aggregation: the master step of Algorithm 1 as
TPU collectives (DESIGN §3.2).

Two-phase structure (sound under shard_map's static replication checker):

  phase 1 -- *inside* shard_map (manual over the worker axes, GSPMD-auto over
  'model'): each worker compresses its gradient innovation and updates its
  control variate.  Everything returned is worker-varying (stacked on a
  leading axis sharded over (pod, data)).

  phase 2 -- *outside* shard_map, plain GSPMD: the master average d_bar is a
  reduction over the worker-sharded leading axis; XLA lowers it to the actual
  wire collective, which is what the roofline reads:

    dense_psum       -> all-reduce of the dense delta (d words / worker);
                        paper-faithful semantics, no byte savings.
    sparse_allgather -> all-gather of the compressor's wire-codec payload
                        (block/flat (values, indices), bit-packed signs,
                        quantized streams -- see repro.distributed.wire) +
                        local decode-sum: the TPU-native realization of the
                        paper's "bits per node proportional to t*k"
                        accounting, for EVERY compressor in the zoo.

Both modes are bit-identical given the same compressor draws (tests assert
this): the wire format changes, Algorithm 1 does not.

Federated rounds (per-round client sampling) thread a per-worker scalar
``mask`` through :func:`compress_local`: an absent worker's message is gated
to decode-zero and its control variate stays stale, so :func:`combine_global`
needs no variant -- the 1/n mean over pre-masked messages IS the paper's
aggregation restricted to the sampled subset, preserving the running-average
invariant h_avg = (1/n) sum_i h_i.  See
docs/algorithms.md#partial-participation--stochastic-gradients for the mask
semantics and docs/wire_format.md for the payload layouts and bit accounting.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.efbv import EFBV, Downlink
from repro.distributed import wire

PyTree = Any
AGG_MODES = ("dense_psum", "sparse_allgather")


# --------------------------------------------------------------------------
# phase 1: worker-local (runs inside shard_map)
# --------------------------------------------------------------------------

def compress_local(
    algo: EFBV,
    key: Optional[jax.Array],
    grads: PyTree,
    h_local: PyTree,
    *,
    mode: str = "dense_psum",
    wire_dtype: str = "float32",
    mask: Optional[jax.Array] = None,
    worker: Optional[jax.Array] = None,
    stream: bool = False,
) -> Tuple[PyTree, PyTree]:
    """d_i = C_i(grad_i - h_i); h_i <- h_i + lam d_i.

    Returns (message, h_local_new) where message is either the dense d_i
    (mode=dense_psum) or the per-leaf wire-codec payload
    (mode=sparse_allgather; every compressor declares one -- see
    repro.distributed.wire).

    ``mask`` is this worker's scalar participation indicator for the round
    (federated mode, docs/algorithms.md): at mask = 0 the message is gated
    to decode-zero (wire.LeafCodec.mask_message / a zeroed dense d_i) and
    h_i stays STALE; at mask = 1 both gates are bitwise identities, and
    ``mask=None`` (full participation) skips them entirely.

    ``worker`` is this worker's (traced) linear index, required when
    ``algo.fleet`` is set: a heterogeneous fleet selects worker i's own
    compressor with lax.switch.  Mixed fleets need a uniform message shape,
    so they run under dense_psum only; the homogeneous fast paths are
    untouched (EFBV.make collapses a uniform fleet to fleet=None).

    ``stream=True`` (the pipelined trainer) asks codecs with an async-copy
    fused kernel to start DMAing the payload toward HBM while the control
    variate update still computes; payload bits are identical either way.
    """
    if mode not in AGG_MODES:
        raise ValueError(f"mode {mode!r} not in {AGG_MODES}")
    if algo.fleet is not None:
        if mode != "dense_psum":
            raise ValueError(
                "mixed fleets need a uniform per-worker message shape; "
                "mode='sparse_allgather' cannot stack heterogeneous "
                "payloads -- use mode='dense_psum'")
        if worker is None:
            raise ValueError("mixed-fleet compress_local needs the worker "
                             "index (worker=)")

    leaves, treedef = jax.tree.flatten(grads)
    h_leaves = treedef.flatten_up_to(h_local)
    fmt = wire.tree_format_for(algo.compressor, grads, wire_dtype=wire_dtype,
                               rules=algo.leaf_rules) \
        if mode == "sparse_allgather" else None
    if algo.leaf_rules and algo.fleet is None:
        # dense path under per-leaf rules: each leaf runs its own resolved
        # (clamped) compressor -- the dense twin of the TreeWire codecs
        dense_comps = [wire.clamp_for_leaf(
            wire.resolve_leaf(algo.leaf_rules, p, algo.compressor),
            int(g.size)) for p, g in zip(wire.leaf_paths(grads), leaves)]
    else:
        dense_comps = [algo.compressor] * len(leaves)
    msgs, h_new_leaves = [], []
    for j, (g_leaf, h_leaf) in enumerate(zip(leaves, h_leaves)):
        kj = None if key is None else jax.random.fold_in(key, j)
        if fmt is not None:
            # fused compress-and-pack through the leaf's codec: emits the
            # payload AND EFBV.worker_update (h <- h + lam d) in one pass;
            # codecs with a Pallas kernel (block-top-k, rand-k, QSGD) never
            # materialize the dense d_i in HBM.
            payload, h_leaf_new = wire.encode_update(
                fmt.leaves[j], kj, g_leaf, h_leaf, algo.lam, stream=stream)
            if mask is not None:
                payload = fmt.leaves[j].mask_message(payload, mask)
            msgs.append(payload)
        else:
            delta = g_leaf - h_leaf
            if algo.fleet is not None:
                # worker-indexed dispatch: every member's program is traced,
                # the switch picks this worker's at run time (dense outputs
                # share one shape, so the branches unify)
                if kj is None:
                    branches = tuple((lambda dl, c=c: c(None, dl))
                                     for c in algo.fleet)
                    d_leaf = jax.lax.switch(worker, branches, delta)
                else:
                    branches = tuple((lambda k_, dl, c=c: c(k_, dl))
                                     for c in algo.fleet)
                    d_leaf = jax.lax.switch(worker, branches, kj, delta)
            else:
                d_leaf = dense_comps[j](kj, delta)
            if mask is not None:
                d_leaf_wire = d_leaf * jnp.asarray(mask, d_leaf.dtype)
            else:
                d_leaf_wire = d_leaf
            msgs.append(d_leaf_wire)
            h_leaf_new = algo.worker_update(h_leaf, d_leaf)
        if mask is not None:
            h_leaf_new = jnp.where(mask > 0, h_leaf_new, h_leaf)
        h_new_leaves.append(h_leaf_new)
    h_local_new = jax.tree.unflatten(treedef, h_new_leaves)
    message = jax.tree.unflatten(treedef, msgs) if mode == "dense_psum" else msgs
    return message, h_local_new


# --------------------------------------------------------------------------
# phase 2: master aggregation (runs under GSPMD, outside shard_map)
# --------------------------------------------------------------------------

def combine_global(
    algo: EFBV,
    message_stacked,
    h_avg: PyTree,
    *,
    n_workers: int,
    mode: str = "dense_psum",
    wire_dtype: str = "float32",
    chunks: int = 1,
) -> Tuple[PyTree, PyTree]:
    """d_bar = (1/n) sum_i d_i; g = h_avg + nu d_bar; h_avg <- h_avg + lam d_bar.

    ``message_stacked`` carries a leading worker axis of size n sharded over
    (pod, data); the reduction over it IS the wire collective.

    ``chunks`` > 1 (the pipelined exchange) splits the worker axis of each
    sparse payload into that many equal slices and decode-sums them in fixed
    ascending order, so XLA can overlap the decode of early chunks with the
    transfer of late ones.  ``chunks=1`` is byte-identical to the historical
    single decode-sum; the dense path ignores chunking (one psum is one
    transfer).
    """
    ref_leaves, treedef = jax.tree.flatten(h_avg)
    if mode == "dense_psum":
        d_bar = jax.tree.map(lambda d: jnp.mean(d, axis=0), message_stacked)
    else:
        fmt = wire.tree_format_for(algo.compressor, h_avg,
                                   wire_dtype=wire_dtype,
                                   rules=algo.leaf_rules)
        d_bar_leaves = []
        for payload, codec, ref in zip(message_stacked, fmt.leaves,
                                       ref_leaves):
            # payload components carry a leading worker axis; the gather of
            # the payload is the wire, the decode-sum is local (one codec,
            # one layout, one combine for every compressor).
            dense = wire.chunked_decode_sum(codec, payload, chunks)
            d_bar_leaves.append((dense / n_workers).reshape(ref.shape))
        d_bar = jax.tree.unflatten(treedef, d_bar_leaves)
    g, h_avg_new = algo.master_update(h_avg, d_bar)
    return g, h_avg_new


def ring_allgather(message: PyTree, axis_name, n: int) -> PyTree:
    """All-gather every worker's ``message`` over ``axis_name`` as an n-hop
    ppermute ring, reconstructing the CANONICAL source order.

    Equivalent to ``jax.lax.all_gather(message, axis_name)`` bit-for-bit, but
    exposed as n-1 point-to-point hops so the pipelined trainer's chunked
    decode (:func:`combine_global` with ``chunks`` > 1) can start consuming
    early arrivals while late hops are still in flight.  Each hop h delivers
    the message of worker (i - h) mod n to worker i; writing it at index
    (i - h) mod n restores src order, so every replica sees the SAME stacked
    array and the fixed-order chunked sum stays replica-identical.
    """
    idx = jax.lax.axis_index(axis_name)
    perm = [(j, (j + 1) % n) for j in range(n)]

    def gather_leaf(leaf):
        bufs = jnp.zeros((n,) + leaf.shape, leaf.dtype)
        cur = leaf
        bufs = bufs.at[idx].set(cur)
        for hop in range(1, n):
            cur = jax.lax.ppermute(cur, axis_name, perm)
            src = (idx - hop) % n
            bufs = bufs.at[src].set(cur)
        return bufs

    return jax.tree.map(gather_leaf, message)


# --------------------------------------------------------------------------
# phase 3: master -> worker broadcast (the downlink channel)
# --------------------------------------------------------------------------

def broadcast_global(
    downlink: Downlink,
    key: Optional[jax.Array],
    params: PyTree,
    w: PyTree,
    *,
    wire_dtype: str = "float32",
) -> Tuple[PyTree, list]:
    """One downlink round: the master encodes C_s(x^{t+1} - w^t) through its
    codec and every worker applies the decoded innovation to the shared
    reconstruction w.  Returns (w_new, payloads); the payloads are what
    crosses the wire (``downlink.format_for(params).downlink_bits_per_round()``
    bits, exactly).  Both trainers and the reference driver call
    :meth:`repro.core.efbv.Downlink.broadcast` through here, so the downlink
    math lives in one place.  ``key`` must be the round's
    ``downlink_key(step_key)`` so all paths draw the same broadcast.
    """
    return downlink.broadcast(key, params, w, wire_dtype=wire_dtype)


# --------------------------------------------------------------------------
# single-call reference (used by equivalence tests, runs un-sharded)
# --------------------------------------------------------------------------

def efbv_aggregate_reference(
    algo: EFBV,
    keys: jax.Array,  # (n,) worker keys
    grads_stacked: PyTree,  # leading worker axis n
    h_stacked: PyTree,
    h_avg: PyTree,
    *,
    mode: str = "dense_psum",
    wire_dtype: str = "float32",
    masks: Optional[jax.Array] = None,  # (n,) participation mask
) -> Tuple[PyTree, PyTree, PyTree]:
    n = jax.tree.leaves(grads_stacked)[0].shape[0]
    widx = jnp.arange(n)  # threaded for the mixed-fleet lax.switch dispatch
    if masks is None:
        msg, h_new = jax.vmap(
            lambda k, g, h, i: compress_local(algo, k, g, h, mode=mode,
                                              wire_dtype=wire_dtype, worker=i)
        )(keys, grads_stacked, h_stacked, widx)
    else:
        msg, h_new = jax.vmap(
            lambda k, g, h, m, i: compress_local(algo, k, g, h, mode=mode,
                                                 wire_dtype=wire_dtype,
                                                 mask=m, worker=i)
        )(keys, grads_stacked, h_stacked, masks, widx)
    g, h_avg_new = combine_global(algo, msg, h_avg, n_workers=n, mode=mode,
                                  wire_dtype=wire_dtype)
    return g, h_new, h_avg_new
