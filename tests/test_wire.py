"""Differential tests for the fused wire-codec pipeline.

Pins, bit-for-bit: jnp oracle == fused Pallas kernels (interpret; compiled
on TPU) for block-top-k, rand-k and QSGD over whole trajectories, payload
bytes == wire.bits_per_round(), sparse_allgather == dense_psum for
representatives of every codec family, and the bidirectional trainer's
Identity-server invariant.  (Per-codec roundtrip/accounting property tests
live in tests/test_wire_codecs.py.)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from harness import (assert_bit_identical, available_pack_impls, codec_impls,
                     run_codec_trajectory, run_wire_trajectory)
from repro.core import (BlockTopK, EFBV, Identity, MixKK, Natural, QSGD,
                        RandK, SignNorm, TopK, theory)
from repro.distributed import wire
from repro.distributed.aggregate import efbv_aggregate_reference

KEY = jax.random.key(0)

# >= 3 compressor configs, incl. a padded leaf (size % block != 0) and a
# kb == block identity block
CONFIGS = [
    # (d, block, kb)
    (1024, 128, 8),
    (1000, 256, 16),   # padding path
    (640, 128, 128),   # kb == block
]


# ---------------------------------------------------------------------------
# payload producers are bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d,block,kb", CONFIGS)
def test_fused_pack_matches_oracle(d, block, kb):
    lw = wire.LeafWire(shape=(d,), size=d, block=block, kb=kb)
    g = jax.random.normal(KEY, (d,))
    h = jax.random.normal(jax.random.key(1), (d,))
    ref = wire.fused_pack(lw, g, h, 0.37, kernel="oracle")
    for impl in available_pack_impls():
        got = wire.fused_pack(lw, g, h, 0.37, kernel=impl)
        assert_bit_identical(got, ref, f"impl={impl} cfg={(d, block, kb)}")


def test_fused_pack_matches_oracle_on_ties():
    """Quantized input forces magnitude ties; selection order must still
    match jax.lax.top_k exactly."""
    lw = wire.LeafWire(shape=(512,), size=512, block=128, kb=8)
    g = jnp.round(jax.random.normal(KEY, (512,)) * 2) / 2
    ref = wire.fused_pack(lw, g, jnp.zeros_like(g), 0.5, kernel="oracle")
    for impl in available_pack_impls():
        got = wire.fused_pack(lw, g, jnp.zeros_like(g), 0.5, kernel=impl)
        assert_bit_identical(got, ref, f"impl={impl} (ties)")


def test_fused_pack_mixed_dtypes_bit_identical():
    """bf16 grads against f32 control variates: the kernel must subtract in
    f32 without pre-rounding h, or backends diverge."""
    lw = wire.LeafWire(shape=(512,), size=512, block=128, kb=8)
    g = jax.random.normal(KEY, (512,)).astype(jnp.bfloat16)
    h = jax.random.normal(jax.random.key(1), (512,))  # f32
    ref = wire.fused_pack(lw, g, h, 0.37, kernel="oracle")
    for impl in available_pack_impls():
        got = wire.fused_pack(lw, g, h, 0.37, kernel=impl)
        assert_bit_identical(got, ref, f"impl={impl} (mixed dtypes)")


def test_fused_pack_unaligned_block_falls_back_to_oracle():
    """block % 128 != 0 has no Pallas tiling; auto dispatch must fall back
    to the (bit-identical) oracle, explicit kernel requests must error."""
    lw = wire.LeafWire(shape=(300,), size=300, block=100, kb=4)
    g = jax.random.normal(KEY, (300,))
    h = jnp.zeros((300,))
    ref = wire.fused_pack(lw, g, h, 0.5, kernel="oracle")
    got = wire.fused_pack(lw, g, h, 0.5)  # auto
    assert_bit_identical(got, ref, "auto fallback, block=100")
    with pytest.raises(ValueError, match="block % 128"):
        wire.fused_pack(lw, g, h, 0.5, kernel="interpret")


def test_pack_oracle_matches_compressor_encode():
    """wire.pack_oracle IS BlockTopK.encode (the layout has one spec)."""
    d, block, kb = 1000, 256, 16
    lw = wire.LeafWire(shape=(d,), size=d, block=block, kb=kb)
    x = jax.random.normal(KEY, (d,))
    vals, idx = wire.pack_oracle(lw, x)
    ov, oi = BlockTopK(block, kb).encode(None, x)
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(ov))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(oi))
    # and unpack reproduces the dense compressor output
    np.testing.assert_array_equal(
        np.asarray(wire.unpack(lw, vals, idx)),
        np.asarray(BlockTopK(block, kb)(None, x)))


# ---------------------------------------------------------------------------
# whole-trajectory bit-identity (the acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d,block,kb", CONFIGS)
def test_trajectory_bit_identical_across_backends(d, block, kb):
    """(x, h) trajectories of Algorithm 1 over the sparse wire are
    bit-identical between the jnp oracle and the fused Pallas kernel."""
    kw = dict(steps=6, n=4, d=d, block=block, kb=kb,
              lam=0.9, nu=1.0, gamma=0.1)
    ref = run_wire_trajectory("oracle", **kw)
    for impl in available_pack_impls():
        got = run_wire_trajectory(impl, **kw)
        assert_bit_identical((got["x"], got["h"], got["payload"]),
                             (ref["x"], ref["h"], ref["payload"]),
                             f"impl={impl} cfg={(d, block, kb)}")
    # sanity: the trajectory actually moves
    assert float(jnp.linalg.norm(ref["x"][-1])) > 0


# ---------------------------------------------------------------------------
# whole-trajectory bit-identity for the other fused-kernel codecs
# ---------------------------------------------------------------------------

CODEC_TRAJ = [RandK(8), QSGD(16), QSGD(400)]


@pytest.mark.parametrize("comp", CODEC_TRAJ, ids=lambda c: repr(c))
def test_codec_trajectory_bit_identical_across_backends(comp):
    """(x, h, payload) trajectories of Algorithm 1 over each fused-kernel
    codec are bit-identical between the jnp oracle and the Pallas kernel
    (interpret on CPU, compiled on TPU) -- the rand-k/QSGD analogue of the
    block-top-k test above."""
    d, n = 600, 3
    lam = theory.lambda_star(comp.eta(d), comp.omega(d))
    nu = theory.nu_star(comp.eta(d), comp.omega(d) / n)
    kw = dict(compressor=comp, steps=5, n=n, d=d, lam=lam, nu=nu, gamma=0.05)
    ref = run_codec_trajectory("oracle", **kw)
    impls = codec_impls(ref["codec"])
    assert impls != ["oracle"], "fused-kernel codec expected"
    for impl in impls[1:]:
        got = run_codec_trajectory(impl, **kw)
        assert_bit_identical((got["x"], got["h"], got["payload"]),
                             (ref["x"], ref["h"], ref["payload"]),
                             f"impl={impl} comp={comp!r}")
    assert float(jnp.linalg.norm(ref["x"][-1])) > 0


def test_oracle_only_codecs_run_trajectories():
    """Codecs without a fused kernel (sign, natural, top-k, ...) still run
    whole trajectories through the same harness, and an explicit kernel
    request on them errors instead of silently diverging."""
    for comp in [SignNorm(), Natural(), TopK(6), MixKK(2, 6)]:
        res = run_codec_trajectory("oracle", compressor=comp, steps=3, n=2,
                                   d=96, lam=0.5, nu=0.5, gamma=0.05)
        assert codec_impls(res["codec"]) == ["oracle"]
        assert np.all(np.isfinite(np.asarray(res["x"])))
        with pytest.raises(ValueError):
            wire.encode_update(res["codec"], KEY, jnp.zeros(96),
                               jnp.zeros(96), 0.5, kernel="interpret")


def test_codec_kernel_hlo_one_pass():
    """AOT TPU HLO proof for the new fused kernels: rand-k's custom call
    emits ONLY h_out; QSGD's emits only the quantized stream + h_out."""
    bench = pytest.importorskip("benchmarks.compressor_bench")
    try:
        rk = bench.randk_update_hlo_report(nr=16, cols=256, k=32)
        qs = bench.qsgd_pack_hlo_report(nr=32, cols=256, s=16)
    except Exception as e:  # pragma: no cover - jax.export surface drift
        pytest.skip(f"TPU AOT export unavailable: {type(e).__name__}")
    assert rk["h_out_only"], rk
    assert qs["one_dense_f32"] and qs["quantized_stream"], qs


# ---------------------------------------------------------------------------
# exact bit accounting
# ---------------------------------------------------------------------------

def test_payload_bytes_equal_bits_per_round():
    """Measured payload bytes == wire.bits_per_round() EXACTLY."""
    comp = BlockTopK(256, 16)
    tree = {"w": jax.random.normal(KEY, (37, 29)),
            "b": jax.random.normal(jax.random.key(1), (65,))}
    fmt = wire.format_for(comp, tree)
    payload = []
    for lw, leaf in zip(fmt.leaves, jax.tree.leaves(tree)):
        (vals, idx), _ = wire.fused_pack(lw, leaf, jnp.zeros_like(leaf), 1.0)
        payload.append((vals, idx))
    assert 8 * wire.payload_bytes(payload) == fmt.bits_per_round()
    # consistent with the compressor's own Wire(words=...) accounting
    words = sum(comp.wire(l.size).words for l in fmt.leaves)
    assert fmt.bits_per_round() == 32 * words
    # and per-round totals scale linearly in n (paper: bits ~ t*k per node)
    assert fmt.bits_per_round(n_workers=8) == 8 * fmt.bits_per_round()


def test_trajectory_payload_accounting():
    res = run_wire_trajectory("oracle", steps=2, n=3, d=1000, block=128,
                              kb=4, lam=1.0, nu=1.0, gamma=0.1)
    vals, idx = res["payload"]
    per_worker = vals[0].nbytes + idx[0].nbytes
    fmt = wire.WireFormat((res["lw"],))
    assert 8 * per_worker == fmt.bits_per_round()


def test_fused_kernel_never_materializes_dense_d():
    """The one-HBM-pass claim, proven from the TPU-lowered HLO (Mosaic
    lowering is AOT, so this runs on CPU hosts): the fused pack kernel's
    custom call emits only (values, indices, h_out); the unfused dense
    kernel's result IS the dense d."""
    bench = pytest.importorskip("benchmarks.compressor_bench")
    try:
        rep = bench.fused_pack_hlo_report(nb=16, block=256, kb=8)
    except Exception as e:  # pragma: no cover - jax.export surface drift
        pytest.skip(f"TPU AOT export unavailable: {type(e).__name__}")
    assert rep["fused_one_hbm_pass"], rep
    assert rep["unfused_dense_output"], rep


# ---------------------------------------------------------------------------
# wire modes and the sharded trainer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("comp", [
    BlockTopK(64, 8), TopK(20), RandK(12), QSGD(16), SignNorm(), Natural(),
    MixKK(4, 8), Identity(),
], ids=lambda c: repr(c))
def test_sparse_allgather_equals_dense_psum(comp):
    """Same compressor draws -> the wire format must not change Algorithm 1
    (the payload path is exercised through compress_local/combine_global)
    -- for a representative of every codec family."""
    n, shape = 4, (32, 16)
    algo = EFBV(comp, lam=0.8, nu=0.9)
    grads = {"w": jax.random.normal(KEY, (n,) + shape)}
    h = {"w": jnp.zeros((n,) + shape)}
    h_avg = {"w": jnp.zeros(shape)}
    keys = jax.random.split(KEY, n)  # repro: noqa(prng-reuse) -- deterministic fixture, draws need not be independent
    dense = efbv_aggregate_reference(algo, keys, grads, h, h_avg,
                                     mode="dense_psum")
    sparse = efbv_aggregate_reference(algo, keys, grads, h, h_avg,
                                      mode="sparse_allgather")
    for a, b in zip(jax.tree.leaves(dense), jax.tree.leaves(sparse)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_bidirectional_identity_downlink_matches_unidirectional():
    """With an Identity downlink the bidirectional trainer reproduces the
    unidirectional trajectory BIT-FOR-BIT: the lossless f32 broadcast
    assigns w = x verbatim (no x_hat + (x - x_hat) re-rounding), so the
    workers' gradients see bit-identical params every round."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import Downlink
    from repro.launch.mesh import make_mesh
    from repro.optim import constant, sgd
    from repro.train import (init_train_state, make_train_step,
                             train_state_shardings)

    mesh = make_mesh((1, 1))
    D = 16
    params = {"w": jax.random.normal(KEY, (D,)) * 0.1}
    specs = {"w": P(None)}

    def loss_fn(p, batch):
        return jnp.mean((batch["x"] @ p["w"] - batch["y"]) ** 2), {}

    algo = EFBV(BlockTopK(8, 2), lam=0.9, nu=0.9)
    opt = sgd(constant(0.05))

    def run(downlink):
        # fresh copies: the jitted step donates its state buffers
        st = init_train_state(jax.tree.map(jnp.array, params), opt, mesh,
                              bidirectional=downlink is not None)
        sh = train_state_shardings(mesh, specs, st)
        st = jax.tree.map(lambda x, s: jax.device_put(x, s), st, sh)
        step = make_train_step(loss_fn, opt, algo, mesh,
                               agg_mode="sparse_allgather",
                               downlink=downlink)
        for i in range(5):
            kb_ = jax.random.fold_in(jax.random.key(42), i)
            x = jax.random.normal(kb_, (4, D))
            batch = {"x": x, "y": x @ jnp.ones((D,)) * 0.3}
            st, m = step(st, batch, jax.random.fold_in(KEY, i))
        return st, m

    st_uni, _ = run(None)
    st_bi, m_bi = run(Downlink(Identity()))
    np.testing.assert_array_equal(np.asarray(st_uni.params["w"]),
                                  np.asarray(st_bi.params["w"]))
    np.testing.assert_array_equal(np.asarray(st_uni.h["w"]),
                                  np.asarray(st_bi.h["w"]))
    np.testing.assert_array_equal(np.asarray(st_bi.params["w"]),
                                  np.asarray(st_bi.w["w"]))
    assert float(m_bi["w_err"]) == 0.0


@pytest.mark.slow
def test_wire_trajectory_1_vs_8_devices():
    """Harness leg: the 8-fake-device shard_map trainer matches the
    single-device vmap reference running the same Algorithm 1 over the same
    sparse wire.  Per-worker packing is deterministic and bit-identical; the
    cross-device d_bar mean is an all-reduce whose f32 summation order
    differs from the single-device reduction, so the trajectories agree to
    reduction-order tolerance (bit-identity holds within a fixed device
    count -- the backend tests above)."""
    from conftest import run_with_devices
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import EFBV, BlockTopK
        from repro.optim import sgd, constant
        from repro.train import (make_train_step, init_train_state,
                                 train_state_shardings)
        from repro.launch.mesh import make_mesh
        from repro.distributed.aggregate import efbv_aggregate_reference
        from repro.optim.optimizers import apply_updates

        D, n, key = 16, 8, jax.random.key(0)
        params = {"w": jax.random.normal(key, (D,)) * 0.1}
        algo = EFBV(BlockTopK(8, 2), lam=0.8, nu=0.9)
        opt = sgd(constant(0.05))

        def loss_fn(p, batch):
            return jnp.mean((batch["x"] @ p["w"] - batch["y"]) ** 2), {}

        def batches(i):
            kb = jax.random.fold_in(jax.random.key(42), i)
            x = jax.random.normal(kb, (16, D))
            return x, x @ jnp.ones((D,)) * 0.3

        mesh = make_mesh((8, 1))
        st = init_train_state(jax.tree.map(jnp.array, params), opt, mesh)
        sh = train_state_shardings(mesh, {"w": P(None)}, st)
        st = jax.tree.map(lambda x, s: jax.device_put(x, s), st, sh)
        step = make_train_step(loss_fn, opt, algo, mesh,
                               agg_mode="sparse_allgather")
        for i in range(6):
            x, y = batches(i)
            batch = {"x": jax.device_put(x, NamedSharding(mesh, P("data"))),
                     "y": jax.device_put(y, NamedSharding(mesh, P("data")))}
            st, _ = step(st, batch, jax.random.fold_in(key, i))

        w = jax.tree.map(jnp.array, params)["w"]
        h, h_avg = jnp.zeros((n, D)), jnp.zeros((D,))
        opt_state = opt.init({"w": w})
        for i in range(6):
            x, y = batches(i)
            xw, yw = x.reshape(n, 2, D), y.reshape(n, 2)
            grads = jax.vmap(lambda xb, yb: jax.grad(
                lambda p: jnp.mean((xb @ p - yb) ** 2))(w))(xw, yw)
            keys = jax.vmap(lambda j: jax.random.fold_in(
                jax.random.fold_in(key, i), j))(jnp.arange(n))
            g_hat, hh, hav = efbv_aggregate_reference(
                algo, keys, {"w": grads}, {"w": h}, {"w": h_avg},
                mode="sparse_allgather")
            h, h_avg = hh["w"], hav["w"]
            updates, opt_state = opt.update(g_hat, opt_state, {"w": w})
            w = apply_updates({"w": w}, updates)["w"]

        np.testing.assert_allclose(np.asarray(st.params["w"]),
                                   np.asarray(w), rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(st.h["w"]), np.asarray(h),
                                   rtol=1e-6, atol=1e-6)
        print("WIRE_1V8_OK")
    """, n_devices=8)
    assert "WIRE_1V8_OK" in out


@pytest.mark.parametrize("trainer", ["shard_map", "fsdp"])
def test_bidirectional_compressed_downlink_tracks_model(trainer):
    """With a contractive downlink C_s, w tracks the model: the
    reconstruction error stays bounded and training still reduces the loss
    -- in BOTH trainers (the FSDP path shares broadcast_global)."""
    from jax.sharding import PartitionSpec as P
    from repro.core import Downlink
    from repro.launch.mesh import make_mesh
    from repro.optim import constant, sgd
    from repro.train import (fsdp_state_shardings, init_train_state,
                             make_train_step, make_train_step_fsdp,
                             train_state_shardings)

    mesh = make_mesh((1, 1))
    D = 32
    params = {"w": jnp.zeros((D,))}
    specs = {"w": P(None)}

    def loss_fn(p, batch):
        return jnp.mean((batch["x"] @ p["w"] - batch["y"]) ** 2), {}

    algo = EFBV(BlockTopK(8, 4), lam=1.0, nu=1.0)
    opt = sgd(constant(0.1))
    st = init_train_state(params, opt, mesh, bidirectional=True)
    make_sh = (fsdp_state_shardings if trainer == "fsdp"
               else train_state_shardings)
    sh = make_sh(mesh, specs, st)
    st = jax.tree.map(lambda x, s: jax.device_put(x, s), st, sh)
    make_step = (make_train_step_fsdp if trainer == "fsdp"
                 else make_train_step)
    step = make_step(loss_fn, opt, algo, mesh,
                     agg_mode="sparse_allgather",
                     downlink=Downlink(BlockTopK(8, 4)))
    losses = []
    for i in range(30):
        kb_ = jax.random.fold_in(jax.random.key(7), i)
        x = jax.random.normal(kb_, (8, D))
        batch = {"x": x, "y": x @ (jnp.arange(D) / D)}
        st, m = step(st, batch, jax.random.fold_in(KEY, i))
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.5 * losses[0], losses
    assert float(m["w_err"]) < 1.0
