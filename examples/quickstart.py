"""Quickstart: EF-BV through the declarative ExperimentSpec API in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

ONE frozen, serializable spec declares the whole experiment -- compressor,
algorithm parametrization, problem, workers, rounds -- and
``repro.core.build(spec)`` turns it into a runnable ``Run``: auto-tuned
(lam*, nu*, gamma) from the theory (Remark 1 -- nothing left to tune) and
driven through the unified reference driver.  Swapping EF-BV for EF21 or
DIANA is a one-field change, not a different code path.  See docs/api.md.
"""

import dataclasses

from repro.core import ExperimentSpec, build

# the paper's compressor comp-(1, d/2): biased AND random -- outside both
# classical compressor classes, but in C(eta, omega)
spec = ExperimentSpec(compressor="comp:1,32", mode="efbv",
                      backend="reference", problem="logreg",
                      n=100, d=64, steps=3000, seed=0)
print(f"spec fingerprint={spec.fingerprint()}  (JSON round-trips losslessly:"
      f" {ExperimentSpec.from_json(spec.to_json()) == spec})")

prob = build(spec).problem_instance()   # heterogeneous logreg (Appendix C)
x_star, f_star = prob.solve()

comp = build(spec).compressor
d = spec.d
print(f"comp-(1, {d // 2}): eta={comp.eta(d):.3f} omega={comp.omega(d):.1f} "
      f"(not contractive: eta^2 + omega = {comp.eta(d)**2 + comp.omega(d):.1f} > 1)")

for mode in ["efbv", "ef21", "diana"]:
    run = build(dataclasses.replace(spec, mode=mode))
    t = run.tuned
    res = run.reference(record=lambda x: prob.f(x) - f_star)
    print(f"{mode:6s} lam={t.lam:.4f} nu={t.nu:.4f} "
          f"f-f* after {spec.steps} rounds: {float(res.metrics[-1]):.3e} "
          f"({run.round_bits()['up']} uplink bits/round, all {spec.n} workers)")

print("\nEF-BV exploits omega_av = omega/n (independent compressors): larger "
      "nu and gamma than EF21,\nwhile still handling the biased compressor "
      "DIANA's classical analysis does not cover.")
