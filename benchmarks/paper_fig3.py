"""Paper Figure 3 / Appendix C.3: nonconvex logistic regression with the
regularizer lam * sum_j x_j^2 / (1 + x_j^2); EF-BV vs EF21 under Theorem 3
stepsizes.  Metric: best gradient norm reached (Thm 3 bounds E||grad f||^2)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import KEY, make_problem
from repro.core import CompKK, EFBV, run_reference, tune_for


def run_bench(fast: bool = True, n: int = 200):
    steps = 1200 if fast else 8000
    rows = []
    for name in (["mushrooms"] if fast else ["mushrooms", "phishing", "a9a", "w8a"]):
        prob = make_problem(name, n=n, mu=0.0, lam_nc=0.1)
        d = prob.d
        comp = CompKK(1, d // 2)
        res = {}
        for mode in ["efbv", "ef21"]:
            t = tune_for(comp, d, prob.n, mode=mode, regime="nonconvex",
                         L=prob.L(), Ltilde=prob.L_tilde())
            algo = EFBV(comp, lam=t.lam, nu=t.nu)
            m = run_reference(algo=algo, grad_fn=lambda _k, x: prob.grads(x),
                              x0=jnp.zeros(d), gamma=t.gamma, steps=steps,
                              key=KEY, n=prob.n,
                              record=lambda x: jnp.sum(prob.grad(x) ** 2)
                              ).metrics
            res[mode] = float(np.min(np.asarray(m)))
        rows.append({
            "name": f"fig3/{name}/min_grad_norm2",
            "us_per_call": "",
            "derived": f"efbv={res['efbv']:.3e};ef21={res['ef21']:.3e};"
                       f"efbv_better={bool(res['efbv'] <= res['ef21'] * 1.05)}",
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run_bench(fast=True))
