"""Trip-count-aware cost model over optimized (post-SPMD) HLO text.

Why: on the CPU backend, ``compiled.cost_analysis()`` counts a while-loop
body ONCE -- a lax.scan over 40 layers contributes 1/40th of its real cost,
which breaks the roofline for every scan-based model here.  This module
re-derives the three roofline numerators directly from the compiled HLO:

  flops       -- 2*M*N*K per dot (descending into fusion computations and
                 multiplying nested while bodies by their trip counts),
  hbm bytes   -- sum of operand+result bytes of *top-level* instructions per
                 computation (XLA's fusion boundaries are exactly the HBM
                 materialization points), trip-count weighted,
  wire bytes  -- per collective kind, with all-reduce counted as 2x payload
                 (ring reduce-scatter + all-gather), all-gather / all-to-all /
                 reduce-scatter / collective-permute as 1x payload.

All numbers are per-device (the HLO is the partitioned module).
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "u64": 8,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"\}?\s*([a-z][\w\-]*)\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    rhs: str
    opcode: str
    result_type: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    types: Dict[str, str]  # value name -> type string (params + results)


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    entry_name = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if current is None:
            if line.endswith("{"):
                m = _COMP_HDR.match(line.strip())
                if m:
                    current = Computation(m.group(2), [], {})
                    if m.group(1):
                        entry_name = m.group(2)
                    # parameter types from the header signature
                    for pm in re.finditer(r"([\w\.\-]+)\s*:\s*((?:\([^)]*\))|[\w\[\],]+)",
                                          m.group(3)):
                        current.types[pm.group(1)] = pm.group(2)
            continue
        if line.strip() == "}":
            comps[current.name] = current
            current = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, rhs = m.group(1), m.group(2)
            om = _OPCODE_RE.search(rhs)
            opcode = om.group(1) if om else ""
            idx = rhs.find(opcode + "(") if opcode else -1
            rtype = rhs[:idx].strip() if idx > 0 else rhs
            ins = Instr(name, rhs, opcode, rtype)
            current.instrs.append(ins)
            current.types[name] = rtype
    if comps and entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _operand_names(ins: Instr) -> List[str]:
    """Operand names of an instruction, robust to both operand syntaxes:
    bare (``dot(%a, %b)``) and inline-typed (``dot(f32[32,64]{1,0} %a, ...)``
    -- older XLA text).  Commas inside ``[]``/``{}`` (shape dims, layouts)
    are not operand separators."""
    idx = ins.rhs.find(ins.opcode + "(")
    if idx < 0:
        return []
    depth, bracket, args, cur = 0, 0, [], ""
    for ch in ins.rhs[idx + len(ins.opcode):]:
        if ch == "(":
            depth += 1
            if depth == 1:
                continue
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if depth < 1:
            continue
        if ch in "[{":
            bracket += 1
        elif ch in "]}":
            bracket -= 1
        if ch == "," and depth == 1 and bracket == 0:
            args.append(cur)
            cur = ""
        else:
            cur += ch
    if cur.strip():
        args.append(cur)
    out = []
    for a in args:
        a = a.strip()
        named = re.findall(r"%([\w\.\-]+)", a)
        if named:
            out.append(named[-1])
            continue
        toks = a.split()
        if toks and re.fullmatch(r"[\w\.\-]+", toks[-1]):
            out.append(toks[-1])
    return out


def _called(ins: Instr) -> List[str]:
    out = []
    for key in ("calls=", "body=", "to_apply=", "condition="):
        for m in re.finditer(re.escape(key) + r"%?([\w\.\-]+)", ins.rhs):
            out.append(m.group(1))
    m = re.search(r"branch_computations=\{([^}]*)\}", ins.rhs)
    if m:
        out.extend(x.strip().lstrip("%") for x in m.group(1).split(","))
    return out


def trip_count(cond: Computation) -> int:
    consts: Dict[str, int] = {}
    best = None
    for ins in cond.instrs:
        m = re.search(r"constant\((\d+)\)", ins.rhs)
        if m:
            consts[ins.name] = int(m.group(1))
    for ins in cond.instrs:
        if "compare(" in ins.rhs:
            for op in _operand_names(ins):
                if op in consts:
                    best = consts[op]
    if best is None:
        best = max(consts.values(), default=1)
    return max(best, 1)


def dot_flops(ins: Instr, types: Dict[str, str]) -> float:
    res = _first_shape_dims(ins.result_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rhs)
    ops = _operand_names(ins)
    k = 1
    if m and ops:
        lhs_dims = _first_shape_dims(types.get(ops[0], ""))
        for c in (int(d) for d in m.group(1).split(",") if d):
            if c < len(lhs_dims):
                k *= lhs_dims[c]
    return 2.0 * float(math.prod(res) if res else 0) * float(k)


def _io_bytes(ins: Instr, types: Dict[str, str]) -> float:
    """HBM traffic of one materialized op: result bytes + operand bytes.

    Slicing/update ops only *touch* the slice, not the whole operand -- a
    dynamic-slice of one layer's weights from the (L, ...) scan stack reads
    the slice, not L x it.  Counting full operands there inflated the memory
    term ~100x on deep models (hypothesis->measure cycle recorded in
    EXPERIMENTS §Perf methodology)."""
    op = ins.opcode
    res = _shape_bytes(ins.result_type)
    ops = _operand_names(ins)
    if op in ("dynamic-slice", "slice"):
        return float(2 * res)  # read slice + write result
    if op == "gather":
        idx = _shape_bytes(types.get(ops[1], "")) if len(ops) > 1 else 0
        return float(2 * res + idx)
    if op == "dynamic-update-slice":
        upd = _shape_bytes(types.get(ops[1], "")) if len(ops) > 1 else 0
        return float(2 * upd)  # in-place: read+write the update region
    if op == "scatter":
        upd = _shape_bytes(types.get(ops[2], "")) if len(ops) > 2 else res
        idx = _shape_bytes(types.get(ops[1], "")) if len(ops) > 1 else 0
        return float(3 * upd + idx)  # read-modify-write of touched region
    total = res
    for name in ops:
        total += _shape_bytes(types.get(name, ""))
    return float(total)


_SLICING = ("dynamic-slice", "slice", "gather")


def _param_names_of(comp: "Computation") -> Dict[int, str]:
    out: Dict[int, str] = {}
    for b_ins in comp.instrs:
        m = re.search(r"parameter\((\d+)\)", b_ins.rhs)
        if m:
            out[int(m.group(1))] = b_ins.name
    return out


def _sliced_only_bytes(body: "Computation", pname: str,
                       comps: Dict[str, "Computation"], seen) -> Optional[float]:
    """Bytes actually read from parameter ``pname`` of ``body`` when its
    every use is a slicing op -- descending through nested fusion/call
    wrappers (older XLA wraps the scan-stack dynamic-slice in a parallel
    call computation).  None if any consumer reads the full operand."""
    key = (body.name, pname)
    if key in seen:
        return None
    seen = seen | {key}
    consumers = [b for b in body.instrs if pname in _operand_names(b)]
    if not consumers:
        return None  # conservatively charge the full operand
    total = 0.0
    for c in consumers:
        if c.opcode in _SLICING:
            total += _shape_bytes(c.result_type)
        elif c.opcode in ("fusion", "call"):
            called = [comps[x] for x in _called(c) if x in comps]
            if not called:
                return None
            inner = called[0]
            inner_params = _param_names_of(inner)
            # the operand may be passed at several positions; every one must
            # be slice-only inside the callee
            positions = [i for i, o in enumerate(_operand_names(c))
                         if o == pname]
            for pos in positions:
                inner_pname = inner_params.get(pos)
                if inner_pname is None:
                    return None
                sub = _sliced_only_bytes(inner, inner_pname, comps, seen)
                if sub is None:
                    return None
                total += sub
        else:
            return None
    return total


def _fusion_io_bytes(ins: Instr, types: Dict[str, str],
                     body: Optional["Computation"],
                     comps: Optional[Dict[str, "Computation"]] = None) -> float:
    """Fusion boundary traffic with slice-awareness: when a fusion *parameter*
    is only consumed by slicing ops inside the body (the scan-stack weight
    lookup pattern), charge the slice sizes, not the full stacked operand."""
    ops = _operand_names(ins)
    # in-place accumulation pattern: fusion rooted in dynamic-update-slice
    # aliases its big buffer operand -- traffic is the update region, not the
    # whole (L, ...) stack (and the result is the aliased buffer, also not
    # re-written in full).
    root = body.instrs[-1] if (body and body.instrs) else None
    if root is not None and root.opcode == "dynamic-update-slice":
        upd_ops = _operand_names(root)
        upd = _shape_bytes(body.types.get(upd_ops[1], "")) if len(upd_ops) > 1 \
            else 0
        small = 0
        res_b = _shape_bytes(ins.result_type)
        for name in ops:
            b = _shape_bytes(types.get(name, ""))
            if b != res_b:  # skip the aliased buffer itself
                small += min(b, res_b)
        return float(2 * upd + small)

    total = _shape_bytes(ins.result_type)
    if body is None:
        for name in ops:
            total += _shape_bytes(types.get(name, ""))
        return float(total)
    # map parameter index -> param instr name inside the body
    param_names = _param_names_of(body)
    for i, name in enumerate(ops):
        full = _shape_bytes(types.get(name, ""))
        pname = param_names.get(i)
        if pname is None:
            total += full
            continue
        sliced = _sliced_only_bytes(body, pname, comps or {}, frozenset())
        total += full if sliced is None else sliced
    return float(total)


_COLL_WEIGHT = {
    "all-reduce": 2.0,        # ring RS + AG
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SKIP_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "",
}


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_breakdown: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.hbm_bytes += other.hbm_bytes
        self.coll_bytes += other.coll_bytes
        for k, v in other.coll_breakdown.items():
            self.coll_breakdown[k] = self.coll_breakdown.get(k, 0.0) + v
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.hbm_bytes * f, self.coll_bytes * f,
                    {k: v * f for k, v in self.coll_breakdown.items()})


def _fusion_flops(comp: Computation, comps, memo) -> float:
    if comp.name in memo:
        return memo[comp.name]
    memo[comp.name] = 0.0
    total = 0.0
    for ins in comp.instrs:
        if ins.opcode == "dot":
            total += dot_flops(ins, comp.types)
        elif ins.opcode == "convolution":
            total += 2.0 * float(math.prod(_first_shape_dims(ins.result_type)) or 0)
        elif ins.opcode in ("fusion", "call"):
            for c in _called(ins):
                if c in comps:
                    total += _fusion_flops(comps[c], comps, memo)
    memo[comp.name] = total
    return total


def computation_cost(comp: Computation, comps: Dict[str, Computation],
                     memo: Dict[str, Cost],
                     flop_memo: Dict[str, float]) -> Cost:
    if comp.name in memo:
        return memo[comp.name]
    memo[comp.name] = Cost()  # cycle guard
    total = Cost()
    for ins in comp.instrs:
        op = ins.opcode
        if op == "while":
            bm = re.search(r"body=%?([\w\.\-]+)", ins.rhs)
            cm = re.search(r"condition=%?([\w\.\-]+)", ins.rhs)
            trips = trip_count(comps[cm.group(1)]) if (cm and cm.group(1) in comps) else 1
            if bm and bm.group(1) in comps:
                total += computation_cost(comps[bm.group(1)], comps, memo,
                                          flop_memo).scaled(trips)
            continue
        if op == "conditional":
            for c in _called(ins):
                if c in comps:
                    total += computation_cost(comps[c], comps, memo, flop_memo)
            continue
        if op in ("fusion", "call"):
            called = [comps[c] for c in _called(ins) if c in comps]
            for c in called:
                total.flops += _fusion_flops(c, comps, flop_memo)
            total.hbm_bytes += _fusion_io_bytes(
                ins, comp.types, called[0] if called else None, comps)
            continue
        if op == "dot":
            total.flops += dot_flops(ins, comp.types)
            total.hbm_bytes += _io_bytes(ins, comp.types)
            continue
        if op == "convolution":
            total.flops += 2.0 * float(math.prod(_first_shape_dims(ins.result_type)) or 0)
            total.hbm_bytes += _io_bytes(ins, comp.types)
            continue
        base = op.replace("-start", "")
        if base in _COLL_WEIGHT and not op.endswith("-done"):
            payload = _shape_bytes(ins.result_type)
            w = _COLL_WEIGHT[base]
            total.coll_bytes += payload * w
            total.coll_breakdown[base] = total.coll_breakdown.get(base, 0.0) \
                + payload * w
            total.hbm_bytes += _io_bytes(ins, comp.types)
            continue
        if op in _SKIP_OPS or op.endswith("-done"):
            continue
        total.hbm_bytes += _io_bytes(ins, comp.types)
    memo[comp.name] = total
    return total


def hlo_cost(hlo_text: str) -> Cost:
    comps = parse_computations(hlo_text)
    entry = comps.get("__entry__")
    if entry is None:
        if not comps:
            return Cost()
        entry = max(comps.values(), key=lambda c: len(c.instrs))
    return computation_cost(entry, comps, {}, {})
