"""Compressor micro-benchmarks (us/call on this host) incl. the Pallas
block-top-k kernel (interpret mode on CPU) vs its XLA oracle, the
packed-vs-dense wire pipeline comparison (one HBM pass, proven from the
TPU-lowered HLO), and measured payload bytes vs theoretical bits_per_round
for EVERY registered wire codec -- all compressors have one."""

from __future__ import annotations

import functools
import re

import jax
import jax.numpy as jnp

from benchmarks.common import KEY, timeit
from repro.core import (BlockTopK, CompKK, Identity, MixKK, Natural, QSGD,
                        RandK, SignNorm, TopK)
from repro.distributed import wire
from repro.kernels import ops, ref


def run(fast: bool = True):
    d = 1 << 16
    x = jax.random.normal(KEY, (d,))
    rows = []
    cases = [
        ("topk_1pc", jax.jit(lambda k, v: TopK(d // 100)(k, v))),
        ("randk_1pc", jax.jit(lambda k, v: RandK(d // 100)(k, v))),
        ("comp_k_kp", jax.jit(lambda k, v: CompKK(d // 100, d // 2)(k, v))),
        ("block_topk_core", jax.jit(lambda k, v: BlockTopK(1024, 16)(k, v))),
        ("natural", jax.jit(lambda k, v: Natural()(k, v))),
        ("qsgd_s16", jax.jit(lambda k, v: QSGD(16)(k, v))),
        ("block_topk_ref", jax.jit(lambda k, v: ref.block_topk_ref(v, 1024, 16))),
    ]
    iters = 5 if fast else 30
    for name, fn in cases:
        us = timeit(fn, KEY, x, iters=iters)
        rows.append({"name": f"compressor/{name}", "us_per_call": f"{us:.1f}",
                     "derived": f"d={d}"})
    # pallas kernel (interpret on CPU -- not a speed claim, a parity check)
    us = timeit(lambda v: ops.block_topk(v, block=1024, kb=16), x, iters=3)
    rows.append({"name": "compressor/block_topk_pallas_interpret",
                 "us_per_call": f"{us:.1f}", "derived": "interpret=True"})
    rows.extend(packed_vs_dense(fast=fast))
    rows.extend(codec_payload_rows())
    return rows


# ---------------------------------------------------------------------------
# measured payload bytes vs theoretical bits for every registered codec
# ---------------------------------------------------------------------------

def codec_payload_rows(d: int = 1 << 16):
    """Every compressor has a wire codec; measure the bytes its payload
    actually occupies and pin them against the exact bits_per_round
    accounting and the fp32 dense baseline.  QSGD and natural compression
    must land at <= 1/3 of dense fp32 (acceptance criterion)."""
    x = jax.random.normal(KEY, (d,))
    dense_bytes = 4 * d
    cases = [
        ("identity", Identity()),
        ("topk_1pc", TopK(d // 100)),
        ("randk_1pc", RandK(d // 100)),
        ("comp_k_kp", CompKK(d // 100, d // 10)),
        ("mix_k_kp", MixKK(d // 200, d // 200)),
        ("block_topk", BlockTopK(1024, 16)),
        ("sign", SignNorm()),
        ("natural", Natural()),
        ("qsgd_s16", QSGD(16)),
    ]
    rows = []
    for name, comp in cases:
        codec = wire.codec_of(comp, (d,), d)
        payload = codec.encode(KEY, x)
        measured = wire.payload_bytes(payload)
        assert 8 * measured == codec.payload_bits, (name, measured)
        ratio = measured / dense_bytes
        if name in ("qsgd_s16", "natural"):
            assert ratio <= 1 / 3, (name, ratio)
        rows.append({
            "name": f"wire/codec_{name}",
            "us_per_call": "",
            "derived": f"kind={codec.kind} payload_bytes={measured} "
                       f"bits_per_round={codec.payload_bits} "
                       f"vs_dense_fp32={ratio:.4f}x",
        })
    return rows


# ---------------------------------------------------------------------------
# packed vs dense wire pipeline
# ---------------------------------------------------------------------------

def _custom_call_result_types(mlir_text: str):
    """Result tensor types of the (single) tpu_custom_call in an exported
    module, e.g. ['tensor<64x16xf32>', 'tensor<64x16xi32>', ...]."""
    line = next(l for l in mlir_text.splitlines() if "tpu_custom_call" in l)
    tail = re.compile(r"->\s*\(([^()]*)\)(?:\s*loc\([^)]*\))?\s*$")
    single = re.compile(r"->\s*(tensor<[^\s,]+>)(?:\s*loc\([^)]*\))?\s*$")
    m = tail.search(line) or single.search(line)
    if m is None:
        return []
    return [t.strip() for t in m.group(1).split(",") if t.strip()]


def fused_pack_hlo_report(nb: int = 64, block: int = 256, kb: int = 16):
    """Prove the one-HBM-pass claim from the LOWERED HLO: the fused pack
    kernel's TPU custom call must emit only (values, indices, h_out) -- the
    dense d never reaches HBM -- while the unfused dense kernel's whole
    RESULT is the dense d, which pack/update then re-read.

    Mosaic lowering is AOT (jax.export with platforms=['tpu']), so this runs
    on CPU-only hosts too.
    """
    from jax import export as jexport
    from repro.kernels.block_topk import block_topk_pallas
    from repro.kernels.pack import pack_update_pallas

    sds = jax.ShapeDtypeStruct((nb, block), jnp.float32)
    fused = jax.jit(functools.partial(pack_update_pallas, lam=0.9, kb=kb,
                                      interpret=False))
    fused_res = _custom_call_result_types(
        jexport.export(fused, platforms=["tpu"])(sds, sds).mlir_module())
    unfused = jax.jit(lambda g: block_topk_pallas(g, kb, interpret=False))
    unfused_res = _custom_call_result_types(
        jexport.export(unfused, platforms=["tpu"])(sds).mlir_module())

    dense_ty = f"tensor<{nb}x{block}xf32>"
    payload_tys = {f"tensor<{nb}x{kb}xf32>", f"tensor<{nb}x{kb}xi32>"}
    report = {
        # exactly one dense output (h_out) and the packed payload: d is
        # never materialized in HBM
        "fused_one_hbm_pass": (fused_res.count(dense_ty) == 1
                               and payload_tys.issubset(set(fused_res))),
        "fused_outputs": fused_res,
        # the unfused kernel's output IS the dense d
        "unfused_dense_output": unfused_res.count(dense_ty) == 1,
    }
    return report


def randk_update_hlo_report(nr: int = 16, cols: int = 256, k: int = 32):
    """The rand-k fused kernel's TPU custom call must emit ONLY h_out (one
    dense f32 tensor): the dense rand-k output d lives in VMEM, and the
    O(k) payload gather never touches the kernel.  AOT-lowered like
    ``fused_pack_hlo_report``, so this runs on CPU-only hosts."""
    from jax import export as jexport
    from repro.kernels.pack import randk_update_pallas

    g = jax.ShapeDtypeStruct((nr, cols), jnp.float32)
    idx = jax.ShapeDtypeStruct((k,), jnp.int32)
    fn = jax.jit(functools.partial(randk_update_pallas, scale=75.0, lam=0.9,
                                   interpret=False))
    res = _custom_call_result_types(
        jexport.export(fn, platforms=["tpu"])(g, g, idx).mlir_module())
    dense_ty = f"tensor<{nr}x{cols}xf32>"
    return {"h_out_only": res == [dense_ty], "outputs": res}


def qsgd_pack_hlo_report(nr: int = 32, cols: int = 256, s: int = 16):
    """The QSGD fused kernel's TPU custom call must emit only the int8
    level stream and h_out: one dense f32 tensor, no dequantized d."""
    from jax import export as jexport
    from repro.kernels.pack import qsgd_pack_update_pallas

    g = jax.ShapeDtypeStruct((nr, cols), jnp.float32)
    norm = jax.ShapeDtypeStruct((1, 1), jnp.float32)
    fn = jax.jit(functools.partial(qsgd_pack_update_pallas, s=s, lam=0.9,
                                   interpret=False))
    res = _custom_call_result_types(
        jexport.export(fn, platforms=["tpu"])(g, g, g, norm).mlir_module())
    f32_ty = f"tensor<{nr}x{cols}xf32>"
    lvl_ty = f"tensor<{nr}x{cols}xi{8 if s <= 127 else 16}>"
    return {
        "one_dense_f32": res.count(f32_ty) == 1,
        "quantized_stream": lvl_ty in res,
        "outputs": res,
    }


def packed_vs_dense(fast: bool = True):
    """us/call of the fused compress-and-pack pipeline vs the unfused
    (dense-compress, then pack, then h-update) one, plus exact wire bytes."""
    d, block, kb = 1 << 16, 1024, 16
    lw = wire.LeafWire(shape=(d,), size=d, block=block, kb=kb)
    g = jax.random.normal(KEY, (d,))
    h = jax.random.normal(jax.random.key(1), (d,))
    lam = 0.9
    comp = BlockTopK(block, kb)

    @jax.jit
    def unfused(g, h):
        delta = g - h                                   # HBM pass 1
        dns = comp(None, delta).reshape(-1)             # dense d: pass 2
        vals, idx = comp.encode(None, delta)            # re-read: pass 3
        return (vals, idx), h + lam * dns               # h update: pass 4

    fused = jax.jit(lambda g, h: wire.fused_pack(lw, g, h, lam))

    iters = 5 if fast else 30
    rows = []
    us_u = timeit(unfused, g, h, iters=iters)
    us_f = timeit(fused, g, h, iters=iters)
    fmt = wire.WireFormat((lw,))
    rows.append({"name": "wire/unfused_compress_pack", "us_per_call": f"{us_u:.1f}",
                 "derived": f"d={d} dense_d_materialized=True"})
    rows.append({"name": "wire/fused_pack", "us_per_call": f"{us_f:.1f}",
                 "derived": f"d={d} payload_bits={fmt.bits_per_round()}"})

    try:
        rep = fused_pack_hlo_report()
        rows.append({"name": "wire/fused_pack_hlo",
                     "us_per_call": "",
                     "derived": f"one_hbm_pass={rep['fused_one_hbm_pass']} "
                                f"unfused_dense_output={rep['unfused_dense_output']}"})
        rk = randk_update_hlo_report()
        rows.append({"name": "wire/randk_update_hlo", "us_per_call": "",
                     "derived": f"h_out_only={rk['h_out_only']}"})
        qs = qsgd_pack_hlo_report()
        rows.append({"name": "wire/qsgd_pack_hlo", "us_per_call": "",
                     "derived": f"one_dense_f32={qs['one_dense_f32']} "
                                f"quantized_stream={qs['quantized_stream']}"})
    except Exception as e:  # jax.export unavailable on some versions
        rows.append({"name": "wire/fused_pack_hlo", "us_per_call": "",
                     "derived": f"skipped ({type(e).__name__})"})
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(fast=True))
