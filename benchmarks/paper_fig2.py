"""Paper Figure 2: f(x^t) - f* versus bits sent per node, EF-BV vs EF21,
comp-(k, d/2) compressors, overlap xi in {1, 2}, k in {1, 2}, n = 1000.

Bits per node per round = 32 * 2k words (k values + k indices), so the x-axis
is proportional to t*k exactly as in the paper.  The headline check: EF-BV
(nu = nu*) reaches any target suboptimality in fewer bits than EF21
(nu = lam).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import make_problem, run_algorithm


def run(fast: bool = True, n: int = 1000):
    steps = 1500 if fast else 12000
    datasets = ["mushrooms", "phishing"] if fast else [
        "mushrooms", "phishing", "a9a", "w8a"]
    rows = []
    curves = {}
    for name in datasets:
        for k in ([1] if fast else [1, 2]):
            for xi in ([1] if fast else [1, 2]):
                prob = make_problem(name, n=n, overlap=xi)
                _, fstar = prob.solve()
                for mode in ["efbv", "ef21"]:
                    traj = np.asarray(run_algorithm(prob, mode, k, steps, fstar))
                    curves[(name, k, xi, mode)] = traj
                f_bv = curves[(name, k, xi, "efbv")][-1]
                f_21 = curves[(name, k, xi, "ef21")][-1]
                rows.append({
                    "name": f"fig2/{name}/k{k}/xi{xi}/final_gap_ratio",
                    "us_per_call": "",
                    "derived": f"efbv={f_bv:.3e};ef21={f_21:.3e};"
                               f"efbv_better={bool(f_bv < f_21)}",
                })
    return rows, curves


if __name__ == "__main__":
    from benchmarks.common import emit
    rows, _ = run(fast=True)
    emit(rows)
