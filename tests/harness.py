"""Differential oracle harness for the wire-codec pipeline.

One algorithm, several executions -- the harness runs the SAME EF-BV
recursion through each backend and asserts the trajectories are
*bit-identical*, not merely close:

    oracle     -- pure jnp (the codec spec),
    interpret  -- fused Pallas kernel, interpret mode (CPU),
    pallas     -- fused Pallas kernel, compiled (TPU only).

Because the kernels reproduce the oracles' f32 arithmetic op-for-op
(jax.lax.top_k's selection order for block-top-k, the SMEM index mask for
rand-k, the stochastic-rounding chain for QSGD), any divergence -- one ULP,
one swapped tie -- is a bug, and equality composes over steps: if round t is
bit-equal, round t+1 sees identical inputs.  ``run_wire_trajectory`` drives
the block-top-k pipeline; ``run_codec_trajectory`` drives ANY compressor
through its declared codec (tests/test_wire.py and tests/test_wire_codecs.py
parametrize over the zoo); ``run_federated_trajectory`` adds randomized
per-round participation masks on top (tests/test_federated.py);
test_distributed.py reuses run_with_devices for the 1-vs-8-fake-device leg.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import wire

Array = jax.Array


def available_pack_impls() -> List[str]:
    impls = ["oracle", "interpret"]
    if jax.default_backend() == "tpu":
        impls.append("pallas")
    return impls


def codec_impls(codec) -> List[str]:
    """Backends to differential-test for ``codec``: always the jnp oracle,
    plus the fused Pallas kernel (interpret; compiled on TPU) when the codec
    has one."""
    if not getattr(codec, "has_kernel", False):
        return ["oracle"]
    return available_pack_impls()


def quadratic_grads(n: int, d: int, seed: int = 0):
    """Per-worker gradient oracle of a strongly convex quadratic finite sum:
    grad_i(x) = Q_i x - b_i, returned as an (n, d) stack."""
    key = jax.random.key(seed)
    A = jax.random.normal(key, (n, d, d)) / np.sqrt(d)
    Q = jnp.einsum("nij,nkj->nik", A, A) + 0.5 * jnp.eye(d)
    b = jax.random.normal(jax.random.key(seed + 1), (n, d))

    def grad_fn(x):
        return jnp.einsum("nij,j->ni", Q, x) - b

    return grad_fn


def run_wire_trajectory(kernel: str, *, steps: int, n: int, d: int,
                        block: int, kb: int, lam: float, nu: float,
                        gamma: float, seed: int = 0) -> Dict[str, Array]:
    """EF-BV (Algorithm 1) over the sparse wire with the given pack backend.

    Every worker packs its innovation with wire.fused_pack(kernel=...), the
    master scatter-adds the stacked payload -- exactly the sparse_allgather
    data path.  Returns the full (x, h) trajectory plus the last round's
    payload so callers can check byte accounting.
    """
    lw = wire.LeafWire(shape=(d,), size=d, block=block, kb=kb)
    grad_fn = quadratic_grads(n, d, seed)

    x = jnp.zeros((d,), jnp.float32)
    h = jnp.zeros((n, d), jnp.float32)
    h_avg = jnp.zeros((d,), jnp.float32)
    xs, hs = [], []
    payload: Tuple[Array, Array] = None
    for _ in range(steps):
        g = grad_fn(x)
        vals_i, idx_i, h_i = [], [], []
        for i in range(n):
            (vals, idx), h_new = wire.fused_pack(lw, g[i], h[i], lam,
                                                 kernel=kernel)
            vals_i.append(vals)
            idx_i.append(idx)
            h_i.append(h_new)
        h = jnp.stack(h_i)
        payload = (jnp.stack(vals_i), jnp.stack(idx_i))
        d_bar = wire.scatter_add(lw, *payload) / n
        x = x - gamma * (h_avg + nu * d_bar)
        h_avg = h_avg + lam * d_bar
        xs.append(x)
        hs.append(h)
    return {"x": jnp.stack(xs), "h": jnp.stack(hs), "payload": payload,
            "lw": lw}


def run_codec_trajectory(kernel: str, *, compressor, steps: int, n: int,
                         d: int, lam: float, nu: float, gamma: float,
                         seed: int = 0, wire_dtype: str = "float32"
                         ) -> Dict[str, Array]:
    """EF-BV (Algorithm 1) over ANY compressor's declared wire codec.

    Every worker runs wire.encode_update (codec pack + h update, fused
    kernel when kernel != 'oracle' and the codec has one), the master
    decode-sums the worker-stacked payload -- exactly the sparse_allgather
    data path.  Returns the (x, h) trajectory plus the last round's stacked
    payload for byte accounting.
    """
    codec = wire.codec_of(compressor, (d,), d, wire_dtype)
    grad_fn = quadratic_grads(n, d, seed)
    key = jax.random.key(seed + 0xC0DEC)

    x = jnp.zeros((d,), jnp.float32)
    h = jnp.zeros((n, d), jnp.float32)
    h_avg = jnp.zeros((d,), jnp.float32)
    xs, hs = [], []
    payload = None
    for t in range(steps):
        g = grad_fn(x)
        payloads, h_i = [], []
        for i in range(n):
            ki = jax.random.fold_in(jax.random.fold_in(key, t), i)
            p, h_new = wire.encode_update(codec, ki, g[i], h[i], lam,
                                          kernel=kernel)
            payloads.append(p)
            h_i.append(h_new)
        h = jnp.stack(h_i)
        payload = jax.tree.map(lambda *xs_: jnp.stack(xs_), *payloads)
        d_bar = codec.decode_sum(payload) / n
        x = x - gamma * (h_avg + nu * d_bar)
        h_avg = h_avg + lam * d_bar
        xs.append(x)
        hs.append(h)
    return {"x": jnp.stack(xs), "h": jnp.stack(hs), "payload": payload,
            "codec": codec}


def run_federated_trajectory(kernel: str, *, compressor, steps: int, n: int,
                             d: int, lam: float, nu: float, gamma: float,
                             participation, seed: int = 0,
                             wire_dtype: str = "float32") -> Dict[str, Array]:
    """EF-BV over a compressor's wire codec under per-round client sampling.

    Same recursion as :func:`run_codec_trajectory` plus the federated gating:
    each round draws a participation mask (Participation.sample_mask from the
    shared participation_key derivation), every worker still encodes with the
    requested pack backend, then absent workers' payloads are gated to
    decode-zero (codec.mask_message) and their h_i kept stale -- exactly the
    masked sparse_allgather data path.  With an all-ones mask (bernoulli
    p = 1) the trajectory is bit-identical to run_codec_trajectory's;
    randomized masks extend the oracle==interpret==compiled pinning to the
    federated regime.  Returns the (x, h) trajectory, the per-round masks and
    the exact federated wire bits of the last round.
    """
    from repro.core.efbv import participation_key

    codec = wire.codec_of(compressor, (d,), d, wire_dtype)
    grad_fn = quadratic_grads(n, d, seed)
    key = jax.random.key(seed + 0xC0DEC)

    x = jnp.zeros((d,), jnp.float32)
    h = jnp.zeros((n, d), jnp.float32)
    h_avg = jnp.zeros((d,), jnp.float32)
    xs, hs, masks = [], [], []
    payload = None
    for t in range(steps):
        kt = jax.random.fold_in(key, t)
        mask = participation.sample_mask(participation_key(kt), n)
        g = grad_fn(x)
        payloads, h_i = [], []
        for i in range(n):
            ki = jax.random.fold_in(kt, i)
            p, h_new = wire.encode_update(codec, ki, g[i], h[i], lam,
                                          kernel=kernel)
            p = codec.mask_message(p, mask[i])
            h_new = jnp.where(mask[i] > 0, h_new, h[i])
            payloads.append(p)
            h_i.append(h_new)
        h = jnp.stack(h_i)
        payload = jax.tree.map(lambda *xs_: jnp.stack(xs_), *payloads)
        d_bar = codec.decode_sum(payload) / n
        x = x - gamma * (h_avg + nu * d_bar)
        h_avg = h_avg + lam * d_bar
        xs.append(x)
        hs.append(h)
        masks.append(mask)
    fmt = wire.WireFormat((codec,))
    return {"x": jnp.stack(xs), "h": jnp.stack(hs), "payload": payload,
            "masks": jnp.stack(masks), "codec": codec,
            "round_bits": wire.federated_round_bits(fmt, masks[-1])}


def run_bidirectional_trajectory(kernel: str, *, compressor, downlink,
                                 steps: int, n: int, d: int, lam: float,
                                 nu: float, gamma: float, participation=None,
                                 seed: int = 0, wire_dtype: str = "float32"
                                 ) -> Dict[str, Array]:
    """EF-BV over a fully bidirectional wire: any uplink codec, any
    :class:`repro.core.efbv.Downlink` broadcast channel, optionally the
    federated execution mode on top.

    The uplink is exactly :func:`run_federated_trajectory`'s recursion
    (same key folds, same pack backend ``kernel``, same mask gating when
    ``participation`` is given -- an all-ones/None mask reduces to
    :func:`run_codec_trajectory`); workers evaluate gradients at the shared
    reconstruction ``w``, and each round ends with ONE broadcast through
    the downlink codec, drawn from the shared downlink_key derivation.
    An Identity downlink assigns w = x verbatim, so identity-downlink +
    full-participation trajectories are BIT-IDENTICAL to
    run_codec_trajectory's (the PR-3 pinning; tests/test_wire_codecs.py and
    tests/test_federated.py hold the harness to it).

    Returns the (x, w, h) trajectories, the per-round masks (all-ones when
    full), the last round's payloads both ways, and the exact bit
    accounting of the last round: uplink, downlink, total, and the dense
    fp32 both-ways baseline.
    """
    from repro.core.efbv import downlink_key, participation_key

    codec = wire.codec_of(compressor, (d,), d, wire_dtype)
    grad_fn = quadratic_grads(n, d, seed)
    key = jax.random.key(seed + 0xC0DEC)

    x = jnp.zeros((d,), jnp.float32)
    w = jnp.zeros((d,), jnp.float32)  # downlink.init(x0), x0 = 0
    h = jnp.zeros((n, d), jnp.float32)
    h_avg = jnp.zeros((d,), jnp.float32)
    xs, ws, hs, masks = [], [], [], []
    payload = down_payload = None
    for t in range(steps):
        kt = jax.random.fold_in(key, t)
        mask = (jnp.ones((n,), jnp.float32) if participation is None
                else participation.sample_mask(participation_key(kt), n))
        g = grad_fn(w)  # workers only ever see the reconstruction
        payloads, h_i = [], []
        for i in range(n):
            ki = jax.random.fold_in(kt, i)
            p, h_new = wire.encode_update(codec, ki, g[i], h[i], lam,
                                          kernel=kernel)
            if participation is not None:
                p = codec.mask_message(p, mask[i])
                h_new = jnp.where(mask[i] > 0, h_new, h[i])
            payloads.append(p)
            h_i.append(h_new)
        h = jnp.stack(h_i)
        payload = jax.tree.map(lambda *xs_: jnp.stack(xs_), *payloads)
        d_bar = codec.decode_sum(payload) / n
        x = x - gamma * (h_avg + nu * d_bar)
        h_avg = h_avg + lam * d_bar
        w, down_payload = downlink.broadcast(downlink_key(kt), x, w,
                                             wire_dtype=wire_dtype)
        xs.append(x)
        ws.append(w)
        hs.append(h)
        masks.append(mask)
    fmt = wire.WireFormat((codec,))
    dfmt = downlink.format_for(jnp.zeros((d,)), wire_dtype=wire_dtype)
    up_bits = (fmt.bits_per_round(n_workers=n) if participation is None
               else wire.federated_round_bits(fmt, masks[-1]))
    down_bits = dfmt.downlink_bits_per_round()
    return {"x": jnp.stack(xs), "w": jnp.stack(ws), "h": jnp.stack(hs),
            "masks": jnp.stack(masks), "payload": payload,
            "down_payload": down_payload, "codec": codec,
            "down_codec": dfmt.leaves[0],
            "round_bits": {"up": up_bits, "down": down_bits,
                           "total": up_bits + down_bits,
                           "dense_both_ways": 32 * d * n + 32 * d}}


def assert_bit_identical(a, b, context: str = ""):
    """Exact equality (values AND dtypes) across two pytrees of arrays."""
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), (context, len(la), len(lb))
    for x, y in zip(la, lb):
        assert jnp.asarray(x).dtype == jnp.asarray(y).dtype, \
            (context, x.dtype, y.dtype)
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=context)
