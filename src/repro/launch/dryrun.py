import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax (the two lines above MUST come first:
# jax locks the device count on first backend init) -------------------------

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Optional  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, get_config  # noqa: E402
from repro.core import BlockTopK, EFBV, make_compressor  # noqa: E402
from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh, num_workers  # noqa: E402
from repro.launch.shapes import (  # noqa: E402
    SHAPES, ShapeSpec, adapt_config, batch_struct, decode_structs,
)
from repro.models import build_model  # noqa: E402
from repro.optim import adamw, cosine  # noqa: E402
from repro.train import init_train_state, make_train_step, train_state_shardings  # noqa: E402

SDS = jax.ShapeDtypeStruct

DEFAULT_COMPRESSOR = "block_topk:4096,64"  # ~1.6% density, paper-style k << d


def _with_shardings(sds_tree, sharding_tree):
    return jax.tree.map(
        lambda sds, sh: SDS(sds.shape, sds.dtype, sharding=sh),
        sds_tree, sharding_tree)


def _params_sds(model, mesh):
    params = model.init_abstract()
    specs = model.param_specs()
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda s: isinstance(s, P))
    return _with_shardings(params, shardings), specs


def build_lowered(arch: str, shape_name: str, *, multi_pod: bool,
                  agg_mode: str = "dense_psum",
                  compressor: str = DEFAULT_COMPRESSOR,
                  remat: Optional[bool] = None,
                  trainer: str = "shard_map",
                  param_dtype: Optional[str] = None,
                  attn_impl: Optional[str] = None):
    """Lower one (arch x shape x mesh) combination; returns (lowered, meta)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    cfg, note = adapt_config(cfg, shape)
    if cfg is None:
        return None, {"skip": note}
    if remat is not None:
        cfg = dataclasses.replace(cfg, remat=remat)
    if param_dtype is not None:
        cfg = dataclasses.replace(cfg, param_dtype=param_dtype)
    if attn_impl is not None:
        cfg = dataclasses.replace(cfg, attn_impl=attn_impl)
    model = build_model(cfg)
    n = num_workers(mesh)
    comp = make_compressor(compressor)
    algo = EFBV.make(comp, d=cfg.d_model * cfg.d_ff if cfg.d_ff else cfg.d_model ** 2,
                     n=n, mode="efbv")

    params_sds, param_specs = _params_sds(model, mesh)
    meta = {"note": note, "n_workers": n, "n_devices": mesh.size,
            "params": cfg.param_count(), "active_params": cfg.active_param_count()}

    if shape.kind == "train":
        from repro.train.trainer import fsdp_state_shardings, make_train_step_fsdp

        opt = adamw(cosine(3e-4, total_steps=10_000, warmup_steps=200))
        state_sds = jax.eval_shape(
            lambda p: init_train_state(p, opt, mesh), params_sds)
        if trainer == "fsdp":
            shardings = fsdp_state_shardings(mesh, param_specs, state_sds)
            step_fn = make_train_step_fsdp(model.loss, opt, algo, mesh,
                                           agg_mode=agg_mode)
        else:
            shardings = train_state_shardings(mesh, param_specs, state_sds)
            step_fn = make_train_step(model.loss, opt, algo, mesh,
                                      agg_mode=agg_mode)
        state_sds = _with_shardings(state_sds, shardings)
        batch_sds = batch_struct(cfg, shape, mesh)
        key_sds = jax.eval_shape(lambda: jax.random.key(0))
        with jax.set_mesh(mesh):
            lowered = step_fn.lower(state_sds, batch_sds, key_sds)
        return lowered, meta

    if shape.kind == "prefill":
        batch_sds = batch_struct(cfg, shape, mesh)
        with jax.set_mesh(mesh):
            lowered = jax.jit(model.prefill).lower(params_sds, batch_sds)
        return lowered, meta

    # decode
    cache_sds, token_sds, pos_sds = decode_structs(cfg, shape, mesh, model)

    def serve_step(params, cache, token, pos):
        logits, cache = model.decode_step(params, cache, token, pos)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, cache

    with jax.set_mesh(mesh):
        lowered = jax.jit(serve_step, donate_argnums=(1,)).lower(
            params_sds, cache_sds, token_sds, pos_sds)
    return lowered, meta


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            agg_mode: str = "dense_psum", compressor: str = DEFAULT_COMPRESSOR,
            verbose: bool = True, hlo_dir: Optional[str] = None,
            trainer: str = "shard_map",
            param_dtype: Optional[str] = None,
            attn_impl: Optional[str] = None,
            hlo_tag: str = "") -> dict:
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "agg_mode": agg_mode, "compressor": compressor,
           "trainer": trainer, "param_dtype": param_dtype,
           "attn_impl": attn_impl}
    t0 = time.time()
    try:
        lowered, meta = build_lowered(arch, shape_name, multi_pod=multi_pod,
                                      agg_mode=agg_mode, compressor=compressor,
                                      trainer=trainer, param_dtype=param_dtype,
                                      attn_impl=attn_impl)
        rec.update(meta)
        if lowered is None:
            rec["status"] = "skipped"
            return rec
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
        hlo_text = compiled.as_text()
        if hlo_dir:
            import gzip
            import os as _os
            _os.makedirs(hlo_dir, exist_ok=True)
            fname = f"{arch}_{shape_name}_{rec['mesh']}_{agg_mode}{hlo_tag}.hlo.gz"
            with gzip.open(_os.path.join(hlo_dir, fname), "wt") as gz:
                gz.write(hlo_text)
        roof = hlo_analysis.analyze(compiled, n_chips=rec.get("n_devices", 256),
                                    hlo_text=hlo_text)
        rec["roofline"] = roof.as_dict()
        rec["memory"] = hlo_analysis.memory_stats(compiled)
        rec["status"] = "ok"
        if verbose:
            m = rec["memory"] or {}
            print(f"[dryrun] {arch:22s} {shape_name:12s} {rec['mesh']:8s} OK "
                  f"lower={rec['lower_s']:.1f}s compile={rec['compile_s']:.1f}s "
                  f"bottleneck={roof.bottleneck} "
                  f"args={m.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"temp={m.get('temp_size_in_bytes', 0)/2**30:.2f}GiB")
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        if verbose:
            print(f"[dryrun] {arch:22s} {shape_name:12s} {rec['mesh']:8s} FAIL {rec['error'][:200]}")
            traceback.print_exc(limit=6)
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run: lower+compile "
                                 "every (arch x shape x mesh) and extract roofline terms")
    ap.add_argument("--arch", default="all", help=f"one of {ARCHS} or 'all'")
    ap.add_argument("--shape", default="all", help=f"one of {list(SHAPES)} or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--agg", default="dense_psum")
    ap.add_argument("--compressor", default=DEFAULT_COMPRESSOR)
    ap.add_argument("--out", default="dryrun_results.jsonl")
    ap.add_argument("--hlo-dir", default="", help="dump gzipped HLO per combo")
    args = ap.parse_args()

    archs = ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    with open(args.out, "a") as f:
        for arch in archs:
            for shape in shapes:
                for mp in meshes:
                    rec = run_one(arch, shape, multi_pod=mp, agg_mode=args.agg,
                                  compressor=args.compressor,
                                  hlo_dir=args.hlo_dir or None)
                    f.write(json.dumps(rec) + "\n")
                    f.flush()


if __name__ == "__main__":
    main()
