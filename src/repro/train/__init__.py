from repro.train.trainer import (  # noqa: F401
    TrainState, make_train_step, init_train_state, train_state_shardings,
    make_train_step_fsdp, fsdp_state_shardings, fsdp_specs,
)
from repro.train.loop import (  # noqa: F401
    FinetuneLoop, FinetuneSettings, expert_sparse_rules, finetune,
)
