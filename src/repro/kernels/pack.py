"""Pallas TPU kernels: fused compress-AND-pack for the wire codecs.

The unfused hot path of any compressor costs three HBM passes and
materializes a dense tensor the theory says should never exist on the wire:

    d      = C(g - h)                 # dense (nb, block) write
    h     <- h + lam * d              # dense read + write
    payload = pack(d)                 # dense read, payload write

Three codecs get a fused kernel here, each with the same property -- the
dense compressed d lives only in VMEM, never in HBM:

  * block-top-k (`_pack_update_kernel`): one pass over (g, h) emitting the
    (values, block-local indices) payload and h_out.
  * rand-k (`_randk_update_kernel`): the k kept positions are
    data-INdependent, so they are drawn outside and prefetched to SMEM; the
    kernel does the dense-free h <- h + lam * d pass in one sweep, and the
    payload values are an O(k) gather outside.
  * QSGD (`_qsgd_pack_kernel`): after a scalar norm reduction, one pass over
    (g, h, uniforms) emits the int8/int16 quantized level stream and h_out
    -- the dequantized d is built in VMEM for the h update and discarded.

All kernels reproduce the jnp oracles' f32 arithmetic op-for-op, which is
what makes the payloads bit-identical across oracle / interpret / compiled
backends -- the differential harness in tests/harness.py pins this.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.block_topk import TILE_NB

Array = jax.Array

QS_TILE_NB = 32  # rows per grid step for int8 outputs (min int8 tile: 32x128)


def _select_block_topk(delta, kb: int):
    """The shared selection core of both pack kernels: returns (vals f32
    (rows, kb), cols f32 (rows, kb), selected bool (rows, block)).  One body
    keeps the streaming and non-streaming variants bit-identical by
    construction."""
    mag = jnp.abs(delta)
    rows, block = mag.shape
    # column indices kept in f32: Mosaic (this jaxlib vintage) implements
    # neither integer reductions nor cumsum; f32 is exact for block < 2**24
    cols = jax.lax.broadcasted_iota(jnp.float32, (rows, block), 1)

    # python-unrolled over the (static, small) kb: payload columns are
    # assembled with one concatenate -- loop-carried dynamic_update_slice has
    # no Mosaic lowering, and the unroll keeps everything elementwise+reduce
    selected = jnp.zeros((rows, block), jnp.bool_)
    v_cols, c_cols = [], []
    for _ in range(kb):
        score = jnp.where(selected, -jnp.inf, mag)
        m = jnp.max(score, axis=1, keepdims=True)
        # m != -inf guards the all-selected row (kb == block); spelled as a
        # compare because isfinite has no Pallas TPU lowering
        is_m = (score == m) & (m != -jnp.inf)
        # exact first-index tie-breaking == jax.lax.top_k's stable order:
        # the smallest column index among the maxima
        cmin = jnp.min(jnp.where(is_m, cols, float(block)), axis=1,
                       keepdims=True)
        first = is_m & (cols == cmin)
        v_cols.append(jnp.sum(jnp.where(first, delta, 0.0), axis=1)[:, None])
        c_cols.append(jnp.max(jnp.where(first, cols, 0.0), axis=1)[:, None])
        selected = selected | first
    return (jnp.concatenate(v_cols, axis=1), jnp.concatenate(c_cols, axis=1),
            selected)


def _pack_update_kernel(g_ref, h_ref, vals_ref, idx_ref, h_out_ref, *,
                        kb: int, lam: float):
    g = g_ref[...]
    h = h_ref[...]
    # subtract in f32: bit-identical between interpret mode and TPU lowering
    delta = g.astype(jnp.float32) - h.astype(jnp.float32)
    vals, cols, selected = _select_block_topk(delta, kb)
    vals_ref[...] = vals.astype(vals_ref.dtype)
    idx_ref[...] = cols.astype(jnp.int32)
    d = jnp.where(selected, delta, 0.0)
    h_out_ref[...] = (h.astype(jnp.float32) + lam * d).astype(h_out_ref.dtype)


def _pack_update_stream_kernel(g_ref, h_ref, vals_ref, idx_ref, h_out_ref,
                               v_scr, i_scr, sems, *, kb: int, lam: float):
    """Async-copy variant: the payload slab is computed into VMEM scratch and
    DMA'd toward its HBM output (vals_ref/idx_ref live in pltpu.ANY) while
    the h update still computes -- the wire bytes of this grid step stream
    out under the remaining compute instead of waiting for the step's
    epilogue.  Arithmetic is the non-streaming kernel's, op for op."""
    t = pl.program_id(0)
    g = g_ref[...]
    h = h_ref[...]
    delta = g.astype(jnp.float32) - h.astype(jnp.float32)
    vals, cols, selected = _select_block_topk(delta, kb)
    v_scr[...] = vals.astype(v_scr.dtype)
    i_scr[...] = cols.astype(jnp.int32)
    rows = v_scr.shape[0]
    v_dma = pltpu.make_async_copy(
        v_scr, vals_ref.at[pl.ds(t * rows, rows), :], sems.at[0])
    i_dma = pltpu.make_async_copy(
        i_scr, idx_ref.at[pl.ds(t * rows, rows), :], sems.at[1])
    v_dma.start()
    i_dma.start()
    d = jnp.where(selected, delta, 0.0)
    h_out_ref[...] = (h.astype(jnp.float32) + lam * d).astype(h_out_ref.dtype)
    # the wait doubles as the write-after-read guard: the next grid step may
    # not overwrite the scratch slabs until this step's copies have landed
    v_dma.wait()
    i_dma.wait()


def pack_update_pallas(g2d: Array, h2d: Array, lam: float, kb: int, *,
                       interpret: bool = False, stream: bool = False):
    """g2d/h2d: (nb, block) with nb % TILE_NB == 0, block % 128 == 0.

    Returns (values (nb, kb), indices (nb, kb) int32, h_new (nb, block)).
    ``stream=True`` takes the async-copy kernel (payload DMA overlaps the h
    update); results are bit-identical to the non-streaming kernel.
    """
    nb, block = g2d.shape
    assert nb % TILE_NB == 0 and block % 128 == 0, (nb, block)
    assert 0 < kb <= block, (kb, block)
    grid = (nb // TILE_NB,)
    slab = pl.BlockSpec((TILE_NB, block), lambda i: (i, 0))
    payload = pl.BlockSpec((TILE_NB, kb), lambda i: (i, 0))
    out_shape = (jax.ShapeDtypeStruct((nb, kb), g2d.dtype),
                 jax.ShapeDtypeStruct((nb, kb), jnp.int32),
                 jax.ShapeDtypeStruct((nb, block), h2d.dtype))
    if stream:
        return pl.pallas_call(
            functools.partial(_pack_update_stream_kernel, kb=kb,
                              lam=float(lam)),
            grid=grid,
            in_specs=[slab, slab],
            out_specs=(pl.BlockSpec(memory_space=pltpu.ANY),
                       pl.BlockSpec(memory_space=pltpu.ANY),
                       slab),
            out_shape=out_shape,
            scratch_shapes=[pltpu.VMEM((TILE_NB, kb), g2d.dtype),
                            pltpu.VMEM((TILE_NB, kb), jnp.int32),
                            pltpu.SemaphoreType.DMA((2,))],
            interpret=interpret,
        )(g2d, h2d)
    return pl.pallas_call(
        functools.partial(_pack_update_kernel, kb=kb, lam=float(lam)),
        grid=grid,
        in_specs=[slab, slab],
        out_specs=(payload, payload, slab),
        out_shape=out_shape,
        interpret=interpret,
    )(g2d, h2d)


# ---------------------------------------------------------------------------
# rand-k: dense-free h update with SMEM-prefetched indices
# ---------------------------------------------------------------------------

def _randk_update_kernel(idx_ref, g_ref, h_ref, h_out_ref, *, k: int,
                         scale: float, lam: float):
    """h_out = h + lam * ((g - h) masked to the k SMEM indices) * scale.

    idx_ref holds the k selected flat positions (into the padded row-major
    (nr, cols) grid) in SMEM; membership of this tile is rebuilt as an
    equality test against the tile-linear f32 iota (exact for size < 2**24,
    and out-of-tile positions can never collide with an in-tile linear
    index).  The dense rand-k output d exists only in VMEM.
    """
    t = pl.program_id(0)
    g = g_ref[...]
    h = h_ref[...]
    delta = g.astype(jnp.float32) - h.astype(jnp.float32)
    rows, cols = delta.shape
    lin = (jax.lax.broadcasted_iota(jnp.float32, (rows, cols), 0) * cols
           + jax.lax.broadcasted_iota(jnp.float32, (rows, cols), 1))
    base = t * (rows * cols)

    def body(j, mask):
        local = (idx_ref[j] - base).astype(jnp.float32)
        return jnp.maximum(mask, (lin == local).astype(jnp.float32))

    mask = jax.lax.fori_loop(0, k, body, jnp.zeros((rows, cols), jnp.float32))
    # rounding chain must match the oracle's h + lam * decode(payload):
    # delta * scale rounds first (those ARE the wire values), then lam * d.
    # The select between the two multiplies stops XLA from reassociating the
    # constant pair into one (lam * scale) product the eager oracle never
    # forms -- adjacent constant muls DO get merged on the CPU backend.
    vals_dense = delta * scale
    d = jnp.where(mask > 0, vals_dense, 0.0)
    h_out_ref[...] = (h.astype(jnp.float32) + lam * d).astype(h_out_ref.dtype)


def randk_update_pallas(g2d: Array, h2d: Array, idx: Array, scale: float,
                        lam: float, *, interpret: bool = False) -> Array:
    """g2d/h2d: (nr, cols) with nr % TILE_NB == 0, cols % 128 == 0; idx: (k,)
    int32 flat positions.  Returns h_new (nr, cols) in h2d's dtype."""
    nr, cols = g2d.shape
    assert nr % TILE_NB == 0 and cols % 128 == 0, (nr, cols)
    # f32 position compare is exact up to 2**24 inclusive (max linear index
    # is nr*cols - 1); <= admits every unpadded size < 2**24 after padding
    assert nr * cols <= 2 ** 24, (nr, cols)
    (k,) = idx.shape
    grid = (nr // TILE_NB,)
    slab = pl.BlockSpec((TILE_NB, cols), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_randk_update_kernel, k=k, scale=float(scale),
                          lam=float(lam)),
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), slab, slab],
        out_specs=slab,
        out_shape=jax.ShapeDtypeStruct((nr, cols), h2d.dtype),
        interpret=interpret,
    )(idx, g2d, h2d)


# ---------------------------------------------------------------------------
# QSGD: fused quantize-and-pack (int8/int16 level stream + h update)
# ---------------------------------------------------------------------------

def _qsgd_pack_kernel(norm_ref, g_ref, h_ref, u_ref, lvl_ref, h_out_ref, *,
                      s: int, lam: float):
    """One pass over (g, h, u): emits the signed level stream and
    h_out = h + lam * dequant(levels); the dense dequantized d stays in
    VMEM.  Op order matches QSGD.__call__ / QsgdQuant exactly."""
    g = g_ref[...]
    h = h_ref[...]
    u = u_ref[...]
    delta = g.astype(jnp.float32) - h.astype(jnp.float32)
    norm = norm_ref[0, 0]
    safe = jnp.where(norm > 0, norm, 1.0)
    a = jnp.abs(delta)
    level = a / safe * s
    low = jnp.floor(level)
    up = (u < (level - low)).astype(jnp.float32)
    # sign spelled as compares: jnp.sign lowers poorly on some Mosaic
    # vintages, and the two differ only at +-0 where every product below is
    # a zero of some sign anyway
    sgn = jnp.where(delta > 0, 1.0, jnp.where(delta < 0, -1.0, 0.0))
    lvq = low + up
    lvl_ref[...] = (sgn * lvq).astype(lvl_ref.dtype)
    # rounding chain matches the oracle decode exactly: reciprocal multiply
    # (jit rewrites /s inexactly) and a VECTOR-predicate select feeding the
    # tail -- scalar-predicate selects get simplified away, leaving a
    # mul+add pair that LLVM contracts into an FMA the eager oracle never
    # performs (see the rand-k kernel for the same constraint)
    dq = jnp.where(lvq > 0, (norm * sgn) * (lvq * (1.0 / s)), 0.0)
    h_out_ref[...] = (h.astype(jnp.float32) + lam * dq).astype(h_out_ref.dtype)


def qsgd_pack_update_pallas(g2d: Array, h2d: Array, u2d: Array, norm: Array,
                            s: int, lam: float, *, interpret: bool = False):
    """g2d/h2d/u2d: (nr, cols) with nr % QS_TILE_NB == 0, cols % 128 == 0;
    norm: (1, 1) f32.  Returns (levels (nr, cols) int8/int16, h_new)."""
    nr, cols = g2d.shape
    assert nr % QS_TILE_NB == 0 and cols % 128 == 0, (nr, cols)
    lvl_dtype = jnp.int8 if s <= 127 else jnp.int16
    grid = (nr // QS_TILE_NB,)
    slab = pl.BlockSpec((QS_TILE_NB, cols), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_qsgd_pack_kernel, s=int(s), lam=float(lam)),
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), slab, slab, slab],
        out_specs=(slab, slab),
        out_shape=(jax.ShapeDtypeStruct((nr, cols), lvl_dtype),
                   jax.ShapeDtypeStruct((nr, cols), h2d.dtype)),
        interpret=interpret,
    )(norm, g2d, h2d, u2d)
