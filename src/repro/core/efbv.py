"""EF-BV (Algorithm 1) over pytrees, with EF21 / DIANA as parametrizations.

Two execution styles share this module:

* the *reference* style used by the convex benchmarks and tests: all n
  workers' control variates are materialized with a leading worker axis and
  the per-worker compressors run under ``vmap`` -- bit-exact semantics of
  Algorithm 1 incl. the master-side bookkeeping;

* the *distributed* style (repro/distributed/aggregate.py) runs the same
  per-worker math inside ``shard_map`` where the leading worker axis is the
  mesh's (pod, data) axes and the master aggregation is a collective.

Both call into :func:`worker_update` / :func:`master_update` below so the
algorithm lives in exactly one place.

Beyond the exact-gradient, full-participation regime of the paper's
experiments, the module also implements the *federated* execution mode
(docs/algorithms.md#partial-participation--stochastic-gradients): per-round
client sampling via :class:`Participation` masks -- only the sampled subset
S_t compresses and communicates, absent workers keep their control variates
h_i stale -- through the masked variants :meth:`EFBV.worker_update_masked` /
:meth:`EFBV.step_federated` and the :func:`run_reference` driver, which also
takes stochastic (minibatch-resampled) local gradients.  With an all-ones
mask every masked op reduces bitwise to its unmasked twin, so full
participation reproduces the original trajectories bit-for-bit (pinned by
tests/test_federated.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.contract import Compressor
from repro.core import theory

Array = jax.Array
PyTree = Any

#: fold_in tag for the per-round participation-mask key.  All execution paths
#: (the reference driver, shard_map trainer, FSDP trainer, the differential
#: harness) derive the mask from fold_in(round_key, PARTICIPATION_FOLD) so the
#: sampled subset S_t is identical everywhere; worker compressor keys are
#: untouched, which is what keeps p = 1 bit-identical to full participation.
PARTICIPATION_FOLD = 0xFEDE4A7E
#: fold_in tag for the per-round minibatch-resampling key (stochastic local
#: gradients) -- decorrelated from both the mask and the compressor draws.
RESAMPLE_FOLD = 0x5A3D0B17
#: fold_in tag for the per-round downlink (master -> worker broadcast) key.
#: One key per round, shared by every worker: the broadcast is a single
#: message, so present and absent workers decode the SAME payload.
DOWNLINK_FOLD = 0xD0401B17
#: fold_in tag for the pipelined schedule's PRIMING payload key: the round-0
#: in-flight buffer is a real wire message that decodes to zero (encode of a
#: zero vector, participation-masked to zero), drawn once from
#: fold_in(key(0), PIPELINE_FOLD) so every execution path primes identically.
PIPELINE_FOLD = 0xF1FE11E
#: fold_in tag for the reference driver's run key: Run.reference() derives
#: its trajectory key from fold_in(key(seed), REFERENCE_FOLD) so it is
#: decorrelated from the problem-data key (jax.random.key(seed) raw).  The
#: value predates this name; changing it would shift every recorded
#: reference trajectory.
REFERENCE_FOLD = 0x5EED


@dataclasses.dataclass(frozen=True)
class Participation:
    """Per-round client-sampling scheme (the federated execution mode).

    kind:
      * ``full``          -- every worker participates (the paper's setting);
      * ``bernoulli``     -- worker i participates independently w.p. ``p``;
      * ``fixed``         -- a uniformly random subset of exactly ``s`` workers.

    Masks are {0., 1.}-valued float32 so that gating is pure arithmetic:
    ``m * d`` zeroes an absent worker's message and ``where(m > 0, h', h)``
    keeps its control variate stale -- both bitwise identities at m = 1.
    """

    kind: str = "full"
    p: float = 1.0   # bernoulli inclusion probability
    s: int = 0       # fixed-size participant count

    def __post_init__(self):
        if self.kind not in ("full", "bernoulli", "fixed"):
            raise ValueError(f"participation kind {self.kind!r}")
        if self.kind == "bernoulli" and not 0.0 < self.p <= 1.0:
            raise ValueError(f"bernoulli participation needs 0 < p <= 1, got {self.p}")
        if self.kind == "fixed" and self.s < 1:
            raise ValueError(f"fixed participation needs s >= 1, got {self.s}")

    @staticmethod
    def parse(spec: str) -> "Participation":
        """Parse the CLI syntax: 'full' | 'bernoulli:p' | 'fixed:s'."""
        name, _, arg = spec.partition(":")
        if name == "full":
            return Participation()
        if name == "bernoulli":
            return Participation(kind="bernoulli", p=float(arg))
        if name == "fixed":
            return Participation(kind="fixed", s=int(arg))
        raise ValueError(f"participation spec {spec!r} (want full | "
                         f"bernoulli:p | fixed:s)")

    @property
    def is_full(self) -> bool:
        return self.kind == "full" or (self.kind == "bernoulli" and self.p >= 1.0)

    def fraction(self, n: int) -> float:
        """Expected fraction of participating workers, E|S_t| / n."""
        if self.kind == "bernoulli":
            return self.p
        if self.kind == "fixed":
            return min(self.s, n) / n
        return 1.0

    def sample_mask(self, key: Array, n: int) -> Array:
        """(n,) float32 participation mask for one round."""
        if self.kind == "bernoulli":
            return jax.random.bernoulli(key, self.p, (n,)).astype(jnp.float32)
        if self.kind == "fixed":
            if self.s > n:
                raise ValueError(f"fixed:{self.s} participation with only {n} workers")
            return (jax.random.permutation(key, n) < self.s).astype(jnp.float32)
        return jnp.ones((n,), jnp.float32)


def participation_key(round_key: Array) -> Array:
    """The shared derivation of the mask key from a round key."""
    return jax.random.fold_in(round_key, PARTICIPATION_FOLD)


def downlink_key(round_key: Array) -> Array:
    """The shared derivation of the broadcast key from a round key.  All
    execution paths (the reference driver, both trainers, the differential
    harness) use this, so the master's compressor draw -- and therefore the
    broadcast every worker decodes -- is identical everywhere."""
    return jax.random.fold_in(round_key, DOWNLINK_FOLD)


@dataclasses.dataclass(frozen=True)
class Pipeline:
    """The pipelined (one-round-stale) execution schedule.

    ``depth = 0`` is the sequential schedule of the paper: round t's
    aggregate is computed from round t's messages.  ``depth = 1``
    double-buffers the compressed payload: round t *applies* the messages
    compressed at round t-1 while round t's own messages are still on the
    wire -- the allgather/broadcast overlaps the next backward pass.
    Workers advance their control variates h_i on time; only the master's
    (g, h_avg) update lags one round, which the auto-tuning absorbs via
    :func:`repro.core.theory.pipeline_eta` / ``pipeline_omega``.  Depths
    beyond 1 would need a ring of in-flight buffers and are rejected.
    """

    depth: int = 0

    def __post_init__(self):
        if not isinstance(self.depth, int) or self.depth < 0:
            raise ValueError(
                f"pipeline depth must be an int >= 0, got {self.depth!r}")
        if self.depth > 1:
            raise ValueError(
                f"pipeline depth {self.depth} not implemented: the trainers "
                "double-buffer exactly ONE in-flight payload; use 'off' or "
                "'depth:1'")

    @staticmethod
    def parse(spec: str) -> "Pipeline":
        """Parse the CLI syntax: '' | 'off' | 'depth:k' (k in {0, 1}).

        Thin delegate into the unified spec grammar
        (:mod:`repro.core.specgrammar`), which also provides the lossless
        ``format_pipeline`` inverse; depth validation stays in
        :meth:`__post_init__`."""
        from repro.core import specgrammar
        return Pipeline(depth=specgrammar.parse_pipeline(spec))

    @property
    def is_off(self) -> bool:
        return self.depth == 0


# ------------------------------------------------------------------------------
# the downlink channel: master -> worker compressed model broadcast
# ------------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Downlink:
    """Master-side EF-BV state for the server -> worker model broadcast
    (EF21-BC generalized to any zoo compressor/codec; Fatkhullin et al. 2021,
    referenced by the paper as an extension).

    The master keeps its own control variate ``w`` -- the workers' shared
    reconstruction of the model -- and each round broadcasts the compressed
    model innovation through the compressor's wire codec:

        q^t   = C_s(x^{t+1} - w^t)          (one message, every worker)
        w^t+1 = w^t + lam_s * q^t

    Workers evaluate their gradients at ``w``, so the uplink direction is
    Algorithm 1 unchanged (with h_i tracking grad f_i(w)).  Because the
    broadcast is ONE payload drawn from the shared :func:`downlink_key`,
    federated rounds need no special casing: an absent worker decodes the
    exact same broadcast it would have received while present, so every
    worker's ``w`` stays replicated -- one copy suffices.

    ``lam_s`` is the downlink scaling (Prop. 1 applies to C_s too); the
    EF21-BC choice is 1.  With ``C_s = Identity`` (and a lossless f32 wire)
    the update telescopes to ``w = x`` and the implementation assigns ``x``
    verbatim, which is what keeps identity-downlink runs *bit-identical* to
    the uncompressed-broadcast trajectories (pinned by the harness).
    """

    compressor: Compressor
    lam: float = 1.0

    @staticmethod
    def parse(spec: str) -> Optional["Downlink"]:
        """CLI syntax: '' | 'none' -> None (uncompressed dense broadcast);
        otherwise any zoo compressor spec, e.g. 'qsgd:16', 'block_topk:256,16',
        optionally '@lam' for the downlink scaling ('topk:64@0.9').

        Thin delegate into the unified spec grammar
        (:mod:`repro.core.specgrammar`), which also provides the lossless
        ``format_downlink`` inverse."""
        from repro.core import specgrammar
        parsed = specgrammar.parse_downlink(spec)
        if parsed is None:
            return None
        compressor, lam = parsed
        return Downlink(compressor=compressor, lam=lam)

    def _is_lossless(self, wire_dtype: str) -> bool:
        from repro.core.compressors import Identity
        return (isinstance(self.compressor, Identity) and self.lam == 1.0
                and wire_dtype == "float32")

    def init(self, params: PyTree) -> PyTree:
        """w^0 = x^0 (workers start from the broadcast initial model)."""
        return jax.tree.map(jnp.asarray, params)

    def format_for(self, tree: PyTree, *, wire_dtype: str = "float32"):
        """The downlink WireFormat (one broadcast message per round)."""
        from repro.distributed import wire
        return wire.format_for(self.compressor, tree, wire_dtype=wire_dtype)

    def broadcast(self, key: Optional[Array], x: PyTree, w: PyTree, *,
                  wire_dtype: str = "float32"
                  ) -> Tuple[PyTree, list]:
        """One downlink round: returns ``(w_new, payloads)``.

        ``payloads`` is the per-leaf wire payload of the single broadcast
        message (what actually crosses the master -> worker wire;
        ``wire.payload_bytes`` of it equals ``downlink_bits_per_round / 8``
        exactly).  ``w_new = w + lam_s * decode(payload)`` -- computed from
        the DECODED payload, so master and workers agree bit-for-bit on the
        reconstruction.  The Identity/f32 wire is lossless and assigns
        ``w_new = x`` verbatim (bitwise; see the class docstring).
        """
        from repro.distributed import wire
        leaves, treedef = jax.tree.flatten(x)
        w_leaves = treedef.flatten_up_to(w)
        payloads, new_leaves = [], []
        for j, (xj, wj) in enumerate(zip(leaves, w_leaves)):
            codec = wire.codec_of(self.compressor, tuple(xj.shape),
                                  int(xj.size), wire_dtype)
            kj = None if key is None else jax.random.fold_in(key, j)
            delta = (xj.astype(jnp.float32)
                     - wj.astype(jnp.float32)).reshape(-1)
            payload = codec.encode(kj, delta)
            payloads.append(payload)
            if self._is_lossless(wire_dtype):
                new_leaves.append(xj)
            else:
                q = codec.decode(payload).reshape(xj.shape)
                new_leaves.append((wj.astype(jnp.float32)
                                   + self.lam * q).astype(wj.dtype))
        return jax.tree.unflatten(treedef, new_leaves), payloads

    # ---- the serving push protocol (compressed-delta model distribution) ----

    def serve_format(self, tree: PyTree, *, wire_dtype: str = "float32",
                     rules=None):
        """The downlink wire format of one serving push for ``tree``:
        the flat per-leaf format of :attr:`compressor`, or -- with per-leaf
        codec ``rules`` (wire.parse_leaf_rules) -- the pytree-native
        :class:`repro.distributed.wire.TreeWire`.  ``push_bits(fmt)`` is
        the exact envelope size of one delta push."""
        from repro.distributed import wire
        return wire.tree_format_for(self.compressor, tree,
                                    wire_dtype=wire_dtype,
                                    rules=tuple(rules) if rules else None)

    def push_kind(self, wire_dtype: str = "float32", rules=None) -> str:
        """'snapshot' for a lossless wire (the payload decodes to the model
        itself and the replica ASSIGNS it -- an identity-downlink push is a
        full checkpoint, bit-for-bit), 'delta' otherwise (the payload
        decodes to the innovation and the replica accumulates it).
        Per-leaf ``rules`` can re-map any leaf to a lossy codec, so a ruled
        push is always a delta."""
        if rules:
            return "delta"
        return "snapshot" if self._is_lossless(wire_dtype) else "delta"

    def encode_push(self, key: Optional[Array], x: PyTree, w: PyTree, *,
                    wire_dtype: str = "float32", rules=None
                    ) -> Tuple[PyTree, list]:
        """Trainer-side half of one serving push: returns ``(w_new,
        payloads)`` -- the replicas' next shared reconstruction and the one
        broadcast message that produces it.

        The payloads are the SAME bits the in-training broadcast puts on
        the wire (same codecs, same ``fold_in(key, j)`` leaf keys as
        :meth:`broadcast`), and ``w_new`` is computed by APPLYING them
        through :meth:`apply_push` -- the replica-side arithmetic -- so the
        pusher's w and every replica's w agree bit-for-bit by construction.
        A lossless wire ships a 'snapshot' (the model encoded absolutely,
        decode-assigns to exactly ``x``) instead of a delta: same exact bit
        count, and it preserves the :meth:`broadcast` invariant that a
        lossless downlink pins ``w = x`` verbatim, which ``w + (x - w)``
        float arithmetic would not."""
        from repro.distributed import wire
        fmt = self.serve_format(x, wire_dtype=wire_dtype, rules=rules)
        leaves, treedef = jax.tree.flatten(x)
        w_leaves = treedef.flatten_up_to(w)
        snapshot = self.push_kind(wire_dtype, rules) == "snapshot"
        payloads = []
        for j, (codec, xj, wj) in enumerate(zip(fmt.leaves, leaves,
                                                w_leaves)):
            kj = None if key is None else jax.random.fold_in(key, j)
            if snapshot:
                flat = xj.astype(jnp.float32).reshape(-1)
            else:
                flat = (xj.astype(jnp.float32)
                        - wj.astype(jnp.float32)).reshape(-1)
            payloads.append(codec.encode(kj, flat))
        w_new = self.apply_push(payloads, w, wire_dtype=wire_dtype,
                                rules=rules)
        return w_new, payloads

    def apply_push(self, payloads, w: PyTree, *,
                   wire_dtype: str = "float32", rules=None) -> PyTree:
        """Replica-side half of one serving push: decode the broadcast
        payloads and advance the local reconstruction, ``w_new = w + lam *
        decode(payload)`` per leaf ('delta' pushes) or ``w_new =
        decode(payload)`` verbatim ('snapshot' pushes from a lossless
        wire).  Same arithmetic, same op order as the trainer side
        (:meth:`broadcast` / :meth:`encode_push`), so a replica that
        applies every push in version order reconstructs the trainer's w
        bit-for-bit -- the property tests/test_serve_delta.py pins for
        every zoo codec."""
        fmt = self.serve_format(w, wire_dtype=wire_dtype, rules=rules)
        w_leaves, treedef = jax.tree.flatten(w)
        snapshot = self.push_kind(wire_dtype, rules) == "snapshot"
        new_leaves = []
        for codec, wj, p in zip(fmt.leaves, w_leaves, payloads):
            q = codec.decode(p).reshape(wj.shape)
            if snapshot:
                new_leaves.append(q.astype(wj.dtype))
            else:
                new_leaves.append((wj.astype(jnp.float32)
                                   + self.lam * q).astype(wj.dtype))
        return jax.tree.unflatten(treedef, new_leaves)


class EFBVState(NamedTuple):
    """State of Algorithm 1.

    h:      per-worker control variates h_i -- leading axis n in the reference
            impl; local (no leading axis) inside shard_map.
    h_avg:  the master's running average h^t = (1/n) sum_i h_i^t.
    step:   iteration counter t.
    """

    h: PyTree
    h_avg: PyTree
    step: Array


@dataclasses.dataclass(frozen=True)
class EFBV:
    """The algorithm, frozen so it can be a static jit argument.

    lam/nu are the two scaling parameters (Sect. 3): lam controls the control-
    variate update (variance reduction), nu the gradient-estimate update
    (error feedback).  nu = lam -> EF21; nu = 1 -> DIANA.

    ``fleet`` switches on the *heterogeneous* setting (Beznosikov et al.
    2020): worker i runs its OWN compressor ``fleet[i]`` (length exactly n;
    round-robin expansion happens at parse time, see
    ``compressors.make_fleet``).  ``compressor`` then holds ``fleet[0]`` as
    the representative; (lam, nu) are tuned for the aggregated mixed-fleet
    constants (theory.tune_fleet).  A homogeneous fleet collapses to
    ``fleet=None`` so the single-compressor fast paths stay untouched.

    ``leaf_rules`` switches on the *pytree-native* wire (wire.TreeWire):
    (fnmatch-pattern, Compressor) pairs resolved against each leaf's
    '/'-joined path, first match wins, unmatched leaves keep ``compressor``.
    Every consumer (compress_delta, the aggregation paths, init_inflight)
    resolves leaves through the same wire.tree_format_for chokepoint, and
    (lam, nu) are tuned for the worst-case leaf composition
    (theory.tune_tree).  ``leaf_rules=None`` is the flat wire, bitwise.
    """

    compressor: Compressor
    lam: float
    nu: float
    fleet: Optional[Tuple[Compressor, ...]] = None
    leaf_rules: Optional[Tuple[Tuple[str, Compressor], ...]] = None

    # ---- constructors -------------------------------------------------------

    @staticmethod
    def make(compressor, d: int, n: int, mode: theory.Mode = "efbv",
             independent: bool = True,
             participation: Optional[float] = None,
             pipeline: Optional[int] = None,
             leaf_rules: Optional[Tuple[Tuple[str, Compressor], ...]] = None
             ) -> "EFBV":
        """Auto-tuned instance (Remark 1).  ``participation`` is the expected
        per-round participation fraction p; when given, (lam, nu) are tuned
        for the effective compressor b*C, b ~ Bernoulli(p) (theory.tune_partial
        -- see docs/theory.md).  ``pipeline`` is the staleness depth of the
        pipelined schedule; when given, the one-round delay is folded into
        the certified constants (theory.pipeline_eta / pipeline_omega) --
        None / 0 is an exact no-op.

        ``compressor`` may be a sequence of compressors -- a heterogeneous
        fleet, round-robin expanded to n members -- tuned via
        theory.tune_fleet (worst-case aggregation; see docs/theory.md).

        ``leaf_rules`` (per-leaf codec rules, wire.parse_leaf_rules) tunes
        (lam, nu) for the worst-case composition over the base compressor
        and every rule member at dimension d (theory.tune_tree; leaf sizes
        are tree-dependent, and the worst-case aggregate is size-free).
        An empty/None rule set is an exact no-op."""
        if isinstance(compressor, (list, tuple)):
            if leaf_rules:
                raise ValueError("per-leaf codec rules cannot be combined "
                                 "with a heterogeneous worker fleet")
            from repro.core.compressors import expand_fleet
            members = expand_fleet(tuple(compressor), n)
            t = theory.tune_for(members, d, n, independent=independent,
                                mode=mode, participation=participation,
                                pipeline=pipeline)
            fleet = None if len(set(members)) == 1 else members
            return EFBV(members[0], lam=t.lam, nu=t.nu, fleet=fleet)
        if leaf_rules:
            if not independent:
                raise ValueError("per-leaf codec tuning assumes independent "
                                 "per-worker compressors")
            comps = [compressor] + [c for _, c in leaf_rules]
            for c in comps:
                if getattr(c, "joint", False):
                    # same rejection as wire.parse_leaf_rules: the string
                    # grammar cannot name a joint compressor, this guards
                    # the programmatic path
                    raise ValueError(
                        "jointly-defined compressors (m-nice) cannot be "
                        "leaf-codec rules: their draws couple all workers")
            t = theory.tune_tree([c.eta(d) for c in comps],
                                 [c.omega(d) for c in comps],
                                 n=n, aggregate="worst", mode=mode,
                                 participation=participation,
                                 pipeline=pipeline)
            return EFBV(compressor, lam=t.lam, nu=t.nu,
                        leaf_rules=tuple(leaf_rules))
        t = theory.tune_for(compressor, d, n, independent=independent, mode=mode,
                            participation=participation, pipeline=pipeline)
        return EFBV(compressor, lam=t.lam, nu=t.nu)

    @staticmethod
    def ef21(compressor: Compressor, d: int, n: int) -> "EFBV":
        return EFBV.make(compressor, d, n, mode="ef21")

    @staticmethod
    def diana(compressor: Compressor, d: int, n: int) -> "EFBV":
        return EFBV.make(compressor, d, n, mode="diana")

    # ---- state --------------------------------------------------------------

    def init(self, params: PyTree, n: int, stacked: bool = True) -> EFBVState:
        """h_i^0 = 0 (any init works; 0 matches the paper's experiments)."""
        zeros = jax.tree.map(jnp.zeros_like, params)
        if stacked:
            h = jax.tree.map(lambda z: jnp.zeros((n,) + z.shape, z.dtype), params)
        else:
            h = zeros
        return EFBVState(h=h, h_avg=zeros, step=jnp.zeros((), jnp.int32))

    # ---- algorithm core (shared by reference and distributed paths) ----------

    def compress_delta(self, key: Optional[Array], grad: PyTree, h: PyTree,
                       compressor: Optional[Compressor] = None) -> PyTree:
        """d_i = C_i(grad_i - h_i), leaf-wise with decorrelated keys.

        ``compressor`` overrides ``self.compressor`` (the heterogeneous-fleet
        path passes worker i's own member).  With ``leaf_rules`` set (and no
        override) each leaf runs the compressor its path resolves to, clamped
        to the leaf's size -- the dense twin of the TreeWire codec path, so
        reference and wire trajectories stay bit-identical leaf-wise."""
        comp = self.compressor if compressor is None else compressor
        leaves, treedef = jax.tree.flatten(grad)
        h_leaves = treedef.flatten_up_to(h)
        if compressor is None and self.leaf_rules:
            from repro.distributed import wire
            comps = [wire.clamp_for_leaf(
                wire.resolve_leaf(self.leaf_rules, p, comp), int(g.size))
                for p, g in zip(wire.leaf_paths(grad), leaves)]
        else:
            comps = [comp] * len(leaves)
        outs = []
        for j, (cj, g, hj) in enumerate(zip(comps, leaves, h_leaves)):
            kj = None if key is None else jax.random.fold_in(key, j)
            outs.append(cj(kj, g - hj))
        return jax.tree.unflatten(treedef, outs)

    def _compress_fleet(self, keys: Array, grads: PyTree, h: PyTree,
                        n: int) -> PyTree:
        """Per-worker d_i = C_i(grad_i - h_i) for a heterogeneous fleet:
        a static Python loop over workers (each member is a different
        program), stacked back on the worker axis.  Key derivation matches
        the vmap path (keys[i] for worker i) so a homogeneous fleet draws
        identically to :meth:`step`'s vmap."""
        if len(self.fleet) != n:
            raise ValueError(f"fleet of {len(self.fleet)} members for {n} "
                             "workers (expand_fleet sizes it to n)")
        d_workers = []
        for i in range(n):
            g_i = jax.tree.map(lambda a: a[i], grads)
            h_i = jax.tree.map(lambda a: a[i], h)
            d_workers.append(
                self.compress_delta(keys[i], g_i, h_i, self.fleet[i]))
        return jax.tree.map(lambda *ds: jnp.stack(ds), *d_workers)

    def worker_update(self, h: PyTree, d: PyTree) -> PyTree:
        """h_i <- h_i + lam d_i."""
        return jax.tree.map(lambda hj, dj: hj + self.lam * dj, h, d)

    def worker_update_masked(self, h: PyTree, d: PyTree, m: Array) -> PyTree:
        """Participation-gated worker update: h_i <- h_i + lam d_i when worker
        i is sampled (m = 1), STALE h_i otherwise (m = 0).

        ``where`` (not ``h + m*lam*d``) so an absent worker's h_i is the old
        array verbatim; at m = 1 the taken branch is exactly
        :meth:`worker_update`'s arithmetic, hence bit-identical.
        """
        return jax.tree.map(
            lambda hj, dj: jnp.where(m > 0, hj + self.lam * dj, hj), h, d)

    def master_update(self, h_avg: PyTree, d_bar: PyTree) -> Tuple[PyTree, PyTree]:
        """g <- h + nu d_bar ; h <- h + lam d_bar.  Returns (g, new h_avg).

        The federated mode needs NO master variant: absent workers' messages
        are zeroed worker-side and d_bar stays normalized by n (not |S_t|),
        which is exactly what preserves the running-average invariant
        h_avg = (1/n) sum_i h_i when only the sampled h_i moved.
        """
        g = jax.tree.map(lambda hj, dj: hj + self.nu * dj, h_avg, d_bar)
        h_new = jax.tree.map(lambda hj, dj: hj + self.lam * dj, h_avg, d_bar)
        return g, h_new

    # ---- reference (vmap-over-workers) step ----------------------------------

    def compress_round(self, key: Array, grads: PyTree, state: EFBVState,
                       mask: Optional[Array] = None
                       ) -> Tuple[PyTree, PyTree]:
        """The worker half of one round: returns ``(d_bar, h_new)`` --
        the normalized aggregate d_bar = (1/n) sum_i [m_i] C_i(grad_i - h_i)
        and the advanced per-worker control variates -- WITHOUT the master
        update.  Factored out of :meth:`step` / :meth:`step_federated`
        (which compose it with :meth:`master_update`, bit-identical to
        their historical bodies) so the pipelined schedule can apply a
        one-round-stale d_bar while h_i advances on time."""
        n = jax.tree.leaves(grads)[0].shape[0]

        if getattr(self.compressor, "joint", False):
            if mask is not None:
                raise ValueError(
                    "jointly-defined compressors (m-nice) model participation "
                    "themselves; combine them with Participation masks is "
                    "ambiguous")

            # jointly-defined compressors (m-nice partial participation,
            # Sect. 2.4): every worker samples from the SAME round key
            def one_worker(i, g_i, h_i):
                return jax.tree.map(
                    lambda g, h: self.compressor.joint_call(key, i, g - h),
                    g_i, h_i)

            d = jax.vmap(one_worker)(jnp.arange(n), grads, state.h)
            h_new = jax.vmap(self.worker_update)(state.h, d)
            d_bar = jax.tree.map(lambda dj: jnp.mean(dj, axis=0), d)
            return d_bar, h_new

        keys = jax.random.split(key, n)
        if self.fleet is not None:
            d = self._compress_fleet(keys, grads, state.h, n)
        else:
            d = jax.vmap(lambda k, g_i, h_i: self.compress_delta(k, g_i, h_i)
                         )(keys, grads, state.h)
        if mask is None:
            h_new = jax.vmap(self.worker_update)(state.h, d)
            d_bar = jax.tree.map(lambda dj: jnp.mean(dj, axis=0), d)
        else:
            h_new = jax.vmap(self.worker_update_masked)(state.h, d, mask)
            d_bar = jax.tree.map(
                lambda dj: jnp.mean(
                    mask.reshape((n,) + (1,) * (dj.ndim - 1)) * dj, axis=0), d)
        return d_bar, h_new

    def step(self, key: Array, grads: PyTree, state: EFBVState
             ) -> Tuple[PyTree, EFBVState]:
        """One round of Algorithm 1.

        grads: per-worker gradients with leading axis n on every leaf
               (grads_i = nabla f_i(x^t)).
        Returns (g^{t+1}, new state); the caller applies
        x^{t+1} = prox_{gamma R}(x^t - gamma g^{t+1}).
        """
        d_bar, h_new = self.compress_round(key, grads, state)
        g, h_avg_new = self.master_update(state.h_avg, d_bar)
        return g, EFBVState(h=h_new, h_avg=h_avg_new, step=state.step + 1)

    # ---- federated (partial-participation) reference step ---------------------

    def step_federated(self, key: Array, grads: PyTree, state: EFBVState,
                       mask: Array) -> Tuple[PyTree, EFBVState]:
        """One round of Algorithm 1 under per-round client sampling.

        ``mask`` is the (n,) {0., 1.} participation mask of this round
        (Participation.sample_mask).  Only sampled workers contribute their
        compressed innovation d_i and advance h_i; absent workers' h_i stay
        stale and their (zero) message still counts in the 1/n normalization,
        preserving h_avg = (1/n) sum_i h_i.  With an all-ones mask this is
        bit-identical to :meth:`step`.
        """
        if getattr(self.compressor, "joint", False):
            raise ValueError(
                "jointly-defined compressors (m-nice) model participation "
                "themselves; combine them with Participation masks is ambiguous")
        d_bar, h_new = self.compress_round(key, grads, state, mask)
        g, h_avg_new = self.master_update(state.h_avg, d_bar)
        return g, EFBVState(h=h_new, h_avg=h_avg_new, step=state.step + 1)


# ------------------------------------------------------------------------------
# proximal operators for the composite term R (problem (1))
# ------------------------------------------------------------------------------

def prox_zero(gamma: float, x: PyTree) -> PyTree:
    return x


def prox_l2(mu_reg: float) -> Callable[[float, PyTree], PyTree]:
    """R = (mu_reg/2)||x||^2  ->  prox = x / (1 + gamma mu_reg)."""

    def prox(gamma, x):
        return jax.tree.map(lambda v: v / (1.0 + gamma * mu_reg), x)

    return prox


def prox_l1(lam_reg: float) -> Callable[[float, PyTree], PyTree]:
    """R = lam_reg ||x||_1  ->  soft threshold."""

    def prox(gamma, x):
        t = gamma * lam_reg
        return jax.tree.map(lambda v: jnp.sign(v) * jnp.maximum(jnp.abs(v) - t, 0.0), x)

    return prox


def proximal_step(x: PyTree, g: PyTree, gamma: float,
                  prox: Callable[[float, PyTree], PyTree] = prox_zero) -> PyTree:
    """x^{t+1} = prox_{gamma R}(x^t - gamma g^{t+1})."""
    y = jax.tree.map(lambda xv, gv: xv - gamma * gv, x, g)
    return prox(gamma, y)


# ------------------------------------------------------------------------------
# THE reference driver: one lax.scan subsuming every execution mode
# ------------------------------------------------------------------------------

class ReferenceRun(NamedTuple):
    """Result of :func:`run_reference`.

    x:       final iterate.
    state:   final :class:`EFBVState` (per-worker + master control variates).
    w:       final downlink control variate (workers' shared model
             reconstruction) under bidirectional compression; None otherwise.
    metrics: per-round scalars from ``record``; None when not recording.
    pending: the in-flight aggregate d_bar of the LAST round under the
             pipelined schedule (compressed but not yet applied by the
             master); None for the sequential schedule.
    """

    x: PyTree
    state: EFBVState
    w: Optional[PyTree]
    metrics: Optional[Array]
    pending: Optional[PyTree] = None


def run_reference(
    *,
    algo: EFBV,
    grad_fn: Callable[[Array, PyTree], PyTree],  # (key, x|w) -> n-leading grads
    x0: PyTree,
    gamma: float,
    steps: int,
    key: Array,
    n: int,
    participation: Optional[Participation] = None,
    downlink: Optional[Downlink] = None,
    prox: Callable[[float, PyTree], PyTree] = prox_zero,
    record: Optional[Callable[[PyTree], Array]] = None,
    wire_dtype: str = "float32",
    pipeline: Optional[Pipeline] = None,
) -> ReferenceRun:
    """jit-compiled lax.scan over Algorithm 1 -- the ONE reference driver.

    The execution mode is selected by what is (not) supplied, exactly the
    cross-product :class:`repro.core.spec.ExperimentSpec` declares:

    * ``participation`` None / full -- the paper's full-participation regime
      (:meth:`EFBV.step`); otherwise per-round client sampling with the
      shared :func:`participation_key` mask derivation and
      :meth:`EFBV.step_federated` (absent workers keep h_i stale).
    * ``downlink`` None -- uncompressed model broadcast (workers read x);
      otherwise the bidirectional wire: workers evaluate gradients at the
      shared reconstruction ``w`` and each round ends with ONE compressed
      broadcast drawn from :func:`downlink_key`.
    * ``grad_fn(key, x)`` may consume the per-round resampling key
      (fold_in(round_key, RESAMPLE_FOLD)) for stochastic local gradients;
      exact-gradient callers simply ignore it.
    * ``pipeline`` None / depth 0 -- the sequential schedule; depth 1 is
      the exact dense oracle of the trainers' pipelined schedule: the
      master applies the aggregate compressed one round earlier (round 0
      applies a zero buffer, so x is unchanged while h_i advances), and
      the last round's aggregate is returned as ``.pending``.

    Each simpler mode reduces *bitwise* to the corresponding specialization:
    the masked ops are arithmetic identities at m = 1 and the Identity/f32
    downlink assigns w = x verbatim, so the spec-driven path
    (``repro.core.build(spec).reference()``) stays bit-identical to a direct
    call supplying only the relevant arguments (pinned by tests/test_spec.py).
    """
    part = participation if participation is not None else Participation()
    depth = 0 if pipeline is None else pipeline.depth
    state0 = algo.init(x0, n)
    w0 = downlink.init(x0) if downlink is not None else None

    if depth:
        # pipelined schedule: the master applies the aggregate compressed
        # `depth` (= 1) rounds ago; the in-flight buffer rides in the carry
        # and starts at the zero aggregate (round 0 leaves x unchanged).
        pending0 = jax.tree.map(jnp.zeros_like, x0)

        def body(carry, k):
            x, w, st, pending = carry
            eval_at = w if downlink is not None else x
            grads = grad_fn(jax.random.fold_in(k, RESAMPLE_FOLD), eval_at)
            if part.is_full:
                d_new, h_new = algo.compress_round(k, grads, st)
            else:
                mask = part.sample_mask(participation_key(k), n)
                d_new, h_new = algo.compress_round(k, grads, st, mask)
            g, h_avg_new = algo.master_update(st.h_avg, pending)
            st = EFBVState(h=h_new, h_avg=h_avg_new, step=st.step + 1)
            x = proximal_step(x, g, gamma, prox)
            if downlink is not None:
                w, _ = downlink.broadcast(downlink_key(k), x, w,
                                          wire_dtype=wire_dtype)
            m = record(x) if record is not None else jnp.zeros(())
            return (x, w, st, d_new), m

        keys = jax.random.split(key, steps)
        (x, w, state, pending), metrics = jax.lax.scan(
            body, (x0, w0, state0, pending0), keys)
        return ReferenceRun(x=x, state=state, w=w,
                            metrics=metrics if record is not None else None,
                            pending=pending)

    def body(carry, k):
        x, w, st = carry
        # under bidirectional compression workers only ever see w
        eval_at = w if downlink is not None else x
        grads = grad_fn(jax.random.fold_in(k, RESAMPLE_FOLD), eval_at)
        if part.is_full:
            g, st = algo.step(k, grads, st)
        else:
            mask = part.sample_mask(participation_key(k), n)
            g, st = algo.step_federated(k, grads, st, mask)
        x = proximal_step(x, g, gamma, prox)
        if downlink is not None:
            w, _ = downlink.broadcast(downlink_key(k), x, w,
                                      wire_dtype=wire_dtype)
        m = record(x) if record is not None else jnp.zeros(())
        return (x, w, st), m

    keys = jax.random.split(key, steps)
    (x, w, state), metrics = jax.lax.scan(body, (x0, w0, state0), keys)
    return ReferenceRun(x=x, state=state, w=w,
                        metrics=metrics if record is not None else None)
