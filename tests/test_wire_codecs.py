"""Property tests for the wire-codec registry (tests/_prop.py driven).

For EVERY compressor in the zoo: the registered codec's
``decode(encode(x))`` equals the dense compressor output bit-for-bit (exact
equality, not closeness -- the codec IS the compressor on the wire), the
measured payload bytes equal ``payload_bits / 8`` exactly (padding
included), and the worker-stacked decode-sum matches the sum of individual
decodes.  Also pins the fp16/bf16 value-precision knob and the acceptance
ratio for the quantized codecs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.core import (BlockTopK, CompKK, FracCompKK, FracTopK, Identity,
                        MixKK, Natural, QSGD, RandK, ScaledRandK, SignNorm,
                        TopK, make_compressor)
from repro.core.compressors import MNice
from repro.distributed import wire

D = 96

ZOO = [
    ("identity", Identity()),
    ("topk", TopK(7)),
    ("randk", RandK(9)),
    ("scaled_randk", ScaledRandK(5)),
    ("comp", CompKK(3, 20)),
    ("mix", MixKK(4, 9)),
    ("block_topk", BlockTopK(16, 4)),
    ("sign", SignNorm()),
    ("natural", Natural()),
    ("qsgd", QSGD(16)),
    ("qsgd_wide", QSGD(400)),
    ("qsgd_odd", QSGD(7)),
    ("frac_topk", FracTopK(0.05)),
    ("frac_comp", FracCompKK(0.03, 0.4)),
    ("mnice", MNice(4, 2)),
]


@pytest.mark.parametrize("name,comp", ZOO, ids=[n for n, _ in ZOO])
@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_codec_roundtrip_bit_exact_and_bytes(name, comp, seed):
    """decode(encode(x)) == dense C(x) exactly; payload bytes == bits/8."""
    x = jax.random.normal(jax.random.key(seed), (D,))
    key = jax.random.key(seed ^ 0xC0DEC)
    codec = wire.codec_of(comp, (D,), D)
    dense = comp(key, x)
    payload = codec.encode(key, x)
    rec = codec.decode(payload)
    assert rec.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(rec), np.asarray(dense),
                                  err_msg=name)
    assert codec.payload_bits % 8 == 0, name
    assert 8 * wire.payload_bytes(payload) == codec.payload_bits, name


@pytest.mark.parametrize("name,comp", ZOO, ids=[n for n, _ in ZOO])
def test_codec_decode_sum_matches_stacked(name, comp):
    """decode_sum of a worker-stacked payload == sum of individual decodes
    (the local combine of the sparse_allgather collective)."""
    n = 3
    keys = jax.random.split(jax.random.key(1), n)
    xs = jax.random.normal(jax.random.key(2), (n, D))
    codec = wire.codec_of(comp, (D,), D)
    payloads = [codec.encode(k, x) for k, x in zip(keys, xs)]
    stacked = jax.tree.map(lambda *a: jnp.stack(a), *payloads)
    got = codec.decode_sum(stacked)
    want = sum(np.asarray(codec.decode(p)) for p in payloads)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-5, err_msg=name)


def test_every_registered_spec_has_a_codec():
    """make_compressor's whole registry: format_for never returns None and
    every leaf codec reports positive, exact bits."""
    tree = {"w": jnp.zeros((24, 4)), "b": jnp.zeros((17,))}
    specs = ["identity", "topk:8", "randk:4", "scaled_randk:4", "comp:2,8",
             "mix:2,4", "block_topk:16,2", "sign", "natural", "qsgd:16",
             "frac_topk:50", "frac_comp:20,400"]
    for spec in specs:
        fmt = wire.format_for(make_compressor(spec), tree)
        assert fmt is not None, spec
        assert len(fmt.leaves) == 2, spec
        assert fmt.bits_per_round() > 0, spec
        assert fmt.bits_per_round(n_workers=8) == 8 * fmt.bits_per_round()


def test_quantized_codecs_beat_a_third_of_dense():
    """Acceptance: QSGD and natural payloads are <= 1/3 of dense fp32."""
    d = 4096
    for comp in [QSGD(16), Natural()]:
        codec = wire.codec_of(comp, (d,), d)
        assert codec.payload_bits <= 32 * d / 3, (comp, codec.payload_bits)
    # sign is ~1 bit/coordinate
    assert wire.codec_of(SignNorm(), (d,), d).payload_bits <= 32 + 32 * (d // 32 + 1)


def test_wire_dtype_knob_halves_sparse_values():
    """fp16/bf16 value payloads: honest accounting and a cast-consistent
    decode (exactness only holds at float32 -- the default)."""
    x = jax.random.normal(jax.random.key(3), (D,))
    comp = TopK(8)
    c32 = wire.codec_of(comp, (D,), D, "float32")
    c16 = wire.codec_of(comp, (D,), D, "bfloat16")
    assert c16.payload_bits == 8 * (16 + 32) < c32.payload_bits
    payload = c16.encode(None, x)
    vals, idx = payload
    assert vals.dtype == jnp.bfloat16
    assert 8 * wire.payload_bytes(payload) == c16.payload_bits
    rec = c16.decode(payload)
    dense = comp(None, x)
    # decode == dense rounded through the wire dtype, exactly
    want = jnp.zeros((D,)).at[idx].add(
        np.asarray(dense)[np.asarray(idx)].astype(jnp.bfloat16).astype(
            jnp.float32))
    np.testing.assert_array_equal(np.asarray(rec), np.asarray(want))


def test_dense_pack_identity_is_lossless():
    x = jax.random.normal(jax.random.key(4), (D,))
    codec = wire.codec_of(Identity(), (D,), D)
    np.testing.assert_array_equal(
        np.asarray(codec.decode(codec.encode(None, x))), np.asarray(x))
    assert codec.payload_bits == 32 * D


# ---------------------------------------------------------------------------
# every codec as a DOWNLINK codec (master -> worker broadcast)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,comp", ZOO, ids=[n for n, _ in ZOO])
@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=4, deadline=None)
def test_downlink_roundtrip_bit_exact_and_bytes(name, comp, seed):
    """Any zoo codec works on the downlink: the broadcast payload of a
    master delta x - w decodes to the dense compressor output bit-for-bit,
    and its measured bytes equal downlink_bits_per_round / 8 exactly."""
    from repro.core import Downlink

    # a master-delta-shaped input: the model innovation x^{t+1} - w^t
    x = jax.random.normal(jax.random.key(seed), (D,)) * 0.3
    w = jax.random.normal(jax.random.key(seed ^ 1), (D,)) * 0.3
    key = jax.random.key(seed ^ 0xD01)
    down = Downlink(comp)
    fmt = down.format_for(jnp.zeros((D,)))
    w_new, payloads = down.broadcast(key, x, w)
    assert len(payloads) == 1
    assert 8 * wire.payload_bytes(payloads[0]) \
        == fmt.downlink_bits_per_round(), name
    # the reconstruction update is exactly w + decode(payload)
    codec = fmt.leaves[0]
    dense = comp(None if not comp.is_random() else jax.random.fold_in(key, 0),
                 x - w)
    np.testing.assert_array_equal(np.asarray(codec.decode(payloads[0])),
                                  np.asarray(dense), err_msg=name)
    np.testing.assert_array_equal(
        np.asarray(w_new),
        np.asarray(x if isinstance(comp, Identity) else w + codec.decode(payloads[0])),
        err_msg=name)


def test_downlink_identity_assigns_x_verbatim():
    """The lossless Identity/f32 downlink assigns w = x bitwise (not
    w + (x - w), which re-rounds)."""
    from repro.core import Downlink

    x = jax.random.normal(jax.random.key(0), (D,))
    w = jax.random.normal(jax.random.key(1), (D,))
    w_new, _ = Downlink(Identity()).broadcast(jax.random.key(2), x, w)
    np.testing.assert_array_equal(np.asarray(w_new), np.asarray(x))
    # a non-f32 wire is lossy -> no verbatim assignment
    w16, (p16,) = Downlink(Identity()).broadcast(
        jax.random.key(2), x, w, wire_dtype="bfloat16")
    assert p16[0].dtype == jnp.bfloat16
    assert not np.array_equal(np.asarray(w16), np.asarray(x))


def test_total_round_bits_composes_up_down_and_participation():
    """total_round_bits = uplink (with the PR-3 federated accounting) +
    ONE downlink broadcast; the downlink never scales with n or |S_t|."""
    d = 4096
    up = wire.format_for(QSGD(16), jnp.zeros((d,)))
    down = wire.format_for(BlockTopK(256, 16), jnp.zeros((d,)))
    n = 8
    full = wire.total_round_bits(up, down, n_workers=n)
    assert full == up.bits_per_round(n_workers=n) \
        + down.downlink_bits_per_round()
    fed = wire.total_round_bits(up, down, n_workers=n, participants=3)
    assert fed == up.bits_per_round(n_workers=n, participants=3) \
        + down.downlink_bits_per_round()
    # down=None is the honest dense fp32 broadcast
    assert wire.total_round_bits(up, None, n_workers=n) \
        == up.bits_per_round(n_workers=n) + 32 * d


def test_qsgd_both_directions_beats_035x_dense():
    """Acceptance: qsgd:16 on BOTH directions puts <= 0.35x of the dense
    fp32 up+down traffic on the wire (measured payload bytes, not
    estimates)."""
    d, n = 1 << 16, 8
    comp = QSGD(16)
    fmt = wire.format_for(comp, jnp.zeros((d,)))
    total = wire.total_round_bits(fmt, fmt, n_workers=n)
    dense_both = 32 * d * n + 32 * d
    assert total <= 0.35 * dense_both, (total, dense_both)
    # and the accounting is measured: one uplink message + one broadcast
    x = jax.random.normal(jax.random.key(0), (d,))
    payload = fmt.leaves[0].encode(jax.random.key(1), x)
    assert 8 * wire.payload_bytes(payload) == fmt.bits_per_round()
    assert total == n * 8 * wire.payload_bytes(payload) \
        + 8 * wire.payload_bytes(payload)


# ---------------------------------------------------------------------------
# heterogeneous fleets: one round, three workers, three codecs
# ---------------------------------------------------------------------------

def test_mixed_fleet_three_codecs_one_round():
    """Three workers running three different codecs in one round: each
    worker's payload decodes bit-exactly, the master mean is the mean of
    the per-worker decodes, and the fleet wire accounting is the sum of the
    heterogeneous per-worker payload bits."""
    from repro.core import EFBV, make_fleet

    n, lam = 3, 0.9
    fleet = make_fleet("topk:7;qsgd:16;sign", n)
    algo = EFBV(fleet[0], lam=lam, nu=1.0, fleet=fleet)
    g = jax.random.normal(jax.random.key(0), (n, D))
    h = jax.random.normal(jax.random.key(1), (n, D)) * 0.1
    keys = jax.random.split(jax.random.key(2), n)

    d_bar = jnp.zeros((D,))
    bits = 0
    for i in range(n):
        codec = wire.codec_of(fleet[i], (D,), D)
        payload, h_new = wire.encode_update(codec, keys[i], g[i], h[i], lam)
        dense_d = fleet[i](keys[i] if fleet[i].is_random() else None,
                           g[i] - h[i])
        np.testing.assert_array_equal(np.asarray(codec.decode(payload)),
                                      np.asarray(dense_d), err_msg=str(i))
        np.testing.assert_array_equal(np.asarray(h_new),
                                      np.asarray(h[i] + lam * dense_d))
        d_bar = d_bar + codec.decode(payload) / n
        bits += 8 * wire.payload_bytes(payload)
        assert bits > 0

    fmts = wire.fleet_formats(fleet, jnp.zeros((D,)))
    assert wire.fleet_bits_per_round(fmts) == bits
    # federated variant: only workers 0 and 2 sampled -> bitmap + their bits
    mask = jnp.asarray([1.0, 0.0, 1.0])
    assert wire.fleet_bits_per_round(fmts, mask) == (
        32 + fmts[0].bits_per_round() + fmts[2].bits_per_round())
    # the reference EFBV fleet step agrees with the hand-rolled round
    st = algo.init(jnp.zeros((D,)), n)
    st = st._replace(h=h[:, :])
    # (compress draws differ by key path; just pin shapes + mean structure)
    g_out, st2 = algo.step(jax.random.key(3), g, st)
    assert g_out.shape == (D,) and st2.h.shape == (n, D)


def test_natural_codec_domain_note():
    """The natural codec clips exponents to [-126, 127]: values inside the
    normal fp32 range roundtrip exactly even at extreme scales."""
    for scale in (1e-30, 1e30):
        x = jax.random.normal(jax.random.key(5), (D,)) * scale
        key = jax.random.key(6)
        comp = Natural()
        codec = wire.codec_of(comp, (D,), D)
        np.testing.assert_array_equal(
            np.asarray(codec.decode(codec.encode(key, x))),
            np.asarray(comp(key, x)))
