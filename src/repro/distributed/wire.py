"""The sparse wire format: payload layout, exact bit accounting, and the
pack/unpack/scatter-add helpers shared by the reference and shard_map paths.

The paper's accounting ("number of bits sent by each node ... proportional to
t*k", Sect. 6) only holds if the bytes that cross the wire are the payload,
not a dense mask-compressed tensor.  This module is the single source of
truth for what that payload IS:

  per leaf (d elements, block size b, kb kept per block, nb = ceil(d/b)):

      values   (nb, kb)  val_dtype   -- kept signed deltas, |.|-descending
      indices  (nb, kb)  int32       -- block-LOCAL column indices

  Local indices keep every index < b (no int32 overflow on 4e10-element
  stacked expert tensors) and make the payload layout independent of the
  leaf's global offset, so the same scatter-add works for a single worker's
  message and for the worker-stacked (n, nb, kb) all-gather result.

Three producers emit this layout and are pinned bit-identical by the
differential harness (tests/harness.py):

  * ``pack_oracle``       -- pure jnp (jax.lax.top_k), the spec;
  * kernels/pack.py       -- fused Pallas kernel, interpret mode (CPU tests);
  * kernels/pack.py       -- same kernel, compiled (TPU).

``bits_per_round`` is EXACT: it must equal 8 * (payload nbytes) -- the wire
tests assert equality, not proportionality.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any

# kernel dispatch for the fused pack: 'auto' uses the compiled Pallas kernel
# on TPU and the jnp oracle elsewhere; 'interpret' forces the Pallas kernel
# in interpret mode (slow -- differential testing only); 'oracle' forces jnp.
KERNEL_MODES = ("auto", "pallas", "interpret", "oracle")


def _kernel_mode(kernel: Optional[str]) -> str:
    mode = kernel or os.environ.get("REPRO_WIRE_KERNEL", "auto")
    if mode not in KERNEL_MODES:
        raise ValueError(f"wire kernel {mode!r} not in {KERNEL_MODES}")
    if mode == "auto":
        mode = "pallas" if jax.default_backend() == "tpu" else "oracle"
    return mode


# ---------------------------------------------------------------------------
# format metadata
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LeafWire:
    """Wire layout of one pytree leaf."""

    shape: Tuple[int, ...]
    size: int
    block: int
    kb: int

    @property
    def nb(self) -> int:
        return -(-self.size // self.block)

    @property
    def payload_bits(self) -> int:
        """Exact bits of one worker's message for this leaf: f32 values +
        int32 local indices, (nb, kb) each."""
        return self.nb * self.kb * (32 + 32)


@dataclasses.dataclass(frozen=True)
class WireFormat:
    """Payload layout for a whole gradient pytree (leaf order = flatten
    order, which both aggregation paths use)."""

    leaves: Tuple[LeafWire, ...]

    @staticmethod
    def for_tree(tree: PyTree, block: int, kb: int) -> "WireFormat":
        return WireFormat(tuple(
            LeafWire(shape=tuple(l.shape), size=int(l.size), block=block, kb=kb)
            for l in jax.tree.leaves(tree)))

    def bits_per_round(self, *, n_workers: int = 1) -> int:
        """Exact uplink bits one round puts on the wire: per worker when
        n_workers == 1 (the paper's per-node accounting), total otherwise."""
        return n_workers * sum(l.payload_bits for l in self.leaves)


def format_for(compressor, tree: PyTree) -> Optional[WireFormat]:
    """WireFormat when ``compressor`` emits this payload (block-top-k
    family: has integer ``block``/``kb`` fields), else None."""
    block = getattr(compressor, "block", None)
    kb = getattr(compressor, "kb", None)
    if isinstance(block, int) and isinstance(kb, int):
        return WireFormat.for_tree(tree, block, kb)
    return None


def payload_bytes(payload: PyTree) -> int:
    """Measured bytes of a payload pytree (what actually crosses the wire)."""
    return sum(a.nbytes for a in jax.tree.leaves(payload))


# ---------------------------------------------------------------------------
# pack / unpack / scatter-add (jnp; the layout spec)
# ---------------------------------------------------------------------------

def _pad2d(xf: Array, lw: LeafWire) -> Array:
    pad = lw.nb * lw.block - lw.size
    return jnp.pad(xf, (0, pad)).reshape(lw.nb, lw.block)


def pack_oracle(lw: LeafWire, delta: Array) -> Tuple[Array, Array]:
    """jnp oracle: (values, local indices), (nb, kb) each -- the layout every
    fused producer must match bit-for-bit."""
    xp = _pad2d(delta.reshape(-1), lw)
    _, idx = jax.lax.top_k(jnp.abs(xp), lw.kb)
    vals = jnp.take_along_axis(xp, idx, axis=1)
    return vals, idx.astype(jnp.int32)


def scatter_add(lw: LeafWire, vals: Array, idx: Array) -> Array:
    """Payload -> dense flat (size,) vector.

    Accepts one message (nb, kb) or the worker-stacked all-gather result
    (n, nb, kb); the stacked form is scatter-SUMMED per block (the local
    combine of the sparse_allgather collective -- divide by n for the mean).
    """
    if vals.ndim == 3:  # (n, nb, kb) -> (nb, n*kb)
        vals = jnp.moveaxis(vals, 0, 1).reshape(vals.shape[1], -1)
        idx = jnp.moveaxis(idx, 0, 1).reshape(idx.shape[1], -1)
    rows = jnp.arange(lw.nb)[:, None]
    out = jnp.zeros((lw.nb, lw.block), vals.dtype).at[rows, idx].add(vals)
    return out.reshape(-1)[:lw.size]


def unpack(lw: LeafWire, vals: Array, idx: Array) -> Array:
    """One message -> dense tensor of the leaf's original shape."""
    return scatter_add(lw, vals, idx).reshape(lw.shape)


# ---------------------------------------------------------------------------
# fused compress-and-pack (the worker hot path)
# ---------------------------------------------------------------------------

def fused_pack(lw: LeafWire, g: Array, h: Array, lam: float, *,
               kernel: Optional[str] = None
               ) -> Tuple[Tuple[Array, Array], Array]:
    """d = block_topk(g - h) packed as (values, indices); h' = h + lam d.

    Dispatches to the Pallas kernel (one HBM pass, dense d never leaves
    VMEM) or the jnp oracle; all backends produce bit-identical results.
    """
    mode = _kernel_mode(kernel)
    if mode in ("pallas", "interpret") and lw.block % 128 != 0:
        # the Pallas kernel tiles 128-lane slabs; other block sizes take the
        # bit-identical oracle.  Only an *explicit* per-call request errors.
        if kernel in ("pallas", "interpret"):
            raise ValueError(
                f"Pallas pack kernel requires block % 128 == 0, got {lw.block}")
        mode = "oracle"
    if mode in ("pallas", "interpret"):
        from repro.kernels import ops
        return ops.efbv_pack_update(g, h, float(lam), block=lw.block,
                                    kb=lw.kb, interpret=(mode == "interpret"))
    # jnp oracle: same arithmetic, same order of operations as the kernel
    delta = g.astype(jnp.float32) - h.astype(jnp.float32)
    vals, idx = pack_oracle(lw, delta)
    d = scatter_add(lw, vals, idx).reshape(lw.shape)
    h_new = (h.astype(jnp.float32) + float(lam) * d).astype(h.dtype)
    return (vals.astype(g.dtype), idx), h_new
