"""Mixture-of-Experts layer: top-k softmax router + capacity-bounded
scatter/gather dispatch (no O(T*E*C) one-hot tensors) + load-balance aux loss.

Expert weights are stacked on a leading E axis and expert-parallel over the
'model' mesh axis when E divides it (dbrx: 16 experts over 16-way model axis
-> one expert per shard); otherwise the per-expert FFN dim is sharded
(granite: 40 experts, d_ff=512 -> ff sharded).
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import MODEL_AXIS_SIZE, _init, auto_spec

Array = jax.Array


def moe_init(key, d: int, ff: int, n_experts: int) -> Tuple[Dict, Dict]:
    ks = jax.random.split(key, 4)
    params = {
        "router": _init(ks[0], (d, n_experts), scale=0.02),
        "wg": _init(ks[1], (n_experts, d, ff)),
        "wu": _init(ks[2], (n_experts, d, ff)),
        "wd": _init(ks[3], (n_experts, ff, d), scale=1.0 / math.sqrt(ff)),
    }
    # Expert-parallel over 'model' ONLY when E divides it (dbrx: 16/16).
    # When it doesn't (granite: 40), REPLICATE the (small) expert weights
    # rather than sharding the per-expert ff dim: ff-sharded experts force a
    # model-axis gather of the (E, C, d) token buffer every layer -- measured
    # 4.1 TB/device on granite prefill_32k (§Perf granite I4).  Replicated
    # weights cost 3*E*d*ff bytes once and make MoE compute group-local.
    specs = {
        "router": P(None, None),
        "wg": auto_spec((n_experts, d, ff), prefer=(0,)),
        "wu": auto_spec((n_experts, d, ff), prefer=(0,)),
        "wd": auto_spec((n_experts, ff, d), prefer=(0,)),
    }
    return params, specs


EXPERT_LEAVES = ("wg", "wu", "wd")


def _is_moe_subtree(node) -> bool:
    return (isinstance(node, dict)
            and "router" in node
            and all(k in node for k in EXPERT_LEAVES))


def expert_activity_mask(moe_grads: Dict) -> Array:
    """Which experts this round's gradients actually touched.

    Capacity-bounded dispatch scatters a ZERO buffer row to every expert no
    token routed to (see :func:`_dispatch_group`), so an unrouted expert's
    wg/wu/wd gradient slab is exactly zero -- its activity is readable off
    the gradients with no routing side-channel.  Returns a boolean mask of
    shape ``(..., E)`` (leading dims = any stacked-layer axes of the expert
    leaves, e.g. ``(L, E)`` for a stacked transformer): True where ANY of
    the three expert slabs carries a nonzero entry.  Router gradients are
    dense (every token differentiates through the softmax) and do not enter
    the mask."""
    masks = []
    for name in EXPERT_LEAVES:
        g = moe_grads[name]
        # (..., E, a, b) -> (..., E): any nonzero in the per-expert slab
        masks.append(jnp.any(g != 0, axis=(-2, -1)))
    return jnp.logical_or(jnp.logical_or(masks[0], masks[1]), masks[2])


def zero_inactive_expert_grads(grads, mask=None):
    """Zero the wg/wu/wd gradient slabs of inactive experts, worker-side.

    This is the enforcement half of the expert-sparsity contract the
    compressed wire relies on (docs/finetuning.md#expert-sparsity): leaves
    under any MoE subtree keep only the slabs of experts in ``mask``
    (default: :func:`expert_activity_mask` derived from the gradients
    themselves, under which this is mathematically the identity -- the
    dispatch already produced exact zeros).  Composed with a top-k leaf
    codec on the expert leaves, the masked gradient's payload carries only
    routed-expert entries.  Non-MoE subtrees pass through untouched."""
    def walk(node):
        if _is_moe_subtree(node):
            m = expert_activity_mask(node) if mask is None else mask
            out = dict(node)
            for name in EXPERT_LEAVES:
                g = node[name]
                out[name] = g * m[..., None, None].astype(g.dtype)
            return out
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        return node

    return walk(grads)


def fixed_routing_params(params):
    """Pin the router: zero every MoE router leaf, so all logits tie and
    ``jax.lax.top_k`` deterministically routes every token to experts
    ``(0, .., k-1)`` (ties break by lowest index).  The deterministic-routing
    regime the expert-sparsity wire tests pin oracle == shard_map under."""
    def walk(node):
        if _is_moe_subtree(node):
            out = dict(node)
            out["router"] = jnp.zeros_like(node["router"])
            return out
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        return node

    return walk(params)


def _auto_axes():
    """Names of non-'model' mesh axes currently under GSPMD (auto) control;
    empty when no mesh is ambient or inside a fully-manual shard_map."""
    from repro import compat
    return compat.auto_axes_of(compat.abstract_mesh(), exclude=("model",))


def _maybe_group_constraint(x: Array, G: int) -> Array:
    """Pin the MoE dispatch-group dim to the (auto) worker axes (§Perf
    granite iteration 3): without this, GSPMD materialized every group's
    expert buffer on every data shard and all-reduced 4.1 TB/device of
    grouped buffers on granite prefill_32k; with it each shard dispatches
    only its own groups."""
    import math as _math
    from repro import compat
    axes = _auto_axes()
    if not axes:
        return x
    mesh = compat.abstract_mesh()
    n = _math.prod(mesh.shape[a] for a in axes)
    if n <= 1 or G % n:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(axes, *([None] * (x.ndim - 1))))


def _maybe_ep_constraint(x: Array, n_experts: int) -> Array:
    """Pin the (E, C, d) expert buffer to expert-parallel sharding when E
    divides the model axis and a mesh is ambient (§Perf dbrx iteration: the
    unconstrained buffer replicates over 'model' and the expert-FFN outputs
    come back via ~1 TB/device of all-reduces; constraining E makes GSPMD
    move tokens with all-to-alls instead -- k*T*d words, ~16x less)."""
    from repro import compat
    mesh = compat.abstract_mesh()
    if mesh is None or mesh.empty or "model" not in mesh.axis_names:
        return x
    if n_experts % mesh.shape["model"] != 0:
        return x
    spec = P(*(["model"] + [None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def _dispatch_group(p, xt: Array, *, n_experts: int, k: int,
                    capacity: int) -> Tuple[Array, Array]:
    """Capacity-bounded dispatch+combine for one token group.
    xt: (Tg, d) -> (out (Tg, d), aux)."""
    Tg, d = xt.shape
    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)  # (Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)                   # (Tg, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balance aux loss (Switch-style): E * sum_e frac_tokens_e * mean_prob_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], n_experts), axis=0)
    aux = n_experts * jnp.sum(me * ce)

    flat_ids = expert_ids.reshape(-1)                                 # (Tg*k,)
    onehot = jax.nn.one_hot(flat_ids, n_experts, dtype=jnp.int32)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - onehot)[
        jnp.arange(Tg * k), flat_ids]
    in_cap = pos_in_expert < capacity
    slot = jnp.where(in_cap, flat_ids * capacity + pos_in_expert,
                     n_experts * capacity)                            # trash slot

    buf = jnp.zeros((n_experts * capacity + 1, d), xt.dtype)
    xk = jnp.repeat(xt, k, axis=0)
    buf = buf.at[slot].add(xk)
    eb = _maybe_ep_constraint(buf[:-1].reshape(n_experts, capacity, d), n_experts)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb, p["wg"].astype(xt.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", eb, p["wu"].astype(xt.dtype))
    out_e = jnp.einsum("ecf,efd->ecd", h, p["wd"].astype(xt.dtype))

    flat_out = jnp.concatenate(
        [out_e.reshape(n_experts * capacity, d), jnp.zeros((1, d), xt.dtype)], 0)
    ok = flat_out[slot]
    weighted = ok * (gate_vals.reshape(-1, 1).astype(xt.dtype) *
                     in_cap.reshape(-1, 1).astype(xt.dtype))
    return jnp.sum(weighted.reshape(Tg, k, d), axis=1), aux


def moe_apply(p, x: Array, *, n_experts: int, k: int,
              capacity_factor: float = 1.25,
              groups: int = 0) -> Tuple[Array, Array]:
    """x: (B, S, d) -> (out (B, S, d), aux load-balance loss scalar).

    Dispatch is *grouped* (§Perf iteration 2): tokens are split into
    ``groups`` independent dispatch groups (default: one per batch row) that
    each build their own (E, C_g, d) expert buffer.  The group dim inherits
    the batch's data-axis sharding, so dispatch is shard-local -- the
    ungrouped formulation scattered into one global (E*C, d) buffer which
    GSPMD all-reduced across data shards (measured 2 x 4.1 TB/device on
    granite prefill_32k).  Per-group capacity also matches how real MoE
    systems bound device-local buffers.
    """
    B, S, d = x.shape
    T = B * S
    G = groups or B
    while T % G:
        G -= 1
    Tg = T // G
    capacity = max(1, int(capacity_factor * k * Tg / n_experts))
    xg = _maybe_group_constraint(x.reshape(G, Tg, d), G)
    out, aux = jax.vmap(
        lambda xt: _dispatch_group(p, xt, n_experts=n_experts, k=k,
                                   capacity=capacity))(xg)
    out = _maybe_group_constraint(out, G)
    return out.reshape(B, S, d), jnp.mean(aux)
