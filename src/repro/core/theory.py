"""Paper theory: optimal scalings, contraction factors, stepsizes, rates.

Implements Sect. 2.5 (Props 1-2), Sect. 4 (Thms 1-2, Remarks 1-3) and Sect. 5
(Thm 3) so that EF-BV can run fully auto-tuned: given (eta, omega, omega_av)
of the compressors and (L, Ltilde) of the objective there is *no* free
parameter left (Remark 1).

The function-by-function map to the paper, with runnable examples, lives in
docs/theory.md; :func:`participation_eta` / :func:`participation_omega` /
:func:`tune_partial` extend the auto-tuning to the federated (per-round
client sampling) regime by composing Bernoulli participation into the
compressor's certified constants.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal, Optional

Mode = Literal["efbv", "ef21", "diana"]
Regime = Literal["pl", "kl", "nonconvex"]


# --- Prop. 1: effect of scaling ------------------------------------------------

def scaled_eta(lam: float, eta: float) -> float:
    return lam * eta + 1.0 - lam


def scaled_omega(lam: float, omega: float) -> float:
    return lam * lam * omega


def r_of(lam: float, eta: float, omega: float) -> float:
    """r = (1 - lam + lam*eta)^2 + lam^2 * omega  (Sect. 4)."""
    return scaled_eta(lam, eta) ** 2 + scaled_omega(lam, omega)


# --- Prop. 2: optimal scaling --------------------------------------------------

def lambda_star(eta: float, omega: float) -> float:
    """argmin_lam r(lam) clipped to (0, 1]:  min((1-eta)/((1-eta)^2+omega), 1)."""
    if eta >= 1.0:
        raise ValueError(f"eta must be < 1, got {eta}")
    return min((1.0 - eta) / ((1.0 - eta) ** 2 + omega), 1.0)


def nu_star(eta: float, omega_av: float) -> float:
    """Same formula with omega replaced by omega_av (Sect. 2.5 / Sect. 4)."""
    return lambda_star(eta, omega_av)


# --- partial participation: Bernoulli client sampling as a compressor ----------

def participation_eta(p: float, eta: float) -> float:
    """Relative bias of the effective operator C'(x) = b C(x), b ~ Bern(p).

    ||E C'(x) - x|| = ||p E C(x) - x|| <= (1 - p(1 - eta)) ||x||: skipping a
    round acts like Prop. 1's downscaling with lam = p on the bias side.
    """
    if not 0.0 < p <= 1.0:
        raise ValueError(f"participation probability in (0, 1] required, got {p}")
    if p == 1.0:  # exact no-op (1 - (1 - eta) would round)
        return eta
    return 1.0 - p * (1.0 - eta)


def participation_omega(p: float, eta: float, omega: float) -> float:
    """Relative variance of C'(x) = b C(x), b ~ Bern(p):

        E||C' - E C'||^2 = p Var[C] + p(1-p) ||E C(x)||^2
                        <= (p omega + p(1-p)(1+eta)^2) ||x||^2 .
    """
    if not 0.0 < p <= 1.0:
        raise ValueError(f"participation probability in (0, 1] required, got {p}")
    if p == 1.0:  # exact no-op
        return omega
    return p * omega + p * (1.0 - p) * (1.0 + eta) ** 2


# --- pipelined rounds: one-round staleness as a compressor perturbation ---------

#: Default per-round drift of the compressed innovation, measured as a
#: fraction of the compressor's contraction SLACK (1 - eta): the pipelined
#: analysis assumes ||u_t - u_{t-1}|| <= drift * (1 - eta) * ||u_{t-1}||.
#: EF-BV's control variates contract the innovation u_t = g_t - h_t at a
#: per-round rate proportional to (1 - eta) (Thm 1's Lyapunov argument), so
#: measuring the drift against the slack keeps the composition valid for
#: EVERY compressor -- weak ones (eta near 1) move their innovations
#: proportionally slower.  Any depth * drift < 1/2 composes to eta' < 1.
DEFAULT_PIPELINE_DRIFT = 1.0 / 32.0


def _check_depth(depth: int) -> int:
    if not isinstance(depth, int) or depth < 0:
        raise ValueError(f"pipeline depth must be an int >= 0, got {depth!r}")
    return depth


def _staleness_rho(depth: int, eta: float, drift: float) -> float:
    """rho_d = depth * drift * (1 - eta), the certified relative movement of
    the innovation across ``depth`` rounds of staleness."""
    if drift < 0.0:
        raise ValueError(f"pipeline drift must be >= 0, got {drift}")
    if not 0.0 <= eta < 1.0:
        raise ValueError(f"eta in [0,1) required, got {eta}")
    rho = depth * drift * (1.0 - eta)
    if rho >= 0.5 * (1.0 - eta):  # i.e. depth * drift >= 1/2
        raise ValueError(
            f"pipelined staleness rho = {depth}*{drift}*(1-{eta}) = {rho} "
            f"leaves no contraction (needs depth * drift < 1/2): use a "
            "shallower pipeline or a smaller certified drift")
    return rho


def pipeline_eta(depth: int, eta: float,
                 drift: float = DEFAULT_PIPELINE_DRIFT) -> float:
    """Relative bias of the effective operator C'(u_t) = C(u_{t-depth}): the
    pipelined schedule applies the message compressed ``depth`` rounds ago.

    Under the bounded relative drift ||u_t - u_{t-1}|| <= rho ||u_{t-1}||
    with rho = drift * (1 - eta) (see DEFAULT_PIPELINE_DRIFT), chaining
    depth rounds gives ||u_{t-depth}|| <= ||u_t|| / (1 - rho_d) and
    ||u_t - u_{t-depth}|| <= rho_d ||u_t|| / (1 - rho_d), rho_d = depth*rho,
    hence

        ||E C(u_{t-depth}) - u_t||
            <= eta ||u_{t-depth}|| + ||u_t - u_{t-depth}||
            <= (eta + rho_d) / (1 - rho_d) * ||u_t||  =:  eta' ||u_t|| .

    eta' < 1 automatically whenever depth * drift < 1/2 -- the staleness
    composes for every compressor, exactly like :func:`participation_eta`'s
    interpolation toward 1 ("EF21 with Bells & Whistles"-style composed
    perturbation).  depth = 0 is an exact no-op."""
    if _check_depth(depth) == 0:
        return eta
    rho = _staleness_rho(depth, eta, drift)
    return (eta + rho) / (1.0 - rho)


def pipeline_omega(depth: int, eta: float, omega: float,
                   drift: float = DEFAULT_PIPELINE_DRIFT) -> float:
    """Relative variance of C'(u_t) = C(u_{t-depth}):

        E||C' - E C'||^2 <= omega ||u_{t-depth}||^2
                         <= omega / (1 - rho_d)^2 * ||u_t||^2 ,

    with rho_d = depth * drift * (1 - eta) as in :func:`pipeline_eta`
    (signature mirrors :func:`participation_omega`: the variance inflation
    depends on the bias constant through the slack).  Applies to omega_av
    identically -- the delay is common to all workers, so the 1/n variance
    reduction of independent compressors is untouched.  depth = 0 is an
    exact no-op."""
    if _check_depth(depth) == 0:
        return omega
    rho = _staleness_rho(depth, eta, drift)
    return omega / (1.0 - rho) ** 2


def tune_pipelined(
    eta: float,
    omega: float,
    depth: int,
    *,
    omega_av: Optional[float] = None,
    drift: float = DEFAULT_PIPELINE_DRIFT,
    **kw,
) -> Tuning:
    """Auto-tuning under a ``depth``-round-stale pipelined schedule.

    Composes the staleness into the compressor's certified constants
    (:func:`pipeline_eta` / :func:`pipeline_omega`) and hands the effective
    C(eta', omega') to :func:`tune` -- same machinery, delayed regime.
    depth = 0 reduces to :func:`tune` exactly."""
    eta_d = pipeline_eta(depth, eta, drift)
    omega_d = pipeline_omega(depth, eta, omega, drift)
    if omega_av is not None:
        return tune(eta_d, omega_d,
                    pipeline_omega(depth, eta, omega_av, drift), **kw)
    return tune(eta_d, omega_d, **kw)


# --- rate ingredients -----------------------------------------------------------

def s_star(r: float) -> float:
    """s* = sqrt((1+r)/(2r)) - 1, so that (1+s*)^2 r = (r+1)/2 (proof of Thm 1).

    r -> 0 (no compression error, Remark 2): s* -> inf and 1/s* -> 0, so the
    stepsize bound reverts to plain gradient descent's 1/L."""
    if r <= 0.0:
        return math.inf
    return math.sqrt((1.0 + r) / (2.0 * r)) - 1.0


def s_nonconvex(r: float) -> float:
    """s = 1/sqrt(r) - 1, so that (1+s)^2 r = 1 (Thm 3)."""
    if r <= 0.0:
        return math.inf
    return 1.0 / math.sqrt(r) - 1.0


def theta_of(s: float, r: float, r_av: float) -> float:
    """theta = s (1+s) r / r_av."""
    if r_av <= 0.0:
        return math.inf
    return s * (1.0 + s) * r / r_av


# --- stepsizes -------------------------------------------------------------------

def gamma_max(L: float, Ltilde: float, r: float, r_av: float, regime: Regime = "pl") -> float:
    """Largest stepsize allowed by Thm 1 (pl / nonconvex, eq. 8/13) or Thm 2 (kl, eq. 10)."""
    if r >= 1.0:
        raise ValueError(f"need r < 1 for convergence, got r={r}")
    if r <= 0.0:  # identity compression: plain (prox-)GD stepsizes (Remark 2)
        return 1.0 / (2.0 * L) if regime == "kl" else 1.0 / L
    if regime == "nonconvex":
        s = s_nonconvex(r)
        return 1.0 / (L + Ltilde * math.sqrt(r_av / r) / s)
    s = s_star(r)
    if regime == "kl":
        return 1.0 / (2.0 * L + Ltilde * math.sqrt(r_av / r) / s)
    return 1.0 / (L + Ltilde * math.sqrt(r_av / r) / s)


def linear_rate(gamma: float, mu: float, r: float, regime: Regime = "pl") -> float:
    """Per-iteration contraction factor of the Lyapunov function (Thms 1-2)."""
    if regime == "kl":
        return max(1.0 / (1.0 + 0.5 * gamma * mu), (r + 1.0) / 2.0)
    return max(1.0 - gamma * mu, (r + 1.0) / 2.0)


# --- one-stop tuning --------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Tuning:
    """Everything EF-BV needs, derived per Remark 1."""

    mode: Mode
    eta: float
    omega: float
    omega_av: float
    lam: float
    nu: float
    r: float
    r_av: float
    s: float
    theta: float
    gamma: Optional[float]  # None if L/Ltilde not supplied
    rate: Optional[float]  # None if mu not supplied

    @property
    def speedup_vs_ef21(self) -> float:
        """The paper's headline factor sqrt(r_av / r) (Sect. 4.1): gamma scales
        by its inverse relative to EF21's choice nu = lam."""
        return math.sqrt(self.r_av / self.r)


def tune(
    eta: float,
    omega: float,
    omega_av: Optional[float] = None,
    *,
    n: Optional[int] = None,
    mode: Mode = "efbv",
    regime: Regime = "pl",
    L: Optional[float] = None,
    Ltilde: Optional[float] = None,
    mu: Optional[float] = None,
) -> Tuning:
    """Derive (lam, nu, gamma) for EF-BV / EF21 / DIANA.

    - mode='efbv' : lam = lam*, nu = nu*          (Remark 1 -- recommended)
    - mode='ef21' : nu = lam = lam*               (Sect. 3.1; r_av := r)
    - mode='diana': nu = 1, lam = lam*            (Sect. 3.2)
    """
    if omega_av is None:
        if n is None:
            raise ValueError("need omega_av or n (independent compressors)")
        omega_av = omega / n
    if not 0.0 <= eta < 1.0:
        raise ValueError(f"eta in [0,1) required, got {eta}")

    lam = lambda_star(eta, omega)
    if mode == "efbv":
        nu = nu_star(eta, omega_av)
    elif mode == "ef21":
        nu = lam
    elif mode == "diana":
        nu = 1.0
    else:
        raise ValueError(mode)

    r = r_of(lam, eta, omega)
    if mode == "ef21":
        # EF21 analysis does not see omega_av: it treats the aggregate like a
        # single worker, i.e. r_av = r (Sect. 4.1).
        r_av = r
    else:
        r_av = r_of(nu, eta, omega_av)

    s = s_nonconvex(r) if regime == "nonconvex" else s_star(r)
    theta = theta_of(s, r, r_av)

    gamma = None
    if L is not None and Ltilde is not None:
        gamma = gamma_max(L, Ltilde, r, r_av, regime)
    rate = None
    if gamma is not None and mu is not None and regime != "nonconvex":
        rate = linear_rate(gamma, mu, r, regime)

    return Tuning(
        mode=mode, eta=eta, omega=omega, omega_av=omega_av,
        lam=lam, nu=nu, r=r, r_av=r_av, s=s, theta=theta,
        gamma=gamma, rate=rate,
    )


def tune_partial(
    eta: float,
    omega: float,
    p: float,
    *,
    n: int,
    **kw,
) -> Tuning:
    """Auto-tuning under per-round Bernoulli(p) client sampling.

    Composes participation into the compressor's certified per-worker
    constants (participation_eta / participation_omega) and hands the
    effective C(eta', omega') to :func:`tune` -- same machinery, sampled
    regime.  Participation masks are independent across workers, so the
    averaged variance keeps the 1/n reduction: omega_av' = omega'/n
    (fixed-size sampling of s = p*n workers is handled with the same
    plug-in p; its without-replacement masks are negatively correlated,
    so this errs on the conservative side).  p = 1 reduces to :func:`tune`
    with omega_av = omega/n exactly.
    """
    eta_p = participation_eta(p, eta)
    omega_p = participation_omega(p, eta, omega)
    return tune(eta_p, omega_p, n=n, **kw)


def tune_for(compressor, d: int, n: int, *, independent: bool = True,
             participation: Optional[float] = None,
             pipeline: Optional[int] = None,
             pipeline_drift: float = DEFAULT_PIPELINE_DRIFT, **kw) -> Tuning:
    """Convenience: read (eta, omega) off a Compressor instance.

    ``participation`` (expected per-round participation fraction p) routes
    through :func:`tune_partial` for the federated regime.  ``pipeline``
    (staleness depth of the pipelined schedule) composes
    :func:`pipeline_eta` / :func:`pipeline_omega` AFTER participation --
    the delay applies to whatever effective operator the round runs;
    None / 0 is an exact no-op.  A *sequence* of compressors is a
    heterogeneous fleet (worker i runs compressor i) and routes through
    :func:`tune_fleet` with the certified worst-case aggregation.
    """
    depth = _check_depth(0 if pipeline is None else pipeline)
    if isinstance(compressor, (list, tuple)):
        if not independent:
            raise ValueError("mixed-fleet tuning assumes independent "
                             "per-worker compressors")
        etas = [c.eta(d) for c in compressor]
        omegas = [c.omega(d) for c in compressor]
        return tune_fleet(etas, omegas, n=n, participation=participation,
                          pipeline=depth, pipeline_drift=pipeline_drift, **kw)
    eta = compressor.eta(d)
    omega = compressor.omega(d)
    if participation is not None and participation < 1.0:
        if not independent:
            raise ValueError("partial participation tuning assumes "
                             "independent per-worker compressors")
        if depth == 0:
            return tune_partial(eta, omega, participation, n=n, **kw)
        p = participation
        eta_p = participation_eta(p, eta)
        omega_p = participation_omega(p, eta, omega)
        # participation masks are independent per worker, so omega_av' =
        # omega'/n (tune_partial's convention); the common one-round delay
        # then scales bias and both variances alike.
        return tune_pipelined(eta_p, omega_p, depth, omega_av=omega_p / n,
                              drift=pipeline_drift, **kw)
    omega_av = compressor.omega_av(d, n) if independent else omega
    if depth:
        return tune_pipelined(eta, omega, depth, omega_av=omega_av,
                              drift=pipeline_drift, **kw)
    return tune(eta, omega, omega_av, **kw)


# --- heterogeneous fleets: per-worker (eta_i, omega_i) aggregation --------------

FleetAggregate = Literal["worst", "mean"]


def fleet_constants(etas, omegas, *, n: Optional[int] = None,
                    aggregate: FleetAggregate = "worst"):
    """Aggregate per-worker certified constants (eta_i, omega_i) of a mixed
    fleet of INDEPENDENT compressors into one (eta, omega, omega_av) triple
    the homogeneous theory can consume.

    * ``worst`` (certified): eta = max_i eta_i and omega = max_i omega_i
      bound every worker's recursion, so Thms. 1-3 hold verbatim with the
      aggregated constants.
    * ``mean`` (averaged): eta = mean(eta_i), omega = mean(omega_i) -- exact
      for homogeneous fleets and for the *averaged* quantities when all
      workers see innovations of equal norm; a tighter but uncertified
      stepsize in general.

    Either way the averaged variance keeps the independent-compressor 1/n
    reduction exactly:  Var[(1/n) sum_i C_i(u_i)] <= (1/n^2) sum_i omega_i
    ||u_i||^2, i.e. omega_av = mean(omega_i)/n against the mean of ||u_i||^2
    (worst-case: max(omega_i)/n).  n = None returns (eta, omega) only.
    """
    etas, omegas = list(etas), list(omegas)
    if not etas or len(etas) != len(omegas):
        raise ValueError(f"need matching non-empty eta/omega lists, got "
                         f"{len(etas)}/{len(omegas)}")
    if aggregate == "worst":
        eta, omega = max(etas), max(omegas)
    elif aggregate == "mean":
        eta, omega = sum(etas) / len(etas), sum(omegas) / len(omegas)
    else:
        raise ValueError(f"fleet aggregate {aggregate!r} (want worst | mean)")
    if n is None:
        return eta, omega
    return eta, omega, omega / max(n, 1)


def tune_fleet(etas, omegas, *, n: int,
               aggregate: FleetAggregate = "worst",
               participation: Optional[float] = None,
               pipeline: Optional[int] = None,
               pipeline_drift: float = DEFAULT_PIPELINE_DRIFT,
               **kw) -> Tuning:
    """Auto-tuning for a heterogeneous worker fleet (worker i's compressor
    certified as C(eta_i, omega_i); all independent).

    Composes per-round Bernoulli(p) participation into EACH member first
    (participation_eta / participation_omega -- skipping a round is a
    per-worker event), then aggregates (:func:`fleet_constants`), composes
    the pipelined staleness last (the delay is common to the whole fleet)
    and hands the result to :func:`tune`.  A homogeneous list reproduces
    :func:`tune_for` / :func:`tune_partial` exactly; pipeline=None/0 is an
    exact no-op.
    """
    if participation is not None and participation < 1.0:
        p = participation
        etas, omegas = zip(*[(participation_eta(p, e),
                              participation_omega(p, e, o))
                             for e, o in zip(etas, omegas)])
    eta, omega, omega_av = fleet_constants(etas, omegas, n=n,
                                           aggregate=aggregate)
    depth = _check_depth(0 if pipeline is None else pipeline)
    if depth:
        return tune_pipelined(eta, omega, depth, omega_av=omega_av,
                              drift=pipeline_drift, **kw)
    return tune(eta, omega, omega_av, **kw)


# --- pytree leaves: per-leaf (eta_j, omega_j) composition ----------------------

def tree_constants(etas, omegas, sizes=None, *, n: Optional[int] = None,
                   aggregate: FleetAggregate = "worst"):
    """Aggregate per-LEAF certified constants (eta_j, omega_j) of a
    pytree-native wire (leaf j compressed by its own independent C_j) into
    one (eta, omega[, omega_av]) triple the homogeneous theory can consume.

    The leaf-wise operator C(x) = (C_1(x_1), ..., C_J(x_J)) acts on DISJOINT
    coordinate blocks of ONE worker's innovation, so the error and variance
    split exactly over leaves:  ||C(x) - x||^2 = sum_j ||C_j(x_j) - x_j||^2
    and Var[C(x)] = sum_j Var[C_j(x_j)].

    * ``worst`` (certified): eta = max_j eta_j, omega = max_j omega_j bound
      the sums above for EVERY split of ||x||^2 over leaves, so Thms. 1-3
      hold verbatim with the aggregated constants.
    * ``mean`` (averaged): exact under the isotropy heuristic ||x_j||^2 =
      w_j ||x||^2 with size weights w_j = size_j / sum(sizes):
      eta = sqrt(sum_j w_j eta_j^2), omega = sum_j w_j omega_j -- tighter
      but uncertified in general (``sizes=None`` weighs leaves equally).

    Unlike a fleet, leaf composition adds NO worker-averaging of its own:
    the 1/n reduction still comes from averaging across the n independent
    workers, omega_av = omega / max(n, 1).  A single leaf is an exact no-op
    under either aggregate.  n = None returns (eta, omega) only.
    """
    etas, omegas = list(etas), list(omegas)
    if not etas or len(etas) != len(omegas):
        raise ValueError(f"need matching non-empty eta/omega lists, got "
                         f"{len(etas)}/{len(omegas)}")
    if sizes is None:
        w = [1.0 / len(etas)] * len(etas)
    else:
        sizes = [float(s) for s in sizes]
        if len(sizes) != len(etas):
            raise ValueError(f"{len(sizes)} leaf sizes for {len(etas)} "
                             "eta/omega pairs")
        total = sum(sizes)
        if total <= 0:
            raise ValueError("leaf sizes must have a positive sum")
        w = [s / total for s in sizes]
    if aggregate == "worst":
        eta, omega = max(etas), max(omegas)
    elif aggregate == "mean":
        eta = math.sqrt(sum(wj * e * e for wj, e in zip(w, etas)))
        omega = sum(wj * o for wj, o in zip(w, omegas))
    else:
        raise ValueError(f"tree aggregate {aggregate!r} (want worst | mean)")
    if n is None:
        return eta, omega
    return eta, omega, omega / max(n, 1)


def tune_tree(etas, omegas, sizes=None, *, n: int,
              aggregate: FleetAggregate = "worst",
              participation: Optional[float] = None,
              pipeline: Optional[int] = None,
              pipeline_drift: float = DEFAULT_PIPELINE_DRIFT,
              **kw) -> Tuning:
    """Auto-tuning for a pytree-native wire with per-leaf compressors.

    Composition order: leaves FIRST (:func:`tree_constants` -- the leaf
    operators compose within one worker's single round message), then
    per-round Bernoulli(p) participation (a per-WORKER event: the whole
    leaf-composed message is present or absent at once), then the pipelined
    staleness, then :func:`tune`.  A single leaf with full participation and
    no pipeline reproduces :func:`tune` on that leaf's constants exactly.
    """
    eta, omega = tree_constants(etas, omegas, sizes, aggregate=aggregate)
    if participation is not None and participation < 1.0:
        eta, omega = (participation_eta(participation, eta),
                      participation_omega(participation, eta, omega))
    omega_av = omega / max(n, 1)
    depth = _check_depth(0 if pipeline is None else pipeline)
    if depth:
        return tune_pipelined(eta, omega, depth, omega_av=omega_av,
                              drift=pipeline_drift, **kw)
    return tune(eta, omega, omega_av, **kw)


def iteration_complexity(L: float, Ltilde: float, mu: float, t: Tuning) -> float:
    """Asymptotic O(.) iteration count to eps-accuracy, eq. (12) (without log)."""
    return L / mu + (Ltilde / mu * math.sqrt(t.r_av / t.r) + 1.0) / (1.0 - t.r)
