"""Static + compiled-artifact analysis for the EF-BV reproduction.

Layers (see docs/static_analysis.md for the rule catalog):

  * :mod:`repro.analysis.framework` -- rule registry, ``# repro: noqa``
    suppressions, golden-count pinning, the runner;
  * :mod:`repro.analysis.rules`     -- the six repo-invariant AST rules;
  * :mod:`repro.analysis.hlo`       -- HLO cost model + roofline (absorbed
    from repro.launch) and the ``dense_free`` pack-kernel proofs;
  * :mod:`repro.analysis.docs`      -- markdown link check + doctest census;
  * :mod:`repro.analysis.sanitize`  -- the ``--sanitize`` runtime mode.

Entry point: ``python -m repro.analysis`` (or the ``repro-analysis``
console script).
"""

from repro.analysis.framework import (  # noqa: F401
    AnalysisResult,
    Finding,
    Module,
    Rule,
    RULES,
    analyze_paths,
    compare_golden,
    rule,
    write_golden,
)
from repro.analysis import rules as _rules  # noqa: F401  (registers rules)


def main(argv=None) -> int:
    """Console-script entry (``repro-analysis`` in pyproject.toml)."""
    from repro.analysis.__main__ import main as _main

    return _main(argv)
