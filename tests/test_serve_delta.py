"""The replica-fleet differential suite for compressed-delta serving.

The claim under test (launch/serve.py + Downlink.encode_push/apply_push):
N serving replicas that apply the trainer's versioned compressed pushes
reconstruct the trainer's downlink control variate w BIT-FOR-BIT -- for
every zoo codec, every wire dtype, the per-leaf TreeWire path, across
multi-push trajectories, through dropped pushes (version gap -> checkpoint
resync), and without ever serving a token from a half-applied model
(hot-swap atomicity).  Plus: continuous-batching decode == fixed-batch
decode token-for-token, and the exact envelope bits accounting.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import ExperimentSpec, make_compressor
from repro.core.efbv import Downlink
from repro.distributed import wire
from repro.distributed.wire import (DeltaEnvelope, PUSH_HEADER_BITS,
                                    checkpoint_push_bits, push_bits)
from repro.launch.serve import (DecodeEngine, DeltaPusher, ServeReplica,
                                push_key, run_fleet)

from test_wire_codecs import ZOO

D = 96
N_REPLICAS = 3
N_PUSHES = 5


def _trajectory(key, t):
    """The trainer's model at push t: deterministic, non-trivial deltas."""
    return jax.random.normal(jax.random.fold_in(key, t), (D,))


def _tree_trajectory(key, t):
    k = jax.random.fold_in(key, t)
    return {
        "embed": jax.random.normal(jax.random.fold_in(k, 0), (8, 16)),
        "layers": {"w": jax.random.normal(jax.random.fold_in(k, 1), (4, 4)),
                   "norm": jax.random.normal(jax.random.fold_in(k, 2), (4,))},
    }


def _push_trajectory(downlink, make_x, *, wire_dtype="float32", rules=None,
                     pushes=N_PUSHES, replicas=N_REPLICAS, seed=0):
    """Run a multi-push trajectory; assert every replica bit-identical to
    the trainer after every push.  Returns the final (pusher, replicas)."""
    key = jax.random.key(seed)
    x0 = make_x(key, 0)
    pusher = DeltaPusher(downlink, x0, key=key, wire_dtype=wire_dtype,
                         rules=rules)
    reps = [ServeReplica(downlink, pusher.w, wire_dtype=wire_dtype,
                         rules=rules) for _ in range(replicas)]
    for t in range(1, pushes + 1):
        env = pusher.push(make_x(key, t))
        for rep in reps:
            assert rep.push(env) == "applied"
        want = jax.tree.leaves(pusher.w)
        for r, rep in enumerate(reps):
            assert rep.version == pusher.version == t
            for a, b in zip(jax.tree.leaves(rep.params), want):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b),
                    err_msg=f"replica {r} diverged at push {t}")
    return pusher, reps


# -----------------------------------------------------------------------------
# bit-identity across the whole zoo
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("name,comp", ZOO, ids=[n for n, _ in ZOO])
def test_replicas_bit_identical_every_zoo_codec(name, comp):
    """N replicas == trainer w bitwise over a multi-push trajectory, for
    every registered downlink codec."""
    _push_trajectory(Downlink(compressor=comp, lam=1.0), _trajectory,
                     seed=hash(name) % (2 ** 31))


_SCALED = [z for z in ZOO if z[0] in ("topk", "qsgd", "sign")]


@pytest.mark.parametrize("name,comp", _SCALED, ids=[n for n, _ in _SCALED])
def test_replicas_bit_identical_scaled_downlink(name, comp):
    """The downlink scaling lam != 1 goes through the same replica
    arithmetic (w + lam * q on both sides)."""
    _push_trajectory(Downlink(compressor=comp, lam=0.5), _trajectory)


@pytest.mark.parametrize("spec", ["topk:7", "qsgd:16", "block_topk:16,4",
                                  "natural"])
def test_replicas_bit_identical_bf16_wire(spec):
    """bf16 wire values: encode/decode is still deterministic, so replicas
    still pin bitwise (the reconstruction just quantizes differently)."""
    _push_trajectory(Downlink(compressor=make_compressor(spec)),
                     _trajectory, wire_dtype="bfloat16")


@pytest.mark.parametrize("wire_dtype", ["float32", "bfloat16"])
def test_replicas_bit_identical_tree_rules(wire_dtype):
    """The pytree/TreeWire per-leaf path: per-leaf codec rules route each
    leaf through its own codec; replicas apply the same rules and pin."""
    rules = wire.parse_leaf_rules("*embed*=qsgd:16;*norm*=identity")
    _push_trajectory(Downlink(compressor=make_compressor("block_topk:16,4")),
                     _tree_trajectory, rules=rules, wire_dtype=wire_dtype)


def test_push_payloads_equal_training_broadcast():
    """A serving push puts the SAME bits on the wire as the in-training
    broadcast of that round (same codecs, same fold keys): the protocol
    reuses the downlink, it does not reimplement it."""
    dl = Downlink(compressor=make_compressor("qsgd:16"))
    key = jax.random.key(3)
    x, w = _trajectory(key, 1), _trajectory(key, 0)
    k1 = push_key(key, 1)
    w_push, payloads = dl.encode_push(k1, x, w)
    w_bcast, bcast_payloads = dl.broadcast(k1, x, w)
    for a, b in zip(jax.tree.leaves(payloads),
                    jax.tree.leaves(bcast_payloads)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(w_push), jax.tree.leaves(w_bcast)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -----------------------------------------------------------------------------
# versioning: stale, gap, resync
# -----------------------------------------------------------------------------

def test_stale_and_out_of_order_pushes_rejected():
    dl = Downlink(compressor=make_compressor("topk:7"))
    key = jax.random.key(0)
    pusher = DeltaPusher(dl, _trajectory(key, 0), key=key)
    rep = ServeReplica(dl, pusher.w)
    env1 = pusher.push(_trajectory(key, 1))
    env2 = pusher.push(_trajectory(key, 2))
    assert rep.push(env1) == "applied"
    assert rep.push(env2) == "applied"
    before = [np.asarray(l).copy() for l in jax.tree.leaves(rep.params)]
    # re-delivery of the current version and an older version: both stale,
    # both leave the replica byte-identical (idempotent delivery)
    assert rep.push(env2) == "stale"
    assert rep.push(env1) == "stale"
    assert rep.version == 2
    for a, b in zip(jax.tree.leaves(rep.params), before):
        np.testing.assert_array_equal(np.asarray(a), b)


def test_dropped_push_gap_resyncs_bitwise_from_checkpoint(tmp_path):
    """Drop push v2: v3's base_version no longer chains -> the replica
    detects the gap, restores the newest checkpoint (the pusher saves its w
    per version), and is bit-identical to the trainer again."""
    dl = Downlink(compressor=make_compressor("qsgd:16"))
    key = jax.random.key(1)
    spec = ExperimentSpec(downlink="qsgd:16", d=D, n=2)
    pusher = DeltaPusher(dl, _trajectory(key, 0), key=key,
                         ckpt_dir=str(tmp_path), spec=spec)
    rep = ServeReplica(dl, pusher.w, ckpt_dir=str(tmp_path), spec=spec)
    env1 = pusher.push(_trajectory(key, 1))
    assert rep.push(env1) == "applied"
    pusher.push(_trajectory(key, 2))           # dropped on the floor
    env3 = pusher.push(_trajectory(key, 3))
    assert env3.base_version == 2 and rep.version == 1
    assert rep.push(env3) == "resync"
    assert rep.resyncs == 1
    assert rep.version == pusher.version == 3
    for a, b in zip(jax.tree.leaves(rep.params), jax.tree.leaves(pusher.w)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gap_without_checkpoint_dir_is_loud():
    dl = Downlink(compressor=make_compressor("topk:7"))
    key = jax.random.key(2)
    pusher = DeltaPusher(dl, _trajectory(key, 0), key=key)
    rep = ServeReplica(dl, pusher.w)
    pusher.push(_trajectory(key, 1))           # dropped
    env2 = pusher.push(_trajectory(key, 2))
    with pytest.raises(RuntimeError, match="resync"):
        rep.push(env2)


def test_snapshot_pushes_repair_gaps_without_resync():
    """A lossless (identity/f32) push is a snapshot: it assigns absolutely,
    so a replica that missed pushes re-pins from the envelope alone."""
    dl = Downlink(compressor=make_compressor("identity"))
    key = jax.random.key(3)
    pusher = DeltaPusher(dl, _trajectory(key, 0), key=key)
    rep = ServeReplica(dl, pusher.w)
    pusher.push(_trajectory(key, 1))           # dropped
    env2 = pusher.push(_trajectory(key, 2))
    assert env2.kind == "snapshot"
    assert rep.push(env2) == "applied"
    for a, b in zip(jax.tree.leaves(rep.params), jax.tree.leaves(pusher.w)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_envelope_versions_strictly_monotonic():
    with pytest.raises(ValueError, match="monotonic"):
        DeltaEnvelope(version=1, base_version=1, payloads=[])
    with pytest.raises(ValueError, match="kind"):
        DeltaEnvelope(version=2, base_version=1, payloads=[], kind="patch")


# -----------------------------------------------------------------------------
# lossless push == checkpoint load; exact bits accounting
# -----------------------------------------------------------------------------

def test_lossless_identity_push_equals_checkpoint_load(tmp_path):
    """An identity-downlink push ships the model itself: the replica ends
    bit-identical both to the trainer's x and to a save/restore round-trip
    of it -- a delta push IS a checkpoint when the wire is lossless."""
    from repro.checkpoint import restore_checkpoint, save_checkpoint

    dl = Downlink(compressor=make_compressor("identity"))
    key = jax.random.key(4)
    x1 = _tree_trajectory(key, 1)
    pusher = DeltaPusher(dl, _tree_trajectory(key, 0), key=key)
    rep = ServeReplica(dl, pusher.w)
    assert rep.push(pusher.push(x1)) == "applied"

    save_checkpoint(str(tmp_path), 1, x1)
    loaded = restore_checkpoint(str(tmp_path), 1, x1)
    for a, b, c in zip(jax.tree.leaves(rep.params), jax.tree.leaves(loaded),
                       jax.tree.leaves(x1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


@pytest.mark.parametrize("name,comp", ZOO, ids=[n for n, _ in ZOO])
def test_push_bits_accounting_exact(name, comp):
    """Measured envelope payload bytes == push_bits minus the version
    header, for every codec -- the BENCH_bits serve_delta numbers are
    measurements, not estimates."""
    dl = Downlink(compressor=comp)
    key = jax.random.key(5)
    pusher = DeltaPusher(dl, _trajectory(key, 0), key=key)
    env = pusher.push(_trajectory(key, 1))
    fmt = dl.serve_format(pusher.w)
    measured = 8 * sum(wire.payload_bytes(p)
                       for p in jax.tree.leaves(env.payloads))
    assert measured == push_bits(fmt) - PUSH_HEADER_BITS, name
    assert checkpoint_push_bits(fmt) == PUSH_HEADER_BITS + fmt.dense_bits()


def test_qsgd16_delta_push_beats_checkpoint_shipping():
    """The acceptance ratio the BENCH gate pins: a qsgd:16 delta push costs
    <= 0.35x shipping the full model."""
    dl = Downlink(compressor=make_compressor("qsgd:16"))
    fmt = dl.serve_format(jnp.zeros((1 << 12,)))
    assert push_bits(fmt) <= 0.35 * checkpoint_push_bits(fmt)


# -----------------------------------------------------------------------------
# the decode engine: continuous batching + hot-swap atomicity
# -----------------------------------------------------------------------------

def _smoke_model():
    from repro.configs import get_smoke_config
    from repro.models import build_model

    cfg = get_smoke_config("mamba2-130m")
    return cfg, build_model(cfg)


def test_continuous_batching_equals_fixed_batch_token_for_token():
    """3 requests through 2 slots (staggered admission/retirement) decode
    exactly the ids the plain fixed-batch lockstep loop decodes."""
    cfg, model = _smoke_model()
    kp, kd = jax.random.split(jax.random.key(0))
    params = model.init(kp)
    B, P, G, ML = 3, 4, 6, 16
    prompts = np.asarray(jax.random.randint(kd, (B, P), 0, cfg.vocab))

    cache = model.init_cache(B, ML)

    @jax.jit
    def step(params, cache, token, pos):
        logits, cache = model.decode_step(params, cache, token, pos)
        return (jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None],
                cache)

    tok = jnp.zeros((B, 1), jnp.int32)
    outs = []
    for t in range(P):
        tok, cache = step(params, cache, jnp.asarray(prompts[:, t:t + 1]),
                          jnp.int32(t))
    for t in range(P, P + G):
        tok, cache = step(params, cache, tok, jnp.int32(t))
        outs.append(np.asarray(tok[:, 0]))
    fixed = np.stack(outs, 1)

    eng = DecodeEngine(model, slots=2, max_len=ML)
    reqs = [eng.submit(prompts[i], G) for i in range(B)]
    eng.run(params)
    assert all(r.done for r in reqs)
    cont = np.stack([r.out for r in sorted(reqs, key=lambda r: r.rid)], 0)
    np.testing.assert_array_equal(fixed, cont)


def test_hot_swap_atomicity_mid_decode():
    """A push staged mid-decode: tokens before the commit come from the old
    version, tokens after from the new -- each from exactly one model, with
    the exact two-phase reference trajectory reproduced token-for-token."""
    cfg, model = _smoke_model()
    kp, kd = jax.random.split(jax.random.key(7))
    params0 = model.init(kp)
    P, G, ML, SWAP = 2, 6, 16, 5  # commit before engine step index 5
    prompt = np.asarray(jax.random.randint(kd, (P,), 0, cfg.vocab))

    dl = Downlink(compressor=make_compressor("qsgd:16"))
    pusher = DeltaPusher(dl, params0, key=jax.random.key(8))
    rep = ServeReplica(dl, pusher.w)
    params1 = jax.tree.map(
        lambda a: a + 0.01 * jnp.ones_like(a), params0)
    env = pusher.push(params1)

    eng = DecodeEngine(model, slots=1, max_len=ML)
    req = eng.submit(prompt, G)
    for i in range(P + G):
        if i == 2:  # arrives mid-decode: staged, old version keeps serving
            assert rep.stage(env) == "staged"
        if i == SWAP:
            assert rep.commit()
        eng.step(rep.params, version=rep.version)
    assert req.done

    # two-phase reference: the same cache continues across the swap
    ref_old = dl.init(params0)                       # the replica's w at v0
    ref_new = dl.apply_push(env.payloads, ref_old)   # and at v1
    cache = model.init_cache(1, ML)

    @jax.jit
    def step(params, cache, token, pos):
        logits, cache = model.decode_step(params, cache, token, pos)
        return (jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None],
                cache)

    tok = jnp.zeros((1, 1), jnp.int32)
    want, want_versions = [], []
    for i in range(P + G):
        p = ref_old if i < SWAP else ref_new
        inp = (jnp.asarray(prompt[i:i + 1])[None] if i < P else tok)
        tok, cache = step(p, cache, inp, jnp.int32(i))
        if i >= P:
            want.append(int(tok[0, 0]))
            want_versions.append(0 if i < SWAP else 1)
    assert req.out == want
    assert req.versions == want_versions
    # every token came from exactly one committed version, and the version
    # stream is monotone: no token was produced by a half-applied model
    assert set(req.versions) == {0, 1}
    assert req.versions == sorted(req.versions)


def test_engine_rejects_overlong_requests():
    _, model = _smoke_model()
    eng = DecodeEngine(model, slots=1, max_len=8)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(np.zeros(5, np.int64), 4)


def test_serve_cli_validates_prompt_plus_gen(capsys):
    from repro.launch import serve

    with pytest.raises(SystemExit):
        serve.parse_args(["--prompt-len", "20", "--gen", "20",
                          "--max-len", "32"])
    assert "--max-len" in capsys.readouterr().err


# -----------------------------------------------------------------------------
# the fleet driver end to end
# -----------------------------------------------------------------------------

def test_run_fleet_pins_and_measures(tmp_path):
    """A tiny end-to-end fleet: the bitwise invariant is asserted inside
    run_fleet for every push; here we also pin the exact bits accounting
    and the serve-spec identity of the returned metrics."""
    spec = ExperimentSpec(
        problem="mamba2-130m", smoke=True, backend="shard_map", mesh="1x1",
        n=1, d=D, downlink="qsgd:16",
        serve="replicas:2,slots:1,prompt:1,gen:2,max_len:4,pushes:2")
    m = run_fleet(spec, ckpt_dir=str(tmp_path), quiet=True)
    assert m["fingerprint"] == spec.fingerprint()
    assert m["pushes"] == 2 and m["replicas"] == 2
    assert m["requests"] == 4  # 2 replicas x 2 waves x 1 slot
    assert m["delta_bits_per_push"] <= 0.35 * m["checkpoint_bits_per_push"]


def test_serve_spec_field_fingerprint_stable_when_unset():
    """Adding the serve field must not move any pre-existing fingerprint:
    unset, it serializes to nothing."""
    d = ExperimentSpec().to_dict()
    assert "serve" not in d
    spec = ExperimentSpec(problem="mamba2-130m", smoke=True,
                          backend="shard_map", mesh="1x1", n=1,
                          serve="gen:4,max_len:8")
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    assert spec.serve_spec().gen == 4
    with pytest.raises(Exception, match="decode loop"):
        ExperimentSpec(serve="gen:4")  # built-in problem has no decoding
    with pytest.raises(Exception, match="overruns"):
        ExperimentSpec(problem="mamba2-130m", smoke=True,
                       backend="shard_map", mesh="1x1", n=1,
                       serve="prompt:30,gen:30,max_len:32")
