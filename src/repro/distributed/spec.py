"""Sharding-spec utilities.

Models declare their own parameter PartitionSpecs (over the 'model' axis
only); these helpers lift them to meshes, to worker-stacked EF-BV state, and
to NamedShardings for jit in_shardings.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import worker_axes

PyTree = Any


def replicated(tree: PyTree) -> PyTree:
    return jax.tree.map(lambda _: P(), tree)


def to_named_sharding(mesh, specs: PyTree) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        specs, is_leaf=lambda s: isinstance(s, P))


def stack_worker_spec(mesh, specs: PyTree) -> PyTree:
    """EF-BV control-variate sharding: prepend the worker axes to each leaf's
    spec (h has a leading per-worker axis of size n)."""
    w = worker_axes(mesh)
    return jax.tree.map(lambda s: P(w, *s), specs,
                        is_leaf=lambda s: isinstance(s, P))


def batch_spec(mesh) -> P:
    """Global batch is sharded over every non-model axis."""
    return P(worker_axes(mesh))


def param_sharding_tree(mesh, specs: PyTree) -> PyTree:
    return to_named_sharding(mesh, specs)


def linear_worker_index(mesh) -> jax.Array:
    """Linearized (pod, data) worker index, valid inside shard_map."""
    w = worker_axes(mesh)
    idx = jax.lax.axis_index(w[0])
    for a in w[1:]:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx
