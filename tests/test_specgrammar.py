"""The unified spec grammar (core/specgrammar.py): four mini-languages, one
parser/printer module.

Obligations pinned here:

1. *Verbatim round-trips* -- for every compressor / fleet / leaf-rule /
   downlink / pipeline spec string used anywhere in this suite (and in the
   committed ``examples/specs/*.json`` files), the unified grammar parses it
   to the same value as the historical entry points it replaced, and
   ``parse(format(parse(s))) == parse(s)`` losslessly.
2. *Delegates are thin* -- ``Downlink.parse`` / ``Pipeline.parse`` /
   ``make_fleet`` / ``wire.parse_leaf_rules`` agree exactly with the
   ``specgrammar`` functions they wrap, error messages included.
3. *Formatting is canonical* -- aliases normalize (``none`` -> ``identity``),
   default ``@1.0`` downlink scalings are omitted, leaf-rule catch-alls print
   their explicit ``*=`` pattern.
"""

import json
import pathlib

import pytest

from repro.core import Downlink, make_compressor, specgrammar
from repro.core.compressors import Identity, MNice, QSGD, TopK, make_fleet
from repro.core.efbv import Pipeline
from repro.distributed import wire

SPECS_DIR = pathlib.Path(__file__).resolve().parents[1] / "examples" / "specs"

# Every atom spelling exercised in this suite (tests/test_spec.py CODEC_SPECS
# plus the zoo aliases).
CODEC_SPECS = [
    "identity", "none", "topk:8", "randk:4", "scaled_randk:4", "comp:2,8",
    "mix:2,4", "block_topk:16,2", "block_topk:256,16", "sign", "natural",
    "qsgd:16", "frac_topk:50", "frac_comp:20,400",
]

# Fleet strings used across tests/test_spec.py, test_bidirectional.py,
# test_wire_codecs.py and docs/wire_format.md.
FLEET_SPECS = [
    "topk:7;qsgd:16;sign", "frac_topk:50;qsgd:16", "topk:16;qsgd:16",
    "topk:16", "topk:16;", "topk:7;randk:9;sign", "topk:5;qsgd:8",
    "topk:4;sign", "topk:8;randk:16;qsgd:16", "topk:8;qsgd:16",
    "topk:8;randk:8;qsgd:16", "topk:64;qsgd:16",
]

# Leaf-codec rule strings used across test_tree_wire.py, test_serve_delta.py
# and the docs.
LEAF_RULE_SPECS = [
    "*embed*=qsgd:16;*norm*=identity", "*embed*=qsgd:16", "*=sign",
    "embed*=qsgd:16;bias=identity", "embed*=qsgd:16;*norm*=identity;block_topk:256,16",
    "", "   ;  ",
]

# Downlink strings from tests/test_spec.py DOWNLINK_SPECS + launch/serve.py.
DOWNLINK_SPECS = ["", "none", "qsgd:16", "block_topk:16,2", "topk:48",
                  "sign@0.9", "topk:64@0.9", "identity"]

PIPELINE_SPECS = ["", "off", "depth:0", "depth:1"]


# ---------------------------------------------------------------------------
# 1. atoms: parse == make_compressor, format∘parse lossless
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", CODEC_SPECS)
def test_atom_parse_matches_make_compressor_and_round_trips(spec):
    comp = specgrammar.parse_compressor(spec)
    assert comp == make_compressor(spec)
    canon = specgrammar.format_compressor(comp)
    assert specgrammar.parse_compressor(canon) == comp


def test_atom_format_normalizes_the_none_alias():
    assert specgrammar.format_compressor(make_compressor("none")) == "identity"


def test_atom_format_rejects_joint_compressors():
    with pytest.raises(ValueError, match="no spec-string spelling"):
        specgrammar.format_compressor(MNice(n=4, m=2))


def test_atom_unknown_name_error_verbatim():
    with pytest.raises(ValueError) as e:
        specgrammar.parse_compressor("nope:3")
    assert "unknown compressor 'nope'; known:" in str(e.value)


# ---------------------------------------------------------------------------
# 2. fleets: parse == make_fleet delegate, format∘parse lossless
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", FLEET_SPECS)
def test_fleet_parse_matches_make_fleet_and_round_trips(spec):
    n = 8
    fleet = specgrammar.parse_fleet(spec, n)
    assert fleet == make_fleet(spec, n)
    assert len(fleet) == n
    canon = specgrammar.format_fleet(fleet)
    assert specgrammar.parse_fleet(canon, n) == fleet


def test_fleet_empty_error_verbatim():
    with pytest.raises(ValueError, match="empty compressor fleet"):
        make_fleet(" ; ", 4)


def test_fleet_too_long_error_verbatim():
    with pytest.raises(ValueError, match="fleet of 3 members for only 2 workers"):
        make_fleet("sign;sign;sign", 2)


# ---------------------------------------------------------------------------
# 3. leaf-codec rules: parse == wire.parse_leaf_rules delegate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", LEAF_RULE_SPECS)
def test_leaf_rules_parse_matches_wire_and_round_trips(spec):
    rules = specgrammar.parse_leaf_rules(spec)
    assert rules == wire.parse_leaf_rules(spec)
    canon = specgrammar.format_leaf_rules(rules)
    assert specgrammar.parse_leaf_rules(canon) == rules


def test_leaf_rules_bare_atom_is_catch_all_and_formats_explicitly():
    rules = specgrammar.parse_leaf_rules("embed*=qsgd:16;sign")
    assert rules == (("embed*", QSGD(16)), ("*", make_compressor("sign")))
    assert specgrammar.format_leaf_rules(rules) == "embed*=qsgd:16;*=sign"


def test_leaf_rules_missing_half_error_verbatim():
    with pytest.raises(ValueError, match="needs both a leaf-path pattern"):
        wire.parse_leaf_rules("=qsgd:16")
    with pytest.raises(ValueError, match="needs both a leaf-path pattern"):
        specgrammar.parse_leaf_rules("embed*=")


def test_leaf_rules_joint_compressor_error_verbatim():
    # the string grammar cannot even name a joint compressor ...
    with pytest.raises(ValueError, match="unknown compressor 'mnice'"):
        wire.parse_leaf_rules("embed*=mnice:4,2")
    # ... and the formatter refuses to invent a spelling for one
    with pytest.raises(ValueError, match="no spec-string spelling"):
        specgrammar.format_leaf_rules((("embed*", MNice(n=4, m=2)),))


# ---------------------------------------------------------------------------
# 4. downlink: parse == Downlink.parse delegate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", DOWNLINK_SPECS)
def test_downlink_parse_matches_delegate_and_round_trips(spec):
    pair = specgrammar.parse_downlink(spec)
    dl = Downlink.parse(spec)
    if pair is None:
        assert dl is None
    else:
        assert dl == Downlink(compressor=pair[0], lam=pair[1])
    canon = specgrammar.format_downlink(pair)
    assert specgrammar.parse_downlink(canon) == pair
    # the Downlink object formats identically to the raw pair
    assert specgrammar.format_downlink(dl) == canon


def test_downlink_format_canonical_spellings():
    assert specgrammar.format_downlink(None) == "none"
    assert specgrammar.format_downlink((QSGD(16), 1.0)) == "qsgd:16"
    assert specgrammar.format_downlink((TopK(64), 0.9)) == "topk:64@0.9"
    assert specgrammar.parse_downlink("topk:64@0.9") == (TopK(64), 0.9)


# ---------------------------------------------------------------------------
# 5. pipeline: parse == Pipeline.parse delegate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", PIPELINE_SPECS)
def test_pipeline_parse_matches_delegate_and_round_trips(spec):
    depth = specgrammar.parse_pipeline(spec)
    assert Pipeline.parse(spec) == Pipeline(depth=depth)
    canon = specgrammar.format_pipeline(depth)
    assert specgrammar.parse_pipeline(canon) == depth
    assert specgrammar.format_pipeline(Pipeline(depth=depth)) == canon


def test_pipeline_grammar_vs_dataclass_split():
    # the grammar accepts any int depth; the dataclass enforces the
    # implemented range (so the 'not implemented' message survives verbatim)
    assert specgrammar.parse_pipeline("depth:2") == 2
    with pytest.raises(ValueError, match="pipeline depth 2 not implemented"):
        Pipeline.parse("depth:2")


@pytest.mark.parametrize("bad", ["depth:", "async", "depth:x"])
def test_pipeline_bad_spec_error_verbatim(bad):
    with pytest.raises(ValueError) as e:
        Pipeline.parse(bad)
    assert f"pipeline spec {bad!r} (want off | depth:0 | depth:1)" in str(e.value)


# ---------------------------------------------------------------------------
# 6. every committed spec file parses through the unified grammar losslessly
# ---------------------------------------------------------------------------

def test_committed_spec_files_round_trip_through_the_grammar():
    files = sorted(SPECS_DIR.glob("*.json"))
    assert files, "no committed spec files found"
    for path in files:
        payload = json.loads(path.read_text())
        comp_spec = payload.get("compressor", "identity")
        n = int(payload.get("n", 1))
        fleet = specgrammar.parse_fleet(comp_spec, n)
        assert specgrammar.parse_fleet(
            specgrammar.format_fleet(fleet), n) == fleet
        pair = specgrammar.parse_downlink(payload.get("downlink", ""))
        assert specgrammar.parse_downlink(
            specgrammar.format_downlink(pair)) == pair
        rules = specgrammar.parse_leaf_rules(payload.get("leaf_codecs", ""))
        assert specgrammar.parse_leaf_rules(
            specgrammar.format_leaf_rules(rules)) == rules
        depth = specgrammar.parse_pipeline(payload.get("pipeline", "off"))
        assert specgrammar.parse_pipeline(
            specgrammar.format_pipeline(depth)) == depth
