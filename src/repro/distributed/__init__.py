from repro.distributed.spec import (  # noqa: F401
    to_named_sharding, stack_worker_spec, batch_spec, replicated,
)
from repro.distributed.aggregate import (  # noqa: F401
    compress_local, combine_global, efbv_aggregate_reference, AGG_MODES,
)
from repro.distributed.wire import (  # noqa: F401
    DensePack, FlatSparse, LeafCodec, LeafWire, NaturalPack, QsgdQuant,
    RandKSparse, SignPack, WireFormat, codec_of, encode_update, format_for,
    fused_pack, pack_bits, pack_oracle, payload_bytes, scatter_add, unpack,
    unpack_bits,
)
