"""Back-compat shim: the cost model moved to :mod:`repro.analysis.hlo`."""

from repro.analysis.hlo import (  # noqa: F401
    Computation,
    Cost,
    Instr,
    computation_cost,
    dot_flops,
    hlo_cost,
    parse_computations,
    trip_count,
)
