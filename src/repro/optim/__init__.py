from repro.optim.optimizers import (  # noqa: F401
    Optimizer, sgd, adamw, apply_updates, global_norm, clip_by_global_norm, chain,
)
from repro.optim.schedules import (  # noqa: F401
    constant, cosine, wsd, linear_warmup,
)
