"""Flat-npz pytree checkpointing (no external deps).

Leaves are addressed by their tree path ('params/layers/attn/wq', ...);
restore validates structure against a template pytree.  Arrays are pulled to
host (sharded arrays are fully gathered -- fine at the scales this repo
executes on CPU; a production TPU deployment would swap in per-shard writes
behind the same interface).
"""

from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any
_SEP = "|"


def _flatten(tree: PyTree):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves_with_paths:
        key = _SEP.join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(ckpt_dir: str, step: int, tree: PyTree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step:08d}.npz")
    np.savez(tmp, **_flatten(tree))  # .npz suffix keeps numpy from renaming
    os.replace(tmp, path)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, template: PyTree) -> PyTree:
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    data = np.load(path)
    flat = _flatten(template)
    missing = set(flat) - set(data.files)
    extra = set(data.files) - set(flat)
    if missing or extra:
        raise ValueError(f"checkpoint mismatch: missing={sorted(missing)[:5]} "
                         f"extra={sorted(extra)[:5]}")
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    new_leaves = []
    for path_t, leaf in leaves_with_paths:
        key = _SEP.join(_path_str(p) for p in path_t)
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {leaf.shape}")
        new_leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
