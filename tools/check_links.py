#!/usr/bin/env python
"""Thin shim: the link checker lives in repro.analysis.docs now
(``python -m repro.analysis --docs``); this keeps the old CI invocation
``python tools/check_links.py docs README.md`` working."""

from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.analysis.docs import check_file, main, slugify  # noqa: E402,F401

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
