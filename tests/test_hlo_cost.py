"""Tests for the trip-count-aware HLO cost model (roofline §methodology)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import hlo_cost, parse_computations, trip_count


def test_scan_flops_trip_weighted():
    """A scan of L matmuls must count L x the body flops (the XLA-CPU
    cost_analysis bug this module exists to fix)."""
    L_, M, K, N = 24, 64, 128, 256
    Ws = jnp.zeros((L_, K, N))
    x = jnp.zeros((M, K))

    def f(x, Ws):
        def body(h, W):
            return jnp.tanh(h @ W @ W.T), None
        h, _ = jax.lax.scan(body, x, Ws)
        return h

    comp = jax.jit(f).lower(x, Ws).compile()
    c = hlo_cost(comp.as_text())
    expect = L_ * (2 * M * K * N + 2 * M * N * K)
    assert abs(c.flops - expect) / expect < 1e-6
    # and the raw XLA number is indeed wrong (trip-unaware)
    from repro.compat import cost_analysis
    xla = cost_analysis(comp)["flops"]
    assert xla < expect / 2


def test_plain_matmul_flops():
    M, K, N = 32, 64, 128
    comp = jax.jit(lambda a, b: a @ b).lower(
        jnp.zeros((M, K)), jnp.zeros((K, N))).compile()
    c = hlo_cost(comp.as_text())
    assert abs(c.flops - 2 * M * K * N) / (2 * M * K * N) < 1e-6


def test_memory_bytes_scale_with_data():
    f = jax.jit(lambda x: jnp.tanh(x) * 2.0 + 1.0)
    c1 = hlo_cost(f.lower(jnp.zeros((1024,))).compile().as_text())
    c2 = hlo_cost(f.lower(jnp.zeros((4096,))).compile().as_text())
    assert 3.0 < c2.hbm_bytes / c1.hbm_bytes < 5.0


def test_dynamic_slice_counts_slice_not_stack():
    """Reading one layer's weights from an (L, ...) stack must cost ~the
    slice, not L x it."""
    L_, D = 64, 256
    stack = jnp.zeros((L_, D, D))

    def f(stack):
        def body(h, W):
            return h @ W, None
        h, _ = jax.lax.scan(body, jnp.zeros((8, D)), stack)
        return h

    c = hlo_cost(jax.jit(f).lower(stack).compile().as_text())
    slice_bytes = D * D * 4
    # L iterations x O(slice) traffic, far below L x full-stack
    assert c.hbm_bytes < L_ * (6 * slice_bytes + 8 * D * 4 * 4)
    assert c.hbm_bytes < 0.2 * L_ * (L_ * slice_bytes)


def test_trip_count_parsing():
    def f(x):
        def body(c, _):
            return c * 1.5, None
        c, _ = jax.lax.scan(body, x, None, length=37)
        return c

    txt = jax.jit(f).lower(jnp.zeros(())).compile().as_text()
    comps = parse_computations(txt)
    # the while condition region resolves to the loop bound (possibly via the
    # max-constant fallback when the compare is fused)
    trips = [trip_count(c) for name, c in comps.items() if "region" in name]
    assert 37 in trips, trips
