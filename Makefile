# Tier-1 verify = the fast default test selection (slow subprocess tests
# excluded via the pytest addopts in pyproject.toml).  Everything runs on CPU
# (JAX_PLATFORMS=cpu): the Pallas kernels auto-select interpret mode off-TPU
# and the fused wire pack dispatches to its bit-identical jnp oracle.

PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test-tier1 test-all test-slow bench bench-micro smoke smoke-federated \
	smoke-bidirectional smoke-spec smoke-pipelined smoke-tree smoke-serve \
	smoke-finetune docs-test docs-check lint sanitize-smoke

test-tier1:
	JAX_PLATFORMS=cpu $(PY) -m pytest -x -q

test-all:
	JAX_PLATFORMS=cpu $(PY) -m pytest -q -m ""

test-slow:
	JAX_PLATFORMS=cpu $(PY) -m pytest -q -m slow --durations=25

# the pinned CI bench: writes BENCH_perf.json + BENCH_bits.json at the repo
# root -- byte-identical machinery to the CI `bench` job, so the committed
# trajectory and a local run are comparable
bench:
	JAX_PLATFORMS=cpu $(PY) -m benchmarks.ci_bench

bench-micro:
	JAX_PLATFORMS=cpu $(PY) -m benchmarks.compressor_bench

docs-test:
	JAX_PLATFORMS=cpu $(PY) -m pytest -q --doctest-glob='*.md' docs/

docs-check: docs-test
	$(PY) tools/check_links.py docs README.md

# the repo-invariant static analyzer (docs/static_analysis.md): AST rules
# over src/ + tests/ pinned against the committed golden counts, the docs
# link/doctest census, and the dense-free proof for every registered pack
# kernel.  Mirrors the CI `lint` job.
lint:
	$(PY) -m repro.analysis src/ tests/ --golden ANALYSIS_GOLDEN.json
	$(PY) -m repro.analysis --docs
	JAX_PLATFORMS=cpu $(PY) -m repro.analysis --hlo-gate

# dynamic sanitizer (repro.analysis.sanitize): one smoke step of each
# trainer under jax_debug_nans + forced Pallas interpret mode
sanitize-smoke:
	JAX_PLATFORMS=cpu $(PY) -m repro.launch.train --arch qwen2-0.5b --smoke \
	    --mesh 2x2 --steps 2 --global-batch 8 --seq 32 \
	    --compressor block_topk:256,16 --agg sparse_allgather --sanitize
	JAX_PLATFORMS=cpu $(PY) -m repro.launch.finetune \
	    --spec examples/specs/finetune_moe.json --steps 2 \
	    --global-batch 8 --seq 32 --eval-every 2 --sanitize

smoke:
	JAX_PLATFORMS=cpu $(PY) -m repro.launch.train --arch qwen2-0.5b --smoke \
	    --mesh 2x2 --steps 4 --global-batch 8 --seq 32 \
	    --compressor block_topk:256,16 --agg sparse_allgather

smoke-federated:
	JAX_PLATFORMS=cpu $(PY) -m repro.launch.train --arch qwen2-0.5b --smoke \
	    --mesh 2x2 --steps 4 --global-batch 8 --seq 32 \
	    --compressor block_topk:256,16 --agg sparse_allgather \
	    --participation bernoulli:0.5 --local-batch-resample

smoke-bidirectional:
	JAX_PLATFORMS=cpu $(PY) -m repro.launch.train --arch qwen2-0.5b --smoke \
	    --mesh 2x2 --steps 4 --global-batch 8 --seq 32 \
	    --compressor qsgd:16 --agg sparse_allgather --downlink qsgd:16

# spec-file driven run: the whole experiment from one committed
# ExperimentSpec JSON (docs/api.md)
smoke-spec:
	JAX_PLATFORMS=cpu $(PY) -m repro.launch.train \
	    --spec examples/specs/qsgd_bidirectional.json --smoke \
	    --global-batch 8 --seq 32

# pipelined (one-round-stale) schedule: the committed depth:1 spec drives a
# double-buffered train step (docs/algorithms.md#pipelined-rounds)
smoke-pipelined:
	JAX_PLATFORMS=cpu $(PY) -m repro.launch.train \
	    --spec examples/specs/pipelined_blocktopk.json --smoke \
	    --global-batch 8 --seq 32

# pytree-native wire: the committed mixed per-leaf codec spec
# (docs/wire_format.md#per-leaf-codecs-the-pytree-native-wire)
smoke-tree:
	JAX_PLATFORMS=cpu $(PY) -m repro.launch.train \
	    --spec examples/specs/tree_mixed_codecs.json --smoke \
	    --global-batch 8 --seq 32

# staged fine-tuning harness: the committed MoE spec (smallest MoE config,
# expert-sparse per-leaf wire, fsdp backend) through all four stages, with
# the multi-host-shaped mesh simulated at 2 processes (docs/finetuning.md)
smoke-finetune:
	JAX_PLATFORMS=cpu $(PY) -m repro.launch.finetune \
	    --spec examples/specs/finetune_moe.json --steps 2 \
	    --global-batch 8 --seq 32 --processes 2 --eval-every 2

# compressed-delta serving: the committed serve spec drives a simulated
# replica fleet reconstructing w from versioned downlink pushes, bitwise
# (docs/serving.md)
smoke-serve:
	JAX_PLATFORMS=cpu $(PY) -m repro.launch.serve \
	    --spec examples/specs/serve_delta.json
