"""Batched serving driver: greedy decode with a KV (or SSM-state) cache.

Example (CPU, reduced config):

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --smoke \
        --batch 4 --prompt-len 16 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import build_model


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    key = jax.random.key(args.seed)
    params = model.init(key)
    B = args.batch

    prompts = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab)
    cache = model.init_cache(B, args.max_len)
    if cfg.family == "encdec":
        frames = jax.random.normal(key, (B, cfg.encoder_frames, cfg.d_model)) * 0.1
        cache = model.encode_cross_cache(params, frames, cache)

    @jax.jit
    def step(params, cache, token, pos):
        logits, cache = model.decode_step(params, cache, token, pos)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, cache

    # prefill via teacher-forced decode (exercises the same serve_step the
    # dry-run lowers; a production deployment would use model.prefill + cache).
    # An empty prompt (--prompt-len 0) skips prefill and generates from a
    # BOS-style zero token.
    tok = jnp.zeros((B, 1), jnp.int32)
    t0 = time.time()
    for t in range(args.prompt_len):
        tok, cache = step(params, cache, prompts[:, t:t + 1], jnp.int32(t))
    generated = []
    for t in range(args.prompt_len, args.prompt_len + args.gen):
        tok, cache = step(params, cache, tok, jnp.int32(t))
        generated.append(np.asarray(tok[:, 0]))
    dt = time.time() - t0
    gen = np.stack(generated, 1)
    total_tokens = B * (args.prompt_len + args.gen)
    print(f"[serve] arch={cfg.name} batch={B} prompt={args.prompt_len} "
          f"gen={args.gen}: {total_tokens / dt:.1f} tok/s (CPU)")
    print(f"[serve] sample continuation (req 0): {gen[0][:16].tolist()}")
    return gen


if __name__ == "__main__":
    main()
