"""Config -> Model: init / param_specs / loss / prefill / decode across the
six assigned families (dense, moe, ssm, hybrid, encdec-audio, vlm).

Conventions
-----------
* Per-layer parameters are stacked on a leading L axis and consumed with
  ``lax.scan`` (keeps HLO size O(1) in depth -- essential for the 78-compile
  dry-run) with optional ``jax.checkpoint`` remat per block.
* A Model never touches the mesh: it only declares PartitionSpecs over the
  'model' axis; the trainer / dryrun decide data/pod sharding.
* ``batch`` dicts:
    train:   {"tokens": (B,S) i32, "labels": (B,S) i32, [frontend stubs]}
    prefill: {"tokens": (B,S) i32, [frontend stubs]}
    decode:  token (B,1) i32 + a cache pytree + scalar position.
* Modality frontends (audio conv stack / vision tower) are stubs per spec:
  the batch carries precomputed frame/patch embeddings.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models.config import ModelConfig

Array = jax.Array
PyTree = Any


def _sinusoid(S: int, d: int, dtype) -> Array:
    pos = jnp.arange(S)[:, None].astype(jnp.float32)
    return _sinusoid_at(pos, d).astype(dtype)


def _sinusoid_at(pos: Array, d: int) -> Array:
    """Sinusoidal position encoding at (possibly dynamic) positions.
    pos: (..., 1) float -> (..., d)."""
    div = jnp.exp(jnp.arange(0, d, 2).astype(jnp.float32) * (-math.log(10000.0) / d))
    ang = pos * div
    pe = jnp.zeros(pos.shape[:-1] + (d,), jnp.float32)
    pe = pe.at[..., 0::2].set(jnp.sin(ang))
    pe = pe.at[..., 1::2].set(jnp.cos(ang))
    return pe


def cross_entropy(logits: Array, labels: Array) -> Tuple[Array, Array]:
    """Mean CE over positions with label >= 0.  logits (B,S,V), labels (B,S)."""
    lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels.clip(0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    per_tok = (lse - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(per_tok) / denom, denom


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------------ init

    def init(self, key: Array) -> PyTree:
        params = self._build(key)[0]
        pdt = jnp.dtype(self.cfg.param_dtype)
        if pdt != jnp.float32:
            params = jax.tree.map(lambda p: p.astype(pdt), params)
        return params

    def init_abstract(self) -> PyTree:
        """ShapeDtypeStruct params (no allocation) -- for the dry-run."""
        return jax.eval_shape(self.init, jax.random.key(0))

    def param_specs(self) -> PyTree:
        return self._build_specs()

    # -- families ---------------------------------------------------------

    def _block_inits(self):
        """(layer_init_fn, spec template) for one decoder block of the family."""
        cfg = self.cfg
        d, ff, hd = cfg.d_model, cfg.d_ff, cfg.hd()

        if cfg.family in ("dense", "vlm"):
            def one(k):
                k1, k2 = jax.random.split(k)
                attn, attn_s = L.attention_init(k1, d, cfg.n_heads, cfg.n_kv_heads,
                                                hd, cfg.qkv_bias,
                                                shard_policy=cfg.attn_shard_policy)
                mlp, mlp_s = L.mlp_init(k2, d, ff)
                ln1, _ = L.rmsnorm_init(d)
                ln2, _ = L.rmsnorm_init(d)
                return ({"attn": attn, "mlp": mlp, "ln1": ln1, "ln2": ln2},
                        {"attn": attn_s, "mlp": mlp_s, "ln1": P(None), "ln2": P(None)})
            return one

        if cfg.family == "moe":
            def one(k):
                k1, k2 = jax.random.split(k)
                attn, attn_s = L.attention_init(k1, d, cfg.n_heads, cfg.n_kv_heads,
                                                hd, cfg.qkv_bias,
                                                shard_policy=cfg.attn_shard_policy)
                moe, moe_s = MOE.moe_init(k2, d, ff, cfg.n_experts)
                ln1, _ = L.rmsnorm_init(d)
                ln2, _ = L.rmsnorm_init(d)
                return ({"attn": attn, "moe": moe, "ln1": ln1, "ln2": ln2},
                        {"attn": attn_s, "moe": moe_s, "ln1": P(None), "ln2": P(None)})
            return one

        if cfg.family in ("ssm", "hybrid"):
            def one(k):
                m, m_s = M2.mamba2_init(k, d, d_inner=cfg.d_inner(),
                                        d_state=cfg.ssm_state,
                                        n_heads=cfg.ssm_heads(), d_conv=cfg.ssm_conv)
                ln, _ = L.rmsnorm_init(d)
                return ({"mamba": m, "ln": ln}, {"mamba": m_s, "ln": P(None)})
            return one

        if cfg.family == "encdec":
            def one(k):
                k1, k2, k3 = jax.random.split(k, 3)
                attn, attn_s = L.attention_init(k1, d, cfg.n_heads, cfg.n_kv_heads,
                                                hd, cfg.qkv_bias,
                                                shard_policy=cfg.attn_shard_policy)
                xattn, xattn_s = L.attention_init(k2, d, cfg.n_heads, cfg.n_kv_heads,
                                                  hd, cfg.qkv_bias,
                                                shard_policy=cfg.attn_shard_policy)
                mlp, mlp_s = L.mlp_init(k3, d, ff)
                ln1, _ = L.rmsnorm_init(d)
                ln2, _ = L.rmsnorm_init(d)
                ln3, _ = L.rmsnorm_init(d)
                return ({"attn": attn, "xattn": xattn, "mlp": mlp,
                         "ln1": ln1, "ln2": ln2, "ln3": ln3},
                        {"attn": attn_s, "xattn": xattn_s, "mlp": mlp_s,
                         "ln1": P(None), "ln2": P(None), "ln3": P(None)})
            return one

        raise ValueError(cfg.family)

    def _build(self, key: Array) -> Tuple[PyTree, PyTree]:
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        d, V = cfg.d_model, cfg.vocab
        one = self._block_inits()

        def layer_init(k):
            return one(k)[0]

        stacked = jax.vmap(layer_init)(jax.random.split(keys[0], cfg.n_layers))
        params: Dict[str, Any] = {
            "embed": (jax.random.normal(keys[1], (V, d)) * 0.02).astype(jnp.float32),
            "layers": stacked,
            "final_norm": jnp.ones((d,), jnp.float32),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = (jax.random.normal(keys[2], (d, V))
                                 * (1.0 / math.sqrt(d))).astype(jnp.float32)

        if cfg.family == "hybrid":
            k1, k2 = jax.random.split(keys[3])
            attn, _ = L.attention_init(k1, d, cfg.n_heads, cfg.n_kv_heads,
                                       cfg.hd(), cfg.qkv_bias,
                                                shard_policy=cfg.attn_shard_policy)
            mlp, _ = L.mlp_init(k2, d, cfg.d_ff)
            ln1, _ = L.rmsnorm_init(d)
            ln2, _ = L.rmsnorm_init(d)
            params["shared_attn"] = {"attn": attn, "mlp": mlp, "ln1": ln1, "ln2": ln2}

        if cfg.family == "encdec":
            def enc_init(k):
                k1, k2 = jax.random.split(k)
                attn, _ = L.attention_init(k1, d, cfg.n_heads, cfg.n_kv_heads,
                                           cfg.hd(), cfg.qkv_bias,
                                                shard_policy=cfg.attn_shard_policy)
                mlp, _ = L.mlp_init(k2, d, cfg.d_ff)
                ln1, _ = L.rmsnorm_init(d)
                ln2, _ = L.rmsnorm_init(d)
                return {"attn": attn, "mlp": mlp, "ln1": ln1, "ln2": ln2}
            params["encoder"] = jax.vmap(enc_init)(
                jax.random.split(keys[4], cfg.encoder_layers))
            params["enc_norm"] = jnp.ones((d,), jnp.float32)

        return params, None

    def _build_specs(self) -> PyTree:
        cfg = self.cfg
        d, V = cfg.d_model, cfg.vocab
        one = self._block_inits()
        _, block_specs = one(jax.random.key(0))
        lift = lambda tree: jax.tree.map(lambda s: P(None, *s), tree,
                                         is_leaf=lambda s: isinstance(s, P))
        specs: Dict[str, Any] = {
            "embed": L.auto_spec((V, d), prefer=(0,)),
            "layers": lift(block_specs),
            "final_norm": P(None),
        }
        if not cfg.tie_embeddings:
            specs["lm_head"] = L.auto_spec((d, V), prefer=(1,))
        if cfg.family == "hybrid":
            attn_s = L.attention_init(jax.random.key(0), d, cfg.n_heads,
                                      cfg.n_kv_heads, cfg.hd(), cfg.qkv_bias,
                                                shard_policy=cfg.attn_shard_policy)[1]
            mlp_s = L.mlp_init(jax.random.key(0), d, cfg.d_ff)[1]
            specs["shared_attn"] = {"attn": attn_s, "mlp": mlp_s,
                                    "ln1": P(None), "ln2": P(None)}
        if cfg.family == "encdec":
            attn_s = L.attention_init(jax.random.key(0), d, cfg.n_heads,
                                      cfg.n_kv_heads, cfg.hd(), cfg.qkv_bias,
                                                shard_policy=cfg.attn_shard_policy)[1]
            mlp_s = L.mlp_init(jax.random.key(0), d, cfg.d_ff)[1]
            specs["encoder"] = lift({"attn": attn_s, "mlp": mlp_s,
                                     "ln1": P(None), "ln2": P(None)})
            specs["enc_norm"] = P(None)
        return specs

    # --------------------------------------------------------------- forward

    def _embed_inputs(self, params, batch) -> Tuple[Array, Array]:
        """Returns (hidden (B,S,d), positions) handling frontend stubs."""
        cfg = self.cfg
        adt = jnp.dtype(cfg.activation_dtype)
        tok_emb = params["embed"].astype(adt)

        if cfg.family == "vlm" and "vision_embeds" in batch:
            ve = batch["vision_embeds"].astype(adt)  # (B, Pn, d) stub tower output
            te = tok_emb[batch["tokens"]]            # (B, St, d)
            h = jnp.concatenate([ve, te], axis=1)
            B, S, _ = h.shape
            Pn = ve.shape[1]
            # M-RoPE ids: vision patches on an (h, w) grid at t=0; text tokens
            # advance t (and h=w=t) after the vision span -- Qwen2-VL scheme.
            side = max(int(math.sqrt(Pn)), 1)
            pidx = jnp.arange(Pn)
            tpos = jnp.concatenate([jnp.zeros((Pn,), jnp.int32),
                                    jnp.arange(S - Pn, dtype=jnp.int32) + 1])
            hpos = jnp.concatenate([(pidx // side).astype(jnp.int32),
                                    jnp.arange(S - Pn, dtype=jnp.int32) + 1])
            wpos = jnp.concatenate([(pidx % side).astype(jnp.int32),
                                    jnp.arange(S - Pn, dtype=jnp.int32) + 1])
            pos3 = jnp.stack([tpos, hpos, wpos])[:, None, :].repeat(B, axis=1)
            return h, pos3

        h = tok_emb[batch["tokens"]]
        B, S, _ = h.shape
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        if cfg.mrope_sections:
            pos = jnp.broadcast_to(pos, (3, B, S))
        return h, pos

    def _decoder_blocks(self, params, h: Array, positions,
                        enc_out: Optional[Array] = None) -> Tuple[Array, Array]:
        """Scan the stacked decoder blocks.  Returns (hidden, aux_loss)."""
        cfg = self.cfg
        hd = cfg.hd()
        attn_kw = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=hd,
                       positions=positions, theta=cfg.rope_theta,
                       window=cfg.attn_window,
                       mrope_sections=cfg.mrope_sections,
                       impl=cfg.attn_impl)

        if cfg.family in ("dense", "vlm"):
            def block(carry, lp):
                h, aux = carry
                h = h + L.attention(lp["attn"], L.rmsnorm(h, lp["ln1"], cfg.norm_eps),
                                    **attn_kw)
                h = h + L.swiglu(lp["mlp"], L.rmsnorm(h, lp["ln2"], cfg.norm_eps))
                return (h, aux), None
        elif cfg.family == "moe":
            def block(carry, lp):
                h, aux = carry
                h = h + L.attention(lp["attn"], L.rmsnorm(h, lp["ln1"], cfg.norm_eps),
                                    **attn_kw)
                y, a = MOE.moe_apply(lp["moe"], L.rmsnorm(h, lp["ln2"], cfg.norm_eps),
                                     n_experts=cfg.n_experts, k=cfg.experts_per_tok,
                                     capacity_factor=cfg.capacity_factor,
                                     groups=cfg.moe_groups)
                return (h + y, aux + a), None
        elif cfg.family == "ssm":
            def block(carry, lp):
                h, aux = carry
                h = h + M2.mamba2_apply(lp["mamba"], L.rmsnorm(h, lp["ln"], cfg.norm_eps),
                                        d_inner=cfg.d_inner(), d_state=cfg.ssm_state,
                                        n_heads=cfg.ssm_heads(), chunk=cfg.ssm_chunk,
                                        norm_eps=cfg.norm_eps)
                return (h, aux), None
        elif cfg.family == "hybrid":
            shared = params["shared_attn"]

            def block(carry, xs):
                lp, idx = xs
                h, aux = carry
                h = h + M2.mamba2_apply(lp["mamba"], L.rmsnorm(h, lp["ln"], cfg.norm_eps),
                                        d_inner=cfg.d_inner(), d_state=cfg.ssm_state,
                                        n_heads=cfg.ssm_heads(), chunk=cfg.ssm_chunk,
                                        norm_eps=cfg.norm_eps)

                def with_attn(h):
                    h = h + L.attention(shared["attn"],
                                        L.rmsnorm(h, shared["ln1"], cfg.norm_eps),
                                        **attn_kw)
                    return h + L.swiglu(shared["mlp"],
                                        L.rmsnorm(h, shared["ln2"], cfg.norm_eps))

                h = jax.lax.cond(idx % cfg.attn_every == cfg.attn_every - 1,
                                 with_attn, lambda h: h, h)
                return (h, aux), None
        elif cfg.family == "encdec":
            def block(carry, lp):
                h, aux = carry
                h = h + L.attention(lp["attn"], L.rmsnorm(h, lp["ln1"], cfg.norm_eps),
                                    **attn_kw)
                # cross-attention: project encoder output with this layer's k/v
                xk = (enc_out @ lp["xattn"]["wk"].astype(h.dtype))
                xv = (enc_out @ lp["xattn"]["wv"].astype(h.dtype))
                B, Se, _ = enc_out.shape
                xk = xk.reshape(B, Se, cfg.n_kv_heads, hd)
                xv = xv.reshape(B, Se, cfg.n_kv_heads, hd)
                h = h + L.attention(lp["xattn"], L.rmsnorm(h, lp["ln2"], cfg.norm_eps),
                                    n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=hd,
                                    positions=positions, theta=0.0, causal=False,
                                    kv=(xk, xv))
                h = h + L.swiglu(lp["mlp"], L.rmsnorm(h, lp["ln3"], cfg.norm_eps))
                return (h, aux), None
        else:
            raise ValueError(cfg.family)

        if cfg.remat:
            block = jax.checkpoint(block, prevent_cse=False)

        # data-derived zero: keeps the aux carry's varying-manual-axes type
        # consistent under shard_map (see mamba2._ssd_chunked)
        aux0 = h.reshape(-1)[0].astype(jnp.float32) * 0.0
        if cfg.family == "hybrid":
            xs = (params["layers"], jnp.arange(cfg.n_layers))
        else:
            xs = params["layers"]
        (h, aux), _ = jax.lax.scan(block, (h, aux0), xs)
        return h, aux

    def _encode(self, params, frames: Array) -> Array:
        """Whisper-style encoder over stub frame embeddings (B, F, d)."""
        cfg = self.cfg
        adt = jnp.dtype(cfg.activation_dtype)
        h = frames.astype(adt) + _sinusoid(frames.shape[1], cfg.d_model, adt)
        B, S, _ = h.shape
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        def block(h, lp):
            h = h + L.attention(lp["attn"], L.rmsnorm(h, lp["ln1"], cfg.norm_eps),
                                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=cfg.hd(),
                                positions=pos, theta=0.0, causal=False)
            h = h + L.swiglu(lp["mlp"], L.rmsnorm(h, lp["ln2"], cfg.norm_eps))
            return h, None

        if cfg.remat:
            block = jax.checkpoint(block, prevent_cse=False)
        h, _ = jax.lax.scan(block, h, params["encoder"])
        return L.rmsnorm(h, params["enc_norm"], cfg.norm_eps)

    def forward(self, params, batch) -> Tuple[Array, Array]:
        """Full-sequence forward -> (logits (B,S,V), aux loss)."""
        cfg = self.cfg
        adt = jnp.dtype(cfg.activation_dtype)
        enc_out = None
        if cfg.family == "encdec":
            enc_out = self._encode(params, batch["frames"])
            h = params["embed"].astype(adt)[batch["tokens"]]
            h = h + _sinusoid(h.shape[1], cfg.d_model, adt)
            B, S, _ = h.shape
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        else:
            h, pos = self._embed_inputs(params, batch)
        h, aux = self._decoder_blocks(params, h, pos, enc_out)
        h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        logits = h @ head.astype(h.dtype)
        return logits, aux

    def prefill(self, params, batch) -> Array:
        """Inference prefill: full-sequence forward, returns last-position
        logits (B, V).  (The prefill_32k dry-run shape lowers this.)"""
        logits, _ = self.forward(params, batch)
        return logits[:, -1]

    def encode_cross_cache(self, params, frames: Array, cache: PyTree) -> PyTree:
        """encdec only: run the encoder and fill the per-layer cross-attention
        K/V of a fresh decode cache."""
        cfg = self.cfg
        assert cfg.family == "encdec"
        enc = self._encode(params, frames)
        B = frames.shape[0]
        hd = cfg.hd()

        def one(lp):
            xk = (enc @ lp["xattn"]["wk"].astype(enc.dtype)
                  ).reshape(B, -1, cfg.n_kv_heads, hd)
            xv = (enc @ lp["xattn"]["wv"].astype(enc.dtype)
                  ).reshape(B, -1, cfg.n_kv_heads, hd)
            return xk, xv

        ck, cv = jax.vmap(one)(params["layers"])
        return {**cache, "cross_k": ck.astype(cache["cross_k"].dtype),
                "cross_v": cv.astype(cache["cross_v"].dtype)}

    # ---------------------------------------------------------------- loss

    def loss(self, params, batch) -> Tuple[Array, Dict[str, Array]]:
        cfg = self.cfg
        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        if cfg.family == "vlm" and "vision_embeds" in batch:
            # no loss on the vision span
            Pn = batch["vision_embeds"].shape[1]
            pad = jnp.full(labels.shape[:1] + (Pn,), -1, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        ce, ntok = cross_entropy(logits, labels)
        total = ce + cfg.router_aux_weight * aux
        return total, {"ce": ce, "aux_loss": aux}

    # ------------------------------------------------------------- serving

    def init_cache(self, batch_size: int, max_len: int) -> PyTree:
        cfg = self.cfg
        hd = cfg.hd()
        kvd = jnp.dtype(cfg.activation_dtype)
        C = min(max_len, cfg.attn_window) if cfg.attn_window else max_len

        def attn_cache(layers: int):
            return {
                "k": jnp.zeros((layers, batch_size, C, cfg.n_kv_heads, hd), kvd),
                "v": jnp.zeros((layers, batch_size, C, cfg.n_kv_heads, hd), kvd),
            }

        if cfg.family in ("dense", "vlm", "moe"):
            return attn_cache(cfg.n_layers)
        if cfg.family == "ssm":
            mk = M2.mamba2_cache_init(batch_size, d_inner=cfg.d_inner(),
                                      d_state=cfg.ssm_state, n_heads=cfg.ssm_heads(),
                                      d_conv=cfg.ssm_conv, dtype=kvd)
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(), mk)
        if cfg.family == "hybrid":
            mk = M2.mamba2_cache_init(batch_size, d_inner=cfg.d_inner(),
                                      d_state=cfg.ssm_state, n_heads=cfg.ssm_heads(),
                                      d_conv=cfg.ssm_conv, dtype=kvd)
            mamba = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(), mk)
            shared = attn_cache(1)
            return {"mamba": mamba, "shared": shared}
        if cfg.family == "encdec":
            return {
                "self": attn_cache(cfg.n_layers),
                "cross_k": jnp.zeros((cfg.n_layers, batch_size, cfg.encoder_frames,
                                      cfg.n_kv_heads, hd), kvd),
                "cross_v": jnp.zeros((cfg.n_layers, batch_size, cfg.encoder_frames,
                                      cfg.n_kv_heads, hd), kvd),
            }
        raise ValueError(cfg.family)

    def cache_specs(self) -> PyTree:
        """PartitionSpecs for the cache (kv-heads / channels over 'model')."""
        cfg = self.cfg
        hd = cfg.hd()
        if cfg.n_kv_heads % L.MODEL_AXIS_SIZE == 0:
            kv_spec = P(None, None, None, "model", None)   # shard kv heads
        elif hd % L.MODEL_AXIS_SIZE == 0:
            kv_spec = P(None, None, None, None, "model")   # shard head_dim
        else:
            kv_spec = P(None, None, None, None, None)
        if cfg.family in ("dense", "vlm", "moe"):
            return {"k": kv_spec, "v": kv_spec}
        if cfg.family == "ssm":
            return {"state": P(None, None, None, None, None),
                    "conv": P(None, None, None, None)}
        if cfg.family == "hybrid":
            return {"mamba": {"state": P(None, None, None, None, None),
                              "conv": P(None, None, None, None)},
                    "shared": {"k": kv_spec, "v": kv_spec}}
        if cfg.family == "encdec":
            return {"self": {"k": kv_spec, "v": kv_spec},
                    "cross_k": kv_spec, "cross_v": kv_spec}
        raise ValueError(cfg.family)

    def decode_step(self, params, cache: PyTree, token: Array, pos: Array
                    ) -> Tuple[Array, PyTree]:
        """One-token decode.  token (B,1) i32; pos scalar i32."""
        cfg = self.cfg
        adt = jnp.dtype(cfg.activation_dtype)
        hd = cfg.hd()
        h = params["embed"].astype(adt)[token]  # (B,1,d)
        if cfg.family == "encdec":
            pe = _sinusoid_at(jnp.asarray(pos, jnp.float32)[None, None, None],
                              cfg.d_model)[0]
            h = h + pe.astype(adt)

        def attn_block(h, lp, ck, cv):
            y, ck, cv = L.attention_decode(
                lp["attn"], L.rmsnorm(h, lp["ln1"], cfg.norm_eps), ck, cv, pos,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=hd,
                theta=cfg.rope_theta, window=cfg.attn_window,
                mrope_sections=cfg.mrope_sections)
            return h + y, ck, cv

        if cfg.family in ("dense", "vlm", "moe"):
            def block(h, xs):
                lp, ck, cv = xs
                h, ck, cv = attn_block(h, lp, ck, cv)
                hn = L.rmsnorm(h, lp["ln2"], cfg.norm_eps)
                if cfg.family == "moe":
                    y, _ = MOE.moe_apply(lp["moe"], hn, n_experts=cfg.n_experts,
                                         k=cfg.experts_per_tok,
                                         capacity_factor=cfg.capacity_factor,
                                         groups=cfg.moe_groups)
                else:
                    y = L.swiglu(lp["mlp"], hn)
                return h + y, (ck, cv)

            h, (ks, vs) = jax.lax.scan(
                lambda c, xs: block(c, xs), h,
                (params["layers"], cache["k"], cache["v"]))
            cache = {"k": ks, "v": vs}

        elif cfg.family == "ssm":
            def block(h, xs):
                lp, cc = xs
                y, cc = M2.mamba2_decode(lp["mamba"], L.rmsnorm(h, lp["ln"], cfg.norm_eps),
                                         cc, d_inner=cfg.d_inner(),
                                         d_state=cfg.ssm_state,
                                         n_heads=cfg.ssm_heads(), norm_eps=cfg.norm_eps)
                return h + y, cc

            h, cache = jax.lax.scan(block, h, (params["layers"], cache))

        elif cfg.family == "hybrid":
            shared = params["shared_attn"]
            sk, sv = cache["shared"]["k"][0], cache["shared"]["v"][0]

            def block(carry, xs):
                h, sk, sv = carry
                lp, cc, idx = xs
                y, cc = M2.mamba2_decode(lp["mamba"], L.rmsnorm(h, lp["ln"], cfg.norm_eps),
                                         cc, d_inner=cfg.d_inner(),
                                         d_state=cfg.ssm_state,
                                         n_heads=cfg.ssm_heads(), norm_eps=cfg.norm_eps)
                h = h + y

                def with_attn(args):
                    h, sk, sv = args
                    y, sk, sv = L.attention_decode(
                        shared["attn"], L.rmsnorm(h, shared["ln1"], cfg.norm_eps),
                        sk, sv, pos, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                        hd=hd, theta=cfg.rope_theta, window=cfg.attn_window)
                    h = h + y
                    h = h + L.swiglu(shared["mlp"],
                                     L.rmsnorm(h, shared["ln2"], cfg.norm_eps))
                    return h, sk, sv

                h, sk, sv = jax.lax.cond(
                    idx % cfg.attn_every == cfg.attn_every - 1,
                    with_attn, lambda a: a, (h, sk, sv))
                return (h, sk, sv), cc

            (h, sk, sv), mamba_cache = jax.lax.scan(
                block, (h, sk, sv),
                (params["layers"], cache["mamba"], jnp.arange(cfg.n_layers)))
            cache = {"mamba": mamba_cache,
                     "shared": {"k": sk[None], "v": sv[None]}}

        elif cfg.family == "encdec":
            def block(h, xs):
                lp, ck, cv, xk, xv = xs
                h, ck, cv = attn_block(h, lp, ck, cv)
                y = L.attention(lp["xattn"], L.rmsnorm(h, lp["ln2"], cfg.norm_eps),
                                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=hd,
                                positions=jnp.zeros((h.shape[0], 1), jnp.int32),
                                theta=0.0, causal=False,
                                kv=(xk.astype(h.dtype), xv.astype(h.dtype)))
                h = h + y
                h = h + L.swiglu(lp["mlp"], L.rmsnorm(h, lp["ln3"], cfg.norm_eps))
                return h, (ck, cv)

            h, (ks, vs) = jax.lax.scan(
                block, h,
                (params["layers"], cache["self"]["k"], cache["self"]["v"],
                 cache["cross_k"], cache["cross_v"]))
            cache = {"self": {"k": ks, "v": vs},
                     "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
        else:
            raise ValueError(cfg.family)

        h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        logits = h @ head.astype(h.dtype)
        return logits, cache


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
