"""Algorithm tests: EF21 / DIANA recovery, variance reduction, linear
convergence at the paper's rate, nonconvex behavior."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CompKK, EFBV, Identity, RandK, TopK, prox_l1, prox_l2, proximal_step,
    run_reference, tune_for,
)
from repro.problems import LogReg, make_synthetic

KEY = jax.random.key(0)


def keyless(grad_fn):
    """Adapt an exact-gradient x -> grads function to run_reference's
    (resample_key, x) signature (the key is ignored)."""
    return lambda _k, x: grad_fn(x)


def quad_problem(n=8, d=16, seed=0):
    """Strongly convex quadratic finite sum with known solution."""
    key = jax.random.key(seed)
    A = jax.random.normal(key, (n, d, d)) / jnp.sqrt(d)
    Q = jnp.einsum("nij,nkj->nik", A, A) + 0.5 * jnp.eye(d)  # PD per worker
    b = jax.random.normal(jax.random.key(seed + 1), (n, d))
    Qbar = jnp.mean(Q, 0)
    bbar = jnp.mean(b, 0)
    x_star = jnp.linalg.solve(Qbar, bbar)

    def grads(x):
        return jnp.einsum("nij,j->ni", Q, x) - b

    mu = float(jnp.linalg.eigvalsh(Qbar)[0])
    L = float(jnp.linalg.eigvalsh(Qbar)[-1])
    Li = jax.vmap(lambda q: jnp.linalg.eigvalsh(q)[-1])(Q)
    Lt = float(jnp.sqrt(jnp.mean(Li**2)))
    return grads, x_star, mu, L, Lt


def test_identity_compressor_is_gd():
    """With C = Id, EF-BV reverts to exact gradient descent (Remark 2)."""
    grads, x_star, mu, L, Lt = quad_problem()
    algo = EFBV(Identity(), lam=1.0, nu=1.0)
    x = run_reference(algo=algo, grad_fn=keyless(grads), x0=jnp.zeros(16),
                      gamma=1.0 / L, steps=300, key=KEY, n=8).x
    assert float(jnp.linalg.norm(x - x_star)) < 1e-4


def test_ef21_equals_efbv_nu_lambda():
    """EF-BV with nu = lam produces the EXACT EF21 iterates (Sect. 3.1)."""
    grads, *_ = quad_problem()
    comp = TopK(3)
    a1 = EFBV(comp, lam=0.7, nu=0.7)

    # hand-rolled EF21 (Algorithm 2): h_i <- h_i + d_i with scaled compressor
    def ef21_run(steps, gamma):
        x = jnp.zeros(16)
        h = jnp.zeros((8, 16))
        traj = []
        for t in range(steps):
            g_i = grads(x)
            d = jax.vmap(lambda gg, hh: 0.7 * comp(None, gg - hh))(g_i, h)
            h = h + d
            g = jnp.mean(h, 0)
            x = x - gamma * g
            traj.append(x)
        return jnp.stack(traj)

    gamma = 0.05
    t_ref = ef21_run(20, gamma)
    x = jnp.zeros(16)
    st = a1.init(x, 8)
    traj = []
    for t in range(20):
        g, st = a1.step(jax.random.fold_in(KEY, t), grads(x), st)
        x = x - gamma * g
        traj.append(x)
    np.testing.assert_allclose(np.asarray(jnp.stack(traj)), np.asarray(t_ref),
                               rtol=1e-5, atol=1e-6)


def test_diana_equals_efbv_nu_one():
    """EF-BV with nu = 1 produces the EXACT DIANA iterates (Sect. 3.2)."""
    grads, *_ = quad_problem()
    comp = RandK(4)
    lam = 1.0 / (1.0 + comp.omega(16))
    a = EFBV(comp, lam=lam, nu=1.0)

    def diana_run(steps, gamma, key):
        x = jnp.zeros(16)
        h = jnp.zeros((8, 16))
        h_avg = jnp.zeros(16)
        traj = []
        for t in range(steps):
            kt = jax.random.fold_in(key, t)
            keys = jax.random.split(kt, 8)
            g_i = grads(x)
            # leaf index 0 fold matches EFBV.compress_delta's per-leaf key
            d = jax.vmap(lambda k, gg, hh: comp(jax.random.fold_in(k, 0), gg - hh)
                         )(keys, g_i, h)
            dbar = jnp.mean(d, 0)
            g = h_avg + dbar            # nu = 1
            h = h + lam * d
            h_avg = h_avg + lam * dbar
            x = x - gamma * g
            traj.append(x)
        return jnp.stack(traj)

    gamma = 0.02
    ref = diana_run(15, gamma, KEY)
    x = jnp.zeros(16)
    st = a.init(x, 8)
    traj = []
    for t in range(15):
        g, st = a.step(jax.random.fold_in(KEY, t), grads(x), st)
        x = x - gamma * g
        traj.append(x)
    np.testing.assert_allclose(np.asarray(jnp.stack(traj)), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def test_linear_convergence_at_theory_rate():
    """Theorem 1: the Lyapunov-bounded quantity f(x^t)-f* decays at least as
    fast as the proven rate."""
    grads, x_star, mu, L, Lt = quad_problem()
    comp = TopK(4)
    t = tune_for(comp, 16, n=8, mode="efbv", L=L, Ltilde=Lt, mu=mu)
    algo = EFBV(comp, lam=t.lam, nu=t.nu)
    steps = 2500
    res = run_reference(algo=algo, grad_fn=keyless(grads), x0=jnp.zeros(16),
                        gamma=t.gamma, steps=steps, key=KEY, n=8,
                        record=lambda x: jnp.sum((x - x_star) ** 2))
    final = float(res.metrics[-1])
    initial = float(jnp.sum(x_star**2))
    assert final < 1e-8 * initial, (final, initial)


def test_variance_reduction_h_tracks_gradients():
    """Control variates converge to nabla f_i(x*): the compressed messages
    C(grad - h) vanish, i.e. the method is variance-reduced."""
    grads, x_star, mu, L, Lt = quad_problem()
    comp = CompKK(2, 8)
    t = tune_for(comp, 16, n=8, mode="efbv", L=L, Ltilde=Lt)
    algo = EFBV(comp, lam=t.lam, nu=t.nu)
    ref = run_reference(algo=algo, grad_fn=keyless(grads), x0=jnp.zeros(16),
                        gamma=t.gamma, steps=8000, key=KEY, n=8)
    res = float(jnp.mean(jnp.sum((grads(ref.x) - ref.state.h) ** 2, -1)))
    assert res < 1e-6, res


def test_prox_operators():
    x = {"a": jnp.asarray([3.0, -0.5])}
    y = proximal_step(x, {"a": jnp.zeros(2)}, 1.0, prox_l1(1.0))
    np.testing.assert_allclose(np.asarray(y["a"]), [2.0, 0.0])
    y2 = proximal_step(x, {"a": jnp.zeros(2)}, 1.0, prox_l2(1.0))
    np.testing.assert_allclose(np.asarray(y2["a"]), [1.5, -0.25])


def test_logreg_efbv_beats_ef21_bits():
    """The paper's experimental claim (Sect. 6): with comp-(k, d/2) and many
    workers, EF-BV reaches lower loss than EF21 after the same number of
    rounds (same bits sent)."""
    d = 32
    A, b = make_synthetic(jax.random.key(2), N=600, d=d)
    prob = LogReg.split(A, b, n=50, mu_reg=0.1)
    _, fstar = prob.solve()
    comp = CompKK(1, d // 2)
    res = {}
    for mode in ["efbv", "ef21"]:
        t = tune_for(comp, d, prob.n, mode=mode, L=prob.L(),
                     Ltilde=prob.L_tilde())
        algo = EFBV(comp, lam=t.lam, nu=t.nu)
        m = run_reference(algo=algo, grad_fn=keyless(prob.grads),
                          x0=jnp.zeros(d), gamma=t.gamma, steps=4000, key=KEY,
                          n=prob.n, record=lambda x: prob.f(x) - fstar).metrics
        res[mode] = float(m[-1])
    assert res["efbv"] < res["ef21"], res


def test_bidirectional_compression_converges():
    """Beyond-paper: master-side broadcast compression (the Downlink
    channel, EF21-BC-style) on top of EF-BV still converges to the exact
    solution."""
    from repro.core import Downlink, TopK
    grads, x_star, mu, L, Lt = quad_problem()
    comp = TopK(4)
    t = tune_for(comp, 16, n=8, mode="efbv", L=L, Ltilde=Lt)
    algo = EFBV(comp, lam=t.lam, nu=t.nu)
    res = run_reference(
        algo=algo, downlink=Downlink(TopK(6)),
        grad_fn=keyless(grads), x0=jnp.zeros(16),
        gamma=t.gamma * 0.5,  # broadcast error feedback tolerates a smaller step
        steps=6000, key=KEY, n=8,
        record=lambda x: jnp.sum((x - x_star) ** 2))
    assert float(res.metrics[-1]) < 1e-7 * float(jnp.sum(x_star**2))
    # the workers' reconstruction has converged to the same point
    assert float(jnp.sum((res.w - x_star) ** 2)) < 1e-6 * float(jnp.sum(x_star**2))


def test_bidirectional_identity_downlink_is_bitwise_run():
    """Identity downlink + full participation reproduces the unidirectional
    trajectory BIT-FOR-BIT (the downlink assigns w = x verbatim and every
    key derivation is shared)."""
    from repro.core import Downlink, Identity
    grads, x_star, mu, L, Lt = quad_problem()
    comp = TopK(4)
    t = tune_for(comp, 16, n=8, mode="efbv", L=L, Ltilde=Lt)
    algo = EFBV(comp, lam=t.lam, nu=t.nu)
    kw = dict(algo=algo, grad_fn=keyless(grads), x0=jnp.zeros(16),
              gamma=t.gamma, steps=40, key=KEY, n=8,
              record=lambda x: jnp.sum((x - x_star) ** 2))
    uni = run_reference(**kw)
    bi = run_reference(downlink=Downlink(Identity()), **kw)
    np.testing.assert_array_equal(np.asarray(uni.metrics),
                                  np.asarray(bi.metrics))
    np.testing.assert_array_equal(np.asarray(bi.x), np.asarray(bi.w))
