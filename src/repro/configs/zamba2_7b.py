"""zamba2-7b [arXiv:2411.15242]: Mamba2 backbone + one *shared* attention
block applied every 6 layers (hybrid).

81 mamba2 layers, d3584 (d_inner 7168, 112 ssm heads of 64, state 64); the
shared block is 32-head MHA (kv=32) + SwiGLU ff=14336, vocab 32000.  Runs
long_500k with the recurrent mamba cache + sliding-window KV for the shared
attention block (DESIGN §6)."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
        d_ff=14336, vocab=32000, head_dim=112,
        ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=128,
        attn_every=6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab=1024, head_dim=32,
        ssm_state=16, ssm_head_dim=32, ssm_expand=2, ssm_conv=4, ssm_chunk=32,
        attn_every=2,
    )
