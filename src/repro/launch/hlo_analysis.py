"""Back-compat shim: roofline extraction moved to :mod:`repro.analysis.hlo`."""

from repro.analysis.hlo import (  # noqa: F401
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    Roofline,
    analyze,
    collective_bytes,
    memory_stats,
)
