"""Production mesh geometry.

Defined as FUNCTIONS so that importing this module never touches jax device
state (jax locks the device count on first backend init -- see
launch/dryrun.py which must set XLA_FLAGS before anything else).
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np


POD_CHIPS = 256  # one v5e pod slice: 16 x 16
DATA_AXIS = "data"
MODEL_AXIS = "model"
POD_AXIS = "pod"


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) ('data','model') single pod; (2,16,16) ('pod','data','model')
    across two pods."""
    from repro import compat

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Optional[Sequence[str]] = None):
    """Arbitrary mesh for tests/smoke runs; axes default to trailing names of
    ('pod','data','model'), so shapes with more than 3 dims need explicit
    axes."""
    from repro import compat

    if axes is None:
        defaults = ("pod", "data", "model")
        if len(shape) > len(defaults):
            # the trailing-names slice cannot grow past 3 axes; silently
            # recycling it would hand jax a short/duplicate axis tuple
            raise ValueError(
                f"make_mesh has default axis names for up to {len(defaults)} "
                f"mesh dims {defaults}, got shape {tuple(shape)} with "
                f"{len(shape)} dims -- pass axes= explicitly")
        axes = defaults[-len(shape):]
    return compat.make_mesh(tuple(shape), tuple(axes))


def worker_axes(mesh) -> Tuple[str, ...]:
    """The EF-BV 'worker' axes of a mesh = every axis except 'model'.

    The paper's n = product of these axis sizes."""
    return tuple(a for a in mesh.axis_names if a != MODEL_AXIS)


def num_workers(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in worker_axes(mesh)]))
