"""Shared benchmark utilities: the paper's experimental setup (Appendix C)
at configurable scale, timing helpers, CSV emission."""

from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp

from repro.core import CompKK, EFBV, run_reference, tune_for
from repro.problems import LogReg, make_synthetic

KEY = jax.random.key(0)

# synthetic stand-ins for the paper's LibSVM datasets (same d; N scaled down
# so the full figure reproduces in CPU-minutes; the theory constants -- Tab. 3
# -- depend only on d, k, k', n and reproduce exactly)
DATASETS = {
    "mushrooms": dict(N=2000, d=112),
    "phishing": dict(N=2000, d=68),
    "a9a": dict(N=2400, d=123),
    "w8a": dict(N=2400, d=300),
}


def make_problem(name: str, n: int, overlap: int = 1, mu: float = 0.1,
                 lam_nc: float = 0.0) -> LogReg:
    spec = DATASETS[name]
    A, b = make_synthetic(jax.random.fold_in(KEY, hash(name) % 2**31),
                          N=spec["N"], d=spec["d"])
    return LogReg.split(A, b, n=n, mu_reg=mu, overlap=overlap,
                        key=jax.random.key(1), lam_nc=lam_nc)


def run_algorithm(prob: LogReg, mode: str, k: int, steps: int,
                  fstar: float) -> jnp.ndarray:
    """One EF-BV/EF21/DIANA run with the paper's parametrization (Tab. 3);
    returns the f(x^t) - f* trajectory."""
    d = prob.d
    comp = CompKK(k, d // 2)
    t = tune_for(comp, d, prob.n, mode=mode, L=prob.L(), Ltilde=prob.L_tilde())
    algo = EFBV(comp, lam=t.lam, nu=t.nu)
    res = run_reference(algo=algo, grad_fn=lambda _k, x: prob.grads(x),
                        x0=jnp.zeros(d), gamma=t.gamma, steps=steps, key=KEY,
                        n=prob.n, record=lambda x: prob.f(x) - fstar)
    return res.metrics


def timeit(fn: Callable, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median microseconds per call (blocks on jax outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(rows: List[Dict]) -> None:
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', '')},{r.get('derived', '')}")
