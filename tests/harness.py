"""Differential oracle harness for the wire-codec pipeline.

One algorithm, several executions -- the harness runs the SAME EF-BV
recursion through each backend and asserts the trajectories are
*bit-identical*, not merely close:

    oracle     -- pure jnp (the codec spec),
    interpret  -- fused Pallas kernel, interpret mode (CPU),
    pallas     -- fused Pallas kernel, compiled (TPU only).

Because the kernels reproduce the oracles' f32 arithmetic op-for-op
(jax.lax.top_k's selection order for block-top-k, the SMEM index mask for
rand-k, the stochastic-rounding chain for QSGD), any divergence -- one ULP,
one swapped tie -- is a bug, and equality composes over steps: if round t is
bit-equal, round t+1 sees identical inputs.

There is ONE trajectory driver, :func:`run_trajectory`, taking a
:class:`repro.core.ExperimentSpec`: the spec's codec / participation /
downlink fields select the execution mode exactly as they do for
``repro.core.build``.  The historical legs -- ``run_codec_trajectory``
(any compressor through its codec), ``run_federated_trajectory``
(randomized per-round masks on top) and ``run_bidirectional_trajectory``
(compressed broadcast on the way back) -- are thin wrappers over the same
internal loop, kept so every existing pin still executes, and pinned
bit-identical to the spec-driven driver by tests/test_spec.py.
``run_wire_trajectory`` drives the raw block-sparse pack path;
test_distributed.py reuses run_with_devices for the 1-vs-8-fake-device leg.

:func:`run_tree_trajectory` is the pytree-native leg of the same
differential contract: the identical EF-BV recursion through a
:class:`repro.distributed.wire.TreeWire`, per-leaf, with no flat vector
ever materialized.  Driving it with the default single-leaf tree and the
same spec as :func:`run_trajectory` is pinned BIT-identical to the flat
path for every codec in the zoo; driving it with a genuinely nested tree
(mixed per-leaf codecs via ``spec.leaf_codecs``) pins
oracle == interpret == compiled and composed bits == sum of per-leaf bits.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import wire

Array = jax.Array


def available_pack_impls() -> List[str]:
    impls = ["oracle", "interpret"]
    if jax.default_backend() == "tpu":
        impls.append("pallas")
    return impls


def codec_impls(codec) -> List[str]:
    """Backends to differential-test for ``codec``: always the jnp oracle,
    plus the fused Pallas kernel (interpret; compiled on TPU) when the codec
    has one."""
    if not getattr(codec, "has_kernel", False):
        return ["oracle"]
    return available_pack_impls()


def quadratic_grads(n: int, d: int, seed: int = 0):
    """Per-worker gradient oracle of a strongly convex quadratic finite sum:
    grad_i(x) = Q_i x - b_i, returned as an (n, d) stack.  Same construction
    as repro.core.spec.Quadratic, so spec-driven reference runs and the
    harness draw identical gradients."""
    from repro.core.spec import Quadratic

    return Quadratic.make(n, d, seed).grads


def run_wire_trajectory(kernel: str, *, steps: int, n: int, d: int,
                        block: int, kb: int, lam: float, nu: float,
                        gamma: float, seed: int = 0) -> Dict[str, Array]:
    """EF-BV (Algorithm 1) over the sparse wire with the given pack backend.

    Every worker packs its innovation with wire.fused_pack(kernel=...), the
    master scatter-adds the stacked payload -- exactly the sparse_allgather
    data path.  Returns the full (x, h) trajectory plus the last round's
    payload so callers can check byte accounting.
    """
    lw = wire.LeafWire(shape=(d,), size=d, block=block, kb=kb)
    grad_fn = quadratic_grads(n, d, seed)

    x = jnp.zeros((d,), jnp.float32)
    h = jnp.zeros((n, d), jnp.float32)
    h_avg = jnp.zeros((d,), jnp.float32)
    xs, hs = [], []
    payload: Tuple[Array, Array] = None
    for _ in range(steps):
        g = grad_fn(x)
        vals_i, idx_i, h_i = [], [], []
        for i in range(n):
            (vals, idx), h_new = wire.fused_pack(lw, g[i], h[i], lam,
                                                 kernel=kernel)
            vals_i.append(vals)
            idx_i.append(idx)
            h_i.append(h_new)
        h = jnp.stack(h_i)
        payload = (jnp.stack(vals_i), jnp.stack(idx_i))
        d_bar = wire.scatter_add(lw, *payload) / n
        x = x - gamma * (h_avg + nu * d_bar)
        h_avg = h_avg + lam * d_bar
        xs.append(x)
        hs.append(h)
    return {"x": jnp.stack(xs), "h": jnp.stack(hs), "payload": payload,
            "lw": lw}


# ---------------------------------------------------------------------------
# the ONE codec trajectory: any uplink codec x any participation x any
# downlink, every pack backend
# ---------------------------------------------------------------------------

def _codec_trajectory(kernel: str, *, compressor, steps: int, n: int, d: int,
                      lam: float, nu: float, gamma: float,
                      participation=None, downlink=None, seed: int = 0,
                      wire_dtype: str = "float32",
                      pipeline_depth: int = 0) -> Dict[str, Array]:
    """The shared recursion behind every harness leg.

    Per round: kt = fold_in(key, t); an optional participation mask drawn
    from the shared participation_key derivation; every worker i encodes
    with fold_in(kt, i) through the requested pack backend (mask-gated to
    decode-zero + stale h_i when ``participation`` is given); the master
    decode-sums the stacked payload; and -- when ``downlink`` is given --
    ONE broadcast through the downlink codec (shared downlink_key) updates
    the reconstruction w that workers evaluate gradients at.  Each optional
    piece is absent from the computation entirely when not requested, so
    the specialized wrappers below reproduce their historical trajectories
    bit-for-bit.

    ``pipeline_depth=1`` runs the one-round-stale double-buffer schedule of
    the pipelined trainers (docs/algorithms.md#pipelined-rounds): the master
    consumes the PREVIOUS round's stacked payload (primed with the shared
    PIPELINE_FOLD zero-message) through the fixed-order chunked decode the
    trainers use, workers encode with the streaming kernel variant, and h_i
    advance on their own fresh messages.  Depth 0 leaves every historical
    trajectory bit-identical.
    """
    from repro.core.efbv import PIPELINE_FOLD, downlink_key, participation_key

    codec = wire.codec_of(compressor, (d,), d, wire_dtype)
    grad_fn = quadratic_grads(n, d, seed)
    key = jax.random.key(seed + 0xC0DEC)

    x = jnp.zeros((d,), jnp.float32)
    w = jnp.zeros((d,), jnp.float32)  # downlink.init(x0), x0 = 0
    h = jnp.zeros((n, d), jnp.float32)
    h_avg = jnp.zeros((d,), jnp.float32)
    pending = None
    if pipeline_depth:
        # the round-0 priming payload: same key fold as trainer.init_inflight
        # (leaf index 0 -- the harness drives one flat leaf)
        base = jax.random.fold_in(jax.random.key(0), PIPELINE_FOLD)
        zero = wire.zero_message(codec, jax.random.fold_in(base, 0))
        pending = jax.tree.map(
            lambda a: jnp.tile(a[None], (n,) + (1,) * a.ndim), zero)
        chunks = wire.pipeline_chunks(n)
    xs, ws, hs, masks = [], [], [], []
    payload = down_payload = None
    for t in range(steps):
        kt = jax.random.fold_in(key, t)
        mask = (jnp.ones((n,), jnp.float32) if participation is None
                else participation.sample_mask(participation_key(kt), n))
        g = grad_fn(w if downlink is not None else x)
        payloads, h_i = [], []
        for i in range(n):
            ki = jax.random.fold_in(kt, i)
            p, h_new = wire.encode_update(codec, ki, g[i], h[i], lam,
                                          kernel=kernel,
                                          stream=bool(pipeline_depth))
            if participation is not None:
                p = codec.mask_message(p, mask[i])
                h_new = jnp.where(mask[i] > 0, h_new, h[i])
            payloads.append(p)
            h_i.append(h_new)
        h = jnp.stack(h_i)
        payload = jax.tree.map(lambda *xs_: jnp.stack(xs_), *payloads)
        if pipeline_depth:
            # master consumes the in-flight round-(t-1) payload through the
            # trainers' fixed-order chunked decode; round t takes its slot
            d_bar = wire.chunked_decode_sum(codec, pending, chunks) / n
            pending = payload
        else:
            d_bar = codec.decode_sum(payload) / n
        x = x - gamma * (h_avg + nu * d_bar)
        h_avg = h_avg + lam * d_bar
        if downlink is not None:
            w, down_payload = downlink.broadcast(downlink_key(kt), x, w,
                                                 wire_dtype=wire_dtype)
            ws.append(w)
        xs.append(x)
        hs.append(h)
        masks.append(mask)

    fmt = wire.WireFormat((codec,))
    up_bits = (fmt.bits_per_round(n_workers=n) if participation is None
               else wire.federated_round_bits(fmt, masks[-1]))
    # down = the honest dense fp32 broadcast when no downlink codec is
    # configured -- the same convention as wire.total_round_bits and
    # Run.round_bits, so the two spec-driven surfaces agree
    down_bits = 32 * d
    out = {"x": jnp.stack(xs), "h": jnp.stack(hs), "payload": payload,
           "masks": jnp.stack(masks), "codec": codec}
    if pipeline_depth:
        out["pending"] = pending
    if downlink is not None:
        dfmt = downlink.format_for(jnp.zeros((d,)), wire_dtype=wire_dtype)
        down_bits = dfmt.downlink_bits_per_round()
        out.update({"w": jnp.stack(ws), "down_payload": down_payload,
                    "down_codec": dfmt.leaves[0]})
    out["round_bits"] = {"up": up_bits, "down": down_bits,
                         "total": up_bits + down_bits,
                         "dense_both_ways": 32 * d * n + 32 * d}
    return out


def run_trajectory(spec, kernel: str = "oracle", *,
                   lam: Optional[float] = None, nu: Optional[float] = None,
                   gamma: Optional[float] = None) -> Dict[str, Array]:
    """Spec-driven differential trajectory: ONE driver for every harness leg.

    ``spec`` is a :class:`repro.core.ExperimentSpec`; its compressor /
    participation / downlink / wire_dtype / pipeline / steps / n / d / seed
    fields select the execution mode (heterogeneous fleets are not a
    codec-level trajectory and are rejected).  ``lam``/``nu`` default to the spec's
    auto-tuning (Remark 1); ``gamma`` to ``spec.gamma``.  The historical
    legs below are wrappers over the same loop and bit-identical to this
    driver for equivalent arguments (pinned by tests/test_spec.py).
    """
    from repro.core import build

    if len(spec.fleet_specs()) > 1:
        raise ValueError("run_trajectory drives ONE codec; heterogeneous "
                         "fleets aggregate dense (see tests/test_bidirectional.py)")
    run = build(spec)
    if lam is None or nu is None:
        t = run.tuned
        if t is None:
            raise ValueError("mode='none' has no tuning; pass lam/nu")
        lam = t.lam if lam is None else lam
        nu = t.nu if nu is None else nu
    if gamma is None:
        if spec.gamma <= 0.0:
            raise ValueError("pass gamma= or set spec.gamma > 0")
        gamma = spec.gamma
    return _codec_trajectory(
        kernel, compressor=run.compressor, steps=spec.steps, n=spec.n,
        d=spec.d, lam=lam, nu=nu, gamma=gamma,
        participation=run.participation if run.federated else None,
        downlink=run.downlink, seed=spec.seed, wire_dtype=spec.wire_dtype,
        pipeline_depth=run.pipeline.depth)


def tree_quadratic_grads(n: int, tree, seed: int = 0):
    """Per-worker, per-leaf DIAGONAL quadratic gradient oracle for pytree
    trajectories: grad_i(x)_j = q_ij * x_j - b_ij with q_ij in [0.5, 1.5)
    and b_ij standard normal, drawn once from fold_in chains keyed by
    (leaf index j, worker i).  Strongly convex and deterministic like
    :func:`quadratic_grads`, but O(size) per leaf so it scales to real
    model trees (the dense (n, d, d) Quadratic cannot).  Returns
    ``grads(x) -> [pytree] * n``."""
    flat, treedef = jax.tree_util.tree_flatten(tree)
    base = jax.random.key(seed + 0x7E3E)
    q, b = [], []
    for j, leaf in enumerate(flat):
        kj = jax.random.fold_in(base, j)
        shape = jnp.shape(leaf)
        qi, bi = [], []
        for i in range(n):
            ki = jax.random.fold_in(kj, i)
            qi.append(0.5 + jax.random.uniform(jax.random.fold_in(ki, 0),
                                               shape, jnp.float32))
            bi.append(jax.random.normal(jax.random.fold_in(ki, 1),
                                        shape, jnp.float32))
        q.append(qi)
        b.append(bi)

    def grads(x):
        xl = treedef.flatten_up_to(x)
        return [jax.tree_util.tree_unflatten(
                    treedef,
                    [q[j][i] * xl[j] - b[j][i] for j in range(len(flat))])
                for i in range(n)]

    return grads


def run_tree_trajectory(spec, kernel: str = "oracle", *, tree=None,
                        lam: Optional[float] = None,
                        nu: Optional[float] = None,
                        gamma: Optional[float] = None) -> Dict[str, Array]:
    """Spec-driven differential trajectory over the PYTREE wire.

    The same EF-BV recursion as :func:`run_trajectory`, but every state
    (x, w, h_i, h_avg) is a pytree and every message crosses a
    :class:`repro.distributed.wire.TreeWire` -- per-leaf encode / decode-sum
    with the spec's ``leaf_codecs`` rules resolved (and clamped) per leaf,
    no flat vector ever materialized.

    ``tree=None`` (the default) drives the spec's flat (d,) problem as a
    SINGLE-LEAF pytree drawing the identical :func:`quadratic_grads`
    gradients with the identical per-worker keys, so the trajectory is
    BIT-identical to :func:`run_trajectory`'s for every codec in the zoo
    (tests/test_tree_wire.py pins it).  A nested ``tree`` (shapes/dtypes
    only; values are ignored) switches to the per-leaf
    :func:`tree_quadratic_grads` oracle and the trainers' per-leaf
    ``fold_in(key, j)`` key convention.

    Returns the stacked (x, h) trajectories (pytree leaves gain a leading
    ``steps`` axis; h also a worker axis), the last round's per-leaf
    payload list, the per-leaf bit accounting (``bits_by_leaf``; its sum
    is asserted equal to the composed ``round_bits['up']`` per worker by
    the tests), and the same ``round_bits`` dict as the flat driver.
    """
    from repro.core import build
    from repro.core.efbv import PIPELINE_FOLD, downlink_key, participation_key

    if len(spec.fleet_specs()) > 1:
        raise ValueError("run_tree_trajectory drives ONE codec tree; "
                         "heterogeneous fleets aggregate dense (see "
                         "tests/test_bidirectional.py)")
    run = build(spec)
    if lam is None or nu is None:
        t = run.tuned
        if t is None:
            raise ValueError("mode='none' has no tuning; pass lam/nu")
        lam = t.lam if lam is None else lam
        nu = t.nu if nu is None else nu
    if gamma is None:
        if spec.gamma <= 0.0:
            raise ValueError("pass gamma= or set spec.gamma > 0")
        gamma = spec.gamma
    n = spec.n
    flat_parity = tree is None
    if flat_parity:
        tree = jnp.zeros((spec.d,), jnp.float32)
        gf = quadratic_grads(n, spec.d, spec.seed)
        grad_fn = lambda xt: list(gf(xt))  # noqa: E731  (rows of the stack)
    else:
        grad_fn = tree_quadratic_grads(n, tree, spec.seed)
    fmt = wire.TreeWire.for_tree(run.compressor, tree,
                                 wire_dtype=spec.wire_dtype,
                                 rules=run.leaf_rules or ())
    participation = run.participation if run.federated else None
    downlink = run.downlink
    pipeline_depth = run.pipeline.depth
    size = sum(int(np.prod(jnp.shape(l)) or 1)
               for l in jax.tree_util.tree_leaves(tree))

    key = jax.random.key(spec.seed + 0xC0DEC)
    zero = jax.tree.map(lambda a: jnp.zeros(jnp.shape(a), jnp.float32), tree)
    x, w, h_avg = zero, zero, zero
    h = [zero for _ in range(n)]
    pending = None
    if pipeline_depth:
        # round-0 priming payloads: the same fold_in(key(0), PIPELINE_FOLD)
        # base as trainer.init_inflight, leaf j primed with fold_in(base, j)
        base = jax.random.fold_in(jax.random.key(0), PIPELINE_FOLD)
        pending = [jax.tree.map(
                       lambda a: jnp.tile(a[None], (n,) + (1,) * a.ndim), zm)
                   for zm in fmt.zero_messages(base)]
        chunks = wire.pipeline_chunks(n)
    xs, ws, hs, masks = [], [], [], []
    payload = down_payload = None
    for t_ in range(spec.steps):
        kt = jax.random.fold_in(key, t_)
        mask = (jnp.ones((n,), jnp.float32) if participation is None
                else participation.sample_mask(participation_key(kt), n))
        g = grad_fn(w if downlink is not None else x)
        payloads_i, h_i = [], []
        for i in range(n):
            ki = jax.random.fold_in(kt, i)
            # single-leaf flat parity: the leaf key IS the worker key (no
            # leaf fold), exactly the flat harness convention; nested trees
            # use the trainers' fold_in(ki, j) per leaf via TreeWire
            keys = (ki,) * len(fmt.leaves) if flat_parity else ki
            p, h_new = fmt.encode_update(keys, g[i], h[i], lam,
                                         kernel=kernel,
                                         stream=bool(pipeline_depth))
            if participation is not None:
                p = fmt.mask_messages(p, mask[i])
                h_new = jax.tree.map(
                    lambda a, b_: jnp.where(mask[i] > 0, a, b_), h_new, h[i])
            payloads_i.append(p)
            h_i.append(h_new)
        h = h_i
        payload = [jax.tree.map(lambda *a: jnp.stack(a),
                                *[pi[j] for pi in payloads_i])
                   for j in range(len(fmt.leaves))]
        if pipeline_depth:
            d_bar = jax.tree.map(lambda a: a / n,
                                 fmt.decode_sum(pending, chunks=chunks))
            pending = payload
        else:
            d_bar = jax.tree.map(lambda a: a / n, fmt.decode_sum(payload))
        x = jax.tree.map(lambda xj, hj, dj: xj - gamma * (hj + nu * dj),
                         x, h_avg, d_bar)
        h_avg = jax.tree.map(lambda hj, dj: hj + lam * dj, h_avg, d_bar)
        if downlink is not None:
            w, down_payload = downlink.broadcast(downlink_key(kt), x, w,
                                                 wire_dtype=spec.wire_dtype)
            ws.append(w)
        xs.append(x)
        hs.append(jax.tree.map(lambda *a: jnp.stack(a), *h))
        masks.append(mask)

    up_bits = (fmt.bits_per_round(n_workers=n) if participation is None
               else wire.federated_round_bits(fmt, masks[-1]))
    down_bits = 32 * size
    out = {"x": jax.tree.map(lambda *a: jnp.stack(a), *xs),
           "h": jax.tree.map(lambda *a: jnp.stack(a), *hs),
           "payload": payload, "masks": jnp.stack(masks), "fmt": fmt,
           "bits_by_leaf": fmt.bits_by_leaf()}
    if pipeline_depth:
        out["pending"] = pending
    if downlink is not None:
        dfmt = downlink.format_for(zero, wire_dtype=spec.wire_dtype)
        down_bits = dfmt.downlink_bits_per_round()
        out.update({"w": jax.tree.map(lambda *a: jnp.stack(a), *ws),
                    "down_payload": down_payload})
    out["round_bits"] = {"up": up_bits, "down": down_bits,
                         "total": up_bits + down_bits,
                         "dense_both_ways": 32 * size * n + 32 * size}
    return out


def run_codec_trajectory(kernel: str, *, compressor, steps: int, n: int,
                         d: int, lam: float, nu: float, gamma: float,
                         seed: int = 0, wire_dtype: str = "float32"
                         ) -> Dict[str, Array]:
    """EF-BV (Algorithm 1) over ANY compressor's declared wire codec
    (wrapper over :func:`run_trajectory`'s loop: full participation, no
    downlink).  Returns the (x, h) trajectory plus the last round's stacked
    payload for byte accounting."""
    return _codec_trajectory(kernel, compressor=compressor, steps=steps,
                             n=n, d=d, lam=lam, nu=nu, gamma=gamma,
                             seed=seed, wire_dtype=wire_dtype)


def run_federated_trajectory(kernel: str, *, compressor, steps: int, n: int,
                             d: int, lam: float, nu: float, gamma: float,
                             participation, seed: int = 0,
                             wire_dtype: str = "float32") -> Dict[str, Array]:
    """EF-BV over a compressor's wire codec under per-round client sampling
    (wrapper over :func:`run_trajectory`'s loop with mask gating: absent
    workers' payloads decode to zero, their h_i stay stale).  With an
    all-ones mask the trajectory is bit-identical to
    :func:`run_codec_trajectory`'s.  Returns the (x, h) trajectory, the
    per-round masks and the exact federated wire bits of the last round.
    """
    out = dict(_codec_trajectory(kernel, compressor=compressor, steps=steps,
                                 n=n, d=d, lam=lam, nu=nu, gamma=gamma,
                                 participation=participation, seed=seed,
                                 wire_dtype=wire_dtype))
    out["round_bits"] = out["round_bits"]["up"]  # historical: uplink int
    return out


def run_bidirectional_trajectory(kernel: str, *, compressor, downlink,
                                 steps: int, n: int, d: int, lam: float,
                                 nu: float, gamma: float, participation=None,
                                 seed: int = 0, wire_dtype: str = "float32"
                                 ) -> Dict[str, Array]:
    """EF-BV over a fully bidirectional wire: any uplink codec, any
    :class:`repro.core.efbv.Downlink` broadcast channel, optionally the
    federated execution mode on top (wrapper over :func:`run_trajectory`'s
    loop).  Workers evaluate gradients at the shared reconstruction ``w``;
    an Identity downlink assigns w = x verbatim, so identity-downlink +
    full-participation trajectories are BIT-IDENTICAL to
    run_codec_trajectory's (the PR-3 pinning).  Returns the (x, w, h)
    trajectories, the per-round masks (all-ones when full), the last
    round's payloads both ways, and the exact bit accounting of the last
    round: uplink, downlink, total, and the dense fp32 both-ways baseline.
    """
    return _codec_trajectory(kernel, compressor=compressor, steps=steps,
                             n=n, d=d, lam=lam, nu=nu, gamma=gamma,
                             participation=participation, downlink=downlink,
                             seed=seed, wire_dtype=wire_dtype)


def assert_bit_identical(a, b, context: str = ""):
    """Exact equality (values AND dtypes) across two pytrees of arrays."""
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), (context, len(la), len(lb))
    for x, y in zip(la, lb):
        assert jnp.asarray(x).dtype == jnp.asarray(y).dtype, \
            (context, x.dtype, y.dtype)
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=context)
