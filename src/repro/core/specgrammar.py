"""One grammar for every compressor-spec mini-language (docs/api.md).

Four spec syntaxes grew up around the ``'name[:a[,b]]'`` compressor atoms of
:func:`repro.core.compressors.make_compressor`, one per subsystem:

====================  =============================  ==========================
grammar               example                        composed from atoms by
====================  =============================  ==========================
fleet                 ``'topk:64;qsgd:16'``          ``';'``-separated atoms,
                                                     round-robin over n workers
leaf-codec rules      ``'*embed*=qsgd:16;topk:8'``   ``';'``-separated
                                                     ``pattern=atom`` entries
                                                     (bare atom == catch-all
                                                     pattern ``'*'``)
downlink              ``'sign@0.9'``                 atom ``'@'`` server
                                                     stepsize (default 1.0)
pipeline              ``'off'`` | ``'depth:1'``      double-buffer depth
====================  =============================  ==========================

This module is the single parser *and* printer for all four.  The historical
entry points -- :meth:`repro.core.efbv.Downlink.parse`,
:meth:`repro.core.efbv.Pipeline.parse`,
:func:`repro.core.compressors.make_fleet` and
:func:`repro.distributed.wire.parse_leaf_rules` -- are thin delegates into
the ``parse_*`` functions below, so error messages, parse results and hence
:class:`~repro.core.spec.ExperimentSpec` fingerprints are identical to the
per-module parsers they replace (pinned by tests/test_specgrammar.py).

``format_*`` is the lossless inverse: for every parseable spec string ``s``,
``parse_*(format_*(parse_*(s)))`` equals ``parse_*(s)`` exactly, and the
formatted string is the canonical spelling (aliases normalized -- ``'none'``
prints as ``'identity'`` -- whitespace dropped, default ``'@1.0'`` scalings
and ``'*='`` catch-all markers made explicit only where the grammar needs
them).  The compressor dataclasses are frozen with ``eq=True``, so the
round-trip equality is plain ``==``.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

from repro.core.contract import Compressor
from repro.core.compressors import (
    BlockTopK, CompKK, FracCompKK, FracTopK, Identity, MixKK, Natural, QSGD,
    RandK, ScaledRandK, SignNorm, TopK, expand_fleet, make_compressor,
)

__all__ = [
    "format_compressor", "format_downlink", "format_fleet",
    "format_leaf_rules", "format_pipeline", "parse_compressor",
    "parse_downlink", "parse_fleet", "parse_leaf_rules", "parse_pipeline",
]


# ---------------------------------------------------------------------------
# atoms: 'name[:a[,b]]'
# ---------------------------------------------------------------------------

def parse_compressor(spec: str) -> Compressor:
    """The atom parser (one zoo compressor); alias of
    :func:`repro.core.compressors.make_compressor`, re-exported here so the
    whole grammar is importable from one module."""
    return make_compressor(spec)


def _per_mille(frac: float) -> int:
    return int(round(frac * 1000.0))


def format_compressor(comp: Compressor) -> str:
    """Canonical atom spelling of a zoo compressor; the exact inverse of
    :func:`parse_compressor` (``parse(format(c)) == c`` for every compressor
    the atom grammar can produce).  Jointly-defined compressors (m-nice) have
    no spec spelling and are rejected."""
    if isinstance(comp, Identity):
        return "identity"
    if isinstance(comp, TopK):
        return f"topk:{comp.k}"
    if isinstance(comp, RandK):
        return f"randk:{comp.k}"
    if isinstance(comp, ScaledRandK):
        return f"scaled_randk:{comp.k}"
    if isinstance(comp, CompKK):
        return f"comp:{comp.k},{comp.kp}"
    if isinstance(comp, MixKK):
        return f"mix:{comp.k},{comp.kp}"
    if isinstance(comp, BlockTopK):
        return f"block_topk:{comp.block},{comp.kb}"
    if isinstance(comp, SignNorm):
        return "sign"
    if isinstance(comp, Natural):
        return "natural"
    if isinstance(comp, QSGD):
        return f"qsgd:{comp.s}"
    # fraction-style atoms spell per-mille integers ("frac_topk:50" = 5%)
    if isinstance(comp, FracCompKK):
        return f"frac_comp:{_per_mille(comp.frac)},{_per_mille(comp.fracp)}"
    if isinstance(comp, FracTopK):
        return f"frac_topk:{_per_mille(comp.frac)}"
    raise ValueError(f"compressor {comp!r} has no spec-string spelling")


# ---------------------------------------------------------------------------
# fleet: ';'-separated atoms assigned round-robin to n workers
# ---------------------------------------------------------------------------

def parse_fleet(spec: str, n: int) -> Tuple[Compressor, ...]:
    """';'-separated atoms -> length-n worker fleet (round-robin when the
    list is shorter than n, explicit when exactly n)."""
    members = tuple(make_compressor(s.strip())
                    for s in spec.split(";") if s.strip())
    return expand_fleet(members, n)


def format_fleet(members: Sequence[Compressor]) -> str:
    """Canonical fleet spelling: ``parse_fleet(format_fleet(f), len(f)) == f``."""
    return ";".join(format_compressor(c) for c in members)


# ---------------------------------------------------------------------------
# leaf-codec rules: ';'-separated 'pattern=atom' entries, first match wins
# ---------------------------------------------------------------------------

def parse_leaf_rules(spec: str) -> Tuple[Tuple[str, Compressor], ...]:
    """';'-separated ``pattern=compressor_spec`` entries -> (pattern,
    Compressor) rules; a bare atom (no '=') is the catch-all rule with
    pattern ``'*'``.  Jointly-defined compressors (m-nice) are rejected:
    their draws couple all workers, not leaves."""
    rules = []
    for entry in spec.split(";"):
        entry = entry.strip()
        if not entry:
            continue
        if "=" in entry:
            pat, _, comp_spec = entry.partition("=")
            pat, comp_spec = pat.strip(), comp_spec.strip()
            if not pat or not comp_spec:
                raise ValueError(
                    f"leaf-codec rule {entry!r} needs both a leaf-path "
                    "pattern and a compressor spec around the '='")
        else:
            pat, comp_spec = "*", entry
        comp = make_compressor(comp_spec)
        if getattr(comp, "joint", False):
            raise ValueError(
                "jointly-defined compressors (m-nice) cannot be leaf-codec "
                "rules: their draws couple all workers")
        rules.append((pat, comp))
    return tuple(rules)


def format_leaf_rules(rules: Sequence[Tuple[str, Compressor]]) -> str:
    """Canonical rule spelling (every pattern explicit, incl. '*'):
    ``parse_leaf_rules(format_leaf_rules(r)) == r``."""
    return ";".join(f"{pat}={format_compressor(c)}" for pat, c in rules)


# ---------------------------------------------------------------------------
# downlink: atom '@' server stepsize
# ---------------------------------------------------------------------------

def parse_downlink(spec: str) -> Optional[Tuple[Compressor, float]]:
    """``'' | 'none'`` -> None (uncompressed dense broadcast); otherwise an
    atom with an optional ``'@lam'`` downlink scaling -> ``(compressor,
    lam)``.  The Downlink dataclass itself lives in repro.core.efbv; its
    ``parse`` wraps this pair."""
    if not spec or spec == "none":
        return None
    comp_spec, _, lam_s = spec.partition("@")
    return make_compressor(comp_spec), float(lam_s) if lam_s else 1.0


def format_downlink(downlink: Any) -> str:
    """Canonical downlink spelling of None, a ``(compressor, lam)`` pair or
    any object with ``.compressor`` / ``.lam`` (i.e. a Downlink): the
    default scaling 1.0 is omitted, so ``format(parse(s))`` re-parses to
    the same pair."""
    if downlink is None:
        return "none"
    if isinstance(downlink, tuple):
        comp, lam = downlink
    else:
        comp, lam = downlink.compressor, downlink.lam
    atom = format_compressor(comp)
    return atom if lam == 1.0 else f"{atom}@{lam!r}"


# ---------------------------------------------------------------------------
# pipeline: 'off' | 'depth:k'
# ---------------------------------------------------------------------------

def parse_pipeline(spec: str) -> int:
    """``'' | 'off' | 'depth:k'`` -> the double-buffer depth as an int.  The
    Pipeline dataclass (repro.core.efbv) wraps the depth and enforces the
    implemented range; this function only speaks the grammar, so 'depth:7'
    parses here and is rejected by the dataclass."""
    if not spec or spec == "off":
        return 0
    name, _, arg = spec.partition(":")
    if name == "depth" and arg:
        try:
            return int(arg)
        except ValueError:
            raise ValueError(f"pipeline spec {spec!r} (want off | "
                             "depth:0 | depth:1)") from None
    raise ValueError(f"pipeline spec {spec!r} (want off | depth:0 | "
                     "depth:1)")


def format_pipeline(pipeline: Any) -> str:
    """Canonical pipeline spelling of an int depth or any object with a
    ``.depth`` (i.e. a Pipeline): depth 0 prints as 'off'."""
    depth = pipeline if isinstance(pipeline, int) else pipeline.depth
    return "off" if depth == 0 else f"depth:{depth}"
