"""The lint framework: rule registry, suppressions, runner, reports.

A *rule* is a function from a parsed :class:`Module` to a list of
:class:`Finding`\\ s, registered under a stable kebab-case name via the
:func:`rule` decorator.  The runner parses every ``.py`` file under the
given paths once, hands the module to each registered rule, then applies
per-line suppressions:

    x = jnp.dot(a, b)  # repro: noqa(low-precision-accumulation)

A suppression silences exactly the named rule on exactly that line --
and an *unused* suppression (no finding of that rule on that line) is
itself reported as ``unused-suppression``, so stale noqa comments cannot
accumulate after the underlying code is fixed.

Findings are reported human-readable (``path:line:col: rule: message``)
or as JSON (``--json``); per-rule finding/suppression counts can be
pinned against a committed golden file (``--golden``) so any drift in
the analyzer or the tree shows up as a diff, not a vibe.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import json
import re
import tokenize
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Tuple

#: pseudo-rule name for stale suppression comments (always active; not a
#: registered rule -- it cannot itself be suppressed)
UNUSED_SUPPRESSION = "unused-suppression"

_NOQA_RE = re.compile(r"#\s*repro:\s*noqa\(([^)]*)\)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic: a rule firing at a source location."""

    rule: str
    path: str
    line: int
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule}: {self.message}"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Module:
    """A parsed source file, as handed to every rule."""

    path: Path
    src: str
    tree: ast.Module

    @property
    def parts(self) -> Tuple[str, ...]:
        return self.path.parts

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        return Finding(rule=rule, path=str(self.path),
                       line=getattr(node, "lineno", 1),
                       col=getattr(node, "col_offset", 0) + 1,
                       message=message)


@dataclasses.dataclass(frozen=True)
class Rule:
    name: str
    doc: str
    check: Callable[[Module], List[Finding]]


#: the registry: rule name -> Rule.  Populated by importing repro.analysis
#: .rules (the @rule decorator); the runner iterates this.
RULES: Dict[str, Rule] = {}


def rule(name: str, doc: str):
    """Register ``fn(module) -> [Finding]`` under ``name``."""
    def deco(fn):
        if name in RULES:
            raise ValueError(f"duplicate rule name {name!r}")
        RULES[name] = Rule(name=name, doc=doc, check=fn)
        return fn
    return deco


def parse_suppressions(src: str) -> Dict[int, Dict[str, bool]]:
    """line -> {rule_name: used_flag} from ``repro: noqa`` comments.

    Tokenized, not line-scanned: a noqa spelled inside a string literal or
    docstring (e.g. documentation showing the syntax) is not a suppression.
    """
    out: Dict[int, Dict[str, bool]] = {}
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(src).readline))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        return out  # analyze_file reports the parse error separately
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _NOQA_RE.search(tok.string)
        if not m:
            continue
        names = [n.strip() for n in m.group(1).split(",") if n.strip()]
        if names:
            out[tok.start[0]] = {n: False for n in names}
    return out


@dataclasses.dataclass
class AnalysisResult:
    findings: List[Finding]
    suppressed: List[Finding]
    files: int
    errors: List[Finding]

    def counts(self) -> dict:
        """The golden-file payload: per-rule finding + suppression counts."""
        def tally(fs: Iterable[Finding]) -> Dict[str, int]:
            out: Dict[str, int] = {}
            for f in fs:
                out[f.rule] = out.get(f.rule, 0) + 1
            return dict(sorted(out.items()))
        return {"files": self.files,
                "rules": sorted(RULES),
                "findings": tally(self.findings + self.errors),
                "suppressions": tally(self.suppressed)}

    def as_dict(self) -> dict:
        return {**self.counts(),
                "details": [f.as_dict() for f in self.findings + self.errors]}


def iter_py_files(paths: Iterable[str]) -> List[Path]:
    files: List[Path] = []
    for a in paths:
        p = Path(a)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py" and p.exists():
            files.append(p)
    # dedupe while keeping order (overlapping path args)
    seen = set()
    out = []
    for f in files:
        if f not in seen:
            seen.add(f)
            out.append(f)
    return out


def analyze_file(path: Path, rules: Optional[Dict[str, Rule]] = None
                 ) -> Tuple[List[Finding], List[Finding], List[Finding]]:
    """-> (findings, suppressed findings, parse errors) for one file."""
    rules = RULES if rules is None else rules
    src = path.read_text()
    try:
        tree = ast.parse(src, filename=str(path))
    except SyntaxError as e:
        return [Finding(rule="parse-error", path=str(path),
                        line=e.lineno or 1, col=(e.offset or 0) + 1,
                        message=f"syntax error: {e.msg}")], [], []
    mod = Module(path=path, src=src, tree=tree)
    noqa = parse_suppressions(src)
    kept: List[Finding] = []
    suppressed: List[Finding] = []
    for r in rules.values():
        for f in r.check(mod):
            line_noqa = noqa.get(f.line)
            if line_noqa is not None and f.rule in line_noqa:
                line_noqa[f.rule] = True
                suppressed.append(f)
            else:
                kept.append(f)
    unused: List[Finding] = []
    for line, names in sorted(noqa.items()):
        for name, used in names.items():
            if name not in rules and name not in RULES:
                unused.append(Finding(
                    rule=UNUSED_SUPPRESSION, path=str(path), line=line, col=1,
                    message=f"noqa names unknown rule {name!r} "
                            f"(known: {', '.join(sorted(RULES))})"))
            elif not used:
                unused.append(Finding(
                    rule=UNUSED_SUPPRESSION, path=str(path), line=line, col=1,
                    message=f"suppression of {name!r} matches no finding on "
                            "this line -- delete the stale noqa"))
    kept.extend(unused)
    kept.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return kept, suppressed, []


def analyze_paths(paths: Iterable[str],
                  rules: Optional[Dict[str, Rule]] = None) -> AnalysisResult:
    files = iter_py_files(paths)
    findings: List[Finding] = []
    suppressed: List[Finding] = []
    errors: List[Finding] = []
    for f in files:
        kept, sup, err = analyze_file(f, rules)
        findings.extend(kept)
        suppressed.extend(sup)
        errors.extend(err)
    return AnalysisResult(findings=findings, suppressed=suppressed,
                          files=len(files), errors=errors)


def compare_golden(result: AnalysisResult, golden_path: str) -> List[str]:
    """Differences between a fresh run and the committed golden counts."""
    try:
        golden = json.loads(Path(golden_path).read_text())
    except (OSError, ValueError) as e:
        return [f"golden file {golden_path}: unreadable ({e})"]
    fresh = result.counts()
    diffs = []
    for key in ("files", "rules", "findings", "suppressions"):
        if golden.get(key) != fresh.get(key):
            diffs.append(f"golden {key} = {golden.get(key)!r} but fresh run "
                         f"has {fresh.get(key)!r}")
    return diffs


def write_golden(result: AnalysisResult, golden_path: str) -> None:
    Path(golden_path).write_text(
        json.dumps(result.counts(), indent=1, sort_keys=True) + "\n")
