"""Compressor micro-benchmarks (us/call on this host) incl. the Pallas
block-top-k kernel (interpret mode on CPU) vs its XLA oracle, and the
packed-vs-dense wire pipeline comparison (one HBM pass, proven from the
TPU-lowered HLO)."""

from __future__ import annotations

import functools
import re

import jax
import jax.numpy as jnp

from benchmarks.common import KEY, timeit
from repro.core import BlockTopK, CompKK, Natural, QSGD, RandK, TopK
from repro.distributed import wire
from repro.kernels import ops, ref


def run(fast: bool = True):
    d = 1 << 16
    x = jax.random.normal(KEY, (d,))
    rows = []
    cases = [
        ("topk_1pc", jax.jit(lambda k, v: TopK(d // 100)(k, v))),
        ("randk_1pc", jax.jit(lambda k, v: RandK(d // 100)(k, v))),
        ("comp_k_kp", jax.jit(lambda k, v: CompKK(d // 100, d // 2)(k, v))),
        ("block_topk_core", jax.jit(lambda k, v: BlockTopK(1024, 16)(k, v))),
        ("natural", jax.jit(lambda k, v: Natural()(k, v))),
        ("qsgd_s16", jax.jit(lambda k, v: QSGD(16)(k, v))),
        ("block_topk_ref", jax.jit(lambda k, v: ref.block_topk_ref(v, 1024, 16))),
    ]
    iters = 5 if fast else 30
    for name, fn in cases:
        us = timeit(fn, KEY, x, iters=iters)
        rows.append({"name": f"compressor/{name}", "us_per_call": f"{us:.1f}",
                     "derived": f"d={d}"})
    # pallas kernel (interpret on CPU -- not a speed claim, a parity check)
    us = timeit(lambda v: ops.block_topk(v, block=1024, kb=16), x, iters=3)
    rows.append({"name": "compressor/block_topk_pallas_interpret",
                 "us_per_call": f"{us:.1f}", "derived": "interpret=True"})
    rows.extend(packed_vs_dense(fast=fast))
    return rows


# ---------------------------------------------------------------------------
# packed vs dense wire pipeline
# ---------------------------------------------------------------------------

def _custom_call_result_types(mlir_text: str):
    """Result tensor types of the (single) tpu_custom_call in an exported
    module, e.g. ['tensor<64x16xf32>', 'tensor<64x16xi32>', ...]."""
    line = next(l for l in mlir_text.splitlines() if "tpu_custom_call" in l)
    tail = re.compile(r"->\s*\(([^()]*)\)(?:\s*loc\([^)]*\))?\s*$")
    single = re.compile(r"->\s*(tensor<[^\s,]+>)(?:\s*loc\([^)]*\))?\s*$")
    m = tail.search(line) or single.search(line)
    if m is None:
        return []
    return [t.strip() for t in m.group(1).split(",") if t.strip()]


def fused_pack_hlo_report(nb: int = 64, block: int = 256, kb: int = 16):
    """Prove the one-HBM-pass claim from the LOWERED HLO: the fused pack
    kernel's TPU custom call must emit only (values, indices, h_out) -- the
    dense d never reaches HBM -- while the unfused dense kernel's whole
    RESULT is the dense d, which pack/update then re-read.

    Mosaic lowering is AOT (jax.export with platforms=['tpu']), so this runs
    on CPU-only hosts too.
    """
    from jax import export as jexport
    from repro.kernels.block_topk import block_topk_pallas
    from repro.kernels.pack import pack_update_pallas

    sds = jax.ShapeDtypeStruct((nb, block), jnp.float32)
    fused = jax.jit(functools.partial(pack_update_pallas, lam=0.9, kb=kb,
                                      interpret=False))
    fused_res = _custom_call_result_types(
        jexport.export(fused, platforms=["tpu"])(sds, sds).mlir_module())
    unfused = jax.jit(lambda g: block_topk_pallas(g, kb, interpret=False))
    unfused_res = _custom_call_result_types(
        jexport.export(unfused, platforms=["tpu"])(sds).mlir_module())

    dense_ty = f"tensor<{nb}x{block}xf32>"
    payload_tys = {f"tensor<{nb}x{kb}xf32>", f"tensor<{nb}x{kb}xi32>"}
    report = {
        # exactly one dense output (h_out) and the packed payload: d is
        # never materialized in HBM
        "fused_one_hbm_pass": (fused_res.count(dense_ty) == 1
                               and payload_tys.issubset(set(fused_res))),
        "fused_outputs": fused_res,
        # the unfused kernel's output IS the dense d
        "unfused_dense_output": unfused_res.count(dense_ty) == 1,
    }
    return report


def packed_vs_dense(fast: bool = True):
    """us/call of the fused compress-and-pack pipeline vs the unfused
    (dense-compress, then pack, then h-update) one, plus exact wire bytes."""
    d, block, kb = 1 << 16, 1024, 16
    lw = wire.LeafWire(shape=(d,), size=d, block=block, kb=kb)
    g = jax.random.normal(KEY, (d,))
    h = jax.random.normal(jax.random.key(1), (d,))
    lam = 0.9
    comp = BlockTopK(block, kb)

    @jax.jit
    def unfused(g, h):
        delta = g - h                                   # HBM pass 1
        dns = comp(None, delta).reshape(-1)             # dense d: pass 2
        vals, idx = comp.encode(None, delta)            # re-read: pass 3
        return (vals, idx), h + lam * dns               # h update: pass 4

    fused = jax.jit(lambda g, h: wire.fused_pack(lw, g, h, lam))

    iters = 5 if fast else 30
    rows = []
    us_u = timeit(unfused, g, h, iters=iters)
    us_f = timeit(fused, g, h, iters=iters)
    fmt = wire.WireFormat((lw,))
    rows.append({"name": "wire/unfused_compress_pack", "us_per_call": f"{us_u:.1f}",
                 "derived": f"d={d} dense_d_materialized=True"})
    rows.append({"name": "wire/fused_pack", "us_per_call": f"{us_f:.1f}",
                 "derived": f"d={d} payload_bits={fmt.bits_per_round()}"})

    try:
        rep = fused_pack_hlo_report()
        rows.append({"name": "wire/fused_pack_hlo",
                     "us_per_call": "",
                     "derived": f"one_hbm_pass={rep['fused_one_hbm_pass']} "
                                f"unfused_dense_output={rep['unfused_dense_output']}"})
    except Exception as e:  # jax.export unavailable on some versions
        rows.append({"name": "wire/fused_pack_hlo", "us_per_call": "",
                     "derived": f"skipped ({type(e).__name__})"})
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(fast=True))
