"""Replica-fleet serving benchmark: compressed delta pushes vs shipping
full checkpoints.

    PYTHONPATH=src:. python -m benchmarks.serve_fleet \
        [--spec examples/specs/serve_delta.json] [--json]

Drives :func:`repro.launch.serve.run_fleet` for a committed spec with a
``serve`` leg: a trainer pushes versioned compressed deltas
(``Downlink.encode_push``) while N simulated replicas decode continuously
and hot-swap between steps.  The fleet invariant -- every replica's w
bit-identical to the trainer's after every push -- is asserted inside the
driver, so a wire/codec regression fails the bench rather than skewing it.

Reported metrics split into the exact and the measured:

* delta_bits_per_push / checkpoint_bits_per_push / push_ratio -- exact
  envelope accounting (``wire.push_bits`` vs ``wire.checkpoint_push_bits``
  on the model's real parameter tree), machine-independent; the
  BENCH_bits.json `serve_delta` table records these.
* tok_per_s, swap_ms_max, stage_ms_max -- measured on this host (a
  trajectory within one runner class); the BENCH_perf.json `serve_fleet`
  row records these, keyed by the spec fingerprint.
"""

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse     # noqa: E402
import json         # noqa: E402
import tempfile     # noqa: E402

DEFAULT_SPEC = os.path.join(os.path.dirname(__file__), os.pardir,
                            "examples", "specs", "serve_delta.json")


def fleet_metrics(spec_path: str = DEFAULT_SPEC, *, ckpt_dir=None,
                  quiet: bool = True):
    """Run the fleet for a spec file; returns ``(spec, metrics)``.  A
    temporary checkpoint directory (the replicas' resync source) is used
    unless ``ckpt_dir`` is given."""
    from repro.core import ExperimentSpec
    from repro.launch.serve import run_fleet

    with open(spec_path) as f:
        spec = ExperimentSpec.from_json(f.read())
    if ckpt_dir is not None:
        return spec, run_fleet(spec, ckpt_dir=ckpt_dir, quiet=quiet)
    with tempfile.TemporaryDirectory() as tmp:
        return spec, run_fleet(spec, ckpt_dir=tmp, quiet=quiet)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec", default=DEFAULT_SPEC)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--json", action="store_true",
                    help="print the metrics dict as JSON")
    args = ap.parse_args(argv)

    spec, m = fleet_metrics(args.spec, ckpt_dir=args.ckpt_dir, quiet=False)
    if args.json:
        print(json.dumps(m, indent=1, sort_keys=True))
    else:
        print(f"[serve-fleet] spec {m['fingerprint']}: "
              f"{m['replicas']} replicas x {m['pushes']} pushes, "
              f"{m['requests']} requests ({m['tokens']} tokens) at "
              f"{m['tok_per_s']:.1f} tok/s")
        print(f"[serve-fleet] delta push {m['delta_bits_per_push']} bits vs "
              f"checkpoint {m['checkpoint_bits_per_push']} bits "
              f"({m['push_ratio']:.3f}x); hot-swap "
              f"{m['swap_ms_max']:.3f} ms max "
              f"(stage {m['stage_ms_max']:.3f} ms off the serving path)")
    return m


if __name__ == "__main__":
    main()
