"""End-to-end driver: train a ~100M-parameter LM with EF-BV compressed
gradient aggregation on a data x model mesh.

    # few-hundred-step run (~100M params; several hours of CPU -- this is the
    # deployment-shaped entry point; on TPU the same command runs per pod):
    PYTHONPATH=src python examples/train_lm.py

    # quick demo (~8M params, minutes on CPU):
    PYTHONPATH=src python examples/train_lm.py --tiny

Everything routes through repro.launch.train: the EF-BV layer (block-top-k
compressor, sparse all-gather wire), the WSD/cosine schedules, synthetic
heterogeneous LM data, and npz checkpointing.
"""

import argparse
import dataclasses
import math
import os
import sys

sys.path.insert(0, "src")

# force enough XLA host devices for the mesh BEFORE jax initializes
if "XLA_FLAGS" not in os.environ:
    _mesh = "4x1"
    if "--mesh" in sys.argv:
        _mesh = sys.argv[sys.argv.index("--mesh") + 1]
    _n = math.prod(int(x) for x in _mesh.split("x"))
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={_n}"

from repro.configs import get_smoke_config  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402


def lm100m() -> ModelConfig:
    """~100M-param llama-style config (qwen2-family reduced)."""
    return ModelConfig(
        name="lm100m", family="dense",
        n_layers=12, d_model=512, n_heads=8, n_kv_heads=2,
        d_ff=2048, vocab=32768, head_dim=64,
        qkv_bias=True, tie_embeddings=True,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true", help="~8M params demo")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--mesh", default="4x1")
    args = ap.parse_args()

    # register the 100M config under a patched smoke lookup, then delegate to
    # the production driver
    import repro.launch.train as T
    cfg = lm100m()
    steps = args.steps or (300 if not args.tiny else 60)
    if args.tiny:
        cfg = dataclasses.replace(cfg, n_layers=4, d_model=256, d_ff=1024,
                                  vocab=4096, name="lm8m")

    orig = T.get_smoke_config
    T.get_smoke_config = lambda name: cfg  # the driver sees our config
    try:
        T.main(["--arch", "qwen2-0.5b", "--smoke", "--mesh", args.mesh,
                "--steps", str(steps), "--global-batch", "16", "--seq", "256",
                "--lr", "1e-3", "--algo", "efbv",
                "--compressor", "block_topk:1024,64",
                "--agg", "sparse_allgather", "--log-every", "10",
                "--ckpt-dir", "/tmp/lm100m_ckpt", "--ckpt-every", "100"])
    finally:
        T.get_smoke_config = orig


if __name__ == "__main__":
    main()
