"""End-to-end training driver.

Example (CPU, reduced config):

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
        --mesh 2x2 --steps 50 --compressor block_topk:256,16 --algo efbv

On a real cluster the same entry point takes --arch <id> (full config) and
--mesh 16x16 / 2x16x16.

Every algorithmic knob is ONE declarative object: the flag namespace is
folded into a :class:`repro.core.ExperimentSpec` (:func:`spec_from_args`)
and the whole run -- EF-BV tuning, trainer dispatch (shard_map vs FSDP),
federated sampling, bidirectional downlink, wire accounting -- is built via
``repro.core.build(spec)``.  ``--spec path.json`` loads a serialized spec
instead (the individual algorithmic flags are then ignored); the spec JSON
+ fingerprint are embedded in every checkpoint, so a mismatched resume is
refused.  See docs/api.md.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time

# On CPU hosts, force enough XLA host devices for the requested mesh BEFORE
# jax initializes (same constraint as launch/dryrun.py).  The mesh comes
# from --mesh, or -- for spec-driven runs -- from the --spec file itself.


def _mesh_from_argv(argv):
    try:
        if "--mesh" in argv:
            return argv[argv.index("--mesh") + 1]
        for i, a in enumerate(argv):
            if a == "--spec" or a.startswith("--spec="):
                path = a.split("=", 1)[1] if "=" in a else argv[i + 1]
                with open(path) as f:
                    return json.load(f).get("mesh", "")
    except (IndexError, OSError, ValueError):
        pass  # malformed argv / unreadable spec: argparse or main() reports
    return ""


if "XLA_FLAGS" not in os.environ:
    _shape = _mesh_from_argv(sys.argv)
    if _shape:
        _n = math.prod(int(x) for x in _shape.split("x"))
        if _n > 1:
            os.environ["XLA_FLAGS"] = \
                f"--xla_force_host_platform_device_count={_n}"

import jax
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import ARCHS, get_config, get_smoke_config
from repro.core import ExperimentSpec, SpecError, build
from repro.data import SyntheticLM, make_batch_shardings
from repro.launch.mesh import make_mesh, num_workers
from repro.models import build_model
from repro.optim import adamw, cosine, wsd


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec", default="",
                    help="path to an ExperimentSpec JSON: the declarative "
                         "form of every algorithmic flag below (which are "
                         "then ignored); see docs/api.md and examples/specs/")
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ARCHS)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--mesh", default="2x2", help="e.g. 2x2, 16x16, 2x16x16")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="auto", choices=["auto", "cosine", "wsd"])
    ap.add_argument("--algo", default="efbv", choices=["efbv", "ef21", "diana", "none"])
    ap.add_argument("--compressor", default="block_topk:256,16")
    ap.add_argument("--agg", default="dense_psum",
                    choices=["dense_psum", "sparse_allgather"])
    ap.add_argument("--wire-dtype", default="float32",
                    choices=["float32", "bfloat16", "float16"],
                    help="value precision of sparse/dense wire payloads "
                         "(quantized and bit-packed codecs ignore it)")
    ap.add_argument("--downlink", default="",
                    help="compressor spec for the master->worker model "
                         "broadcast (bidirectional compression through the "
                         "spec's wire codec, e.g. 'qsgd:16' or "
                         "'block_topk:256,16', optionally '@lam'); empty = "
                         "uncompressed dense broadcast")
    ap.add_argument("--worker-comps", default="",
                    help="heterogeneous fleet: ';'-separated compressor "
                         "specs assigned round-robin to the n workers (or "
                         "an explicit length-n list), e.g. "
                         "'topk:64;randk:64;qsgd:16'.  Overrides "
                         "--compressor; mixed fleets need --agg dense_psum")
    ap.add_argument("--participation", default="full",
                    help="per-round client sampling: full | bernoulli:p | "
                         "fixed:s (federated execution mode; absent workers "
                         "keep stale control variates)")
    ap.add_argument("--local-batch-resample", action="store_true",
                    help="stochastic local gradients: resample each worker's "
                         "minibatch from a FIXED local shard every round "
                         "instead of streaming fresh data")
    ap.add_argument("--shard-size", type=int, default=64,
                    help="sequences per worker shard for "
                         "--local-batch-resample")
    ap.add_argument("--leaf-codecs", default="",
                    help="per-leaf wire codecs: ';'-separated "
                         "'pattern=comp_spec' rules matched against "
                         "'/'-joined parameter paths (fnmatch; first match "
                         "wins; unmatched leaves use --compressor), e.g. "
                         "'*embed*=qsgd:16;*norm*=identity'.  With --spec, "
                         "a non-default value overrides the spec's "
                         "leaf_codecs field")
    ap.add_argument("--pipeline", default="off",
                    help="execution schedule: off | depth:1 (double-buffer "
                         "the compressed payload; the master applies round "
                         "t-1's message while round t's is on the wire).  "
                         "With --spec, a non-default value overrides the "
                         "spec's pipeline field")
    ap.add_argument("--trainer", default="shard_map",
                    choices=["shard_map", "fsdp"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--heterogeneity", type=float, default=0.5)
    ap.add_argument("--sanitize", action="store_true",
                    help="debug run: jax_debug_nans + Pallas interpret mode "
                         "with out-of-bounds checking "
                         "(repro.analysis.sanitize; see make sanitize-smoke)")
    return ap.parse_args(argv)


def tuning_dim(cfg) -> int:
    """THE tuning dimension of an arch: its dominant layer size.  Shared by
    spec_from_args and the CI bench's spec keying, so the fingerprint the
    driver embeds and the one the bench rows carry can never drift."""
    return max(cfg.d_model * max(cfg.d_ff, 1), 1)


def spec_from_args(args, n: int) -> ExperimentSpec:
    """Fold the driver's flag namespace into the declarative spec (the
    runtime-only knobs -- batch/seq/lr/schedule/ckpt/logging -- stay flags).

    The tuning dimension d is the arch's dominant layer size, computed from
    the config the run actually uses (smoke or full), so the spec is
    self-contained: re-running it reproduces the identical (lam, nu)."""
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    return ExperimentSpec(
        compressor=args.worker_comps if args.worker_comps else args.compressor,
        mode=args.algo,
        agg=args.agg,
        wire_dtype=args.wire_dtype,
        downlink=args.downlink,
        participation=args.participation,
        resample=args.local_batch_resample,
        backend="fsdp" if args.trainer == "fsdp" else "shard_map",
        problem=args.arch,
        smoke=args.smoke,
        mesh=args.mesh,
        n=n,
        d=tuning_dim(cfg),
        steps=args.steps,
        seed=args.seed,
        pipeline=args.pipeline,
        leaf_codecs=args.leaf_codecs,
    )


def main(argv=None):
    args = parse_args(argv)
    if args.sanitize:
        from repro.analysis import sanitize

        sanitize.enable()
        print("[train] sanitize mode: jax_debug_nans + Pallas interpret")
    try:
        if args.spec:
            with open(args.spec) as f:
                spec = ExperimentSpec.from_json(f.read())
            if args.smoke and not spec.smoke:
                # --smoke changes the MODEL (reduced config), so it is part
                # of the experiment identity: fold it into the spec --
                # including the tuning dimension, which must come from the
                # config the run actually uses -- before anything derives
                # from or embeds the fingerprint
                import dataclasses
                spec = dataclasses.replace(
                    spec, smoke=True,
                    d=tuning_dim(get_smoke_config(spec.problem))
                    if spec.problem in ARCHS else spec.d)
            if args.pipeline != "off" and spec.pipeline != args.pipeline:
                # like --smoke, the schedule is part of the experiment
                # identity: fold the override in before the fingerprint is
                # derived or embedded anywhere
                import dataclasses
                spec = dataclasses.replace(spec, pipeline=args.pipeline)
            if args.leaf_codecs and spec.leaf_codecs != args.leaf_codecs:
                # the per-leaf wire is part of the experiment identity too:
                # fold the override in before the fingerprint is derived
                import dataclasses
                spec = dataclasses.replace(spec, leaf_codecs=args.leaf_codecs)
            if spec.backend == "reference":
                raise SpecError(
                    "the train driver runs the distributed trainers; a "
                    "backend='reference' spec runs via "
                    "repro.core.build(spec).reference()")
            if spec.problem not in ARCHS:
                # valid spec (e.g. a logreg trainer run wired up in user
                # code, like examples/distributed_logreg.py), but this
                # driver only trains the LM arch zoo
                raise SpecError(
                    f"this driver trains model archs {sorted(ARCHS)}; "
                    f"problem={spec.problem!r} specs supply their own "
                    "loss via repro.core.build(spec).train_step(...)")
        else:
            mesh_probe = make_mesh([int(x) for x in args.mesh.split("x")])
            spec = spec_from_args(args, num_workers(mesh_probe))
        run = build(spec)
    except (SpecError, ValueError, OSError) as e:
        raise SystemExit(f"[train] bad experiment spec: {e}")

    mesh = run.make_mesh()
    n = num_workers(mesh)
    cfg = (get_smoke_config(spec.problem) if spec.smoke
           else get_config(spec.problem))
    model = build_model(cfg)

    # WSD schedule for minicpm (its assigned training recipe), cosine otherwise
    sched_kind = args.schedule
    if sched_kind == "auto":
        sched_kind = "wsd" if spec.problem.startswith("minicpm") else "cosine"
    if sched_kind == "wsd":
        sched = wsd(args.lr, warmup_steps=max(spec.steps // 20, 1),
                    stable_steps=int(spec.steps * 0.7),
                    decay_steps=max(int(spec.steps * 0.25), 1))
    else:
        sched = cosine(args.lr, total_steps=spec.steps,
                       warmup_steps=max(spec.steps // 20, 1))
    opt = adamw(sched, weight_decay=0.01)

    algo, downlink, participation = run.algo, run.downlink, run.participation
    federated = run.federated
    print(f"[train] arch={cfg.name} family={cfg.family} params~{cfg.param_count():,} "
          f"workers={n} algo={spec.mode} lam={algo.lam:.4g} nu={algo.nu:.4g} "
          f"agg={spec.agg}"
          + (f" pipeline={spec.pipeline}" if not run.pipeline.is_off else "")
          + (f" participation={spec.participation}" if federated else "")
          + (f" downlink={spec.downlink}" if downlink else "")
          + (f" fleet={spec.compressor}" if algo.fleet is not None else "")
          + (f" leaf_codecs={spec.leaf_codecs}" if spec.leaf_codecs else ""))
    print(f"[train] spec fingerprint={spec.fingerprint()}"
          + (f" (from {args.spec})" if args.spec else ""))

    key = jax.random.key(spec.seed)
    params = model.init(key)
    state = run.init_state(params, opt, mesh)

    # exact wire accounting for the codec payload (docs/wire_format.md);
    # every compressor declares a codec, so this always prints
    from repro.distributed import wire
    up_fmt = wire.tree_format_for(algo.compressor, params,
                                  wire_dtype=spec.wire_dtype,
                                  rules=algo.leaf_rules) \
        if spec.agg == "sparse_allgather" else None
    if up_fmt is not None:
        up = up_fmt.bits_per_round()
        dense = up_fmt.dense_bits()
        kinds = sorted({l.kind for l in up_fmt.leaves})
        print(f"[train] wire: codec={','.join(kinds)} {up} bits/round/worker "
              f"uplink ({up / 8 / 2**20:.2f} MiB, "
              f"{up / max(dense, 1):.4f}x dense fp32)")
        if federated:
            exp_s = participation.fraction(n) * n
            fed = up_fmt.bits_per_round(n_workers=n, participants=exp_s)
            full = up_fmt.bits_per_round(n_workers=n)
            print(f"[train] wire: federated round (mask bitmap + E|S_t|={exp_s:g}"
                  f" of {n} payloads) ~{fed / 8 / 2**20:.2f} MiB total "
                  f"({fed / max(full, 1):.3f}x the full-participation round)")
    elif algo.fleet is not None:
        fmts = wire.fleet_formats(algo.fleet, params,
                                  wire_dtype=spec.wire_dtype)
        bits = wire.fleet_bits_per_round(fmts)
        per = sorted({f.bits_per_round() for f in fmts})
        print(f"[train] wire: mixed fleet of {len(set(algo.fleet))} member "
              f"kinds, per-worker bits in {per}, {bits} bits/round uplink "
              f"(would-be payload; dense_psum carries dense tensors)")
    if downlink is not None:
        # the downlink accounting prints for EVERY agg mode: the broadcast
        # payload is real regardless of how the uplink travels
        dfmt = downlink.format_for(params, wire_dtype=spec.wire_dtype)
        down = dfmt.downlink_bits_per_round()
        dense = dfmt.dense_bits()
        up = (up_fmt.bits_per_round() if up_fmt is not None else dense)
        total = wire.total_round_bits(
            up_fmt, dfmt, n_workers=n,
            participants=participation.fraction(n) * n if federated
            else None) if up_fmt is not None else n * up + down
        dense_total = n * dense + dense  # fp32 both directions
        print(f"[train] wire: downlink {down} bits/round broadcast "
              f"({down / max(dense, 1):.4f}x dense fp32); total "
              f"{total:g} bits/round up+down "
              f"({total / max(dense_total, 1):.4f}x dense both ways)")

    shardings = run.state_shardings(mesh, model.param_specs(), state)
    state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, shardings)

    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                       global_batch=args.global_batch, n_workers=n,
                       seed=spec.seed, heterogeneity=args.heterogeneity,
                       resample_from_shard=spec.resample,
                       shard_size=args.shard_size)

    def loss_fn(p, batch):
        return model.loss(p, batch)

    step_fn = run.train_step(loss_fn, opt, mesh)

    t_start = time.time()
    for step in range(spec.steps):
        batch = make_batch_shardings(mesh, data.batch(step))
        if cfg.family == "vlm":
            batch["vision_embeds"] = jax.device_put(
                np.random.default_rng(step).standard_normal(
                    (args.global_batch, cfg.vision_patches, cfg.d_model),
                    dtype=np.float32))
        if cfg.family == "encdec":
            batch["frames"] = jax.device_put(
                np.random.default_rng(step).standard_normal(
                    (args.global_batch, cfg.encoder_frames, cfg.d_model),
                    dtype=np.float32))
        state, metrics = step_fn(state, batch, jax.random.fold_in(key, step))
        if step % args.log_every == 0 or step == spec.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            part_str = f"|S|={int(m['participants'])}/{n} " \
                if "participants" in m else ""
            print(f"[train] step {step:5d} loss={m['loss']:.4f} "
                  f"|g|={m['g_norm']:.3f} |upd|={m['update_norm']:.4f} "
                  f"h_res={m['h_residual']:.3f} {part_str}"
                  f"({(time.time()-t_start)/(step+1):.2f}s/step)")
        if args.ckpt_dir and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1, {"params": state.params},
                            spec=spec)
            print(f"[train] checkpoint @ {step + 1}")
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, spec.steps, {"params": state.params},
                        spec=spec)
    print(f"[train] done: final loss {float(metrics['loss']):.4f}")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
