"""Docs analysis: offline link checking + doctest discovery.

The link checker (formerly ``tools/check_links.py``, which remains as a
thin shim) validates every markdown link target:

  * relative links must resolve to an existing file or directory
    (anchors are stripped; pure-anchor links are checked against the
    file's own headings);
  * http(s) links are only syntax-checked (CI runs offline).

Doctest discovery parses every ``>>>`` example in the same markdown set
with :class:`doctest.DocTestParser` -- a malformed example (bad prompt
continuation, unparseable source) fails here instead of silently being
skipped by the pytest collector, and the per-file example counts make an
empty docs-test run (collector misconfiguration) loud.
"""

from __future__ import annotations

import doctest
import re
import sys
from pathlib import Path
from typing import List, Tuple

LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#+\s+(.*)$", re.MULTILINE)


def slugify(heading: str) -> str:
    """GitHub-style anchor slug."""
    s = re.sub(r"[`*_]", "", heading.strip().lower())
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def check_file(md: Path) -> List[str]:
    text = md.read_text()
    anchors = {slugify(h) for h in HEADING_RE.findall(text)}
    errors = []
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        if not path_part:  # same-file anchor
            if anchor and slugify(anchor) not in anchors:
                errors.append(f"{md}: dangling anchor #{anchor}")
            continue
        resolved = (md.parent / path_part).resolve()
        if not resolved.exists():
            errors.append(f"{md}: broken link -> {target}")
    return errors


def discover_doctests(md: Path) -> Tuple[int, List[str]]:
    """-> (number of ``>>>`` examples, parse errors) for one markdown file."""
    text = md.read_text()
    parser = doctest.DocTestParser()
    n = 0
    errors: List[str] = []
    try:
        for item in parser.parse(text, name=str(md)):
            if isinstance(item, doctest.Example):
                n += 1
    except ValueError as e:
        errors.append(f"{md}: malformed doctest: {e}")
    return n, errors


def iter_md_files(argv: List[str]) -> Tuple[List[Path], List[str]]:
    files: List[Path] = []
    missing: List[str] = []
    for a in argv:
        p = Path(a)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        elif p.exists():
            files.append(p)
        else:
            missing.append(a)
    return files, missing


def main(argv: List[str]) -> int:
    if not argv:
        argv = ["docs", "README.md"]
    files, missing = iter_md_files(argv)
    for a in missing:
        print(f"check_links: no such path {a}", file=sys.stderr)
    if missing:
        return 2
    errors = [e for f in files for e in check_file(f)]
    n_examples = 0
    for f in files:
        n, errs = discover_doctests(f)
        n_examples += n
        errors.extend(errs)
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_links: {len(files)} files, {n_examples} doctest examples, "
          f"{'FAIL' if errors else 'ok'} ({len(errors)} broken)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
