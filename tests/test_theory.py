"""Theory-layer tests, including an exact reproduction of the paper's
Table 3 parameter values (mushrooms / phishing / a9a / w8a columns)."""

import math

import pytest
from _prop import given, settings, st

from repro.core import CompKK, theory, tune, tune_for


def test_lambda_star_formula():
    # Prop. 2 special case eta=0 recovers EF21's Lemma 8: lam* = 1/(1+omega)
    assert abs(theory.lambda_star(0.0, 3.0) - 1.0 / 4.0) < 1e-12
    # no randomness -> no scaling
    assert theory.lambda_star(0.5, 0.0) == 1.0


@given(eta=st.floats(0.0, 0.99), omega=st.floats(0.0, 100.0))
@settings(max_examples=200, deadline=None)
def test_lambda_star_optimality(eta, omega):
    """lam* minimizes r(lam) on (0, 1] (Prop. 2)."""
    lam = theory.lambda_star(eta, omega)
    r_star = theory.r_of(lam, eta, omega)
    assert r_star < 1.0 + 1e-12
    for probe in [lam * 0.5, lam * 0.9, min(lam * 1.1, 1.0), 1.0, 0.01]:
        if 0 < probe <= 1.0:
            assert r_star <= theory.r_of(probe, eta, omega) + 1e-9


@given(eta=st.floats(0.0, 0.95), omega=st.floats(0.0, 50.0),
       n=st.integers(1, 10_000))
@settings(max_examples=200, deadline=None)
def test_efbv_gamma_at_least_ef21(eta, omega, n):
    """The paper's headline: with omega_av = omega/n, EF-BV's stepsize bound
    is >= EF21's, strictly when omega > 0 and n > 1 (Sect. 4.1)."""
    L = Lt = 1.0
    t_bv = tune(eta, omega, omega / n, mode="efbv", L=L, Ltilde=Lt)
    t_21 = tune(eta, omega, omega / n, mode="ef21", L=L, Ltilde=Lt)
    assert t_bv.gamma >= t_21.gamma - 1e-12
    if omega > 1e-3 and n > 1:
        assert t_bv.r_av <= t_21.r_av + 1e-12
        assert t_bv.speedup_vs_ef21 <= 1.0 + 1e-12


def test_rate_below_one():
    t = tune(0.5, 4.0, 0.4, mode="efbv", L=1.0, Ltilde=1.5, mu=0.1)
    assert 0 < t.rate < 1.0
    assert (t.r + 1) / 2 < 1.0


# ---- Table 3 of the paper: comp-(k, d/2), n = 1000 -------------------------

TAB3 = [
    # dataset, d, k, eta, omega, lam, gamma_ratio_check
    ("mushrooms", 112, 1, 0.707, 55.0, 5.32e-3),
    ("phishing", 68, 1, 0.707, 33.0, 8.85e-3),
    ("a9a", 123, 1, 0.710, 60.0, 4.83e-3),
    ("w8a", 300, 1, 0.707, 149.0, 1.96e-3),
    ("mushrooms", 112, 2, 0.707, 27.0, 1.08e-2),
]


@pytest.mark.parametrize("name,d,k,eta,omega,lam", TAB3)
def test_paper_table3(name, d, k, eta, omega, lam):
    """Reproduce the paper's Tab. 3 compressor constants and lam values."""
    kp = d // 2
    comp = CompKK(k, kp)
    assert abs(comp.eta(d) - eta) < 5e-3, (comp.eta(d), eta)
    assert abs(comp.omega(d) - omega) < 0.51, (comp.omega(d), omega)
    t = tune_for(comp, d, n=1000, mode="efbv")
    assert abs(t.lam - lam) / lam < 0.02, (t.lam, lam)
    # nu = 1 in the table for EF-BV (omega_av tiny -> nu* ~ 1)
    assert t.nu > 0.9
    # sqrt(r_av / r) matches the table's ~0.72-0.81 range
    assert 0.70 < t.speedup_vs_ef21 < 0.85


def test_table3_r_values():
    """r ~ 0.998 and r_av ~ 0.555 for mushrooms k=1 (paper Tab. 3)."""
    comp = CompKK(1, 56)
    t = tune_for(comp, 112, n=1000, mode="efbv")
    assert abs(t.r - 0.998) < 2e-3
    assert abs(t.r_av - 0.555) < 1e-2
    assert abs(t.s - 3.90e-4) / 3.90e-4 < 0.05


def test_iteration_complexity_improves_with_n():
    comp = CompKK(1, 56)
    d = 112
    c_prev = None
    for n in [1, 10, 100, 1000]:
        t = tune_for(comp, d, n=n, mode="efbv")
        c = theory.iteration_complexity(1.0, 1.0, 0.1, t)
        if c_prev is not None:
            assert c <= c_prev * (1 + 1e-9)
        c_prev = c


def test_diana_and_ef21_modes():
    comp = CompKK(1, 56)
    t_diana = tune_for(comp, 112, n=1000, mode="diana")
    assert t_diana.nu == 1.0
    t_ef21 = tune_for(comp, 112, n=1000, mode="ef21")
    assert t_ef21.nu == t_ef21.lam


def test_tune_validation():
    with pytest.raises(ValueError):
        tune(1.0, 0.5, 0.1)  # eta must be < 1
    with pytest.raises(ValueError):
        tune(0.0, 1.0)  # needs omega_av or n
