"""Property tests for the wire-codec registry (tests/_prop.py driven).

For EVERY compressor in the zoo: the registered codec's
``decode(encode(x))`` equals the dense compressor output bit-for-bit (exact
equality, not closeness -- the codec IS the compressor on the wire), the
measured payload bytes equal ``payload_bits / 8`` exactly (padding
included), and the worker-stacked decode-sum matches the sum of individual
decodes.  Also pins the fp16/bf16 value-precision knob and the acceptance
ratio for the quantized codecs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.core import (BlockTopK, CompKK, FracCompKK, FracTopK, Identity,
                        MixKK, Natural, QSGD, RandK, ScaledRandK, SignNorm,
                        TopK, make_compressor)
from repro.core.compressors import MNice
from repro.distributed import wire

D = 96

ZOO = [
    ("identity", Identity()),
    ("topk", TopK(7)),
    ("randk", RandK(9)),
    ("scaled_randk", ScaledRandK(5)),
    ("comp", CompKK(3, 20)),
    ("mix", MixKK(4, 9)),
    ("block_topk", BlockTopK(16, 4)),
    ("sign", SignNorm()),
    ("natural", Natural()),
    ("qsgd", QSGD(16)),
    ("qsgd_wide", QSGD(400)),
    ("qsgd_odd", QSGD(7)),
    ("frac_topk", FracTopK(0.05)),
    ("frac_comp", FracCompKK(0.03, 0.4)),
    ("mnice", MNice(4, 2)),
]


@pytest.mark.parametrize("name,comp", ZOO, ids=[n for n, _ in ZOO])
@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_codec_roundtrip_bit_exact_and_bytes(name, comp, seed):
    """decode(encode(x)) == dense C(x) exactly; payload bytes == bits/8."""
    x = jax.random.normal(jax.random.key(seed), (D,))
    key = jax.random.key(seed ^ 0xC0DEC)
    codec = wire.codec_of(comp, (D,), D)
    dense = comp(key, x)
    payload = codec.encode(key, x)
    rec = codec.decode(payload)
    assert rec.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(rec), np.asarray(dense),
                                  err_msg=name)
    assert codec.payload_bits % 8 == 0, name
    assert 8 * wire.payload_bytes(payload) == codec.payload_bits, name


@pytest.mark.parametrize("name,comp", ZOO, ids=[n for n, _ in ZOO])
def test_codec_decode_sum_matches_stacked(name, comp):
    """decode_sum of a worker-stacked payload == sum of individual decodes
    (the local combine of the sparse_allgather collective)."""
    n = 3
    keys = jax.random.split(jax.random.key(1), n)
    xs = jax.random.normal(jax.random.key(2), (n, D))
    codec = wire.codec_of(comp, (D,), D)
    payloads = [codec.encode(k, x) for k, x in zip(keys, xs)]
    stacked = jax.tree.map(lambda *a: jnp.stack(a), *payloads)
    got = codec.decode_sum(stacked)
    want = sum(np.asarray(codec.decode(p)) for p in payloads)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-5, err_msg=name)


def test_every_registered_spec_has_a_codec():
    """make_compressor's whole registry: format_for never returns None and
    every leaf codec reports positive, exact bits."""
    tree = {"w": jnp.zeros((24, 4)), "b": jnp.zeros((17,))}
    specs = ["identity", "topk:8", "randk:4", "scaled_randk:4", "comp:2,8",
             "mix:2,4", "block_topk:16,2", "sign", "natural", "qsgd:16",
             "frac_topk:50", "frac_comp:20,400"]
    for spec in specs:
        fmt = wire.format_for(make_compressor(spec), tree)
        assert fmt is not None, spec
        assert len(fmt.leaves) == 2, spec
        assert fmt.bits_per_round() > 0, spec
        assert fmt.bits_per_round(n_workers=8) == 8 * fmt.bits_per_round()


def test_quantized_codecs_beat_a_third_of_dense():
    """Acceptance: QSGD and natural payloads are <= 1/3 of dense fp32."""
    d = 4096
    for comp in [QSGD(16), Natural()]:
        codec = wire.codec_of(comp, (d,), d)
        assert codec.payload_bits <= 32 * d / 3, (comp, codec.payload_bits)
    # sign is ~1 bit/coordinate
    assert wire.codec_of(SignNorm(), (d,), d).payload_bits <= 32 + 32 * (d // 32 + 1)


def test_wire_dtype_knob_halves_sparse_values():
    """fp16/bf16 value payloads: honest accounting and a cast-consistent
    decode (exactness only holds at float32 -- the default)."""
    x = jax.random.normal(jax.random.key(3), (D,))
    comp = TopK(8)
    c32 = wire.codec_of(comp, (D,), D, "float32")
    c16 = wire.codec_of(comp, (D,), D, "bfloat16")
    assert c16.payload_bits == 8 * (16 + 32) < c32.payload_bits
    payload = c16.encode(None, x)
    vals, idx = payload
    assert vals.dtype == jnp.bfloat16
    assert 8 * wire.payload_bytes(payload) == c16.payload_bits
    rec = c16.decode(payload)
    dense = comp(None, x)
    # decode == dense rounded through the wire dtype, exactly
    want = jnp.zeros((D,)).at[idx].add(
        np.asarray(dense)[np.asarray(idx)].astype(jnp.bfloat16).astype(
            jnp.float32))
    np.testing.assert_array_equal(np.asarray(rec), np.asarray(want))


def test_dense_pack_identity_is_lossless():
    x = jax.random.normal(jax.random.key(4), (D,))
    codec = wire.codec_of(Identity(), (D,), D)
    np.testing.assert_array_equal(
        np.asarray(codec.decode(codec.encode(None, x))), np.asarray(x))
    assert codec.payload_bits == 32 * D


def test_natural_codec_domain_note():
    """The natural codec clips exponents to [-126, 127]: values inside the
    normal fp32 range roundtrip exactly even at extreme scales."""
    for scale in (1e-30, 1e30):
        x = jax.random.normal(jax.random.key(5), (D,)) * scale
        key = jax.random.key(6)
        comp = Natural()
        codec = wire.codec_of(comp, (D,), D)
        np.testing.assert_array_equal(
            np.asarray(codec.decode(codec.encode(key, x))),
            np.asarray(comp(key, x)))
