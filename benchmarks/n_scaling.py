"""The paper's headline property (Tab. 1 last row): EF-BV's convergence
improves as the number of workers n grows, while EF21's rate is n-independent.

We sweep n and report (a) the theoretical stepsize gamma (monotone in n for
EF-BV, flat for EF21) and (b) the measured suboptimality after a fixed number
of rounds on the logistic-regression problem.

The participation sweep (federated execution mode) holds n fixed and sweeps
the per-round sampling fraction p: the wire bits of a round scale as |S_t|
(mask bitmap + only the sampled payloads -- wire.federated_round_bits) while
the tuned stepsize and the measured suboptimality degrade gracefully, which
is the bits-vs-convergence trade-off the docs quote."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import KEY, make_problem
from repro.core import (CompKK, Downlink, EFBV, Participation,
                        make_compressor, run, run_bidirectional,
                        run_federated, tune_for)
from repro.distributed import wire


def run_bench(fast: bool = True):
    steps = 1200 if fast else 6000
    name = "phishing"
    rows = []
    gammas = {"efbv": [], "ef21": []}
    finals = {"efbv": [], "ef21": []}
    ns = [10, 100, 1000] if fast else [10, 50, 100, 500, 1000, 2000]
    for n in ns:
        prob = make_problem(name, n=n)
        _, fstar = prob.solve()
        d = prob.d
        comp = CompKK(1, d // 2)
        for mode in ["efbv", "ef21"]:
            t = tune_for(comp, d, n, mode=mode, L=prob.L(), Ltilde=prob.L_tilde())
            algo = EFBV(comp, lam=t.lam, nu=t.nu)
            _, _, m = run(algo=algo, grad_fn=prob.grads, x0=jnp.zeros(d),
                          gamma=t.gamma, steps=steps, key=KEY, n=n,
                          record=lambda x: prob.f(x) - fstar)
            gammas[mode].append(t.gamma)
            finals[mode].append(float(m[-1]))
    # theory: EF-BV gamma must increase with n; EF21's is n-independent
    bv_monotone = all(gammas["efbv"][i] <= gammas["efbv"][i + 1] * (1 + 1e-9)
                      for i in range(len(ns) - 1))
    ef21_flat = max(gammas["ef21"]) / max(min(gammas["ef21"]), 1e-30) < 1.3
    rows.append({"name": "n_scaling/gamma_monotone_in_n",
                 "us_per_call": "",
                 "derived": f"efbv_monotone={bv_monotone};ef21_flat={ef21_flat};"
                            f"gamma_efbv={[f'{g:.2e}' for g in gammas['efbv']]};"
                            f"gamma_ef21={[f'{g:.2e}' for g in gammas['ef21']]}"})
    for i, n in enumerate(ns):
        rows.append({"name": f"n_scaling/n{n}/final_gap",
                     "us_per_call": "",
                     "derived": f"efbv={finals['efbv'][i]:.3e};"
                                f"ef21={finals['ef21'][i]:.3e}"})
    rows.extend(participation_rows(fast=fast))
    rows.extend(bidirectional_rows(fast=fast))
    return rows


def bidirectional_rows(fast: bool = True):
    """Up/down bits sweep: fixed uplink (the paper's comp-(k, k')), sweep of
    downlink codecs from dense fp32 to qsgd:16.  Exact total_round_bits
    (uplink x n + ONE broadcast) against the measured suboptimality after a
    fixed round budget -- the bidirectional bits-vs-convergence trade-off."""
    steps = 1500 if fast else 6000
    n = 50
    prob = make_problem("phishing", n=n)
    _, fstar = prob.solve()
    d = prob.d
    comp = CompKK(1, d // 2)
    up_fmt = wire.format_for(comp, jnp.zeros(d))
    t = tune_for(comp, d, n, mode="efbv", L=prob.L(), Ltilde=prob.L_tilde())
    algo = EFBV(comp, lam=t.lam, nu=t.nu)

    downs = ["identity", f"topk:{d // 4}", "qsgd:16"]
    rows, gaps, totals = [], [], []
    for spec in downs:
        down = Downlink(make_compressor(spec))
        # broadcast error feedback tolerates a smaller step for lossy C_s
        gamma = t.gamma if spec == "identity" else t.gamma * 0.5
        _, _, m = run_bidirectional(
            algo=algo, downlink=down, grad_fn=lambda k, x: prob.grads(x),
            x0=jnp.zeros(d), gamma=gamma, steps=steps, key=KEY, n=n,
            record=lambda x: prob.f(x) - fstar)
        down_fmt = down.format_for(jnp.zeros(d))
        total = wire.total_round_bits(up_fmt, down_fmt, n_workers=n)
        gaps.append(float(m[-1]))
        totals.append(float(total))
        rows.append({"name": f"n_scaling/bidirectional_{spec.split(':')[0]}",
                     "us_per_call": "",
                     "derived": f"final_gap={gaps[-1]:.3e};"
                                f"up_bits={up_fmt.bits_per_round(n_workers=n):g};"
                                f"down_bits={down_fmt.downlink_bits_per_round():g};"
                                f"total_bits={total:g}"})
    # the downlink shrinks total bits monotonically along the sweep while
    # the gap stays finite (lossy broadcasts still converge)
    assert all(t1 >= t2 for t1, t2 in zip(totals, totals[1:])), totals
    assert all(np.isfinite(g) for g in gaps), gaps
    rows.append({"name": "n_scaling/bidirectional/bits_vs_gap",
                 "us_per_call": "",
                 "derived": f"downs={downs};"
                            f"totals={[f'{t_:g}' for t_ in totals]};"
                            f"gaps={[f'{g:.2e}' for g in gaps]}"})
    return rows


def participation_rows(fast: bool = True):
    """Federated sweep: wire bits/round scale as |S_t|, convergence degrades
    gracefully as the participation fraction p shrinks."""
    steps = 1500 if fast else 6000
    n = 100
    prob = make_problem("phishing", n=n)
    _, fstar = prob.solve()
    d = prob.d
    comp = CompKK(1, d // 2)
    fmt = wire.format_for(comp, jnp.zeros(d))
    rows, gaps, bits = [], [], []
    ps = [1.0, 0.5, 0.25] if fast else [1.0, 0.5, 0.25, 0.1]
    for p in ps:
        part = (Participation() if p >= 1.0
                else Participation(kind="bernoulli", p=p))
        t = tune_for(comp, d, n, mode="efbv", L=prob.L(),
                     Ltilde=prob.L_tilde(),
                     participation=None if p >= 1.0 else p)
        algo = EFBV(comp, lam=t.lam, nu=t.nu)
        _, _, m = run_federated(
            algo=algo, grad_fn=lambda k, x: prob.grads(x), x0=jnp.zeros(d),
            gamma=t.gamma, steps=steps, key=KEY, n=n, participation=part,
            record=lambda x: prob.f(x) - fstar)
        # expected federated uplink: mask bitmap + E|S_t| payloads
        b = fmt.bits_per_round(n_workers=n, participants=p * n)
        gaps.append(float(m[-1]))
        bits.append(float(b))
        rows.append({"name": f"n_scaling/participation_p{p:g}/trade_off",
                     "us_per_call": "",
                     "derived": f"final_gap={gaps[-1]:.3e};"
                                f"gamma={t.gamma:.2e};"
                                f"exp_bits_per_round={b:g}"})
    # the wire side of the trade-off is exact: bits scale as |S_t|
    full_payload = n * fmt.bits_per_round()
    assert all(b <= full_payload * p + 32 * wire.bitmap_words(n) + 1e-9
               for p, b in zip(ps, bits)), (ps, bits, full_payload)
    rows.append({"name": "n_scaling/participation/bits_scale_with_s",
                 "us_per_call": "",
                 "derived": f"ps={ps};bits={[f'{b:g}' for b in bits]};"
                            f"monotone={all(b1 >= b2 for b1, b2 in zip(bits, bits[1:]))}"})
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run_bench(fast=True))
