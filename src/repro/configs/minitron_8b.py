"""minitron-8b: width-pruned Nemotron-4 [arXiv:2407.14679].

Dense decoder, 32L x d4096, 32 query heads with GQA kv=8, SwiGLU ff=16384,
256k vocabulary (the large vocab makes the LM head / embedding the dominant
memory term -- good roofline stressor)."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=16384, vocab=256000, head_dim=128,
        rope_theta=1e4, attn_window=0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b-smoke", family="dense",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
        d_ff=512, vocab=1024, head_dim=64,
    )
