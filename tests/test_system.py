"""End-to-end behaviour tests for the paper's system: the full train driver
(EF-BV in the loop) and the serve driver, on reduced configs."""

import pytest

from conftest import run_with_devices


@pytest.mark.slow
def test_train_driver_end_to_end():
    """repro.launch.train with EF-BV + sparse wire on a 2x2 mesh learns."""
    out = run_with_devices("""
        from repro.launch.train import main
        loss = main(["--arch", "qwen2-0.5b", "--smoke", "--mesh", "2x2",
                     "--steps", "40", "--global-batch", "8", "--seq", "64",
                     "--lr", "3e-3", "--algo", "efbv",
                     "--compressor", "block_topk:256,64",
                     "--agg", "sparse_allgather", "--log-every", "20"])
        assert loss < 7.0, loss   # started ~log(1024)=6.93, must not blow up
        print("TRAIN_DRIVER_OK", loss)
    """, n_devices=4, timeout=1200)
    assert "TRAIN_DRIVER_OK" in out


@pytest.mark.slow
def test_train_driver_smoke_both_agg_modes():
    """Regression: launch/train.py --smoke must run under BOTH aggregation
    wire formats (the sparse path is the fused-payload pipeline)."""
    out = run_with_devices("""
        from repro.launch.train import main
        for agg in ["dense_psum", "sparse_allgather"]:
            loss = main(["--arch", "qwen2-0.5b", "--smoke", "--mesh", "2x2",
                         "--steps", "2", "--global-batch", "8", "--seq", "32",
                         "--algo", "efbv", "--compressor", "block_topk:256,16",
                         "--agg", agg, "--log-every", "10"])
            assert loss < 8.0, (agg, loss)
            print("AGG_OK", agg)
    """, n_devices=4, timeout=1200)
    assert out.count("AGG_OK") == 2


def test_serve_driver_end_to_end(capsys):
    from repro.launch.serve import main
    gen = main(["--arch", "mamba2-130m", "--smoke", "--batch", "2",
                "--prompt-len", "4", "--gen", "6"])
    assert gen.shape == (2, 6)


def test_checkpoint_from_train_driver(tmp_path):
    from repro.launch.train import main
    main(["--arch", "mamba2-130m", "--smoke", "--mesh", "1x1", "--steps", "3",
          "--global-batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path),
          "--log-every", "100"])
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path)) == 3
