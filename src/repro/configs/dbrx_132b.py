"""dbrx-132b [hf:databricks/dbrx-base]: fine-grained MoE, 16 experts top-4.

40L x d6144, 48 heads GQA kv=8, per-expert ff=10752, vocab 100352.  16
experts map one-per-shard onto the 16-way model axis (pure expert
parallelism) -- the biggest collective load in the assignment set."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=10752, vocab=100352, head_dim=128,
        n_experts=16, experts_per_tok=4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-smoke", family="moe",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=1024, head_dim=64,
        n_experts=4, experts_per_tok=2,
    )
