"""Distributed-runtime tests.

Single-process tests cover the reference aggregation path (vmap semantics);
multi-device behavior (shard_map trainer, wire-mode equivalence, per-worker
gradient semantics, mini dry-run lowering) runs in subprocesses with forced
XLA host devices -- never globally (smoke tests must see 1 device).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_with_devices
from repro.core import BlockTopK, EFBV, TopK
from repro.distributed.aggregate import efbv_aggregate_reference

KEY = jax.random.key(0)


def test_reference_agg_modes_identical():
    """dense_psum and sparse_allgather wire formats are bit-equivalent."""
    n, shape = 4, (32, 16)
    algo = EFBV(BlockTopK(64, 8), lam=0.8, nu=0.9)
    grads = {"w": jax.random.normal(KEY, (n,) + shape)}
    h = {"w": jnp.zeros((n,) + shape)}
    h_avg = {"w": jnp.zeros(shape)}
    keys = jax.random.split(KEY, n)  # repro: noqa(prng-reuse) -- deterministic fixture, draws need not be independent
    outs = {}
    for mode in ["dense_psum", "sparse_allgather"]:
        outs[mode] = efbv_aggregate_reference(algo, keys, grads, h, h_avg,
                                              mode=mode)
    for a, b in zip(jax.tree.leaves(outs["dense_psum"]),
                    jax.tree.leaves(outs["sparse_allgather"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_reference_agg_matches_core_step():
    """The distributed-decomposed path == the core EFBV.step reference."""
    n, d = 4, 50
    algo = EFBV(TopK(5), lam=0.6, nu=0.8)
    grads = jax.random.normal(KEY, (n, d))
    st = algo.init(jnp.zeros(d), n)
    g_core, st2 = algo.step(KEY, grads, st)

    keys = jax.random.split(KEY, n)  # repro: noqa(prng-reuse) -- deterministic fixture, draws need not be independent
    g_dist, h_new, h_avg_new = efbv_aggregate_reference(
        algo, keys, grads, st.h, st.h_avg, mode="dense_psum")
    np.testing.assert_allclose(np.asarray(g_core), np.asarray(g_dist),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(st2.h), np.asarray(h_new),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.slow
def test_trainer_modes_and_convergence_8dev():
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import EFBV, BlockTopK
        from repro.optim import sgd, constant
        from repro.train import make_train_step, init_train_state, train_state_shardings
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((4, 2))
        key = jax.random.key(0)
        D, H = 16, 32
        params = {"w1": jax.random.normal(key, (D, H)) * 0.1,
                  "w2": jax.random.normal(key, (H, D)) * 0.1}
        specs = {"w1": P(None, "model"), "w2": P("model", None)}

        def loss_fn(p, batch):
            pred = jnp.tanh(batch["x"] @ p["w1"]) @ p["w2"]
            l = jnp.mean((pred - batch["y"]) ** 2)
            return l, {}

        algo = EFBV.make(BlockTopK(16, 4), d=D * H, n=4)
        opt = sgd(constant(0.05))
        finals = {}
        for mode in ["dense_psum", "sparse_allgather"]:
            st = init_train_state(params, opt, mesh)
            sh = train_state_shardings(mesh, specs, st)
            st = jax.tree.map(lambda x, s: jax.device_put(x, s), st, sh)
            step = make_train_step(loss_fn, opt, algo, mesh, agg_mode=mode)
            for i in range(120):
                kb = jax.random.fold_in(jax.random.key(42), i)
                x = jax.random.normal(kb, (16, D)); y = x * 0.3
                batch = {"x": jax.device_put(x, NamedSharding(mesh, P("data"))),
                         "y": jax.device_put(y, NamedSharding(mesh, P("data")))}
                st, m = step(st, batch, jax.random.fold_in(key, i))
            finals[mode] = float(m["loss"])
            print(mode, finals[mode])
        assert finals["dense_psum"] < 0.2, finals
        assert abs(finals["dense_psum"] - finals["sparse_allgather"]) < 1e-5, finals
        print("MODES_MATCH")
    """, n_devices=8)
    assert "MODES_MATCH" in out


@pytest.mark.slow
def test_per_worker_gradients_8dev():
    """The trainer's phase-1 gradient is this worker's nabla f_i, not the sum
    (regression test for the VMA psum-of-invariant-cotangent pitfall)."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import EFBV, Identity
        from repro.optim import sgd, constant
        from repro.train import make_train_step, init_train_state, train_state_shardings
        from repro.launch.mesh import make_mesh

        mesh = jax.make_mesh((4, 1), ("data", "model"))
        params = {"w": jnp.zeros((4,))}
        specs = {"w": P(None)}

        def loss_fn(p, batch):
            # worker i's loss: <w, x_i>; grad = x_i
            return jnp.sum(p["w"] * batch["x"][0]), {}

        algo = EFBV(Identity(), lam=1.0, nu=1.0)   # no compression
        opt = sgd(constant(1.0))
        st = init_train_state(params, opt, mesh)
        sh = train_state_shardings(mesh, specs, st)
        st = jax.tree.map(lambda x, s: jax.device_put(x, s), st, sh)
        step = make_train_step(loss_fn, opt, algo, mesh)
        x = jnp.arange(16.0).reshape(4, 4)  # worker i sees row i
        batch = {"x": jax.device_put(x, NamedSharding(mesh, P("data")))}
        st2, m = step(st, batch, jax.random.key(0))
        # with identity compressor + zero h: g = mean_i x_i; w' = -g
        import numpy as np
        np.testing.assert_allclose(np.asarray(st2.params["w"]),
                                   -np.asarray(x.mean(0)), rtol=1e-6)
        # h_i must equal worker i's own gradient x_i (lam=1)
        np.testing.assert_allclose(np.asarray(st2.h["w"]), np.asarray(x),
                                   rtol=1e-6)
        print("PER_WORKER_OK")
    """, n_devices=8)
    assert "PER_WORKER_OK" in out


@pytest.mark.slow
def test_federated_trainer_matches_oracle_8dev():
    """The federated differential leg: the shard_map trainer under a
    RANDOMIZED bernoulli:0.5 participation trajectory matches the vmap
    oracle (efbv_aggregate_reference with the same masks/keys) step for
    step, in both wire modes -- and the sampled subsets are genuinely
    partial."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import EFBV, BlockTopK, Participation
        from repro.core.efbv import participation_key
        from repro.distributed.aggregate import efbv_aggregate_reference
        from repro.optim import sgd, constant
        from repro.train import make_train_step, init_train_state, train_state_shardings

        mesh = jax.make_mesh((4, 1), ("data", "model"))
        n, D, lr = 4, 32, 0.2
        key = jax.random.key(0)
        # numpy-held so the train step's donated buffers can't delete the
        # oracle's copy of the initial point
        params = {"w": np.asarray(jax.random.normal(key, (D,)) * 0.1)}
        specs = {"w": P(None)}

        def loss_fn(p, batch):
            # worker i's local objective: 0.5||w - mean_rows(x_i)||^2,
            # grad = w - xbar_i (exactly computable for the oracle)
            xbar = jnp.mean(batch["x"], 0)
            return 0.5 * jnp.sum((p["w"] - xbar) ** 2), {}

        algo = EFBV(BlockTopK(8, 2), lam=0.6, nu=0.9)
        part = Participation.parse("bernoulli:0.5")
        opt = sgd(constant(lr))
        for mode in ["dense_psum", "sparse_allgather"]:
            st = init_train_state(params, opt, mesh)
            sh = train_state_shardings(mesh, specs, st)
            st = jax.tree.map(lambda x, s: jax.device_put(x, s), st, sh)
            step = make_train_step(loss_fn, opt, algo, mesh, agg_mode=mode,
                                   participation=part)
            w = jnp.asarray(params["w"])
            h = jnp.zeros((n, D)); h_avg = jnp.zeros(D)
            sampled = 0
            for i in range(20):
                kb = jax.random.fold_in(jax.random.key(42), i)
                x = jax.random.normal(kb, (16, D))
                batch = {"x": jax.device_put(x, NamedSharding(mesh, P("data")))}
                ki = jax.random.fold_in(key, i)
                st, m = step(st, batch, ki)
                # the oracle redraws the SAME mask and worker keys
                mask = part.sample_mask(participation_key(ki), n)
                sampled += int(mask.sum())
                assert int(m["participants"]) == int(mask.sum())
                grads = w[None] - x.reshape(n, 4, D).mean(1)
                wkeys = jax.vmap(lambda j: jax.random.fold_in(ki, j))(
                    jnp.arange(n))
                g, h, h_avg = efbv_aggregate_reference(
                    algo, wkeys, grads, h, h_avg, mode=mode, masks=mask)
                w = w - lr * g
                np.testing.assert_allclose(np.asarray(st.params["w"]),
                                           np.asarray(w), rtol=1e-6, atol=1e-6)
                np.testing.assert_allclose(np.asarray(st.h["w"]),
                                           np.asarray(h), rtol=1e-6, atol=1e-6)
                np.testing.assert_allclose(np.asarray(st.h_avg["w"]),
                                           np.asarray(h_avg), rtol=1e-6,
                                           atol=1e-6)
            assert 0 < sampled < 20 * n, sampled  # genuinely partial rounds
            print(mode, "ok, sampled", sampled, "/", 20 * n)
        print("FED_ORACLE_MATCH")
    """, n_devices=8)
    assert "FED_ORACLE_MATCH" in out


@pytest.mark.slow
def test_federated_full_participation_bit_identical_8dev():
    """participation=bernoulli:1.0 (and fixed:n) must leave the trainer on
    the unmasked code path: params/h after several steps are BIT-identical
    to a participation=None run."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import EFBV, BlockTopK, Participation
        from repro.optim import sgd, constant
        from repro.train import make_train_step, init_train_state, train_state_shardings

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        key = jax.random.key(0)
        D, H = 16, 32
        params = {"w1": jax.random.normal(key, (D, H)) * 0.1,
                  "w2": jax.random.normal(key, (H, D)) * 0.1}
        specs = {"w1": P(None, "model"), "w2": P("model", None)}

        def loss_fn(p, batch):
            pred = jnp.tanh(batch["x"] @ p["w1"]) @ p["w2"]
            return jnp.mean((pred - batch["y"]) ** 2), {}

        algo = EFBV.make(BlockTopK(16, 4), d=D * H, n=4)
        opt = sgd(constant(0.05))
        finals = {}
        for part in [None, Participation.parse("bernoulli:1.0"),
                     Participation.parse("fixed:4")]:
            st = init_train_state(params, opt, mesh)
            sh = train_state_shardings(mesh, specs, st)
            st = jax.tree.map(lambda x, s: jax.device_put(x, s), st, sh)
            step = make_train_step(loss_fn, opt, algo, mesh,
                                   agg_mode="sparse_allgather",
                                   participation=part)
            for i in range(10):
                kb = jax.random.fold_in(jax.random.key(42), i)
                x = jax.random.normal(kb, (16, D)); y = x * 0.3
                batch = {"x": jax.device_put(x, NamedSharding(mesh, P("data"))),
                         "y": jax.device_put(y, NamedSharding(mesh, P("data")))}
                st, m = step(st, batch, jax.random.fold_in(key, i))
            finals[str(part)] = (np.asarray(st.params["w1"]),
                                 np.asarray(st.h["w1"]))
        ref = finals["None"]
        for name, got in finals.items():
            np.testing.assert_array_equal(got[0], ref[0], err_msg=name)
            np.testing.assert_array_equal(got[1], ref[1], err_msg=name)
        print("FED_FULL_BITWISE")
    """, n_devices=8)
    assert "FED_FULL_BITWISE" in out


@pytest.mark.slow
def test_mini_dryrun_lowering_16dev():
    """dryrun-style lower+compile on a 4x4 mini-mesh with a smoke config:
    proves the (pod,data,model) sharding machinery end to end, cheaply."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, dataclasses
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.configs import get_smoke_config
        from repro.models import build_model
        from repro.core import EFBV, BlockTopK
        from repro.optim import adamw, cosine
        from repro.train import init_train_state, make_train_step, train_state_shardings
        from repro.launch.mesh import make_mesh, num_workers
        SDS = jax.ShapeDtypeStruct

        mesh = make_mesh((2, 2, 4))  # pod x data x model
        cfg = get_smoke_config("granite-moe-3b-a800m")
        model = build_model(cfg)
        algo = EFBV.make(BlockTopK(128, 16), d=4096, n=num_workers(mesh))
        opt = adamw(cosine(1e-3, 100, 10))
        specs = model.param_specs()
        params_sds = model.init_abstract()
        shard = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda s: isinstance(s, P))
        params_sds = jax.tree.map(lambda s, h: SDS(s.shape, s.dtype, sharding=h),
                                  params_sds, shard)
        state = jax.eval_shape(lambda p: init_train_state(p, opt, mesh), params_sds)
        sh = train_state_shardings(mesh, specs, state)
        state = jax.tree.map(lambda s, h: SDS(s.shape, s.dtype, sharding=h), state, sh)
        bsh = NamedSharding(mesh, P(("pod", "data")))
        batch = {"tokens": SDS((8, 64), jnp.int32, sharding=bsh),
                 "labels": SDS((8, 64), jnp.int32, sharding=bsh)}
        key = jax.eval_shape(lambda: jax.random.key(0))
        step = make_train_step(model.loss, opt, algo, mesh)
        compiled = step.lower(state, batch, key).compile()
        from repro.compat import cost_analysis
        assert cost_analysis(compiled)["flops"] > 0
        txt = compiled.as_text()
        assert any(op in txt for op in ("all-reduce", "reduce-scatter")), "no worker collective found"
        print("MINI_DRYRUN_OK")
    """, n_devices=16)
    assert "MINI_DRYRUN_OK" in out
