"""The C(eta, omega) compressor zoo (Sect. 2 + Appendix A of the paper).

All compressors take (key, x) and return a dense tensor of x's shape with the
non-kept coordinates zeroed.  ``x`` may have any shape; compression constants
are computed for d = x.size.  Deterministic compressors ignore the key (it may
be None).

Certified constants (all proved in the paper or the cited literature):

  top-k        : B(k/d)            -> eta = sqrt(1 - k/d),        omega = 0
  rand-k       : U(d/k - 1)        -> eta = 0,                    omega = d/k - 1
  comp-(k,k')  : Prop. 5           -> eta = sqrt((d-k')/d),       omega = (k'-k)/k
  mix-(k,k')   : Prop. 4           -> eta = (d-k-k')/sqrt((d-k)d) omega = k'(d-k-k')/((d-k)d)
  block-top-k  : B(kb/b) per block -> eta = sqrt(1 - kb/b),       omega = 0
  sign (norm)  : B(1/d) worst case -> eta = sqrt(1 - 1/d),        omega = 0
  natural      : U(1/8)            -> eta = 0,                    omega = 1/8
  qsgd (s lvls): U(min(d/s^2, sqrt(d)/s))

Every compressor also declares a wire codec (``codec`` -> a LeafCodec from
repro.distributed.wire) with an exact bits-per-round payload layout; the
rendered table lives in docs/compressor_zoo.md.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.contract import Compressor, Wire

Array = jax.Array


def _flat(x: Array) -> Array:
    return x.reshape(-1)


def _topk_mask(xf: Array, k: int) -> Array:
    """0/1 mask of the k largest-|.| entries of the flat vector xf."""
    _, idx = jax.lax.top_k(jnp.abs(xf), k)
    return jnp.zeros_like(xf).at[idx].set(1.0)


def _flat_sparse_codec(compressor, shape, k: int, wire_dtype: str):
    # lazy import: repro.distributed.wire is layout-only (imports nothing
    # from repro.core), but its package __init__ pulls in aggregate -> efbv,
    # which would cycle at module-import time
    from repro.distributed import wire
    return wire.FlatSparse(shape=tuple(shape), size=int(math.prod(shape)),
                           k=k, selector=compressor, val_dtype=wire_dtype)


@dataclasses.dataclass(frozen=True)
class Identity(Compressor):
    def eta(self, d):
        return 0.0

    def omega(self, d):
        return 0.0

    def is_random(self):
        return False

    def __call__(self, key, x):
        return x

    def wire(self, d):
        return Wire(words=d, sparse=False)


@dataclasses.dataclass(frozen=True)
class TopK(Compressor):
    """Deterministic top-k by magnitude (Sect. 2.2): in B(k/d)."""

    k: int

    def eta(self, d):
        return math.sqrt(max(0.0, 1.0 - self.k / d))

    def omega(self, d):
        return 0.0

    def is_random(self):
        return False

    def __call__(self, key, x):
        xf = _flat(x)
        return (xf * _topk_mask(xf, self.k)).reshape(x.shape)

    def wire(self, d):
        return Wire(words=2 * self.k, sparse=True)  # (index, value) pairs

    def codec(self, shape, *, wire_dtype="float32"):
        return _flat_sparse_codec(self, shape, self.k, wire_dtype)

    def encode(self, key, x):
        xf = _flat(x)
        vals, idx = jax.lax.top_k(jnp.abs(xf), self.k)
        return xf[idx], idx

    def decode(self, payload, d):
        vals, idx = payload
        return jnp.zeros((d,), vals.dtype).at[idx.reshape(-1)].add(vals.reshape(-1))


@dataclasses.dataclass(frozen=True)
class RandK(Compressor):
    """Unbiased rand-k (Sect. 2.1): keeps k random coords scaled by d/k; U(d/k-1)."""

    k: int

    def eta(self, d):
        return 0.0

    def omega(self, d):
        return d / self.k - 1.0

    def __call__(self, key, x):
        xf = _flat(x)
        d = xf.shape[0]
        idx = jax.random.choice(key, d, shape=(self.k,), replace=False)
        mask = jnp.zeros_like(xf).at[idx].set(1.0)
        return (xf * mask * (d / self.k)).reshape(x.shape)

    def wire(self, d):
        return Wire(words=2 * self.k, sparse=True)

    def codec(self, shape, *, wire_dtype="float32"):
        from repro.distributed import wire
        return wire.RandKSparse(shape=tuple(shape),
                                size=int(math.prod(shape)), k=self.k,
                                selector=self, val_dtype=wire_dtype)

    def encode(self, key, x):
        xf = _flat(x)
        d = xf.shape[0]
        idx = jax.random.choice(key, d, shape=(self.k,), replace=False)
        return xf[idx] * (d / self.k), idx

    def decode(self, payload, d):
        vals, idx = payload
        return jnp.zeros((d,), vals.dtype).at[idx.reshape(-1)].add(vals.reshape(-1))


@dataclasses.dataclass(frozen=True)
class ScaledRandK(Compressor):
    """rand-k without the d/k blow-up (== (k/d) * RandK; Sect. 2.5): in B(k/d)."""

    k: int

    def eta(self, d):
        return 1.0 - self.k / d  # Prop. 1 with lam = k/d, eta0 = 0

    def omega(self, d):
        return (self.k / d) * (1.0 - self.k / d)

    def __call__(self, key, x):
        xf = _flat(x)
        d = xf.shape[0]
        idx = jax.random.choice(key, d, shape=(self.k,), replace=False)
        mask = jnp.zeros_like(xf).at[idx].set(1.0)
        return (xf * mask).reshape(x.shape)

    def wire(self, d):
        return Wire(words=2 * self.k, sparse=True)

    def codec(self, shape, *, wire_dtype="float32"):
        return _flat_sparse_codec(self, shape, self.k, wire_dtype)

    def encode(self, key, x):
        xf = _flat(x)
        idx = jax.random.choice(key, xf.shape[0], shape=(self.k,),
                                replace=False)
        return xf[idx], idx


@dataclasses.dataclass(frozen=True)
class CompKK(Compressor):
    """comp-(k, k') = rand-k o top-k' (Appendix A.2, Prop. 5).

    Keeps k coords among the k' largest, scaled by k'/k.  Requires k <= k'.
    This is the compressor of the paper's experiments: biased (eta > 0) AND
    random with omega that can exceed 1 -- not in B(alpha) for any alpha, so
    neither plain EF21 nor DIANA theory covers it, but EF-BV does.
    """

    k: int
    kp: int  # k'

    def __post_init__(self):
        assert self.k <= self.kp

    def eta(self, d):
        return math.sqrt((d - self.kp) / d)

    def omega(self, d):
        return (self.kp - self.k) / self.k

    def __call__(self, key, x):
        xf = _flat(x)
        _, top_idx = jax.lax.top_k(jnp.abs(xf), self.kp)  # k' largest
        sub = jax.random.choice(key, self.kp, shape=(self.k,), replace=False)
        keep = top_idx[sub]
        mask = jnp.zeros_like(xf).at[keep].set(1.0)
        return (xf * mask * (self.kp / self.k)).reshape(x.shape)

    def wire(self, d):
        return Wire(words=2 * self.k, sparse=True)

    def codec(self, shape, *, wire_dtype="float32"):
        return _flat_sparse_codec(self, shape, self.k, wire_dtype)

    def encode(self, key, x):
        xf = _flat(x)
        _, top_idx = jax.lax.top_k(jnp.abs(xf), self.kp)
        sub = jax.random.choice(key, self.kp, shape=(self.k,), replace=False)
        keep = top_idx[sub]
        return xf[keep] * (self.kp / self.k), keep

    def decode(self, payload, d):
        vals, idx = payload
        return jnp.zeros((d,), vals.dtype).at[idx.reshape(-1)].add(vals.reshape(-1))


@dataclasses.dataclass(frozen=True)
class MixKK(Compressor):
    """mix-(k, k'): top-k plus k' uniformly-random others (Appendix A.1, Prop. 4)."""

    k: int
    kp: int  # k'

    def eta(self, d):
        assert self.k + self.kp <= d
        return (d - self.k - self.kp) / math.sqrt((d - self.k) * d)

    def omega(self, d):
        return self.kp * (d - self.k - self.kp) / ((d - self.k) * d)

    def __call__(self, key, x):
        xf = _flat(x)
        top_mask = _topk_mask(xf, self.k)
        # choose k' of the remaining d-k uniformly: random scores, masked top-k'
        scores = jax.random.uniform(key, xf.shape)
        scores = jnp.where(top_mask > 0, -1.0, scores)  # exclude already-kept
        _, rnd_idx = jax.lax.top_k(scores, self.kp)
        mask = top_mask.at[rnd_idx].set(1.0)
        return (xf * mask).reshape(x.shape)

    def wire(self, d):
        return Wire(words=2 * (self.k + self.kp), sparse=True)

    def codec(self, shape, *, wire_dtype="float32"):
        return _flat_sparse_codec(self, shape, self.k + self.kp, wire_dtype)

    def encode(self, key, x):
        """k top indices then k' random ones -- disjoint by construction
        (excluded scores are -1 < uniform's [0, 1) range), so the codec's
        scatter-add reproduces the dense mask output exactly."""
        xf = _flat(x)
        _, top_idx = jax.lax.top_k(jnp.abs(xf), self.k)
        top_mask = jnp.zeros_like(xf).at[top_idx].set(1.0)
        scores = jax.random.uniform(key, xf.shape)
        scores = jnp.where(top_mask > 0, -1.0, scores)
        _, rnd_idx = jax.lax.top_k(scores, self.kp)
        idx = jnp.concatenate([top_idx, rnd_idx])
        return xf[idx], idx


@dataclasses.dataclass(frozen=True)
class BlockTopK(Compressor):
    """TPU-native block-local top-k: each contiguous block of size ``block``
    keeps its own ``kb`` largest-|.| entries (DESIGN §3.4).

    Deterministic contraction: per block E||C(xb)-xb||^2 <= (1-kb/b)||xb||^2,
    hence globally in B(kb/b).  The Pallas kernel in repro/kernels/block_topk.py
    implements exactly this operator; this class is the jnp oracle with the
    same semantics (used on the convex path and as the kernel's spec holder).
    """

    block: int
    kb: int

    def eta(self, d):
        return math.sqrt(max(0.0, 1.0 - self.kb / self.block))

    def omega(self, d):
        return 0.0

    def is_random(self):
        return False

    def __call__(self, key, x):
        xf = _flat(x)
        d = xf.shape[0]
        nb = -(-d // self.block)
        pad = nb * self.block - d
        xp = jnp.pad(xf, (0, pad)).reshape(nb, self.block)
        _, idx = jax.lax.top_k(jnp.abs(xp), self.kb)  # (nb, kb)
        mask = jnp.zeros_like(xp)
        mask = jax.vmap(lambda m, i: m.at[i].set(1.0))(mask, idx)
        return (xp * mask).reshape(-1)[:d].reshape(x.shape)

    def wire(self, d):
        nb = -(-d // self.block)
        return Wire(words=2 * nb * self.kb, sparse=True)

    def codec(self, shape, *, wire_dtype="float32"):
        from repro.distributed import wire
        return wire.LeafWire(shape=tuple(shape), size=int(math.prod(shape)),
                             block=self.block, kb=self.kb,
                             val_dtype=wire_dtype)

    def _leaf_wire(self, d: int):
        # import inside the method: repro.distributed.wire is layout-only
        # (imports nothing from repro.core), but its package __init__ pulls
        # in aggregate -> efbv, which would cycle at module-import time
        from repro.distributed import wire
        return wire.LeafWire(shape=(d,), size=d, block=self.block, kb=self.kb)

    def encode(self, key, x):
        """Payload: per-block (values, block-LOCAL indices), shapes (nb, kb).

        Local indices keep the wire payload at log2(block) bits per index and
        -- critically -- avoid int32 overflow on giant leaves (dbrx's stacked
        expert tensor has 4.2e10 elements; a global flat index cannot be an
        int32).  The layout itself is specified once, in
        repro/distributed/wire.py."""
        from repro.distributed import wire
        return wire.pack_oracle(self._leaf_wire(x.size), _flat(x))

    def decode(self, payload, d):
        """Accepts (vals, idx) of shape (nb, kb) or worker-stacked
        (n, nb, kb); the stacked form is scatter-summed per block (the
        sparse_allgather combine path)."""
        from repro.distributed import wire
        return wire.scatter_add(self._leaf_wire(d), *payload)


@dataclasses.dataclass(frozen=True)
class SignNorm(Compressor):
    """L1-norm-scaled sign: C(x) = (||x||_1 / d) * sgn(x); B(1/d) worst case.

    sgn maps 0 -> +1 (not jnp.sign's 0): every coordinate is exactly
    +-scale, so the wire codec is one scale + a 1-bit-per-coordinate sign
    bitmap with a lossless decode.  The B(1/d) certificate is unchanged:
    ||C(x)||^2 = scale^2 d and <C(x), x> = scale ||x||_1 either way.
    """

    def eta(self, d):
        return math.sqrt(max(0.0, 1.0 - 1.0 / d))

    def omega(self, d):
        return 0.0

    def is_random(self):
        return False

    def __call__(self, key, x):
        xf = _flat(x)
        scale = jnp.sum(jnp.abs(xf)) / xf.shape[0]
        return (scale * jnp.where(xf < 0, -1.0, 1.0)).reshape(x.shape)

    def wire(self, d):
        return Wire(words=1 + (d + 31) // 32, sparse=False)  # norm + bitmap

    def codec(self, shape, *, wire_dtype="float32"):
        from repro.distributed import wire
        return wire.SignPack(shape=tuple(shape), size=int(math.prod(shape)))


@dataclasses.dataclass(frozen=True)
class Natural(Compressor):
    """Natural compression (Horvath et al. 2019): stochastic rounding of the
    magnitude to a power of two.  Unbiased with omega = 1/8."""

    def eta(self, d):
        return 0.0

    def omega(self, d):
        return 1.0 / 8.0

    def __call__(self, key, x):
        xf = _flat(x)
        a = jnp.abs(xf)
        safe = jnp.where(a > 0, a, 1.0)
        e = jnp.floor(jnp.log2(safe))
        lo = jnp.exp2(e)
        p = safe / lo - 1.0  # in [0,1): prob of rounding up to 2**(e+1)
        up = jax.random.uniform(key, xf.shape) < p
        # exp2 of the selected integer exponent (== 2*lo or lo exactly):
        # the same expression the wire codec decodes, so the int8 exponent
        # stream is lossless by construction
        mag = jnp.exp2(e + up.astype(jnp.float32))
        out = jnp.where(a > 0, jnp.sign(xf) * mag, 0.0)
        return out.reshape(x.shape)

    def wire(self, d):
        # exact codec accounting: int8 exponent stream + uint32 sign bitmap
        return Wire(words=(8 * d + 31) // 32 + (d + 31) // 32, sparse=False)

    def codec(self, shape, *, wire_dtype="float32"):
        from repro.distributed import wire
        return wire.NaturalPack(shape=tuple(shape),
                                size=int(math.prod(shape)))


@dataclasses.dataclass(frozen=True)
class QSGD(Compressor):
    """QSGD stochastic quantization with s levels (Alistarh et al. 2017).

    Unbiased with omega = min(d/s^2, sqrt(d)/s).
    """

    s: int

    def eta(self, d):
        return 0.0

    def omega(self, d):
        return min(d / self.s**2, math.sqrt(d) / self.s)

    def __call__(self, key, x):
        xf = _flat(x)
        norm = jnp.linalg.norm(xf)
        safe_norm = jnp.where(norm > 0, norm, 1.0)
        level = jnp.abs(xf) / safe_norm * self.s  # in [0, s]
        low = jnp.floor(level)
        p = level - low
        up = jax.random.uniform(key, xf.shape) < p
        # multiply by the f32 reciprocal rather than divide: XLA's jit
        # rewrites division-by-constant inexactly, so a divide here could
        # never be reproduced bit-for-bit by the fused wire kernel.  For
        # power-of-two s the two are identical; otherwise this adds a ~2^-24
        # relative bias, far below the omega certificate's slack.
        q = (low + up.astype(xf.dtype)) * (1.0 / self.s)
        out = jnp.where(norm > 0, norm * jnp.sign(xf) * q, 0.0)
        return out.reshape(x.shape)

    def wire(self, d):
        # exact codec accounting: f32 norm + int8/int16 level stream.  (The
        # entropy-coded bound of Alistarh et al. is log2(2s+1) bits/coord;
        # the fixed-width stream trades ~37% of that for O(1) decode.)
        bits = 8 if self.s <= 127 else 16
        return Wire(words=1 + (bits * d + 31) // 32, sparse=False)

    def codec(self, shape, *, wire_dtype="float32"):
        from repro.distributed import wire
        return wire.QsgdQuant(shape=tuple(shape), size=int(math.prod(shape)),
                              s=self.s)


@dataclasses.dataclass(frozen=True)
class FracTopK(Compressor):
    """top-k with k = max(1, round(frac*d)) -- size-adaptive for per-leaf use
    on parameter pytrees whose leaves have heterogeneous sizes."""

    frac: float

    def _k(self, d: int) -> int:
        return max(1, int(round(self.frac * d)))

    def eta(self, d):
        return math.sqrt(max(0.0, 1.0 - self._k(d) / d))

    def omega(self, d):
        return 0.0

    def is_random(self):
        return False

    def __call__(self, key, x):
        xf = _flat(x)
        return (xf * _topk_mask(xf, self._k(xf.shape[0]))).reshape(x.shape)

    def wire(self, d):
        return Wire(words=2 * self._k(d), sparse=True)

    def codec(self, shape, *, wire_dtype="float32"):
        return _flat_sparse_codec(self, shape,
                                  self._k(int(math.prod(shape))), wire_dtype)

    def encode(self, key, x):
        xf = _flat(x)
        _, idx = jax.lax.top_k(jnp.abs(xf), self._k(xf.shape[0]))
        return xf[idx], idx

    def decode(self, payload, d):
        vals, idx = payload
        return jnp.zeros((d,), vals.dtype).at[idx.reshape(-1)].add(vals.reshape(-1))


@dataclasses.dataclass(frozen=True)
class FracCompKK(Compressor):
    """comp-(k,k') with k = frac*d, k' = fracp*d (size-adaptive CompKK)."""

    frac: float
    fracp: float

    def _kk(self, d):
        k = max(1, int(round(self.frac * d)))
        kp = max(k, int(round(self.fracp * d)))
        return k, kp

    def eta(self, d):
        _, kp = self._kk(d)
        return math.sqrt((d - kp) / d)

    def omega(self, d):
        k, kp = self._kk(d)
        return (kp - k) / k

    def __call__(self, key, x):
        xf = _flat(x)
        return CompKK(*self._kk(xf.shape[0]))(key, xf).reshape(x.shape)

    def wire(self, d):
        k, _ = self._kk(d)
        return Wire(words=2 * k, sparse=True)

    def codec(self, shape, *, wire_dtype="float32"):
        return _flat_sparse_codec(self, shape,
                                  self._kk(int(math.prod(shape)))[0],
                                  wire_dtype)

    def encode(self, key, x):
        return CompKK(*self._kk(x.size)).encode(key, _flat(x))

    def decode(self, payload, d):
        vals, idx = payload
        return jnp.zeros((d,), vals.dtype).at[idx.reshape(-1)].add(vals.reshape(-1))


@dataclasses.dataclass(frozen=True)
class MNice(Compressor):
    """m-nice sampling (Sect. 2.4): models partial participation of m of n
    workers per round.  The workers' compressors are *jointly* defined --
    every worker must sample the SAME subset Omega from the round key -- so
    this is a ``joint`` compressor: EFBV.step calls ``joint_call(round_key,
    worker_idx, x)`` instead of splitting per-worker keys.

    Constants (paper + Condat & Richtarik 2022, Prop. 1):
        omega    = (n - m) / m
        omega_av = (n - m) / (m (n - 1))   (= omega / (n-1); 0 if n = m = 1)
    """

    n: int
    m: int

    joint = True

    def eta(self, d):
        return 0.0  # unbiased: E[C_i(x)] = (m/n)*(n/m) x = x

    def omega(self, d):
        return (self.n - self.m) / self.m

    def omega_av(self, d, n):
        if self.n == 1:
            return 0.0
        return (self.n - self.m) / (self.m * (self.n - 1))

    def joint_call(self, round_key, worker_idx, x):
        member = jax.random.permutation(round_key, self.n)[: self.m]
        keep = jnp.any(member == worker_idx)
        return jnp.where(keep, (self.n / self.m) * x, jnp.zeros_like(x))

    def __call__(self, key, x):
        # marginal law of one worker (for property tests): participate w.p. m/n
        keep = jax.random.uniform(key, ()) < self.m / self.n
        return jnp.where(keep, (self.n / self.m) * x, jnp.zeros_like(x))

    def wire(self, d):
        return Wire(words=d * self.m // self.n, sparse=False)  # amortized


# ----------------------------------------------------------------------------
# registry / parsing ("topk:64", "comp:1,56", ...) used by configs & CLI
# ----------------------------------------------------------------------------

def make_compressor(spec: str) -> Compressor:
    """Parse 'name[:a[,b]]' into a Compressor."""
    name, _, args = spec.partition(":")
    argv = [int(a) for a in args.split(",") if a]
    table = {
        "identity": lambda: Identity(),
        "none": lambda: Identity(),
        "topk": lambda: TopK(*argv),
        "randk": lambda: RandK(*argv),
        "scaled_randk": lambda: ScaledRandK(*argv),
        "comp": lambda: CompKK(*argv),
        "mix": lambda: MixKK(*argv),
        "block_topk": lambda: BlockTopK(*argv),
        "sign": lambda: SignNorm(),
        "natural": lambda: Natural(),
        "qsgd": lambda: QSGD(*argv),
        # fraction-style specs use per-mille integers: "frac_topk:50" = 5%
        "frac_topk": lambda: FracTopK(argv[0] / 1000.0),
        "frac_comp": lambda: FracCompKK(argv[0] / 1000.0, argv[1] / 1000.0),
    }
    if name not in table:
        raise ValueError(f"unknown compressor {name!r}; known: {sorted(table)}")
    return table[name]()


def expand_fleet(members: Tuple[Compressor, ...], n: int
                 ) -> Tuple[Compressor, ...]:
    """Assign a fleet of compressors to n workers: an explicit length-n list
    is kept as-is, anything shorter is expanded round-robin (worker i gets
    members[i % len(members)])."""
    if not members:
        raise ValueError("empty compressor fleet")
    if len(members) > n:
        raise ValueError(f"fleet of {len(members)} members for only {n} workers")
    if any(getattr(c, "joint", False) for c in members):
        raise ValueError("jointly-defined compressors (m-nice) cannot be "
                         "fleet members: their draws couple all workers")
    return tuple(members[i % len(members)] for i in range(n))


def make_fleet(spec: str, n: int) -> Tuple[Compressor, ...]:
    """Parse a heterogeneous-fleet spec -- ';'-separated compressor specs,
    e.g. 'topk:64;randk:64;qsgd:16' -- and assign it to n workers
    (round-robin when shorter than n, explicit when exactly n).

    Thin delegate into the unified spec grammar (repro.core.specgrammar),
    which also provides the lossless ``format_fleet`` inverse; imported
    lazily because specgrammar imports the compressor classes from here."""
    from repro.core import specgrammar
    return specgrammar.parse_fleet(spec, n)
