"""Roofline-term extraction from compiled executables.

collective_bytes is not in cost_analysis(); we parse the post-SPMD HLO text
and sum the *output* bytes of every communication op (all-gather, all-reduce,
reduce-scatter, all-to-all, collective-permute), per op kind.  Shapes in the
optimized HLO are per-device, so the totals are per-device wire bytes per
step -- exactly the numerator of the collective roofline term.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# v5e hardware constants (assignment)
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+\[[\d,]*\]))\S*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute|"
    r"all-gather-start|all-reduce-start|collective-permute-start)\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind output bytes (per device)."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str = m.group(1) or m.group(2)
        kind = m.group(3).replace("-start", "")
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_str)
    return out


@dataclasses.dataclass
class Roofline:
    """The three roofline terms (seconds) + raw numerators."""

    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: Dict[str, int]
    n_chips: int
    xla_flops: float = 0.0  # raw cost_analysis (undercounts scan bodies)
    xla_bytes: float = 0.0

    @property
    def t_compute(self) -> float:
        # cost_analysis flops are whole-program per-device after SPMD
        return self.hlo_flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    def as_dict(self) -> Dict:
        return {
            "hlo_flops_per_device": self.hlo_flops,
            "hlo_bytes_per_device": self.hlo_bytes,
            "coll_bytes_per_device": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "n_chips": self.n_chips,
            "xla_cost_analysis_flops": self.xla_flops,
            "xla_cost_analysis_bytes": self.xla_bytes,
        }


def analyze(compiled, n_chips: int, hlo_text: Optional[str] = None) -> Roofline:
    """Roofline terms from the compiled artifact.

    Primary source: the trip-count-aware HLO cost model (repro.launch.hlo_cost)
    -- XLA-CPU's cost_analysis() counts while-loop (lax.scan) bodies once
    instead of x trip-count, which under-reports every scan-over-layers model
    here by ~n_layers.  The raw cost_analysis numbers are retained in
    ``xla_flops`` / ``xla_bytes`` for reference.
    """
    from repro.launch import hlo_cost as HC

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):  # older API returned [dict]
        cost = cost[0] if cost else {}
    txt = hlo_text if hlo_text is not None else compiled.as_text()
    c = HC.hlo_cost(txt)
    r = Roofline(
        hlo_flops=c.flops,
        hlo_bytes=c.hbm_bytes,
        coll_bytes=c.coll_bytes,
        coll_breakdown={k: int(v) for k, v in c.coll_breakdown.items()},
        n_chips=n_chips,
    )
    r.xla_flops = float(cost.get("flops", 0.0))
    r.xla_bytes = float(cost.get("bytes accessed", 0.0))
    return r


def memory_stats(compiled) -> Optional[Dict[str, float]]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes"]
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    if not out and isinstance(ma, dict):
        out = {k: float(v) for k, v in ma.items()}
    return out or None
