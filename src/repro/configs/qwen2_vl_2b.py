"""qwen2-vl-2b [arXiv:2409.12191]: M-RoPE + dynamic-resolution VLM.

Language decoder only (vision tower is a stub per the assignment carve-out):
28L x d1536, 12 heads GQA kv=2, ff=8960, vocab 151936.  M-RoPE sections
(16, 24, 24) over head_dim/2 = 64 frequency channels; batches carry
precomputed patch embeddings interleaved before the text tokens."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b", family="vlm",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
        d_ff=8960, vocab=151936, head_dim=128,
        qkv_bias=True, mrope_sections=(16, 24, 24),
        frontend="vision", vision_patches=1024,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke", family="vlm",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
        d_ff=512, vocab=1024, head_dim=64,
        qkv_bias=True, mrope_sections=(8, 12, 12),
        frontend="vision", vision_patches=16,
    )
