"""The paper's experiment on an actual device mesh: EF-BV vs EF21 vs DIANA on
heterogeneous logistic regression, with the compressed aggregation running
through the SAME shard_map trainer used for LM training (not the vmap
reference).  8 fake XLA devices; bits-on-the-wire accounting included.

    PYTHONPATH=src python examples/distributed_logreg.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys  # noqa: E402

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import CompKK, EFBV, tune_for  # noqa: E402
from repro.launch.mesh import make_mesh, num_workers  # noqa: E402
from repro.optim import sgd, constant  # noqa: E402
from repro.problems import LogReg, make_synthetic  # noqa: E402
from repro.train import (  # noqa: E402
    init_train_state, make_train_step, train_state_shardings,
)


def main():
    mesh = make_mesh((8, 1))  # 8 data workers, no model parallelism needed
    n = num_workers(mesh)
    d = 64
    A, b = make_synthetic(jax.random.key(0), N=800, d=d)
    prob = LogReg.split(A, b, n=n, mu_reg=0.1)
    x_star, f_star = prob.solve()

    comp = CompKK(1, d // 2)
    rounds = 2000
    bits_per_round = 32 * 2 * 1  # k=1: one (index, value) pair per worker
    for mode in ["efbv", "ef21", "diana"]:
        t = tune_for(comp, d, n, mode=mode, L=prob.L(), Ltilde=prob.L_tilde())
        algo = EFBV(comp, lam=t.lam, nu=t.nu)
        opt = sgd(constant(t.gamma))

        def loss_fn(params, batch):
            x = params["x"]
            z = -batch["b"][0] * (batch["A"][0] @ x)
            loss = jnp.mean(jnp.logaddexp(0.0, z)) + 0.05 * jnp.sum(x * x) * 2
            return loss, {}

        params = {"x": jnp.zeros(d)}
        state = init_train_state(params, opt, mesh)
        sh = train_state_shardings(mesh, {"x": P(None)}, state)
        state = jax.tree.map(lambda a, s: jax.device_put(a, s), state, sh)
        batch = {
            "A": jax.device_put(prob.A[:, None], NamedSharding(mesh, P("data"))),
            "b": jax.device_put(prob.b[:, None], NamedSharding(mesh, P("data"))),
        }
        step = make_train_step(loss_fn, opt, algo, mesh, agg_mode="dense_psum")
        key = jax.random.key(1)
        for i in range(rounds):
            state, metrics = step(state, batch, jax.random.fold_in(key, i))
        gap = float(prob.f(state.params["x"]) - f_star)
        print(f"{mode:6s} lam={t.lam:.4f} nu={t.nu:.4f} gamma={t.gamma:.2e} "
              f"f-f*={gap:.3e} after {rounds * bits_per_round} bits/worker")


if __name__ == "__main__":
    main()
