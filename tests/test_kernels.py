"""Pallas kernel tests: shape/dtype sweeps + hypothesis-driven random shapes
against the pure-jnp oracle (interpret=True on CPU per assignment)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.core.compressors import BlockTopK
from repro.kernels import ops, ref

KEY = jax.random.key(0)


SWEEP = [
    ((4096,), 512, 16),
    ((1000,), 256, 8),     # padding path
    ((64, 300), 128, 4),   # multi-dim input
    ((8192,), 1024, 64),
    ((128,), 128, 128),    # kb == block: identity
    ((5, 7, 11), 128, 2),  # awkward shape
]


@pytest.mark.parametrize("shape,block,kb", SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_topk_matches_ref(shape, block, kb, dtype):
    x = jax.random.normal(KEY, shape, dtype=dtype)
    got = ops.block_topk(x, block=block, kb=kb)
    want = ref.block_topk_ref(x, block, kb)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


@pytest.mark.parametrize("shape,block,kb", SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_efbv_update_matches_ref(shape, block, kb, dtype):
    g = jax.random.normal(KEY, shape, dtype=dtype)
    h = jax.random.normal(jax.random.key(1), shape, dtype=dtype)
    d1, h1 = ops.efbv_update(g, h, 0.37, block=block, kb=kb)
    d2, h2 = ref.efbv_update_ref(g, h, 0.37, block, kb)
    np.testing.assert_array_equal(np.asarray(d1, np.float32),
                                  np.asarray(d2, np.float32))
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), rtol=1e-6, atol=1e-6)


@given(d=st.integers(1, 3000), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_block_topk_random_sizes(d, seed):
    x = jax.random.normal(jax.random.key(seed), (d,))
    got = ops.block_topk(x, block=128, kb=8)
    want = ref.block_topk_ref(x, 128, 8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_agrees_with_core_compressor():
    """The Pallas op and the core BlockTopK compressor implement the same
    operator (on distinct-magnitude inputs where tie-breaking can't differ)."""
    x = jax.random.normal(KEY, (2048,))
    a = ops.block_topk(x, block=256, kb=16)
    b = BlockTopK(256, 16)(None, x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0)


def test_kernel_is_contraction():
    """Kernel output satisfies the B(kb/block) contraction (DESIGN §3.4)."""
    for seed in range(5):
        x = jax.random.normal(jax.random.key(seed), (4096,))
        y = ops.block_topk(x, block=256, kb=32)
        err = float(jnp.sum((y - x) ** 2))
        bound = (1 - 32 / 256) * float(jnp.sum(x * x))
        assert err <= bound * (1 + 1e-6)


def test_efbv_update_semantics():
    """d is supported on <= kb entries per block; h' = h + lam*d exactly."""
    g = jax.random.normal(KEY, (1024,))
    h = jnp.zeros((1024,))
    lam = 0.25
    d, h_new = ops.efbv_update(g, h, lam, block=256, kb=4)
    nz = np.asarray(d).reshape(4, 256)
    assert ((nz != 0).sum(axis=1) <= 4).all()
    np.testing.assert_allclose(np.asarray(h_new), lam * np.asarray(d), rtol=1e-6)
