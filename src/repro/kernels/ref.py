"""Pure-jnp oracles for the Pallas kernels.

Semantics of block-top-k (shared by kernel and oracle): within each
contiguous block of size ``block``, keep the ``kb`` largest-|.| entries;
ties are broken toward the *lowest index* (matching iterative max
extraction).  This is the TPU-native compressor of DESIGN §3.4 -- a
deterministic member of B(kb/block).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def _select_topk_rows(xa: Array, kb: int) -> Array:
    """xa: (nb, block) magnitudes -> 0/1 mask keeping kb per row with
    first-index tie-breaking (iterative max extraction, vectorized)."""
    nb, block = xa.shape

    def body(_, carry):
        selected = carry
        score = jnp.where(selected > 0, -jnp.inf, xa)
        m = jnp.max(score, axis=1, keepdims=True)
        is_m = (score == m) & jnp.isfinite(m)
        first = (jnp.cumsum(is_m.astype(jnp.int32), axis=1) == 1) & is_m
        return selected + first.astype(xa.dtype)

    selected = jax.lax.fori_loop(0, kb, body, jnp.zeros_like(xa))
    return selected


def block_topk_ref(x: Array, block: int, kb: int) -> Array:
    """Dense block-top-k: zero all but the kb largest-|.| per block."""
    xf = x.reshape(-1)
    d = xf.shape[0]
    nb = -(-d // block)
    pad = nb * block - d
    xp = jnp.pad(xf, (0, pad)).reshape(nb, block)
    mask = _select_topk_rows(jnp.abs(xp).astype(jnp.float32), kb)
    out = xp * mask.astype(xp.dtype)
    return out.reshape(-1)[:d].reshape(x.shape)


def efbv_update_ref(g: Array, h: Array, lam: float, block: int, kb: int
                    ) -> Tuple[Array, Array]:
    """Fused worker-side EF-BV update:
        d = block_topk(g - h);  h_new = h + lam * d.
    Returns (d, h_new).  The subtraction is done in f32 (kernel-identical)."""
    delta = g.astype(jnp.float32) - h.astype(jnp.float32)
    d = block_topk_ref(delta, block, kb).astype(g.dtype)
    return d, (h.astype(jnp.float32) + lam * d.astype(jnp.float32)).astype(h.dtype)
