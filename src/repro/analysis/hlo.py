"""Compiled-artifact analysis: HLO cost model, roofline, dense-free proofs.

This module absorbs the former ``repro.launch.hlo_cost`` (trip-count-aware
cost model over post-SPMD HLO text) and ``repro.launch.hlo_analysis``
(roofline-term extraction); both old import paths remain as thin shims.

On top of those it adds the piece that makes the analyzers a CI *gate*
rather than a per-PR ritual: :func:`dense_free` statically proves that a
registered pack kernel never materializes a d-sized dense buffer outside
its tile-granular VMEM working set.  The proof traces the kernel wrapper to
a jaxpr (no lowering, no TPU needed) and checks

  1. the wrapper stages exactly into a ``pallas_call`` -- no top-level eqn
     creates a new >= d buffer around it (a stray ``astype`` or mask there
     would be a dense HBM pass the fusion docs promised away), and
  2. every value inside the kernel jaxpr (including fori_loop bodies) is
     bounded by the tile size, which itself is a strict fraction of d.

Together these say: the dense compressed delta exists only one tile at a
time, in VMEM -- the EF-BV payload path is O(payload), not O(d), in HBM.

-- cost model rationale (unchanged from the former module) -----------------
On the CPU backend, ``compiled.cost_analysis()`` counts a while-loop body
ONCE -- a lax.scan over 40 layers contributes 1/40th of its real cost,
which breaks the roofline for every scan-based model here.  ``hlo_cost``
re-derives the three roofline numerators directly from the compiled HLO:

  flops       -- 2*M*N*K per dot (descending into fusion computations and
                 multiplying nested while bodies by their trip counts),
  hbm bytes   -- sum of operand+result bytes of *top-level* instructions per
                 computation (XLA's fusion boundaries are exactly the HBM
                 materialization points), trip-count weighted,
  wire bytes  -- per collective kind, with all-reduce counted as 2x payload
                 (ring reduce-scatter + all-gather).

All numbers are per-device (the HLO is the partitioned module).
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Callable, Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"\}?\s*([a-z][\w\-]*)\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    rhs: str
    opcode: str
    result_type: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    types: Dict[str, str]  # value name -> type string (params + results)


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    entry_name = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if current is None:
            if line.endswith("{"):
                m = _COMP_HDR.match(line.strip())
                if m:
                    current = Computation(m.group(2), [], {})
                    if m.group(1):
                        entry_name = m.group(2)
                    # parameter types from the header signature
                    for pm in re.finditer(r"([\w\.\-]+)\s*:\s*((?:\([^)]*\))|[\w\[\],]+)",
                                          m.group(3)):
                        current.types[pm.group(1)] = pm.group(2)
            continue
        if line.strip() == "}":
            comps[current.name] = current
            current = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, rhs = m.group(1), m.group(2)
            om = _OPCODE_RE.search(rhs)
            opcode = om.group(1) if om else ""
            idx = rhs.find(opcode + "(") if opcode else -1
            rtype = rhs[:idx].strip() if idx > 0 else rhs
            ins = Instr(name, rhs, opcode, rtype)
            current.instrs.append(ins)
            current.types[name] = rtype
    if comps and entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _operand_names(ins: Instr) -> List[str]:
    """Operand names of an instruction, robust to both operand syntaxes:
    bare (``dot(%a, %b)``) and inline-typed (``dot(f32[32,64]{1,0} %a, ...)``
    -- older XLA text).  Commas inside ``[]``/``{}`` (shape dims, layouts)
    are not operand separators."""
    idx = ins.rhs.find(ins.opcode + "(")
    if idx < 0:
        return []
    depth, bracket, args, cur = 0, 0, [], ""
    for ch in ins.rhs[idx + len(ins.opcode):]:
        if ch == "(":
            depth += 1
            if depth == 1:
                continue
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if depth < 1:
            continue
        if ch in "[{":
            bracket += 1
        elif ch in "]}":
            bracket -= 1
        if ch == "," and depth == 1 and bracket == 0:
            args.append(cur)
            cur = ""
        else:
            cur += ch
    if cur.strip():
        args.append(cur)
    out = []
    for a in args:
        a = a.strip()
        named = re.findall(r"%([\w\.\-]+)", a)
        if named:
            out.append(named[-1])
            continue
        toks = a.split()
        if toks and re.fullmatch(r"[\w\.\-]+", toks[-1]):
            out.append(toks[-1])
    return out


def _called(ins: Instr) -> List[str]:
    out = []
    for key in ("calls=", "body=", "to_apply=", "condition="):
        for m in re.finditer(re.escape(key) + r"%?([\w\.\-]+)", ins.rhs):
            out.append(m.group(1))
    m = re.search(r"branch_computations=\{([^}]*)\}", ins.rhs)
    if m:
        out.extend(x.strip().lstrip("%") for x in m.group(1).split(","))
    return out


def trip_count(cond: Computation) -> int:
    consts: Dict[str, int] = {}
    best = None
    for ins in cond.instrs:
        m = re.search(r"constant\((\d+)\)", ins.rhs)
        if m:
            consts[ins.name] = int(m.group(1))
    for ins in cond.instrs:
        if "compare(" in ins.rhs:
            for op in _operand_names(ins):
                if op in consts:
                    best = consts[op]
    if best is None:
        best = max(consts.values(), default=1)
    return max(best, 1)


def dot_flops(ins: Instr, types: Dict[str, str]) -> float:
    res = _first_shape_dims(ins.result_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rhs)
    ops = _operand_names(ins)
    k = 1
    if m and ops:
        lhs_dims = _first_shape_dims(types.get(ops[0], ""))
        for c in (int(d) for d in m.group(1).split(",") if d):
            if c < len(lhs_dims):
                k *= lhs_dims[c]
    return 2.0 * float(math.prod(res) if res else 0) * float(k)


def _io_bytes(ins: Instr, types: Dict[str, str]) -> float:
    """HBM traffic of one materialized op: result bytes + operand bytes.

    Slicing/update ops only *touch* the slice, not the whole operand -- a
    dynamic-slice of one layer's weights from the (L, ...) scan stack reads
    the slice, not L x it.  Counting full operands there inflated the memory
    term ~100x on deep models (hypothesis->measure cycle recorded in
    EXPERIMENTS §Perf methodology)."""
    op = ins.opcode
    res = _shape_bytes(ins.result_type)
    ops = _operand_names(ins)
    if op in ("dynamic-slice", "slice"):
        return float(2 * res)  # read slice + write result
    if op == "gather":
        idx = _shape_bytes(types.get(ops[1], "")) if len(ops) > 1 else 0
        return float(2 * res + idx)
    if op == "dynamic-update-slice":
        upd = _shape_bytes(types.get(ops[1], "")) if len(ops) > 1 else 0
        return float(2 * upd)  # in-place: read+write the update region
    if op == "scatter":
        upd = _shape_bytes(types.get(ops[2], "")) if len(ops) > 2 else res
        idx = _shape_bytes(types.get(ops[1], "")) if len(ops) > 1 else 0
        return float(3 * upd + idx)  # read-modify-write of touched region
    total = res
    for name in ops:
        total += _shape_bytes(types.get(name, ""))
    return float(total)


_SLICING = ("dynamic-slice", "slice", "gather")


def _param_names_of(comp: "Computation") -> Dict[int, str]:
    out: Dict[int, str] = {}
    for b_ins in comp.instrs:
        m = re.search(r"parameter\((\d+)\)", b_ins.rhs)
        if m:
            out[int(m.group(1))] = b_ins.name
    return out


def _sliced_only_bytes(body: "Computation", pname: str,
                       comps: Dict[str, "Computation"], seen) -> Optional[float]:
    """Bytes actually read from parameter ``pname`` of ``body`` when its
    every use is a slicing op -- descending through nested fusion/call
    wrappers (older XLA wraps the scan-stack dynamic-slice in a parallel
    call computation).  None if any consumer reads the full operand."""
    key = (body.name, pname)
    if key in seen:
        return None
    seen = seen | {key}
    consumers = [b for b in body.instrs if pname in _operand_names(b)]
    if not consumers:
        return None  # conservatively charge the full operand
    total = 0.0
    for c in consumers:
        if c.opcode in _SLICING:
            total += _shape_bytes(c.result_type)
        elif c.opcode in ("fusion", "call"):
            called = [comps[x] for x in _called(c) if x in comps]
            if not called:
                return None
            inner = called[0]
            inner_params = _param_names_of(inner)
            # the operand may be passed at several positions; every one must
            # be slice-only inside the callee
            positions = [i for i, o in enumerate(_operand_names(c))
                         if o == pname]
            for pos in positions:
                inner_pname = inner_params.get(pos)
                if inner_pname is None:
                    return None
                sub = _sliced_only_bytes(inner, inner_pname, comps, seen)
                if sub is None:
                    return None
                total += sub
        else:
            return None
    return total


def _fusion_io_bytes(ins: Instr, types: Dict[str, str],
                     body: Optional["Computation"],
                     comps: Optional[Dict[str, "Computation"]] = None) -> float:
    """Fusion boundary traffic with slice-awareness: when a fusion *parameter*
    is only consumed by slicing ops inside the body (the scan-stack weight
    lookup pattern), charge the slice sizes, not the full stacked operand."""
    ops = _operand_names(ins)
    # in-place accumulation pattern: fusion rooted in dynamic-update-slice
    # aliases its big buffer operand -- traffic is the update region, not the
    # whole (L, ...) stack (and the result is the aliased buffer, also not
    # re-written in full).
    root = body.instrs[-1] if (body and body.instrs) else None
    if root is not None and root.opcode == "dynamic-update-slice":
        upd_ops = _operand_names(root)
        upd = _shape_bytes(body.types.get(upd_ops[1], "")) if len(upd_ops) > 1 \
            else 0
        small = 0
        res_b = _shape_bytes(ins.result_type)
        for name in ops:
            b = _shape_bytes(types.get(name, ""))
            if b != res_b:  # skip the aliased buffer itself
                small += min(b, res_b)
        return float(2 * upd + small)

    total = _shape_bytes(ins.result_type)
    if body is None:
        for name in ops:
            total += _shape_bytes(types.get(name, ""))
        return float(total)
    # map parameter index -> param instr name inside the body
    param_names = _param_names_of(body)
    for i, name in enumerate(ops):
        full = _shape_bytes(types.get(name, ""))
        pname = param_names.get(i)
        if pname is None:
            total += full
            continue
        sliced = _sliced_only_bytes(body, pname, comps or {}, frozenset())
        total += full if sliced is None else sliced
    return float(total)


_COLL_WEIGHT = {
    "all-reduce": 2.0,        # ring RS + AG
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SKIP_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "",
}


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_breakdown: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.hbm_bytes += other.hbm_bytes
        self.coll_bytes += other.coll_bytes
        for k, v in other.coll_breakdown.items():
            self.coll_breakdown[k] = self.coll_breakdown.get(k, 0.0) + v
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.hbm_bytes * f, self.coll_bytes * f,
                    {k: v * f for k, v in self.coll_breakdown.items()})


def _fusion_flops(comp: Computation, comps, memo) -> float:
    if comp.name in memo:
        return memo[comp.name]
    memo[comp.name] = 0.0
    total = 0.0
    for ins in comp.instrs:
        if ins.opcode == "dot":
            total += dot_flops(ins, comp.types)
        elif ins.opcode == "convolution":
            total += 2.0 * float(math.prod(_first_shape_dims(ins.result_type)) or 0)
        elif ins.opcode in ("fusion", "call"):
            for c in _called(ins):
                if c in comps:
                    total += _fusion_flops(comps[c], comps, memo)
    memo[comp.name] = total
    return total


def computation_cost(comp: Computation, comps: Dict[str, Computation],
                     memo: Dict[str, Cost],
                     flop_memo: Dict[str, float]) -> Cost:
    if comp.name in memo:
        return memo[comp.name]
    memo[comp.name] = Cost()  # cycle guard
    total = Cost()
    for ins in comp.instrs:
        op = ins.opcode
        if op == "while":
            bm = re.search(r"body=%?([\w\.\-]+)", ins.rhs)
            cm = re.search(r"condition=%?([\w\.\-]+)", ins.rhs)
            trips = trip_count(comps[cm.group(1)]) if (cm and cm.group(1) in comps) else 1
            if bm and bm.group(1) in comps:
                total += computation_cost(comps[bm.group(1)], comps, memo,
                                          flop_memo).scaled(trips)
            continue
        if op == "conditional":
            for c in _called(ins):
                if c in comps:
                    total += computation_cost(comps[c], comps, memo, flop_memo)
            continue
        if op in ("fusion", "call"):
            called = [comps[c] for c in _called(ins) if c in comps]
            for c in called:
                total.flops += _fusion_flops(c, comps, flop_memo)
            total.hbm_bytes += _fusion_io_bytes(
                ins, comp.types, called[0] if called else None, comps)
            continue
        if op == "dot":
            total.flops += dot_flops(ins, comp.types)
            total.hbm_bytes += _io_bytes(ins, comp.types)
            continue
        if op == "convolution":
            total.flops += 2.0 * float(math.prod(_first_shape_dims(ins.result_type)) or 0)
            total.hbm_bytes += _io_bytes(ins, comp.types)
            continue
        base = op.replace("-start", "")
        if base in _COLL_WEIGHT and not op.endswith("-done"):
            payload = _shape_bytes(ins.result_type)
            w = _COLL_WEIGHT[base]
            total.coll_bytes += payload * w
            total.coll_breakdown[base] = total.coll_breakdown.get(base, 0.0) \
                + payload * w
            total.hbm_bytes += _io_bytes(ins, comp.types)
            continue
        if op in _SKIP_OPS or op.endswith("-done"):
            continue
        total.hbm_bytes += _io_bytes(ins, comp.types)
    memo[comp.name] = total
    return total


def hlo_cost(hlo_text: str) -> Cost:
    comps = parse_computations(hlo_text)
    entry = comps.get("__entry__")
    if entry is None:
        if not comps:
            return Cost()
        entry = max(comps.values(), key=lambda c: len(c.instrs))
    return computation_cost(entry, comps, {}, {})


# ---------------------------------------------------------------------------
# roofline-term extraction (former repro.launch.hlo_analysis)
# ---------------------------------------------------------------------------

# v5e hardware constants (assignment)
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+\[[\d,]*\]))\S*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute|"
    r"all-gather-start|all-reduce-start|collective-permute-start)\(")


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind output bytes (per device)."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str = m.group(1) or m.group(2)
        kind = m.group(3).replace("-start", "")
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_str)
    return out


@dataclasses.dataclass
class Roofline:
    """The three roofline terms (seconds) + raw numerators."""

    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: Dict[str, int]
    n_chips: int
    xla_flops: float = 0.0  # raw cost_analysis (undercounts scan bodies)
    xla_bytes: float = 0.0

    @property
    def t_compute(self) -> float:
        # cost_analysis flops are whole-program per-device after SPMD
        return self.hlo_flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    def as_dict(self) -> Dict:
        return {
            "hlo_flops_per_device": self.hlo_flops,
            "hlo_bytes_per_device": self.hlo_bytes,
            "coll_bytes_per_device": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "n_chips": self.n_chips,
            "xla_cost_analysis_flops": self.xla_flops,
            "xla_cost_analysis_bytes": self.xla_bytes,
        }


def analyze(compiled, n_chips: int, hlo_text: Optional[str] = None) -> Roofline:
    """Roofline terms from the compiled artifact.

    Primary source: the trip-count-aware HLO cost model above -- XLA-CPU's
    cost_analysis() counts while-loop (lax.scan) bodies once instead of
    x trip-count, which under-reports every scan-over-layers model here by
    ~n_layers.  The raw cost_analysis numbers are retained in ``xla_flops``
    / ``xla_bytes`` for reference.
    """
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):  # older API returned [dict]
        cost = cost[0] if cost else {}
    txt = hlo_text if hlo_text is not None else compiled.as_text()
    c = hlo_cost(txt)
    r = Roofline(
        hlo_flops=c.flops,
        hlo_bytes=c.hbm_bytes,
        coll_bytes=c.coll_bytes,
        coll_breakdown={k: int(v) for k, v in c.coll_breakdown.items()},
        n_chips=n_chips,
    )
    r.xla_flops = float(cost.get("flops", 0.0))
    r.xla_bytes = float(cost.get("bytes accessed", 0.0))
    return r


def memory_stats(compiled) -> Optional[Dict[str, float]]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes"]
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    if not out and isinstance(ma, dict):
        out = {k: float(v) for k, v in ma.items()}
    return out or None


# ---------------------------------------------------------------------------
# dense-free proofs over the registered pack kernels
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DenseFreeReport:
    """The evidence behind one dense-free verdict (``as_dict`` goes to CI)."""

    kernel: str
    d: int                    #: dense element count of the full problem
    tile: int                 #: largest kernel-visible ref (elements)
    max_inner: int            #: largest value inside the kernel jaxpr
    n_pallas_calls: int
    violations: List[str]

    @property
    def ok(self) -> bool:
        return not self.violations

    def as_dict(self) -> dict:
        return {"kernel": self.kernel, "d": self.d, "tile": self.tile,
                "max_inner": self.max_inner, "ok": self.ok,
                "violations": list(self.violations)}


def _aval_size(v) -> int:
    aval = getattr(v, "aval", None)
    shape = getattr(aval, "shape", None)
    if shape is None:
        return 0
    return int(math.prod(shape)) if shape else 1


def _inner_jaxprs(params: dict):
    """Every jaxpr-valued entry of an eqn's params (scan/while bodies,
    pallas kernels, custom_* wrappers), across jax versions."""
    for val in params.values():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            if hasattr(v, "eqns") or hasattr(v, "jaxpr"):
                yield getattr(v, "jaxpr", v)


def _walk_sizes(jaxpr, out: List[int]) -> None:
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            out.append(_aval_size(v))
        for sub in _inner_jaxprs(eqn.params):
            _walk_sizes(sub, out)


def dense_free(name: str) -> DenseFreeReport:
    """Statically prove the registered pack kernel ``name`` materializes no
    d-sized dense buffer: trace to a jaxpr (no lowering; runs on CPU) and
    bound every intermediate by the tile size.

    The dense inputs (g, h) and the dense state output h_new are exempt by
    construction -- they are the algorithm's state, written one tile per
    grid step; what must never exist is a NEW dense buffer holding the
    compressed delta d = C(g - h)."""
    import jax

    fn, example_args, d = PACK_KERNELS[name]()
    jaxpr = jax.make_jaxpr(fn)(*example_args).jaxpr
    violations: List[str] = []

    pallas_eqns = [e for e in jaxpr.eqns if e.primitive.name == "pallas_call"]
    if not pallas_eqns:
        violations.append("no pallas_call primitive in the traced jaxpr")
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            continue
        for v in eqn.outvars:
            if _aval_size(v) >= d:
                violations.append(
                    f"top-level {eqn.primitive.name} materializes a "
                    f"{_aval_size(v)}-element buffer (d = {d}) outside "
                    "the kernel")

    tile = 0
    max_inner = 0
    for eqn in pallas_eqns:
        inners = list(_inner_jaxprs(eqn.params))
        if not inners:
            violations.append("pallas_call carries no inner jaxpr to check")
            continue
        kernel_jaxpr = inners[0]
        tile = max(tile, max((_aval_size(v) for v in kernel_jaxpr.invars),
                             default=0))
        sizes: List[int] = []
        _walk_sizes(kernel_jaxpr, sizes)
        max_inner = max([max_inner] + sizes)
    if pallas_eqns and not violations:
        if tile >= d:
            violations.append(
                f"tile covers the whole problem (tile = {tile} >= d = {d}); "
                "grid must split d so only a fraction is live at once")
        if max_inner > tile:
            violations.append(
                f"kernel-internal value of {max_inner} elements exceeds the "
                f"tile ({tile}) -- the kernel builds something denser than "
                "its VMEM working set")

    return DenseFreeReport(kernel=name, d=d, tile=tile, max_inner=max_inner,
                           n_pallas_calls=len(pallas_eqns),
                           violations=violations)


def _block_topk_case():
    import jax.numpy as jnp
    from repro.kernels import pack

    nb, block, kb = 32, 128, 4
    g = jnp.zeros((nb, block), jnp.float32)
    h = jnp.zeros((nb, block), jnp.float32)
    fn = lambda g, h: pack.pack_update_pallas(g, h, 0.5, kb)
    return fn, (g, h), nb * block


def _randk_case():
    import jax.numpy as jnp
    from repro.kernels import pack

    nr, cols, k = 32, 128, 16
    g = jnp.zeros((nr, cols), jnp.float32)
    h = jnp.zeros((nr, cols), jnp.float32)
    idx = jnp.zeros((k,), jnp.int32)
    fn = lambda g, h, idx: pack.randk_update_pallas(g, h, idx, 2.0, 0.5)
    return fn, (g, h, idx), nr * cols


def _qsgd_case():
    import jax.numpy as jnp
    from repro.kernels import pack

    nr, cols, s = 64, 128, 16
    g = jnp.zeros((nr, cols), jnp.float32)
    h = jnp.zeros((nr, cols), jnp.float32)
    u = jnp.zeros((nr, cols), jnp.float32)
    norm = jnp.ones((1, 1), jnp.float32)
    fn = lambda g, h, u, norm: pack.qsgd_pack_update_pallas(g, h, u, norm,
                                                            s, 0.5)
    return fn, (g, h, u, norm), nr * cols


#: name -> zero-arg builder returning (traceable fn, example args, d).
#: Every fused pack kernel MUST be registered here: the CI lint job runs
#: ``python -m repro.analysis --hlo-gate`` which proves each one dense-free.
PACK_KERNELS: Dict[str, Callable[[], Tuple[Callable, tuple, int]]] = {
    "block_topk_pack": _block_topk_case,
    "randk_update": _randk_case,
    "qsgd_pack": _qsgd_case,
}


def gate(names: Optional[List[str]] = None) -> List[DenseFreeReport]:
    """Run the dense-free proof over (a subset of) the registry."""
    return [dense_free(n) for n in (names or sorted(PACK_KERNELS))]
