"""qwen2-0.5b [arXiv:2407.10671]: GQA with QKV bias.

24L x d896, 14 heads GQA kv=2, ff=4864, vocab 151936, tied embeddings.  The
smallest assigned arch -- its roofline is dominated by the 152k-vocab LM head
relative to the 0.5B body."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b", family="dense",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
        d_ff=4864, vocab=151936, head_dim=64,
        qkv_bias=True, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b-smoke", family="dense",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
        d_ff=512, vocab=1024, head_dim=64,
        qkv_bias=True, tie_embeddings=True,
    )
