"""The pytree-native wire layer and its per-leaf differential harness.

Four contracts, each pinned exactly:

1. SINGLE-LEAF PARITY -- :func:`harness.run_tree_trajectory` over a
   single-leaf pytree is BIT-identical to the flat-vector
   :func:`harness.run_trajectory` for every codec in the zoo, in every
   execution mode (full / federated / bidirectional / pipelined), on every
   pack backend the codec has.
2. NESTED DIFFERENTIAL -- on genuinely nested trees with mixed per-leaf
   codecs (block-top-k / QSGD / dense), oracle == interpret (== compiled on
   TPU), including the real qwen2-0.5b smoke parameter tree.
3. COMPOSED ACCOUNTING -- the TreeWire's composed ``bits_per_round`` is
   EXACTLY the sum of its per-leaf bits, independent of leaf order, and
   ``payload_bytes`` of a real message equals bits / 8.
4. DEGENERATE LEAVES -- 0-d, size-1 and size < k leaves encode, decode,
   zero-message and mask-message without clamping crashes (the per-leaf
   compressor is clamped to the leaf's size), including the pipelined
   schedule's priming payload.

Plus the negative paths: every inconsistent-combo SpecError added since the
spec PR asserted VERBATIM, and the new leaf_codecs rejections with them.
"""

import random
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import harness
from _prop import given, settings, st
from conftest import run_with_devices
from repro.core import ExperimentSpec, SpecError
from repro.core.compressors import make_compressor
from repro.core.spec import REFERENCE_PROBLEMS
from repro.distributed import wire

# every codec in the zoo, as compressor specs (d = 64 in the parity legs)
ZOO = ["identity", "topk:8", "randk:8", "scaled_randk:8", "comp:4,16",
       "mix:4,4", "block_topk:32,4", "sign", "natural", "qsgd:16",
       "frac_topk:125"]


def _spec(comp, **kw):
    base = dict(compressor=comp, problem="quadratic", backend="reference",
                n=4, d=64, steps=3, gamma=0.05)
    base.update(kw)
    return ExperimentSpec(**base)


def _assert_same_trajectory(a, b, context):
    harness.assert_bit_identical(a["x"], b["x"], context + " x")
    harness.assert_bit_identical(a["h"], b["h"], context + " h")
    assert a["round_bits"] == b["round_bits"], context


# ---------------------------------------------------------------------------
# 1. single-leaf pytree == flat vector, bit-for-bit, whole zoo
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("comp", ZOO)
def test_single_leaf_parity_whole_zoo(comp):
    spec = _spec(comp)
    codec = wire.codec_of(make_compressor(comp), (64,), 64, "float32")
    for kernel in harness.codec_impls(codec):
        a = harness.run_trajectory(spec, kernel)
        b = harness.run_tree_trajectory(spec, kernel)
        _assert_same_trajectory(a, b, f"{comp}/{kernel}")
        harness.assert_bit_identical(a["payload"], b["payload"][0],
                                     f"{comp}/{kernel} payload")
        assert b["bits_by_leaf"] == (codec.payload_bits,)


def test_single_leaf_parity_federated():
    spec = _spec("qsgd:16", participation="bernoulli:0.7")
    a = harness.run_trajectory(spec)
    b = harness.run_tree_trajectory(spec)
    _assert_same_trajectory(a, b, "federated")
    harness.assert_bit_identical(a["masks"], b["masks"], "federated masks")


def test_single_leaf_parity_bidirectional():
    spec = _spec("block_topk:32,4", downlink="qsgd:16")
    a = harness.run_trajectory(spec)
    b = harness.run_tree_trajectory(spec)
    _assert_same_trajectory(a, b, "bidirectional")
    harness.assert_bit_identical(a["w"], b["w"], "bidirectional w")


def test_single_leaf_parity_pipelined():
    spec = _spec("randk:8", backend="shard_map", mesh="4x1",
                 pipeline="depth:1")
    a = harness.run_trajectory(spec)
    b = harness.run_tree_trajectory(spec)
    _assert_same_trajectory(a, b, "pipelined")
    harness.assert_bit_identical(a["pending"], b["pending"][0],
                                 "pipelined in-flight buffer")


# ---------------------------------------------------------------------------
# 2. nested trees, mixed codecs: oracle == interpret (== pallas on TPU)
# ---------------------------------------------------------------------------

NESTED_TREE = {"embed": jnp.zeros((16, 8)),
               "mlp": {"w": jnp.zeros((64,)), "bias": jnp.zeros((1,))},
               "scale": jnp.zeros(())}
MIXED_RULES = "embed*=qsgd:16;*bias=identity"


def test_nested_mixed_codecs_differential():
    spec = _spec("block_topk:32,4", leaf_codecs=MIXED_RULES)
    ref = harness.run_tree_trajectory(spec, "oracle", tree=NESTED_TREE)
    kinds = [c.kind for c in ref["fmt"].leaves]
    assert kinds == ["qsgd_quant", "dense_pack", "block_sparse",
                     "block_sparse"]
    for kernel in harness.available_pack_impls()[1:]:
        out = harness.run_tree_trajectory(spec, kernel, tree=NESTED_TREE)
        _assert_same_trajectory(ref, out, f"nested oracle vs {kernel}")


def test_nested_pipelined_differential():
    spec = _spec("block_topk:32,4", backend="shard_map", mesh="4x1",
                 pipeline="depth:1", leaf_codecs=MIXED_RULES)
    ref = harness.run_tree_trajectory(spec, "oracle", tree=NESTED_TREE)
    for kernel in harness.available_pack_impls()[1:]:
        out = harness.run_tree_trajectory(spec, kernel, tree=NESTED_TREE)
        _assert_same_trajectory(ref, out, f"pipelined nested vs {kernel}")
        harness.assert_bit_identical(ref["pending"], out["pending"],
                                     "pipelined nested in-flight")
    assert all(bool(jnp.all(jnp.isfinite(l)))
               for l in jax.tree.leaves(ref["x"]))


def test_qwen2_param_tree_mixed_codecs():
    """The ISSUE's proof obligation (b): the REAL qwen2-0.5b (smoke)
    parameter tree with mixed block-top-k / QSGD / dense leaves runs the
    identical trajectory through every available pack backend, and the
    composed accounting is exactly the per-leaf sum."""
    from repro.configs import get_smoke_config
    from repro.models import build_model

    params = build_model(get_smoke_config("qwen2-0.5b")).init(
        jax.random.key(0))
    spec = ExperimentSpec(
        compressor="block_topk:256,16", problem="quadratic",
        backend="reference", n=2, d=131072, steps=2, gamma=0.01,
        leaf_codecs="*embed*=qsgd:16;*norm*=identity")
    ref = harness.run_tree_trajectory(spec, "oracle", tree=params)
    kinds = {c.kind for c in ref["fmt"].leaves}
    assert kinds == {"block_sparse", "qsgd_quant", "dense_pack"}
    assert ref["round_bits"]["up"] == spec.n * sum(ref["bits_by_leaf"])
    for kernel in harness.available_pack_impls()[1:]:
        out = harness.run_tree_trajectory(spec, kernel, tree=params)
        _assert_same_trajectory(ref, out, f"qwen2 oracle vs {kernel}")


# ---------------------------------------------------------------------------
# 3. composed accounting
# ---------------------------------------------------------------------------

def test_composed_bits_is_sum_of_leaf_bits():
    fmt = wire.TreeWire.for_tree(
        make_compressor("block_topk:32,4"), NESTED_TREE,
        rules=wire.parse_leaf_rules(MIXED_RULES))
    per_worker = fmt.bits_per_round()
    assert per_worker == sum(fmt.bits_by_leaf())
    assert fmt.bits_per_round(n_workers=4) == 4 * per_worker
    assert fmt.dense_bits() == 32 * (16 * 8 + 64 + 1 + 1)


def test_composed_bits_leaf_order_independent():
    """Permuting WHERE each (path, leaf) pair sits in the tree structure
    cannot move the composed accounting: rules follow the path, so the
    per-leaf bit multiset -- and its sum -- is structure-order free."""
    comp = make_compressor("topk:8")
    rules = wire.parse_leaf_rules("*embed*=qsgd:16")
    named = [("embed", jnp.zeros((16, 8))), ("w", jnp.zeros((64,))),
             ("tiny", jnp.zeros((5,)))]
    layouts = [dict(named),
               {"outer": dict(named[::-1])},
               (dict(named[:1]), dict(named[1:]))]
    fmts = [wire.TreeWire.for_tree(comp, t, rules=rules) for t in layouts]
    assert len({f.bits_per_round() for f in fmts}) == 1
    assert len({tuple(sorted(f.bits_by_leaf())) for f in fmts}) == 1


# ---------------------------------------------------------------------------
# 4. property tests: random nested pytrees (seed-driven, so the _prop shim
#    and real hypothesis both drive them)
# ---------------------------------------------------------------------------

_LEAF_SHAPES = [(), (1,), (7,), (64,), (3, 5), (16, 8), (2, 2, 3)]
_LEAF_DTYPES = [jnp.float32, jnp.bfloat16]
_PROP_COMPS = ["topk:8", "randk:8", "block_topk:32,4", "qsgd:16", "sign",
               "identity", "mix:4,4", "comp:4,16"]


def _random_tree(rng: random.Random):
    """A random nested pytree of dict/tuple/list nodes with 1..6 mixed
    f32/bf16 leaves, always including at least one degenerate (0-d or
    size-1) leaf candidate in the shape pool."""
    n_leaves = rng.randint(1, 6)
    leaves = [jnp.zeros(rng.choice(_LEAF_SHAPES),
                        rng.choice(_LEAF_DTYPES)) for _ in range(n_leaves)]

    def nest(ls):
        if len(ls) == 1 and rng.random() < 0.5:
            return ls[0]
        kind = rng.choice(["dict", "tuple", "list"])
        if kind == "dict":
            return {f"k{i}": l for i, l in enumerate(ls)}
        if len(ls) >= 2 and rng.random() < 0.4:
            split = rng.randint(1, len(ls) - 1)
            inner = nest(ls[split:])
            ls = ls[:split] + [inner]
        return tuple(ls) if kind == "tuple" else list(ls)

    return nest(leaves)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6))
def test_prop_decode_encode_equals_dense_per_leaf(seed):
    """decode(encode(delta)) == the (clamped) dense compressor output,
    bit-for-bit, on EVERY leaf of a random nested tree."""
    rng = random.Random(seed)
    tree = _random_tree(rng)
    base = make_compressor(rng.choice(_PROP_COMPS))
    rules = ()
    if rng.random() < 0.6:
        rules = wire.parse_leaf_rules(
            f"*k0*={rng.choice(_PROP_COMPS)};*k1*={rng.choice(_PROP_COMPS)}")
    fmt = wire.TreeWire.for_tree(base, tree, rules=rules)
    key = jax.random.key(seed)
    ks = fmt.leaf_keys(key)
    flat = jax.tree_util.tree_leaves(tree)
    for j, (codec, comp, leaf) in enumerate(
            zip(fmt.leaves, fmt.compressors, flat)):
        kj = ks[j]
        delta = jax.random.normal(jax.random.fold_in(jax.random.key(7), j),
                                  jnp.shape(leaf), jnp.float32)
        payload = codec.encode(kj, delta.reshape(-1))
        dec = codec.decode(payload).reshape(jnp.shape(leaf))
        dense = comp(kj, delta)
        np.testing.assert_array_equal(np.asarray(dec), np.asarray(dense),
                                      err_msg=f"leaf {fmt.paths[j]} "
                                              f"codec {codec.kind}")


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6))
def test_prop_payload_bytes_equals_bits(seed):
    """What actually crosses the wire -- payload_bytes of a real encoded
    message -- is EXACTLY bits_per_round / 8, per leaf and composed."""
    rng = random.Random(seed)
    tree = _random_tree(rng)
    fmt = wire.TreeWire.for_tree(make_compressor(rng.choice(_PROP_COMPS)),
                                 tree)
    ks = fmt.leaf_keys(jax.random.key(seed))
    total = 0
    for j, codec in enumerate(fmt.leaves):
        payload = codec.encode(ks[j], jnp.arange(codec.size,
                                                 dtype=jnp.float32))
        assert wire.payload_bytes(payload) == codec.payload_bits // 8
        assert codec.payload_bits % 8 == 0
        total += codec.payload_bits
    assert fmt.bits_per_round() == total


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6))
def test_prop_zero_messages_decode_to_zero(seed):
    """The pipelined priming payload decodes to EXACTLY zero on every leaf
    of a random tree -- including degenerate 0-d / size-1 leaves."""
    rng = random.Random(seed)
    tree = _random_tree(rng)
    fmt = wire.TreeWire.for_tree(make_compressor(rng.choice(_PROP_COMPS)),
                                 tree)
    zmsgs = fmt.zero_messages(jax.random.key(seed))
    dense = fmt.decode(zmsgs)
    for leaf in jax.tree_util.tree_leaves(dense):
        np.testing.assert_array_equal(np.asarray(leaf),
                                      np.zeros(leaf.shape, np.float32))


# ---------------------------------------------------------------------------
# 5. degenerate leaves: size-1, 0-d and size < k (satellite: the
#    zero_message / mask_message priming regression)
# ---------------------------------------------------------------------------

DEGENERATE_TREE = {"scalar": jnp.zeros(()), "one": jnp.zeros((1,)),
                   "tiny": jnp.zeros((3,)), "wide": jnp.zeros((64,))}


@pytest.mark.parametrize("comp", ["topk:8", "randk:8", "scaled_randk:8",
                                  "block_topk:32,4", "mix:4,4", "comp:4,16",
                                  "qsgd:16", "sign", "natural"])
def test_degenerate_leaves_encode_decode_zero_mask(comp):
    """k > leaf size clamps per leaf: encode, decode, zero_message and
    mask_message all work on 0-d / size-1 / size-3 leaves, and the masked
    zero message still decodes to exactly zero."""
    fmt = wire.TreeWire.for_tree(make_compressor(comp), DEGENERATE_TREE)
    key = jax.random.key(3)
    ks = fmt.leaf_keys(key)
    for j, codec in enumerate(fmt.leaves):
        delta = jax.random.normal(jax.random.fold_in(key, 100 + j),
                                  (codec.size,), jnp.float32)
        payload = codec.encode(ks[j], delta)
        dec = codec.decode(payload)
        assert dec.shape == (codec.size,)
        zero = wire.zero_message(codec, ks[j])
        np.testing.assert_array_equal(np.asarray(codec.decode(zero)),
                                      np.zeros((codec.size,), np.float32))
        masked = codec.mask_message(payload, jnp.float32(0.0))
        np.testing.assert_array_equal(np.asarray(codec.decode(masked)),
                                      np.zeros((codec.size,), np.float32))


def test_degenerate_tree_pipelined_priming_trajectory():
    """The regression the clamp exists for: a pipelined trajectory over a
    tree with size-1 / size<k leaves primes, streams and decodes without
    crashing, and stays finite."""
    spec = _spec("block_topk:32,4", backend="shard_map", mesh="4x1",
                 pipeline="depth:1", steps=3)
    out = harness.run_tree_trajectory(spec, tree=DEGENERATE_TREE)
    assert all(bool(jnp.all(jnp.isfinite(l)))
               for l in jax.tree.leaves(out["x"]))
    # the priming buffer itself: one stacked zero message per leaf
    assert len(out["pending"]) == len(out["fmt"].leaves)


def test_clamp_for_leaf_identity_when_no_clamp_needed():
    """clamp_for_leaf returns the SAME object when k fits -- hashing (and
    so jit caches and spec fingerprints) cannot be perturbed."""
    for comp in ["topk:8", "randk:8", "block_topk:32,4", "mix:4,4",
                 "comp:4,16", "qsgd:16", "sign", "identity"]:
        c = make_compressor(comp)
        assert wire.clamp_for_leaf(c, 64) is c
    small = wire.clamp_for_leaf(make_compressor("topk:8"), 3)
    assert small.k == 3
    mix = wire.clamp_for_leaf(make_compressor("mix:4,4"), 5)
    assert (mix.k, mix.kp) == (4, 1)  # k + kp <= d, no double-counting


# ---------------------------------------------------------------------------
# 6. negative paths: inconsistent spec combos, messages VERBATIM
# ---------------------------------------------------------------------------

def _verbatim(msg):
    return "^" + re.escape(msg) + "$"


def test_rejects_pipelined_reference_verbatim():
    with pytest.raises(SpecError, match=_verbatim(
            "the pipelined schedule double-buffers the trainer's wire "
            "payload; the reference backend runs the exact sequential "
            "recursion (set pipeline='off', or backend='shard_map' / "
            "'fsdp')")):
        ExperimentSpec(n=2, d=8, pipeline="depth:1")


def test_rejects_smoke_reference_problem_verbatim():
    with pytest.raises(SpecError, match=_verbatim(
            "spec.smoke selects a model arch's reduced config; the "
            f"built-in problems {REFERENCE_PROBLEMS} are sized by "
            "spec.d/n")):
        ExperimentSpec(n=2, d=8, smoke=True)


def test_rejects_reference_mesh_verbatim():
    with pytest.raises(SpecError, match=_verbatim(
            "spec.mesh is a trainer-backend field; the reference backend "
            "takes n directly (set mesh='')")):
        ExperimentSpec(n=2, d=8, mesh="2x1")


def test_rejects_resample_quadratic_verbatim():
    with pytest.raises(SpecError, match=_verbatim(
            "the quadratic problem has exact gradients only; "
            "resample=True needs problem='logreg' or a trainer backend")):
        ExperimentSpec(n=2, d=8, resample=True)


def test_rejects_mesh_worker_mismatch_verbatim():
    with pytest.raises(SpecError, match=_verbatim(
            "spec.n = 4 but mesh '2x2' has 2 workers (product of the "
            "non-'model' axes)")):
        ExperimentSpec(compressor="qsgd:16", backend="shard_map",
                       problem="quadratic", mesh="2x2", n=4, d=8)


def test_rejects_leaf_codecs_with_fleet_verbatim():
    with pytest.raises(SpecError, match=_verbatim(
            "spec.leaf_codecs assigns compressors per LEAF of one uplink "
            "compressor; a heterogeneous fleet assigns them per WORKER -- "
            "use one or the other (got compressor='topk:8;qsgd:16')")):
        ExperimentSpec(compressor="topk:8;qsgd:16", n=2, d=8,
                       leaf_codecs="*=sign")


def test_rejects_leaf_codecs_mode_none_verbatim():
    with pytest.raises(SpecError, match=_verbatim(
            "spec.leaf_codecs configures the compression layer's wire; "
            "mode='none' has no compression layer")):
        ExperimentSpec(mode="none", n=2, d=8, leaf_codecs="*=sign")


def test_rejects_malformed_leaf_rule_verbatim():
    with pytest.raises(ValueError, match=_verbatim(
            "leaf-codec rule '=qsgd:16' needs both a leaf-path pattern "
            "and a compressor spec around the '='")):
        ExperimentSpec(n=2, d=8, leaf_codecs="=qsgd:16")


def test_rejects_unknown_compressor_in_leaf_rule():
    with pytest.raises(ValueError, match="unknown compressor 'mnice'"):
        ExperimentSpec(n=2, d=8, leaf_codecs="embed*=mnice:4,2")


def test_rejects_joint_leaf_rule_verbatim():
    """The string grammar cannot name a joint compressor, so the guard is
    exercised on the programmatic EFBV.make path (same message as
    wire.parse_leaf_rules' own)."""
    from repro.core.compressors import MNice, TopK
    from repro.core.efbv import EFBV
    with pytest.raises(ValueError, match=_verbatim(
            "jointly-defined compressors (m-nice) cannot be leaf-codec "
            "rules: their draws couple all workers")):
        EFBV.make(TopK(4), d=16, n=4, leaf_rules=(("*", MNice(4, 2)),))


def test_rejects_fleet_plus_leaf_rules_in_efbv_make():
    from repro.core.compressors import QSGD, TopK
    from repro.core.efbv import EFBV
    with pytest.raises(ValueError, match=_verbatim(
            "per-leaf codec rules cannot be combined with a heterogeneous "
            "worker fleet")):
        EFBV.make([TopK(4), QSGD(16)], d=16, n=2,
                  leaf_rules=(("*", QSGD(16)),))


# ---------------------------------------------------------------------------
# 7. tuning composition over leaves
# ---------------------------------------------------------------------------

def test_tree_constants_single_leaf_noop():
    """ONE leaf: tree composition is exactly the leaf's own constants --
    the tuning (and so every existing fingerprinted run) cannot move."""
    from repro.core import theory
    c = make_compressor("topk:8")
    eta, omega = c.eta(64), c.omega(64)
    for agg in ("worst", "mean"):
        e, o, oav = theory.tree_constants([eta], [omega], [64], n=4,
                                          aggregate=agg)
        assert (e, o) == (eta, omega)
        assert oav == omega / 4
    flat = theory.tune_for(c, d=64, n=4)
    tree = theory.tune_tree([eta], [omega], [64], n=4)
    assert (flat.lam, flat.nu, flat.r, flat.r_av, flat.theta) == \
        (tree.lam, tree.nu, tree.r, tree.r_av, tree.theta)


def test_tune_tree_worst_case_dominated_by_worst_leaf():
    from repro.core import theory
    etas, omegas = [0.3, 0.9], [4.0, 0.5]
    e, o, _ = theory.tree_constants(etas, omegas, n=4, aggregate="worst")
    assert (e, o) == (0.9, 4.0)
    t = theory.tune_tree(etas, omegas, n=4, aggregate="worst")
    worst = theory.tune(eta=0.9, omega=4.0, omega_av=1.0, n=4)
    assert (t.lam, t.nu) == (worst.lam, worst.nu)


def test_spec_fingerprint_unchanged_without_leaf_codecs():
    a = ExperimentSpec(n=2, d=8)
    assert "leaf_codecs" not in a.to_dict()
    b = ExperimentSpec(n=2, d=8, leaf_codecs="*=sign")
    assert b.to_dict()["leaf_codecs"] == "*=sign"
    assert a.fingerprint() != b.fingerprint()
    rt = ExperimentSpec.from_json(b.to_json())
    assert rt == b and rt.fingerprint() == b.fingerprint()


# ---------------------------------------------------------------------------
# 8. the trainers consume the tree path (multi-device, subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_tree_wire_trainer_4dev():
    """4-device shard_map run of a leaf_codecs spec: the trainer's wire is
    a TreeWire (mixed leaf kinds), training stays finite, and dropping the
    rules changes the trajectory (the per-leaf wire is real, not cosmetic).
    """
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.core import ExperimentSpec, build
        from repro.distributed import wire
        from repro.launch.train import main

        spec = ExperimentSpec(
            compressor="block_topk:256,16", mode="efbv",
            agg="sparse_allgather", backend="shard_map",
            problem="qwen2-0.5b", smoke=True, mesh="2x2", n=2, d=131072,
            steps=2, leaf_codecs="*embed*=qsgd:16;*norm*=identity")
        run = build(spec)
        assert run.leaf_rules is not None and len(run.leaf_rules) == 2
        print("SPEC_OK", spec.fingerprint())

        import json, tempfile, os
        path = os.path.join(tempfile.mkdtemp(), "tree.json")
        with open(path, "w") as f:
            f.write(spec.to_json())
        main(["--spec", path, "--smoke", "--global-batch", "8",
              "--seq", "32", "--steps", "2", "--log-every", "1"])
        print("TRAIN_OK")
    """, n_devices=4)
    assert "TRAIN_OK" in out
