"""Compressor contract: the paper's class C(eta, omega).

A compressor is a (possibly randomized) map R^d -> R^d with two certified
constants:

  (i)  || E[C(x)] - x ||            <= eta   * ||x||        (relative bias)
  (ii) E[ ||C(x) - E[C(x)]||^2 ]    <= omega * ||x||^2      (relative variance)

(Sect. 2.3 of the paper.)  ``C(eta, 0)`` are the deterministic contractive
compressors B(alpha) with ``1 - alpha = eta**2``; ``C(0, omega)`` are the
unbiased compressors U(omega).  When ``eta**2 + omega < 1`` the compressor is
contractive with ``alpha = 1 - eta**2 - omega`` (eq. (5)).

Every compressor here is jit-compatible: static shapes, explicit PRNG keys.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Wire:
    """Wire-format accounting for one compressed message of a d-vector.

    ``words`` counts 32-bit words sent per worker per message, which is the
    unit the paper plots ("number of bits sent by each node ... proportional
    to t*k", Sect. 6).
    """

    words: int
    sparse: bool  # True if the message is a fixed-size (indices, values) list


class Compressor:
    """Base class.  Subclasses must be pure / hashable (frozen dataclasses)."""

    # --- certified constants -------------------------------------------------
    def eta(self, d: int) -> float:
        raise NotImplementedError

    def omega(self, d: int) -> float:
        raise NotImplementedError

    def alpha(self, d: int) -> float:
        """Contraction factor when in B(alpha); eq. (5)."""
        return 1.0 - self.eta(d) ** 2 - self.omega(d)

    def omega_av(self, d: int, n: int) -> float:
        """Average relative variance of n independent copies (Sect. 2.4)."""
        return self.omega(d) / max(n, 1)

    def is_random(self) -> bool:
        return True

    # --- application ----------------------------------------------------------
    def __call__(self, key: Optional[Array], x: Array) -> Array:
        """Dense application: returns C(x) with the same shape as x."""
        raise NotImplementedError

    # --- wire format -----------------------------------------------------------
    def wire(self, d: int) -> Wire:
        """Words-on-the-wire for one message (default: dense)."""
        return Wire(words=d, sparse=False)

    def codec(self, shape: Tuple[int, ...], *, wire_dtype: str = "float32"):
        """The wire codec for one leaf of this shape (repro.distributed.wire).

        Every compressor has one -- subclasses declare their native layout
        (block-sparse, flat-sparse, bit-packed sign, quantized stream); the
        default is the honest dense value stream.  ``wire_dtype`` is the
        orthogonal value-precision knob (ignored by codecs whose payload
        carries no raw values).
        """
        import math as _math
        from repro.distributed import wire  # lazy: wire imports no core
        return wire.DensePack(shape=tuple(shape),
                              size=int(_math.prod(shape)),
                              compressor=self, val_dtype=wire_dtype)

    # sparse encode/decode (optional; top-k family overrides)
    def encode(self, key: Optional[Array], x: Array):
        raise NotImplementedError(f"{type(self).__name__} has no sparse encoding")

    def decode(self, payload, d: int) -> Array:
        raise NotImplementedError(f"{type(self).__name__} has no sparse encoding")


def scaled(c: Compressor, lam: float) -> Callable[[Optional[Array], Array], Array]:
    """lam * C  (Prop. 1: eta' = lam*eta + 1 - lam, omega' = lam^2 omega)."""

    def apply(key, x):
        return lam * c(key, x)

    return apply


def bias_variance_estimate(
    c: Compressor, key: Array, x: Array, n_samples: int = 256
) -> Tuple[float, float]:
    """Monte-Carlo estimate of (bias, variance) of C at the point x.

    Returns (||E C(x) - x|| / ||x||,  E||C(x) - E C(x)||^2 / ||x||^2).
    Used by the property tests to check class membership empirically.
    """
    keys = jax.random.split(key, n_samples)
    ys = jax.vmap(lambda k: c(k, x))(keys)
    mean = jnp.mean(ys, axis=0)
    nx2 = jnp.sum(x * x)
    bias = jnp.sqrt(jnp.sum((mean - x) ** 2) / nx2)
    var = jnp.mean(jnp.sum((ys - mean) ** 2, axis=-1)) / nx2
    return float(bias), float(var)
