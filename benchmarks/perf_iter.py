"""§Perf hillclimb harness: compile a VARIANT of one (arch × shape) pair and
report the roofline-term deltas against the recorded baseline.

    PYTHONPATH=src python -m benchmarks.perf_iter --arch dbrx-132b \
        --shape train_4k --agg sparse_allgather --tag "sparse wire"

Each invocation = one hypothesis→change→measure cycle; results append to
results/perf_iters.jsonl for the EXPERIMENTS §Perf log.

:func:`smoke_rows` is the PINNED smoke slice of this harness used by the CI
bench job (benchmarks/ci_bench.py -> BENCH_perf.json): one real jitted
train-step on a small CPU mesh -- measured steps/sec, compile time, and the
compiled HLO byte count as a code-size trajectory.
"""

import os

import argparse  # noqa: E402
import json      # noqa: E402
import time      # noqa: E402

# the pinned CI/`make bench` configuration -- change it and every later
# BENCH_perf.json entry starts a new trajectory, so don't
SMOKE = dict(arch="qwen2-0.5b", mesh=(2, 2), steps=4, global_batch=8, seq=32,
             compressor="block_topk:256,16", agg="sparse_allgather",
             downlink="qsgd:16")


def smoke_rows(pipeline: str = "off", leaf_codecs: str = ""):
    """Measure the pinned smoke train-step (see SMOKE): steps/sec excluding
    compile and warmup, compile seconds, and compiled-HLO bytes.  Needs >= 4
    XLA host devices (the caller sets XLA_FLAGS before jax initializes).

    ``pipeline`` ('off' | 'depth:1') selects the execution schedule; the
    depth:1 row lands in BENCH_perf.json next to the sequential baseline
    under its own spec fingerprint.  ``leaf_codecs`` (per-leaf codec rules,
    docs/wire_format.md) switches the wire to the pytree-native TreeWire --
    the smoke_train_step_tree row; '' keeps the flat wire and the existing
    rows byte-compatible."""
    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.core import Downlink, EFBV, make_compressor
    from repro.core.efbv import Pipeline
    from repro.data import SyntheticLM, make_batch_shardings
    from repro.distributed import wire
    from repro.launch.mesh import make_mesh, num_workers
    from repro.models import build_model
    from repro.optim import adamw, cosine
    from repro.train import (init_train_state, make_train_step,
                             train_state_shardings)

    cfg = get_smoke_config(SMOKE["arch"])
    mesh = make_mesh(SMOKE["mesh"])
    n = num_workers(mesh)
    model = build_model(cfg)
    comp = make_compressor(SMOKE["compressor"])
    pipe = Pipeline.parse(pipeline)
    rules = wire.parse_leaf_rules(leaf_codecs) if leaf_codecs else None
    algo = EFBV.make(comp, d=max(cfg.d_model * max(cfg.d_ff, 1), 1), n=n,
                     pipeline=pipe.depth or None, leaf_rules=rules)
    downlink = Downlink.parse(SMOKE["downlink"])
    opt = adamw(cosine(3e-4, total_steps=SMOKE["steps"], warmup_steps=1))

    params = model.init(jax.random.key(0))
    state = init_train_state(params, opt, mesh, bidirectional=True,
                             algo=algo, agg_mode=SMOKE["agg"], pipeline=pipe)
    sh = train_state_shardings(mesh, model.param_specs(), state)
    state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, sh)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=SMOKE["seq"],
                       global_batch=SMOKE["global_batch"], n_workers=n,
                       seed=0)
    step_fn = make_train_step(model.loss, opt, algo, mesh,
                              agg_mode=SMOKE["agg"], downlink=downlink,
                              pipeline=pipe)

    key = jax.random.key(0)
    batch = make_batch_shardings(mesh, data.batch(0))
    t0 = time.perf_counter()
    compiled = step_fn.lower(state, batch, key).compile()
    compile_s = time.perf_counter() - t0
    hlo_bytes = len(compiled.as_text().encode())

    # drive the AOT-compiled executable directly (calling step_fn again
    # would recompile through jit's separate dispatch cache): one warmup
    # dispatch, then the timed steps.  GSPMD may emit a few output leaves
    # with different shardings than the input layout the step was compiled
    # for (e.g. a small norm param flipping to 'model'), and AOT calls are
    # strict about input shardings -- reshard those leaves back outside the
    # timed region.
    resync = lambda st: jax.tree.map(
        lambda x, s: x if x.sharding == s else jax.device_put(x, s), st, sh)
    state, warm_metrics = compiled(state, batch, key)
    # warmup synchronizes on EVERYTHING it produced, so no async dispatch
    # (or lazy host transfer) bleeds into the first timed step
    jax.block_until_ready((state, warm_metrics))
    times = []
    for i in range(SMOKE["steps"]):
        state = resync(state)
        batch = make_batch_shardings(mesh, data.batch(i + 1))
        t0 = time.perf_counter()
        state, metrics = compiled(state, batch, jax.random.fold_in(key, i))
        # the step isn't done until every output leaf is: blocking only on
        # params used to stop the clock while h / h_avg / w / the in-flight
        # payload / metrics could still be computing
        jax.block_until_ready((state, metrics))
        times.append(time.perf_counter() - t0)
    sec_per_step = float(np.median(times))
    return {
        "config": {**{k: (list(v) if isinstance(v, tuple) else v)
                      for k, v in SMOKE.items()}, "pipeline": pipeline,
                   # only a real rule set enters the row (the flat rows stay
                   # byte-compatible with the pre-field trajectory)
                   **({"leaf_codecs": leaf_codecs} if leaf_codecs else {})},
        "steps_per_sec": round(1.0 / sec_per_step, 4),
        "sec_per_step_median": round(sec_per_step, 4),
        "compile_s": round(compile_s, 2),
        "train_step_hlo_bytes": hlo_bytes,
        "final_loss": round(float(metrics["loss"]), 4),
    }


def main():
    # 512 fake host devices for the roofline meshes; set here (not at import
    # time) so importers of smoke_rows() keep their own device count
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--agg", default="dense_psum")
    ap.add_argument("--compressor", default="block_topk:4096,64")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--trainer", default="shard_map",
                    choices=["shard_map", "fsdp"])
    ap.add_argument("--param-dtype", default=None)
    ap.add_argument("--attn-impl", default=None,
                    choices=[None, "direct", "chunked"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--hypothesis", default="")
    ap.add_argument("--out", default="results/perf_iters.jsonl")
    ap.add_argument("--baseline", default="results/dryrun_results.jsonl")
    args = ap.parse_args()

    from repro.launch.dryrun import run_one

    tag = "_" + args.tag.replace(" ", "-") if args.tag else ""
    rec = run_one(args.arch, args.shape, multi_pod=args.multi_pod,
                  agg_mode=args.agg, compressor=args.compressor,
                  hlo_dir="results/hlo_perf", trainer=args.trainer,
                  param_dtype=args.param_dtype, attn_impl=args.attn_impl,
                  hlo_tag=tag)
    rec["tag"] = args.tag
    rec["hypothesis"] = args.hypothesis

    # diff vs baseline
    base = None
    if os.path.exists(args.baseline):
        for line in open(args.baseline):
            r = json.loads(line)
            if (r["arch"] == args.arch and r["shape"] == args.shape
                    and r["mesh"] == rec["mesh"] and r.get("status") == "ok"):
                base = r
    if base and rec.get("status") == "ok":
        b, v = base["roofline"], rec["roofline"]
        print(f"\n=== {args.arch} x {args.shape} [{args.tag}] ===")
        for term in ["t_compute_s", "t_memory_s", "t_collective_s"]:
            delta = (v[term] - b[term]) / max(b[term], 1e-30) * 100
            print(f"  {term:16s} {b[term]:.4e} -> {v[term]:.4e}  ({delta:+.1f}%)")
        print(f"  bottleneck       {b['bottleneck']} -> {v['bottleneck']}")
        rec["baseline"] = {k: b[k] for k in
                           ["t_compute_s", "t_memory_s", "t_collective_s",
                            "bottleneck"]}
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "a") as f:
        f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
