"""Fine-tuning harness tests (ISSUE 9): multi-host mesh geometry, MoE
expert-gradient sparsity composed with the per-leaf compressed wire, the
committed zoo specs, and the staged FinetuneLoop.

The expert-sparsity contract under test (docs/finetuning.md#expert-sparsity):
capacity dispatch scatters zero buffers to unrouted experts, so their wg/wu/wd
gradient slabs are EXACTLY zero; zero_inactive_expert_grads is then the
bitwise identity, a flat top-k leaf rule's payload only carries routed-expert
entries, and bits_by_leaf accounts for the routed fraction exactly.
"""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import REPO, run_with_devices
from repro.configs import get_smoke_config
from repro.core import (BlockTopK, ExperimentSpec, SpecError, TopK,
                        make_compressor)
from repro.data import SyntheticLM
from repro.distributed import wire
from repro.launch.mesh import (make_multihost_mesh, multihost_worker_shape,
                               process_worker_slice)
from repro.models import build_model, moe
from repro.train.loop import (EVAL_SEED_XOR, FinetuneLoop, FinetuneSettings,
                              expert_sparse_rules, family_batch_extras)

SPECS_DIR = os.path.join(REPO, "examples", "specs")

# the committed zoo specs and their pinned fingerprints: these keys are how
# BENCH_perf/BENCH_bits zoo_scaling rows are addressed across the bench
# trajectory -- a fingerprint drift silently orphans every recorded row
ZOO_FINGERPRINTS = {
    "finetune_moe.json": "f67bc877b3e73340",
    "zoo_qwen2_fsdp.json": "e379cbd8a0e45487",
    "zoo_mamba2_fsdp.json": "6a9502177435874c",
}


# ---------------------------------------------------------------------------
# multi-host mesh geometry
# ---------------------------------------------------------------------------

def test_multihost_worker_shape():
    assert multihost_worker_shape(8, 2) == (2, 4)
    assert multihost_worker_shape(4, 4) == (4, 1)
    assert multihost_worker_shape(6, 1) == (1, 6)


def test_multihost_worker_shape_errors():
    with pytest.raises(ValueError, match="cannot tile"):
        multihost_worker_shape(6, 4)
    with pytest.raises(ValueError, match="num_processes"):
        multihost_worker_shape(4, 0)


def test_process_worker_slice():
    # (4, 1) mesh: 4 workers, trailing model axis does not change numbering
    assert process_worker_slice((4, 1), 2, 0) == range(0, 2)
    assert process_worker_slice((4, 1), 2, 1) == range(2, 4)
    # 1-d mesh is all workers (mesh_worker_count convention)
    assert process_worker_slice((8,), 4, 3) == range(6, 8)
    # 3-d pod mesh: workers = pod * data
    assert process_worker_slice((2, 4, 2), 2, 1) == range(4, 8)
    with pytest.raises(ValueError, match="out of range"):
        process_worker_slice((4, 1), 2, 2)
    with pytest.raises(ValueError, match="cannot tile"):
        process_worker_slice((4, 1), 3, 0)


def test_make_multihost_mesh_single_device():
    mesh = make_multihost_mesh((1, 1))
    assert mesh.axis_names == ("data", "model")
    assert dict(mesh.shape) == {"data": 1, "model": 1}


def test_make_multihost_mesh_device_count_mismatch():
    with pytest.raises(ValueError, match="needs 4 devices"):
        make_multihost_mesh((4, 1))  # only 1 real device in tier-1


class _FakeDev:
    def __init__(self, process_index, id):
        self.process_index = process_index
        self.id = id


def test_make_multihost_mesh_rejects_non_process_major():
    # interleaved ownership: device 1 belongs to process 1 but sits in
    # process 0's block -- the check fires before any Mesh is built
    devs = [_FakeDev(0, 0), _FakeDev(1, 0), _FakeDev(0, 1), _FakeDev(1, 1)]
    with pytest.raises(ValueError, match="not process-major"):
        make_multihost_mesh((4, 1), num_processes=2, devices=devs)


def test_make_multihost_mesh_indivisible_leading_axis():
    with pytest.raises(ValueError, match="cannot tile"):
        make_multihost_mesh((4, 1), num_processes=3)


def test_make_multihost_mesh_default_axes_overflow():
    with pytest.raises(ValueError, match="pass axes= explicitly"):
        make_multihost_mesh((1, 1, 1, 1))


@pytest.mark.slow
def test_make_multihost_mesh_simulated_processes_4dev():
    out = run_with_devices("""
        import jax
        from repro.launch.mesh import (make_multihost_mesh, num_workers,
                                       process_worker_slice, worker_axes)

        for procs in (1, 2, 4):
            mesh = make_multihost_mesh((4, 1), num_processes=procs)
            assert mesh.axis_names == ("data", "model")
            assert num_workers(mesh) == 4
            # process-major: the flat device order IS sorted jax.devices()
            flat = list(mesh.devices.reshape(-1))
            want = sorted(jax.devices(),
                          key=lambda d: (d.process_index, d.id))
            assert flat == want, (flat, want)
            # every worker is owned by exactly one simulated process slice
            owned = [w for p in range(procs)
                     for w in process_worker_slice((4, 1), procs, p)]
            assert owned == list(range(4)), owned
        try:
            make_multihost_mesh((4, 1), num_processes=3)
        except ValueError as e:
            assert "cannot tile" in str(e)
        else:
            raise AssertionError("indivisible process count accepted")
        print("MULTIHOST_MESH_OK")
    """, n_devices=4)
    assert "MULTIHOST_MESH_OK" in out


# ---------------------------------------------------------------------------
# MoE expert-gradient sparsity x per-leaf wire
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def granite():
    """Granite smoke model under FIXED routing (zeroed router: every token
    deterministically routes to experts (0, 1)), plus one real backward."""
    cfg = get_smoke_config("granite-moe-3b-a800m")
    model = build_model(cfg)
    params = moe.fixed_routing_params(model.init(jax.random.key(0)))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=16, global_batch=4,
                       n_workers=1, seed=0)
    batch = data.batch(0)
    grads, _aux = jax.grad(model.loss, has_aux=True)(params, batch)
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    return {"cfg": cfg, "model": model, "params": params, "batch": batch,
            "grads": grads}


def test_fixed_routing_inactive_slabs_exactly_zero(granite):
    """A real backward under fixed routing: experts (0, 1) active, (2, 3)
    gradient slabs EXACTLY zero -- so zero_inactive_expert_grads is the
    bitwise identity (the dispatch already produced the zeros)."""
    grads = granite["grads"]
    mg = grads["layers"]["moe"]
    mask = np.asarray(moe.expert_activity_mask(mg))
    assert mask.shape == (2, 4)  # (L, E) for the stacked granite smoke
    assert mask[:, :2].all() and not mask[:, 2:].any(), mask
    for name in moe.EXPERT_LEAVES:
        g = np.asarray(mg[name])
        assert np.all(g[:, 2:] == 0.0), name       # inactive: exact zeros
        assert np.any(g[:, :2] != 0.0), name       # routed: real gradient
    assert np.any(np.asarray(mg["router"]) != 0.0)  # router grads are dense
    masked = moe.zero_inactive_expert_grads(grads)
    for a, b in zip(jax.tree.leaves(grads), jax.tree.leaves(masked)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_zero_inactive_with_explicit_mask(granite):
    """An explicit mask zeroes exactly the deselected slabs and leaves the
    router untouched."""
    grads = granite["grads"]["layers"]["moe"]
    m = jnp.asarray([[True, False, False, False],
                     [False, True, False, False]])
    out = moe.zero_inactive_expert_grads({"moe": grads}, mask=m)["moe"]
    for name in moe.EXPERT_LEAVES:
        g = np.asarray(out[name])
        assert np.all(g[0, 1:] == 0.0) and np.all(g[1, 0] == 0.0)
        assert np.all(g[1, 2:] == 0.0)
        np.testing.assert_array_equal(
            g[0, 0], np.asarray(grads[name][0, 0]))
    np.testing.assert_array_equal(np.asarray(out["router"]),
                                  np.asarray(grads["router"]))


def test_expert_sparse_rules_pinned(granite):
    """The committed granite rule string, and the a/E budget rescale for
    both entry-budget compressors."""
    cfg, params = granite["cfg"], granite["params"]
    rules = expert_sparse_rules(params, BlockTopK(256, 16),
                                n_experts=cfg.n_experts,
                                experts_per_tok=cfg.experts_per_tok)
    assert rules == ("layers/moe/wd=topk:8192;layers/moe/wg=topk:8192;"
                     "layers/moe/wu=topk:8192")
    # flat topk base: K = k * a / E
    rules = expert_sparse_rules(params, TopK(100), n_experts=cfg.n_experts,
                                experts_per_tok=cfg.experts_per_tok)
    assert rules.split(";")[0] == "layers/moe/wd=topk:50"
    with pytest.raises(ValueError, match="entry budget"):
        expert_sparse_rules(params, make_compressor("qsgd:16"),
                            n_experts=4, experts_per_tok=2)
    with pytest.raises(ValueError, match="no MoE subtree"):
        expert_sparse_rules({"w": jnp.zeros((4, 4))}, BlockTopK(256, 16),
                            n_experts=4, experts_per_tok=2)


def _expert_wire(granite_fix):
    cfg = granite_fix["cfg"]
    base = make_compressor("block_topk:256,16")
    rules = expert_sparse_rules(granite_fix["params"], base,
                                n_experts=cfg.n_experts,
                                experts_per_tok=cfg.experts_per_tok)
    fmt = wire.tree_format_for(base, granite_fix["grads"],
                               rules=wire.parse_leaf_rules(rules))
    return base, fmt


def test_masked_payload_decodes_identically_to_dense_then_zero(granite):
    """The satellite pin: the masked-expert payload is bit-identical to the
    raw-gradient payload (masking IS the identity under capacity dispatch),
    and its decode is supported ONLY on routed-expert slabs -- decode equals
    dense-then-zero bitwise."""
    grads = granite["grads"]
    _, fmt = _expert_wire(granite)
    h0 = jax.tree.map(jnp.zeros_like, grads)
    pay_raw, _ = fmt.encode_update(None, grads, h0, 1.0)
    pay_masked, _ = fmt.encode_update(
        None, moe.zero_inactive_expert_grads(grads), h0, 1.0)
    for a, b in zip(jax.tree.leaves(pay_raw), jax.tree.leaves(pay_masked)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    decoded = fmt.decode(pay_raw)
    # dense-then-zero: zeroing inactive slabs of the decode changes nothing,
    # because every top-K entry already fell inside a routed slab
    rezeroed = moe.zero_inactive_expert_grads(decoded)
    for a, b in zip(jax.tree.leaves(decoded), jax.tree.leaves(rezeroed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for name in moe.EXPERT_LEAVES:
        d = np.asarray(decoded["layers"]["moe"][name])
        assert np.all(d[:, 2:] == 0.0), name
        assert np.count_nonzero(d) > 0, name


def test_bits_by_leaf_exact_under_routing(granite):
    """Exact accounting: composed bits == sum of per-leaf bits == measured
    payload bytes, and each expert leaf spends exactly a/E = 1/2 of its
    dense block-top-k budget (64 bits/entry on both sides at float32)."""
    grads = granite["grads"]
    base, fmt = _expert_wire(granite)
    by_leaf = fmt.bits_by_leaf()
    assert sum(by_leaf) == fmt.bits_per_round()
    h0 = jax.tree.map(jnp.zeros_like, grads)
    payloads, _ = fmt.encode_update(None, grads, h0, 1.0)
    assert wire.payload_bytes(payloads) * 8 == fmt.bits_per_round()

    dense = wire.tree_format_for(base, grads, rules=(("*", base),))
    dense_by_leaf = dense.bits_by_leaf()
    assert fmt.paths == dense.paths
    expert = [i for i, p in enumerate(fmt.paths)
              if p.split("/")[-1] in moe.EXPERT_LEAVES
              and "moe" in p.split("/")]
    assert len(expert) == 3
    for i in expert:
        assert by_leaf[i] == 8192 * 64            # topk:8192 at fp32
        assert dense_by_leaf[i] == 16384 * 64     # block_topk:256,16 dense
        assert 2 * by_leaf[i] == dense_by_leaf[i]
    for i in range(len(by_leaf)):                 # non-expert leaves: shared
        if i not in expert:
            assert by_leaf[i] == dense_by_leaf[i]


# ---------------------------------------------------------------------------
# fixed-routing fine-tune step: trainers == vmap oracle
# ---------------------------------------------------------------------------

def _oracle_code(n_devices, mesh_shape, steps, fsdp_atol):
    """The fixed-routing step pin, parametrized by device count.

    The shard_map trainer is pinned TIGHT against the vmap oracle -- its
    per-worker gradients are the same single-shard computation the oracle
    runs, so compression sees bit-equal inputs.  The fsdp trainer computes
    grads under vmap over the worker axis; on multi-device meshes that
    reassociates bf16 matmuls just enough to flip block-top-k ties in the
    embed leaf, so its pin is structural (loss + expert-slab support) plus
    a loose parameter tolerance (``fsdp_atol``); h is only compared when
    the tolerance is tight (tie flips land whole gradient entries in h).
    """
    return f"""
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding
        from repro.configs import get_smoke_config
        from repro.core import ExperimentSpec, build
        from repro.data import SyntheticLM, make_batch_shardings
        from repro.distributed.aggregate import efbv_aggregate_reference
        from repro.launch.mesh import make_mesh
        from repro.models import build_model, moe
        from repro.optim import constant, sgd
        from repro.train import (fsdp_state_shardings, init_train_state,
                                 make_train_step, make_train_step_fsdp,
                                 train_state_shardings)

        spec = ExperimentSpec.from_json(
            open("examples/specs/finetune_moe.json").read())
        run = build(spec)
        mesh = make_mesh({mesh_shape})
        n, lr, steps = {mesh_shape}[0], 0.05, {steps}
        cfg = get_smoke_config(spec.problem)
        model = build_model(cfg)
        params0 = moe.fixed_routing_params(model.init(jax.random.key(0)))
        params0 = jax.tree.map(np.asarray, params0)  # survives donation
        data = SyntheticLM(vocab=cfg.vocab, seq_len=16, global_batch=4 * n,
                           n_workers=n, seed=0)
        opt = sgd(constant(lr))
        key = jax.random.key(spec.seed)
        loss_fn = model.loss
        grad_fn = jax.grad(lambda p, b: loss_fn(p, b)[0])

        results = {{}}
        for trainer in ["shard_map", "fsdp"]:
            make = (make_train_step_fsdp if trainer == "fsdp"
                    else make_train_step)
            shard = (fsdp_state_shardings if trainer == "fsdp"
                     else train_state_shardings)
            st = init_train_state(params0, opt, mesh)
            sh = shard(mesh, model.param_specs(), st)
            st = jax.tree.map(lambda x, s: jax.device_put(x, s), st, sh)
            step = make(loss_fn, opt, run.algo, mesh, agg_mode=spec.agg,
                        grad_transform=moe.zero_inactive_expert_grads)
            for i in range(steps):
                batch = make_batch_shardings(mesh, data.batch(i))
                st, m = step(st, batch, jax.random.fold_in(key, i))
            results[trainer] = (jax.tree.map(np.asarray, st.params),
                                jax.tree.map(np.asarray, st.h),
                                float(m["loss"]))
            # the expert-sparsity invariant holds in BOTH trainers: h only
            # ever accumulates compressed MASKED grads, so inactive-expert
            # slabs of h stay exactly zero.  Only checkable on the first
            # step -- the router trains, so routing is no longer pinned to
            # experts (0, 1) afterwards.
            if steps == 1:
                for name in ("wg", "wu", "wd"):
                    hh = np.asarray(st.h["layers"]["moe"][name])
                    assert np.all(hh[:, :, 2:] == 0.0), (trainer, name)

        # the vmap oracle: per-worker grads on each worker's batch rows,
        # masked exactly as the trainers' grad_transform masks them
        w = jax.tree.map(jnp.asarray, params0)
        h = jax.tree.map(lambda p: jnp.zeros((n,) + p.shape), params0)
        h_avg = jax.tree.map(jnp.zeros_like, params0)
        per = 4  # rows per worker
        for i in range(steps):
            batch = data.batch(i)
            gs = []
            for j in range(n):
                shard_j = {{k: v[j * per:(j + 1) * per]
                           for k, v in batch.items()}}
                gj = grad_fn(w, shard_j)
                gj = jax.tree.map(lambda g: g.astype(jnp.float32), gj)
                gs.append(moe.zero_inactive_expert_grads(gj))
            grads = jax.tree.map(lambda *x: jnp.stack(x), *gs)
            ki = jax.random.fold_in(key, i)
            wkeys = jax.vmap(lambda j: jax.random.fold_in(ki, j))(
                jnp.arange(n))
            g, h, h_avg = efbv_aggregate_reference(
                run.algo, wkeys, grads, h, h_avg, mode=spec.agg)
            w = jax.tree.map(lambda p, gg: p - lr * gg, w, g)

        atols = {{"shard_map": 1e-6, "fsdp": {fsdp_atol}}}
        for trainer, (p_t, h_t, loss_t) in results.items():
            atol = atols[trainer]
            for a, b in zip(jax.tree.leaves(p_t), jax.tree.leaves(w)):
                np.testing.assert_allclose(a, np.asarray(b), rtol=1e-6,
                                           atol=atol, err_msg=trainer)
            if atol <= 1e-6:
                for a, b in zip(jax.tree.leaves(h_t), jax.tree.leaves(h)):
                    np.testing.assert_allclose(a, np.asarray(b), rtol=1e-6,
                                               atol=1e-6, err_msg=trainer)
        # both trainers ran the same forward at the same point: final-step
        # loss metrics agree tightly even where the wires tie-flip
        assert abs(results["shard_map"][2] - results["fsdp"][2]) < 1e-3, \\
            (results["shard_map"][2], results["fsdp"][2])
        print("FIXED_ROUTING_ORACLE_MATCH")
    """


def test_fixed_routing_step_matches_oracle_1dev():
    """Single-worker tier-1 leg of the oracle pin: both trainers' fixed-
    routing fine-tune step (expert-sparse leaf rules from the committed
    finetune_moe spec, grad_transform masking) tracks the vmap oracle."""
    import subprocess
    import sys
    import textwrap

    from conftest import SRC

    # run in-process-style but isolated: the module-level fixture already
    # holds jax state; a subprocess keeps the 1-device regime explicit
    prog = textwrap.dedent(_oracle_code(1, (1, 1), 2, "1e-6"))
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=900, env=env, cwd=REPO)
    assert res.returncode == 0, res.stderr[-4000:]
    assert "FIXED_ROUTING_ORACLE_MATCH" in res.stdout


@pytest.mark.slow
def test_fixed_routing_step_matches_oracle_4dev():
    """4-worker leg: the shard_map trainer == the vmap oracle (tight) under
    fixed routing with per-worker heterogeneous batches; the fsdp trainer
    holds the structural expert-sparsity pins plus a loose parameter
    tolerance (its vmap'd bf16 grads tie-flip block-top-k in embed)."""
    out = run_with_devices(_oracle_code(4, (4, 1), 1, "2e-2") + "\n",
                           n_devices=4)
    assert "FIXED_ROUTING_ORACLE_MATCH" in out


# ---------------------------------------------------------------------------
# committed zoo specs
# ---------------------------------------------------------------------------

def test_committed_zoo_specs_pinned():
    """Byte-equality (file == spec.to_json()) and fingerprint pins for the
    three zoo specs the BENCH zoo_scaling rows are keyed by."""
    for fname, fp in ZOO_FINGERPRINTS.items():
        raw = open(os.path.join(SPECS_DIR, fname)).read()
        spec = ExperimentSpec.from_json(raw)
        assert raw == spec.to_json(), fname
        assert spec.fingerprint() == fp, fname
        assert spec.backend == "fsdp" and spec.mesh == "4x1", fname
        assert spec.compressor == "block_topk:256,16", fname
        assert spec.downlink == "qsgd:16", fname


def test_finetune_moe_spec_leaf_codecs_are_expert_sparse_rules(granite):
    """The committed MoE spec's leaf_codecs string IS the expert_sparse_rules
    output for its own config + base compressor (no hand-maintained drift)."""
    cfg = granite["cfg"]
    spec = ExperimentSpec.from_json(
        open(os.path.join(SPECS_DIR, "finetune_moe.json")).read())
    assert spec.problem == "granite-moe-3b-a800m" and spec.smoke
    want = expert_sparse_rules(granite["params"],
                               make_compressor(spec.compressor),
                               n_experts=cfg.n_experts,
                               experts_per_tok=cfg.experts_per_tok)
    assert spec.leaf_codecs == want


# ---------------------------------------------------------------------------
# the staged FinetuneLoop
# ---------------------------------------------------------------------------

def test_finetune_loop_rejects_reference_backend():
    spec = ExperimentSpec(compressor="topk:4", backend="reference",
                          problem="quadratic", d=32, n=2, steps=2)
    with pytest.raises(SpecError, match="reference"):
        FinetuneLoop(spec)


def test_finetune_loop_needs_config_for_non_zoo_problems():
    spec = ExperimentSpec(compressor="topk:4", backend="shard_map",
                          problem="quadratic", d=32, n=1, mesh="1x1",
                          steps=2)
    with pytest.raises(SpecError, match="config"):
        FinetuneLoop(spec)


def test_finetune_loop_stages_smoke():
    """All four stages on the cheapest zoo family (mamba2 smoke), single
    device: staged prerequisites, decorrelated eval stream, summary schema,
    exact wire accounting in the report."""
    spec = ExperimentSpec.from_json(
        open(os.path.join(SPECS_DIR, "zoo_mamba2_fsdp.json")).read())
    spec = dataclasses.replace(spec, mesh="1x1", n=1, steps=2)
    # seq_len 32: the mamba2 SSD scan runs in chunks of 32 tokens
    st = FinetuneSettings(global_batch=2, seq_len=32, eval_batches=1,
                          log_every=1)
    loop = FinetuneLoop(spec, st, verbose=False)
    with pytest.raises(RuntimeError, match="setup"):
        loop.wire_report()
    summary = loop.run()
    assert summary["fingerprint"] == spec.fingerprint()
    assert summary["family"] == "ssm"
    assert summary["final_loss"] > 0 and summary["eval_loss"] > 0
    assert summary["steps_per_sec"] > 0
    rb = summary["round_bits"]
    assert 0 < rb["total"] < rb["dense_both_ways"]
    # eval stream is decorrelated from the train stream, same geometry
    assert loop.eval_data.seed == spec.seed ^ EVAL_SEED_XOR
    assert loop.data.seed == spec.seed
    assert loop.history and loop.history[-1]["eval_loss"] > 0


def test_family_batch_extras():
    import types

    vlm = types.SimpleNamespace(family="vlm", vision_patches=3, d_model=8)
    ed = types.SimpleNamespace(family="encdec", encoder_frames=5, d_model=8)
    dense = types.SimpleNamespace(family="dense")
    x = family_batch_extras(vlm, 2, 7)
    assert x["vision_embeds"].shape == (2, 3, 8)
    np.testing.assert_array_equal(
        x["vision_embeds"], family_batch_extras(vlm, 2, 7)["vision_embeds"])
    assert family_batch_extras(ed, 4, 0)["frames"].shape == (4, 5, 8)
    assert family_batch_extras(dense, 4, 0) == {}


def test_finetune_cli_mesh_sniffing(tmp_path):
    """launch/finetune.py reads the spec's mesh BEFORE jax initializes to
    force the device count; malformed argv degrades to no forcing."""
    from repro.launch.finetune import _mesh_from_argv, parse_args

    p = os.path.join(SPECS_DIR, "finetune_moe.json")
    assert _mesh_from_argv(["--spec", p]) == "4x1"
    assert _mesh_from_argv([f"--spec={p}"]) == "4x1"
    assert _mesh_from_argv(["--spec"]) == ""           # truncated argv
    assert _mesh_from_argv(["--spec", "/nonexistent"]) == ""
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert _mesh_from_argv(["--spec", str(bad)]) == ""
    args = parse_args(["--spec", p, "--steps", "3", "--processes", "2"])
    assert args.spec == p and args.steps == 3 and args.processes == 2
