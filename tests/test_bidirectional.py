"""Bidirectional wire compression + heterogeneous worker fleets.

Three families of guarantees:

* the differential harness extends to the downlink: oracle == Pallas
  interpret (== compiled on TPU) over randomized BIDIRECTIONAL and
  bidirectional-FEDERATED trajectories, and an Identity downlink at full
  participation is *bit-identical* to the pre-downlink (PR-3)
  run_codec_trajectory / run_federated_trajectory pinnings;

* the trainers share the same downlink math (broadcast_global from the
  shared downlink_key), pinned against a hand-rolled reference round;

* mixed fleets: per-worker compressors in the reference step and the
  dense_psum trainers, with the (eta_i, omega_i) aggregation of
  theory.tune_fleet (worst-case certified, averaged variant monotone).

The 8-device shard_map leg lives at the bottom (slow marker; the nightly CI
job runs it).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from harness import (assert_bit_identical, codec_impls,
                     run_bidirectional_trajectory, run_codec_trajectory,
                     run_federated_trajectory)
from repro.core import (
    BlockTopK, Downlink, EFBV, Identity, Natural, Participation, QSGD, RandK,
    SignNorm, TopK, make_compressor, make_fleet, run_reference,
    theory, tune_for,
)
from repro.core.compressors import MNice, expand_fleet
from repro.distributed import wire
from repro.distributed.aggregate import (broadcast_global, compress_local,
                                         efbv_aggregate_reference)

KEY = jax.random.key(0)

TRAJ = dict(steps=5, n=4, d=256, lam=0.8, nu=0.9, gamma=0.05)

# uplink compressors with fused kernels (the interesting backends) and a
# deterministic one; downlinks cover sparse, quantized and dense broadcasts
UPLINKS = [BlockTopK(128, 8), RandK(32), QSGD(16)]
DOWNLINKS = [Downlink(BlockTopK(128, 16)), Downlink(QSGD(16)),
             Downlink(TopK(48))]


# ---------------------------------------------------------------------------
# harness: backend bit-identity over bidirectional (+ federated) trajectories
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("up", UPLINKS, ids=lambda c: type(c).__name__)
@pytest.mark.parametrize("down", DOWNLINKS,
                         ids=lambda d: type(d.compressor).__name__)
def test_bidirectional_trajectory_bit_identical_across_backends(up, down):
    codec = wire.codec_of(up, (TRAJ["d"],), TRAJ["d"])
    ref = run_bidirectional_trajectory("oracle", compressor=up, downlink=down,
                                       **TRAJ)
    for impl in codec_impls(codec):
        got = run_bidirectional_trajectory(impl, compressor=up, downlink=down,
                                           **TRAJ)
        assert_bit_identical(
            (got["x"], got["w"], got["h"], got["payload"],
             got["down_payload"]),
            (ref["x"], ref["w"], ref["h"], ref["payload"],
             ref["down_payload"]),
            f"impl={impl} up={up} down={down.compressor}")
    assert float(jnp.linalg.norm(ref["x"][-1])) > 0


@pytest.mark.parametrize("up", UPLINKS, ids=lambda c: type(c).__name__)
def test_bidirectional_federated_bit_identical_across_backends(up):
    """Randomized per-round participation on top of a compressed downlink:
    the backend pinning still holds, and the masks are genuinely random."""
    part = Participation(kind="bernoulli", p=0.5)
    down = Downlink(QSGD(16))
    codec = wire.codec_of(up, (TRAJ["d"],), TRAJ["d"])
    ref = run_bidirectional_trajectory("oracle", compressor=up, downlink=down,
                                       participation=part, **TRAJ)
    m = np.asarray(ref["masks"])
    assert 0 < m.sum() < m.size  # the trajectory really is partial
    for impl in codec_impls(codec):
        got = run_bidirectional_trajectory(impl, compressor=up, downlink=down,
                                           participation=part, **TRAJ)
        assert_bit_identical(
            (got["x"], got["w"], got["h"], got["masks"], got["payload"]),
            (ref["x"], ref["w"], ref["h"], ref["masks"], ref["payload"]),
            f"impl={impl} up={up} federated")


# ---------------------------------------------------------------------------
# harness: identity downlink reproduces the PR-3 trajectories bit-for-bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("up", UPLINKS + [SignNorm(), Natural()],
                         ids=lambda c: type(c).__name__)
def test_identity_downlink_full_participation_is_pr3_trajectory(up):
    """downlink=Identity + full participation == run_codec_trajectory
    (x, h AND the uplink payloads), bit for bit -- the downlink channel is
    provably a no-op when lossless."""
    bi = run_bidirectional_trajectory("oracle", compressor=up,
                                      downlink=Downlink(Identity()), **TRAJ)
    uni = run_codec_trajectory("oracle", compressor=up, **TRAJ)
    assert_bit_identical((bi["x"], bi["h"], bi["payload"]),
                         (uni["x"], uni["h"], uni["payload"]),
                         f"up={up}")
    assert_bit_identical(bi["w"], bi["x"], "w == x under identity downlink")


def test_identity_downlink_federated_is_pr3_federated_trajectory():
    """Same pinning for the federated regime: identity downlink + random
    masks == run_federated_trajectory, including the masks themselves."""
    part = Participation(kind="bernoulli", p=0.5)
    up = BlockTopK(128, 8)
    bi = run_bidirectional_trajectory("oracle", compressor=up,
                                      downlink=Downlink(Identity()),
                                      participation=part, **TRAJ)
    fed = run_federated_trajectory("oracle", compressor=up,
                                   participation=part, **TRAJ)
    assert_bit_identical((bi["x"], bi["h"], bi["masks"], bi["payload"]),
                         (fed["x"], fed["h"], fed["masks"], fed["payload"]),
                         "identity downlink, federated")


# ---------------------------------------------------------------------------
# bit accounting of the full round
# ---------------------------------------------------------------------------

def test_qsgd_both_ways_total_round_bits_under_035x():
    """Acceptance: qsgd:16 on both directions measures <= 0.35x of the
    dense fp32 up+down traffic on a whole harness trajectory."""
    out = run_bidirectional_trajectory(
        "oracle", compressor=QSGD(16), downlink=Downlink(QSGD(16)),
        steps=3, n=8, d=4096, lam=0.8, nu=0.9, gamma=0.05)
    rb = out["round_bits"]
    assert rb["total"] == rb["up"] + rb["down"]
    assert rb["total"] <= 0.35 * rb["dense_both_ways"], rb
    # measured, not estimated: stacked uplink payload + one broadcast
    up_meas = 8 * wire.payload_bytes(out["payload"])
    down_meas = 8 * wire.payload_bytes(out["down_payload"])
    assert up_meas == rb["up"]
    assert down_meas == rb["down"]


def test_federated_round_bits_compose_with_downlink():
    """Federated uplink accounting (mask bitmap + |S_t| payloads) composes
    with the single downlink broadcast: absent workers still receive it."""
    part = Participation(kind="fixed", s=2)
    out = run_bidirectional_trajectory(
        "oracle", compressor=QSGD(16), downlink=Downlink(QSGD(16)),
        participation=part, steps=2, n=6, d=512, lam=0.8, nu=0.9, gamma=0.05)
    fmt = wire.WireFormat((out["codec"],))
    assert out["round_bits"]["up"] == fmt.bits_per_round(
        n_workers=6, participants=2)
    assert out["round_bits"]["down"] \
        == wire.WireFormat((out["down_codec"],)).downlink_bits_per_round()


# ---------------------------------------------------------------------------
# trainer == reference: the downlink broadcast draws the same key everywhere
# ---------------------------------------------------------------------------

def test_trainer_downlink_matches_reference_round():
    """Each shard_map-trainer step with a compressed downlink equals the
    hand-rolled reference round (compress/combine + broadcast_global from
    downlink_key(step_key)) on params, h, h_avg and w, with the reference
    RESYNCED to the trainer's state every round: quantized/sparsified
    channels are discontinuous, so the ULP-level fusion differences between
    the trainer's jitted step and the standalone reference would decorrelate
    whole trajectories (the same reason the 1-vs-8-device legs use
    allclose).  Per-round agreement is what pins the key folds and the
    broadcast semantics."""
    from jax.sharding import PartitionSpec as P
    from repro.core.efbv import downlink_key
    from repro.launch.mesh import make_mesh
    from repro.optim import constant, sgd
    from repro.optim.optimizers import apply_updates
    from repro.train import (init_train_state, make_train_step,
                             train_state_shardings)

    mesh = make_mesh((1, 1))
    D = 64
    params = {"p": jax.random.normal(KEY, (D,)) * 0.1}
    algo = EFBV(QSGD(8), lam=0.9, nu=0.9)
    down = Downlink(BlockTopK(16, 4))
    opt = sgd(constant(0.05))

    st = init_train_state(jax.tree.map(jnp.array, params), opt, mesh,
                          bidirectional=True)
    sh = train_state_shardings(mesh, {"p": P(None)}, st)
    st = jax.tree.map(lambda x, s: jax.device_put(x, s), st, sh)
    step = make_train_step(
        lambda p, b: (jnp.mean((b["x"] @ p["p"] - b["y"]) ** 2), {}),
        opt, algo, mesh, agg_mode="sparse_allgather", downlink=down)

    for i in range(4):
        kb = jax.random.fold_in(jax.random.key(9), i)
        x = jax.random.normal(kb, (4, D))
        batch = {"x": x, "y": x @ jnp.ones((D,)) * 0.3}
        k = jax.random.fold_in(KEY, i)
        # resync the reference to the trainer's state BEFORE stepping (the
        # jitted step donates its buffers, so copy out first)
        w_ref = {"p": jnp.array(st.w["p"])}
        p_prev = {"p": jnp.array(st.params["p"])}
        h_prev = {"p": jnp.array(st.h["p"])}
        havg_prev = {"p": jnp.array(st.h_avg["p"])}
        st, _ = step(st, batch, k)

        grads = jax.grad(
            lambda p: jnp.mean((x @ p["p"] - batch["y"]) ** 2))(w_ref)
        grads = {"p": grads["p"][None]}
        keys = jax.random.fold_in(k, 0)[None]
        g, h_ref, havg_ref = efbv_aggregate_reference(
            algo, keys, grads, h_prev, havg_prev, mode="sparse_allgather")
        updates, _ = opt.update(g, opt.init(p_prev), p_prev)
        p_ref = apply_updates(p_prev, updates)
        # the downlink's top-k selection is discontinuous in params, so the
        # broadcast is verified against the trainer's OWN params output
        # (bit-identical inputs -> bit-identical broadcast)
        w_check, _ = broadcast_global(down, downlink_key(k),
                                      {"p": jnp.array(st.params["p"])}, w_ref)

        for got, want, name in [(st.params["p"], p_ref["p"], "params"),
                                (st.h["p"], h_ref["p"], "h"),
                                (st.h_avg["p"], havg_ref["p"], "h_avg")]:
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-6, atol=1e-7,
                                       err_msg=f"{name} @ round {i}")
        np.testing.assert_array_equal(np.asarray(st.w["p"]),
                                      np.asarray(w_check["p"]),
                                      err_msg=f"w @ round {i}")


# ---------------------------------------------------------------------------
# heterogeneous fleets: theory aggregation
# ---------------------------------------------------------------------------

def test_fleet_constants_worst_and_mean():
    etas, omegas = [0.0, 0.5, 0.9], [15.0, 0.0, 3.0]
    e_w, o_w, oav_w = theory.fleet_constants(etas, omegas, n=4)
    assert (e_w, o_w) == (0.9, 15.0)
    assert oav_w == 15.0 / 4
    e_m, o_m, oav_m = theory.fleet_constants(etas, omegas, n=4,
                                             aggregate="mean")
    assert np.isclose(e_m, sum(etas) / 3) and np.isclose(o_m, 6.0)
    assert np.isclose(oav_m, 6.0 / 4)
    with pytest.raises(ValueError):
        theory.fleet_constants([], [], n=4)
    with pytest.raises(ValueError):
        theory.fleet_constants(etas, omegas, n=4, aggregate="median")


def test_fleet_tuning_homogeneous_collapses_and_mean_is_tighter():
    """A homogeneous fleet tunes exactly like the single compressor; the
    averaged aggregate never yields a smaller stepsize than worst-case."""
    d, n = 256, 8
    comp = TopK(16)
    t_single = tune_for(comp, d, n, L=1.0, Ltilde=1.0)
    t_fleet = tune_for((comp,) * n, d, n, L=1.0, Ltilde=1.0)
    assert t_single.lam == t_fleet.lam and t_single.nu == t_fleet.nu
    assert t_single.gamma == t_fleet.gamma

    mixed = [TopK(16), RandK(64), QSGD(16)]
    etas = [c.eta(d) for c in mixed]
    omegas = [c.omega(d) for c in mixed]
    t_worst = theory.tune_fleet(etas, omegas, n=n, L=1.0, Ltilde=1.0)
    t_mean = theory.tune_fleet(etas, omegas, n=n, aggregate="mean",
                               L=1.0, Ltilde=1.0)
    assert t_mean.gamma >= t_worst.gamma
    assert 0 < t_worst.r < 1


def test_fleet_tuning_composes_participation_per_member():
    """Bernoulli(p) participation composes into EACH member before the
    aggregation (skipping a round is a per-worker event); p = 1 is a
    no-op."""
    d, n, p = 256, 8, 0.5
    mixed = [TopK(16), QSGD(16)]
    etas = [c.eta(d) for c in mixed]
    omegas = [c.omega(d) for c in mixed]
    t_p1 = theory.tune_fleet(etas, omegas, n=n, participation=1.0)
    t_ref = theory.tune_fleet(etas, omegas, n=n)
    assert (t_p1.lam, t_p1.nu) == (t_ref.lam, t_ref.nu)
    t_half = theory.tune_fleet(etas, omegas, n=n, participation=p)
    e_comp = [theory.participation_eta(p, e) for e in etas]
    o_comp = [theory.participation_omega(p, e, o)
              for e, o in zip(etas, omegas)]
    e, o, oav = theory.fleet_constants(e_comp, o_comp, n=n)
    assert t_half.eta == e and t_half.omega == o and t_half.omega_av == oav
    # and the sampled regime shrinks the contraction budget
    assert t_half.r >= t_ref.r


def test_tune_for_accepts_fleet_and_efbv_make_collapses_uniform():
    d, n = 256, 6
    fleet = make_fleet("topk:16;qsgd:16", n)
    assert len(fleet) == n
    assert isinstance(fleet[0], TopK) and isinstance(fleet[1], QSGD)
    assert fleet[2] == fleet[0]  # round-robin
    t = tune_for(fleet, d, n)
    assert 0 < t.lam <= 1.0

    algo = EFBV.make(fleet, d=d, n=n)
    assert algo.fleet == fleet and algo.compressor == fleet[0]
    uniform = EFBV.make(make_fleet("topk:16", n), d=d, n=n)
    assert uniform.fleet is None  # collapses to the homogeneous fast path

    with pytest.raises(ValueError):
        make_fleet("topk:16;" * 7, n)  # 7 members, 6 workers
    with pytest.raises(ValueError):
        expand_fleet((MNice(4, 2),), n)  # joint draws cannot be a fleet
    with pytest.raises(ValueError):
        make_fleet("", n)


# ---------------------------------------------------------------------------
# heterogeneous fleets: execution
# ---------------------------------------------------------------------------

def test_fleet_reference_step_runs_each_members_compressor():
    """EFBV.step with a fleet: worker i's innovation is compressed by ITS
    member (pinned against per-worker manual compress_delta calls)."""
    n, d = 3, 96
    fleet = make_fleet("topk:7;randk:9;sign", n)
    algo = EFBV(fleet[0], lam=0.7, nu=0.9, fleet=fleet)
    grads = jax.random.normal(KEY, (n, d))
    st = algo.init(jnp.zeros((d,)), n)
    k = jax.random.fold_in(KEY, 1)
    g, st2 = algo.step(k, grads, st)

    keys = jax.random.split(k, n)
    d_manual = jnp.stack([
        algo.compress_delta(keys[i], grads[i], jnp.zeros((d,)), fleet[i])
        for i in range(n)])
    np.testing.assert_array_equal(
        np.asarray(st2.h), np.asarray(algo.lam * d_manual))
    d_bar = jnp.mean(d_manual, axis=0)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(algo.nu * d_bar))


def test_fleet_dense_psum_aggregate_matches_reference_step():
    """The dense_psum aggregation path (lax.switch worker dispatch) agrees
    with the reference fleet step on one round."""
    n, d = 4, 64
    fleet = make_fleet("topk:5;qsgd:8", n)
    algo = EFBV(fleet[0], lam=0.8, nu=1.0, fleet=fleet)
    grads = {"p": jax.random.normal(KEY, (n, d))}
    h = {"p": jax.random.normal(jax.random.key(1), (n, d)) * 0.1}
    h_avg = {"p": jnp.zeros((d,))}
    keys = jax.random.split(jax.random.key(2), n)

    g, h_new, h_avg_new = efbv_aggregate_reference(
        algo, keys, grads, h, h_avg, mode="dense_psum")

    d_manual = jnp.stack([
        algo.compress_delta(keys[i], grads["p"][i], h["p"][i], fleet[i])
        for i in range(n)])
    np.testing.assert_array_equal(
        np.asarray(h_new["p"]), np.asarray(h["p"] + algo.lam * d_manual))
    d_bar = jnp.mean(d_manual, axis=0)
    np.testing.assert_allclose(np.asarray(g["p"]),
                               np.asarray(algo.nu * d_bar), rtol=1e-7)


def test_fleet_rejects_sparse_allgather_and_requires_worker_index():
    n, d = 2, 32
    fleet = make_fleet("topk:4;sign", n)
    algo = EFBV(fleet[0], lam=0.8, nu=1.0, fleet=fleet)
    g = jnp.ones((d,))
    with pytest.raises(ValueError, match="uniform per-worker message"):
        compress_local(algo, KEY, g, jnp.zeros((d,)),
                       mode="sparse_allgather", worker=jnp.asarray(0))
    with pytest.raises(ValueError, match="worker index"):
        compress_local(algo, KEY, g, jnp.zeros((d,)), mode="dense_psum")


def test_fleet_run_converges_on_quadratic():
    """A mixed top-k / rand-k / QSGD fleet still converges under the
    worst-case tuned stepsize (the certified aggregate)."""
    n, d = 6, 32
    key = jax.random.key(3)
    A = jax.random.normal(key, (n, d, d)) / np.sqrt(d)
    Q = jnp.einsum("nij,nkj->nik", A, A) + 0.5 * jnp.eye(d)
    b = jax.random.normal(jax.random.key(4), (n, d))
    x_star = jnp.linalg.solve(jnp.mean(Q, 0), jnp.mean(b, 0))
    L = float(jnp.linalg.norm(jnp.mean(Q, 0), 2))
    Lt = float(jnp.sqrt(jnp.mean(jnp.asarray(
        [jnp.linalg.norm(Q[i], 2) ** 2 for i in range(n)]))))

    fleet = make_fleet("topk:8;randk:16;qsgd:16", n)
    t = tune_for(fleet, d, n, L=L, Ltilde=Lt)
    algo = EFBV.make(fleet, d=d, n=n)
    m = run_reference(algo=algo,
                      grad_fn=lambda _k, x: jnp.einsum("nij,j->ni", Q, x) - b,
                      x0=jnp.zeros(d), gamma=t.gamma, steps=3000, key=KEY,
                      n=n, record=lambda x: jnp.sum((x - x_star) ** 2)).metrics
    # worst-case mixed-fleet tuning is conservative (r close to 1 with the
    # unbiased members' omega): ask for 3 orders of magnitude, not exactness
    assert float(m[-1]) < 1e-3 * float(m[0]), (float(m[0]), float(m[-1]))


def test_fleet_bidirectional_run_converges():
    """Fleet uplink + compressed downlink in the reference driver."""
    n, d = 4, 32
    key = jax.random.key(5)
    A = jax.random.normal(key, (n, d, d)) / np.sqrt(d)
    Q = jnp.einsum("nij,nkj->nik", A, A) + 0.5 * jnp.eye(d)
    b = jax.random.normal(jax.random.key(6), (n, d))
    x_star = jnp.linalg.solve(jnp.mean(Q, 0), jnp.mean(b, 0))

    fleet = make_fleet("topk:8;qsgd:16", n)
    algo = EFBV.make(fleet, d=d, n=n)
    m = run_reference(
        algo=algo, downlink=Downlink(TopK(16)),
        grad_fn=lambda k, x: jnp.einsum("nij,j->ni", Q, x) - b,
        x0=jnp.zeros(d), gamma=0.05, steps=4000, key=KEY, n=n,
        record=lambda x: jnp.sum((x - x_star) ** 2)).metrics
    assert float(m[-1]) < 1e-5 * max(float(jnp.sum(x_star ** 2)), 1.0)


# ---------------------------------------------------------------------------
# the shard_map leg (slow; nightly CI)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_bidirectional_federated_trainer_8dev_matches_reference():
    """8-fake-device shard_map trainer with a compressed downlink AND
    bernoulli:0.5 participation vs the single-process reference
    (efbv_aggregate_reference + broadcast_global): per-worker packing and
    the broadcast are deterministic given the shared key folds, so params,
    h and w agree to all-reduce reordering tolerance."""
    from conftest import run_with_devices
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import BlockTopK, Downlink, EFBV, Participation, QSGD
        from repro.core.efbv import downlink_key, participation_key
        from repro.optim import sgd, constant
        from repro.optim.optimizers import apply_updates
        from repro.train import (make_train_step, init_train_state,
                                 train_state_shardings)
        from repro.launch.mesh import make_mesh
        from repro.distributed.aggregate import (broadcast_global,
                                                 efbv_aggregate_reference)

        D, n, key = 16, 8, jax.random.key(0)
        params = {"p": jax.random.normal(key, (D,)) * 0.1}
        algo = EFBV(BlockTopK(8, 2), lam=0.8, nu=0.9)
        down = Downlink(QSGD(8))
        part = Participation(kind="bernoulli", p=0.5)
        opt = sgd(constant(0.05))

        def loss_fn(p, batch):
            return jnp.mean((batch["x"] @ p["p"] - batch["y"]) ** 2), {}

        def batches(i):
            kb = jax.random.fold_in(jax.random.key(42), i)
            x = jax.random.normal(kb, (16, D))
            return x, x @ jnp.ones((D,)) * 0.3

        mesh = make_mesh((8, 1))
        st = init_train_state(jax.tree.map(jnp.array, params), opt, mesh,
                              bidirectional=True)
        sh = train_state_shardings(mesh, {"p": P(None)}, st)
        st = jax.tree.map(lambda x, s: jax.device_put(x, s), st, sh)
        step = make_train_step(loss_fn, opt, algo, mesh,
                               agg_mode="sparse_allgather",
                               downlink=down, participation=part)
        for i in range(6):
            x, y = batches(i)
            batch = {"x": jax.device_put(x, NamedSharding(mesh, P("data"))),
                     "y": jax.device_put(y, NamedSharding(mesh, P("data")))}
            st, _ = step(st, batch, jax.random.fold_in(key, i))

        p_ref = jax.tree.map(jnp.array, params)
        w_ref = jax.tree.map(jnp.array, params)
        h, h_avg = jnp.zeros((n, D)), jnp.zeros((D,))
        opt_state = opt.init(p_ref)
        for i in range(6):
            k = jax.random.fold_in(key, i)
            x, y = batches(i)
            xw, yw = x.reshape(n, 2, D), y.reshape(n, 2)
            grads = jax.vmap(lambda xb, yb: jax.grad(
                lambda p: jnp.mean((xb @ p - yb) ** 2))(w_ref["p"]))(xw, yw)
            keys = jax.vmap(lambda j: jax.random.fold_in(k, j))(jnp.arange(n))
            mask = part.sample_mask(participation_key(k), n)
            g, hh, hav = efbv_aggregate_reference(
                algo, keys, {"p": grads}, {"p": h}, {"p": h_avg},
                mode="sparse_allgather", masks=mask)
            h, h_avg = hh["p"], hav["p"]
            updates, opt_state = opt.update(g, opt_state, p_ref)
            p_ref = apply_updates(p_ref, updates)
            w_ref, _ = broadcast_global(down, downlink_key(k), p_ref, w_ref)

        np.testing.assert_allclose(np.asarray(st.params["p"]),
                                   np.asarray(p_ref["p"]),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(st.h["p"]), np.asarray(h),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(st.w["p"]),
                                   np.asarray(w_ref["p"]),
                                   rtol=1e-6, atol=1e-6)
        print("BIDIR_8DEV_OK")
    """, n_devices=8)
    assert "BIDIR_8DEV_OK" in out


@pytest.mark.slow
def test_fleet_dense_psum_trainer_8dev_runs():
    """8-device shard_map trainer with a 3-member mixed fleet under
    dense_psum: the lax.switch worker dispatch works inside the manual
    region and the loss decreases."""
    from conftest import run_with_devices
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import EFBV, make_fleet
        from repro.optim import sgd, constant
        from repro.train import (make_train_step, init_train_state,
                                 train_state_shardings)
        from repro.launch.mesh import make_mesh

        D, n, key = 32, 8, jax.random.key(0)
        params = {"p": jnp.zeros((D,))}
        fleet = make_fleet("topk:8;randk:8;qsgd:16", n)
        algo = EFBV.make(fleet, d=D, n=n)
        assert algo.fleet is not None
        opt = sgd(constant(0.1))

        def loss_fn(p, batch):
            return jnp.mean((batch["x"] @ p["p"] - batch["y"]) ** 2), {}

        mesh = make_mesh((8, 1))
        st = init_train_state(jax.tree.map(jnp.array, params), opt, mesh)
        sh = train_state_shardings(mesh, {"p": P(None)}, st)
        st = jax.tree.map(lambda x, s: jax.device_put(x, s), st, sh)
        step = make_train_step(loss_fn, opt, algo, mesh,
                               agg_mode="dense_psum")
        losses = []
        for i in range(20):
            kb = jax.random.fold_in(jax.random.key(42), i)
            x = jax.random.normal(kb, (16, D))
            batch = {"x": jax.device_put(x, NamedSharding(mesh, P("data"))),
                     "y": jax.device_put(x @ (jnp.arange(D) / D),
                                         NamedSharding(mesh, P("data")))}
            st, m = step(st, batch, jax.random.fold_in(key, i))
            losses.append(float(m["loss"]))
        assert losses[-1] < 0.5 * losses[0], losses
        print("FLEET_8DEV_OK")
    """, n_devices=8)
    assert "FLEET_8DEV_OK" in out
