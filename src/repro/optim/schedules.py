"""Learning-rate schedules.  A schedule is a pure fn: step (int32 array) -> lr.

Includes WSD (warmup-stable-decay) from MiniCPM [arXiv:2404.06395], assigned
to the minicpm-2b config.
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

Schedule = Callable


def constant(lr: float) -> Schedule:
    return lambda step: jnp.full((), lr, jnp.float32)


def linear_warmup(lr: float, warmup_steps: int) -> Schedule:
    def f(step):
        w = jnp.minimum(step.astype(jnp.float32) / max(warmup_steps, 1), 1.0)
        return lr * w

    return f


def cosine(lr: float, total_steps: int, warmup_steps: int = 0, final_frac: float = 0.1) -> Schedule:
    def f(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / max(warmup_steps, 1), 1.0) if warmup_steps else 1.0
        t = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1.0 - final_frac) * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return lr * warm * cos

    return f


def wsd(lr: float, warmup_steps: int, stable_steps: int, decay_steps: int,
        final_frac: float = 0.01) -> Schedule:
    """Warmup-Stable-Decay (MiniCPM): linear warmup, flat plateau, then an
    exponential-style decay over the last ``decay_steps``."""

    def f(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / max(warmup_steps, 1), 1.0)
        t = jnp.clip((s - warmup_steps - stable_steps) / max(decay_steps, 1), 0.0, 1.0)
        decay = jnp.power(jnp.asarray(final_frac, jnp.float32), t)
        return lr * warm * decay

    return f
