"""CLI: ``python -m repro.analysis`` (also the ``repro-analysis`` script).

Modes (combinable; exit code is non-zero if any requested mode fails):

  python -m repro.analysis src/ tests/          # AST rules over .py trees
  python -m repro.analysis --docs               # link check + doctest census
  python -m repro.analysis --hlo-gate           # dense-free kernel proofs
  python -m repro.analysis src/ --golden ANALYSIS_GOLDEN.json
  python -m repro.analysis src/ tests/ --write-golden ANALYSIS_GOLDEN.json
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.analysis import framework
from repro.analysis import rules as _rules  # noqa: F401  (populates RULES)


def _parse_args(argv):
    ap = argparse.ArgumentParser(
        prog="repro.analysis",
        description="repo-invariant static analysis (rules: %s)"
                    % ", ".join(sorted(framework.RULES)))
    ap.add_argument("paths", nargs="*",
                    help="files/directories of .py sources to lint")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable findings report on stdout")
    ap.add_argument("--rules", default="",
                    help="comma-separated subset of rules to run")
    ap.add_argument("--golden", default="",
                    help="compare finding counts against this golden file")
    ap.add_argument("--write-golden", default="",
                    help="write finding counts to this golden file and exit 0")
    ap.add_argument("--docs", nargs="*", metavar="PATH",
                    help="run the docs analysis (link check + doctest "
                         "census) over PATHs (default: docs README.md)")
    ap.add_argument("--hlo-gate", nargs="*", metavar="KERNEL",
                    help="prove registered pack kernels dense-free "
                         "(default: all registered kernels)")
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = _parse_args(sys.argv[1:] if argv is None else argv)
    status = 0
    ran_anything = False

    if args.docs is not None:
        from repro.analysis import docs as docs_mod

        ran_anything = True
        status = max(status, docs_mod.main(list(args.docs)))

    if args.hlo_gate is not None:
        from repro.analysis import hlo

        ran_anything = True
        reports = hlo.gate(list(args.hlo_gate) or None)
        for r in reports:
            line = (f"dense-free {r.kernel}: d={r.d} tile={r.tile} "
                    f"max_inner={r.max_inner} -> "
                    + ("PROVEN" if r.ok else "VIOLATED"))
            print(line)
            for v in r.violations:
                print(f"  {v}", file=sys.stderr)
        if not all(r.ok for r in reports):
            status = max(status, 1)

    if args.paths:
        ran_anything = True
        rules = None
        if args.rules:
            names = [n.strip() for n in args.rules.split(",") if n.strip()]
            unknown = [n for n in names if n not in framework.RULES]
            if unknown:
                print(f"unknown rules: {', '.join(unknown)} "
                      f"(known: {', '.join(sorted(framework.RULES))})",
                      file=sys.stderr)
                return 2
            rules = {n: framework.RULES[n] for n in names}
        result = framework.analyze_paths(args.paths, rules)
        if args.write_golden:
            framework.write_golden(result, args.write_golden)
            print(f"wrote {args.write_golden}: {result.counts()}")
            return status
        if args.json:
            print(json.dumps(result.as_dict(), indent=1, sort_keys=True))
        else:
            for f in result.findings + result.errors:
                print(f.format())
            c = result.counts()
            print(f"repro.analysis: {c['files']} files, "
                  f"{len(result.findings) + len(result.errors)} findings, "
                  f"{len(result.suppressed)} suppressed "
                  f"({len(c['rules'])} rules active)")
        if result.findings or result.errors:
            status = max(status, 1)
        if args.golden:
            diffs = framework.compare_golden(result, args.golden)
            for d in diffs:
                print(f"golden drift: {d}", file=sys.stderr)
            if diffs:
                status = max(status, 1)

    if not ran_anything:
        print("nothing to do: give source paths and/or --docs/--hlo-gate "
              "(see --help)", file=sys.stderr)
        return 2
    return status


if __name__ == "__main__":
    sys.exit(main())
