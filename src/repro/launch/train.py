"""End-to-end training driver.

Example (CPU, reduced config):

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
        --mesh 2x2 --steps 50 --compressor block_topk:256,16 --algo efbv

On a real cluster the same entry point takes --arch <id> (full config) and
--mesh 16x16 / 2x16x16.  The EF-BV layer is selected with --algo
{efbv, ef21, diana, none} and --agg {dense_psum, sparse_allgather}; the
federated execution mode with --participation {full, bernoulli:p, fixed:s}
and --local-batch-resample (see
docs/algorithms.md#partial-participation--stochastic-gradients).
"""

from __future__ import annotations

import argparse
import math
import os
import sys
import time

# On CPU hosts, force enough XLA host devices for the requested mesh BEFORE
# jax initializes (same constraint as launch/dryrun.py).
if "--mesh" in sys.argv and "XLA_FLAGS" not in os.environ:
    _shape = sys.argv[sys.argv.index("--mesh") + 1]
    _n = math.prod(int(x) for x in _shape.split("x"))
    if _n > 1:
        os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={_n}"

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import ARCHS, get_config, get_smoke_config
from repro.core import (Downlink, EFBV, Identity, Participation,
                        make_compressor, make_fleet)
from repro.data import SyntheticLM, make_batch_shardings
from repro.launch.mesh import make_mesh, num_workers
from repro.models import build_model
from repro.optim import adamw, cosine, wsd
from repro.train import init_train_state, make_train_step, train_state_shardings


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ARCHS)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--mesh", default="2x2", help="e.g. 2x2, 16x16, 2x16x16")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="auto", choices=["auto", "cosine", "wsd"])
    ap.add_argument("--algo", default="efbv", choices=["efbv", "ef21", "diana", "none"])
    ap.add_argument("--compressor", default="block_topk:256,16")
    ap.add_argument("--agg", default="dense_psum",
                    choices=["dense_psum", "sparse_allgather"])
    ap.add_argument("--wire-dtype", default="float32",
                    choices=["float32", "bfloat16", "float16"],
                    help="value precision of sparse/dense wire payloads "
                         "(quantized and bit-packed codecs ignore it)")
    ap.add_argument("--downlink", default="",
                    help="compressor spec for the master->worker model "
                         "broadcast (bidirectional compression through the "
                         "spec's wire codec, e.g. 'qsgd:16' or "
                         "'block_topk:256,16', optionally '@lam'); empty = "
                         "uncompressed dense broadcast")
    ap.add_argument("--worker-comps", default="",
                    help="heterogeneous fleet: ';'-separated compressor "
                         "specs assigned round-robin to the n workers (or "
                         "an explicit length-n list), e.g. "
                         "'topk:64;randk:64;qsgd:16'.  Overrides "
                         "--compressor; mixed fleets need --agg dense_psum")
    ap.add_argument("--participation", default="full",
                    help="per-round client sampling: full | bernoulli:p | "
                         "fixed:s (federated execution mode; absent workers "
                         "keep stale control variates)")
    ap.add_argument("--local-batch-resample", action="store_true",
                    help="stochastic local gradients: resample each worker's "
                         "minibatch from a FIXED local shard every round "
                         "instead of streaming fresh data")
    ap.add_argument("--shard-size", type=int, default=64,
                    help="sequences per worker shard for "
                         "--local-batch-resample")
    ap.add_argument("--trainer", default="shard_map",
                    choices=["shard_map", "fsdp"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--heterogeneity", type=float, default=0.5)
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    mesh = make_mesh([int(x) for x in args.mesh.split("x")])
    n = num_workers(mesh)
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)

    # WSD schedule for minicpm (its assigned training recipe), cosine otherwise
    sched_kind = args.schedule
    if sched_kind == "auto":
        sched_kind = "wsd" if args.arch.startswith("minicpm") else "cosine"
    if sched_kind == "wsd":
        sched = wsd(args.lr, warmup_steps=max(args.steps // 20, 1),
                    stable_steps=int(args.steps * 0.7),
                    decay_steps=max(int(args.steps * 0.25), 1))
    else:
        sched = cosine(args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 20, 1))
    opt = adamw(sched, weight_decay=0.01)

    participation = Participation.parse(args.participation)
    if participation.kind == "fixed" and participation.s > n:
        raise SystemExit(f"--participation fixed:{participation.s} needs at "
                         f"least that many workers, mesh has {n}")
    federated = not participation.is_full
    if args.algo == "none":
        algo = EFBV(Identity(), lam=1.0, nu=1.0)
    else:
        if args.worker_comps:
            # heterogeneous fleet: worker i runs its own compressor; (lam, nu)
            # tuned for the aggregated mixed-fleet constants (theory.tune_fleet)
            comp = make_fleet(args.worker_comps, n)
        else:
            comp = make_compressor(args.compressor)
        # federated rounds tune (lam, nu) for the effective compressor b*C,
        # b ~ Bernoulli(E|S_t|/n) -- theory.tune_partial / docs/theory.md
        algo = EFBV.make(comp, d=max(cfg.d_model * max(cfg.d_ff, 1), 1), n=n,
                         mode=args.algo,
                         participation=participation.fraction(n) if federated
                         else None)
    if algo.fleet is not None and args.agg != "dense_psum":
        raise SystemExit("--worker-comps with distinct members needs a "
                         "uniform message shape: use --agg dense_psum")
    downlink = Downlink.parse(args.downlink)
    print(f"[train] arch={cfg.name} family={cfg.family} params~{cfg.param_count():,} "
          f"workers={n} algo={args.algo} lam={algo.lam:.4g} nu={algo.nu:.4g} "
          f"agg={args.agg}"
          + (f" participation={args.participation}" if federated else "")
          + (f" downlink={args.downlink}" if downlink else "")
          + (f" fleet={args.worker_comps}" if algo.fleet is not None else ""))

    key = jax.random.key(args.seed)
    params = model.init(key)
    state = init_train_state(params, opt, mesh,
                             bidirectional=downlink is not None)

    # exact wire accounting for the codec payload (docs/wire_format.md);
    # every compressor declares a codec, so this always prints
    from repro.distributed import wire
    up_fmt = wire.format_for(algo.compressor, params,
                             wire_dtype=args.wire_dtype) \
        if args.agg == "sparse_allgather" else None
    if up_fmt is not None:
        up = up_fmt.bits_per_round()
        dense = up_fmt.dense_bits()
        kinds = sorted({l.kind for l in up_fmt.leaves})
        print(f"[train] wire: codec={','.join(kinds)} {up} bits/round/worker "
              f"uplink ({up / 8 / 2**20:.2f} MiB, "
              f"{up / max(dense, 1):.4f}x dense fp32)")
        if federated:
            exp_s = participation.fraction(n) * n
            fed = up_fmt.bits_per_round(n_workers=n, participants=exp_s)
            full = up_fmt.bits_per_round(n_workers=n)
            print(f"[train] wire: federated round (mask bitmap + E|S_t|={exp_s:g}"
                  f" of {n} payloads) ~{fed / 8 / 2**20:.2f} MiB total "
                  f"({fed / max(full, 1):.3f}x the full-participation round)")
    elif algo.fleet is not None:
        fmts = wire.fleet_formats(algo.fleet, params,
                                  wire_dtype=args.wire_dtype)
        bits = wire.fleet_bits_per_round(fmts)
        per = sorted({f.bits_per_round() for f in fmts})
        print(f"[train] wire: mixed fleet of {len(set(algo.fleet))} member "
              f"kinds, per-worker bits in {per}, {bits} bits/round uplink "
              f"(would-be payload; dense_psum carries dense tensors)")
    if downlink is not None:
        # the downlink accounting prints for EVERY agg mode: the broadcast
        # payload is real regardless of how the uplink travels
        dfmt = downlink.format_for(params, wire_dtype=args.wire_dtype)
        down = dfmt.downlink_bits_per_round()
        dense = dfmt.dense_bits()
        up = (up_fmt.bits_per_round() if up_fmt is not None else dense)
        total = wire.total_round_bits(
            up_fmt, dfmt, n_workers=n,
            participants=participation.fraction(n) * n if federated
            else None) if up_fmt is not None else n * up + down
        dense_total = n * dense + dense  # fp32 both directions
        print(f"[train] wire: downlink {down} bits/round broadcast "
              f"({down / max(dense, 1):.4f}x dense fp32); total "
              f"{total:g} bits/round up+down "
              f"({total / max(dense_total, 1):.4f}x dense both ways)")
    if args.trainer == "fsdp":
        from repro.train import fsdp_state_shardings
        shardings = fsdp_state_shardings(mesh, model.param_specs(), state)
    else:
        shardings = train_state_shardings(mesh, model.param_specs(), state)
    state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, shardings)

    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                       global_batch=args.global_batch, n_workers=n,
                       seed=args.seed, heterogeneity=args.heterogeneity,
                       resample_from_shard=args.local_batch_resample,
                       shard_size=args.shard_size)

    def loss_fn(p, batch):
        return model.loss(p, batch)

    if args.trainer == "fsdp":
        from repro.train import make_train_step_fsdp
        step_fn = make_train_step_fsdp(loss_fn, opt, algo, mesh,
                                       agg_mode=args.agg,
                                       wire_dtype=args.wire_dtype,
                                       downlink=downlink,
                                       participation=participation)
    else:
        step_fn = make_train_step(loss_fn, opt, algo, mesh, agg_mode=args.agg,
                                  wire_dtype=args.wire_dtype,
                                  downlink=downlink,
                                  participation=participation)

    t_start = time.time()
    for step in range(args.steps):
        batch = make_batch_shardings(mesh, data.batch(step))
        if cfg.family == "vlm":
            batch["vision_embeds"] = jax.device_put(
                np.random.default_rng(step).standard_normal(
                    (args.global_batch, cfg.vision_patches, cfg.d_model),
                    dtype=np.float32))
        if cfg.family == "encdec":
            batch["frames"] = jax.device_put(
                np.random.default_rng(step).standard_normal(
                    (args.global_batch, cfg.encoder_frames, cfg.d_model),
                    dtype=np.float32))
        state, metrics = step_fn(state, batch, jax.random.fold_in(key, step))
        if step % args.log_every == 0 or step == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            part_str = f"|S|={int(m['participants'])}/{n} " \
                if "participants" in m else ""
            print(f"[train] step {step:5d} loss={m['loss']:.4f} "
                  f"|g|={m['g_norm']:.3f} |upd|={m['update_norm']:.4f} "
                  f"h_res={m['h_residual']:.3f} {part_str}"
                  f"({(time.time()-t_start)/(step+1):.2f}s/step)")
        if args.ckpt_dir and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1, {"params": state.params})
            print(f"[train] checkpoint @ {step + 1}")
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, {"params": state.params})
    print(f"[train] done: final loss {float(metrics['loss']):.4f}")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
