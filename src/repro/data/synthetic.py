"""Deterministic synthetic LM data pipeline.

Mirrors the paper's heterogeneous-data regime: each worker's shard is drawn
from a *worker-specific* Zipf-ish distribution (heterogeneity > 0 skews the
per-worker vocabulary slice), so the per-worker gradients nabla f_i genuinely
differ -- the setting where EF-BV's control variates matter.

Sequences have local bigram structure (token t+1 = t * A + noise mod V) so a
~100M model visibly learns within a few hundred steps in the end-to-end
example.

``resample_from_shard`` switches to the federated stochastic-gradient
regime: each worker owns a fixed finite shard and every round resamples its
minibatch from it (--local-batch-resample in launch/train.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.distributed.spec import batch_spec
from repro.launch.mesh import num_workers


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    n_workers: int = 1
    seed: int = 0
    heterogeneity: float = 0.5  # 0 = iid workers, 1 = disjoint vocab slices
    # federated stochastic-gradient regime: each worker holds a FIXED local
    # shard of shard_size sequences (its finite-sum f_i) and every round
    # resamples its minibatch from that shard, instead of streaming fresh
    # sequences (the exact-local-objective regime above).
    resample_from_shard: bool = False
    shard_size: int = 64

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # per-worker vocab offsets create heterogeneous token marginals
        self._offsets = rng.integers(0, self.vocab, size=self.n_workers)
        self._mult = 6364136223846793005 % self.vocab
        if self.resample_from_shard:
            shard_rng = np.random.default_rng((self.seed, 0x5A3D))
            self._shards = [self._gen_rows(shard_rng, w, self.shard_size)
                            for w in range(self.n_workers)]

    def _gen_rows(self, rng, w: int, count: int) -> np.ndarray:
        """``count`` bigram-structured sequences from worker w's marginal."""
        S, V = self.seq_len, self.vocab
        span = max(int(V * (1.0 - self.heterogeneity)), V // 16)
        base = rng.integers(0, span, size=(count, 1))
        start = (base + self._offsets[w]) % V
        noise = rng.integers(0, 7, size=(count, S))
        seqs = np.zeros((count, S), np.int64)
        seqs[:, 0] = start[:, 0]
        for t in range(1, S):
            seqs[:, t] = (seqs[:, t - 1] * 3 + noise[:, t] + self._offsets[w]) % V
        return seqs

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Global batch for one step: tokens + next-token labels.

        Streaming mode draws fresh per-worker sequences; shard-resampling
        mode draws per_w uniform (with replacement) rows from each worker's
        fixed shard -- both deterministic in (seed, step).
        """
        B, S = self.global_batch, self.seq_len
        per_w = B // self.n_workers
        rng = np.random.default_rng((self.seed, step))
        rows = []
        for w in range(self.n_workers):
            if self.resample_from_shard:
                idx = rng.integers(0, self.shard_size, size=per_w)
                rows.append(self._shards[w][idx])
            else:
                rows.append(self._gen_rows(rng, w, per_w))
        tokens = np.concatenate(rows, 0).astype(np.int32)
        labels = np.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1).astype(np.int32)
        labels[:, -1] = -1  # no loss on the wrap position
        return {"tokens": tokens, "labels": labels}


def make_batch_shardings(mesh, batch: Dict[str, np.ndarray]):
    spec = batch_spec(mesh)
    return {k: jax.device_put(v, NamedSharding(mesh, spec)) for k, v in batch.items()}
