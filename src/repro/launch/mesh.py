"""Production mesh geometry.

Defined as FUNCTIONS so that importing this module never touches jax device
state (jax locks the device count on first backend init -- see
launch/dryrun.py which must set XLA_FLAGS before anything else).
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np


POD_CHIPS = 256  # one v5e pod slice: 16 x 16
DATA_AXIS = "data"
MODEL_AXIS = "model"
POD_AXIS = "pod"


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) ('data','model') single pod; (2,16,16) ('pod','data','model')
    across two pods."""
    from repro import compat

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Optional[Sequence[str]] = None):
    """Arbitrary mesh for tests/smoke runs; axes default to trailing names of
    ('pod','data','model'), so shapes with more than 3 dims need explicit
    axes."""
    from repro import compat

    if axes is None:
        defaults = ("pod", "data", "model")
        if len(shape) > len(defaults):
            # the trailing-names slice cannot grow past 3 axes; silently
            # recycling it would hand jax a short/duplicate axis tuple
            raise ValueError(
                f"make_mesh has default axis names for up to {len(defaults)} "
                f"mesh dims {defaults}, got shape {tuple(shape)} with "
                f"{len(shape)} dims -- pass axes= explicitly")
        axes = defaults[-len(shape):]
    return compat.make_mesh(tuple(shape), tuple(axes))


def multihost_worker_shape(n_workers: int, num_processes: int
                           ) -> Tuple[int, int]:
    """Split a worker count into (num_processes, workers_per_process).

    The leading worker axis of a multi-host mesh must tile exactly across
    processes -- a worker shard that straddled two hosts would turn every
    phase-1 shard_map into a cross-host collective."""
    if num_processes < 1:
        raise ValueError(f"num_processes must be >= 1, got {num_processes}")
    if n_workers % num_processes:
        raise ValueError(
            f"{n_workers} workers cannot tile {num_processes} processes: "
            f"the leading worker axis must be divisible by the process "
            f"count so each host owns whole workers")
    return num_processes, n_workers // num_processes


def make_multihost_mesh(shape: Sequence[int],
                        axes: Optional[Sequence[str]] = None, *,
                        num_processes: int = 1,
                        devices: Optional[Sequence] = None):
    """A mesh whose device layout is PROCESS-MAJOR: process p's devices fill
    rows [p * rows_per_process, (p+1) * rows_per_process) of the leading
    mesh axis, contiguously.

    On a real multi-host cluster every jax process contributes its local
    devices; sorting the global device list by (process_index, id) and
    reshaping row-major means each host's devices land in one contiguous
    block of the leading (worker) axis -- so the phase-1 worker collectives
    of the EF-BV trainers stay host-local wherever the axis splits cleanly.
    On a single process with fake XLA host devices (CPU CI) the same
    construction simulates the multi-host layout: pass ``num_processes`` to
    validate the geometry, the device order is already process-major.

    Axis-name defaults match :func:`make_mesh`.  Requires the leading axis
    divisible by ``num_processes`` (each process owns whole rows) and
    ``prod(shape)`` total devices.
    """
    import jax
    from jax.sharding import Mesh

    shape = tuple(shape)
    if axes is None:
        defaults = ("pod", "data", "model")
        if len(shape) > len(defaults):
            raise ValueError(
                f"make_multihost_mesh has default axis names for up to "
                f"{len(defaults)} mesh dims {defaults}, got shape {shape} "
                f"with {len(shape)} dims -- pass axes= explicitly")
        axes = defaults[-len(shape):]
    axes = tuple(axes)
    multihost_worker_shape(shape[0], num_processes)

    if devices is None:
        devices = sorted(jax.devices(),
                         key=lambda d: (d.process_index, d.id))
    devices = list(devices)
    total = int(np.prod(shape))
    if len(devices) != total:
        raise ValueError(
            f"mesh shape {shape} needs {total} devices, got {len(devices)}")
    per_process = total // num_processes
    owners = [getattr(d, "process_index", 0) for d in devices]
    if len(set(owners)) > 1:
        # real multi-host: device i must belong to process i // per_process.
        # (Single-process fake host devices -- the simulated multi-process
        # CPU regime -- all report process 0; there the contiguous blocks
        # ARE the simulated processes and only the geometry is checked.)
        for i, owner in enumerate(owners):
            if owner != i // per_process:
                raise ValueError(
                    f"device list is not process-major: device {i} belongs "
                    f"to process {owner}, expected process "
                    f"{i // per_process} -- sort by (process_index, id) "
                    f"before building the mesh")
    dev_array = np.asarray(devices, dtype=object).reshape(shape)
    try:
        return Mesh(dev_array, axes,
                    axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (AttributeError, TypeError):  # old jax: no axis_types kwarg
        return Mesh(dev_array, axes)


def process_worker_slice(shape: Sequence[int], num_processes: int,
                         process_index: int) -> range:
    """The linear worker indices process ``process_index`` owns under the
    process-major layout of :func:`make_multihost_mesh` (its slice of the
    global batch, for per-host data pipelines).  The model axis, if any, is
    the trailing mesh dim and does not change worker numbering."""
    shape = tuple(shape)
    # all axes except the trailing 'model' axis are worker axes; a 1-d mesh
    # is all workers (mesh_worker_count convention in core/spec.py)
    n = int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]
    multihost_worker_shape(shape[0], num_processes)
    if not 0 <= process_index < num_processes:
        raise ValueError(f"process_index {process_index} out of range for "
                         f"{num_processes} processes")
    per = n // num_processes
    return range(process_index * per, (process_index + 1) * per)


def worker_axes(mesh) -> Tuple[str, ...]:
    """The EF-BV 'worker' axes of a mesh = every axis except 'model'.

    The paper's n = product of these axis sizes."""
    return tuple(a for a in mesh.axis_names if a != MODEL_AXIS)


def num_workers(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in worker_axes(mesh)]))
