"""Per-architecture smoke tests (assignment deliverable f): every assigned
arch instantiates a REDUCED same-family config and runs one forward/train
step on CPU, asserting output shapes and finiteness; plus decode-vs-forward
consistency per family and layer-level unit tests."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import build_model
from repro.models import layers as L
from repro.optim import adamw, constant
from repro.optim.optimizers import apply_updates

KEY = jax.random.key(0)
B, S = 2, 64


def make_batch(cfg, key=KEY, with_labels=True):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if with_labels:
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab)  # repro: noqa(prng-reuse) -- deterministic fixture, draws need not be independent
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(  # repro: noqa(prng-reuse) -- deterministic fixture, draws need not be independent
            key, (B, cfg.vision_patches, cfg.d_model)) * 0.1
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(  # repro: noqa(prng-reuse) -- deterministic fixture, draws need not be independent
            key, (B, cfg.encoder_frames, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """Reduced config: forward shapes + one optimizer step, no NaNs."""
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512 and cfg.n_experts <= 4
    model = build_model(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg)

    logits, aux = jax.jit(model.forward)(params, batch)
    S_total = S + (cfg.vision_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_total, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    opt = adamw(constant(1e-3))
    opt_state = opt.init(params)
    (loss, _), grads = jax.jit(
        jax.value_and_grad(model.loss, has_aux=True))(params, batch)
    assert bool(jnp.isfinite(loss))
    g_leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in g_leaves)
    updates, opt_state = opt.update(grads, opt_state, params)
    new_params = apply_updates(params, updates)
    loss2, _ = jax.jit(model.loss)(new_params, batch)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    cache = model.init_cache(B, 32)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = jax.jit(model.decode_step)(params, cache, tok, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-130m", "zamba2-7b",
                                  "granite-moe-3b-a800m", "whisper-medium",
                                  "qwen2-vl-2b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode reproduces the full forward logits (fp32)."""
    S_ = 16
    cfg = get_smoke_config(arch)
    # capacity large enough that no token is dropped: capacity-bounded MoE
    # otherwise legitimately differs between batched prefill (tokens compete
    # for expert slots) and one-token decode (they don't).
    cfg = dataclasses.replace(cfg, remat=False, activation_dtype="float32",
                              ssm_chunk=8, capacity_factor=float(cfg.n_experts))
    model = build_model(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(KEY, (B, S_), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch.pop("vision_embeds", None)  # text-only decode path
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(KEY, (B, cfg.encoder_frames,  # repro: noqa(prng-reuse) -- deterministic fixture, draws need not be independent
                                                  cfg.d_model)) * 0.1
    full, _ = jax.jit(model.forward)(params, batch)
    cache = model.init_cache(B, S_)
    if cfg.family == "encdec":
        cache = model.encode_cross_cache(params, batch["frames"], cache)
    dec = jax.jit(model.decode_step)
    outs = []
    for t in range(S_):
        lg, cache = dec(params, cache, toks[:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    got = jnp.stack(outs, 1)
    ref = full[:, -S_:] if cfg.family == "vlm" else full
    rel = float(jnp.max(jnp.abs(got - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert rel < 2e-2, rel


def test_sliding_window_masks_old_tokens():
    """A token beyond the window must not influence attention output."""
    cfg = dataclasses.replace(get_smoke_config("qwen2-0.5b"), attn_window=8,
                              remat=False, activation_dtype="float32")
    model = build_model(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(KEY, (1, 24), 0, cfg.vocab)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 7) % cfg.vocab)  # mutate pos 0
    l1, _ = model.forward(params, {"tokens": toks})
    l2, _ = model.forward(params, {"tokens": toks2})
    # last position is > window away from position 0: logits identical
    np.testing.assert_allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]),
                               atol=1e-5)
    # but an in-window position does change
    assert float(jnp.max(jnp.abs(l1[0, 4] - l2[0, 4]))) > 1e-6


def test_gqa_head_grouping():
    """GQA: with n_kv < n_heads, groups of queries share one kv head."""
    d, H, K, hd = 32, 4, 2, 8
    p, _ = L.attention_init(jax.random.key(1), d, H, K, hd, qkv_bias=False)
    x = jax.random.normal(KEY, (1, 6, d))
    pos = jnp.broadcast_to(jnp.arange(6), (1, 6))
    out = L.attention(p, x, n_heads=H, n_kv=K, hd=hd, positions=pos,
                      theta=1e4)
    assert out.shape == (1, 6, d)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_rope_is_relative():
    """RoPE: q.k depends only on relative offsets."""
    hd = 16
    q = jax.random.normal(KEY, (1, 1, 1, hd))
    k = jax.random.normal(jax.random.key(1), (1, 1, 1, hd))
    def score(pq, pk):
        qr = L.apply_rope(q, jnp.asarray([[pq]]), 1e4)
        kr = L.apply_rope(k, jnp.asarray([[pk]]), 1e4)
        return float(jnp.sum(qr * kr))
    assert abs(score(3, 1) - score(10, 8)) < 1e-4
    assert abs(score(3, 1) - score(5, 1)) > 1e-4


def test_mrope_sections_rotate_independently():
    """M-RoPE: changing only the h-position stream must not affect the
    temporal-section channels."""
    hd = 16
    secs = (3, 3, 2)
    x = jax.random.normal(KEY, (1, 1, 1, hd))
    p1 = jnp.zeros((3, 1, 1), jnp.int32).at[0].set(5)
    p2 = p1.at[1].set(9)
    y1 = L.apply_mrope(x, p1, 1e4, secs)
    y2 = L.apply_mrope(x, p2, 1e4, secs)
    # temporal section channels: 0:3 and 8:11 (paired halves)
    np.testing.assert_allclose(np.asarray(y1[..., 0:3]), np.asarray(y2[..., 0:3]),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(y1[..., 8:11]), np.asarray(y2[..., 8:11]),
                               atol=1e-6)
    assert float(jnp.max(jnp.abs(y1 - y2))) > 1e-6


def test_moe_router_balance_loss():
    from repro.models.moe import moe_apply, moe_init
    p, _ = moe_init(jax.random.key(2), 16, 32, 4)
    x = jax.random.normal(KEY, (2, 8, 16))
    out, aux = moe_apply(p, x, n_experts=4, k=2)
    assert out.shape == x.shape
    assert float(aux) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz, == 1 at balance


def test_moe_capacity_drop():
    """Tokens over capacity are dropped, not duplicated."""
    from repro.models.moe import moe_apply, moe_init
    p, _ = moe_init(jax.random.key(2), 8, 16, 2)
    x = jax.random.normal(KEY, (1, 4, 8))
    out_small, _ = moe_apply(p, x, n_experts=2, k=1, capacity_factor=0.25)
    out_big, _ = moe_apply(p, x, n_experts=2, k=1, capacity_factor=4.0)
    # with tiny capacity some outputs are zeroed
    assert float(jnp.sum(jnp.abs(out_small))) < float(jnp.sum(jnp.abs(out_big)))


def test_mamba2_chunk_invariance():
    """SSD output must not depend on the chunk size (pure algebra identity)."""
    from repro.models.mamba2 import mamba2_apply, mamba2_init
    d, di, st, nh = 16, 32, 8, 4
    p, _ = mamba2_init(jax.random.key(3), d, d_inner=di, d_state=st,
                       n_heads=nh, d_conv=4)
    x = jax.random.normal(KEY, (2, 32, d))
    y1 = mamba2_apply(p, x, d_inner=di, d_state=st, n_heads=nh, chunk=8)
    y2 = mamba2_apply(p, x, d_inner=di, d_state=st, n_heads=nh, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-3,
                               atol=2e-4)


def test_param_specs_structure_matches_params():
    for arch in ARCHS:
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        params = jax.eval_shape(model.init, KEY)
        specs = model.param_specs()
        # tree structures must match leaf-for-leaf
        jax.tree.map(lambda p, s: None, params, specs,
                     is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def test_full_config_param_counts():
    """Full (non-smoke) configs match their published parameter scale."""
    expect = {
        "minitron-8b": (7e9, 10e9),
        "phi3-medium-14b": (12e9, 15.5e9),
        "dbrx-132b": (120e9, 140e9),
        "mamba2-130m": (0.1e9, 0.2e9),
        "qwen2-0.5b": (0.4e9, 0.7e9),
        "minicpm-2b": (2.2e9, 3.3e9),
        "zamba2-7b": (6e9, 8.5e9),
        "qwen2-vl-2b": (1.2e9, 2.3e9),
        "whisper-medium": (0.6e9, 1.1e9),  # SwiGLU MLP (3 mats) vs GELU (2)
        "granite-moe-3b-a800m": (2.5e9, 4e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, f"{n:,}", lo, hi)
