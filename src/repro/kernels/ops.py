"""jit'd public wrappers around the Pallas kernels.

Handle arbitrary input shapes (flatten + pad to (nb, block) slabs), pick
interpret mode automatically off-TPU, and expose the same signatures as the
jnp oracles in ref.py (tests assert allclose between the two).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import block_topk as K
from repro.kernels import pack as KP

Array = jax.Array


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _to_slabs(x: Array, block: int) -> Tuple[Array, int, Tuple[int, ...]]:
    xf = x.reshape(-1)
    d = xf.shape[0]
    nb = -(-d // block)
    nb_pad = -(-nb // K.TILE_NB) * K.TILE_NB
    xp = jnp.pad(xf, (0, nb_pad * block - d)).reshape(nb_pad, block)
    return xp, d, x.shape


@functools.partial(jax.jit, static_argnames=("block", "kb", "interpret"))
def block_topk(x: Array, block: int = 1024, kb: int = 64,
               interpret: bool | None = None) -> Array:
    """Dense block-top-k compression of an arbitrary-shape tensor."""
    interpret = _interpret_default() if interpret is None else interpret
    xp, d, shape = _to_slabs(x, block)
    out = K.block_topk_pallas(xp, kb, interpret=interpret)
    return out.reshape(-1)[:d].reshape(shape)


@functools.partial(jax.jit, static_argnames=("block", "kb", "lam", "interpret"))
def efbv_update(g: Array, h: Array, lam: float, block: int = 1024, kb: int = 64,
                interpret: bool | None = None) -> Tuple[Array, Array]:
    """Fused worker update: d = C(g - h); h' = h + lam d.  Returns (d, h')."""
    interpret = _interpret_default() if interpret is None else interpret
    gp, d_len, shape = _to_slabs(g, block)
    hp, _, _ = _to_slabs(h.astype(g.dtype), block)
    d_out, h_out = K.efbv_update_pallas(gp, hp, lam, kb, interpret=interpret)
    unpad = lambda a: a.reshape(-1)[:d_len].reshape(shape)
    return unpad(d_out), unpad(h_out).astype(h.dtype)


@functools.partial(jax.jit, static_argnames=("block", "kb", "lam", "interpret"))
def efbv_pack_update(g: Array, h: Array, lam: float, block: int = 1024,
                     kb: int = 64, interpret: bool | None = None
                     ) -> Tuple[Tuple[Array, Array], Array]:
    """Fused compress-and-pack worker update (kernels/pack.py): one HBM pass
    computing d = block_topk(g - h), h' = h + lam d, and the wire payload.

    Returns ((values, indices), h') with values/indices of shape (nb, kb),
    nb = ceil(g.size / block) -- the same payload layout as
    ``BlockTopK.encode`` (rows added for TILE_NB alignment are sliced off).
    """
    interpret = _interpret_default() if interpret is None else interpret
    gp, d_len, shape = _to_slabs(g, block)
    # h keeps its own dtype: the kernel subtracts in f32, so pre-rounding h
    # to g.dtype would break bit-identity with the jnp oracle on mixed dtypes
    hp, _, _ = _to_slabs(h, block)
    vals, idx, h_out = KP.pack_update_pallas(gp, hp, lam, kb,
                                             interpret=interpret)
    nb = -(-d_len // block)
    h_new = h_out.reshape(-1)[:d_len].reshape(shape)
    return (vals[:nb], idx[:nb]), h_new
