"""The paper's headline property (Tab. 1 last row): EF-BV's convergence
improves as the number of workers n grows, while EF21's rate is n-independent.

We sweep n and report (a) the theoretical stepsize gamma (monotone in n for
EF-BV, flat for EF21) and (b) the measured suboptimality after a fixed number
of rounds on the logistic-regression problem."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import KEY, make_problem
from repro.core import CompKK, EFBV, run, tune_for


def run_bench(fast: bool = True):
    steps = 1200 if fast else 6000
    name = "phishing"
    rows = []
    gammas = {"efbv": [], "ef21": []}
    finals = {"efbv": [], "ef21": []}
    ns = [10, 100, 1000] if fast else [10, 50, 100, 500, 1000, 2000]
    for n in ns:
        prob = make_problem(name, n=n)
        _, fstar = prob.solve()
        d = prob.d
        comp = CompKK(1, d // 2)
        for mode in ["efbv", "ef21"]:
            t = tune_for(comp, d, n, mode=mode, L=prob.L(), Ltilde=prob.L_tilde())
            algo = EFBV(comp, lam=t.lam, nu=t.nu)
            _, _, m = run(algo=algo, grad_fn=prob.grads, x0=jnp.zeros(d),
                          gamma=t.gamma, steps=steps, key=KEY, n=n,
                          record=lambda x: prob.f(x) - fstar)
            gammas[mode].append(t.gamma)
            finals[mode].append(float(m[-1]))
    # theory: EF-BV gamma must increase with n; EF21's is n-independent
    bv_monotone = all(gammas["efbv"][i] <= gammas["efbv"][i + 1] * (1 + 1e-9)
                      for i in range(len(ns) - 1))
    ef21_flat = max(gammas["ef21"]) / max(min(gammas["ef21"]), 1e-30) < 1.3
    rows.append({"name": "n_scaling/gamma_monotone_in_n",
                 "us_per_call": "",
                 "derived": f"efbv_monotone={bv_monotone};ef21_flat={ef21_flat};"
                            f"gamma_efbv={[f'{g:.2e}' for g in gammas['efbv']]};"
                            f"gamma_ef21={[f'{g:.2e}' for g in gammas['ef21']]}"})
    for i, n in enumerate(ns):
        rows.append({"name": f"n_scaling/n{n}/final_gap",
                     "us_per_call": "",
                     "derived": f"efbv={finals['efbv'][i]:.3e};"
                                f"ef21={finals['ef21'][i]:.3e}"})
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run_bench(fast=True))
