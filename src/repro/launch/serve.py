"""Compressed-delta serving: replicas fed by the EF-BV downlink.

The trainer's downlink control variate ``w`` (core.efbv.Downlink) is the
workers' shared reconstruction of the model -- which is exactly what a
serving replica needs.  This module turns that observation into a
production-shaped subsystem:

:class:`DeltaPusher`    trainer side: monotonically versioned compressed
                        pushes (``Downlink.encode_push``) + a checkpoint
                        per version as the replicas' resync source.
:class:`ServeReplica`   replica side: local ``w`` advanced by
                        ``Downlink.apply_push`` (same codecs, same fold
                        keys as the in-training broadcast, so replica w ==
                        trainer w bit-for-bit), versioned hot-swap (stage
                        the next model into a shadow while the current one
                        serves; atomic swap between decode steps), stale /
                        out-of-order rejection with checkpoint resync.
:class:`DecodeEngine`   continuous batching: requests admitted / retired
                        per decode step from a queue over a fixed set of
                        cache slots (vmapped per-slot decode), instead of
                        the fixed ``(B, prompt)`` block.
:func:`run_fleet`       simulated many-replica fleet driver for an
                        ExperimentSpec with a ``serve`` leg (the
                        benchmarks/serve_fleet.py entry point).

CLI (the original single-model greedy-decode contract, now running on the
continuous-batching engine):

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --smoke \
        --batch 4 --prompt-len 16 --gen 32

or a replica-fleet run from a spec file with a ``serve`` field:

    PYTHONPATH=src python -m repro.launch.serve \
        --spec examples/specs/serve_delta.json
"""

from __future__ import annotations

import argparse
import collections
import dataclasses
import time
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.core.efbv import Downlink, downlink_key
from repro.distributed.wire import DeltaEnvelope, checkpoint_push_bits, push_bits
from repro.models import build_model

PyTree = Any


# -----------------------------------------------------------------------------
# continuous batching
# -----------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    """One decode request: ``prompt`` then ``gen`` greedy tokens.

    ``out`` collects the generated ids; ``versions[i]`` is the model
    version (the tag passed to :meth:`DecodeEngine.step`) that produced
    ``out[i]`` -- the hot-swap atomicity evidence."""

    rid: int
    prompt: np.ndarray
    gen: int
    frames: Optional[np.ndarray] = None
    out: List[int] = dataclasses.field(default_factory=list)
    versions: List[int] = dataclasses.field(default_factory=list)
    done: bool = False

    @property
    def total_steps(self) -> int:
        return len(self.prompt) + self.gen


class DecodeEngine:
    """Greedy decode over ``slots`` independent cache lanes with per-step
    admission/retirement.

    The cache batch axis (axis 1 in every cache leaf, all model families)
    is the slot axis; the decode step is ``jax.vmap`` of the model's
    single-sequence step over it, so each lane advances with its own
    position and its own token stream.  Per-lane independence is what makes
    continuous batching equal fixed batching token-for-token (pinned by
    tests/test_serve_delta.py): a request decodes the same ids whether its
    neighbours are mid-prompt, retired, or empty.

    Token semantics (identical to the original fixed-block driver): the
    input at position p is ``prompt[p]`` while p < len(prompt), else the
    previous output (a BOS-style 0 for an empty prompt at p=0); the ids
    collected as output are the outputs of positions [len(prompt),
    len(prompt) + gen).
    """

    def __init__(self, model, *, slots: int, max_len: int):
        if slots <= 0:
            raise ValueError(f"need at least one slot, got {slots}")
        self.model = model
        self.cfg = model.cfg
        self.slots = slots
        self.max_len = max_len
        self.cache = model.init_cache(slots, max_len)
        self.pos = np.zeros(slots, np.int64)
        self.last_tok = np.zeros(slots, np.int64)
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: collections.deque = collections.deque()
        self.finished: List[Request] = []
        self.tokens_decoded = 0
        self._next_rid = 0

        def slot_step(params, cache_slot, token, pos):
            # one lane: re-add the size-1 batch axis the vmap stripped
            cache1 = jax.tree.map(lambda a: a[:, None], cache_slot)
            logits, cache1 = model.decode_step(
                params, cache1, token[None, None].astype(jnp.int32),
                pos.astype(jnp.int32))
            nxt = jnp.argmax(logits[0, -1], axis=-1).astype(jnp.int32)
            return nxt, jax.tree.map(lambda a: a[:, 0], cache1)

        self._step = jax.jit(jax.vmap(slot_step, in_axes=(None, 1, 0, 0),
                                      out_axes=(0, 1)))

    # ---- request lifecycle -------------------------------------------------

    def submit(self, prompt, gen: int, *, frames=None) -> Request:
        prompt = np.asarray(prompt, np.int64).reshape(-1)
        if len(prompt) + gen > self.max_len:
            raise ValueError(
                f"request needs {len(prompt)} prompt + {gen} generated = "
                f"{len(prompt) + gen} positions but the decode cache holds "
                f"{self.max_len}; shorten the request or build the engine "
                "with a larger max_len")
        req = Request(rid=self._next_rid, prompt=prompt, gen=int(gen),
                      frames=None if frames is None else np.asarray(frames))
        self._next_rid += 1
        self.queue.append(req)
        return req

    def _admit(self, params) -> None:
        for s in range(self.slots):
            if self.active[s] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            self.active[s] = req
            self.pos[s] = 0
            self.last_tok[s] = 0
            # a fresh lane: SSM state is cumulative, so the slot's cache
            # column must be zeroed, not just overwritten lazily
            self.cache = jax.tree.map(lambda a: a.at[:, s].set(0), self.cache)
            if req.frames is not None:
                c1 = self.model.init_cache(1, self.max_len)
                c1 = self.model.encode_cross_cache(params, req.frames[None],
                                                   c1)
                for k in ("cross_k", "cross_v"):
                    self.cache[k] = self.cache[k].at[:, s].set(c1[k][:, 0])

    @property
    def idle(self) -> bool:
        return not self.queue and all(r is None for r in self.active)

    # ---- one decode step ---------------------------------------------------

    def step(self, params, *, version: int = -1) -> int:
        """Admit what fits, advance every lane one token, retire finished
        requests.  ``version`` tags the tokens this step emits (the model
        version serving them).  Returns the number of request tokens
        decoded (prompt and generated; idle lanes don't count)."""
        self._admit(params)
        toks = np.zeros(self.slots, np.int32)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            p = self.pos[s]
            toks[s] = req.prompt[p] if p < len(req.prompt) else \
                self.last_tok[s]
        out, self.cache = self._step(params, self.cache, jnp.asarray(toks),
                                     jnp.asarray(self.pos, jnp.int32))
        out = np.asarray(out)
        decoded = 0
        for s, req in enumerate(self.active):
            if req is None:
                continue
            decoded += 1
            p = int(self.pos[s])
            if p >= len(req.prompt):
                req.out.append(int(out[s]))
                req.versions.append(version)
            self.last_tok[s] = int(out[s])
            self.pos[s] = p + 1
            if p + 1 == req.total_steps:
                req.done = True
                self.finished.append(req)
                self.active[s] = None
        self.tokens_decoded += decoded
        return decoded

    def run(self, params, *, version: int = -1) -> int:
        """Drain queue + lanes to completion; returns tokens decoded."""
        n = 0
        while not self.idle:
            n += self.step(params, version=version)
        return n


# -----------------------------------------------------------------------------
# the versioned push protocol
# -----------------------------------------------------------------------------

def push_key(key, version: int):
    """The per-push broadcast key: the SAME derivation as training round
    ``version`` (fold the round index, then the downlink domain), so a
    serving push and the in-training broadcast of that round put identical
    payload bits on the wire."""
    return downlink_key(jax.random.fold_in(key, version))


class DeltaPusher:
    """Trainer-side push source: holds the fleet's shared reconstruction
    ``w``, emits strictly versioned :class:`DeltaEnvelope`s, and saves one
    checkpoint of ``w`` per version as the replicas' resync source."""

    def __init__(self, downlink: Downlink, params0: PyTree, *, key,
                 wire_dtype: str = "float32", rules=None,
                 ckpt_dir: Optional[str] = None, spec=None):
        self.downlink = downlink
        self.wire_dtype = wire_dtype
        self.rules = rules
        self.key = key
        self.ckpt_dir = ckpt_dir
        self.spec = spec
        self.version = 0
        self.w = downlink.init(params0)
        if ckpt_dir is not None:
            from repro.checkpoint import save_checkpoint
            save_checkpoint(ckpt_dir, 0, self.w, spec=spec)

    def push(self, x: PyTree) -> DeltaEnvelope:
        """Compress ``x - w`` (or a lossless snapshot of ``x``) into the
        next versioned envelope and advance ``w`` exactly as every replica
        will."""
        v = self.version + 1
        self.w, payloads = self.downlink.encode_push(
            push_key(self.key, v), x, self.w, wire_dtype=self.wire_dtype,
            rules=self.rules)
        env = DeltaEnvelope(
            version=v, base_version=self.version, payloads=payloads,
            kind=self.downlink.push_kind(self.wire_dtype, self.rules))
        self.version = v
        if self.ckpt_dir is not None:
            from repro.checkpoint import save_checkpoint
            save_checkpoint(self.ckpt_dir, v, self.w, spec=self.spec)
        return env


class ServeReplica:
    """One serving replica: local reconstruction ``w`` + versioned
    hot-swap.

    A push is first *staged* -- decoded into a shadow copy while the
    current model keeps serving -- then *committed*: an atomic rebind of
    ``(version, params)`` between decode steps, so every token is produced
    by exactly one version.  Version checks are strict: a push at or below
    the replica's version is rejected as stale (re-delivery is idempotent),
    and a delta whose ``base_version`` is not the replica's version is a
    gap -- the replica resyncs from the newest checkpoint (the pusher
    writes one per version, so resync re-pins ``w`` bit-for-bit) and then
    re-chains the push if it still applies.  Snapshot pushes (lossless
    wire) assign absolutely, so they repair any gap by themselves.
    """

    def __init__(self, downlink: Downlink, params0: PyTree, *,
                 wire_dtype: str = "float32", rules=None,
                 ckpt_dir: Optional[str] = None, spec=None,
                 version: int = 0):
        self.downlink = downlink
        self.wire_dtype = wire_dtype
        self.rules = rules
        self.ckpt_dir = ckpt_dir
        self.spec = spec
        self.version = version
        self.params = jax.tree.map(jnp.asarray, params0)
        self._shadow: Optional[tuple] = None
        self.stage_s: List[float] = []
        self.swap_s: List[float] = []
        self.resyncs = 0

    # ---- two-phase hot-swap ------------------------------------------------

    def stage(self, env: DeltaEnvelope) -> str:
        """Decode a push into the shadow (the current model keeps serving).
        Returns 'staged' | 'stale' | 'gap'."""
        if env.version <= self.version:
            return "stale"
        if env.kind == "delta" and env.base_version != self.version:
            return "gap"
        t0 = time.perf_counter()
        w_new = self.downlink.apply_push(env.payloads, self.params,
                                         wire_dtype=self.wire_dtype,
                                         rules=self.rules)
        w_new = jax.block_until_ready(w_new)
        self.stage_s.append(time.perf_counter() - t0)
        self._shadow = (env.version, w_new)
        return "staged"

    def commit(self) -> bool:
        """Swap the staged model in (between decode steps): one atomic
        rebind, nothing to decode on the serving path."""
        if self._shadow is None:
            return False
        t0 = time.perf_counter()
        self.version, self.params = self._shadow
        self._shadow = None
        self.swap_s.append(time.perf_counter() - t0)
        return True

    # ---- resync ------------------------------------------------------------

    def resync(self) -> int:
        """Re-pin from the newest checkpoint (bit-for-bit: the pusher
        checkpoints its ``w`` per version).  Stages the restored model;
        commit applies it."""
        if self.ckpt_dir is None:
            raise RuntimeError(
                "replica hit a version gap but has no ckpt_dir to resync "
                "from; construct ServeReplica(..., ckpt_dir=...) or ship "
                "snapshot pushes")
        from repro.checkpoint import restore_latest
        got = restore_latest(self.ckpt_dir, self.params, spec=self.spec)
        if got is None:
            raise RuntimeError(f"no checkpoint to resync from in "
                               f"{self.ckpt_dir!r}")
        step, params = got
        self.resyncs += 1
        self._shadow = (step, jax.tree.map(jnp.asarray, params))
        return step

    def push(self, env: DeltaEnvelope) -> str:
        """Stage + commit in one call (the fleet driver's path when no
        decode is in flight).  Returns 'applied' | 'stale' | 'resync'."""
        st = self.stage(env)
        if st == "staged":
            self.commit()
            return "applied"
        if st == "gap":
            self.resync()
            self.commit()
            if self.stage(env) == "staged":  # push chains onto the restore
                self.commit()
            return "resync"
        return st


# -----------------------------------------------------------------------------
# the simulated replica fleet
# -----------------------------------------------------------------------------

def _train_move(x: PyTree, key) -> PyTree:
    """One simulated training update (deterministic in ``key``): a small
    per-leaf perturbation standing in for an optimizer step, so the fleet
    driver exercises real non-zero deltas without a training loop."""
    leaves, treedef = jax.tree.flatten(x)
    new = []
    for j, leaf in enumerate(leaves):
        kj = jax.random.fold_in(key, j)
        step = 0.01 * jax.random.normal(kj, leaf.shape, jnp.float32)
        new.append((leaf.astype(jnp.float32) + step).astype(leaf.dtype))
    return jax.tree.unflatten(treedef, new)


def run_fleet(spec, *, ckpt_dir: Optional[str] = None,
              quiet: bool = False) -> dict:
    """Drive a simulated replica fleet for ``spec`` (an ExperimentSpec with
    a ``serve`` leg): trainer pushes ``serve.pushes`` compressed deltas
    while every replica continuously decodes, hot-swapping between decode
    steps.  Asserts the fleet invariant -- every replica's w bit-identical
    to the trainer's w after every push -- and returns the bits / tok/s /
    swap-latency metrics the CI bench records."""
    from repro.core.spec import SpecError

    sv = spec.serve_spec()
    if sv is None:
        raise SpecError("run_fleet needs a spec with a serve leg (e.g. "
                        "serve='replicas:2,slots:2,prompt:4,gen:8')")
    cfg = (get_smoke_config(spec.problem) if spec.smoke
           else get_config(spec.problem))
    model = build_model(cfg)

    root = jax.random.key(spec.seed)
    k_params, k_prompt, k_train = jax.random.split(root, 3)
    params = model.init(k_params)

    downlink = Downlink.parse(spec.downlink) or Downlink.parse("identity")
    rules = None
    if spec.leaf_codecs:
        from repro.distributed import wire
        rules = wire.parse_leaf_rules(spec.leaf_codecs)

    pusher = DeltaPusher(downlink, params, key=root,
                         wire_dtype=spec.wire_dtype, rules=rules,
                         ckpt_dir=ckpt_dir, spec=spec)
    replicas = [ServeReplica(downlink, pusher.w, wire_dtype=spec.wire_dtype,
                             rules=rules, ckpt_dir=ckpt_dir, spec=spec)
                for _ in range(sv.replicas)]
    engines = [DecodeEngine(model, slots=sv.slots, max_len=sv.max_len)
               for _ in range(sv.replicas)]
    for r, eng in enumerate(engines):
        for q in range(2 * sv.slots):  # 2 waves: admission mid-flight
            kq = jax.random.fold_in(k_prompt, r * 1000 + q)
            prompt = np.asarray(
                jax.random.randint(kq, (sv.prompt,), 0, cfg.vocab))
            eng.submit(prompt, sv.gen)

    # exact per-push wire accounting (the envelope, header included)
    fmt = downlink.serve_format(params, wire_dtype=spec.wire_dtype,
                                rules=rules)
    delta_bits = push_bits(fmt)
    ckpt_bits = checkpoint_push_bits(fmt)

    x = pusher.w
    steps_per_phase = max(1, (2 * sv.slots * (sv.prompt + sv.gen))
                          // (sv.pushes * max(1, sv.slots)))
    t0 = time.perf_counter()
    for v in range(1, sv.pushes + 1):
        x = _train_move(x, jax.random.fold_in(k_train, v))
        env = pusher.push(x)
        for rep, eng in zip(replicas, engines):
            st = rep.stage(env)
            assert st == "staged", st
            for _ in range(steps_per_phase):  # old version keeps serving
                if eng.idle:
                    break
                eng.step(rep.params, version=rep.version)
            rep.commit()
        _assert_fleet_pinned(pusher, replicas)
    for rep, eng in zip(replicas, engines):
        eng.run(rep.params, version=rep.version)
    wall_s = time.perf_counter() - t0

    tokens = sum(eng.tokens_decoded for eng in engines)
    swaps = [s for rep in replicas for s in rep.swap_s]
    stages = [s for rep in replicas for s in rep.stage_s]
    metrics = {
        "fingerprint": spec.fingerprint(),
        "replicas": sv.replicas,
        "pushes": sv.pushes,
        "requests": sum(len(eng.finished) for eng in engines),
        "tokens": tokens,
        "tok_per_s": tokens / max(wall_s, 1e-9),
        "delta_bits_per_push": delta_bits,
        "checkpoint_bits_per_push": ckpt_bits,
        "push_ratio": delta_bits / ckpt_bits,
        "swap_ms_max": 1e3 * max(swaps, default=0.0),
        "stage_ms_max": 1e3 * max(stages, default=0.0),
    }
    if not quiet:
        print(f"[serve-fleet] arch={cfg.name} replicas={sv.replicas} "
              f"pushes={sv.pushes}: {metrics['tok_per_s']:.1f} tok/s, "
              f"delta {delta_bits} vs checkpoint {ckpt_bits} bits/push "
              f"({metrics['push_ratio']:.3f}x), swap "
              f"{metrics['swap_ms_max']:.3f} ms max")
    return metrics


def _assert_fleet_pinned(pusher: DeltaPusher, replicas) -> None:
    """The whole point: every replica's w bit-identical to the trainer's."""
    want = jax.tree.leaves(pusher.w)
    for r, rep in enumerate(replicas):
        if rep.version != pusher.version:
            raise AssertionError(f"replica {r} at version {rep.version}, "
                                 f"trainer at {pusher.version}")
        for j, (a, b) in enumerate(zip(jax.tree.leaves(rep.params), want)):
            if not np.array_equal(np.asarray(a), np.asarray(b)):
                raise AssertionError(
                    f"replica {r} leaf {j} diverged from the trainer's w "
                    f"at version {pusher.version}")


# -----------------------------------------------------------------------------
# CLI
# -----------------------------------------------------------------------------

def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--spec", default=None, metavar="SPEC_JSON",
                    help="run the replica-fleet driver for this spec file "
                         "(needs a 'serve' field) instead of the "
                         "single-model decode")
    ap.add_argument("--ckpt-dir", default=None,
                    help="fleet mode: checkpoint directory for the "
                         "per-version resync source")
    args = ap.parse_args(argv)
    if args.prompt_len + args.gen > args.max_len:
        ap.error(
            f"--prompt-len {args.prompt_len} + --gen {args.gen} = "
            f"{args.prompt_len + args.gen} tokens would overrun the decode "
            f"cache (--max-len {args.max_len}); shorten the request or "
            "raise --max-len")
    return args


def main(argv=None):
    args = parse_args(argv)

    if args.spec is not None:
        from repro.core.spec import ExperimentSpec
        with open(args.spec) as f:
            spec = ExperimentSpec.from_json(f.read())
        return run_fleet(spec, ckpt_dir=args.ckpt_dir)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    # independent streams for params vs data (one shared key would correlate
    # the random prompts with the random init)
    k_params, k_prompt, k_frames = jax.random.split(
        jax.random.key(args.seed), 3)
    params = model.init(k_params)
    B = args.batch

    prompts = jax.random.randint(k_prompt, (B, args.prompt_len), 0, cfg.vocab)
    frames = None
    if cfg.family == "encdec":
        frames = jax.random.normal(
            k_frames, (B, cfg.encoder_frames, cfg.d_model)) * 0.1

    engine = DecodeEngine(model, slots=B, max_len=args.max_len)
    reqs = [engine.submit(np.asarray(prompts[i]), args.gen,
                          frames=None if frames is None else frames[i])
            for i in range(B)]
    t0 = time.time()
    engine.run(params)
    dt = time.time() - t0
    gen = np.stack([np.asarray(r.out, np.int64) for r in reqs], 0)
    total_tokens = B * (args.prompt_len + args.gen)
    print(f"[serve] arch={cfg.name} batch={B} prompt={args.prompt_len} "
          f"gen={args.gen}: {total_tokens / dt:.1f} tok/s (CPU)")
    print(f"[serve] sample continuation (req 0): {gen[0][:16].tolist()}")
    return gen


if __name__ == "__main__":
    main()
