"""Generate the EXPERIMENTS.md §Roofline table from dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.make_roofline_md

Writes results/roofline_table.md and splices it into EXPERIMENTS.md between
the <!-- ROOFLINE_TABLE --> marker and the §Perf header.
"""

from __future__ import annotations

import json
import os

from benchmarks.roofline import SHAPE_TOKENS, load, model_flops
from repro.configs import get_config
from repro.launch.mesh import POD_CHIPS

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

FIX_HINT = {
    ("compute",): "raise arithmetic intensity (larger per-chip tiles, bf16 MXU)",
    ("memory",): "cut HBM traffic: fuse CE with logits, bf16 states, better remat",
    ("collective",): "shrink the wire: sparse_allgather EF-BV payloads / overlap",
}


def state_bytes_per_device(arch: str, trainer: str = "shard_map") -> float:
    """Analytic optimizer/EF-BV state footprint per device (fp32):
    params + 2 adam + h_i + h_avg + grads ~= 6x params, sharded by 16 (TP
    only, shard_map trainer) or 256 (FSDP)."""
    n = get_config(arch).param_count()
    div = 256.0 if trainer == "fsdp" else 16.0
    # h is n_workers x params sharded over (data=16 x model=16) -> /256 always
    per = n * 4.0 * (5.0 / div + 1.0 / 256.0)
    return per


def fmt(x: float) -> str:
    return f"{x:.2e}"


def main():
    recs = load(mesh="16x16")
    rows = []
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | skip | — | — | "
                        f"{r.get('skip', r.get('note', ''))} |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | "
                        f"{r.get('error', '')[:60]} |")
            continue
        ro = r["roofline"]
        mf = model_flops(r)
        hlo_total = ro["hlo_flops_per_device"] * ro["n_chips"]
        useful = mf / hlo_total if (mf and hlo_total) else float("nan")
        bound = ro["bottleneck"]
        hint = FIX_HINT[(bound,)]
        if r["shape"].startswith("train"):
            sb = state_bytes_per_device(r["arch"]) / 2**30
            fit = f"{sb:.1f}GiB state"
        else:
            fit = ""
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt(ro['t_compute_s'])} | "
            f"{fmt(ro['t_memory_s'])} | {fmt(ro['t_collective_s'])} | "
            f"**{bound}** | {useful:.2f} | {fit} | {hint} |")

    table = (
        "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) | "
        "bound | useful-FLOPs ratio | per-dev state | what moves the dominant term |\n"
        "|---|---|---|---|---|---|---|---|---|\n" + "\n".join(rows) + "\n")

    out = os.path.join(REPO, "results", "roofline_table.md")
    with open(out, "w") as f:
        f.write(table)
    # splice into EXPERIMENTS.md
    exp_path = os.path.join(REPO, "EXPERIMENTS.md")
    txt = open(exp_path).read()
    marker = "<!-- ROOFLINE_TABLE -->"
    if marker in txt:
        head, tail = txt.split(marker, 1)
        rest = tail.split("\n## §Perf", 1)
        perf = "\n## §Perf" + rest[1] if len(rest) == 2 else ""
        open(exp_path, "w").write(head + marker + "\n\n" + table + perf)
    print(f"wrote {out} ({len(rows)} rows) and spliced EXPERIMENTS.md")


if __name__ == "__main__":
    main()
