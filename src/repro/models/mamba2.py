"""Mamba-2 (SSD: state-space duality, arXiv:2405.21060) block in pure JAX.

Training/prefill uses the chunked SSD algorithm (quadratic intra-chunk
"attention" + linear inter-chunk state recurrence via lax.scan); decode uses
the exact O(1)-per-token recurrent form with a (state, conv-buffer) cache --
this is what makes the long_500k decode shape sub-quadratic.

Projections are stored un-fused (wz/wx/wB/wC/wdt) so each can carry its own
'model'-axis sharding (the fused (d, 2*di+2*st+nh) matrix has no divisible
axis on a 16-way mesh).
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import _init, auto_spec, rmsnorm

Array = jax.Array


def mamba2_init(key, d: int, *, d_inner: int, d_state: int, n_heads: int,
                d_conv: int) -> Tuple[Dict, Dict]:
    ks = jax.random.split(key, 8)
    conv_ch = d_inner + 2 * d_state
    params = {
        "wz": _init(ks[0], (d, d_inner)),
        "wx": _init(ks[1], (d, d_inner)),
        "wB": _init(ks[2], (d, d_state)),
        "wC": _init(ks[3], (d, d_state)),
        "wdt": _init(ks[4], (d, n_heads), scale=0.02),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[5], (n_heads,),
                                       minval=math.log(1e-3), maxval=math.log(1e-1))))),
        "A_log": jnp.log(jnp.arange(1, n_heads + 1, dtype=jnp.float32)),
        "D": jnp.ones((n_heads,)),
        "conv_w": _init(ks[6], (d_conv, conv_ch), scale=0.5 / math.sqrt(d_conv)),
        "conv_b": jnp.zeros((conv_ch,)),
        "norm_w": jnp.ones((d_inner,)),
        "wo": _init(ks[7], (d_inner, d), scale=1.0 / math.sqrt(d_inner)),
    }
    specs = {
        "wz": auto_spec((d, d_inner), prefer=(1,)),
        "wx": auto_spec((d, d_inner), prefer=(1,)),
        "wB": auto_spec((d, d_state), prefer=(1,)),
        "wC": auto_spec((d, d_state), prefer=(1,)),
        "wdt": auto_spec((d, n_heads), prefer=(1,)),
        "dt_bias": P(None), "A_log": P(None), "D": P(None),
        "conv_w": auto_spec((d_conv, conv_ch), prefer=(1,)),
        "conv_b": auto_spec((conv_ch,), prefer=(0,)),
        "norm_w": auto_spec((d_inner,), prefer=(0,)),
        "wo": auto_spec((d_inner, d), prefer=(0,)),
    }
    return params, specs


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv over time as K shifted adds.  x: (B, S, C)."""
    K = w.shape[0]
    out = x * w[K - 1]
    for j in range(K - 1):
        shift = K - 1 - j
        out = out + jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :-shift] * w[j]
    return jax.nn.silu(out + b.astype(x.dtype))


def _ssd_chunked(xh: Array, dt: Array, A: Array, Bm: Array, Cm: Array,
                 chunk: int) -> Array:
    """Chunked SSD scan.

    xh: (B, S, H, hp); dt: (B, S, H); A: (H,) negative; Bm, Cm: (B, S, st).
    Returns y: (B, S, H, hp).
    """
    B, S, H, hp = xh.shape
    st = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    f32 = jnp.float32

    xc = xh.reshape(B, nc, chunk, H, hp)
    dtc = dt.reshape(B, nc, chunk, H).astype(f32)
    Bc = Bm.reshape(B, nc, chunk, st)
    Cc = Cm.reshape(B, nc, chunk, st)

    a = dtc * A  # (B, nc, Q, H): per-step log decay (negative)
    cum_a = jnp.cumsum(a, axis=2)  # inclusive cumsum within chunk
    xdt = xc * dtc[..., None].astype(xc.dtype)

    # ---- intra-chunk (quadratic within the chunk) -------------------------
    # L[i, j] = exp(cum_a[i] - cum_a[j]) for i >= j else 0
    diff = cum_a[:, :, :, None, :] - cum_a[:, :, None, :, :]  # (B,nc,Q,Q,H)
    causal = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bnis,bnjs->bnij", Cc.astype(f32), Bc.astype(f32))
    att = cb[..., None] * L  # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", att.astype(xc.dtype), xdt)

    # ---- chunk-local end states -------------------------------------------
    # S_local = sum_j exp(cum_a[Q-1] - cum_a[j]) B_j (x_j dt_j)
    decay_to_end = jnp.exp(cum_a[:, :, -1:, :] - cum_a)  # (B,nc,Q,H)
    s_local = jnp.einsum("bnjs,bnjh,bnjhp->bnhsp",
                         Bc.astype(f32), decay_to_end, xdt.astype(f32))

    # ---- inter-chunk recurrence (scan over chunks) -------------------------
    chunk_decay = jnp.exp(cum_a[:, :, -1, :])  # (B, nc, H)

    def body(s_prev, inp):
        dec, s_loc = inp  # (B,H), (B,H,st,hp)
        s_new = dec[:, :, None, None] * s_prev + s_loc
        return s_new, s_prev

    # seed the carry with a zero *derived from the data* so its varying-
    # manual-axes type matches the loop output when running inside shard_map
    # (an invariant literal zero would trip the scan vma check); outside
    # shard_map the extra +0 folds away.
    s0 = jnp.zeros((B, H, st, hp), f32) + xh.reshape(-1)[0].astype(f32) * 0.0
    _, s_prevs = jax.lax.scan(
        body, s0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(s_local, 1, 0)))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)  # (B, nc, H, st, hp): state entering chunk

    # ---- inter-chunk contribution ------------------------------------------
    decay_from_start = jnp.exp(cum_a)  # exp(cum_a[i] - 0)
    y_inter = jnp.einsum("bnis,bnih,bnhsp->bnihp",
                         Cc.astype(f32), decay_from_start, s_prevs)

    return (y_intra + y_inter.astype(xc.dtype)).reshape(B, S, H, hp)


def mamba2_apply(p, x: Array, *, d_inner: int, d_state: int, n_heads: int,
                 chunk: int, norm_eps: float = 1e-5) -> Array:
    """Full-sequence SSD block.  x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    hp = d_inner // n_heads
    z = x @ p["wz"].astype(x.dtype)
    xin = x @ p["wx"].astype(x.dtype)
    Bm = x @ p["wB"].astype(x.dtype)
    Cm = x @ p["wC"].astype(x.dtype)
    dt = jax.nn.softplus((x @ p["wdt"].astype(x.dtype)).astype(jnp.float32)
                         + p["dt_bias"])  # (B,S,H)

    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_out = _causal_conv(conv_in, p["conv_w"].astype(x.dtype), p["conv_b"])
    xin, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + d_state], axis=-1)

    A = -jnp.exp(p["A_log"])  # (H,)
    xh = xin.reshape(B, S, n_heads, hp)
    y = _ssd_chunked(xh, dt, A, Bm, Cm, chunk)
    y = y + p["D"].astype(x.dtype)[None, None, :, None] * xh
    y = y.reshape(B, S, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"], norm_eps)
    return y @ p["wo"].astype(x.dtype)


# --------------------------------------------------------------------------
# recurrent decode
# --------------------------------------------------------------------------

def mamba2_cache_init(batch: int, *, d_inner: int, d_state: int, n_heads: int,
                      d_conv: int, dtype=jnp.float32) -> Dict[str, Array]:
    hp = d_inner // n_heads
    return {
        "state": jnp.zeros((batch, n_heads, d_state, hp), jnp.float32),
        "conv": jnp.zeros((batch, d_conv - 1, d_inner + 2 * d_state), dtype),
    }


def mamba2_decode(p, x: Array, cache: Dict[str, Array], *, d_inner: int,
                  d_state: int, n_heads: int, norm_eps: float = 1e-5
                  ) -> Tuple[Array, Dict[str, Array]]:
    """One-token recurrent step.  x: (B, 1, d)."""
    B = x.shape[0]
    hp = d_inner // n_heads
    xt = x[:, 0]
    z = xt @ p["wz"].astype(x.dtype)
    xin = xt @ p["wx"].astype(x.dtype)
    Bm = xt @ p["wB"].astype(x.dtype)
    Cm = xt @ p["wC"].astype(x.dtype)
    # the dt projection runs in f32 end-to-end: the narrow (d, H) bf16
    # matmul is the one op whose accumulation order varies with the lowered
    # batch size, and dt feeds the state recurrence, so a bf16 dot here
    # would break the serve engine's vmapped-per-slot == batched bitwise
    # decode invariant (dt is consumed in f32 anyway)
    dt = jax.nn.softplus(xt.astype(jnp.float32) @ p["wdt"]
                         + p["dt_bias"])  # (B,H)

    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)  # (B, C)
    hist = jnp.concatenate([cache["conv"], conv_in[:, None]], axis=1)  # (B,K,C)
    w = p["conv_w"].astype(x.dtype)
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist, w)
                           + p["conv_b"].astype(x.dtype))
    new_conv = hist[:, 1:]
    xin, Bm, Cm = (conv_out[:, :d_inner],
                   conv_out[:, d_inner:d_inner + d_state],
                   conv_out[:, d_inner + d_state:])

    A = -jnp.exp(p["A_log"])  # (H,)
    decay = jnp.exp(dt * A)  # (B,H)
    xh = xin.reshape(B, n_heads, hp).astype(jnp.float32)
    # explicit broadcast product, NOT a 3-operand einsum: einsum's pairwise
    # association order varies with the lowered batch size, which would make
    # the state drift in the last ulp between a vmapped per-slot decode and
    # the plain batched one (the serve engine needs them bit-identical)
    upd = (Bm.astype(jnp.float32)[:, None, :, None]
           * xh[:, :, None, :] * dt[:, :, None, None])
    state = decay[:, :, None, None] * cache["state"] + upd
    y = jnp.einsum("bs,bhsp->bhp", Cm.astype(jnp.float32), state)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(B, d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"], norm_eps)
    out = (y @ p["wo"].astype(x.dtype))[:, None]
    return out, {"state": state, "conv": new_conv}
