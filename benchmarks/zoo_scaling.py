"""Scaling sweeps, two kinds (formerly benchmarks/n_scaling.py -- the row
names keep the historical ``n_scaling/`` prefix so the bench trajectory
stays continuous):

* **Worker scaling** (the paper's headline property, Tab. 1 last row):
  EF-BV's convergence improves as the number of workers n grows, while
  EF21's rate is n-independent.  We sweep n and report (a) the theoretical
  stepsize gamma (monotone in n for EF-BV, flat for EF21) and (b) the
  measured suboptimality after a fixed number of rounds on the
  logistic-regression problem.  The participation sweep (federated
  execution mode) holds n fixed and sweeps the per-round sampling fraction
  p: the wire bits of a round scale as |S_t| while the tuned stepsize and
  the measured suboptimality degrade gracefully.

* **Model-zoo scaling** (:func:`zoo_rows`): the committed fine-tune specs
  (examples/specs/finetune_moe.json + zoo_*_fsdp.json -- smoke-scaled
  stand-ins for each model family) run through the staged harness
  (repro/train/loop.py) under the compressed FSDP wire, recording measured
  steps/sec and exact uplink+downlink bits per round, keyed by each spec's
  committed fingerprint.  These are the model-scale rows of
  BENCH_perf.json / BENCH_bits.json (benchmarks/ci_bench.py)."""

from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np

from benchmarks.common import KEY, make_problem
from repro.core import (CompKK, Downlink, EFBV, Participation,
                        make_compressor, run_reference, tune_for)
from repro.distributed import wire

SPECS_DIR = os.path.join(os.path.dirname(__file__), os.pardir,
                         "examples", "specs")

# the committed model-zoo fine-tune specs, one per family stand-in (moe,
# dense, ssm); zoo_rows runs each through the staged harness
ZOO_SPEC_FILES = ["finetune_moe.json", "zoo_qwen2_fsdp.json",
                  "zoo_mamba2_fsdp.json"]


def run_bench(fast: bool = True):
    steps = 1200 if fast else 6000
    name = "phishing"
    rows = []
    gammas = {"efbv": [], "ef21": []}
    finals = {"efbv": [], "ef21": []}
    ns = [10, 100, 1000] if fast else [10, 50, 100, 500, 1000, 2000]
    for n in ns:
        prob = make_problem(name, n=n)
        _, fstar = prob.solve()
        d = prob.d
        comp = CompKK(1, d // 2)
        for mode in ["efbv", "ef21"]:
            t = tune_for(comp, d, n, mode=mode, L=prob.L(), Ltilde=prob.L_tilde())
            algo = EFBV(comp, lam=t.lam, nu=t.nu)
            res = run_reference(algo=algo,
                                grad_fn=lambda _k, x: prob.grads(x),
                                x0=jnp.zeros(d), gamma=t.gamma, steps=steps,
                                key=KEY, n=n,
                                record=lambda x: prob.f(x) - fstar)
            gammas[mode].append(t.gamma)
            finals[mode].append(float(res.metrics[-1]))
    # theory: EF-BV gamma must increase with n; EF21's is n-independent
    bv_monotone = all(gammas["efbv"][i] <= gammas["efbv"][i + 1] * (1 + 1e-9)
                      for i in range(len(ns) - 1))
    ef21_flat = max(gammas["ef21"]) / max(min(gammas["ef21"]), 1e-30) < 1.3
    rows.append({"name": "n_scaling/gamma_monotone_in_n",
                 "us_per_call": "",
                 "derived": f"efbv_monotone={bv_monotone};ef21_flat={ef21_flat};"
                            f"gamma_efbv={[f'{g:.2e}' for g in gammas['efbv']]};"
                            f"gamma_ef21={[f'{g:.2e}' for g in gammas['ef21']]}"})
    for i, n in enumerate(ns):
        rows.append({"name": f"n_scaling/n{n}/final_gap",
                     "us_per_call": "",
                     "derived": f"efbv={finals['efbv'][i]:.3e};"
                                f"ef21={finals['ef21'][i]:.3e}"})
    rows.extend(participation_rows(fast=fast))
    rows.extend(bidirectional_rows(fast=fast))
    return rows


def bidirectional_rows(fast: bool = True):
    """Up/down bits sweep: fixed uplink (the paper's comp-(k, k')), sweep of
    downlink codecs from dense fp32 to qsgd:16.  Exact total_round_bits
    (uplink x n + ONE broadcast) against the measured suboptimality after a
    fixed round budget -- the bidirectional bits-vs-convergence trade-off."""
    steps = 1500 if fast else 6000
    n = 50
    prob = make_problem("phishing", n=n)
    _, fstar = prob.solve()
    d = prob.d
    comp = CompKK(1, d // 2)
    up_fmt = wire.format_for(comp, jnp.zeros(d))
    t = tune_for(comp, d, n, mode="efbv", L=prob.L(), Ltilde=prob.L_tilde())
    algo = EFBV(comp, lam=t.lam, nu=t.nu)

    downs = ["identity", f"topk:{d // 4}", "qsgd:16"]
    rows, gaps, totals = [], [], []
    for spec in downs:
        down = Downlink(make_compressor(spec))
        # broadcast error feedback tolerates a smaller step for lossy C_s
        gamma = t.gamma if spec == "identity" else t.gamma * 0.5
        res = run_reference(
            algo=algo, downlink=down, grad_fn=lambda k, x: prob.grads(x),
            x0=jnp.zeros(d), gamma=gamma, steps=steps, key=KEY, n=n,
            record=lambda x: prob.f(x) - fstar)
        m = res.metrics
        down_fmt = down.format_for(jnp.zeros(d))
        total = wire.total_round_bits(up_fmt, down_fmt, n_workers=n)
        gaps.append(float(m[-1]))
        totals.append(float(total))
        rows.append({"name": f"n_scaling/bidirectional_{spec.split(':')[0]}",
                     "us_per_call": "",
                     "derived": f"final_gap={gaps[-1]:.3e};"
                                f"up_bits={up_fmt.bits_per_round(n_workers=n):g};"
                                f"down_bits={down_fmt.downlink_bits_per_round():g};"
                                f"total_bits={total:g}"})
    # the downlink shrinks total bits monotonically along the sweep while
    # the gap stays finite (lossy broadcasts still converge)
    assert all(t1 >= t2 for t1, t2 in zip(totals, totals[1:])), totals
    assert all(np.isfinite(g) for g in gaps), gaps
    rows.append({"name": "n_scaling/bidirectional/bits_vs_gap",
                 "us_per_call": "",
                 "derived": f"downs={downs};"
                            f"totals={[f'{t_:g}' for t_ in totals]};"
                            f"gaps={[f'{g:.2e}' for g in gaps]}"})
    return rows


def participation_rows(fast: bool = True):
    """Federated sweep: wire bits/round scale as |S_t|, convergence degrades
    gracefully as the participation fraction p shrinks."""
    steps = 1500 if fast else 6000
    n = 100
    prob = make_problem("phishing", n=n)
    _, fstar = prob.solve()
    d = prob.d
    comp = CompKK(1, d // 2)
    fmt = wire.format_for(comp, jnp.zeros(d))
    rows, gaps, bits = [], [], []
    ps = [1.0, 0.5, 0.25] if fast else [1.0, 0.5, 0.25, 0.1]
    for p in ps:
        part = (Participation() if p >= 1.0
                else Participation(kind="bernoulli", p=p))
        t = tune_for(comp, d, n, mode="efbv", L=prob.L(),
                     Ltilde=prob.L_tilde(),
                     participation=None if p >= 1.0 else p)
        algo = EFBV(comp, lam=t.lam, nu=t.nu)
        res = run_reference(
            algo=algo, grad_fn=lambda k, x: prob.grads(x), x0=jnp.zeros(d),
            gamma=t.gamma, steps=steps, key=KEY, n=n, participation=part,
            record=lambda x: prob.f(x) - fstar)
        m = res.metrics
        # expected federated uplink: mask bitmap + E|S_t| payloads
        b = fmt.bits_per_round(n_workers=n, participants=p * n)
        gaps.append(float(m[-1]))
        bits.append(float(b))
        rows.append({"name": f"n_scaling/participation_p{p:g}/trade_off",
                     "us_per_call": "",
                     "derived": f"final_gap={gaps[-1]:.3e};"
                                f"gamma={t.gamma:.2e};"
                                f"exp_bits_per_round={b:g}"})
    # the wire side of the trade-off is exact: bits scale as |S_t|
    full_payload = n * fmt.bits_per_round()
    assert all(b <= full_payload * p + 32 * wire.bitmap_words(n) + 1e-9
               for p, b in zip(ps, bits)), (ps, bits, full_payload)
    rows.append({"name": "n_scaling/participation/bits_scale_with_s",
                 "us_per_call": "",
                 "derived": f"ps={ps};bits={[f'{b:g}' for b in bits]};"
                            f"monotone={all(b1 >= b2 for b1, b2 in zip(bits, bits[1:]))}"})
    return rows


def load_zoo_specs():
    """The committed zoo fine-tune specs, parsed (fingerprints are the BENCH
    row keys; the files are exact ``spec.to_json()`` bytes, pinned by
    tests/test_finetune.py)."""
    from repro.core import ExperimentSpec

    specs = []
    for fname in ZOO_SPEC_FILES:
        with open(os.path.join(SPECS_DIR, fname)) as f:
            specs.append((fname, ExperimentSpec.from_dict(json.load(f))))
    return specs


def _expert_leaf_bits(fmt, paths):
    """Sum of exact per-leaf payload bits over the MoE expert leaves."""
    from repro.models.moe import EXPERT_LEAVES

    by_leaf = fmt.bits_by_leaf()
    assert fmt.bits_per_round() == sum(by_leaf)
    return sum(b for p, b in zip(paths, by_leaf)
               if p.split("/")[-1] in EXPERT_LEAVES and "moe" in p.split("/"))


def zoo_bits_rows():
    """The exact (machine-independent) half of the zoo sweep: uplink x n +
    ONE broadcast of every committed fine-tune spec's round on its real
    smoke parameter tree, keyed by the committed fingerprints.  MoE rows
    additionally carry the expert-leaf split -- sparse (rescaled topk rules
    on masked gradients) vs the dense block-top-k budget on those same
    leaves -- which the expert-sparsity gate in ci_bench.py pins at
    <= 0.5x."""
    import jax

    from repro.configs import get_smoke_config
    from repro.core import build
    from repro.models import build_model

    rows = {}
    for fname, spec in load_zoo_specs():
        cfg = get_smoke_config(spec.problem)
        params = build_model(cfg).init(jax.random.key(spec.seed))
        run = build(spec)
        rb = run.round_bits(params)
        row = {
            "name": f"zoo_scaling/{fname[:-len('.json')]}",
            "arch": cfg.name,
            "family": cfg.family,
            "spec_file": fname,
            "compressor": spec.compressor,
            "downlink": spec.downlink or "dense_fp32",
            "leaf_codecs": spec.leaf_codecs,
            "params": cfg.param_count(),
            "up_bits": rb["up"],
            "down_bits": rb["down"],
            "total_bits": rb["total"],
            "vs_dense_both_ways": round(rb["total"] / rb["dense_both_ways"],
                                        6),
        }
        if cfg.family == "moe":
            paths = wire.leaf_paths(params)
            sparse_fmt = wire.tree_format_for(
                run.compressor, params, wire_dtype=spec.wire_dtype,
                rules=run.leaf_rules)
            dense_fmt = wire.tree_format_for(
                run.compressor, params, wire_dtype=spec.wire_dtype,
                rules=(("*", run.compressor),))
            sparse_bits = _expert_leaf_bits(sparse_fmt, paths)
            dense_bits = _expert_leaf_bits(dense_fmt, paths)
            row["expert_leaf_bits"] = sparse_bits
            row["dense_expert_leaf_bits"] = dense_bits
            row["expert_sparsity_ratio"] = round(sparse_bits / dense_bits, 6)
        rows[spec.fingerprint()] = row
    return rows


def zoo_perf_rows(measure_steps: int = 3):
    """The measured half of the zoo sweep: steps/sec of every committed
    fine-tune spec through the staged harness (repro/train/loop.py) under
    its compressed FSDP wire, keyed by the committed fingerprints.  Compile
    excluded: one warm-up step, then ``measure_steps`` timed."""
    from repro.train.loop import FinetuneLoop, FinetuneSettings

    rows = {}
    for fname, spec in load_zoo_specs():
        loop = FinetuneLoop(
            spec, FinetuneSettings(global_batch=8, seq_len=32, log_every=10),
            verbose=False)
        loop.setup()
        loop.build_data()
        loop.train(steps=1)
        loop.train(steps=measure_steps)
        rows[spec.fingerprint()] = {
            "name": f"zoo_scaling/{fname[:-len('.json')]}",
            "arch": loop.cfg.name,
            "family": loop.cfg.family,
            "params": loop.cfg.param_count(),
            "steps_per_sec": round(loop._steps_per_sec, 4),
            "final_loss": round(loop._final["loss"], 4),
        }
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run_bench(fast=True))
