from repro.data.synthetic import SyntheticLM, make_batch_shardings  # noqa: F401
