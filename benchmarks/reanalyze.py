"""Re-derive roofline terms from the stored HLO dumps (no recompiles).

    PYTHONPATH=src python -m benchmarks.reanalyze

Rewrites the 'roofline' field of every record in results/dryrun_results.jsonl
using the current repro.launch.hlo_cost model and the gzipped HLO in
results/hlo/.  Lets cost-model fixes iterate in seconds instead of re-running
the 80-compile sweep.
"""

from __future__ import annotations

import glob
import gzip
import json
import os

from repro.launch import hlo_cost as HC
from repro.launch.hlo_analysis import Roofline

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(REPO, "results", "dryrun_results.jsonl")
HLO_DIR = os.path.join(REPO, "results", "hlo")


def main():
    recs = [json.loads(l) for l in open(RESULTS)]
    n_done = 0
    for r in recs:
        if r.get("status") != "ok":
            continue
        fname = os.path.join(
            HLO_DIR, f"{r['arch']}_{r['shape']}_{r['mesh']}_{r['agg_mode']}.hlo.gz")
        if not os.path.exists(fname):
            continue
        with gzip.open(fname, "rt") as f:
            txt = f.read()
        c = HC.hlo_cost(txt)
        roof = Roofline(
            hlo_flops=c.flops, hlo_bytes=c.hbm_bytes, coll_bytes=c.coll_bytes,
            coll_breakdown={k: int(v) for k, v in c.coll_breakdown.items()},
            n_chips=r.get("n_devices", 256),
            xla_flops=r["roofline"].get("xla_cost_analysis_flops", 0.0),
            xla_bytes=r["roofline"].get("xla_cost_analysis_bytes", 0.0),
        )
        r["roofline"] = roof.as_dict()
        n_done += 1
    with open(RESULTS, "w") as f:
        for r in recs:
            f.write(json.dumps(r) + "\n")
    print(f"re-analyzed {n_done}/{len(recs)} records")


if __name__ == "__main__":
    main()
