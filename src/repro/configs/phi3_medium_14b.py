"""phi3-medium-14b [arXiv:2404.14219]: RoPE + SwiGLU + GQA dense decoder.

40L x d5120, 40 heads GQA kv=10 (kv heads don't divide the 16-way model axis
-> kv projections replicate, q/o shard), ff=17920, vocab 100352.  The largest
dense arch: the remat + microbatch + ZeRO-1 memory path is sized for it."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b", family="dense",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10,
        d_ff=17920, vocab=100352, head_dim=128,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3-smoke", family="dense",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
        d_ff=512, vocab=1024, head_dim=64,
    )
