"""mamba2-130m: SSD / state-space duality [arXiv:2405.21060].

Attention-free: 24 SSD blocks, d_model=768 (d_inner=1536, 24 ssm heads of 64),
state=128, tied embeddings, vocab 50280.  Runs long_500k natively (O(1)/token
recurrent decode)."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m", family="ssm",
        n_layers=24, d_model=768, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab=50280,
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=128,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        n_layers=2, d_model=128, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab=1024,
        ssm_state=16, ssm_head_dim=32, ssm_expand=2, ssm_conv=4, ssm_chunk=32,
        tie_embeddings=True,
    )
