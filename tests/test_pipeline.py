"""The pipelined (one-round-stale) execution schedule, end to end.

Covers the whole vertical slice the `pipeline` spec field switches on --
spec surface (parsing, fingerprint stability, backend gating), theory
composition (staleness as a compressor perturbation), the reference oracle
(depth-0 bitwise no-op, round-0 priming), the differential harness legs
(depth-1 oracle == interpret over randomized bidirectional + federated
trajectories), both trainers and the mid-pipeline checkpoint round-trip --
plus the satellite regressions that rode along: `WireFormat.bits_per_round`
int/float typing, `make_mesh` axis-name validation, the streaming pack
kernel's bit-identity, and the fixed-order chunked decode.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st
from conftest import run_with_devices
from harness import (assert_bit_identical, quadratic_grads, run_trajectory)

from repro.core import ExperimentSpec, build, make_compressor, theory
from repro.core.efbv import (EFBV, PIPELINE_FOLD, Pipeline, run_reference)
from repro.core.spec import SpecError
from repro.distributed import wire
from repro.distributed.aggregate import ring_allgather
from repro.launch.mesh import make_mesh


# ---------------------------------------------------------------------------
# 1. spec surface
# ---------------------------------------------------------------------------

def test_pipeline_parse():
    assert Pipeline.parse("off") == Pipeline(depth=0)
    assert Pipeline.parse("") == Pipeline(depth=0)
    assert Pipeline.parse("depth:0") == Pipeline(depth=0)
    assert Pipeline.parse("depth:1") == Pipeline(depth=1)
    assert Pipeline.parse("off").is_off
    assert not Pipeline.parse("depth:1").is_off
    with pytest.raises(ValueError, match="depth"):
        Pipeline.parse("depth:2")  # one in-flight buffer only
    with pytest.raises(ValueError, match="pipeline spec"):
        Pipeline.parse("depth:")
    with pytest.raises(ValueError, match="pipeline spec"):
        Pipeline.parse("async")
    with pytest.raises(ValueError, match="depth"):
        Pipeline(depth=-1)


def test_spec_pipeline_fingerprint_stable():
    """pipeline='off' serializes to NOTHING: every pre-pipeline spec (and
    its fingerprint, the BENCH/checkpoint row key) is unchanged."""
    base = ExperimentSpec(compressor="qsgd:16", n=4, d=32, steps=3)
    off = dataclasses.replace(base, pipeline="off")
    assert "pipeline" not in base.to_dict()
    assert base.to_dict() == off.to_dict()
    assert base.fingerprint() == off.fingerprint()
    assert ExperimentSpec.from_json(off.to_json()) == off

    deep = ExperimentSpec(compressor="qsgd:16", backend="shard_map",
                          problem="quadratic", mesh="4x1", n=4, d=32,
                          steps=3, pipeline="depth:1")
    assert deep.to_dict()["pipeline"] == "depth:1"
    assert deep.fingerprint() != base.fingerprint()
    assert ExperimentSpec.from_json(deep.to_json()) == deep


def test_reference_backend_rejects_pipeline():
    with pytest.raises(SpecError, match="sequential"):
        ExperimentSpec(n=2, d=8, pipeline="depth:1")
    # depth:0 IS the sequential schedule: allowed everywhere
    ExperimentSpec(n=2, d=8, pipeline="depth:0")


def test_build_carries_pipeline():
    spec = ExperimentSpec(compressor="block_topk:16,4",
                          agg="sparse_allgather", backend="shard_map",
                          problem="quadratic", mesh="4x1", n=4, d=64,
                          steps=2, pipeline="depth:1")
    run = build(spec)
    assert run.pipeline == Pipeline(depth=1)
    t = run.tuned
    seq = theory.tune_for(run.compressor, spec.d, spec.n)
    assert t.r < 1.0
    assert t.r > seq.r  # staleness can only slow the certified rate


# ---------------------------------------------------------------------------
# 2. theory: staleness composition
# ---------------------------------------------------------------------------

def test_theory_depth0_exact_noop():
    for eta, omega in [(0.2, 3.0), (0.9, 0.1), (1.0 - 1e-6, 0.0)]:
        assert theory.pipeline_eta(0, eta) == eta
        assert theory.pipeline_omega(0, eta, omega) == omega
    comp = make_compressor("block_topk:16,4")
    seq = theory.tune_for(comp, 64, 4)
    assert theory.tune_for(comp, 64, 4, pipeline=None) == seq
    assert theory.tune_for(comp, 64, 4, pipeline=0) == seq


def test_theory_depth1_composition():
    for eta in [0.1, 0.5, 0.9]:
        eta_d = theory.pipeline_eta(1, eta)
        assert eta < eta_d < 1.0
        om_d = theory.pipeline_omega(1, eta, 2.0)
        assert om_d >= 2.0
    # composes AFTER participation and still certifies a rate < 1
    comp = make_compressor("block_topk:16,4")
    t = theory.tune_for(comp, 64, 4, participation=0.5, pipeline=1)
    assert 0.0 < t.r < 1.0
    assert t != theory.tune_for(comp, 64, 4, participation=0.5)


def test_theory_drift_guard():
    # rho = depth * drift * (1 - eta) must stay below 1/2
    with pytest.raises(ValueError, match="rho"):
        theory.pipeline_eta(1, 0.0, drift=0.5)
    with pytest.raises(ValueError, match="drift"):
        theory.pipeline_eta(1, 0.5, drift=-0.1)
    with pytest.raises(ValueError, match="depth"):
        theory.pipeline_eta(-1, 0.5)
    # the default drift is safe for the whole eta range
    theory.pipeline_eta(1, 0.0)


# ---------------------------------------------------------------------------
# 3. reference driver
# ---------------------------------------------------------------------------

def _ref(pipeline, steps=5, n=4, d=32, seed=0):
    grad_fn = quadratic_grads(n, d, seed)
    algo = EFBV.make(make_compressor("block_topk:16,4"), d=d, n=n,
                     pipeline=(pipeline.depth or None) if pipeline else None)
    return run_reference(algo=algo, grad_fn=lambda _k, x: grad_fn(x),
                         x0=jnp.zeros((d,)), gamma=0.05, steps=steps,
                         key=jax.random.key(seed), n=n, pipeline=pipeline)


def test_reference_depth0_bit_identical_to_off():
    a = _ref(None)
    b = _ref(Pipeline(depth=0))
    assert_bit_identical((a.x, a.state.h, a.state.h_avg),
                         (b.x, b.state.h, b.state.h_avg), "depth-0 == off")
    assert a.pending is None and b.pending is None


def test_reference_depth1_round0_is_noop_on_x():
    """Round 0 applies the zero priming buffer: g = h_avg0 + nu*0 = 0, so x
    is untouched while the workers' control variates advance on time."""
    seq = _ref(None, steps=1)
    pipe = _ref(Pipeline(depth=1), steps=1)
    np.testing.assert_array_equal(np.asarray(pipe.x), np.zeros(32))
    assert float(jnp.max(jnp.abs(seq.x))) > 0.0
    # round 0 compresses the same grads at the same x with the same key:
    # h advances identically on both schedules
    assert_bit_identical(pipe.state.h, seq.state.h, "round-0 h")
    assert pipe.pending is not None
    assert float(jnp.max(jnp.abs(pipe.pending))) > 0.0


def test_reference_depth1_matches_manual_double_buffer():
    """The scan's depth-1 carry == an eager double-buffer simulation built
    from the same compress_round / master_update primitives."""
    n, d, steps, gamma = 4, 32, 5, 0.05
    grad_fn = quadratic_grads(n, d, 0)
    algo = EFBV.make(make_compressor("block_topk:16,4"), d=d, n=n, pipeline=1)
    res = _ref(Pipeline(depth=1), steps=steps, n=n, d=d)

    x = jnp.zeros((d,))
    st = algo.init(x, n)
    pending = jnp.zeros((d,))
    keys = jax.random.split(jax.random.key(0), steps)
    for k in keys:
        d_new, h_new = algo.compress_round(k, grad_fn(x), st)
        g, h_avg = algo.master_update(st.h_avg, pending)
        st = type(st)(h=h_new, h_avg=h_avg, step=st.step + 1)
        x = x - gamma * g
        pending = d_new
    np.testing.assert_allclose(np.asarray(res.x), np.asarray(x),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(res.pending), np.asarray(pending),
                               rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# 4. differential harness legs
# ---------------------------------------------------------------------------

def _pipe_spec(seed=0, **kw):
    base = dict(compressor="block_topk:128,8", agg="sparse_allgather",
                backend="shard_map", problem="quadratic", mesh="4x1",
                n=4, d=256, steps=4, gamma=0.05, seed=seed,
                pipeline="depth:1")
    base.update(kw)
    return ExperimentSpec(**base)


def test_harness_depth0_bit_identical_to_off():
    """'depth:0' through the spec-driven harness is the SAME trajectory as
    'off' -- the historical pins cannot move."""
    off = ExperimentSpec(compressor="qsgd:16", agg="sparse_allgather",
                         downlink="sign", participation="bernoulli:0.5",
                         n=4, d=96, steps=4, gamma=0.05, seed=3)
    zero = dataclasses.replace(off, pipeline="depth:0")
    a = run_trajectory(off, "oracle")
    b = run_trajectory(zero, "oracle")
    assert_bit_identical((a["x"], a["w"], a["h"], a["masks"], a["payload"]),
                         (b["x"], b["w"], b["h"], b["masks"], b["payload"]),
                         "harness depth-0 == off")
    assert "pending" not in a and "pending" not in b


def test_harness_depth1_round0_noop_then_diverges():
    spec = _pipe_spec()
    pipe = run_trajectory(spec, "oracle")
    seq = run_trajectory(
        dataclasses.replace(spec, pipeline="off"), "oracle")
    # round 0 applies the zero-decoding priming payload
    np.testing.assert_array_equal(np.asarray(pipe["x"][0]),
                                  np.zeros(spec.d, np.float32))
    # ... then the one-round-stale schedule is a genuinely different run
    assert not np.array_equal(np.asarray(pipe["x"][-1]),
                              np.asarray(seq["x"][-1]))
    # the last round's payload is exactly what is left in flight
    assert_bit_identical(pipe["pending"], pipe["payload"], "in-flight")


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=6, deadline=None)
def test_harness_depth1_oracle_matches_interpret_bidirectional(seed):
    """The acceptance pin: depth-1 oracle == interpret, bit for bit, over
    randomized bidirectional + federated trajectories."""
    spec = _pipe_spec(seed=seed, downlink="qsgd:16",
                      participation="bernoulli:0.75")
    a = run_trajectory(spec, "oracle")
    b = run_trajectory(spec, "interpret")
    assert_bit_identical(
        (a["x"], a["w"], a["h"], a["masks"], a["payload"], a["pending"],
         a["down_payload"]),
        (b["x"], b["w"], b["h"], b["masks"], b["payload"], b["pending"],
         b["down_payload"]), f"depth-1 oracle==interpret seed={seed}")


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=4, deadline=None)
def test_harness_depth1_oracle_matches_interpret_full(seed):
    spec = _pipe_spec(seed=seed)
    a = run_trajectory(spec, "oracle")
    b = run_trajectory(spec, "interpret")
    assert_bit_identical((a["x"], a["h"], a["payload"], a["pending"]),
                         (b["x"], b["h"], b["payload"], b["pending"]),
                         f"depth-1 full seed={seed}")


# ---------------------------------------------------------------------------
# 5. wire primitives of the pipelined exchange
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec_str", ["block_topk:16,4", "qsgd:16", "sign",
                                      "topk:7", "identity"])
def test_zero_message_decodes_to_zero(spec_str):
    codec = wire.codec_of(make_compressor(spec_str), (96,), 96)
    key = jax.random.fold_in(jax.random.key(0), PIPELINE_FOLD)
    msg = wire.zero_message(codec, key)
    np.testing.assert_array_equal(np.asarray(codec.decode(msg)),
                                  np.zeros(96, np.float32))
    stacked = jax.tree.map(lambda a: jnp.tile(a[None], (4,) + (1,) * a.ndim),
                           tuple(msg))
    np.testing.assert_array_equal(np.asarray(codec.decode_sum(stacked)),
                                  np.zeros(96, np.float32))


def test_pipeline_chunks():
    assert wire.pipeline_chunks(1) == 1
    # n < 4: a chunk would be one worker's slice -- resharding eats the win
    assert wire.pipeline_chunks(2) == 1
    assert wire.pipeline_chunks(3) == 1
    assert wire.pipeline_chunks(4) == 4
    assert wire.pipeline_chunks(6) == 2
    assert wire.pipeline_chunks(8) == 4


def test_chunked_decode_sum():
    codec = wire.codec_of(make_compressor("block_topk:16,4"), (64,), 64)
    key = jax.random.key(7)
    msgs = [codec.encode(jax.random.fold_in(key, i),
                         jax.random.normal(jax.random.fold_in(key, 100 + i),
                                           (64,)))
            for i in range(8)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *msgs)
    whole = codec.decode_sum(stacked)
    # chunks=1 is LITERALLY decode_sum
    assert_bit_identical(wire.chunked_decode_sum(codec, stacked, 1), whole,
                         "chunks=1")
    for chunks in (2, 4, 8):
        got = wire.chunked_decode_sum(codec, stacked, chunks)
        np.testing.assert_allclose(np.asarray(got), np.asarray(whole),
                                   rtol=1e-6, atol=1e-6)
    # the fixed ascending order is replica-deterministic: same split, same sum
    assert_bit_identical(wire.chunked_decode_sum(codec, stacked, 4),
                         wire.chunked_decode_sum(codec, stacked, 4),
                         "deterministic")
    with pytest.raises(ValueError, match="split"):
        wire.chunked_decode_sum(codec, stacked, 3)


def test_ring_allgather_matches_stacked_order():
    n = 4
    msg = (jax.random.normal(jax.random.key(0), (n, 6)),
           jax.random.normal(jax.random.key(1), (n, 2, 3)))
    out = jax.vmap(lambda m: ring_allgather(m, "w", n), axis_name="w")(msg)
    for leaf, full in zip(jax.tree.leaves(out), jax.tree.leaves(msg)):
        assert leaf.shape == (n,) + full.shape
        for i in range(n):  # every worker reconstructs the canonical stack
            np.testing.assert_array_equal(np.asarray(leaf[i]),
                                          np.asarray(full))


def test_streaming_pack_bit_identical():
    lw = wire.LeafWire(shape=(640,), size=640, block=128, kb=8)
    g = jax.random.normal(jax.random.key(2), (640,))
    h = jax.random.normal(jax.random.key(3), (640,)) * 0.1
    base_p, base_h = wire.fused_pack(lw, g, h, 0.9, kernel="interpret")
    for kernel in ("interpret", "oracle"):  # oracle ignores stream
        p, hn = wire.fused_pack(lw, g, h, 0.9, kernel=kernel, stream=True)
        assert_bit_identical((p, hn), (base_p, base_h), f"stream {kernel}")


# ---------------------------------------------------------------------------
# 6. satellite regressions: wire bits typing, mesh axis validation
# ---------------------------------------------------------------------------

def test_bits_per_round_integer_counts_are_int():
    fmt = wire.format_for(make_compressor("qsgd:16"), jnp.zeros((96,)))
    per_worker = sum(l.payload_bits for l in fmt.leaves)
    bitmap = 32 * wire.bitmap_words(8)

    full = fmt.bits_per_round(n_workers=8)
    assert type(full) is int and full == 8 * per_worker

    got = fmt.bits_per_round(n_workers=8, participants=3)
    assert type(got) is int and got == bitmap + 3 * per_worker
    # an integral float |S_t| (e.g. float(mask.sum())) is still exact int
    got = fmt.bits_per_round(n_workers=8, participants=3.0)
    assert type(got) is int and got == bitmap + 3 * per_worker
    # a fractional expected count stays an (explicitly documented) float
    exp = fmt.bits_per_round(n_workers=8, participants=2.5)
    assert type(exp) is float and exp == bitmap + 2.5 * per_worker


def test_bits_per_round_exact_past_float53():
    """The historical int(float) round-trip silently rounded above 2**53."""
    fmt = wire.format_for(make_compressor("qsgd:16"), jnp.zeros((96,)))
    per_worker = sum(l.payload_bits for l in fmt.leaves)
    s = 2**53 + 1  # not representable as a float
    n = s + 7
    got = fmt.bits_per_round(n_workers=n, participants=s)
    assert type(got) is int
    assert got == 32 * wire.bitmap_words(n) + s * per_worker


def test_make_mesh_rejects_4d_shape_without_axes():
    with pytest.raises(ValueError, match="pass axes= explicitly"):
        make_mesh((2, 1, 1, 2))


# ---------------------------------------------------------------------------
# 7. checkpoints: the depth-1 fingerprint gates restore
# ---------------------------------------------------------------------------

def test_checkpoint_pipeline_fingerprint_gates_restore(tmp_path):
    from repro.checkpoint import restore_checkpoint, save_checkpoint

    deep = ExperimentSpec(compressor="block_topk:16,4",
                          agg="sparse_allgather", backend="shard_map",
                          problem="quadratic", mesh="4x1", n=4, d=64,
                          steps=2, pipeline="depth:1")
    off = dataclasses.replace(deep, pipeline="off")
    # an in-flight payload buffer checkpoints like any other state leaf
    tree = {"params": jnp.ones((4,)),
            "inflight": [(jnp.ones((4, 2, 3)), jnp.zeros((4, 2, 3),
                                                         jnp.int32))]}
    save_checkpoint(str(tmp_path), 3, tree, spec=deep)
    out = restore_checkpoint(str(tmp_path), 3, tree, spec=deep)
    assert_bit_identical(out, tree, "mid-pipeline round-trip")
    with pytest.raises(ValueError, match="refusing resume"):
        restore_checkpoint(str(tmp_path), 3, tree, spec=off)


# ---------------------------------------------------------------------------
# 8. trainers (multi-device, subprocess)
# ---------------------------------------------------------------------------

_TRAINER_PRELUDE = """
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.core import EFBV, BlockTopK
        from repro.core.efbv import Pipeline
        from repro.optim import sgd, constant
        from repro.train import (make_train_step, make_train_step_fsdp,
                                 init_train_state, train_state_shardings,
                                 fsdp_state_shardings)
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((4, 1))
        D, H = 8, 16
        key = jax.random.key(0)
        params0 = {"w1": jax.random.normal(key, (D, H)) * 0.1,
                   "w2": jax.random.normal(key, (H, D)) * 0.1}
        specs = {"w1": P(None, "model"), "w2": P("model", None)}

        def loss_fn(p, batch):
            pred = jnp.tanh(batch["x"] @ p["w1"]) @ p["w2"]
            return jnp.mean((pred - batch["y"]) ** 2), {}

        algo = EFBV.make(BlockTopK(16, 4), d=D * H, n=4)
        opt = sgd(constant(0.05))

        def batch_at(i):
            kb = jax.random.fold_in(jax.random.key(42), i)
            x = jax.random.normal(kb, (8, D)); y = x * 0.3
            return {"x": jax.device_put(x, NamedSharding(mesh, P("data"))),
                    "y": jax.device_put(y, NamedSharding(mesh, P("data")))}

        def fresh_params():
            return jax.tree.map(lambda a: jnp.array(a, copy=True), params0)

        def run(trainer, agg, pipe, steps):
            make = make_train_step if trainer == "shard_map" else make_train_step_fsdp
            shard = (train_state_shardings if trainer == "shard_map"
                     else fsdp_state_shardings)
            st = init_train_state(fresh_params(), opt, mesh, algo=algo,
                                  agg_mode=agg, pipeline=pipe)
            sh = shard(mesh, specs, st)
            st = jax.tree.map(lambda x, s: jax.device_put(x, s), st, sh)
            step = make(loss_fn, opt, algo, mesh, agg_mode=agg, pipeline=pipe)
            for i in range(steps):
                st, m = step(st, batch_at(i), jax.random.fold_in(key, i))
            return st
"""


@pytest.mark.slow
def test_trainer_depth0_bit_identical_4dev():
    """pipeline=depth:0 and pipeline=off are the SAME program on both
    trainers and both wire modes -- the PR-5 trajectories cannot move."""
    out = run_with_devices(_TRAINER_PRELUDE + """
        for trainer in ["shard_map", "fsdp"]:
            for agg in ["dense_psum", "sparse_allgather"]:
                a = run(trainer, agg, None, 4)
                b = run(trainer, agg, Pipeline(depth=0), 4)
                for la, lb in zip(jax.tree.leaves((a.params, a.h, a.h_avg)),
                                  jax.tree.leaves((b.params, b.h, b.h_avg))):
                    np.testing.assert_array_equal(np.asarray(la),
                                                  np.asarray(lb))
                assert a.inflight is None and b.inflight is None
                print("IDENT", trainer, agg)
        print("DEPTH0_OK")
    """, n_devices=4)
    assert "DEPTH0_OK" in out


@pytest.mark.slow
def test_trainer_depth1_semantics_4dev():
    """Depth-1: round 0 leaves params untouched (zero priming payload) while
    h advances exactly as the sequential schedule's round 0; the in-flight
    buffer is carried; later rounds genuinely diverge from sequential."""
    out = run_with_devices(_TRAINER_PRELUDE + """
        for trainer in ["shard_map", "fsdp"]:
            for agg in ["dense_psum", "sparse_allgather"]:
                pipe1 = run(trainer, agg, Pipeline(depth=1), 1)
                seq1 = run(trainer, agg, None, 1)
                for lp, l0 in zip(jax.tree.leaves(pipe1.params),
                                  jax.tree.leaves(params0)):
                    np.testing.assert_array_equal(np.asarray(lp),
                                                  np.asarray(l0))
                for la, lb in zip(jax.tree.leaves(pipe1.h),
                                  jax.tree.leaves(seq1.h)):
                    np.testing.assert_array_equal(np.asarray(la),
                                                  np.asarray(lb))
                assert pipe1.inflight is not None
                pipe3 = run(trainer, agg, Pipeline(depth=1), 3)
                seq3 = run(trainer, agg, None, 3)
                diff = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
                    jax.tree.leaves(pipe3.params), jax.tree.leaves(seq3.params)))
                assert diff > 0.0, (trainer, agg)
                assert all(bool(jnp.all(jnp.isfinite(l)))
                           for l in jax.tree.leaves(pipe3.params))
                print("SEMANTICS", trainer, agg)
        print("DEPTH1_OK")
    """, n_devices=4)
    assert "DEPTH1_OK" in out


@pytest.mark.slow
def test_checkpoint_midpipeline_resume_bit_identical_4dev():
    """Save a depth-1 TrainState MID-pipeline (in-flight payload included),
    restore, continue: bit-identical to the uninterrupted run."""
    out = run_with_devices(_TRAINER_PRELUDE + """
        import tempfile
        from repro.checkpoint import restore_checkpoint, save_checkpoint

        agg = "sparse_allgather"
        pipe = Pipeline(depth=1)
        step = make_train_step(loss_fn, opt, algo, mesh, agg_mode=agg,
                               pipeline=pipe)

        def init():
            st = init_train_state(fresh_params(), opt, mesh, algo=algo,
                                  agg_mode=agg, pipeline=pipe)
            sh = train_state_shardings(mesh, specs, st)
            return jax.tree.map(lambda x, s: jax.device_put(x, s), st, sh), sh

        st, sh = init()
        for i in range(5):
            st, m = step(st, batch_at(i), jax.random.fold_in(key, i))
        gold = st

        st, sh = init()
        for i in range(2):
            st, m = step(st, batch_at(i), jax.random.fold_in(key, i))
        with tempfile.TemporaryDirectory() as ckpt:
            save_checkpoint(ckpt, 2, st)
            template, _ = init()
            st = restore_checkpoint(ckpt, 2, template)
        st = jax.tree.map(lambda x, s: jax.device_put(x, s), st, sh)
        for i in range(2, 5):
            st, m = step(st, batch_at(i), jax.random.fold_in(key, i))

        for la, lb in zip(jax.tree.leaves(gold), jax.tree.leaves(st)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        print("RESUME_OK")
    """, n_devices=4)
    assert "RESUME_OK" in out
