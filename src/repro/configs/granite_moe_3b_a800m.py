"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0-*-base family].

Fine-grained MoE: 40 experts top-8 (per the assignment card; the HF 1b-a400m
card lists 32 experts -- we follow the assignment), tiny per-expert ff=512.
40 experts don't divide the 16-way model axis, so expert-parallelism falls
back to sharding the per-expert ff dim (see models/moe.py auto_spec)."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_ff=512, vocab=49155, head_dim=64,
        n_experts=40, experts_per_tok=8,
        attn_shard_policy="replicate",  # §Perf: 24 heads don't divide the
        # 16-way model axis; replicated attn weights beat score all-reduces
        # on this arch's collective-bound shapes
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke", family="moe",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=1024, head_dim=64,
        n_experts=4, experts_per_tok=2,
    )
