"""jit'd public wrappers around the Pallas kernels.

Handle arbitrary input shapes (flatten + pad to (nb, block) slabs), pick
interpret mode automatically off-TPU, and expose the same signatures as the
jnp oracles in ref.py (tests assert allclose between the two).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import block_topk as K
from repro.kernels import pack as KP

Array = jax.Array


def _interpret_default() -> bool:
    # sanitize mode (repro.analysis.sanitize) forces interpret even on TPU:
    # interpret mode raises on out-of-bounds ref indexing where the hardware
    # silently clamps
    from repro.analysis import sanitize

    return sanitize.active() or jax.default_backend() != "tpu"


def _to_slabs(x: Array, block: int, tile: int = K.TILE_NB
              ) -> Tuple[Array, int, Tuple[int, ...]]:
    xf = x.reshape(-1)
    d = xf.shape[0]
    nb = -(-d // block)
    nb_pad = -(-nb // tile) * tile
    xp = jnp.pad(xf, (0, nb_pad * block - d)).reshape(nb_pad, block)
    return xp, d, x.shape


@functools.partial(jax.jit, static_argnames=("block", "kb", "interpret"))
def block_topk(x: Array, block: int = 1024, kb: int = 64,
               interpret: bool | None = None) -> Array:
    """Dense block-top-k compression of an arbitrary-shape tensor."""
    interpret = _interpret_default() if interpret is None else interpret
    xp, d, shape = _to_slabs(x, block)
    out = K.block_topk_pallas(xp, kb, interpret=interpret)
    return out.reshape(-1)[:d].reshape(shape)


@functools.partial(jax.jit, static_argnames=("block", "kb", "lam", "interpret"))
def efbv_update(g: Array, h: Array, lam: float, block: int = 1024, kb: int = 64,
                interpret: bool | None = None) -> Tuple[Array, Array]:
    """Fused worker update: d = C(g - h); h' = h + lam d.  Returns (d, h')."""
    interpret = _interpret_default() if interpret is None else interpret
    gp, d_len, shape = _to_slabs(g, block)
    hp, _, _ = _to_slabs(h.astype(g.dtype), block)
    d_out, h_out = K.efbv_update_pallas(gp, hp, lam, kb, interpret=interpret)
    unpad = lambda a: a.reshape(-1)[:d_len].reshape(shape)
    return unpad(d_out), unpad(h_out).astype(h.dtype)


@functools.partial(jax.jit, static_argnames=("block", "kb", "lam", "interpret",
                                             "stream"))
def efbv_pack_update(g: Array, h: Array, lam: float, block: int = 1024,
                     kb: int = 64, interpret: bool | None = None,
                     stream: bool = False
                     ) -> Tuple[Tuple[Array, Array], Array]:
    """Fused compress-and-pack worker update (kernels/pack.py): one HBM pass
    computing d = block_topk(g - h), h' = h + lam d, and the wire payload.

    Returns ((values, indices), h') with values/indices of shape (nb, kb),
    nb = ceil(g.size / block) -- the same payload layout as
    ``BlockTopK.encode`` (rows added for TILE_NB alignment are sliced off).
    ``stream=True`` selects the async-copy kernel variant (the payload slab
    DMAs toward HBM while the h update computes); bit-identical payloads.
    """
    interpret = _interpret_default() if interpret is None else interpret
    gp, d_len, shape = _to_slabs(g, block)
    # h keeps its own dtype: the kernel subtracts in f32, so pre-rounding h
    # to g.dtype would break bit-identity with the jnp oracle on mixed dtypes
    hp, _, _ = _to_slabs(h, block)
    vals, idx, h_out = KP.pack_update_pallas(gp, hp, lam, kb,
                                             interpret=interpret,
                                             stream=stream)
    nb = -(-d_len // block)
    h_new = h_out.reshape(-1)[:d_len].reshape(shape)
    return (vals[:nb], idx[:nb]), h_new


# default flat-vector slab width for the codec kernels below (rand-k / QSGD
# have no block structure of their own; 1024 lanes = 8 full vregs)
_CODEC_COLS = 1024


@functools.partial(jax.jit, static_argnames=("lam", "scale", "interpret"))
def randk_update(g: Array, h: Array, idx: Array, lam: float, scale: float,
                 interpret: bool | None = None) -> Array:
    """Fused rand-k worker update (kernels/pack.py): h' = h + lam * d with
    d = randk(g - h) rebuilt in VMEM from the SMEM index list -- the dense d
    never reaches HBM.  ``idx``: (k,) int32 flat positions into g; returns
    h' shaped/dtyped like h."""
    interpret = _interpret_default() if interpret is None else interpret
    gp, d_len, _ = _to_slabs(g, _CODEC_COLS)
    hp, _, h_shape = _to_slabs(h, _CODEC_COLS)
    h_out = KP.randk_update_pallas(gp, hp, idx, scale, lam,
                                   interpret=interpret)
    return h_out.reshape(-1)[:d_len].reshape(h_shape)


@functools.partial(jax.jit, static_argnames=("lam", "s", "interpret"))
def qsgd_pack_update(g: Array, h: Array, u: Array, norm: Array, lam: float,
                     s: int, interpret: bool | None = None
                     ) -> Tuple[Array, Array]:
    """Fused QSGD quantize-and-pack (kernels/pack.py): returns the flat
    (g.size,) signed level stream (int8 for s <= 127, int16 above) and
    h' = h + lam * dequant(levels).  ``u``: the (g.size,) uniform draws of
    the jnp oracle; ``norm``: scalar ||g - h||_2."""
    interpret = _interpret_default() if interpret is None else interpret
    gp, d_len, _ = _to_slabs(g, _CODEC_COLS, tile=KP.QS_TILE_NB)
    hp, _, h_shape = _to_slabs(h, _CODEC_COLS, tile=KP.QS_TILE_NB)
    up_, _, _ = _to_slabs(u, _CODEC_COLS, tile=KP.QS_TILE_NB)
    lvl, h_out = KP.qsgd_pack_update_pallas(
        gp, hp, up_, jnp.reshape(norm, (1, 1)).astype(jnp.float32), s, lam,
        interpret=interpret)
    levels = lvl.reshape(-1)[:d_len]
    return levels, h_out.reshape(-1)[:d_len].reshape(h_shape)
