#!/usr/bin/env python
"""Offline link checker for the markdown docs.

Validates every markdown link target in the given files/directories:

  * relative links must resolve to an existing file or directory
    (anchors are stripped; pure-anchor links are checked against the
    file's own headings);
  * http(s) links are only syntax-checked (CI runs offline).

Exit code 1 with a per-link report when anything dangles.

Usage: python tools/check_links.py docs README.md
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#+\s+(.*)$", re.MULTILINE)


def slugify(heading: str) -> str:
    """GitHub-style anchor slug."""
    s = re.sub(r"[`*_]", "", heading.strip().lower())
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def check_file(md: Path) -> list[str]:
    text = md.read_text()
    anchors = {slugify(h) for h in HEADING_RE.findall(text)}
    errors = []
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        if not path_part:  # same-file anchor
            if anchor and slugify(anchor) not in anchors:
                errors.append(f"{md}: dangling anchor #{anchor}")
            continue
        resolved = (md.parent / path_part).resolve()
        if not resolved.exists():
            errors.append(f"{md}: broken link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    if not argv:
        argv = ["docs", "README.md"]
    files: list[Path] = []
    for a in argv:
        p = Path(a)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        elif p.exists():
            files.append(p)
        else:
            print(f"check_links: no such path {a}", file=sys.stderr)
            return 2
    errors = [e for f in files for e in check_file(f)]
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_links: {len(files)} files, "
          f"{'FAIL' if errors else 'ok'} ({len(errors)} broken)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
