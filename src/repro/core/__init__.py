"""The paper's primary contribution: the C(eta, omega) compressor class,
EF-BV (with EF21 / DIANA as parametrizations) and its tuning theory."""

from repro.core.contract import Compressor, Wire, bias_variance_estimate  # noqa: F401
from repro.core.compressors import (  # noqa: F401
    Identity, TopK, RandK, ScaledRandK, CompKK, MixKK, BlockTopK,
    SignNorm, Natural, QSGD, FracTopK, FracCompKK, MNice, expand_fleet,
    make_compressor, make_fleet,
)
from repro.core.efbv import (  # noqa: F401
    Downlink, EFBV, EFBVState, Participation, ReferenceRun, downlink_key,
    participation_key, proximal_step,
    prox_zero, prox_l1, prox_l2, run_reference,
)
from repro.core import specgrammar  # noqa: F401
from repro.core import theory  # noqa: F401
from repro.core.theory import (  # noqa: F401
    Tuning, tune, tune_for, tune_partial,
)
from repro.core.spec import (  # noqa: F401
    ExperimentSpec, Quadratic, Run, ServeSpec, SpecError, build,
)
