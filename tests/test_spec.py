"""The declarative ExperimentSpec surface (repro/core/spec.py).

Four obligations, all acceptance-critical:

1. *Serialization is lossless and stable*: parse -> to_json -> from_json is
   the identity, fingerprints ignore field ordering, and every codec /
   fleet / downlink / participation combination the wire-codec suite
   exercises round-trips losslessly.
2. *Inconsistent specs are rejected loudly* with actionable messages
   (sparse wire + heterogeneous fleet, oversized fixed participation, ...).
3. *The spec-driven path is bit-identical to direct driver calls*:
   build(spec).reference() vs run_reference with the spec's pieces passed
   by hand, and the three historical harness legs vs the spec-driven
   run_trajectory.
4. *Checkpoints carry the spec*: the embedded fingerprint gates resume.
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from harness import (run_bidirectional_trajectory, run_codec_trajectory,
                     run_federated_trajectory, run_trajectory,
                     assert_bit_identical)
from repro.core import (Downlink, ExperimentSpec, Participation, SpecError,
                        build, make_compressor, run_reference)
from repro.core.efbv import REFERENCE_FOLD

# every codec spec exercised by tests/test_wire_codecs.py's registry test,
# plus the fleet / downlink / participation axes the suite uses
CODEC_SPECS = ["identity", "topk:8", "randk:4", "scaled_randk:4", "comp:2,8",
               "mix:2,4", "block_topk:16,2", "sign", "natural", "qsgd:16",
               "frac_topk:50", "frac_comp:20,400"]
FLEET_SPECS = ["topk:7;qsgd:16;sign", "frac_topk:50;qsgd:16"]
DOWNLINK_SPECS = ["", "qsgd:16", "block_topk:16,2", "topk:48", "sign@0.9"]
PARTICIPATIONS = ["full", "bernoulli:0.5", "bernoulli:1.0", "fixed:3"]


# ---------------------------------------------------------------------------
# 1. lossless serialization
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("comp", CODEC_SPECS + FLEET_SPECS)
@pytest.mark.parametrize("down", DOWNLINK_SPECS)
def test_roundtrip_every_codec_and_downlink(comp, down):
    """to_json/from_json is the identity for every codec x downlink combo
    the wire-codec suite exercises (fleets forced onto the dense wire)."""
    spec = ExperimentSpec(compressor=comp, downlink=down,
                          agg="dense_psum" if ";" in comp
                          else "sparse_allgather", n=8, d=96)
    back = ExperimentSpec.from_json(spec.to_json())
    assert back == spec
    assert back.fingerprint() == spec.fingerprint()


@pytest.mark.parametrize("part", PARTICIPATIONS)
def test_roundtrip_every_participation(part):
    spec = ExperimentSpec(participation=part, n=8)
    assert ExperimentSpec.from_json(spec.to_json()) == spec


@given(n=st.integers(1, 64), d=st.integers(1, 4096),
       steps=st.integers(1, 10**6), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_parse_tojson_fromjson_identity(n, d, steps, seed):
    """Property: CLI parse -> JSON -> parse is the identity, over random
    numeric fields and both CLI token forms."""
    argv = (f"--compressor qsgd:16 --participation bernoulli:0.5 "
            f"--downlink sign --n {n} --d {d} --steps {steps} "
            f"--seed {seed} --resample --problem logreg")
    spec = ExperimentSpec.parse(argv)
    assert spec.n == n and spec.resample is True
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    # key=value token form parses to the same spec
    alt = ExperimentSpec.parse(
        ["compressor=qsgd:16", "participation=bernoulli:0.5",
         "downlink=sign", f"n={n}", f"d={d}", f"steps={steps}",
         f"seed={seed}", "resample=true", "problem=logreg"])
    assert alt == spec and alt.fingerprint() == spec.fingerprint()


def test_fingerprint_stable_across_field_ordering():
    spec = ExperimentSpec(compressor="qsgd:16", downlink="sign", n=4, d=128)
    d = json.loads(spec.to_json())
    reordered = dict(sorted(d.items(), reverse=True))
    assert ExperimentSpec.from_dict(reordered).fingerprint() \
        == spec.fingerprint()
    # and differs for a different experiment
    other = dataclasses.replace(spec, downlink="qsgd:16")
    assert other.fingerprint() != spec.fingerprint()


def test_fingerprint_includes_defaults():
    """A default-valued field is part of the identity: constructing it
    explicitly changes nothing."""
    assert ExperimentSpec().fingerprint() \
        == ExperimentSpec(mode="efbv", seed=0).fingerprint()


# ---------------------------------------------------------------------------
# 2. rejection of inconsistent combos
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad,fragment", [
    (dict(compressor="topk:4;qsgd:16", agg="sparse_allgather"),
     "dense_psum"),
    (dict(compressor="qsgd:16;qsgd:16;qsgd:16", n=2), "fleet of 3"),
    (dict(participation="fixed:9", n=4), "fixed:9"),
    (dict(backend="shard_map"), "mesh"),
    (dict(backend="shard_map", mesh="2x2", n=4), "workers"),
    (dict(problem="qwen2-0.5b"), "backend"),
    (dict(backend="shard_map", mesh="2x2", n=2, problem="nope"), "unknown"),
    (dict(mode="sgd"), "mode"),
    (dict(agg="ring"), "agg"),
    (dict(wire_dtype="int4"), "wire_dtype"),
    (dict(compressor="bogus:1"), "bogus"),
    (dict(downlink="bogus:1"), "bogus"),
    (dict(participation="sometimes"), "participation"),
    (dict(resample=True, problem="quadratic"), "resample"),
    (dict(mesh="2x2"), "mesh"),
    (dict(n=0), "positive"),
    (dict(gamma=-1.0), "gamma"),
    (dict(compressor=""), "empty"),
])
def test_inconsistent_specs_rejected_with_actionable_messages(bad, fragment):
    with pytest.raises((SpecError, ValueError), match=fragment):
        ExperimentSpec(**bad)


def test_unknown_fields_rejected():
    with pytest.raises(SpecError, match="unknown spec field"):
        ExperimentSpec.parse("--compresor qsgd:16")
    with pytest.raises(SpecError, match="unknown spec fields"):
        ExperimentSpec.from_dict({"compresor": "qsgd:16"})
    with pytest.raises(SpecError, match="spec_version"):
        ExperimentSpec.from_dict({"spec_version": 99})


def test_parse_bad_values_rejected():
    with pytest.raises(SpecError, match="wants int"):
        ExperimentSpec.parse("--n eight")
    with pytest.raises(SpecError, match="boolean"):
        ExperimentSpec.parse("--resample maybe")
    with pytest.raises(SpecError, match="missing a value"):
        ExperimentSpec.parse(["--compressor"])


# ---------------------------------------------------------------------------
# 3a. spec-driven reference == direct run_reference calls, bitwise
# ---------------------------------------------------------------------------

def test_spec_reference_bit_identical_to_direct_run_reference():
    """build(spec).reference() == a hand-assembled run_reference call
    (exact gradients, full participation) bit-for-bit -- incl. the
    fold_in(key(seed), REFERENCE_FOLD) root-key derivation."""
    spec = ExperimentSpec(compressor="comp:2,16", problem="quadratic",
                          n=6, d=32, steps=15, seed=0, gamma=0.04)
    r = build(spec)
    prob = r.problem_instance()
    res = r.reference(record=prob.f)
    ref = run_reference(algo=r.algo, grad_fn=lambda _k, x: prob.grads(x),
                        x0=jnp.zeros(32), gamma=0.04, steps=15,
                        key=jax.random.fold_in(jax.random.key(0), REFERENCE_FOLD),
                        n=6, record=prob.f)
    assert_bit_identical((res.x, res.state.h, res.metrics),
                         (ref.x, ref.state.h, ref.metrics), "spec reference")
    assert res.w is None


def test_spec_federated_reference_bit_identical_to_direct_run_reference():
    spec = ExperimentSpec(compressor="qsgd:8", problem="logreg",
                          participation="bernoulli:0.5", resample=True,
                          n=5, d=24, steps=10, seed=1, gamma=0.05)
    r = build(spec)
    prob = r.problem_instance()
    gf = lambda k, x: prob.minibatch_grads(k, x, max(1, prob.A.shape[1] // 8))  # noqa: E731
    res = r.reference(record=prob.f)
    ref = run_reference(
        algo=r.algo, grad_fn=gf, x0=jnp.zeros(24), gamma=0.05, steps=10,
        key=jax.random.fold_in(jax.random.key(1), REFERENCE_FOLD), n=5,
        participation=r.participation, record=prob.f)
    assert_bit_identical((res.x, res.state.h, res.metrics),
                         (ref.x, ref.state.h, ref.metrics), "federated spec")


def test_spec_bidirectional_reference_bit_identical_to_direct_run_reference():
    spec = ExperimentSpec(compressor="qsgd:8", downlink="block_topk:8,2",
                          participation="fixed:3", problem="quadratic",
                          n=5, d=24, steps=10, seed=2, gamma=0.03)
    r = build(spec)
    prob = r.problem_instance()
    res = r.reference(record=prob.f)
    ref = run_reference(
        algo=r.algo, downlink=r.downlink,
        grad_fn=lambda _k, x: prob.grads(x), x0=jnp.zeros(24), gamma=0.03,
        steps=10, key=jax.random.fold_in(jax.random.key(2), REFERENCE_FOLD), n=5,
        participation=r.participation, record=prob.f)
    assert_bit_identical((res.x, res.w, res.metrics),
                         (ref.x, ref.w, ref.metrics), "bidirectional spec")


def test_run_reference_full_equals_federated_full_bitwise():
    """The is_full fast path (EFBV.step) == the masked path at an all-ones
    mask, through whole run_reference trajectories."""
    spec = ExperimentSpec(compressor="randk:4", n=4, d=16, steps=8,
                          gamma=0.05, seed=3)
    r = build(spec)
    prob = r.problem_instance()
    kw = dict(algo=r.algo, grad_fn=lambda _k, x: prob.grads(x),
              x0=jnp.zeros(16), gamma=0.05, steps=8,
              key=jax.random.key(3), n=4, record=prob.f)
    a = run_reference(**kw)
    b = run_reference(participation=Participation.parse("bernoulli:1.0"),
                      **kw)
    assert_bit_identical((a.x, a.state.h, a.metrics),
                         (b.x, b.state.h, b.metrics), "full == bern(1)")


# ---------------------------------------------------------------------------
# 3b. historical harness legs == spec-driven run_trajectory, bitwise
# ---------------------------------------------------------------------------

def test_codec_leg_bit_identical_to_spec_trajectory():
    spec = ExperimentSpec(compressor="qsgd:16", agg="sparse_allgather",
                          n=3, d=96, steps=4, seed=0)
    got = run_trajectory(spec, "oracle", lam=0.8, nu=0.9, gamma=0.05)
    ref = run_codec_trajectory("oracle", compressor=make_compressor("qsgd:16"),
                               steps=4, n=3, d=96, lam=0.8, nu=0.9,
                               gamma=0.05, seed=0)
    assert_bit_identical((got["x"], got["h"], got["payload"]),
                         (ref["x"], ref["h"], ref["payload"]), "codec leg")


def test_federated_leg_bit_identical_to_spec_trajectory():
    spec = ExperimentSpec(compressor="block_topk:16,4",
                          agg="sparse_allgather",
                          participation="bernoulli:0.5", n=4, d=64,
                          steps=5, seed=1)
    got = run_trajectory(spec, "oracle", lam=0.7, nu=0.8, gamma=0.05)
    ref = run_federated_trajectory(
        "oracle", compressor=make_compressor("block_topk:16,4"), steps=5,
        n=4, d=64, lam=0.7, nu=0.8, gamma=0.05,
        participation=Participation.parse("bernoulli:0.5"), seed=1)
    assert_bit_identical((got["x"], got["h"], got["masks"], got["payload"]),
                         (ref["x"], ref["h"], ref["masks"], ref["payload"]),
                         "federated leg")
    assert got["round_bits"]["up"] == ref["round_bits"]


def test_bidirectional_leg_bit_identical_to_spec_trajectory():
    spec = ExperimentSpec(compressor="randk:8", agg="sparse_allgather",
                          downlink="qsgd:16", participation="fixed:2",
                          n=4, d=64, steps=5, seed=2)
    got = run_trajectory(spec, "oracle", lam=0.6, nu=0.7, gamma=0.04)
    ref = run_bidirectional_trajectory(
        "oracle", compressor=make_compressor("randk:8"),
        downlink=Downlink.parse("qsgd:16"), steps=5, n=4, d=64, lam=0.6,
        nu=0.7, gamma=0.04, participation=Participation.parse("fixed:2"),
        seed=2)
    assert_bit_identical(
        (got["x"], got["w"], got["h"], got["masks"], got["payload"],
         got["down_payload"]),
        (ref["x"], ref["w"], ref["h"], ref["masks"], ref["payload"],
         ref["down_payload"]), "bidirectional leg")
    assert got["round_bits"] == ref["round_bits"]


def test_spec_trajectory_defaults_from_tuning():
    """lam/nu default to the spec's auto-tuning; gamma must come from the
    spec (or explicitly)."""
    spec = ExperimentSpec(compressor="qsgd:16", agg="sparse_allgather",
                          n=3, d=96, steps=2, gamma=0.05)
    run_ = build(spec)
    got = run_trajectory(spec)
    ref = run_codec_trajectory("oracle",
                               compressor=make_compressor("qsgd:16"),
                               steps=2, n=3, d=96, lam=run_.tuned.lam,
                               nu=run_.tuned.nu, gamma=0.05, seed=0)
    assert_bit_identical(got["x"], ref["x"], "tuned defaults")
    with pytest.raises(ValueError, match="gamma"):
        run_trajectory(dataclasses.replace(spec, gamma=0.0))
    with pytest.raises(ValueError, match="fleet"):
        run_trajectory(ExperimentSpec(compressor="topk:4;qsgd:16",
                                      agg="dense_psum", n=4))


# ---------------------------------------------------------------------------
# 4. checkpoints embed the spec and refuse mismatched resumes
# ---------------------------------------------------------------------------

def test_checkpoint_embeds_spec_and_gates_resume(tmp_path):
    from repro.checkpoint import (restore_checkpoint, save_checkpoint,
                                  saved_spec)

    spec = ExperimentSpec(compressor="qsgd:16", n=4, d=32, steps=7, seed=5)
    tree = {"params": {"x": jnp.arange(6, dtype=jnp.float32)},
            "h_avg": jnp.ones((3,))}
    save_checkpoint(str(tmp_path), 7, tree, spec=spec)

    assert saved_spec(str(tmp_path), 7) == spec
    # matching spec restores bit-exactly
    out = restore_checkpoint(str(tmp_path), 7, tree, spec=spec)
    np.testing.assert_array_equal(np.asarray(out["params"]["x"]),
                                  np.arange(6, dtype=np.float32))
    # mismatched spec is refused, with both specs in the message
    other = dataclasses.replace(spec, compressor="block_topk:16,4")
    with pytest.raises(ValueError, match="refusing resume"):
        restore_checkpoint(str(tmp_path), 7, tree, spec=other)
    # opting out of the gate still works
    restore_checkpoint(str(tmp_path), 7, tree)


def test_checkpoint_specless_files_still_restore(tmp_path):
    from repro.checkpoint import (restore_checkpoint, save_checkpoint,
                                  saved_spec)

    tree = {"x": jnp.ones((4,))}
    save_checkpoint(str(tmp_path), 1, tree)
    assert saved_spec(str(tmp_path), 1) is None
    restore_checkpoint(str(tmp_path), 1, tree)  # ungated: fine
    with pytest.raises(ValueError, match="embeds no experiment spec"):
        restore_checkpoint(str(tmp_path), 1, tree, spec=ExperimentSpec())


# ---------------------------------------------------------------------------
# Run object surface
# ---------------------------------------------------------------------------

def test_round_bits_delegates_to_wire_accounting():
    from repro.distributed import wire

    spec = ExperimentSpec(compressor="qsgd:16", downlink="block_topk:16,4",
                          participation="fixed:3", agg="sparse_allgather",
                          n=8, d=96)
    r = build(spec)
    rb = r.round_bits()
    up_fmt = wire.format_for(r.compressor, jnp.zeros((96,)))
    down_fmt = r.downlink.format_for(jnp.zeros((96,)))
    assert rb["total"] == wire.total_round_bits(up_fmt, down_fmt,
                                                n_workers=8, participants=3)
    assert rb["up"] == up_fmt.bits_per_round(n_workers=8, participants=3)
    assert rb["down"] == down_fmt.downlink_bits_per_round()
    assert rb["dense_both_ways"] == 8 * 32 * 96 + 32 * 96


def test_harness_round_bits_agrees_with_run_round_bits():
    """The two spec-driven surfaces report the same wire accounting,
    including the dense-broadcast convention when no downlink is set."""
    for spec in [
        ExperimentSpec(compressor="qsgd:16", agg="sparse_allgather",
                       n=3, d=96, steps=2, gamma=0.05),
        ExperimentSpec(compressor="qsgd:16", agg="sparse_allgather",
                       downlink="sign", n=3, d=96, steps=2, gamma=0.05),
    ]:
        traj = run_trajectory(spec)
        assert traj["round_bits"] == build(spec).round_bits(), spec.downlink


def test_reference_custom_grad_fn_requires_gamma():
    """Auto-tuned stepsizes come from the problem's smoothness constants;
    a custom grad_fn with no gamma must raise, not silently tune against
    the unrelated built-in problem."""
    r = build(ExperimentSpec(n=2, d=8, steps=1))
    with pytest.raises(SpecError, match="gamma"):
        r.reference(grad_fn=lambda x: jnp.zeros((2, 8)))
    # explicit gamma works without ever building the built-in problem
    res = r.reference(grad_fn=lambda x: jnp.zeros((2, 8)), gamma=0.1)
    assert res.x.shape == (8,)


def test_train_driver_missing_spec_file_is_friendly():
    from repro.launch.train import main

    with pytest.raises(SystemExit, match="bad experiment spec"):
        main(["--spec", "/nonexistent/spec.json"])


def test_train_driver_rejects_builtin_problem_specs(tmp_path):
    """A valid logreg trainer spec is not an LM-driver experiment: the
    driver refuses it with the friendly spec error, not a KeyError."""
    import os

    from repro.launch.train import main

    spec = ExperimentSpec(backend="shard_map", problem="logreg", mesh="1x1",
                          n=1, d=16, steps=1)
    path = os.path.join(str(tmp_path), "s.json")
    with open(path, "w") as f:
        f.write(spec.to_json())
    with pytest.raises(SystemExit, match="model archs"):
        main(["--spec", path])


def test_round_bits_fleet_delegates_to_fleet_accounting():
    from repro.core.compressors import make_fleet
    from repro.distributed import wire

    spec = ExperimentSpec(compressor="topk:7;qsgd:16;sign",
                          agg="dense_psum", n=6, d=96)
    r = build(spec)
    fmts = wire.fleet_formats(make_fleet(spec.compressor, 6),
                              jnp.zeros((96,)))
    assert r.round_bits()["up"] == wire.fleet_bits_per_round(fmts)


def test_round_bits_fleet_composes_participation():
    """Federated fleet accounting: bitmap + inclusion-probability-weighted
    per-worker payloads (the fleet analogue of bits_per_round's
    participants= term)."""
    from repro.core.compressors import make_fleet
    from repro.distributed import wire

    spec = ExperimentSpec(compressor="topk:4;qsgd:16", agg="dense_psum",
                          participation="bernoulli:0.5", n=8, d=64)
    rb = build(spec).round_bits()
    fmts = wire.fleet_formats(make_fleet(spec.compressor, 8),
                              jnp.zeros((64,)))
    want = 32 * wire.bitmap_words(8) \
        + 0.5 * sum(f.bits_per_round() for f in fmts)
    assert rb["up"] == want
    # full participation stays the plain fleet sum
    full = build(dataclasses.replace(spec, participation="full"))
    assert full.round_bits()["up"] == wire.fleet_bits_per_round(fmts)


def test_smoke_field_is_part_of_the_identity():
    """smoke selects a different model config, so it must change the
    fingerprint (the checkpoint gate separates smoke from full runs)."""
    full = ExperimentSpec(backend="shard_map", problem="qwen2-0.5b",
                          mesh="2x2", n=2, d=131072)
    smoke = dataclasses.replace(full, smoke=True)
    assert smoke.fingerprint() != full.fingerprint()
    with pytest.raises(SpecError, match="smoke"):
        ExperimentSpec(smoke=True)  # built-in problems have no smoke config


def test_run_tuned_matches_theory_tune_for():
    from repro.core import tune_for

    spec = ExperimentSpec(compressor="qsgd:16", n=4, d=256,
                          participation="bernoulli:0.5")
    t = build(spec).tuned
    want = tune_for(make_compressor("qsgd:16"), 256, 4, mode="efbv",
                    participation=0.5)
    assert (t.lam, t.nu, t.r) == (want.lam, want.nu, want.r)
    assert build(ExperimentSpec(mode="none")).tuned is None


def test_build_rejects_non_spec():
    with pytest.raises(SpecError, match="ExperimentSpec"):
        build("qsgd:16")
    # dict form is accepted (the JSON-file path)
    assert build({"compressor": "qsgd:16"}).spec.compressor == "qsgd:16"


def test_reference_backend_has_no_trainer_and_vice_versa():
    r = build(ExperimentSpec())
    with pytest.raises(SpecError, match="train_step|reference"):
        r.train_step(lambda p, b: (0.0, {}), None)
    with pytest.raises(SpecError, match="mesh"):
        r.make_mesh()


def test_example_spec_files_parse_and_fingerprint(request):
    """The committed canonical specs under examples/specs/ stay valid and
    their fingerprints match a fresh re-serialization."""
    import pathlib

    spec_dir = pathlib.Path(__file__).resolve().parent.parent \
        / "examples" / "specs"
    files = sorted(spec_dir.glob("*.json"))
    assert len(files) >= 3, files
    for f in files:
        spec = ExperimentSpec.from_json(f.read_text())
        assert ExperimentSpec.from_json(spec.to_json()) == spec
        # the file on disk IS the canonical serialization
        assert f.read_text() == spec.to_json(), f
