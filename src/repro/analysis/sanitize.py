"""``--sanitize`` runtime mode: debug_nans + Pallas interpret everywhere.

The static rules catch what is visible in source; this is the dynamic
half.  Enabling sanitize mode before any jax work:

  * turns on ``jax_debug_nans`` -- the first NaN/Inf produced anywhere in
    a jitted computation raises at the producing primitive instead of
    poisoning the trajectory silently;
  * forces every Pallas kernel through interpret mode (kernels/ops.py's
    ``_interpret_default`` consults :func:`active`), where out-of-bounds
    ref indexing raises instead of wrapping -- on TPU hardware an OOB
    access is silently clamped, which is exactly the bug class interpret
    mode exists to surface;
  * exports ``REPRO_SANITIZE=1`` so subprocesses (the spec-file drivers
    spawn workers) inherit the mode.

Both trainers expose this as ``--sanitize``; ``make sanitize-smoke`` runs
a smoke step of each under it.
"""

from __future__ import annotations

import os

_ENV = "REPRO_SANITIZE"
_active = False


def active() -> bool:
    """Sanitize mode on?  True once :func:`enable` ran in this process or
    the ``REPRO_SANITIZE`` env var marks an enabling parent process."""
    return _active or os.environ.get(_ENV, "") == "1"


def enable() -> None:
    """Idempotently switch this process (and children) into sanitize mode.

    Must run before the first jitted computation: debug_nans only rewraps
    computations compiled after the flag flips.
    """
    global _active
    _active = True
    os.environ[_ENV] = "1"
    import jax

    jax.config.update("jax_debug_nans", True)
    try:  # interpret-at-the-source, where available (newer jax)
        from jax.experimental.pallas import tpu as pltpu

        ctx = getattr(pltpu, "force_tpu_interpret_mode", None)
        if ctx is not None:
            ctx().__enter__()  # process-lifetime scope, deliberately unexited
    except Exception:
        pass  # kernels/ops.py's _interpret_default() hook still covers us
