"""Roofline table from the dry-run artifacts (assignment deliverable g).

Reads results/dryrun_results.jsonl (written by repro.launch.dryrun) and
prints, per (arch x shape) on the single-pod mesh: the three roofline terms,
the dominant bottleneck, MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference) and
the useful-compute ratio MODEL_FLOPS / HLO_FLOPS.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from repro.launch.hlo_analysis import PEAK_FLOPS_BF16

RESULTS = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       "results", "dryrun_results.jsonl")

SHAPE_TOKENS = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (1, 128, "decode"),
    "long_500k": (1, 1, "decode"),
}


def model_flops(rec: dict) -> Optional[float]:
    shape = rec.get("shape")
    n_active = rec.get("active_params")
    if shape not in SHAPE_TOKENS or not n_active:
        return None
    seq, batch, kind = SHAPE_TOKENS[shape]
    tokens = seq * batch
    per_tok = 6.0 * n_active if kind == "train" else 2.0 * n_active
    return per_tok * tokens


def load(path: str = RESULTS, mesh: str = "16x16") -> List[dict]:
    recs: Dict[tuple, dict] = {}
    if not os.path.exists(path):
        return []
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if r.get("mesh") == mesh:
                recs[(r["arch"], r["shape"], r.get("agg_mode"))] = r  # last write wins
    return list(recs.values())


def rows_from_records(recs: List[dict]) -> List[dict]:
    out = []
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        name = f"roofline/{r['arch']}/{r['shape']}"
        if r.get("status") == "skipped":
            out.append({"name": name, "us_per_call": "",
                        "derived": f"skipped:{r.get('note', r.get('skip', ''))}"})
            continue
        if r.get("status") != "ok":
            out.append({"name": name, "us_per_call": "",
                        "derived": f"ERROR:{r.get('error', '?')[:80]}"})
            continue
        roof = r["roofline"]
        mf = model_flops(r)
        hlo_total = roof["hlo_flops_per_device"] * roof["n_chips"]
        useful = (mf / hlo_total) if (mf and hlo_total) else None
        out.append({
            "name": name,
            "us_per_call": f"{max(roof['t_compute_s'], roof['t_memory_s'], roof['t_collective_s']) * 1e6:.1f}",
            "derived": (
                f"t_comp={roof['t_compute_s']:.3e};t_mem={roof['t_memory_s']:.3e};"
                f"t_coll={roof['t_collective_s']:.3e};bound={roof['bottleneck']};"
                f"useful_flops_ratio={useful:.3f}" if useful is not None else
                f"t_comp={roof['t_compute_s']:.3e};t_mem={roof['t_memory_s']:.3e};"
                f"t_coll={roof['t_collective_s']:.3e};bound={roof['bottleneck']}"),
        })
    return out


def run(fast: bool = True):
    recs = load()
    if not recs:
        return [{"name": "roofline/missing", "us_per_call": "",
                 "derived": "run `python -m repro.launch.dryrun` first"}]
    return rows_from_records(recs)


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
