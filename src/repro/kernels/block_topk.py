"""Pallas TPU kernel: block-local top-k gradient compression.

TPU adaptation of the paper's top-k (DESIGN §3.4): an exact global top-k
needs a sort across HBM, which maps terribly onto the TPU vector unit.
Instead each VMEM-resident block keeps its own kb largest-magnitude entries
via *iterative max extraction*: kb data-parallel passes over the (8,128)
vregs -- no sort, no gather, exact first-index tie-breaking, and the working
set never leaves VMEM.

Grid: one step per tile of TILE_NB blocks; BlockSpec tiles are
(TILE_NB, BLOCK) slabs in VMEM (BLOCK a multiple of 128 lanes, TILE_NB a
multiple of 8 sublanes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

TILE_NB = 8  # blocks (rows) per grid step


def _select_mask(xa, kb: int):
    """(rows, block) magnitudes -> 0/1 keep-mask, kb per row, exact."""
    block = xa.shape[1]
    # f32 column indices: Mosaic here lowers neither cumsum nor integer
    # reductions; f32 is exact for block < 2**24
    cols = jax.lax.broadcasted_iota(jnp.float32, xa.shape, 1)

    def body(_, selected):
        score = jnp.where(selected > 0, -jnp.inf, xa)
        m = jnp.max(score, axis=1, keepdims=True)
        # (isfinite has no Pallas TPU lowering; != -inf is the same guard)
        is_m = (score == m) & (m != -jnp.inf)
        # first-index tie-break via min-reduction (cumsum doesn't lower)
        cmin = jnp.min(jnp.where(is_m, cols, float(block)), axis=1,
                       keepdims=True)
        first = is_m & (cols == cmin)
        return selected + first.astype(xa.dtype)

    return jax.lax.fori_loop(0, kb, body, jnp.zeros_like(xa))


def _block_topk_kernel(x_ref, o_ref, *, kb: int):
    x = x_ref[...]
    mask = _select_mask(jnp.abs(x).astype(jnp.float32), kb)
    o_ref[...] = x * mask.astype(x.dtype)


def block_topk_pallas(x2d: Array, kb: int, *, interpret: bool = False) -> Array:
    """x2d: (nb, block) -- nb % TILE_NB == 0, block % 128 == 0."""
    nb, block = x2d.shape
    assert nb % TILE_NB == 0 and block % 128 == 0, (nb, block)
    grid = (nb // TILE_NB,)
    return pl.pallas_call(
        functools.partial(_block_topk_kernel, kb=kb),
        grid=grid,
        in_specs=[pl.BlockSpec((TILE_NB, block), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((TILE_NB, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), x2d.dtype),
        interpret=interpret,
    )(x2d)


def _efbv_update_kernel(g_ref, h_ref, d_ref, h_out_ref, *, kb: int, lam: float):
    """Fused: d = block_topk(g - h); h_new = h + lam * d.  One HBM pass over
    (g, h) instead of three (delta materialize, compress, h update).  lam is
    a compile-time constant (it comes from the paper's closed-form lam*)."""
    g = g_ref[...]
    h = h_ref[...]
    # subtract in f32: bit-identical between interpret mode (which emulates
    # bf16 arithmetic in f32) and real TPU lowering
    delta = g.astype(jnp.float32) - h.astype(jnp.float32)
    mask = _select_mask(jnp.abs(delta), kb)
    d = (delta * mask).astype(g.dtype)
    d_ref[...] = d
    h_out_ref[...] = (h.astype(jnp.float32) + lam * d.astype(jnp.float32)
                      ).astype(h.dtype)


def efbv_update_pallas(g2d: Array, h2d: Array, lam: float, kb: int, *,
                       interpret: bool = False):
    nb, block = g2d.shape
    assert nb % TILE_NB == 0 and block % 128 == 0, (nb, block)
    grid = (nb // TILE_NB,)
    spec = pl.BlockSpec((TILE_NB, block), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_efbv_update_kernel, kb=kb, lam=float(lam)),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=(spec, spec),
        out_shape=(jax.ShapeDtypeStruct((nb, block), g2d.dtype),
                   jax.ShapeDtypeStruct((nb, block), h2d.dtype)),
        interpret=interpret,
    )(g2d, h2d)
