"""Distributed training step: forward/backward under GSPMD (model axis) +
EF-BV compressed gradient aggregation over the worker axes (pod, data).

This is the integration point of the paper into the framework, in two phases
(see distributed/aggregate.py for why):

    phase 1 -- shard_map( manual = worker axes, auto = 'model' ):
        grads_i  = grad( mean loss over the *local* data shard )   # nabla f_i
        message_i, h_i = compress_local(...)                       # Algorithm 1, worker side
    phase 2 -- plain GSPMD:
        g, h_avg = combine_global(stacked messages, ...)           # the wire collective
        params  <- optimizer(params, g)                            # replicated over workers

Per-worker control variates h_i live in the TrainState with a leading worker
axis sharded over (pod, data); inside phase 1 each worker sees its own h_i.

The federated execution mode (``participation=``) samples a per-round worker
mask before phase 1 and threads it through the shard_map as a worker-sharded
(n,) array: sampled workers run Algorithm 1 unchanged, absent workers' wire
messages are gated to decode-zero and their h_i stay stale -- see
docs/algorithms.md#partial-participation--stochastic-gradients.

Bidirectional compression (``downlink=``) adds a phase 3: workers evaluate
gradients at the master's downlink control variate w (their shared model
reconstruction) and the round ends with ONE compressed broadcast through
the downlink codec (aggregate.broadcast_global) -- identical for present
and absent workers, so w stays replicated.  Heterogeneous fleets
(``algo.fleet``) dispatch each worker's own compressor inside phase 1 via
lax.switch on the worker index (dense_psum mode; mixed payload shapes
cannot stack).

The declarative way to obtain a train step is
``repro.core.build(spec).train_step(loss_fn, opt, mesh)``: the
:class:`repro.core.ExperimentSpec` selects this builder vs
:func:`make_train_step_fsdp` from ``spec.backend`` and threads
agg/wire_dtype/downlink/participation from its fields (docs/api.md).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.efbv import (EFBV, PIPELINE_FOLD, Downlink, Participation,
                             Pipeline, downlink_key, participation_key)
from repro.distributed import wire
from repro.distributed.aggregate import (broadcast_global, combine_global,
                                         compress_local)
from repro.distributed.spec import (
    batch_spec, linear_worker_index, stack_worker_spec, to_named_sharding,
)
from repro.launch.mesh import MODEL_AXIS, num_workers, worker_axes
from repro.optim.optimizers import Optimizer, apply_updates, global_norm

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    opt_state: PyTree
    h: PyTree        # per-worker control variates, leading axis n
    h_avg: PyTree    # master's uplink control variate
    step: jax.Array
    # the master's DOWNLINK control variate w: the workers' shared
    # reconstruction of the model under bidirectional compression (one
    # replicated copy -- every worker decodes the same broadcast).  None
    # when the broadcast is uncompressed.
    w: PyTree = None
    # the IN-FLIGHT wire payload of the pipelined schedule (pipeline=depth:1,
    # docs/algorithms.md#pipelined-rounds): the message compressed at round
    # t-1, applied by the master at round t while round t's own payload is
    # still on the wire.  Stacked on a leading worker axis like the phase-1
    # message it double-buffers; None when the schedule is sequential.
    inflight: PyTree = None


def init_inflight(algo: EFBV, params: PyTree, n: int, *,
                  agg_mode: str = "dense_psum",
                  wire_dtype: str = "float32") -> PyTree:
    """The round-0 priming payload of the pipelined schedule: every worker's
    slot holds a REAL wire message that decodes to exactly zero, so the first
    step's master update is g = h_avg0 + nu * 0 (Algorithm 1's x-update is a
    no-op while the h recursion already advances).  Drawn from
    fold_in(key(0), PIPELINE_FOLD) -- the one convention the trainers, the
    reference driver and the differential harness all share."""
    base = jax.random.fold_in(jax.random.key(0), PIPELINE_FOLD)
    if agg_mode != "sparse_allgather":
        return jax.tree.map(
            lambda p: jnp.zeros((n,) + p.shape, jnp.float32), params)
    fmt = wire.tree_format_for(algo.compressor, params, wire_dtype=wire_dtype,
                               rules=algo.leaf_rules)
    tile = lambda a: jnp.tile(a[None], (n,) + (1,) * a.ndim)
    return [jax.tree.map(tile, wire.zero_message(
                codec, jax.random.fold_in(base, j)))
            for j, codec in enumerate(fmt.leaves)]


def init_train_state(params: PyTree, optimizer: Optimizer, mesh, *,
                     bidirectional: bool = False,
                     algo: Optional[EFBV] = None,
                     agg_mode: str = "dense_psum",
                     wire_dtype: str = "float32",
                     pipeline: Optional[Pipeline] = None) -> TrainState:
    n = num_workers(mesh)
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    h = jax.tree.map(lambda p: jnp.zeros((n,) + p.shape, jnp.float32), params)
    pipelined = pipeline is not None and pipeline.depth > 0
    if pipelined and algo is None:
        raise ValueError("a pipelined TrainState buffers a wire payload; "
                         "init_train_state needs algo= to build it")
    return TrainState(
        params=params,
        opt_state=optimizer.init(params),
        h=h,
        h_avg=zeros,
        step=jnp.zeros((), jnp.int32),
        w=jax.tree.map(jnp.array, params) if bidirectional else None,
        inflight=init_inflight(algo, params, n, agg_mode=agg_mode,
                               wire_dtype=wire_dtype) if pipelined else None,
    )


def train_state_shardings(mesh, param_specs: PyTree, state: TrainState) -> TrainState:
    """NamedShardings for every TrainState leaf (params/opt sharded over
    'model', h additionally over the worker axes, scalars replicated)."""
    p_shard = to_named_sharding(mesh, param_specs)

    # momenta share param shapes; match by shape against the param specs
    params_flat = jax.tree.leaves(state.params)
    specs_flat = jax.tree.leaves(param_specs, is_leaf=lambda s: isinstance(s, P))
    shape_to_spec = {}
    for leaf, spec in zip(params_flat, specs_flat):
        shape_to_spec.setdefault(leaf.shape, spec)

    def spec_for(leaf):
        return shape_to_spec.get(leaf.shape, P())

    opt_sh = jax.tree.map(lambda l: NamedSharding(mesh, spec_for(l)), state.opt_state)
    h_sh = to_named_sharding(mesh, stack_worker_spec(mesh, param_specs))
    havg_sh = jax.tree.map(lambda l: NamedSharding(mesh, spec_for(l)), state.h_avg)
    rep = NamedSharding(mesh, P())
    w_sh = None if state.w is None \
        else jax.tree.map(lambda _, s: s, state.w, p_shard)
    fl_sh = _inflight_shardings(mesh, state.inflight)
    return TrainState(params=p_shard, opt_state=opt_sh, h=h_sh, h_avg=havg_sh,
                      step=rep, w=w_sh, inflight=fl_sh)


def _inflight_shardings(mesh, inflight: PyTree):
    """Every in-flight payload leaf carries a leading worker axis of size n:
    shard it over the worker axes like the live phase-1 message it mirrors."""
    if inflight is None:
        return None
    waxes = worker_axes(mesh)
    return jax.tree.map(
        lambda _: NamedSharding(mesh, P(tuple(waxes))), inflight)


def make_train_step(
    loss_fn: Callable[[PyTree, Any], Tuple[jax.Array, dict]],
    optimizer: Optimizer,
    algo: EFBV,
    mesh,
    *,
    agg_mode: str = "dense_psum",
    wire_dtype: str = "float32",
    remat: bool = False,
    downlink: Optional[Downlink] = None,
    participation: Optional[Participation] = None,
    pipeline: Optional[Pipeline] = None,
    grad_transform: Optional[Callable[[PyTree], PyTree]] = None,
) -> Callable[[TrainState, Any, jax.Array], Tuple[TrainState, dict]]:
    """Build the jitted multi-pod train step.

    loss_fn(params, batch) -> (scalar loss, metrics dict); it sees the LOCAL
    batch shard (the worker's f_i) and may use GSPMD-auto 'model' collectives.

    ``wire_dtype`` selects the value precision of sparse/dense payloads under
    ``agg_mode='sparse_allgather'`` (float32 / bfloat16 / float16; quantized
    and bit-packed codecs ignore it).

    With ``downlink`` the step runs *bidirectional* compression
    (core/efbv.py::Downlink / run_reference, same math here): workers
    evaluate gradients at the master's downlink control variate w -- their
    shared reconstruction of the model -- and the round ends with ONE
    compressed broadcast C_s(x^{t+1} - w^t) through the downlink codec,
    which every worker (present or absent under partial participation)
    decodes identically.  Requires a TrainState built with
    ``init_train_state(..., bidirectional=True)``.  An Identity downlink
    is lossless and keeps the run bit-identical to ``downlink=None``.

    ``participation`` switches on the federated execution mode
    (docs/algorithms.md#partial-participation--stochastic-gradients): each
    round samples a worker mask from fold_in(step_key, PARTICIPATION_FOLD)
    OUTSIDE phase 1 (so the reference and sharded paths draw the same
    subset) and threads it through the shard_map as a worker-sharded (n,)
    array; absent workers' messages are gated to decode-zero and their h_i
    stay stale.  None / 'full' keeps the original unmasked code path.

    ``grad_transform`` (optional) rewrites each worker's fp32 gradient tree
    BEFORE Algorithm 1's compress step -- the worker-side hook of the MoE
    expert-sparsity contract (``repro.models.moe.zero_inactive_expert_grads``
    composes the routed-expert mask with the wire codec so the payload only
    carries routed experts; docs/finetuning.md#expert-sparsity).  It must be
    a per-worker pure function of one gradient pytree; None is the exact
    historical step.

    ``pipeline`` (depth 1) switches on the one-round-stale two-phase
    schedule (docs/algorithms.md#pipelined-rounds): the master applies the
    in-flight payload of round t-1 from ``state.inflight`` while round t's
    freshly compressed message replaces it -- the wire exchange of round t
    overlaps the backward pass of round t+1.  Workers' h_i advance on their
    OWN round-t messages, the master's (h_avg, x) recursion lags one round;
    depth 0 / None is the exact sequential step, bit for bit.  Requires a
    TrainState built with ``init_train_state(..., pipeline=...)``.
    """
    waxes = worker_axes(mesh)
    n = num_workers(mesh)
    federated = participation is not None and not participation.is_full
    pipelined = pipeline is not None and pipeline.depth > 0
    # chunked decode (fixed ascending order, see wire.chunked_decode_sum)
    # lets the decode of early chunks overlap the transfer of late ones
    chunks = wire.pipeline_chunks(n) \
        if (pipelined and agg_mode == "sparse_allgather") else 1

    if remat:
        loss_fn = jax.checkpoint(loss_fn)

    # ---- phase 1: worker-local grad + compress (manual over worker axes) ----
    # One body shared by both phase-1 formulations below, so the shard_map
    # and vmap paths cannot drift apart.
    def worker_body(params_for_grad, h_i, batch_i, kw, m=None, widx=None,
                    stream=False):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params_for_grad, batch_i)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if grad_transform is not None:
            grads = grad_transform(grads)
        message, h_i_new = compress_local(algo, kw, grads, h_i, mode=agg_mode,
                                          wire_dtype=wire_dtype, mask=m,
                                          worker=widx, stream=stream)
        local_metrics = {
            "loss": loss,
            "grad_norm": global_norm(grads),
            "h_residual": global_norm(
                jax.tree.map(lambda a, b: a - b, grads, h_i_new)),
            **aux,
        }
        return message, h_i_new, local_metrics

    def local_phase(params, h, batch, key, mask=None):
        widx = linear_worker_index(mesh)
        kw = jax.random.fold_in(key, widx)

        # Differentiate w.r.t. a *worker-varying* view of the params: without
        # the pcast, jax's VMA machinery would treat the cotangent of the
        # worker-invariant params as invariant and psum it over the worker
        # axes -- giving sum_i grad f_i instead of this worker's grad f_i.
        params_v = compat.pcast_varying(params, tuple(waxes))
        h_loc = jax.tree.map(lambda a: a[0], h)
        m = None if mask is None else mask[0]
        # streaming (payload DMA under the h update) only on this un-vmapped
        # path: pallas_call batching would re-purpose the grid dim the
        # streaming kernel slices its HBM outputs by
        message, h_loc_new, local_metrics = worker_body(
            params_v, h_loc, batch, kw, m, widx, stream=pipelined)
        # stack everything on the worker axis
        stack = lambda t: jax.tree.map(lambda a: a[None], t)
        return stack(message), stack(h_loc_new), stack(local_metrics)

    # Old jaxlibs miscompile *partial*-auto shard_map (manual worker axes +
    # auto 'model' axis with size > 1 trips an SPMD-partitioner CHECK).  The
    # vmap formulation below is the same per-worker math under pure GSPMD --
    # worker-major batch reshape, worker keys fold_in(key, i) identical to
    # linear_worker_index -- so the two phase-1s are bit-equivalent for
    # deterministic compressors and draw-equivalent for random ones.
    model_size = mesh.shape.get(MODEL_AXIS, 1)
    use_shard_map = compat.HAS_PARTIAL_AUTO_SHARD_MAP or model_size == 1

    if use_shard_map:
        base_in_specs = (P(), P(waxes), batch_spec(mesh), P())
        local_sharded = compat.shard_map(
            local_phase,
            mesh=mesh,
            # the (n,) participation mask rides in worker-sharded: inside the
            # manual region each worker sees its own scalar mask bit
            in_specs=base_in_specs + ((P(waxes),) if federated else ()),
            out_specs=(P(waxes), P(waxes), P(waxes)),
            manual_axes=waxes,
        )
    else:
        def local_sharded(params, h, batch, key, mask=None):
            wb = jax.tree.map(
                lambda a: a.reshape((n, a.shape[0] // n) + a.shape[1:]), batch)
            wb = jax.lax.with_sharding_constraint(
                wb, jax.tree.map(lambda _: NamedSharding(mesh, P(waxes)), wb))

            def one_worker(i, h_i, wbatch):
                return worker_body(params, h_i, wbatch,
                                   jax.random.fold_in(key, i), widx=i)

            if mask is None:
                return jax.vmap(one_worker)(jnp.arange(n), h, wb)

            def one_worker_masked(i, h_i, wbatch, m):
                return worker_body(params, h_i, wbatch,
                                   jax.random.fold_in(key, i), m, i)

            return jax.vmap(one_worker_masked)(jnp.arange(n), h, wb, mask)

    # ---- full step: phase 1 + phase 2 under one jit ---------------------------
    def train_step(state: TrainState, batch, key):
        # under bidirectional compression workers only ever see w, the
        # master's downlink control variate (their model reconstruction)
        eval_params = state.w if downlink is not None else state.params
        if federated:
            # sampled OUTSIDE phase 1 so reference and sharded paths draw the
            # identical subset S_t from the identical key
            mask = participation.sample_mask(participation_key(key), n)
            message, h_new, local_metrics = local_sharded(
                eval_params, state.h, batch, key, mask)
        else:
            mask = None
            message, h_new, local_metrics = local_sharded(
                eval_params, state.h, batch, key)

        # pipelined: the master consumes the IN-FLIGHT payload (round t-1)
        # while `message` (round t) takes its slot in the double buffer --
        # the data dependence between this round's wire exchange and the
        # optimizer breaks, so XLA overlaps it with the next backward pass
        apply_msg = state.inflight if pipelined else message
        g, h_avg_new = combine_global(
            algo, apply_msg, state.h_avg, n_workers=n, mode=agg_mode,
            wire_dtype=wire_dtype, chunks=chunks)

        updates, opt_state = optimizer.update(g, state.opt_state, state.params)
        params = apply_updates(state.params, updates)

        metrics = {k: jnp.mean(v, axis=0) for k, v in local_metrics.items()}
        metrics["g_norm"] = global_norm(g)
        metrics["update_norm"] = global_norm(updates)
        if federated:
            metrics["participants"] = jnp.sum(mask)

        w = state.w
        if downlink is not None:
            # phase 3: one compressed broadcast through the downlink codec;
            # every worker applies the same decoded innovation, so one
            # replicated copy of w suffices (and absent workers under
            # partial participation decode the identical payload).
            w, _ = broadcast_global(downlink, downlink_key(key), params, w,
                                    wire_dtype=wire_dtype)
            metrics["w_err"] = global_norm(
                jax.tree.map(lambda a, b: a - b, params, w))

        new_state = TrainState(
            params=params,
            opt_state=opt_state,
            h=h_new,
            h_avg=h_avg_new,
            step=state.step + 1,
            w=w,
            inflight=message if pipelined else state.inflight,
        )
        return new_state, metrics

    return jax.jit(train_step, donate_argnums=(0,))


# ---------------------------------------------------------------------------
# FSDP variant (beyond-paper, §Perf): pure-GSPMD trainer where parameters and
# optimizer state are additionally sharded over the worker axes (ZeRO-3
# style).  Per-worker gradients come from vmap over a worker-major batch
# reshape instead of shard_map -- XLA's partitioner then emits the FSDP
# all-gathers per layer and keeps every state shard at 1/(data*model) size.
# Required for dbrx-132b-class models: at 16-way TP alone the fp32 params are
# 33 GiB/device; FSDP brings params+adam+h to ~9 GiB/device.
# ---------------------------------------------------------------------------


def fsdp_specs(mesh, param_specs: PyTree, shapes: PyTree) -> PyTree:
    """Add the worker axes to the first divisible, unsharded dim of each
    param spec (classic FSDP weight sharding on top of tensor parallelism)."""
    w = worker_axes(mesh)
    n = num_workers(mesh)

    def one(spec, leaf):
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for i, (p, dim) in enumerate(zip(parts, leaf.shape)):
            if p is None and dim % n == 0 and dim > 0:
                parts[i] = w
                break
        return P(*parts)

    return jax.tree.map(one, param_specs, shapes,
                        is_leaf=lambda s: isinstance(s, P))


def fsdp_state_shardings(mesh, param_specs: PyTree, state: TrainState
                         ) -> TrainState:
    fspecs = fsdp_specs(mesh, param_specs, state.params)
    p_sh = to_named_sharding(mesh, fspecs)

    shape_to_spec = {}
    for leaf, spec in zip(jax.tree.leaves(state.params),
                          jax.tree.leaves(fspecs, is_leaf=lambda s: isinstance(s, P))):
        shape_to_spec.setdefault(leaf.shape, spec)

    def spec_for(leaf):
        return shape_to_spec.get(leaf.shape, P())

    opt_sh = jax.tree.map(lambda l: NamedSharding(mesh, spec_for(l)), state.opt_state)
    # h has the worker axis on dim 0; inner dims keep only the 'model' sharding
    h_sh = to_named_sharding(mesh, stack_worker_spec(mesh, param_specs))
    havg_sh = jax.tree.map(lambda l: NamedSharding(mesh, spec_for(l)), state.h_avg)
    rep = NamedSharding(mesh, P())
    # the downlink control variate w shards like the params (FSDP included:
    # it is read back densely by every worker's grad anyway)
    w_sh = None if state.w is None \
        else jax.tree.map(lambda _, s: s, state.w, p_sh)
    fl_sh = _inflight_shardings(mesh, state.inflight)
    return TrainState(params=p_sh, opt_state=opt_sh, h=h_sh, h_avg=havg_sh,
                      step=rep, w=w_sh, inflight=fl_sh)


def make_train_step_fsdp(
    loss_fn: Callable[[PyTree, Any], Tuple[jax.Array, dict]],
    optimizer: Optimizer,
    algo: EFBV,
    mesh,
    *,
    agg_mode: str = "dense_psum",
    wire_dtype: str = "float32",
    downlink: Optional[Downlink] = None,
    participation: Optional[Participation] = None,
    pipeline: Optional[Pipeline] = None,
    grad_transform: Optional[Callable[[PyTree], PyTree]] = None,
) -> Callable[[TrainState, Any, jax.Array], Tuple[TrainState, dict]]:
    """Pure-GSPMD train step: vmap over the worker axis for per-worker grads,
    FSDP-sharded params/optimizer state, same EF-BV wire as the shard_map
    trainer (compress_local / combine_global / broadcast_global are shared,
    incl. the federated participation masking, the compressed downlink
    broadcast, the worker-side ``grad_transform`` hook and the pipelined
    one-round-stale schedule -- see
    :func:`make_train_step` for the ``pipeline`` double-buffer semantics;
    phase 1 runs under vmap here, so the streaming kernel variant stays
    off)."""
    waxes = worker_axes(mesh)
    n = num_workers(mesh)
    federated = participation is not None and not participation.is_full
    pipelined = pipeline is not None and pipeline.depth > 0
    chunks = wire.pipeline_chunks(n) \
        if (pipelined and agg_mode == "sparse_allgather") else 1

    def worker_grads(params, batch, key):
        # batch leaves: (B, ...) -> (n, B/n, ...) worker-major
        wb = jax.tree.map(lambda a: a.reshape((n, a.shape[0] // n) + a.shape[1:]),
                          batch)
        wb = jax.lax.with_sharding_constraint(
            wb, jax.tree.map(lambda _: NamedSharding(mesh, P(waxes)), wb))
        keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(n))

        def one(wbatch):
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, wbatch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            if grad_transform is not None:
                grads = grad_transform(grads)
            return loss, aux, grads

        loss, aux, grads = jax.vmap(one)(wb)
        return loss, aux, grads, keys

    def train_step(state: TrainState, batch, key):
        eval_params = state.w if downlink is not None else state.params
        loss, aux, grads, keys = worker_grads(eval_params, batch, key)
        # pin the stacked grads to (worker, model)-sharding
        gspec = stack_worker_spec(mesh, jax.tree.map(
            lambda g: P(*([None] * (g.ndim - 1))), state.h_avg))
        widx = jnp.arange(n)
        if federated:
            mask = participation.sample_mask(participation_key(key), n)
            message, h_new = jax.vmap(
                lambda k, g, h, m, i: compress_local(
                    algo, k, g, h, mode=agg_mode, wire_dtype=wire_dtype,
                    mask=m, worker=i)
            )(keys, grads, state.h, mask, widx)
        else:
            message, h_new = jax.vmap(
                lambda k, g, h, i: compress_local(
                    algo, k, g, h, mode=agg_mode, wire_dtype=wire_dtype,
                    worker=i)
            )(keys, grads, state.h, widx)
        apply_msg = state.inflight if pipelined else message
        g, h_avg_new = combine_global(algo, apply_msg, state.h_avg,
                                      n_workers=n, mode=agg_mode,
                                      wire_dtype=wire_dtype, chunks=chunks)
        updates, opt_state = optimizer.update(g, state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        metrics = {"loss": jnp.mean(loss), "g_norm": global_norm(g),
                   "update_norm": global_norm(updates),
                   "grad_norm": jnp.mean(jax.vmap(global_norm)(grads)),
                   "h_residual": jnp.mean(jax.vmap(
                       lambda gi, hi: global_norm(jax.tree.map(
                           lambda a, b: a - b, gi, hi)))(grads, h_new)),
                   **{k: jnp.mean(v) for k, v in aux.items()}}
        if federated:
            metrics["participants"] = jnp.sum(mask)
        w = state.w
        if downlink is not None:
            w, _ = broadcast_global(downlink, downlink_key(key), params, w,
                                    wire_dtype=wire_dtype)
            metrics["w_err"] = global_norm(
                jax.tree.map(lambda a, b: a - b, params, w))
        new_state = TrainState(params=params, opt_state=opt_state, h=h_new,
                               h_avg=h_avg_new, step=state.step + 1, w=w,
                               inflight=message if pipelined
                               else state.inflight)
        return new_state, metrics

    return jax.jit(train_step, donate_argnums=(0,))
