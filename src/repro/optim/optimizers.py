"""Minimal optax-like optimizer substrate (no external deps).

An Optimizer is a pair (init, update):
    state            = init(params)
    updates, state   = update(grads, state, params)   # updates are *deltas*
    params           = apply_updates(params, updates)

The trainer feeds the EF-BV-aggregated gradient estimate g^{t+1} in as
``grads`` -- the optimizer is agnostic to how the gradient was communicated,
which is exactly the paper's layering (Algorithm 1 wraps "Distributed
proximal SGD"; any first-order method can consume g).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], Tuple[PyTree, PyTree]]


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(tree)))


# ---------------------------------------------------------------------------


def sgd(schedule, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        mom = jax.tree.map(jnp.zeros_like, params) if momentum else None
        return {"count": jnp.zeros((), jnp.int32), "mom": mom}

    def update(grads, state, params):
        lr = schedule(state["count"])
        if momentum:
            mom = jax.tree.map(lambda m, g: momentum * m + g, state["mom"], grads)
            eff = (jax.tree.map(lambda m, g: momentum * m + g, mom, grads)
                   if nesterov else mom)
        else:
            mom, eff = None, grads
        updates = jax.tree.map(lambda g: -lr * g, eff)
        return updates, {"count": state["count"] + 1, "mom": mom}

    return Optimizer(init, update)


def adamw(schedule, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {
            "count": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        }

    def update(grads, state, params):
        count = state["count"] + 1
        lr = schedule(state["count"])
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)

        def upd(m_, v_, p):
            step = m_ / c1 / (jnp.sqrt(v_ / c2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype)

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"count": count, "m": m, "v": v}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------


def clip_by_global_norm(max_norm: float) -> Optimizer:
    """Gradient transform: rescale so ||g|| <= max_norm (chainable)."""

    def init(params):
        return {}

    def update(grads, state, params):
        norm = global_norm(grads)
        scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
        return jax.tree.map(lambda g: g * scale, grads), state

    return Optimizer(init, update)


def chain(*transforms: Optimizer) -> Optimizer:
    """Compose gradient transforms; the last one produces the final deltas."""

    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params):
        new_states = []
        for t, s in zip(transforms, state):
            grads, s = t.update(grads, s, params)
            new_states.append(s)
        return grads, tuple(new_states)

    return Optimizer(init, update)
