from repro.problems.logreg import LogReg, make_synthetic  # noqa: F401
