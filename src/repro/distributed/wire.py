"""Wire codecs: payload layouts, exact bit accounting, and the pack /
unpack / scatter-add helpers shared by the reference and shard_map paths.

The paper's accounting ("number of bits sent by each node ... proportional to
t*k", Sect. 6) only holds if the bytes that cross the wire are the payload,
not a dense mask-compressed tensor.  This module is the single source of
truth for what that payload IS, for EVERY compressor in the C(eta, omega)
zoo -- each compressor declares a :class:`LeafCodec` via ``Compressor.codec``
and :func:`format_for` assembles the per-pytree :class:`WireFormat`:

  codec           compressors                       payload (one leaf, d elems)
  --------------  --------------------------------  ---------------------------
  LeafWire        block-top-k                       (values, local idx) (nb, kb)
  FlatSparse      top-k, rand-k, scaled-rand-k,     (values, global idx) (k,)
                  comp-(k,k'), mix-(k,k'), frac-*
  SignPack        sign (L1-norm scaled)             f32 scale + uint32 bitmap
  QsgdQuant       QSGD(s)                           f32 norm + int8/16 levels
  NaturalPack     natural compression               int8 exponents + sign bitmap
  DensePack       identity, m-nice                  raw values (wire dtype)

``val_dtype`` (float32 / bfloat16 / float16) is an orthogonal knob on the
value-carrying codecs (sparse values, dense streams); scales, norms, signs
and exponents are dtype-fixed.  ``payload_bits`` is EXACT for every codec:
the wire tests assert ``8 * payload_nbytes == payload_bits``, equality, not
proportionality.

Three producers of the block-sparse layout are pinned bit-identical by the
differential harness (tests/harness.py) -- jnp oracle, fused Pallas kernel in
interpret mode, and the same kernel compiled on TPU -- and the rand-k and
QSGD codecs have their own fused kernels (kernels/pack.py) pinned the same
way.  See docs/wire_format.md and docs/compressor_zoo.md.

Federated rounds (per-round client sampling, docs/algorithms.md) gate
messages through :meth:`LeafCodec.mask_message` -- an absent worker's
payload decodes to exactly zero, a present worker's is bitwise untouched --
and ``WireFormat.bits_per_round(participants=...)`` /
:func:`federated_round_bits` account the variable-participant wire: an
n-worker participation bitmap plus only the |S_t| sampled payloads.

The wire is bidirectional: the master -> worker broadcast
(core/efbv.py::Downlink) reuses the same codecs -- ONE message per round
regardless of n or S_t, ``WireFormat.downlink_bits_per_round()`` exact --
and :func:`total_round_bits` composes uplink + downlink with the federated
accounting.  Heterogeneous fleets (per-worker compressors) account their
mixed payloads through :func:`fleet_formats` / :func:`fleet_bits_per_round`.
See docs/wire_format.md#the-downlink-payload.
"""

from __future__ import annotations

import dataclasses
import fnmatch
import math
import os
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
PyTree = Any

# kernel dispatch for the fused pack paths: 'auto' uses the compiled Pallas
# kernel on TPU and the jnp oracle elsewhere; 'interpret' forces the Pallas
# kernel in interpret mode (slow -- differential testing only); 'oracle'
# forces jnp.  Codecs without a fused kernel always take the oracle under
# 'auto' and reject an *explicit* kernel request.
KERNEL_MODES = ("auto", "pallas", "interpret", "oracle")

VAL_DTYPES = ("float32", "bfloat16", "float16")
_VAL_BITS = {"float32": 32, "bfloat16": 16, "float16": 16}


def _kernel_mode(kernel: Optional[str]) -> str:
    mode = kernel or os.environ.get("REPRO_WIRE_KERNEL", "auto")
    if mode not in KERNEL_MODES:
        raise ValueError(f"wire kernel {mode!r} not in {KERNEL_MODES}")
    if mode == "auto":
        mode = "pallas" if jax.default_backend() == "tpu" else "oracle"
    return mode


def _val_bits(val_dtype: str) -> int:
    if val_dtype not in _VAL_BITS:
        raise ValueError(f"wire value dtype {val_dtype!r} not in {VAL_DTYPES}")
    return _VAL_BITS[val_dtype]


# ---------------------------------------------------------------------------
# bit packing helpers (sign bitmaps)
# ---------------------------------------------------------------------------

def bitmap_words(nbits: int) -> int:
    return -(-nbits // 32)


def pack_bits(bits: Array) -> Array:
    """(m,) boolean -> (ceil(m/32),) uint32, LSB-first within each word."""
    m = bits.shape[0]
    w = bitmap_words(m)
    b = jnp.pad(bits.astype(jnp.uint32), (0, 32 * w - m)).reshape(w, 32)
    return jnp.sum(b << jnp.arange(32, dtype=jnp.uint32), axis=1,
                   dtype=jnp.uint32)


def unpack_bits(words: Array, m: int) -> Array:
    """(w,) uint32 -> (m,) boolean, inverse of :func:`pack_bits`."""
    b = (words[:, None] >> jnp.arange(32, dtype=jnp.uint32)) & jnp.uint32(1)
    return b.reshape(-1)[:m].astype(jnp.bool_)


# ---------------------------------------------------------------------------
# codec base class
# ---------------------------------------------------------------------------

class LeafCodec:
    """Wire codec of one pytree leaf: how a compressed message is laid out
    on the wire, with exact bit accounting.

    Subclasses are frozen dataclasses carrying at least ``shape`` and
    ``size``.  A payload is a tuple of arrays; ``encode`` consumes the flat
    f32 innovation ``delta`` (compress-and-pack in one step, losslessly
    representing the compressor's dense output), ``decode`` reproduces that
    dense output bit-for-bit (the property tests assert equality, not
    closeness), and ``decode_sum`` additionally accepts worker-stacked
    payloads (leading axis n) and returns the scatter-SUM -- the local
    combine of the sparse_allgather collective.
    """

    kind: str = "abstract"
    #: ndim of the first payload component in a single (un-stacked) message
    MSG_NDIM: int = 1

    # -- accounting ---------------------------------------------------------
    @property
    def payload_bits(self) -> int:
        """Exact bits of one worker's message for this leaf."""
        raise NotImplementedError

    @property
    def has_kernel(self) -> bool:
        """True if a fused Pallas compress-and-pack kernel exists."""
        return False

    # -- pack / unpack ------------------------------------------------------
    def encode(self, key: Optional[Array], delta: Array) -> Tuple[Array, ...]:
        """Flat f32 innovation -> payload tuple."""
        raise NotImplementedError

    # -- partial participation ---------------------------------------------
    def mask_message(self, payload: Sequence[Array], m: Array
                     ) -> Tuple[Array, ...]:
        """Gate a message on a participation mask: an absent worker's
        (m = 0) payload must decode to exactly zero so the federated round's
        decode-sum only sees the sampled subset S_t.

        ``m`` broadcasts: a scalar gates one un-stacked message, an (n,)
        mask gates the worker-stacked all-gather form.  Default: scale the
        leading value-carrying component (sparse values / sign scale / QSGD
        norm / dense stream) in ITS dtype, so m = 1 is a bitwise identity --
        full participation stays bit-identical to the unmasked wire.
        Codecs whose zero is a sentinel (NaturalPack) override.
        """
        head, *rest = payload
        mm = jnp.asarray(m, head.dtype)
        mm = mm.reshape(mm.shape + (1,) * (head.ndim - mm.ndim))
        return (head * mm, *rest)

    def decode(self, payload: Sequence[Array]) -> Array:
        """One payload -> dense flat f32 (size,) vector, bit-equal to the
        dense compressor output."""
        raise NotImplementedError

    def decode_sum(self, payload: Sequence[Array]) -> Array:
        """Payload (possibly worker-stacked on a leading axis) -> dense flat
        (size,) sum over workers (divide by n for the master mean)."""
        if jax.tree.leaves(payload)[0].ndim > self.MSG_NDIM:
            return jnp.sum(jax.vmap(self.decode)(tuple(payload)), axis=0)
        return self.decode(payload)

    # -- fused worker update ------------------------------------------------
    def encode_update(self, key: Optional[Array], g: Array, h: Array,
                      lam: float, *, kernel: Optional[str] = None,
                      stream: bool = False
                      ) -> Tuple[Tuple[Array, ...], Array]:
        """(payload, h') with d = C(g - h) packed and h' = h + lam d.

        The base implementation is the jnp oracle (encode, scatter back,
        update); codecs with a fused Pallas kernel override it and stay
        bit-identical to this oracle.  ``stream`` asks codecs with an
        async-copy kernel variant to DMA the payload out while the h
        update computes; everyone else ignores it (results are
        bit-identical either way).
        """
        mode = _kernel_mode(kernel)
        if mode in ("pallas", "interpret") and kernel in ("pallas", "interpret"):
            raise ValueError(
                f"{type(self).__name__} has no fused kernel; use kernel="
                f"'oracle' or 'auto'")
        delta = g.astype(jnp.float32) - h.astype(jnp.float32)
        payload = self.encode(key, delta.reshape(-1))
        d = self.decode(payload).reshape(g.shape)
        h_new = (h.astype(jnp.float32) + float(lam) * d).astype(h.dtype)
        return payload, h_new


# ---------------------------------------------------------------------------
# block-sparse codec (block-top-k; the PR-1 format)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LeafWire(LeafCodec):
    """Block-sparse layout: per-block (values, block-LOCAL indices), shapes
    (nb, kb) each.  Local indices keep every index < block (no int32
    overflow on 4e10-element stacked expert tensors) and make the payload
    independent of the leaf's global offset, so the same scatter-add decodes
    one message and the worker-stacked (n, nb, kb) all-gather result."""

    shape: Tuple[int, ...]
    size: int
    block: int
    kb: int
    val_dtype: str = "float32"

    kind = "block_sparse"
    MSG_NDIM = 2

    @property
    def nb(self) -> int:
        return -(-self.size // self.block)

    @property
    def payload_bits(self) -> int:
        """Exact bits of one worker's message for this leaf: values +
        int32 local indices, (nb, kb) each."""
        return self.nb * self.kb * (_val_bits(self.val_dtype) + 32)

    @property
    def has_kernel(self) -> bool:
        # non-f32 value payloads take the oracle: the control variate must
        # track the DECODED payload (what the master adds), and the fused
        # kernel updates h with the pre-cast f32 values
        return self.block % 128 == 0 and self.val_dtype == "float32"

    def encode(self, key, delta):
        vals, idx = pack_oracle(self, delta)
        return vals.astype(jnp.dtype(self.val_dtype)), idx

    def decode(self, payload):
        vals, idx = payload
        return scatter_add(self, vals.astype(jnp.float32), idx)

    decode_sum = decode  # scatter_add natively handles the stacked form

    def encode_update(self, key, g, h, lam, *, kernel=None, stream=False):
        # the fused path emits payload values in g's dtype and updates h with
        # the f32 scatter; both equal the decoded payload only for f32 wires.
        # kernel= is forwarded so an explicit kernel request on a non-f32
        # wire errors (base class) instead of silently taking the oracle.
        if self.val_dtype != "float32" or g.dtype != jnp.float32:
            return LeafCodec.encode_update(self, key, g, h, lam,
                                           kernel=kernel)
        return fused_pack(self, g, h, lam, kernel=kernel, stream=stream)


# ---------------------------------------------------------------------------
# flat-sparse codec (top-k / rand-k / comp-(k,k') / mix-(k,k') families)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FlatSparse(LeafCodec):
    """(values, global int32 indices), (k,) each.  ``selector`` is the
    compressor whose ``encode`` picks the k kept coordinates (and applies
    any unbiasedness scaling); it is a frozen dataclass, so the codec stays
    hashable/jit-static.  Global flat indices require size < 2**31 -- the
    block-sparse codec is the one that scales past int32 leaves."""

    shape: Tuple[int, ...]
    size: int
    k: int
    selector: Any
    val_dtype: str = "float32"

    kind = "flat_sparse"
    MSG_NDIM = 1

    @property
    def payload_bits(self) -> int:
        return self.k * (_val_bits(self.val_dtype) + 32)

    def encode(self, key, delta):
        vals, idx = self.selector.encode(key, delta)
        return vals.astype(jnp.dtype(self.val_dtype)), idx.astype(jnp.int32)

    def decode(self, payload):
        vals, idx = payload
        return jnp.zeros((self.size,), jnp.float32).at[idx.reshape(-1)].add(
            vals.astype(jnp.float32).reshape(-1))

    # the flat scatter-add natively handles the worker-stacked (n, k) form:
    # one (size,) scatter of n*k pairs, never an (n, size) dense intermediate
    decode_sum = decode


@dataclasses.dataclass(frozen=True)
class RandKSparse(FlatSparse):
    """FlatSparse specialised to rand-k: index selection is data-independent,
    which is what makes the fused Pallas h-update kernel possible (the k
    selected positions are drawn outside, the kernel does the dense-free
    h <- h + lam d pass, and the payload values are an O(k) gather)."""

    kind = "randk_sparse"

    @property
    def has_kernel(self) -> bool:
        # the kernel compares f32 linear positions (exact below 2**24) and
        # updates h with the unquantized f32 values (== the decoded payload
        # only for f32 wires)
        return self.size < 2 ** 24 and self.val_dtype == "float32"

    def encode_update(self, key, g, h, lam, *, kernel=None, stream=False):
        del stream  # the rand-k gather kernel has no streaming variant
        mode = _kernel_mode(kernel)
        if mode in ("pallas", "interpret") and not self.has_kernel:
            if kernel in ("pallas", "interpret"):
                raise ValueError(
                    "rand-k fused kernel requires size < 2**24 and a float32"
                    f" wire, got size={self.size} val_dtype={self.val_dtype}")
            mode = "oracle"
        if mode == "oracle":
            return LeafCodec.encode_update(self, key, g, h, lam,
                                           kernel="oracle")
        from repro.kernels import ops
        gf, hf = g.reshape(-1), h.reshape(-1)
        scale = self.size / self.k
        idx = jax.random.choice(key, self.size, shape=(self.k,), replace=False)
        # gather-of-difference == difference-of-gathers, bitwise; the dense
        # delta is never materialized (the kernel recomputes it in VMEM)
        vals = (gf[idx].astype(jnp.float32)
                - hf[idx].astype(jnp.float32)) * scale
        h_new = ops.randk_update(g, h, idx.astype(jnp.int32), float(lam),
                                 float(scale),
                                 interpret=(mode == "interpret"))
        return ((vals.astype(jnp.dtype(self.val_dtype)),
                 idx.astype(jnp.int32)), h_new)


# ---------------------------------------------------------------------------
# 1-bit sign codec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SignPack(LeafCodec):
    """L1-norm-scaled sign: one f32 scale + an LSB-first uint32 sign bitmap
    (bit set <=> coordinate is negative).  32 + 32*ceil(d/32) bits, i.e.
    ~1 bit per coordinate."""

    shape: Tuple[int, ...]
    size: int

    kind = "sign_pack"
    MSG_NDIM = 1

    @property
    def payload_bits(self) -> int:
        return 32 + 32 * bitmap_words(self.size)

    def encode(self, key, delta):
        scale = jnp.sum(jnp.abs(delta)) / delta.shape[0]
        return scale.reshape(1).astype(jnp.float32), pack_bits(delta < 0)

    def decode(self, payload):
        scale, words = payload
        sgn = jnp.where(unpack_bits(words, self.size), -1.0, 1.0)
        return scale[0] * sgn


# ---------------------------------------------------------------------------
# QSGD quantized codec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QsgdQuant(LeafCodec):
    """QSGD(s): one f32 L2 norm + a signed integer level stream, level in
    [-s, s] (int8 when s <= 127, int16 otherwise).  32 + 8*d (or 16*d) bits
    -- <= 1/3 of the fp32 dense tensor, measured, not estimated."""

    shape: Tuple[int, ...]
    size: int
    s: int

    kind = "qsgd_quant"
    MSG_NDIM = 1

    @property
    def level_dtype(self):
        return jnp.int8 if self.s <= 127 else jnp.int16

    @property
    def payload_bits(self) -> int:
        return 32 + self.size * (8 if self.s <= 127 else 16)

    @property
    def has_kernel(self) -> bool:
        return True

    def _levels(self, key, delta, norm):
        """Replicates QSGD.__call__'s stochastic rounding draw exactly."""
        safe = jnp.where(norm > 0, norm, 1.0)
        level = jnp.abs(delta) / safe * self.s
        low = jnp.floor(level)
        up = jax.random.uniform(key, delta.shape) < (level - low)
        return jnp.sign(delta) * (low + up.astype(jnp.float32))

    def encode(self, key, delta):
        norm = jnp.linalg.norm(delta)
        lv = self._levels(key, delta, norm)
        return norm.reshape(1).astype(jnp.float32), lv.astype(self.level_dtype)

    def decode(self, payload):
        norm, lv = payload
        lf = lv.astype(jnp.float32)
        # same op chain as QSGD.__call__: (norm * sign) * (level * 1/s).
        # The vector predicate (not the compressor's scalar norm > 0) only
        # changes zero-level lanes from +-0 to +0 -- value-equal -- and is
        # what lets the fused kernel's jitted tail avoid FMA contraction.
        return jnp.where(lf != 0,
                         (norm[0] * jnp.sign(lf))
                         * (jnp.abs(lf) * (1.0 / self.s)),
                         0.0)

    def encode_update(self, key, g, h, lam, *, kernel=None, stream=False):
        del stream  # the qsgd quantizer has no streaming variant
        mode = _kernel_mode(kernel)
        if mode == "oracle":
            return LeafCodec.encode_update(self, key, g, h, lam,
                                           kernel="oracle")
        from repro.kernels import ops
        norm = jnp.linalg.norm(g.reshape(-1).astype(jnp.float32)
                               - h.reshape(-1).astype(jnp.float32))
        u = jax.random.uniform(key, (self.size,))
        levels, h_new = ops.qsgd_pack_update(
            g, h, u, norm, float(lam), self.s,
            interpret=(mode == "interpret"))
        return (norm.reshape(1).astype(jnp.float32), levels), h_new


# ---------------------------------------------------------------------------
# natural-compression codec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NaturalPack(LeafCodec):
    """Natural compression: int8 power-of-two exponent stream (sentinel -128
    for exact zeros) + uint32 sign bitmap -- the paper's ~9 bits/coordinate.
    Exponents are clipped to [-126, 127]: the codec is exact on the normal
    fp32 range |x| in [2^-126, 2^126]; subnormal magnitudes (never produced
    by training-scale gradients) would clip."""

    shape: Tuple[int, ...]
    size: int

    kind = "natural_pack"
    MSG_NDIM = 1

    @property
    def payload_bits(self) -> int:
        return 8 * self.size + 32 * bitmap_words(self.size)

    def encode(self, key, delta):
        a = jnp.abs(delta)
        safe = jnp.where(a > 0, a, 1.0)
        e = jnp.floor(jnp.log2(safe))
        lo = jnp.exp2(e)
        up = jax.random.uniform(key, delta.shape) < (safe / lo - 1.0)
        es = jnp.clip(e + up.astype(jnp.float32), -126.0, 127.0)
        exps = jnp.where(a > 0, es, -128.0).astype(jnp.int8)
        return exps, pack_bits(delta < 0)

    def decode(self, payload):
        exps, words = payload
        mag = jnp.exp2(exps.astype(jnp.float32))
        sgn = jnp.where(unpack_bits(words, self.size), -1.0, 1.0)
        return jnp.where(exps == -128, 0.0, sgn * mag)

    def mask_message(self, payload, m):
        # zero is the sentinel exponent -128, not a scalable value: absent
        # workers' streams are forced to the sentinel (m = 1 keeps exps as-is)
        exps, words = payload
        mm = jnp.asarray(m)
        mm = mm.reshape(mm.shape + (1,) * (exps.ndim - mm.ndim))
        return jnp.where(mm > 0, exps, jnp.int8(-128)), words


# ---------------------------------------------------------------------------
# dense codec (identity / m-nice / fallback)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DensePack(LeafCodec):
    """Raw value stream in the wire dtype.  Used where the message is
    genuinely dense (identity, m-nice participation scaling); the exact
    accounting is size * value_bits -- honest, if unimpressive."""

    shape: Tuple[int, ...]
    size: int
    compressor: Any
    val_dtype: str = "float32"

    kind = "dense_pack"
    MSG_NDIM = 1

    @property
    def payload_bits(self) -> int:
        return self.size * _val_bits(self.val_dtype)

    def encode(self, key, delta):
        y = self.compressor(key, delta.reshape(self.shape))
        return (y.reshape(-1).astype(jnp.dtype(self.val_dtype)),)

    def decode(self, payload):
        (vals,) = payload
        return vals.astype(jnp.float32)


# ---------------------------------------------------------------------------
# format metadata
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WireFormat:
    """Payload layout for a whole gradient pytree (leaf order = flatten
    order, which both aggregation paths use)."""

    leaves: Tuple[LeafCodec, ...]

    @staticmethod
    def for_tree(tree: PyTree, block: int, kb: int) -> "WireFormat":
        """Block-sparse format for every leaf (the PR-1 constructor)."""
        return WireFormat(tuple(
            LeafWire(shape=tuple(l.shape), size=int(l.size), block=block, kb=kb)
            for l in jax.tree.leaves(tree)))

    def bits_per_round(self, *, n_workers: int = 1,
                       participants: Optional[float] = None):
        """Exact uplink bits one round puts on the wire: per worker when
        n_workers == 1 (the paper's per-node accounting), total otherwise.

        ``participants`` switches to the variable-participant federated
        round: an n-worker participation bitmap (whole uint32 words, like
        every bitmap on this wire) plus only |S_t| payloads.  Pass the
        concrete |S_t| for exact ``int`` bits of one round; a fractional
        expected count p*n returns the expected accounting, explicitly a
        ``float`` (the ONLY case this method returns one).
        """
        per_worker = sum(l.payload_bits for l in self.leaves)
        if participants is None:
            return n_workers * per_worker
        bitmap = 32 * bitmap_words(n_workers)
        if float(participants).is_integer():
            # exact participant count: stay in int arithmetic end to end (a
            # float product silently rounds above 2**53, and the historical
            # int(float) round-trip leaked floats into BENCH rows and
            # `== bits/8` byte assertions)
            return bitmap + int(participants) * per_worker
        return bitmap + participants * per_worker

    def downlink_bits_per_round(self) -> int:
        """Exact bits of the ONE master -> worker broadcast message of a
        round.  The downlink is a single payload regardless of n or of the
        sampled subset S_t: present and absent workers decode the same
        broadcast, so no participation bitmap and no per-worker factor."""
        return sum(l.payload_bits for l in self.leaves)

    def dense_bits(self) -> int:
        """The fp32 dense baseline for this tree (one full copy)."""
        return 32 * sum(l.size for l in self.leaves)


def total_round_bits(up: "WireFormat", down: Optional["WireFormat"] = None, *,
                     n_workers: int, participants: Optional[float] = None):
    """Exact wire bits of one FULL round, both directions:

        uplink   -- n_workers payloads (or, federated, a participation
                    bitmap + the |S_t| sampled payloads), and
        downlink -- one broadcast message (``down``; None means the
                    uncompressed dense fp32 broadcast of the same tree).

    ``participants`` composes the PR-3 federated accounting into the uplink
    term only: the broadcast still goes out (and is decoded by absent
    workers) every round.
    """
    up_bits = up.bits_per_round(n_workers=n_workers, participants=participants)
    down_bits = (up.dense_bits() if down is None
                 else down.downlink_bits_per_round())
    return up_bits + down_bits


def federated_round_bits(fmt: "WireFormat", mask) -> int:
    """Exact wire bits of one federated round given its concrete (n,) mask:
    participation bitmap + the |S_t| sampled workers' payloads."""
    m = np.asarray(mask)
    return fmt.bits_per_round(n_workers=int(m.shape[0]),
                              participants=int(m.sum()))


# ---------------------------------------------------------------------------
# heterogeneous fleets: per-worker formats
# ---------------------------------------------------------------------------

def fleet_formats(fleet: Sequence[Any], tree: PyTree, *,
                  wire_dtype: str = "float32") -> Tuple["WireFormat", ...]:
    """One WireFormat per worker of a heterogeneous fleet (worker i's
    payload layout is its own compressor's)."""
    return tuple(format_for(c, tree, wire_dtype=wire_dtype) for c in fleet)


def fleet_bits_per_round(fmts: Sequence["WireFormat"],
                         mask: Optional[Any] = None) -> int:
    """Exact uplink bits of one mixed-fleet round: the sum of the
    participating workers' (heterogeneous) payloads.

    ``mask`` is the concrete (n,) participation mask of a federated round
    (adds the n-worker bitmap and drops absent workers' payloads); None is
    the full-participation round.
    """
    if mask is None:
        return sum(f.bits_per_round() for f in fmts)
    m = np.asarray(mask)
    if m.shape[0] != len(fmts):
        raise ValueError(f"mask of {m.shape[0]} workers for a fleet of "
                         f"{len(fmts)}")
    return 32 * bitmap_words(len(fmts)) + sum(
        f.bits_per_round() for f, mi in zip(fmts, m) if mi > 0)


# ---------------------------------------------------------------------------
# the serving downlink: versioned compressed-delta push envelopes
# ---------------------------------------------------------------------------

#: exact header bits of one versioned push envelope: two unsigned 64-bit
#: version fields (``version`` of the w this push produces, ``base_version``
#: of the w it must be applied to) -- the only metadata the replica protocol
#: needs beyond the payload itself.
PUSH_HEADER_BITS = 2 * 64

#: envelope kinds: a ``delta`` decodes to the model INNOVATION (the replica
#: applies w + lam * decode, the trainer-side Downlink arithmetic verbatim);
#: a ``snapshot`` decodes to the model itself (the replica assigns it --
#: lossless downlinks ship snapshots, which is what makes an identity-
#: downlink push bit-equal to a full checkpoint load).
PUSH_KINDS = ("delta", "snapshot")


@dataclasses.dataclass(frozen=True)
class DeltaEnvelope:
    """One versioned model push on the serving downlink.

    ``payloads`` is the per-leaf wire payload list of ONE broadcast message
    (exactly what :meth:`repro.core.efbv.Downlink.encode_push` emits and
    :meth:`~repro.core.efbv.Downlink.apply_push` consumes);
    :func:`payload_bytes` of it equals ``push_bits(fmt) / 8`` minus the
    header, exactly.  ``version`` is the model version the push produces,
    ``base_version`` the replica-side w it must be applied to -- a replica
    at any other version MUST refuse the push (stale or gapped) and resync
    from a checkpoint instead of silently drifting.
    """

    version: int
    base_version: int
    payloads: Any
    kind: str = "delta"

    def __post_init__(self):
        if self.kind not in PUSH_KINDS:
            raise ValueError(f"push kind {self.kind!r} not in {PUSH_KINDS}")
        if self.version <= self.base_version:
            raise ValueError(
                f"push version {self.version} must advance past its base "
                f"{self.base_version} (versions are strictly monotonic)")


def push_bits(fmt: "WireFormat") -> int:
    """Exact bits of one versioned delta push: the envelope header plus the
    ONE broadcast message of the downlink wire format (no n or |S_t|
    factor -- every replica decodes the same push)."""
    return PUSH_HEADER_BITS + fmt.downlink_bits_per_round()


def checkpoint_push_bits(fmt: "WireFormat") -> int:
    """Exact bits of shipping a FULL fp32 checkpoint of the same tree under
    the same envelope header -- the baseline a delta push is measured
    against (BENCH_bits ``serve_delta`` rows)."""
    return PUSH_HEADER_BITS + fmt.dense_bits()


def clamp_for_leaf(compressor, size: int):
    """Clamp a compressor's selection counts to one leaf's size.

    Fixed-k sparsifiers (top-k, rand-k, comp-(k,k'), mix-(k,k'), block-top-k)
    assume d >= k; on a pytree with size-1 or 0-d edge leaves that assumption
    breaks -- ``jax.lax.top_k(x, k)`` and ``jax.random.choice(..., (k,),
    replace=False)`` both reject k > d, so encode (and transitively
    :func:`zero_message`, the pipelined priming payload) crashes.  Clamping
    is per-leaf and returns the SAME object whenever no count changes, so
    every existing single-leaf/flat call site is bitwise (and hash-)
    untouched.  Quantizers, sign, natural, dense and the fraction-style
    compressors are size-adaptive already and pass through."""
    from repro.core import compressors as cz  # lazy: cz constructs codecs
    d = int(size)
    if isinstance(cmp := compressor, cz.MixKK):
        k = min(cmp.k, d)
        kp = min(cmp.kp, d - k)
        if (k, kp) != (cmp.k, cmp.kp):
            return dataclasses.replace(cmp, k=k, kp=kp)
    elif isinstance(cmp, cz.CompKK):
        kp = min(cmp.kp, d)
        k = min(cmp.k, kp)
        if (k, kp) != (cmp.k, cmp.kp):
            return dataclasses.replace(cmp, k=k, kp=kp)
    elif isinstance(cmp, (cz.TopK, cz.RandK, cz.ScaledRandK)):
        if cmp.k > d:
            return dataclasses.replace(cmp, k=d)
    elif isinstance(cmp, cz.BlockTopK):
        kb = min(cmp.kb, cmp.block, d)
        if kb != cmp.kb:
            return dataclasses.replace(cmp, kb=kb)
    return compressor


def codec_of(compressor, shape: Tuple[int, ...], size: int,
             wire_dtype: str = "float32") -> LeafCodec:
    """The codec ``compressor`` declares for one leaf (DensePack fallback
    for compressors that declare nothing).  Fixed-k sparsifiers are clamped
    to the leaf's size first (:func:`clamp_for_leaf`), so degenerate leaves
    get a well-formed -- if trivially dense -- payload instead of a crash."""
    compressor = clamp_for_leaf(compressor, size)
    fn = getattr(compressor, "codec", None)
    if fn is None:
        return DensePack(shape=tuple(shape), size=int(size),
                         compressor=compressor, val_dtype=wire_dtype)
    return fn(tuple(shape), wire_dtype=wire_dtype)


def format_for(compressor, tree: PyTree, *,
               wire_dtype: str = "float32") -> WireFormat:
    """WireFormat for ``compressor`` applied leaf-wise to ``tree``.

    Every compressor in the zoo declares a codec, so this never returns
    None: block-top-k gets the block-sparse layout, the top-k/rand-k family
    gets flat (values, indices), sign/QSGD/natural get their bit-packed /
    quantized streams, and identity/m-nice fall back to a dense value
    stream -- all with exact ``bits_per_round``.
    """
    return WireFormat(tuple(
        codec_of(compressor, tuple(l.shape), int(l.size), wire_dtype)
        for l in jax.tree.leaves(tree)))


# ---------------------------------------------------------------------------
# pytree-native wire: per-leaf codec rules composed into ONE accounting
# ---------------------------------------------------------------------------

def _key_str(entry) -> str:
    """One pytree path entry -> its path-string segment."""
    tu = jax.tree_util
    if isinstance(entry, tu.DictKey):
        return str(entry.key)
    if isinstance(entry, tu.SequenceKey):
        return str(entry.idx)
    if isinstance(entry, tu.GetAttrKey):
        return str(entry.name)
    if isinstance(entry, tu.FlattenedIndexKey):
        return str(entry.key)
    return str(entry)


def leaf_paths(tree: PyTree) -> Tuple[str, ...]:
    """'/'-joined path string of every leaf, in flatten order (dict keys,
    sequence indices and attribute names as segments; a bare array tree has
    the single path '')."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return tuple("/".join(_key_str(e) for e in kp) for kp, _ in flat)


def parse_leaf_rules(spec: str) -> Tuple[Tuple[str, Any], ...]:
    """Parse the ';'-separated per-leaf codec grammar into (pattern,
    Compressor) rules, first match wins.

    Each entry is ``pattern=compressor_spec`` -- the pattern is an fnmatch
    glob over the leaf's '/'-joined path -- and a bare ``compressor_spec``
    (no '=') is the default rule, pattern '*'.  Example::

        'embed*=qsgd:16;*norm*=identity;block_topk:256,16'

    Leaves matching no rule keep the experiment's base compressor, so the
    default entry is optional.  Jointly-defined compressors (m-nice) are
    rejected: their draws couple all workers, not leaves.

    Thin delegate into the unified spec grammar (repro.core.specgrammar),
    which also provides the lossless ``format_leaf_rules`` inverse; imported
    lazily because this module is layout-only."""
    from repro.core import specgrammar
    return specgrammar.parse_leaf_rules(spec)


def resolve_leaf(rules, path: str, default):
    """The compressor the rule list assigns to one leaf path (first matching
    fnmatch pattern wins; no match keeps the default compressor)."""
    for pat, comp in rules or ():
        if fnmatch.fnmatchcase(path, pat):
            return comp
    return default


@dataclasses.dataclass(frozen=True)
class TreeWire(WireFormat):
    """Pytree-native wire format: leaf-path -> codec, with the SAME composed
    accounting as every flat format (``bits_per_round`` et al. are inherited
    sums over leaves, so composed bits == sum of per-leaf bits exactly --
    the harness pins the equality).

    Mixed leaves reuse the fleet mixed-codec machinery leaf-wise: each leaf
    carries the (clamped) compressor a rule resolved for it plus that
    compressor's own codec, and encode/decode/zero/mask walk the leaves with
    the per-leaf ``fold_in(key, j)`` convention the aggregation paths and
    ``init_inflight`` already use.  With no rules and one leaf this is the
    flat-vector wire, payload-bitwise."""

    paths: Tuple[str, ...]
    compressors: Tuple[Any, ...]
    treedef: Any

    @staticmethod
    def for_tree(compressor, tree: PyTree, *, wire_dtype: str = "float32",
                 rules: Tuple[Tuple[str, Any], ...] = ()) -> "TreeWire":
        """TreeWire for ``tree``: every leaf's compressor is resolved through
        ``rules`` (falling back to ``compressor``), clamped to the leaf's
        size, and asked for its codec."""
        flat, treedef = jax.tree_util.tree_flatten(tree)
        paths = leaf_paths(tree)
        comps = tuple(
            clamp_for_leaf(resolve_leaf(rules, p, compressor), int(l.size))
            for p, l in zip(paths, flat))
        codecs = tuple(
            codec_of(c, tuple(l.shape), int(l.size), wire_dtype)
            for c, l in zip(comps, flat))
        return TreeWire(leaves=codecs, paths=paths, compressors=comps,
                        treedef=treedef)

    # -- keys ---------------------------------------------------------------
    def leaf_keys(self, keys) -> Tuple[Optional[Array], ...]:
        """Normalize the key argument: an explicit per-leaf sequence is used
        as-is (the harness's single-leaf flat-parity leg), one base key is
        folded per leaf index -- fold_in(key, j) -- the convention every
        aggregation path already uses."""
        if keys is None or not isinstance(keys, (tuple, list)):
            return tuple(jax.random.fold_in(keys, j) if keys is not None
                         else None for j in range(len(self.leaves)))
        if len(keys) != len(self.leaves):
            raise ValueError(f"{len(keys)} leaf keys for a tree of "
                             f"{len(self.leaves)} leaves")
        return tuple(keys)

    # -- pack / unpack, leaf-wise -------------------------------------------
    def encode_update(self, keys, grads: PyTree, h: PyTree, lam: float, *,
                      kernel: Optional[str] = None, stream: bool = False):
        """Per-leaf fused worker update: (payload list, h' pytree) with
        d_j = C_j(g_j - h_j) packed and h'_j = h_j + lam d_j -- no flat
        vector is ever materialized."""
        gl = self.treedef.flatten_up_to(grads)
        hl = self.treedef.flatten_up_to(h)
        ks = self.leaf_keys(keys)
        payloads, h_new = [], []
        for codec, kj, gj, hj in zip(self.leaves, ks, gl, hl):
            # an explicit kernel request applies leaf-wise where a fused
            # kernel exists; kernel-less leaves (dense, sign, ...) run their
            # jnp oracle -- which IS their only backend, so the mixed-tree
            # differential legs stay bit-identical across backends
            kj_kernel = kernel
            if (kernel in ("pallas", "interpret")
                    and not getattr(codec, "has_kernel", False)):
                kj_kernel = "oracle"
            p, hn = codec.encode_update(kj, gj, hj, lam, kernel=kj_kernel,
                                        stream=stream)
            payloads.append(p)
            h_new.append(hn)
        return payloads, jax.tree_util.tree_unflatten(self.treedef, h_new)

    def decode(self, payloads) -> PyTree:
        """One worker's payload list -> dense f32 pytree (leaf shapes)."""
        dense = [c.decode(p).reshape(c.shape)
                 for c, p in zip(self.leaves, payloads)]
        return jax.tree_util.tree_unflatten(self.treedef, dense)

    def decode_sum(self, payloads, *, chunks: int = 1) -> PyTree:
        """Worker-stacked payload list -> dense f32 pytree of scatter-SUMS
        (divide by n for the master mean); ``chunks`` splits the worker axis
        exactly like the flat path's :func:`chunked_decode_sum`."""
        dense = [chunked_decode_sum(c, p, chunks).reshape(c.shape)
                 for c, p in zip(self.leaves, payloads)]
        return jax.tree_util.tree_unflatten(self.treedef, dense)

    def mask_messages(self, payloads, m):
        """Participation-gate every leaf's message (list in, list out)."""
        return [c.mask_message(p, m) for c, p in zip(self.leaves, payloads)]

    def zero_messages(self, base_key: Array):
        """The pipelined schedule's priming payloads, one per leaf, keyed
        fold_in(base_key, j) -- exactly the init_inflight convention."""
        return [zero_message(c, jax.random.fold_in(base_key, j))
                for j, c in enumerate(self.leaves)]

    # -- accounting ---------------------------------------------------------
    def bits_by_leaf(self) -> Tuple[int, ...]:
        """Exact per-leaf payload bits, in flatten order (their sum IS
        ``bits_per_round()``; the harness asserts the equality)."""
        return tuple(c.payload_bits for c in self.leaves)


def tree_format_for(compressor, tree: PyTree, *, wire_dtype: str = "float32",
                    rules=None):
    """The wire format of ``tree``: a plain :class:`WireFormat` when no
    per-leaf rules are given (bit-compatible with every existing call site)
    and a :class:`TreeWire` otherwise."""
    if not rules:
        return format_for(compressor, tree, wire_dtype=wire_dtype)
    return TreeWire.for_tree(compressor, tree, wire_dtype=wire_dtype,
                             rules=tuple(rules))


def payload_bytes(payload: PyTree) -> int:
    """Measured bytes of a payload pytree (what actually crosses the wire)."""
    return sum(a.nbytes for a in jax.tree.leaves(payload))


def encode_update(codec: LeafCodec, key: Optional[Array], g: Array, h: Array,
                  lam: float, *, kernel: Optional[str] = None,
                  stream: bool = False) -> Tuple[Tuple[Array, ...], Array]:
    """Fused compress-and-pack worker update through ``codec`` (module-level
    convenience; dispatches to the codec's fused kernel when it has one).

    ``stream=True`` requests the async-copy variant of the fused kernel
    (payload DMAs out while the control-variate update still computes --
    the pipelined trainer's hot path); codecs without a streaming kernel
    ignore it, and the streamed payload is bit-identical either way."""
    return codec.encode_update(key, g, h, lam, kernel=kernel, stream=stream)


def zero_message(codec: LeafCodec, key: Array) -> Tuple[Array, ...]:
    """The decode-zero payload of ``codec``: a REAL wire message (encode of
    the zero vector, then participation-masked to zero, so stochastic codecs
    decode to exactly zero too).  Primes the pipelined schedule's round-0
    in-flight buffer -- every execution path (trainer, harness) builds it
    from the same fold_in(key(0), PIPELINE_FOLD) key, so they agree
    bit-for-bit."""
    payload = codec.encode(key, jnp.zeros((codec.size,), jnp.float32))
    return codec.mask_message(payload, jnp.zeros((), jnp.float32))


def pipeline_chunks(n_workers: int) -> int:
    """Worker-axis chunk count of the pipelined (depth >= 1) exchange:
    gcd(n, 4) splits the stacked payload into equal slices so the decode of
    early chunks overlaps the transfer of late ones.  Below four workers a
    chunk degenerates to a single worker's slice of the worker-sharded
    payload -- the partitioner reshards every slice and the permutes cost
    more than the overlap buys -- so the exchange stays whole.  ONE rule
    shared by the trainer and the differential harness, so their depth-1
    trajectories chunk -- and therefore sum -- identically."""
    n = int(n_workers)
    return math.gcd(n, 4) if n >= 4 else 1


def chunked_decode_sum(codec: LeafCodec, payload, chunks: int) -> Array:
    """decode_sum of a worker-stacked payload with the worker axis split
    into ``chunks`` equal slices, partial sums accumulated in FIXED
    ascending chunk order.

    ``chunks=1`` is literally ``codec.decode_sum`` (the sequential path's
    byte-identity is preserved).  The fixed order is load-bearing: the ring
    exchange delivers chunks in a device-dependent order, and float sums
    only stay replica-identical if every device accumulates them the same
    way."""
    if chunks <= 1:
        return codec.decode_sum(payload)
    n = jax.tree.leaves(payload)[0].shape[0]
    if n % chunks:
        raise ValueError(f"{n} stacked messages do not split into {chunks} "
                         "equal chunks")
    cs = n // chunks
    total = None
    for c in range(chunks):
        part = jax.tree.map(lambda a: a[c * cs:(c + 1) * cs], tuple(payload))
        dec = codec.decode_sum(part)
        total = dec if total is None else total + dec
    return total


# ---------------------------------------------------------------------------
# block-sparse pack / unpack / scatter-add (jnp; the layout spec)
# ---------------------------------------------------------------------------

def _pad2d(xf: Array, lw: LeafWire) -> Array:
    pad = lw.nb * lw.block - lw.size
    return jnp.pad(xf, (0, pad)).reshape(lw.nb, lw.block)


def pack_oracle(lw: LeafWire, delta: Array) -> Tuple[Array, Array]:
    """jnp oracle: (values, local indices), (nb, kb) each -- the layout every
    fused producer must match bit-for-bit."""
    xp = _pad2d(delta.reshape(-1), lw)
    _, idx = jax.lax.top_k(jnp.abs(xp), lw.kb)
    vals = jnp.take_along_axis(xp, idx, axis=1)
    return vals, idx.astype(jnp.int32)


def scatter_add(lw: LeafWire, vals: Array, idx: Array) -> Array:
    """Payload -> dense flat (size,) vector.

    Accepts one message (nb, kb) or the worker-stacked all-gather result
    (n, nb, kb); the stacked form is scatter-SUMMED per block (the local
    combine of the sparse_allgather collective -- divide by n for the mean).
    """
    if vals.ndim == 3:  # (n, nb, kb) -> (nb, n*kb)
        vals = jnp.moveaxis(vals, 0, 1).reshape(vals.shape[1], -1)
        idx = jnp.moveaxis(idx, 0, 1).reshape(idx.shape[1], -1)
    rows = jnp.arange(lw.nb)[:, None]
    out = jnp.zeros((lw.nb, lw.block), vals.dtype).at[rows, idx].add(vals)
    return out.reshape(-1)[:lw.size]


def unpack(lw: LeafWire, vals: Array, idx: Array) -> Array:
    """One message -> dense tensor of the leaf's original shape."""
    return scatter_add(lw, vals, idx).reshape(lw.shape)


# ---------------------------------------------------------------------------
# fused compress-and-pack (the block-top-k worker hot path)
# ---------------------------------------------------------------------------

def fused_pack(lw: LeafWire, g: Array, h: Array, lam: float, *,
               kernel: Optional[str] = None, stream: bool = False
               ) -> Tuple[Tuple[Array, Array], Array]:
    """d = block_topk(g - h) packed as (values, indices); h' = h + lam d.

    Dispatches to the Pallas kernel (one HBM pass, dense d never leaves
    VMEM) or the jnp oracle; all backends produce bit-identical results.
    ``stream=True`` selects the async-copy kernel variant -- the payload
    slab DMAs toward HBM while the h update still computes (same bits, the
    pipelined trainer just stops waiting for them).
    """
    mode = _kernel_mode(kernel)
    if mode in ("pallas", "interpret") and lw.block % 128 != 0:
        # the Pallas kernel tiles 128-lane slabs; other block sizes take the
        # bit-identical oracle.  Only an *explicit* per-call request errors.
        if kernel in ("pallas", "interpret"):
            raise ValueError(
                f"Pallas pack kernel requires block % 128 == 0, got {lw.block}")
        mode = "oracle"
    if mode in ("pallas", "interpret"):
        from repro.kernels import ops
        return ops.efbv_pack_update(g, h, float(lam), block=lw.block,
                                    kb=lw.kb, interpret=(mode == "interpret"),
                                    stream=stream)
    # jnp oracle: same arithmetic, same order of operations as the kernel
    delta = g.astype(jnp.float32) - h.astype(jnp.float32)
    vals, idx = pack_oracle(lw, delta)
    d = scatter_add(lw, vals, idx).reshape(lw.shape)
    h_new = (h.astype(jnp.float32) + float(lam) * d).astype(h.dtype)
    return (vals.astype(g.dtype), idx), h_new
