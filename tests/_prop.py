"""Property-testing shim: hypothesis when installed, fixed-seed sampling
otherwise.

The tier-1 suite must never ImportError on an optional dependency.  Tests
import ``given/settings/st`` from here; with hypothesis present they get the
real thing, and on a bare container they get a deterministic degradation:
``@given`` expands into a loop over ``max_examples`` pseudo-random draws
(seeded from the test name, so runs are reproducible) and ``@settings`` just
records ``max_examples``.

Only the strategy surface this repo uses is emulated: ``st.integers`` and
``st.floats`` with inclusive bounds.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random
    import zlib

    _DEFAULT_MAX_EXAMPLES = 25

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_at(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    st = _Strategies()

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_):
        def deco(fn):
            fn._prop_max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            n = getattr(fn, "_prop_max_examples", _DEFAULT_MAX_EXAMPLES)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = random.Random(seed)
                for _ in range(n):
                    drawn = {name: s.example_at(rng)
                             for name, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)

            # hide the generated parameters from pytest's fixture resolution
            # (hypothesis does the same via its own wrapper signature)
            sig = inspect.signature(fn)
            kept = [p for name, p in sig.parameters.items()
                    if name not in strategies]
            wrapper.__signature__ = sig.replace(parameters=kept)
            return wrapper

        return deco
