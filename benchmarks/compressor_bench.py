"""Compressor micro-benchmarks (us/call on this host) incl. the Pallas
block-top-k kernel (interpret mode on CPU) vs its XLA oracle."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import KEY, timeit
from repro.core import BlockTopK, CompKK, Natural, QSGD, RandK, TopK
from repro.kernels import ops, ref


def run(fast: bool = True):
    d = 1 << 16
    x = jax.random.normal(KEY, (d,))
    rows = []
    cases = [
        ("topk_1pc", jax.jit(lambda k, v: TopK(d // 100)(k, v))),
        ("randk_1pc", jax.jit(lambda k, v: RandK(d // 100)(k, v))),
        ("comp_k_kp", jax.jit(lambda k, v: CompKK(d // 100, d // 2)(k, v))),
        ("block_topk_core", jax.jit(lambda k, v: BlockTopK(1024, 16)(k, v))),
        ("natural", jax.jit(lambda k, v: Natural()(k, v))),
        ("qsgd_s16", jax.jit(lambda k, v: QSGD(16)(k, v))),
        ("block_topk_ref", jax.jit(lambda k, v: ref.block_topk_ref(v, 1024, 16))),
    ]
    iters = 5 if fast else 30
    for name, fn in cases:
        us = timeit(fn, KEY, x, iters=iters)
        rows.append({"name": f"compressor/{name}", "us_per_call": f"{us:.1f}",
                     "derived": f"d={d}"})
    # pallas kernel (interpret on CPU -- not a speed claim, a parity check)
    us = timeit(lambda v: ops.block_topk(v, block=1024, kb=16), x, iters=3)
    rows.append({"name": "compressor/block_topk_pallas_interpret",
                 "us_per_call": f"{us:.1f}", "derived": "interpret=True"})
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(fast=True))
