"""§Perf hillclimb harness: compile a VARIANT of one (arch × shape) pair and
report the roofline-term deltas against the recorded baseline.

    PYTHONPATH=src python -m benchmarks.perf_iter --arch dbrx-132b \
        --shape train_4k --agg sparse_allgather --tag "sparse wire"

Each invocation = one hypothesis→change→measure cycle; results append to
results/perf_iters.jsonl for the EXPERIMENTS §Perf log.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import json      # noqa: E402
import time      # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--agg", default="dense_psum")
    ap.add_argument("--compressor", default="block_topk:4096,64")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--trainer", default="shard_map",
                    choices=["shard_map", "fsdp"])
    ap.add_argument("--param-dtype", default=None)
    ap.add_argument("--attn-impl", default=None,
                    choices=[None, "direct", "chunked"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--hypothesis", default="")
    ap.add_argument("--out", default="results/perf_iters.jsonl")
    ap.add_argument("--baseline", default="results/dryrun_results.jsonl")
    args = ap.parse_args()

    from repro.launch.dryrun import run_one

    tag = "_" + args.tag.replace(" ", "-") if args.tag else ""
    rec = run_one(args.arch, args.shape, multi_pod=args.multi_pod,
                  agg_mode=args.agg, compressor=args.compressor,
                  hlo_dir="results/hlo_perf", trainer=args.trainer,
                  param_dtype=args.param_dtype, attn_impl=args.attn_impl,
                  hlo_tag=tag)
    rec["tag"] = args.tag
    rec["hypothesis"] = args.hypothesis

    # diff vs baseline
    base = None
    if os.path.exists(args.baseline):
        for line in open(args.baseline):
            r = json.loads(line)
            if (r["arch"] == args.arch and r["shape"] == args.shape
                    and r["mesh"] == rec["mesh"] and r.get("status") == "ok"):
                base = r
    if base and rec.get("status") == "ok":
        b, v = base["roofline"], rec["roofline"]
        print(f"\n=== {args.arch} x {args.shape} [{args.tag}] ===")
        for term in ["t_compute_s", "t_memory_s", "t_collective_s"]:
            delta = (v[term] - b[term]) / max(b[term], 1e-30) * 100
            print(f"  {term:16s} {b[term]:.4e} -> {v[term]:.4e}  ({delta:+.1f}%)")
        print(f"  bottleneck       {b['bottleneck']} -> {v['bottleneck']}")
        rec["baseline"] = {k: b[k] for k in
                           ["t_compute_s", "t_memory_s", "t_collective_s",
                            "bottleneck"]}
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "a") as f:
        f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
