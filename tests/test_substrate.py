"""Substrate unit tests: optimizers, schedules, data pipeline, checkpointing,
logreg problem layer."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import SyntheticLM
from repro.optim import adamw, cosine, constant, linear_warmup, sgd, wsd
from repro.optim.optimizers import apply_updates, chain, clip_by_global_norm, global_norm
from repro.problems import LogReg, make_synthetic

KEY = jax.random.key(0)


# ---- optimizers -------------------------------------------------------------

def test_sgd_matches_closed_form():
    params = {"w": jnp.asarray([1.0, -2.0])}
    opt = sgd(constant(0.1))
    st = opt.init(params)
    g = {"w": jnp.asarray([0.5, 0.5])}
    upd, st = opt.update(g, st, params)
    np.testing.assert_allclose(np.asarray(upd["w"]), [-0.05, -0.05])


def test_adamw_converges_quadratic():
    opt = adamw(constant(0.05), weight_decay=0.0)
    x = {"w": jnp.asarray([5.0, -3.0])}
    st = opt.init(x)
    for _ in range(400):
        g = {"w": 2 * x["w"]}
        upd, st = opt.update(g, st, x)
        x = apply_updates(x, upd)
    assert float(jnp.max(jnp.abs(x["w"]))) < 1e-2


def test_clip_chain():
    opt = chain(clip_by_global_norm(1.0), sgd(constant(1.0)))
    params = {"w": jnp.zeros(3)}
    st = opt.init(params)
    g = {"w": jnp.asarray([30.0, 0.0, 40.0])}  # norm 50
    upd, st = opt.update(g, st, params)
    assert abs(float(global_norm(upd)) - 1.0) < 1e-5


def test_schedules():
    s = cosine(1.0, total_steps=100, warmup_steps=10)
    assert float(s(jnp.int32(0))) == 0.0
    assert abs(float(s(jnp.int32(10))) - 1.0) < 1e-6
    assert float(s(jnp.int32(100))) < 0.2
    w = wsd(1.0, warmup_steps=10, stable_steps=50, decay_steps=40)
    assert abs(float(w(jnp.int32(30))) - 1.0) < 1e-6   # stable plateau
    assert float(w(jnp.int32(100))) < 0.05             # decayed
    lw = linear_warmup(2.0, 4)
    assert abs(float(lw(jnp.int32(2))) - 1.0) < 1e-6


# ---- data -------------------------------------------------------------------

def test_synthetic_lm_determinism_and_shapes():
    d1 = SyntheticLM(vocab=101, seq_len=16, global_batch=8, n_workers=4, seed=3)
    d2 = SyntheticLM(vocab=101, seq_len=16, global_batch=8, n_workers=4, seed=3)
    b1, b2 = d1.batch(7), d2.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (8, 16)
    assert b1["labels"][0, -1] == -1
    assert (b1["tokens"] >= 0).all() and (b1["tokens"] < 101).all()
    # next-token labels
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_synthetic_lm_heterogeneity():
    """heterogeneous workers have distinct token marginals."""
    d = SyntheticLM(vocab=1000, seq_len=64, global_batch=8, n_workers=4,
                    seed=0, heterogeneity=0.9)
    b = d.batch(0)["tokens"].reshape(4, 2, 64)
    means = b.mean(axis=(1, 2))
    assert np.std(means) > 10.0  # worker marginals differ


# ---- checkpoint ---------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "layers": [jnp.ones(2), jnp.zeros(3)]},
            "step": jnp.int32(7)}
    save_checkpoint(str(tmp_path), 7, tree)
    save_checkpoint(str(tmp_path), 9, tree)
    assert latest_step(str(tmp_path)) == 9
    got = restore_checkpoint(str(tmp_path), 7, jax.tree.map(jnp.zeros_like, tree))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                            np.asarray(b)),
                 tree, got)


def test_checkpoint_structure_mismatch(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"a": jnp.ones(2)})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 1, {"b": jnp.ones(2)})


# ---- logreg problem ------------------------------------------------------------

def test_logreg_solver_stationarity():
    A, b = make_synthetic(KEY, N=300, d=20)
    prob = LogReg.split(A, b, n=10, mu_reg=0.1)
    x_star, f_star = prob.solve()
    gnorm = float(jnp.linalg.norm(prob.grad(x_star)))
    assert gnorm < 1e-5, gnorm
    # strong convexity: any other point has larger f
    x2 = x_star + 0.01
    assert float(prob.f(x2)) > f_star


def test_logreg_smoothness_constants():
    A, b = make_synthetic(KEY, N=200, d=10)
    prob = LogReg.split(A, b, n=5, mu_reg=0.1)
    Li = prob.L_i()
    assert prob.L_max() >= prob.L_tilde() >= 0.1
    assert Li.shape == (5,)
    # empirical gradient-Lipschitz check against L_max
    x1 = jax.random.normal(KEY, (10,))
    x2 = x1 + 0.01 * jax.random.normal(jax.random.key(1), (10,))
    for i in range(5):
        g1 = jax.grad(prob._loss_one)(x1, prob.A[i], prob.b[i])
        g2 = jax.grad(prob._loss_one)(x2, prob.A[i], prob.b[i])
        lhs = float(jnp.linalg.norm(g1 - g2))
        rhs = float(Li[i] * jnp.linalg.norm(x1 - x2))
        assert lhs <= rhs * (1 + 1e-3)


def test_logreg_overlap():
    A, b = make_synthetic(KEY, N=100, d=8)
    p1 = LogReg.split(A, b, n=10, overlap=1)
    p2 = LogReg.split(A, b, n=10, overlap=2)
    assert p2.A.shape[1] == 2 * p1.A.shape[1]
