"""Shared transformer building blocks: norms, RoPE / M-RoPE, GQA attention
(QKV bias, sliding window, KV cache), SwiGLU / GELU MLPs.

Parameters are plain dict pytrees; initializers return (params, specs) where
specs are PartitionSpecs over the 'model' mesh axis chosen by
:func:`auto_spec` (first divisible preferred dim wins, else replicate --
handles head counts like 36 or expert counts like 40 that don't divide 16).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array


# --------------------------------------------------------------------------
# sharding helper
# --------------------------------------------------------------------------

MODEL_AXIS_SIZE = 16  # production 'model' axis; smoke meshes divide it


def auto_spec(shape: Sequence[int], prefer: Sequence[int],
              axis_size: int = MODEL_AXIS_SIZE) -> P:
    """PartitionSpec putting 'model' on the first preferred dim divisible by
    the model-axis size; replicated otherwise."""
    for dim in prefer:
        if shape[dim] % axis_size == 0:
            spec = [None] * len(shape)
            spec[dim] = "model"
            return P(*spec)
    return P(*([None] * len(shape)))


def _init(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rmsnorm_init(d: int) -> Tuple[Array, P]:
    return jnp.ones((d,), jnp.float32), P(None)


def rmsnorm(x: Array, w: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w).astype(dt)


# --------------------------------------------------------------------------
# RoPE and M-RoPE
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, hd); positions: (B, S) int."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, positions3: Array, theta: float,
                sections: Sequence[int]) -> Array:
    """Multimodal RoPE (Qwen2-VL): positions3 (3, B, S) = (t, h, w) ids;
    frequency channels are split into len(sections) groups, each rotated by
    its own position stream.  sum(sections) == hd // 2."""
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    # build per-channel positions by section
    chunks = []
    start = 0
    for sec, pos in zip(sections, positions3):
        chunks.append(pos[..., None].astype(jnp.float32) * freqs[start:start + sec])
        start += sec
    angles = jnp.concatenate(chunks, axis=-1)  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

def _head_spec(n_heads: int, hd: int, dim: int, policy: str,
               axis_size: int = MODEL_AXIS_SIZE) -> P:
    """Attention projection sharding policy (§Perf iterations 1/4).

    When n_heads divides the model axis, flat sharding IS head-aligned and
    everyone agrees.  When it doesn't (phi3: 40, granite: 24, minicpm: 36,
    qwen2: 14), the measured tradeoff is:

      'flat'      -- shard the flat H*hd dim anyway: sharded attention compute
                     but GSPMD repartitions heads and all-reduces S x S score
                     tensors (+wire).  Wins when the pair is memory-bound
                     (phi3 train: max-term 63.6s vs 109s replicated).
      'replicate' -- replicate the (small) attention weights: no score
                     collectives at all, but attention compute/memory runs on
                     every model shard.  Wins when the pair is collective-
                     bound (granite prefill: max-term 124s vs 199s flat).
    """
    aligned = n_heads % axis_size == 0
    if aligned or policy == "flat":
        if (n_heads * hd) % axis_size == 0:
            return P(None, "model") if dim == 1 else P("model", None)
        return P(None, None)
    return P(None, None)  # replicate


def attention_init(key, d: int, n_heads: int, n_kv: int, hd: int,
                   qkv_bias: bool, shard_policy: str = "flat"
                   ) -> Tuple[Dict[str, Array], Dict[str, P]]:
    ks = jax.random.split(key, 4)
    params = {
        "wq": _init(ks[0], (d, n_heads * hd)),
        "wk": _init(ks[1], (d, n_kv * hd)),
        "wv": _init(ks[2], (d, n_kv * hd)),
        "wo": _init(ks[3], (n_heads * hd, d), scale=1.0 / math.sqrt(n_heads * hd)),
    }
    specs = {
        "wq": _head_spec(n_heads, hd, 1, shard_policy),
        "wk": _head_spec(n_kv, hd, 1, shard_policy),
        "wv": _head_spec(n_kv, hd, 1, shard_policy),
        "wo": _head_spec(n_heads, hd, 0, shard_policy),
    }
    if qkv_bias:
        params.update({
            "bq": jnp.zeros((n_heads * hd,)),
            "bk": jnp.zeros((n_kv * hd,)),
            "bv": jnp.zeros((n_kv * hd,)),
        })

        def bias_spec(nh):
            s = _head_spec(nh, hd, 1, shard_policy)
            return P("model") if s[1] == "model" else P(None)

        specs.update({
            "bq": bias_spec(n_heads),
            "bk": bias_spec(n_kv),
            "bv": bias_spec(n_kv),
        })
    return params, specs


def _project_qkv(p, x, n_heads, n_kv, hd):
    B, S, _ = x.shape
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return (q.reshape(B, S, n_heads, hd), k.reshape(B, S, n_kv, hd),
            v.reshape(B, S, n_kv, hd))


def _sdpa(q: Array, k: Array, v: Array, mask: Optional[Array]) -> Array:
    """Grouped scaled-dot-product attention.
    q: (B, Sq, H, hd); k, v: (B, Sk, K, hd); H = K * G."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k) / math.sqrt(hd)
    scores = scores.astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(B, Sq, H, hd)


def _sdpa_chunked(q: Array, k: Array, v: Array, *, window: int = 0,
                  chunk: int = 1024) -> Array:
    """Flash-style attention: lax.scan over KV chunks with an online softmax.

    §Perf iteration 3: the direct SDPA materializes (B, K, G, S, S) f32 score
    tensors in HBM (the dominant memory term on phi3/minitron train+prefill);
    this keeps the working set at (B, K, G, S, chunk) and lets XLA fuse the
    rescale chain.  Causal-only (training/prefill path).
    """
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    nc = -(-k.shape[1] // chunk)
    Sk = nc * chunk
    kp = jnp.pad(k, ((0, 0), (0, Sk - k.shape[1]), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Sk - v.shape[1]), (0, 0), (0, 0)))
    qg = (q.reshape(B, Sq, K, G, hd) / math.sqrt(hd)).astype(q.dtype)
    kc = kp.reshape(B, nc, chunk, K, hd)
    vc = vp.reshape(B, nc, chunk, K, hd)
    qi = jnp.arange(Sq)

    def body(carry, xs):
        m, l, acc = carry           # (B,K,G,Sq), (B,K,G,Sq), (B,K,G,Sq,hd)
        kj, vj, j = xs              # (B,chunk,K,hd) x2, chunk index
        s = jnp.einsum("bqkgh,bckh->bkgqc", qg, kj).astype(jnp.float32)
        kidx = j * chunk + jnp.arange(chunk)
        valid = kidx[None, :] <= qi[:, None]
        if window:
            valid &= kidx[None, :] > qi[:, None] - window
        s = jnp.where(valid[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m - m_new)
        l_new = l * scale + jnp.sum(p, axis=-1)
        acc = acc * scale[..., None] + jnp.einsum(
            "bkgqc,bckh->bkgqh", p.astype(q.dtype), vj).astype(jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, K, G, Sq), -1e30, jnp.float32) + qg.reshape(-1)[0].astype(jnp.float32) * 0
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32) + qg.reshape(-1)[0].astype(jnp.float32) * 0
    a0 = jnp.zeros((B, K, G, Sq, hd), jnp.float32) + qg.reshape(-1)[0].astype(jnp.float32) * 0
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.arange(nc)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out.astype(q.dtype), -2, 1).reshape(B, Sq, H, hd)


def causal_mask(Sq: int, Sk: int, window: int = 0, offset: int = 0) -> Array:
    """(1, 1, 1, Sq, Sk) boolean mask.  offset = Sk - Sq for cached decode."""
    qi = jnp.arange(Sq)[:, None] + offset
    ki = jnp.arange(Sk)[None, :]
    m = ki <= qi
    if window:
        m = m & (ki > qi - window)
    return m[None, None, None]


def attention(p, x: Array, *, n_heads: int, n_kv: int, hd: int,
              positions: Array, theta: float, window: int = 0,
              mrope_sections: Sequence[int] = (), causal: bool = True,
              kv: Optional[Tuple[Array, Array]] = None,
              impl: str = "direct") -> Array:
    """Full-sequence attention (training / prefill).

    kv: optional externally-provided (k, v) for cross-attention.
    impl: 'direct' (materialized scores) or 'chunked' (online softmax)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, n_heads, n_kv, hd)
    if kv is not None:
        k, v = kv  # cross-attention: encoder keys/values (already projected)
    if mrope_sections:
        q = apply_mrope(q, positions, theta, mrope_sections)
        if kv is None:
            k = apply_mrope(k, positions, theta, mrope_sections)
    elif theta > 0 and kv is None:
        pos2 = positions if positions.ndim == 2 else positions[0]
        q = apply_rope(q, pos2, theta)
        k = apply_rope(k, pos2, theta)
    if impl == "chunked" and causal and kv is None:
        out = _sdpa_chunked(q, k, v, window=window,
                            chunk=min(1024, k.shape[1]))
    else:
        mask = causal_mask(S, k.shape[1], window) if causal else None
        out = _sdpa(q, k, v, mask)
    return out.reshape(B, S, n_heads * hd) @ p["wo"].astype(x.dtype)


def attention_decode(p, x: Array, cache_k: Array, cache_v: Array, pos: Array,
                     *, n_heads: int, n_kv: int, hd: int, theta: float,
                     window: int = 0, mrope_sections: Sequence[int] = ()
                     ) -> Tuple[Array, Array, Array]:
    """One-token decode with a KV cache.

    x: (B, 1, d); cache_k/v: (B, C, K, hd) where C = max context (or window);
    pos: scalar int32 -- the absolute position of the new token.
    Returns (out (B,1,d'), new_cache_k, new_cache_v)."""
    B = x.shape[0]
    q, k, v = _project_qkv(p, x, n_heads, n_kv, hd)
    posb = jnp.full((B, 1), pos, jnp.int32)
    if mrope_sections:
        pos3 = jnp.broadcast_to(pos, (3,))[:, None, None] * jnp.ones((3, B, 1), jnp.int32)
        q = apply_mrope(q, pos3, theta, mrope_sections)
        k = apply_mrope(k, pos3, theta, mrope_sections)
    elif theta > 0:
        q = apply_rope(q, posb, theta)
        k = apply_rope(k, posb, theta)
    C = cache_k.shape[1]
    slot = pos % C if window else jnp.minimum(pos, C - 1)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                           (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                           (0, slot, 0, 0))
    ki = jnp.arange(C)
    if window:
        # ring buffer: before it is warm only slots <= pos are live; after
        # wrap-around every slot holds one of the last C tokens.
        valid = (ki <= pos) | (pos >= C)
    else:
        valid = ki <= pos
    mask = valid[None, None, None, None, :]  # (1,1,1,1,C)
    out = _sdpa(q, cache_k.astype(q.dtype), cache_v.astype(q.dtype), mask)
    out = out.reshape(B, 1, n_heads * hd) @ p["wo"].astype(x.dtype)
    return out, cache_k, cache_v


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------

def mlp_init(key, d: int, ff: int) -> Tuple[Dict[str, Array], Dict[str, P]]:
    ks = jax.random.split(key, 3)
    params = {
        "wg": _init(ks[0], (d, ff)),
        "wu": _init(ks[1], (d, ff)),
        "wd": _init(ks[2], (ff, d), scale=1.0 / math.sqrt(ff)),
    }
    specs = {
        "wg": auto_spec((d, ff), prefer=(1,)),
        "wu": auto_spec((d, ff), prefer=(1,)),
        "wd": auto_spec((ff, d), prefer=(0,)),
    }
    return params, specs


def swiglu(p, x: Array) -> Array:
    g = jax.nn.silu(x @ p["wg"].astype(x.dtype))
    u = x @ p["wu"].astype(x.dtype)
    return (g * u) @ p["wd"].astype(x.dtype)


def gelu_mlp(p, x: Array) -> Array:
    h = jax.nn.gelu(x @ p["wg"].astype(x.dtype) + 0.0)
    return h @ p["wd"].astype(x.dtype)
