"""minicpm-2b [arXiv:2404.06395]: llama-like dense arch trained with the WSD
(warmup-stable-decay) schedule -- wired to repro.optim.schedules.wsd in the
train driver.

40L x d2304, 36 heads MHA (kv=36: neither divides the 16-way model axis, so
attention projections shard on their divisible dim per auto_spec), ff=5760,
vocab 122753, tied embeddings."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b", family="dense",
        n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
        d_ff=5760, vocab=122753, head_dim=64,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minicpm-smoke", family="dense",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
        d_ff=512, vocab=1024, head_dim=64,
        tie_embeddings=True,
    )
