"""The paper's experiment on an actual device mesh, declared as a spec:
EF-BV vs EF21 vs DIANA on heterogeneous logistic regression, with the
compressed aggregation running through the SAME shard_map trainer used for
LM training (not the vmap reference).  8 fake XLA devices; bits-on-the-wire
accounting included.

The whole cross-product -- compressor, algorithm mode, backend, mesh --
lives in ONE :class:`repro.core.ExperimentSpec`; ``build(spec)`` hands back
the trainer (``run.train_step`` dispatches shard_map vs FSDP), the state
init/shardings, and the exact wire accounting.

    PYTHONPATH=src python examples/distributed_logreg.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses  # noqa: E402
import sys  # noqa: E402

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import ExperimentSpec, build  # noqa: E402
from repro.optim import sgd, constant  # noqa: E402
from repro.problems import LogReg, make_synthetic  # noqa: E402


def main():
    d = 64
    spec = ExperimentSpec(compressor="comp:1,32", backend="shard_map",
                          problem="logreg", mesh="8x1", n=8, d=d,
                          steps=2000, seed=0)

    A, b = make_synthetic(jax.random.key(0), N=800, d=d)
    prob = LogReg.split(A, b, n=spec.n, mu_reg=0.1)
    x_star, f_star = prob.solve()
    rounds = spec.steps
    bits_per_round = 32 * 2 * 1  # k=1: one (index, value) pair per worker
    for mode in ["efbv", "ef21", "diana"]:
        run = build(dataclasses.replace(spec, mode=mode))
        mesh = run.make_mesh()
        # run.algo carries the auto-tuned (lam*, nu*); the stepsize needs the
        # problem's smoothness constants on top (Thm 1)
        from repro.core import tune_for
        t = tune_for(run.compressor, d, run.n, mode=mode, L=prob.L(),
                     Ltilde=prob.L_tilde())
        opt = sgd(constant(t.gamma))

        def loss_fn(params, batch):
            x = params["x"]
            z = -batch["b"][0] * (batch["A"][0] @ x)
            loss = jnp.mean(jnp.logaddexp(0.0, z)) + 0.05 * jnp.sum(x * x) * 2
            return loss, {}

        params = {"x": jnp.zeros(d)}
        state = run.init_state(params, opt, mesh)
        sh = run.state_shardings(mesh, {"x": P(None)}, state)
        state = jax.tree.map(lambda a, s: jax.device_put(a, s), state, sh)
        batch = {
            "A": jax.device_put(prob.A[:, None], NamedSharding(mesh, P("data"))),
            "b": jax.device_put(prob.b[:, None], NamedSharding(mesh, P("data"))),
        }
        step = run.train_step(loss_fn, opt, mesh)
        key = jax.random.key(1)
        for i in range(rounds):
            state, metrics = step(state, batch, jax.random.fold_in(key, i))
        gap = float(prob.f(state.params["x"]) - f_star)
        print(f"{mode:6s} lam={run.algo.lam:.4f} nu={run.algo.nu:.4f} "
              f"gamma={t.gamma:.2e} f-f*={gap:.3e} after "
              f"{rounds * bits_per_round} bits/worker")


if __name__ == "__main__":
    main()
