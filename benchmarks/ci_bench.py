"""The pinned CI bench: writes BENCH_perf.json and BENCH_bits.json at the
repo root (the bench trajectory that CI uploads as an artifact and commits
on main; `make bench` produces the identical files locally).

    PYTHONPATH=src:. python -m benchmarks.ci_bench [--out-dir .]

Two files, two kinds of signal:

* BENCH_perf.json -- measured on this host (noisy across machines, a
  trajectory within one runner class): steps/sec + compile time of the
  pinned smoke train-step (benchmarks/perf_iter.py::SMOKE), us/call of the
  fused-vs-unfused wire pack, and HLO byte counts (compiled train step +
  AOT TPU exports of the three fused kernels) as a code-size trajectory.

* BENCH_bits.json -- exact and machine-independent: measured payload bytes
  == bits/8 for every registered wire codec, and the bidirectional
  up+down accounting (uplink x n + ONE broadcast) for pinned combos,
  including the acceptance row named `qsgd16_both_ways` whose ratio vs
  dense fp32 both ways must stay <= 0.35 (also pinned by
  tests/test_bidirectional.py).  The `serve_delta` table accounts the
  compressed model-push envelope of the serving protocol, gated at
  <= 0.35x a full checkpoint for the committed qsgd:16 downlink; the
  BENCH_perf.json `serve_fleet` row carries the measured fleet tok/s and
  hot-swap latency for the same spec.  The `zoo_scaling` table (both files;
  benchmarks/zoo_scaling.py) carries the model-scale rows: every committed
  fine-tune spec (examples/specs/finetune_moe.json + zoo_*_fsdp.json, >=3
  model families incl. MoE and mamba2) measured under its compressed FSDP
  wire -- exact up+down bits per round in BENCH_bits.json (with the MoE
  expert-sparsity gate: expert-leaf uplink <= 0.5x the dense block-top-k
  budget) and steps/sec through the staged fine-tune harness in
  BENCH_perf.json.

Since schema 2, every row is KEYED by the stable fingerprint of the
canonical repro.core.ExperimentSpec it measures (the human-readable
compressor/downlink specs stay inside the row): within each table, a row
with the same key across commits measures the same experiment by
construction.  The two tables are two MEASUREMENTS -- per-worker codec
payload vs whole bidirectional round -- so the same experiment (e.g. an
uplink codec with the dense broadcast) may legitimately appear in both
under the same key; duplicates WITHIN a table are rejected.
"""

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")
if "XLA_FLAGS" not in os.environ:
    # the smoke train-step runs on a 2x2 mesh of fake host devices
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import argparse      # noqa: E402
import json          # noqa: E402
import platform      # noqa: E402


D_BITS = 1 << 16  # codec accounting vector size (matches compressor_bench)
N_WORKERS = 8     # uplink fan-in for the bidirectional combos

# (name, uplink spec, downlink spec or None=dense broadcast) -- pinned; the
# acceptance row is qsgd16_both_ways
BIDIR_COMBOS = [
    ("block_topk_up_dense_down", "block_topk:1024,16", None),
    ("block_topk_up_qsgd16_down", "block_topk:1024,16", "qsgd:16"),
    ("qsgd16_both_ways", "qsgd:16", "qsgd:16"),
    ("sign_up_natural_down", "sign", "natural"),
]

CODECS = ["identity", "topk:655", "randk:655", "comp:655,6553",
          "block_topk:1024,16", "sign", "natural", "qsgd:16"]


def _bench_spec(up_spec: str, down_spec=None):
    """The canonical ExperimentSpec of one bench row.  Its stable
    fingerprint is the row KEY in BENCH_bits.json: a row with the same
    fingerprint across commits measures the same experiment, so the bench
    trajectory survives renames and row reordering."""
    from repro.core import ExperimentSpec

    agg = ("dense_psum"
           if len({s.strip() for s in up_spec.split(";")}) > 1
           else "sparse_allgather")
    return ExperimentSpec(compressor=up_spec, downlink=down_spec or "",
                          agg=agg, backend="reference", problem="quadratic",
                          n=N_WORKERS, d=D_BITS, steps=1, seed=0)


def bits_payload():
    import jax.numpy as jnp

    from repro.core import Downlink, make_compressor
    from repro.distributed import wire

    zeros = jnp.zeros((D_BITS,))
    dense = 32 * D_BITS
    codec_rows = {}
    for spec_str in CODECS:
        spec = _bench_spec(spec_str)
        fmt = wire.format_for(make_compressor(spec_str), zeros)
        bits = fmt.bits_per_round()
        codec_rows[spec.fingerprint()] = {
            "compressor": spec_str,
            "payload_bits": bits,
            "payload_bytes": bits // 8,
            "vs_dense_fp32": round(bits / dense, 6),
        }

    combo_rows = {}
    for name, up_spec, down_spec in BIDIR_COMBOS:
        spec = _bench_spec(up_spec, down_spec)
        assert spec.fingerprint() not in combo_rows, (
            f"combo {name!r} duplicates the spec of "
            f"{combo_rows[spec.fingerprint()]['name']!r}: the trajectory "
            "would silently drop one row")
        up = wire.format_for(make_compressor(up_spec), zeros)
        down = (None if down_spec is None else
                Downlink.parse(down_spec).format_for(zeros))
        total = wire.total_round_bits(up, down, n_workers=N_WORKERS)
        dense_both = N_WORKERS * dense + dense
        combo_rows[spec.fingerprint()] = {
            "name": name,
            "uplink_spec": up_spec,
            "downlink_spec": down_spec or "dense_fp32",
            "up_bits": up.bits_per_round(n_workers=N_WORKERS),
            "down_bits": (dense if down is None
                          else down.downlink_bits_per_round()),
            "total_bits": total,
            "vs_dense_both_ways": round(total / dense_both, 6),
        }
    qs = next(r["vs_dense_both_ways"] for r in combo_rows.values()
              if r["name"] == "qsgd16_both_ways")
    assert qs <= 0.35, f"qsgd:16 both ways regressed past 0.35x dense: {qs}"

    # the pytree-native wire row: the committed mixed per-leaf codec spec
    # (examples/specs/tree_mixed_codecs.json) measured on the real qwen2
    # smoke parameter tree, keyed -- like every other row -- by the spec's
    # stable fingerprint.  Exact and machine-independent, and the composed
    # == sum-of-per-leaf invariant the harness pins is asserted here too so
    # the trajectory can never silently depend on it breaking.
    import jax

    from repro.configs import get_smoke_config
    from repro.core import ExperimentSpec
    from repro.models import build_model

    spec_path = os.path.join(os.path.dirname(__file__), os.pardir,
                             "examples", "specs", "tree_mixed_codecs.json")
    with open(spec_path) as f:
        tree_spec = ExperimentSpec.from_dict(json.load(f))
    params = build_model(get_smoke_config(tree_spec.problem)).init(
        jax.random.key(0))
    fmt = wire.tree_format_for(
        make_compressor(tree_spec.compressor), params,
        wire_dtype=tree_spec.wire_dtype,
        rules=wire.parse_leaf_rules(tree_spec.leaf_codecs))
    by_leaf = fmt.bits_by_leaf()
    tree_bits = fmt.bits_per_round()
    assert tree_bits == sum(by_leaf), (
        f"TreeWire composed bits {tree_bits} != sum of per-leaf bits "
        f"{sum(by_leaf)}")
    dense_tree = 32 * sum(int(l.size) for l in jax.tree_util.tree_leaves(
        params))
    tree_rows = {tree_spec.fingerprint(): {
        "name": "tree_mixed_codecs",
        "uplink_spec": tree_spec.compressor,
        "leaf_codecs": tree_spec.leaf_codecs,
        "problem": tree_spec.problem,
        "n_leaves": len(by_leaf),
        "leaf_kinds": sorted({c.kind for c in fmt.leaves}),
        "payload_bits": tree_bits,
        "payload_bytes": tree_bits // 8,
        "sum_of_leaf_bits": sum(by_leaf),
        "vs_dense_fp32": round(tree_bits / dense_tree, 6),
    }}

    # the serve-delta table: exact envelope accounting of the compressed
    # model-push protocol (launch/serve.py) on the committed serve spec's
    # real smoke parameter tree.  One delta push ships push_bits(fmt) --
    # the versioned envelope header + the downlink payload -- vs
    # checkpoint_push_bits(fmt) for shipping the model densely; the
    # acceptance gate pins the committed qsgd:16 downlink at <= 0.35x the
    # full-checkpoint baseline (also pinned by tests/test_serve_delta.py).
    from repro.core import Downlink

    serve_path = os.path.join(os.path.dirname(__file__), os.pardir,
                              "examples", "specs", "serve_delta.json")
    with open(serve_path) as f:
        serve_spec = ExperimentSpec.from_dict(json.load(f))
    serve_params = build_model(get_smoke_config(serve_spec.problem)).init(
        jax.random.key(0))
    serve_dl = Downlink.parse(serve_spec.downlink)
    serve_fmt = serve_dl.serve_format(serve_params,
                                      wire_dtype=serve_spec.wire_dtype)
    delta_bits = wire.push_bits(serve_fmt)
    ckpt_bits = wire.checkpoint_push_bits(serve_fmt)
    ratio = delta_bits / ckpt_bits
    serve_rows = {serve_spec.fingerprint(): {
        "name": "serve_delta_push",
        "downlink_spec": serve_spec.downlink,
        "problem": serve_spec.problem,
        "push_kind": serve_dl.push_kind(serve_spec.wire_dtype),
        "delta_bits_per_push": delta_bits,
        "checkpoint_bits_per_push": ckpt_bits,
        "vs_full_checkpoint": round(ratio, 6),
    }}
    assert serve_spec.downlink == "qsgd:16" and ratio <= 0.35, (
        f"serve delta push regressed past 0.35x a full checkpoint: "
        f"{ratio} ({serve_spec.downlink})")

    # the model-zoo scaling table (benchmarks/zoo_scaling.py): exact
    # up+down bits of every committed fine-tune spec's round on its real
    # smoke parameter tree, keyed by the committed fingerprints.  The MoE
    # gate pins the expert-sparsity contract: with inactive-expert grads
    # zeroed worker-side and the expert leaves on rescaled topk rules, the
    # expert-leaf uplink must cost <= 0.5x the dense block-top-k budget on
    # those same leaves (exactly a/E = 2/4 for the committed granite spec).
    from benchmarks import zoo_scaling

    zoo_bits = zoo_scaling.zoo_bits_rows()
    for row in zoo_bits.values():
        if row["family"] == "moe":
            assert row["expert_leaf_bits"] <= \
                0.5 * row["dense_expert_leaf_bits"], (
                    f"expert-sparse MoE uplink regressed past 0.5x the "
                    f"dense block-top-k budget: {row['expert_leaf_bits']} "
                    f"vs {row['dense_expert_leaf_bits']} bits "
                    f"({row['spec_file']})")
    assert any(r["family"] == "moe" for r in zoo_bits.values()) and \
        any(r["family"] == "ssm" for r in zoo_bits.values()) and \
        len(zoo_bits) >= 3, "the zoo table needs >=3 families incl. moe+ssm"

    return {
        "schema": 2,  # schema 2: rows keyed by ExperimentSpec fingerprint
        "d": D_BITS,
        "n_workers": N_WORKERS,
        "codec_bits_per_round": codec_rows,
        "bidirectional_rounds": combo_rows,
        "tree_wire": tree_rows,
        "serve_delta": serve_rows,
        "zoo_scaling": zoo_bits,
    }


def perf_payload(fast: bool = True):
    import jax

    from benchmarks import compressor_bench, perf_iter

    # key each smoke row by the ACTUAL train-step experiment it measures
    # (same identity scheme as the BENCH_bits.json rows); worker count and
    # tuning dimension come from the canonical shared helpers, so this
    # fingerprint can never drift from the one the train driver embeds
    from repro.configs import get_smoke_config
    from repro.core import ExperimentSpec
    from repro.core.spec import mesh_worker_count
    from repro.launch.train import tuning_dim

    s = perf_iter.SMOKE

    def smoke_fingerprint(pipeline: str = "off",
                          leaf_codecs: str = "") -> str:
        return ExperimentSpec(
            compressor=s["compressor"], agg=s["agg"], downlink=s["downlink"],
            backend="shard_map", problem=s["arch"], smoke=True,
            mesh="x".join(str(x) for x in s["mesh"]),
            n=mesh_worker_count(s["mesh"]),
            d=tuning_dim(get_smoke_config(s["arch"])), steps=s["steps"],
            seed=0, pipeline=pipeline, leaf_codecs=leaf_codecs).fingerprint()

    smoke = perf_iter.smoke_rows()
    # the pipelined smoke row + the perf gate: the depth-1 schedule only
    # removes a data dependence, so its steps/sec must never lose to the
    # sequential row measured in the SAME run.  Both sides re-measure on a
    # losing attempt -- a transiently loaded host slows whichever row it
    # happens to overlap, and one fresh pair beats comparing a noisy row
    # against a stale one.
    smoke_pipe = perf_iter.smoke_rows("depth:1")
    for _ in range(2):
        if smoke_pipe["steps_per_sec"] >= smoke["steps_per_sec"]:
            break
        smoke = perf_iter.smoke_rows()
        smoke_pipe = perf_iter.smoke_rows("depth:1")
    assert smoke_pipe["steps_per_sec"] >= smoke["steps_per_sec"], (
        f"pipelined smoke regressed below the sequential baseline: "
        f"{smoke_pipe['steps_per_sec']} < {smoke['steps_per_sec']} steps/s")
    smoke["spec_fingerprint"] = smoke_fingerprint()
    smoke_pipe["spec_fingerprint"] = smoke_fingerprint("depth:1")

    # the pytree-native wire smoke row + its perf gate: the per-leaf rules
    # swap the big embedding leaf's block top-k for a flat quantizer and
    # stop compressing the tiny norms, so the tree-wire step must never
    # lose to the flat wire measured in the SAME run.  Same re-measure
    # discipline as the pipeline gate above; the flat reference re-measured
    # on a retry travels INSIDE the tree row, leaving the recorded
    # sequential/pipelined pair exactly as gated.
    tree_leaf_codecs = "*embed*=qsgd:16;*norm*=identity"
    flat_ref = smoke
    smoke_tree = perf_iter.smoke_rows(leaf_codecs=tree_leaf_codecs)
    for _ in range(2):
        if smoke_tree["steps_per_sec"] >= flat_ref["steps_per_sec"]:
            break
        flat_ref = perf_iter.smoke_rows()
        smoke_tree = perf_iter.smoke_rows(leaf_codecs=tree_leaf_codecs)
    assert smoke_tree["steps_per_sec"] >= flat_ref["steps_per_sec"], (
        f"per-leaf tree wire regressed below the flat-wire baseline: "
        f"{smoke_tree['steps_per_sec']} < {flat_ref['steps_per_sec']} "
        f"steps/s")
    smoke_tree["spec_fingerprint"] = smoke_fingerprint(
        leaf_codecs=tree_leaf_codecs)
    smoke_tree["flat_steps_per_sec_same_run"] = flat_ref["steps_per_sec"]

    # the replica-fleet serving row: tok/s + hot-swap latency of the
    # committed serve spec (benchmarks/serve_fleet.py), keyed by its
    # fingerprint like every other row.  The bitwise fleet invariant is
    # asserted inside run_fleet, so this row only exists if every replica
    # reconstructed the trainer's w exactly.
    from benchmarks import serve_fleet

    _, sm = serve_fleet.fleet_metrics()
    serve_row = {
        "spec_fingerprint": sm["fingerprint"],
        "replicas": sm["replicas"],
        "pushes": sm["pushes"],
        "requests": sm["requests"],
        "tokens": sm["tokens"],
        "tok_per_s": round(sm["tok_per_s"], 3),
        "swap_ms_max": round(sm["swap_ms_max"], 4),
        "stage_ms_max": round(sm["stage_ms_max"], 4),
    }

    # the model-zoo scaling rows: steps/sec of every committed fine-tune
    # spec through the staged harness under its compressed FSDP wire
    # (benchmarks/zoo_scaling.py), keyed by the committed fingerprints --
    # the model-scale leg of the bench trajectory
    from benchmarks import zoo_scaling

    zoo_rows = zoo_scaling.zoo_perf_rows()

    pack_rows = {}
    for row in compressor_bench.packed_vs_dense(fast=fast):
        key = row["name"].split("/", 1)[1]
        pack_rows[key] = {"us_per_call": row["us_per_call"],
                          "derived": row["derived"]}

    kernel_hlo = {}
    try:
        import functools

        import jax.numpy as jnp
        from jax import export as jexport

        from repro.kernels.pack import (pack_update_pallas,
                                        qsgd_pack_update_pallas,
                                        randk_update_pallas)

        sds = jax.ShapeDtypeStruct((64, 256), jnp.float32)
        idx = jax.ShapeDtypeStruct((32,), jnp.int32)
        norm = jax.ShapeDtypeStruct((1, 1), jnp.float32)
        exports = {
            "block_topk_pack": jexport.export(
                jax.jit(functools.partial(pack_update_pallas, lam=0.9, kb=16,
                                          interpret=False)),
                platforms=["tpu"])(sds, sds),
            "randk_update": jexport.export(
                jax.jit(functools.partial(randk_update_pallas, scale=75.0,
                                          lam=0.9, interpret=False)),
                platforms=["tpu"])(sds, sds, idx),
            "qsgd_pack": jexport.export(
                jax.jit(functools.partial(qsgd_pack_update_pallas, s=16,
                                          lam=0.9, interpret=False)),
                platforms=["tpu"])(sds, sds, sds, norm),
        }
        kernel_hlo = {k: len(e.mlir_module().encode())
                      for k, e in exports.items()}
    except Exception as e:  # jax.export unavailable on some versions
        kernel_hlo = {"skipped": type(e).__name__}

    return {
        "schema": 1,
        "host": {"python": platform.python_version(), "jax": jax.__version__,
                 "machine": platform.machine()},
        "smoke_train_step": smoke,
        "smoke_train_step_pipelined": smoke_pipe,
        "smoke_train_step_tree": smoke_tree,
        "serve_fleet": serve_row,
        "zoo_scaling": zoo_rows,
        "wire_pack_us": pack_rows,
        "kernel_hlo_bytes": kernel_hlo,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=".")
    ap.add_argument("--skip-perf", action="store_true",
                    help="only write the (deterministic) BENCH_bits.json")
    args = ap.parse_args(argv)

    bits = bits_payload()
    path = os.path.join(args.out_dir, "BENCH_bits.json")
    with open(path, "w") as f:
        json.dump(bits, f, indent=1, sort_keys=True)
        f.write("\n")
    qs = next(r["vs_dense_both_ways"]
              for r in bits["bidirectional_rounds"].values()
              if r["name"] == "qsgd16_both_ways")
    print(f"[bench] wrote {path} (qsgd16_both_ways = {qs}x dense up+down)")

    if not args.skip_perf:
        perf = perf_payload()
        path = os.path.join(args.out_dir, "BENCH_perf.json")
        with open(path, "w") as f:
            json.dump(perf, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"[bench] wrote {path} "
              f"(smoke {perf['smoke_train_step']['steps_per_sec']} steps/s, "
              f"pipelined "
              f"{perf['smoke_train_step_pipelined']['steps_per_sec']} "
              f"steps/s, tree "
              f"{perf['smoke_train_step_tree']['steps_per_sec']} steps/s)")


if __name__ == "__main__":
    main()
