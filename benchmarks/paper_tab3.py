"""Paper Table 3: the derived parameter values (eta, omega, omega_av, lam,
nu, r, r_av, sqrt(r_av/r), s*, gamma) for comp-(k, d/2), n = 1000, on each
dataset's dimensionality.  eta/omega/lam/r/r_av/s* depend only on (d, k, k',
n) and must match the paper's printed values exactly; gamma additionally
depends on the (synthetic) data through L, Ltilde."""

from __future__ import annotations

from benchmarks.common import DATASETS, make_problem
from repro.core import CompKK, tune_for

# the paper's printed values for (dataset, k): eta, omega, lam, sqrt(r_av/r)
PAPER = {
    ("mushrooms", 1): (0.707, 55.0, 5.32e-3, 0.746),
    ("phishing", 1): (0.707, 33.0, 8.85e-3, 0.731),
    ("a9a", 1): (0.710, 60.0, 4.83e-3, 0.752),
    ("w8a", 1): (0.707, 149.0, 1.96e-3, 0.806),
    ("mushrooms", 2): (0.707, 27.0, 1.08e-2, 0.727),
}


def run(fast: bool = True, n: int = 1000):
    rows = []
    for (name, k), (eta_p, om_p, lam_p, ratio_p) in PAPER.items():
        d = DATASETS[name]["d"]
        comp = CompKK(k, d // 2)
        t = tune_for(comp, d, n, mode="efbv")
        ok = (abs(t.eta - eta_p) < 5e-3 and abs(t.omega - om_p) < 0.51
              and abs(t.lam - lam_p) / lam_p < 0.02
              and abs(t.speedup_vs_ef21 - ratio_p) < 0.01)
        rows.append({
            "name": f"tab3/{name}/k{k}",
            "us_per_call": "",
            "derived": f"eta={t.eta:.3f};omega={t.omega:.1f};"
                       f"omega_av={t.omega_av:.3f};lam={t.lam:.3e};nu={t.nu:.3f};"
                       f"r={t.r:.4f};r_av={t.r_av:.3f};"
                       f"sqrt_rav_r={t.speedup_vs_ef21:.3f};s={t.s:.3e};"
                       f"matches_paper={ok}",
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run(fast=True))
