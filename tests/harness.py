"""Differential oracle harness for the sparse wire-format pipeline.

One algorithm, several executions -- the harness runs the SAME EF-BV
recursion through each backend and asserts the trajectories are
*bit-identical*, not merely close:

    oracle     -- pure jnp (jax.lax.top_k pack; the spec),
    interpret  -- fused Pallas pack kernel, interpret mode (CPU),
    pallas     -- fused Pallas pack kernel, compiled (TPU only).

Because the kernel reproduces jax.lax.top_k's selection order exactly
(descending |.|, first-index tie-breaking) and performs the same f32
arithmetic, any divergence -- one ULP, one swapped tie -- is a bug, and
equality composes over steps: if round t is bit-equal, round t+1 sees
identical inputs.  tests/test_wire.py drives this across compressor
configs; test_distributed.py reuses run_with_devices for the
1-vs-8-fake-device leg.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import wire

Array = jax.Array


def available_pack_impls() -> List[str]:
    impls = ["oracle", "interpret"]
    if jax.default_backend() == "tpu":
        impls.append("pallas")
    return impls


def quadratic_grads(n: int, d: int, seed: int = 0):
    """Per-worker gradient oracle of a strongly convex quadratic finite sum:
    grad_i(x) = Q_i x - b_i, returned as an (n, d) stack."""
    key = jax.random.key(seed)
    A = jax.random.normal(key, (n, d, d)) / np.sqrt(d)
    Q = jnp.einsum("nij,nkj->nik", A, A) + 0.5 * jnp.eye(d)
    b = jax.random.normal(jax.random.key(seed + 1), (n, d))

    def grad_fn(x):
        return jnp.einsum("nij,j->ni", Q, x) - b

    return grad_fn


def run_wire_trajectory(kernel: str, *, steps: int, n: int, d: int,
                        block: int, kb: int, lam: float, nu: float,
                        gamma: float, seed: int = 0) -> Dict[str, Array]:
    """EF-BV (Algorithm 1) over the sparse wire with the given pack backend.

    Every worker packs its innovation with wire.fused_pack(kernel=...), the
    master scatter-adds the stacked payload -- exactly the sparse_allgather
    data path.  Returns the full (x, h) trajectory plus the last round's
    payload so callers can check byte accounting.
    """
    lw = wire.LeafWire(shape=(d,), size=d, block=block, kb=kb)
    grad_fn = quadratic_grads(n, d, seed)

    x = jnp.zeros((d,), jnp.float32)
    h = jnp.zeros((n, d), jnp.float32)
    h_avg = jnp.zeros((d,), jnp.float32)
    xs, hs = [], []
    payload: Tuple[Array, Array] = None
    for _ in range(steps):
        g = grad_fn(x)
        vals_i, idx_i, h_i = [], [], []
        for i in range(n):
            (vals, idx), h_new = wire.fused_pack(lw, g[i], h[i], lam,
                                                 kernel=kernel)
            vals_i.append(vals)
            idx_i.append(idx)
            h_i.append(h_new)
        h = jnp.stack(h_i)
        payload = (jnp.stack(vals_i), jnp.stack(idx_i))
        d_bar = wire.scatter_add(lw, *payload) / n
        x = x - gamma * (h_avg + nu * d_bar)
        h_avg = h_avg + lam * d_bar
        xs.append(x)
        hs.append(h)
    return {"x": jnp.stack(xs), "h": jnp.stack(hs), "payload": payload,
            "lw": lw}


def assert_bit_identical(a, b, context: str = ""):
    """Exact equality (values AND dtypes) across two pytrees of arrays."""
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), (context, len(la), len(lb))
    for x, y in zip(la, lb):
        assert jnp.asarray(x).dtype == jnp.asarray(y).dtype, \
            (context, x.dtype, y.dtype)
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=context)
