# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark orchestrator.

  PYTHONPATH=src python -m benchmarks.run [--full]

One section per paper table/figure:
  tab3      -- Table 3 parameter derivations (exact reproduction)
  fig2      -- Figure 2 convex experiments: EF-BV vs EF21 bits-to-accuracy
  fig3      -- Figure/Appx C.3 nonconvex experiments
  n_scaling -- Table 1 row 5: rate improves with n (EF-BV), flat (EF21)
               (benchmarks/zoo_scaling.py; the zoo model-scale rows run in
               benchmarks/ci_bench.py)
  compressor-- compression micro-benchmarks incl. the Pallas kernel
  roofline  -- per-(arch x shape) roofline terms from the dry-run artifacts
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale runs (slower); default is fast mode")
    ap.add_argument("--only", default="",
                    help="comma list of sections (tab3,fig2,fig3,n_scaling,"
                         "compressor,roofline)")
    args = ap.parse_args()
    fast = not args.full
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (compressor_bench, paper_fig2, paper_fig3,
                            paper_tab3, roofline, zoo_scaling)
    from benchmarks.common import emit

    sections = [
        ("tab3", lambda: paper_tab3.run(fast)),
        ("compressor", lambda: compressor_bench.run(fast)),
        ("fig2", lambda: paper_fig2.run(fast)[0]),
        ("fig3", lambda: paper_fig3.run_bench(fast)),
        ("n_scaling", lambda: zoo_scaling.run_bench(fast)),
        ("roofline", lambda: roofline.run(fast)),
    ]
    print("name,us_per_call,derived")
    for name, fn in sections:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # keep the harness running, report the section
            print(f"{name}/ERROR,,{type(e).__name__}:{e}", flush=True)
            continue
        emit(rows)
        print(f"{name}/_elapsed,{(time.time() - t0) * 1e6:.0f},s={time.time() - t0:.1f}",
              flush=True)


if __name__ == "__main__":
    main()
