"""End-to-end behaviour tests for the paper's system: the full train driver
(EF-BV in the loop) and the serve driver, on reduced configs."""

import pytest

from conftest import run_with_devices


@pytest.mark.slow
def test_train_driver_end_to_end():
    """repro.launch.train with EF-BV + sparse wire on a 2x2 mesh learns."""
    out = run_with_devices("""
        from repro.launch.train import main
        loss = main(["--arch", "qwen2-0.5b", "--smoke", "--mesh", "2x2",
                     "--steps", "40", "--global-batch", "8", "--seq", "64",
                     "--lr", "3e-3", "--algo", "efbv",
                     "--compressor", "block_topk:256,64",
                     "--agg", "sparse_allgather", "--log-every", "20"])
        assert loss < 7.0, loss   # started ~log(1024)=6.93, must not blow up
        print("TRAIN_DRIVER_OK", loss)
    """, n_devices=4, timeout=1200)
    assert "TRAIN_DRIVER_OK" in out


@pytest.mark.slow
def test_train_driver_smoke_both_agg_modes():
    """Regression: launch/train.py --smoke must run under BOTH aggregation
    wire formats (the sparse path is the fused-payload pipeline)."""
    out = run_with_devices("""
        from repro.launch.train import main
        for agg in ["dense_psum", "sparse_allgather"]:
            loss = main(["--arch", "qwen2-0.5b", "--smoke", "--mesh", "2x2",
                         "--steps", "2", "--global-batch", "8", "--seq", "32",
                         "--algo", "efbv", "--compressor", "block_topk:256,16",
                         "--agg", agg, "--log-every", "10"])
            assert loss < 8.0, (agg, loss)
            print("AGG_OK", agg)
    """, n_devices=4, timeout=1200)
    assert out.count("AGG_OK") == 2


def test_serve_driver_end_to_end(capsys):
    from repro.launch.serve import main
    gen = main(["--arch", "mamba2-130m", "--smoke", "--batch", "2",
                "--prompt-len", "4", "--gen", "6"])
    assert gen.shape == (2, 6)


def test_serve_driver_zero_prompt_len(capsys):
    """Regression: --prompt-len 0 used to NameError (generation read the
    never-assigned prefill token); an empty prompt now generates from a
    BOS-style zero token."""
    from repro.launch.serve import main
    gen = main(["--arch", "mamba2-130m", "--smoke", "--batch", "2",
                "--prompt-len", "0", "--gen", "4"])
    assert gen.shape == (2, 4)


def test_checkpoint_from_train_driver(tmp_path):
    from repro.launch.train import main
    main(["--arch", "mamba2-130m", "--smoke", "--mesh", "1x1", "--steps", "3",
          "--global-batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path),
          "--log-every", "100"])
    from repro.checkpoint import latest_step, restore_checkpoint, saved_spec
    from repro.launch.train import parse_args, spec_from_args

    assert latest_step(str(tmp_path)) == 3
    # the driver embedded its ExperimentSpec: same flags -> same fingerprint
    args = parse_args(["--arch", "mamba2-130m", "--smoke", "--mesh", "1x1",
                       "--steps", "3", "--global-batch", "2", "--seq", "32"])
    spec = spec_from_args(args, n=1)
    assert saved_spec(str(tmp_path), 3) == spec
    # a different experiment is refused at restore time
    import dataclasses
    import jax.numpy as jnp
    import pytest as _pytest
    other = dataclasses.replace(spec, compressor="qsgd:16")
    with _pytest.raises(ValueError, match="refusing resume"):
        restore_checkpoint(str(tmp_path), 3,
                           {"params": {"x": jnp.zeros(1)}}, spec=other)


@pytest.mark.slow
def test_train_driver_spec_file_smoke(tmp_path):
    """--spec path.json drives the whole run from a serialized
    ExperimentSpec (the CI spec-smoke job runs the committed canonical
    file; this pins the same path with a locally-written spec)."""
    import json
    import os

    spec_path = os.path.join(str(tmp_path), "spec.json")
    with open(spec_path, "w") as f:
        json.dump({"compressor": "qsgd:16", "agg": "sparse_allgather",
                   "downlink": "qsgd:16", "backend": "shard_map",
                   "problem": "qwen2-0.5b", "mesh": "2x2", "n": 2,
                   "d": 131072, "steps": 2, "seed": 0}, f)
    out = run_with_devices(f"""
        from repro.launch.train import main
        loss = main(["--spec", {spec_path!r}, "--smoke", "--global-batch",
                     "8", "--seq", "32", "--log-every", "10"])
        assert loss < 8.0, loss
        print("SPEC_SMOKE_OK", loss)
    """, n_devices=4, timeout=1200)
    assert "SPEC_SMOKE_OK" in out
