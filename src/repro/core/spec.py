"""One declarative, serializable experiment surface for the whole repo.

EF-BV's point is that ONE parameterized object C(eta, omega) unifies what
used to be separate algorithm families (DIANA, EF21).  This module does the
same for the *system*: a frozen :class:`ExperimentSpec` captures the full
execution cross-product --

    uplink compressor (or ';'-separated heterogeneous fleet),
    aggregation wire + value dtype,
    downlink broadcast channel,
    per-round client sampling,
    algorithm parametrization (efbv / ef21 / diana / none),
    problem (built-in convex problems or a model arch),
    backend (reference / shard_map / fsdp),
    steps / seed / stepsize

-- with lossless JSON round-trips, CLI-style parsing, and a stable
:meth:`ExperimentSpec.fingerprint` hash (used by the checkpoint layer to
refuse mismatched resumes and by the CI bench to key its trajectory rows).

:func:`build` turns a spec into a :class:`Run`: the single entry point
whose ``.reference()`` drives :func:`repro.core.efbv.run_reference` (the
one lax.scan driver; the historical run / run_federated / run_bidirectional
entry points are gone), whose ``.train_step()`` dispatches
the shard_map vs FSDP trainers, whose ``.round_bits()`` delegates to the
exact wire accounting, and whose ``.tuned`` delegates to the paper's
auto-tuning (:func:`repro.core.theory.tune_for`).  Every future scenario is
a new spec field, not a fourth driver; the migration table from the old
surface lives in docs/algorithms.md#migrating-to-experimentspec and the
doctested API reference in docs/api.md.
"""

from __future__ import annotations

import dataclasses
import hashlib
import inspect
import json
import math
from typing import Any, Callable, Optional, Sequence, Tuple, Union

SPEC_VERSION = 1

MODES = ("efbv", "ef21", "diana", "none")
AGG_MODES = ("dense_psum", "sparse_allgather")
BACKENDS = ("reference", "shard_map", "fsdp")
WIRE_DTYPES = ("float32", "bfloat16", "float16")  # == wire.VAL_DTYPES
#: problems the reference backend can build itself (anything else is a model
#: arch id from repro.configs.ARCHS, trainer backends only)
REFERENCE_PROBLEMS = ("quadratic", "logreg")

PyTree = Any


class SpecError(ValueError):
    """An ExperimentSpec that does not describe a runnable experiment."""


def _choice(field: str, value: str, allowed: Sequence[str]) -> None:
    if value not in allowed:
        raise SpecError(f"spec.{field} = {value!r} not in {tuple(allowed)}")


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """Parsed form of ``ExperimentSpec.serve``: the replica-fleet serving
    leg.  The trainer's compressed downlink doubles as a model-delta
    streaming protocol (launch/serve.py); this object sizes the simulated
    fleet and its decode workload.

    Fields (','-separated 'key:value' entries in the spec string; any
    subset, missing keys keep the defaults below):

    replicas:  serving replicas reconstructing w from delta pushes.
    slots:     continuous-batching slots per replica (concurrent requests).
    prompt:    prompt length per request (0 = BOS-only generation).
    gen:       tokens generated per request.
    max_len:   decode-cache capacity; prompt + gen must fit.
    pushes:    delta pushes the fleet driver replays per run.
    """

    replicas: int = 2
    slots: int = 2
    prompt: int = 4
    gen: int = 8
    max_len: int = 32
    pushes: int = 3

    def __post_init__(self):
        for f in ("replicas", "slots", "gen", "max_len", "pushes"):
            v = getattr(self, f)
            if not isinstance(v, int) or v <= 0:
                raise SpecError(f"serve.{f} must be a positive int, got "
                                f"{v!r}")
        if not isinstance(self.prompt, int) or self.prompt < 0:
            raise SpecError(f"serve.prompt must be an int >= 0, got "
                            f"{self.prompt!r}")
        if self.prompt + self.gen > self.max_len:
            raise SpecError(
                f"serve.prompt + serve.gen = {self.prompt + self.gen} "
                f"overruns the decode cache (serve.max_len = {self.max_len});"
                " shorten the request or raise max_len")

    @classmethod
    def parse(cls, s: str) -> Optional["ServeSpec"]:
        """'' -> None; 'replicas:4,gen:16' -> ServeSpec(replicas=4, gen=16).
        Unknown keys raise with the known field list."""
        if not s:
            return None
        known = {f.name: f.default for f in dataclasses.fields(cls)}
        kw: dict = {}
        for entry in s.split(","):
            entry = entry.strip()
            if not entry:
                continue
            if ":" not in entry:
                raise SpecError(f"serve entry {entry!r} is not 'key:value'")
            key, val = entry.split(":", 1)
            key = key.strip().replace("-", "_")
            if key not in known:
                raise SpecError(f"unknown serve field {key!r}; known: "
                                f"{sorted(known)}")
            if key in kw:
                raise SpecError(f"serve field {key!r} given twice")
            try:
                kw[key] = int(val)
            except ValueError:
                raise SpecError(f"serve.{key} wants an int, got "
                                f"{val!r}") from None
        return cls(**kw)


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """The full experiment, as data.  Frozen + hashable (jit-static safe);
    every field is a JSON scalar so ``to_json`` / ``from_json`` round-trip
    losslessly and :meth:`fingerprint` is stable across field ordering.

    Fields (all optional -- the defaults are the PR-1 smoke setup):

    compressor:    uplink compressor spec ('qsgd:16', 'block_topk:256,16',
                   ...); ';'-separated specs declare a heterogeneous fleet
                   assigned round-robin to the n workers (needs
                   agg='dense_psum').
    mode:          'efbv' | 'ef21' | 'diana' (the (lam, nu) parametrization,
                   auto-tuned per Remark 1) | 'none' (no compression layer).
    agg:           'dense_psum' | 'sparse_allgather' (the wire the trainers
                   aggregate over; the reference backend always runs the
                   exact dense recursion).
    wire_dtype:    value precision of sparse/dense payloads ('float32' |
                   'bfloat16' | 'float16'; quantized codecs ignore it).
    downlink:      master -> worker broadcast compressor spec (optionally
                   '@lam'); '' = uncompressed dense broadcast.
    participation: 'full' | 'bernoulli:p' | 'fixed:s' per-round client
                   sampling.
    resample:      stochastic local gradients (per-round minibatch
                   resampling) instead of exact/streamed gradients.
    pipeline:      'off' | 'depth:1' -- double-buffer the compressed
                   payload so round t applies the message compressed at
                   round t-1 (the exchange overlaps the next backward
                   pass).  'depth:0' parses and means 'off'.  Trainer
                   backends only; the auto-tuning folds the staleness in
                   via theory.pipeline_eta/omega.
    leaf_codecs:   per-leaf codec rules for the pytree-native wire:
                   ';'-separated 'pattern=compressor_spec' entries matched
                   (fnmatch, first wins) against each leaf's '/'-joined
                   path; a bare compressor spec is the default rule '*',
                   and unmatched leaves keep ``compressor``.  '' = the flat
                   single-codec wire.  (lam, nu) are tuned for the
                   worst-case leaf composition (theory.tune_tree).
    backend:       'reference' (vmap-over-workers exact semantics) |
                   'shard_map' | 'fsdp' (the distributed trainers).
    problem:       'quadratic' | 'logreg' (built-in convex problems, the
                   reference backend) or a model arch id (trainers).
    smoke:         trainer arch problems only: run the reduced (CPU-sized)
                   config of the arch.  Part of the identity -- smoke and
                   full runs of the same arch are DIFFERENT experiments
                   (different model size), so their fingerprints differ
                   and the checkpoint gate keeps them apart.
    mesh:          trainer device mesh, e.g. '2x2' (trailing axes of
                   ('pod', 'data', 'model')); '' for the reference backend.
    n:             number of workers (must equal the mesh's worker-axis
                   product for trainer backends).
    d:             problem dimension; also the dimension the compression
                   constants (eta, omega) are certified at for auto-tuning.
    serve:         replica-fleet serving leg: ','-separated 'key:value'
                   sizing of the simulated fleet fed by the compressed
                   downlink (see :class:`ServeSpec`), e.g.
                   'replicas:4,slots:2,prompt:4,gen:8'.  '' = no serving
                   leg (and the field serializes only when set, so every
                   pre-existing fingerprint is unchanged).  Model-arch
                   problems only -- the built-in convex problems have no
                   decode loop.
    steps:         rounds to run.
    gamma:         stepsize; 0.0 = auto-tune from the theory (Remark 1,
                   built-in problems only).
    seed:          base PRNG seed (problem data, round keys, masks).
    """

    compressor: str = "block_topk:256,16"
    mode: str = "efbv"
    agg: str = "dense_psum"
    wire_dtype: str = "float32"
    downlink: str = ""
    participation: str = "full"
    resample: bool = False
    backend: str = "reference"
    problem: str = "quadratic"
    smoke: bool = False
    mesh: str = ""
    n: int = 8
    d: int = 64
    steps: int = 100
    gamma: float = 0.0
    seed: int = 0
    pipeline: str = "off"
    leaf_codecs: str = ""
    serve: str = ""

    # ---- validation --------------------------------------------------------

    def __post_init__(self):
        from repro.core.compressors import make_compressor
        from repro.core.efbv import Downlink, Participation, Pipeline

        _choice("mode", self.mode, MODES)
        _choice("agg", self.agg, AGG_MODES)
        _choice("backend", self.backend, BACKENDS)
        _choice("wire_dtype", self.wire_dtype, WIRE_DTYPES)
        for f in ("n", "d", "steps"):
            if not isinstance(getattr(self, f), int) or getattr(self, f) <= 0:
                raise SpecError(f"spec.{f} must be a positive int, got "
                                f"{getattr(self, f)!r}")
        if self.gamma < 0:
            raise SpecError(f"spec.gamma must be >= 0 (0 = auto-tune), got "
                            f"{self.gamma}")

        members = self.fleet_specs()
        if not members:
            raise SpecError("spec.compressor is empty")
        for m in members:  # raises ValueError with the registry's message
            make_compressor(m)
        if len(members) > self.n:
            raise SpecError(f"fleet of {len(members)} members for only "
                            f"{self.n} workers")
        if len(set(members)) > 1 and self.agg == "sparse_allgather":
            raise SpecError(
                "heterogeneous fleet + sparse wire: mixed payload shapes "
                "cannot stack over the all-gather; set agg='dense_psum' "
                f"or use a uniform compressor (got {self.compressor!r})")

        if self.smoke and self.problem in REFERENCE_PROBLEMS:
            raise SpecError("spec.smoke selects a model arch's reduced "
                            "config; the built-in problems "
                            f"{REFERENCE_PROBLEMS} are sized by spec.d/n")

        if self.leaf_codecs:
            if len(set(members)) > 1:
                raise SpecError(
                    "spec.leaf_codecs assigns compressors per LEAF of one "
                    "uplink compressor; a heterogeneous fleet assigns them "
                    "per WORKER -- use one or the other (got compressor="
                    f"{self.compressor!r})")
            if self.mode == "none":
                raise SpecError("spec.leaf_codecs configures the compression "
                                "layer's wire; mode='none' has no "
                                "compression layer")
            from repro.distributed import wire
            wire.parse_leaf_rules(self.leaf_codecs)  # raises on a bad rule

        if self.serve:
            ServeSpec.parse(self.serve)  # raises on a bad serve string
            if self.problem in REFERENCE_PROBLEMS:
                raise SpecError(
                    "spec.serve sizes the model-serving fleet; the built-in "
                    f"problems {REFERENCE_PROBLEMS} have no decode loop -- "
                    "set problem to a model arch")

        part = Participation.parse(self.participation)
        if part.kind == "fixed" and part.s > self.n:
            raise SpecError(f"participation 'fixed:{part.s}' needs at least "
                            f"that many workers, spec.n = {self.n}")
        Downlink.parse(self.downlink)  # raises on a bad compressor spec
        pipe = Pipeline.parse(self.pipeline)  # raises on a bad depth spec

        if self.backend == "reference":
            if pipe.depth:
                raise SpecError(
                    "the pipelined schedule double-buffers the trainer's "
                    "wire payload; the reference backend runs the exact "
                    "sequential recursion (set pipeline='off', or "
                    "backend='shard_map' / 'fsdp')")
            if self.problem not in REFERENCE_PROBLEMS:
                raise SpecError(
                    f"the reference backend runs the built-in problems "
                    f"{REFERENCE_PROBLEMS}, got {self.problem!r}; model "
                    "archs need backend='shard_map' or 'fsdp'")
            if self.mesh:
                raise SpecError("spec.mesh is a trainer-backend field; the "
                                "reference backend takes n directly (set "
                                "mesh='')")
            if self.resample and self.problem == "quadratic":
                raise SpecError("the quadratic problem has exact gradients "
                                "only; resample=True needs problem='logreg' "
                                "or a trainer backend")
        else:
            if not self.mesh:
                raise SpecError(f"backend {self.backend!r} needs a device "
                                "mesh, e.g. mesh='2x2'")
            workers = self.mesh_workers()
            if workers != self.n:
                raise SpecError(
                    f"spec.n = {self.n} but mesh {self.mesh!r} has {workers} "
                    "workers (product of the non-'model' axes)")
            if self.problem not in REFERENCE_PROBLEMS:
                from repro.configs import ARCHS
                if self.problem not in ARCHS:
                    raise SpecError(
                        f"unknown problem {self.problem!r}: want one of "
                        f"{REFERENCE_PROBLEMS} or a model arch in "
                        f"{sorted(ARCHS)}")

    # ---- derived views -----------------------------------------------------

    def fleet_specs(self) -> Tuple[str, ...]:
        """The ';'-separated compressor members (length 1 = homogeneous)."""
        return tuple(s.strip() for s in self.compressor.split(";")
                     if s.strip())

    def serve_spec(self) -> Optional["ServeSpec"]:
        """The parsed serving leg (None when ``serve`` is unset)."""
        return ServeSpec.parse(self.serve)

    def mesh_dims(self) -> Tuple[int, ...]:
        try:
            return tuple(int(x) for x in self.mesh.split("x"))
        except ValueError:
            raise SpecError(f"spec.mesh {self.mesh!r} is not an 'AxBxC' "
                            "integer shape") from None

    def mesh_workers(self) -> int:
        """Worker count of the mesh: product of the non-'model' axes
        (axes are the trailing names of ('pod', 'data', 'model'), matching
        repro.launch.mesh.make_mesh)."""
        return mesh_worker_count(self.mesh_dims())

    # ---- serialization -----------------------------------------------------

    def to_dict(self) -> dict:
        d = {"spec_version": SPEC_VERSION, **dataclasses.asdict(self)}
        # Fields added after spec_version 1 shipped serialize only when
        # non-default: 'off' IS the default, so dropping it keeps every
        # pre-existing spec file and fingerprint byte-stable, and the
        # "equal specs <-> equal fingerprints" property still holds.
        if self.pipeline == "off":
            del d["pipeline"]
        if self.leaf_codecs == "":
            del d["leaf_codecs"]
        if self.serve == "":
            del d["serve"]
        return d

    def to_json(self, indent: Optional[int] = 1) -> str:
        """Lossless JSON form (``from_json(to_json(s)) == s``)."""
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True) + "\n"

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentSpec":
        d = dict(d)
        version = d.pop("spec_version", SPEC_VERSION)
        if version != SPEC_VERSION:
            raise SpecError(f"spec_version {version!r} != {SPEC_VERSION} "
                            "(this build cannot read that spec)")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - known)
        if unknown:
            raise SpecError(f"unknown spec fields {unknown}; known: "
                            f"{sorted(known)}")
        return cls(**d)

    @classmethod
    def from_json(cls, text: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(text))

    def fingerprint(self) -> str:
        """Stable 16-hex-digit hash of the spec: independent of field
        ordering and formatting (canonical sorted-key JSON underneath), and
        includes the defaults, so two specs are equal iff their fingerprints
        are.  Checkpoints embed it to refuse mismatched resumes; the CI
        bench keys its rows by it."""
        canon = json.dumps(self.to_dict(), sort_keys=True,
                           separators=(",", ":"))
        return hashlib.sha256(canon.encode()).hexdigest()[:16]

    # ---- CLI-style parsing -------------------------------------------------

    @classmethod
    def parse(cls, argv: Union[str, Sequence[str]]) -> "ExperimentSpec":
        """Build a spec from CLI-style strings.

        Accepts one string or a token list, in '--key value', '--key=value'
        or bare 'key=value' form ('-' and '_' interchangeable in keys);
        boolean fields also take the bare '--resample' flag form.  Unknown
        keys raise with the list of known fields.

            ExperimentSpec.parse("--compressor qsgd:16 --downlink qsgd:16")
            ExperimentSpec.parse(["participation=bernoulli:0.5", "--n", "8"])
        """
        toks = argv.split() if isinstance(argv, str) else list(argv)
        defaults = {f.name: f.default for f in dataclasses.fields(cls)}
        kw: dict = {}
        i = 0
        while i < len(toks):
            tok = toks[i]
            key = tok[2:] if tok.startswith("--") else tok
            if "=" in key:
                key, val = key.split("=", 1)
                i += 1
            else:
                if not tok.startswith("--"):
                    raise SpecError(f"cannot parse token {tok!r}: want "
                                    "'--key value' or 'key=value'")
                nxt = toks[i + 1] if i + 1 < len(toks) else None
                if isinstance(defaults.get(key.replace("-", "_")), bool) and (
                        nxt is None or nxt.startswith("--") or "=" in nxt):
                    val = "true"
                    i += 1
                else:
                    if nxt is None:
                        raise SpecError(f"flag {tok!r} is missing a value")
                    val = nxt
                    i += 2
            key = key.replace("-", "_")
            if key not in defaults:
                raise SpecError(f"unknown spec field {key!r}; known: "
                                f"{sorted(defaults)}")
            kw[key] = _coerce(key, val, defaults[key])
        return cls(**kw)


def mesh_worker_count(dims: Sequence[int]) -> int:
    """The EF-BV worker count of a mesh shape: product of the non-'model'
    axes, where axes are the trailing names of ('pod', 'data', 'model') --
    THE formula (shared with launch.mesh.num_workers semantics), so spec
    validation, the train driver and the CI bench can never drift."""
    dims = tuple(dims)
    axes = ("pod", "data", "model")[-len(dims):]
    return int(math.prod(s for s, a in zip(dims, axes) if a != "model"))


def _coerce(key: str, val: str, default: Any) -> Any:
    if isinstance(default, bool):
        low = str(val).lower()
        if low in ("1", "true", "yes", "on"):
            return True
        if low in ("0", "false", "no", "off"):
            return False
        raise SpecError(f"spec.{key} wants a boolean, got {val!r}")
    try:
        if isinstance(default, int):
            return int(val)
        if isinstance(default, float):
            return float(val)
    except ValueError:
        raise SpecError(f"spec.{key} wants {type(default).__name__}, got "
                        f"{val!r}") from None
    return val


# -----------------------------------------------------------------------------
# built-in reference problems
# -----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Quadratic:
    """Strongly convex quadratic finite sum f_i(x) = 0.5 x'Q_i x - b_i'x
    (the differential harness's trajectory problem, exported so spec-driven
    reference runs and tests/harness.py draw the SAME gradients)."""

    Q: Any  # (n, d, d)
    b: Any  # (n, d)

    @staticmethod
    def make(n: int, d: int, seed: int = 0) -> "Quadratic":
        import jax
        import jax.numpy as jnp
        import numpy as np

        key = jax.random.key(seed)
        A = jax.random.normal(key, (n, d, d)) / np.sqrt(d)
        Q = jnp.einsum("nij,nkj->nik", A, A) + 0.5 * jnp.eye(d)
        b = jax.random.normal(jax.random.key(seed + 1), (n, d))
        return Quadratic(Q=Q, b=b)

    @property
    def n(self) -> int:
        return self.Q.shape[0]

    @property
    def d(self) -> int:
        return self.Q.shape[2]

    def grads(self, x):
        """Per-worker gradients Q_i x - b_i, shape (n, d)."""
        import jax.numpy as jnp

        return jnp.einsum("nij,j->ni", self.Q, x) - self.b

    def f(self, x):
        import jax.numpy as jnp

        quad = 0.5 * jnp.einsum("j,nij,i->n", x, self.Q, x)
        return jnp.mean(quad - self.b @ x)

    def L_i(self):
        import jax.numpy as jnp

        return jnp.linalg.eigvalsh(self.Q)[:, -1]

    def L(self) -> float:
        import jax.numpy as jnp

        return float(jnp.max(self.L_i()))

    def L_tilde(self) -> float:
        import jax.numpy as jnp

        return float(jnp.sqrt(jnp.mean(self.L_i() ** 2)))

    def solve(self):
        """Exact minimizer of the average: mean(Q) x* = mean(b)."""
        import jax.numpy as jnp

        x_star = jnp.linalg.solve(jnp.mean(self.Q, 0), jnp.mean(self.b, 0))
        return x_star, float(self.f(x_star))


# -----------------------------------------------------------------------------
# the Run object: one entry point over every backend
# -----------------------------------------------------------------------------

class Run:
    """A built (executable) experiment.  Construct via :func:`build`.

    Exposes the spec's derived objects (``algo``, ``participation``,
    ``downlink``), the reference driver (:meth:`reference`), the
    distributed trainers (:meth:`train_step`, dispatching shard_map vs
    FSDP), the exact wire accounting (:meth:`round_bits`) and the paper's
    auto-tuning (:attr:`tuned`)."""

    def __init__(self, spec: ExperimentSpec):
        from repro.core.compressors import Identity, make_compressor
        from repro.core.efbv import EFBV, Downlink, Participation, Pipeline

        self.spec = spec
        self.participation: Participation = Participation.parse(
            spec.participation)
        self.downlink: Optional[Downlink] = Downlink.parse(spec.downlink)
        self.pipeline: Pipeline = Pipeline.parse(spec.pipeline)
        members = tuple(make_compressor(s) for s in spec.fleet_specs())
        self.leaf_rules = None
        if spec.leaf_codecs:
            from repro.distributed import wire
            self.leaf_rules = wire.parse_leaf_rules(spec.leaf_codecs)
        if spec.mode == "none":
            self.algo = EFBV(Identity(), lam=1.0, nu=1.0)
        else:
            comp = members if len(members) > 1 else members[0]
            self.algo = EFBV.make(
                comp, d=spec.d, n=spec.n, mode=spec.mode,
                participation=(self.participation.fraction(spec.n)
                               if self.federated else None),
                pipeline=self.pipeline.depth or None,
                leaf_rules=self.leaf_rules)
        self.compressor = self.algo.compressor

    def __repr__(self):
        return (f"Run(fingerprint={self.spec.fingerprint()}, "
                f"backend={self.spec.backend!r}, "
                f"compressor={self.spec.compressor!r})")

    # ---- derived properties ------------------------------------------------

    @property
    def federated(self) -> bool:
        return not self.participation.is_full

    @property
    def n(self) -> int:
        return self.spec.n

    def _tune(self, **kw):
        """The spec's auto-tuning call, shared by :attr:`tuned` and the
        auto-stepsize path: fleet / per-leaf / participation / pipeline
        composition on the SAME compressor objects ``algo`` was tuned
        with."""
        from repro.core import theory

        spec = self.spec
        part = (self.participation.fraction(spec.n) if self.federated
                else None)
        if self.algo.leaf_rules:
            comps = [self.compressor] + [c for _, c in self.algo.leaf_rules]
            return theory.tune_tree(
                [c.eta(spec.d) for c in comps],
                [c.omega(spec.d) for c in comps],
                n=spec.n, aggregate="worst", mode=spec.mode,
                participation=part, pipeline=self.pipeline.depth or None,
                **kw)
        return theory.tune_for(
            self.algo.fleet if self.algo.fleet is not None
            else self.compressor,
            spec.d, spec.n, mode=spec.mode, participation=part,
            pipeline=self.pipeline.depth or None, **kw)

    @property
    def tuned(self):
        """The paper's auto-tuning for this spec (delegates to
        :func:`repro.core.theory.tune_for` -- or ``tune_tree`` under
        per-leaf codec rules: fleet / participation composition included,
        on the SAME compressor objects ``algo`` was tuned with).  None for
        mode='none'."""
        if self.spec.mode == "none":
            return None
        return self._tune()

    # ---- built-in problems -------------------------------------------------

    def problem_instance(self):
        """The built-in reference problem (:class:`Quadratic` or
        :class:`repro.problems.LogReg`), seeded from the spec."""
        spec = self.spec
        if spec.problem == "quadratic":
            return Quadratic.make(spec.n, spec.d, spec.seed)
        if spec.problem == "logreg":
            import jax

            from repro.problems import LogReg, make_synthetic

            A, b = make_synthetic(jax.random.key(spec.seed), N=16 * spec.d,
                                  d=spec.d)
            return LogReg.split(A, b, n=spec.n, mu_reg=0.1)
        raise SpecError(f"problem {spec.problem!r} is a model arch: build "
                        "its loss via repro.models and use .train_step()")

    # ---- the reference driver ----------------------------------------------

    def reference(self, grad_fn: Optional[Callable] = None,
                  x0: Optional[PyTree] = None, *,
                  gamma: Optional[float] = None,
                  prox: Optional[Callable] = None,
                  record: Optional[Callable] = None,
                  key=None):
        """Run the exact reference recursion of this spec: ONE driver for
        plain / federated / bidirectional execution
        (:func:`repro.core.efbv.run_reference`).

        With no arguments the spec is self-contained: the built-in problem
        supplies ``grad_fn``/``x0`` (stochastic minibatch gradients when
        ``spec.resample``) and, when ``spec.gamma == 0``, the auto-tuned
        stepsize of Remark 1.  Custom problems pass ``grad_fn`` (signature
        ``x -> grads`` or ``(key, x) -> grads``), ``x0`` and ``gamma``.
        Returns a :class:`repro.core.efbv.ReferenceRun`.
        """
        import jax
        import jax.numpy as jnp

        from repro.core import efbv

        spec = self.spec
        if grad_fn is not None and gamma is None and spec.gamma == 0.0:
            # auto-tuned stepsizes need the PROBLEM's smoothness constants;
            # silently using the built-in problem's would misstep a custom
            # objective
            raise SpecError("a custom grad_fn needs a stepsize: pass "
                            "gamma= (or set spec.gamma > 0)")
        # (x0 defaults to zeros without touching the problem, so a custom
        # grad_fn never pays for building the built-in problem)
        prob = self.problem_instance() if grad_fn is None else None

        if grad_fn is None:
            if spec.resample:
                batch = max(1, prob.A.shape[1] // 8)
                gf = lambda k, x: prob.minibatch_grads(k, x, batch)  # noqa: E731
            else:
                gf = lambda _k, x: prob.grads(x)  # noqa: E731
        else:
            try:
                takes_key = len(inspect.signature(grad_fn).parameters) >= 2
            except (TypeError, ValueError):
                takes_key = False
            gf = grad_fn if takes_key else (lambda _k, x: grad_fn(x))

        if x0 is None:
            x0 = jnp.zeros((spec.d,), jnp.float32)
        if gamma is None:
            gamma = spec.gamma if spec.gamma > 0.0 else None
        if gamma is None:
            if spec.mode == "none":
                gamma = 1.0 / prob.L()
            else:
                gamma = self._tune(L=prob.L(), Ltilde=prob.L_tilde()).gamma
        if key is None:
            # decorrelated from the problem-data key (jax.random.key(seed))
            key = jax.random.fold_in(jax.random.key(spec.seed),
                                     efbv.REFERENCE_FOLD)

        return efbv.run_reference(
            algo=self.algo, grad_fn=gf, x0=x0, gamma=gamma, steps=spec.steps,
            key=key, n=spec.n, participation=self.participation,
            downlink=self.downlink, prox=prox or efbv.prox_zero,
            record=record, wire_dtype=spec.wire_dtype)

    # ---- the distributed trainers ------------------------------------------

    def make_mesh(self):
        """The spec's device mesh (trainer backends).  The process must
        already expose enough XLA devices -- launch/train.py forces the
        host-device count from the spec before jax initializes."""
        from repro.launch.mesh import make_mesh

        if self.spec.backend == "reference":
            raise SpecError("the reference backend has no device mesh; use "
                            ".reference()")
        return make_mesh(self.spec.mesh_dims())

    def train_step(self, loss_fn: Callable, optimizer, mesh=None,
                   **kw) -> Callable:
        """The jitted distributed train step of this spec, dispatching the
        shard_map vs FSDP trainer from ``spec.backend`` and threading
        agg/wire_dtype/downlink/participation from the spec."""
        from repro.train import make_train_step, make_train_step_fsdp

        if self.spec.backend == "reference":
            raise SpecError("backend='reference' has no distributed trainer:"
                            " use .reference(), or set backend='shard_map' "
                            "or 'fsdp'")
        mesh = self.make_mesh() if mesh is None else mesh
        make = (make_train_step_fsdp if self.spec.backend == "fsdp"
                else make_train_step)
        return make(loss_fn, optimizer, self.algo, mesh,
                    agg_mode=self.spec.agg, wire_dtype=self.spec.wire_dtype,
                    downlink=self.downlink,
                    participation=self.participation,
                    pipeline=self.pipeline, **kw)

    def init_state(self, params: PyTree, optimizer, mesh):
        """TrainState for this spec (bidirectional iff a downlink is set;
        a zero-decoding in-flight payload buffer iff pipelined)."""
        from repro.train import init_train_state

        return init_train_state(params, optimizer, mesh,
                                bidirectional=self.downlink is not None,
                                algo=self.algo, agg_mode=self.spec.agg,
                                wire_dtype=self.spec.wire_dtype,
                                pipeline=self.pipeline)

    def state_shardings(self, mesh, param_specs: PyTree, state):
        """NamedShardings for the TrainState, FSDP-aware per the backend."""
        from repro.train import fsdp_state_shardings, train_state_shardings

        fn = (fsdp_state_shardings if self.spec.backend == "fsdp"
              else train_state_shardings)
        return fn(mesh, param_specs, state)

    # ---- exact wire accounting ---------------------------------------------

    def round_bits(self, tree: Optional[PyTree] = None, *,
                   participants: Optional[float] = None) -> dict:
        """Exact bits one round puts on the wire, both directions, for a
        gradient pytree shaped like ``tree`` (default: the spec's flat
        (d,) problem vector).

        Delegates to :func:`repro.distributed.wire.total_round_bits`
        (uplink x n + ONE broadcast, federated accounting composed into the
        uplink term) and, for heterogeneous fleets, to
        :func:`repro.distributed.wire.fleet_bits_per_round`.  Returns
        ``{'up', 'down', 'total', 'dense_both_ways'}``.
        """
        import jax
        import jax.numpy as jnp

        from repro.distributed import wire

        spec = self.spec
        if tree is None:
            tree = jnp.zeros((spec.d,), jnp.float32)
        n = spec.n
        if participants is None and self.federated:
            participants = self.participation.fraction(n) * n
        down_fmt = (None if self.downlink is None else
                    self.downlink.format_for(tree,
                                             wire_dtype=spec.wire_dtype))
        if self.algo.fleet is not None:
            fmts = wire.fleet_formats(self.algo.fleet, tree,
                                      wire_dtype=spec.wire_dtype)
            up = wire.fleet_bits_per_round(fmts)
            if participants is not None:
                # expected federated fleet round: participation bitmap +
                # each worker's own payload weighted by its inclusion
                # probability E|S_t|/n (uniform across workers for both
                # bernoulli and fixed-size sampling)
                bitmap = 32 * wire.bitmap_words(n)
                per_fleet = sum(f.bits_per_round() for f in fmts)
                if float(participants).is_integer():
                    # exact participant count: stay in int arithmetic (a
                    # float product silently rounds above 2**53)
                    num = int(participants) * per_fleet
                    up = (bitmap + num // n if num % n == 0
                          else bitmap + num / n)
                else:
                    up = bitmap + participants / n * per_fleet
            dense = fmts[0].dense_bits()
            down = (dense if down_fmt is None
                    else down_fmt.downlink_bits_per_round())
            total = up + down
        else:
            up_fmt = wire.tree_format_for(self.compressor, tree,
                                          wire_dtype=spec.wire_dtype,
                                          rules=self.algo.leaf_rules)
            up = up_fmt.bits_per_round(n_workers=n, participants=participants)
            total = wire.total_round_bits(up_fmt, down_fmt, n_workers=n,
                                          participants=participants)
            down = total - up
            dense = up_fmt.dense_bits()
        return {"up": up, "down": down, "total": total,
                "dense_both_ways": n * dense + dense}


def build(spec: ExperimentSpec) -> Run:
    """THE entry point: spec -> executable :class:`Run`.

        >>> from repro.core import ExperimentSpec, build
        >>> run = build(ExperimentSpec(compressor="qsgd:16", n=4, d=256))
        >>> round(run.algo.lam, 4), round(run.algo.nu, 4)
        (0.5, 0.8)
    """
    if isinstance(spec, dict):
        spec = ExperimentSpec.from_dict(spec)
    if not isinstance(spec, ExperimentSpec):
        raise SpecError(f"build() wants an ExperimentSpec (or its dict "
                        f"form), got {type(spec).__name__}")
    return Run(spec)
