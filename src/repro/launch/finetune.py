"""Spec-driven fine-tuning entry point (the staged harness of
repro/train/loop.py behind a CLI).

    PYTHONPATH=src python -m repro.launch.finetune \
        --spec examples/specs/finetune_moe.json --global-batch 8 --seq 32

Unlike launch/train.py (which also folds a flag namespace into a spec),
this driver is spec-file-ONLY: the experiment identity comes entirely from
the committed :class:`repro.core.ExperimentSpec` JSON; the flags below are
runtime knobs (:class:`repro.train.loop.FinetuneSettings`) that never enter
the fingerprint.  ``--processes`` builds the mesh with the multi-host
process-major layout (simulated on CPU fake host devices).  See
docs/finetuning.md.
"""

from __future__ import annotations

import json
import math
import os
import sys

# enough XLA host devices for the spec's mesh BEFORE jax initializes (the
# same pre-import constraint as launch/train.py / launch/dryrun.py)


def _mesh_from_argv(argv):
    try:
        for i, a in enumerate(argv):
            if a == "--spec" or a.startswith("--spec="):
                path = a.split("=", 1)[1] if "=" in a else argv[i + 1]
                with open(path) as f:
                    return json.load(f).get("mesh", "")
    except (IndexError, OSError, ValueError):
        pass  # malformed argv / unreadable spec: argparse or main() reports
    return ""


if "XLA_FLAGS" not in os.environ:
    _shape = _mesh_from_argv(sys.argv)
    if _shape:
        _n = math.prod(int(x) for x in _shape.split("x"))
        if _n > 1:
            os.environ["XLA_FLAGS"] = \
                f"--xla_force_host_platform_device_count={_n}"


def parse_args(argv=None):
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--spec", required=True,
                    help="path to the ExperimentSpec JSON driving the run "
                         "(committed examples live in examples/specs/)")
    ap.add_argument("--steps", type=int, default=0,
                    help="train this many steps instead of spec.steps "
                         "(0 = the spec's own budget; a truncated run keeps "
                         "the spec identity -- it is the same experiment, "
                         "stopped early)")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-4)
    ap.add_argument("--schedule", default="auto",
                    choices=["auto", "cosine", "wsd"])
    ap.add_argument("--eval-every", type=int, default=0,
                    help="held-out eval cadence (0 = final eval only)")
    ap.add_argument("--eval-batches", type=int, default=2)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--heterogeneity", type=float, default=0.5)
    ap.add_argument("--shard-size", type=int, default=64)
    ap.add_argument("--processes", type=int, default=1,
                    help="multi-host-shaped mesh: validate the process-major "
                         "device layout for this many processes "
                         "(launch/mesh.py::make_multihost_mesh; simulated "
                         "with fake host devices on CPU)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--sanitize", action="store_true",
                    help="debug run: jax_debug_nans + Pallas interpret mode "
                         "with out-of-bounds checking "
                         "(repro.analysis.sanitize; see make sanitize-smoke)")
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    if args.sanitize:
        from repro.analysis import sanitize

        sanitize.enable()
        print("[finetune] sanitize mode: jax_debug_nans + Pallas interpret")

    from repro.core import ExperimentSpec, SpecError
    from repro.train.loop import FinetuneLoop, FinetuneSettings

    settings = FinetuneSettings(
        global_batch=args.global_batch, seq_len=args.seq, lr=args.lr,
        schedule=args.schedule, eval_every=args.eval_every,
        eval_batches=args.eval_batches, log_every=args.log_every,
        heterogeneity=args.heterogeneity, shard_size=args.shard_size,
        num_processes=args.processes, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every)
    try:
        with open(args.spec) as f:
            spec = ExperimentSpec.from_json(f.read())
        loop = FinetuneLoop(spec, settings)
    except (SpecError, ValueError, OSError) as e:
        raise SystemExit(f"[finetune] bad experiment spec: {e}")

    loop.setup()
    loop.build_data()
    loop.train(steps=args.steps or None)
    eval_loss = loop.evaluate()
    print(f"[finetune] done: final loss {loop._final['loss']:.4f} "
          f"eval loss {eval_loss:.4f} "
          f"({loop._steps_per_sec:.3f} steps/s)")
    return eval_loss


if __name__ == "__main__":
    main()
