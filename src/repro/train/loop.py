"""The staged fine-tuning harness: ONE :class:`repro.core.ExperimentSpec`
drives setup -> data pipeline -> compressed train loop -> periodic eval for
every model family in the zoo (CPM-2-style finetune staging).

    from repro.core import ExperimentSpec
    from repro.train.loop import FinetuneLoop, FinetuneSettings

    loop = FinetuneLoop(ExperimentSpec.from_json(open(path).read()),
                        FinetuneSettings(global_batch=8, seq_len=32))
    summary = loop.run()          # all four stages
    # or stage by stage: loop.setup(); loop.build_data(); loop.train();
    #                    loop.evaluate()

What the spec buys here over the raw trainers:

* **FSDP + per-leaf compressed wire** -- ``backend='fsdp'`` shards params and
  optimizer state over the worker axes while ``leaf_codecs`` routes every
  parameter leaf through its own uplink codec (``TreeWire`` rules,
  docs/wire_format.md).
* **MoE expert-gradient sparsity** -- for ``family='moe'`` archs the loop
  installs :func:`repro.models.moe.zero_inactive_expert_grads` as the
  trainers' worker-side ``grad_transform``: inactive-expert slabs are pinned
  to exact zero before Algorithm 1 compresses, so a ``topk`` leaf rule on
  the expert leaves (see :func:`expert_sparse_rules`) ships only
  routed-expert entries, with exact ``bits_by_leaf`` accounting.
* **Multi-host-shaped meshes** -- ``FinetuneSettings.num_processes`` builds
  the mesh via :func:`repro.launch.mesh.make_multihost_mesh` (process-major
  device blocks, validated on simulated multi-process CPU).

The runtime-only knobs (batch/seq/lr/eval cadence/checkpoints) live in
:class:`FinetuneSettings` and never enter the spec fingerprint; everything
that changes the experiment's math lives in the spec.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

PyTree = Any

# eval streams draw from a seed decorrelated from the training stream's
# (SyntheticLM folds (seed, step) internally; the xor keeps the two streams
# from ever sharing a fold for any spec.seed)
EVAL_SEED_XOR = 0xE7A1


@dataclasses.dataclass(frozen=True)
class FinetuneSettings:
    """Runtime-only knobs of a fine-tune run.  None of these enter the
    :class:`repro.core.ExperimentSpec` fingerprint -- they change how fast
    or how observably the run executes, never which experiment it is."""

    global_batch: int = 8
    seq_len: int = 32
    lr: float = 1e-4
    schedule: str = "auto"       # auto | cosine | wsd
    eval_every: int = 0          # 0 = final eval only
    eval_batches: int = 2
    log_every: int = 10
    heterogeneity: float = 0.5
    shard_size: int = 64         # for spec.resample fixed-shard minibatches
    num_processes: int = 1       # multi-host-shaped mesh (simulated on CPU)
    ckpt_dir: str = ""
    ckpt_every: int = 0


def expert_sparse_rules(params: PyTree, base, *, n_experts: int,
                        experts_per_tok: int) -> str:
    """The ``leaf_codecs`` rule string that composes MoE expert sparsity
    with the base compressor's budget.

    For every expert leaf (wg/wu/wd under a MoE subtree) the base
    compressor's dense entry budget is rescaled by the routed fraction
    ``experts_per_tok / n_experts`` and spelled as a flat ``topk:K`` rule:
    with inactive-expert gradient slabs pinned to exact zero
    (:func:`repro.models.moe.zero_inactive_expert_grads`), the top-K entries
    of the masked gradient all fall inside routed slabs, so the payload only
    carries routed experts -- at exactly ``a/E`` of the dense-baseline
    expert-leaf bits (both spend 64 bits/entry at float32).

    ``base`` must be a TopK or BlockTopK (the entry-budget compressors);
    other codecs have no per-entry budget to rescale.

    >>> import jax
    >>> from repro.configs import get_smoke_config
    >>> from repro.core.compressors import BlockTopK
    >>> from repro.models import build_model
    >>> cfg = get_smoke_config("granite-moe-3b-a800m")
    >>> params = build_model(cfg).init(jax.random.key(0))
    >>> expert_sparse_rules(params, BlockTopK(256, 16),
    ...                     n_experts=cfg.n_experts,
    ...                     experts_per_tok=cfg.experts_per_tok)
    'layers/moe/wd=topk:8192;layers/moe/wg=topk:8192;layers/moe/wu=topk:8192'
    """
    from repro.core.compressors import BlockTopK, TopK
    from repro.models import moe

    def dense_entries(size: int) -> int:
        if isinstance(base, BlockTopK):
            nb = -(-size // base.block)
            return nb * min(base.kb, base.block)
        if isinstance(base, TopK):
            return min(base.k, size)
        raise ValueError(
            f"expert_sparse_rules rescales an entry budget; base compressor "
            f"{base!r} has none (use topk:k or block_topk:b,kb)")

    leaves: Dict[str, int] = {}

    def walk(node, prefix):
        if not isinstance(node, dict):
            return
        if moe._is_moe_subtree(node):
            for name in moe.EXPERT_LEAVES:
                leaves["/".join(prefix + [name])] = int(node[name].size)
        for k, v in node.items():
            walk(v, prefix + [k])

    walk(params, [])
    if not leaves:
        raise ValueError("expert_sparse_rules: no MoE subtree "
                         "(router + wg/wu/wd) found in the parameter tree")
    rules = []
    for path in sorted(leaves):
        k = max(1, dense_entries(leaves[path]) * experts_per_tok // n_experts)
        rules.append(f"{path}=topk:{k}")
    return ";".join(rules)


def family_batch_extras(cfg, global_batch: int, step: int) -> Dict[str, Any]:
    """The per-family auxiliary batch inputs beyond tokens/labels (the vlm
    vision embeddings, the encdec audio frames); deterministic in ``step``
    so every trainer backend sees identical data."""
    import numpy as np

    if cfg.family == "vlm":
        return {"vision_embeds": np.random.default_rng(step).standard_normal(
            (global_batch, cfg.vision_patches, cfg.d_model),
            dtype=np.float32)}
    if cfg.family == "encdec":
        return {"frames": np.random.default_rng(step).standard_normal(
            (global_batch, cfg.encoder_frames, cfg.d_model),
            dtype=np.float32)}
    return {}


class FinetuneLoop:
    """The four-stage fine-tuning harness of one spec.

    Stages run in order (each checks its prerequisite): :meth:`setup`
    builds mesh/model/optimizer/state, :meth:`build_data` the train + held-
    out eval streams, :meth:`train` the compressed train loop with periodic
    eval, :meth:`evaluate` the held-out loss.  :meth:`run` chains all four
    and returns the summary dict."""

    def __init__(self, spec, settings: Optional[FinetuneSettings] = None, *,
                 config=None, verbose: bool = True):
        from repro.configs import ARCHS, get_config, get_smoke_config
        from repro.core import SpecError, build

        self.spec = spec
        self.settings = settings or FinetuneSettings()
        self.verbose = verbose
        if spec.backend == "reference":
            raise SpecError(
                "the fine-tune harness drives the distributed trainers; a "
                "backend='reference' spec runs via build(spec).reference()")
        if config is None and spec.problem not in ARCHS:
            raise SpecError(
                f"the fine-tune harness trains model archs {sorted(ARCHS)}; "
                f"problem={spec.problem!r} needs an explicit config=")
        self.cfg = config if config is not None else (
            get_smoke_config(spec.problem) if spec.smoke
            else get_config(spec.problem))
        self.run_obj = build(spec)
        self.mesh = None
        self.data = None
        self.eval_data = None
        self.state = None
        self.history: List[Dict[str, float]] = []

    def _log(self, msg: str):
        if self.verbose:
            print(f"[finetune] {msg}")

    # ---- stage 1: setup ----------------------------------------------------

    def setup(self):
        """Mesh (multi-host-shaped), model, optimizer schedule, sharded
        TrainState and the jitted compressed train step."""
        import jax

        from repro.launch.mesh import make_multihost_mesh, num_workers
        from repro.models import build_model, moe
        from repro.optim import adamw, cosine, wsd

        spec, st = self.spec, self.settings
        run = self.run_obj
        self.mesh = make_multihost_mesh(spec.mesh_dims(),
                                        num_processes=st.num_processes)
        self.n = num_workers(self.mesh)
        self.model = build_model(self.cfg)

        kind = st.schedule
        if kind == "auto":
            kind = "wsd" if spec.problem.startswith("minicpm") else "cosine"
        if kind == "wsd":
            sched = wsd(st.lr, warmup_steps=max(spec.steps // 20, 1),
                        stable_steps=int(spec.steps * 0.7),
                        decay_steps=max(int(spec.steps * 0.25), 1))
        else:
            sched = cosine(st.lr, total_steps=spec.steps,
                           warmup_steps=max(spec.steps // 20, 1))
        self.opt = adamw(sched, weight_decay=0.01)

        self.key = jax.random.key(spec.seed)
        params = self.model.init(self.key)
        state = run.init_state(params, self.opt, self.mesh)
        shardings = run.state_shardings(self.mesh, self.model.param_specs(),
                                        state)
        self.state = jax.tree.map(jax.device_put, state, shardings)

        # the worker-side expert-sparsity hook: enforce exact-zero inactive
        # slabs before Algorithm 1 compresses (the identity under capacity
        # dispatch, and the contract the expert topk leaf rules rely on)
        grad_transform = (moe.zero_inactive_expert_grads
                          if self.cfg.family == "moe" else None)
        loss_fn = self.model.loss
        self.step_fn = run.train_step(loss_fn, self.opt, self.mesh,
                                      grad_transform=grad_transform)
        self._eval_fn = jax.jit(lambda p, b: loss_fn(p, b)[0])

        algo = run.algo
        self._log(f"arch={self.cfg.name} family={self.cfg.family} "
                  f"params~{self.cfg.param_count():,} workers={self.n} "
                  f"backend={spec.backend} mesh={spec.mesh} "
                  f"processes={st.num_processes} algo={spec.mode} "
                  f"lam={algo.lam:.4g} nu={algo.nu:.4g}"
                  + (f" grad_transform=expert_sparsity"
                     if grad_transform else ""))
        self._log(f"spec fingerprint={spec.fingerprint()}")
        rb = self.wire_report()
        if rb:
            self._log(f"wire: up={rb['up']:g} down={rb['down']:g} "
                      f"total={rb['total']:g} bits/round "
                      f"({rb['total'] / max(rb['dense_both_ways'], 1):.4f}x "
                      f"dense both ways)")
        return self

    def wire_report(self) -> Dict[str, float]:
        """Exact up+down bits of one round on this model's parameter tree
        (``{'up','down','total','dense_both_ways'}``; docs/wire_format.md)."""
        if self.state is None:
            raise RuntimeError("wire_report() needs setup() first")
        return self.run_obj.round_bits(self.state.params)

    # ---- stage 2: data pipeline --------------------------------------------

    def build_data(self):
        """Heterogeneous synthetic LM streams: a training stream plus a
        held-out eval stream on a decorrelated seed.  Under a multi-host
        layout each process would feed only its
        :func:`repro.launch.mesh.process_worker_slice` of the global batch;
        the single-process (simulated) harness materializes all of it."""
        from repro.data import SyntheticLM

        spec, st = self.spec, self.settings
        if self.mesh is None:
            self.setup()
        mk = lambda seed: SyntheticLM(  # noqa: E731
            vocab=self.cfg.vocab, seq_len=st.seq_len,
            global_batch=st.global_batch, n_workers=self.n, seed=seed,
            heterogeneity=st.heterogeneity,
            resample_from_shard=spec.resample, shard_size=st.shard_size)
        self.data = mk(spec.seed)
        self.eval_data = mk(spec.seed ^ EVAL_SEED_XOR)
        return self

    def _batch(self, data, step: int):
        import jax

        from repro.data import make_batch_shardings

        batch = make_batch_shardings(self.mesh, data.batch(step))
        for k, v in family_batch_extras(self.cfg, self.settings.global_batch,
                                        step).items():
            batch[k] = jax.device_put(v)
        return batch

    # ---- stage 3: compressed train loop ------------------------------------

    def train(self, steps: Optional[int] = None):
        """The compressed train loop (periodic eval per
        ``settings.eval_every``, checkpoints per ``settings.ckpt_every``)."""
        import jax

        from repro.checkpoint import save_checkpoint

        spec, st = self.spec, self.settings
        if self.data is None:
            self.build_data()
        steps = spec.steps if steps is None else steps
        t0 = time.time()
        metrics = {}
        for step in range(steps):
            batch = self._batch(self.data, step)
            self.state, metrics = self.step_fn(
                self.state, batch, jax.random.fold_in(self.key, step))
            if step % st.log_every == 0 or step == steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                self._log(f"step {step:5d} loss={m['loss']:.4f} "
                          f"|g|={m['g_norm']:.3f} "
                          f"h_res={m['h_residual']:.3f} "
                          f"({(time.time() - t0) / (step + 1):.2f}s/step)")
            if st.eval_every and (step + 1) % st.eval_every == 0:
                self.evaluate(step=step + 1)
            if st.ckpt_dir and st.ckpt_every and (step + 1) % st.ckpt_every == 0:
                save_checkpoint(st.ckpt_dir, step + 1,
                                {"params": self.state.params}, spec=spec)
                self._log(f"checkpoint @ {step + 1}")
        self._final = {k: float(v) for k, v in metrics.items()}
        self._steps_per_sec = steps / max(time.time() - t0, 1e-9)
        if st.ckpt_dir:
            save_checkpoint(st.ckpt_dir, steps,
                            {"params": self.state.params}, spec=spec)
        return self

    # ---- stage 4: eval -----------------------------------------------------

    def evaluate(self, step: Optional[int] = None) -> float:
        """Mean held-out loss over ``settings.eval_batches`` eval batches,
        at the workers' view of the model (the downlink reconstruction ``w``
        under bidirectional compression, the master params otherwise)."""
        import numpy as np

        if self.eval_data is None:
            self.build_data()
        params = (self.state.w if self.state.w is not None
                  else self.state.params)
        losses = [float(self._eval_fn(params, self._batch(self.eval_data, b)))
                  for b in range(self.settings.eval_batches)]
        loss = float(np.mean(losses))
        self.history.append({"step": float(self.state.step),
                             "eval_loss": loss})
        self._log(f"eval @ {int(self.state.step)}: loss={loss:.4f} "
                  f"({self.settings.eval_batches} held-out batches)")
        return loss

    # ---- all four stages ---------------------------------------------------

    def run(self) -> Dict[str, Any]:
        self.setup()
        self.build_data()
        self.train()
        eval_loss = self.evaluate()
        rb = self.wire_report()
        return {
            "fingerprint": self.spec.fingerprint(),
            "arch": self.cfg.name,
            "family": self.cfg.family,
            "final_loss": self._final["loss"],
            "eval_loss": eval_loss,
            "steps_per_sec": round(self._steps_per_sec, 4),
            "round_bits": rb,
        }


def finetune(spec, settings: Optional[FinetuneSettings] = None, *,
             config=None, verbose: bool = True) -> Dict[str, Any]:
    """Run all four stages of :class:`FinetuneLoop`; returns the summary."""
    return FinetuneLoop(spec, settings, config=config, verbose=verbose).run()
