"""Quickstart: EF-BV on distributed logistic regression in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's comp-(k, d/2) compressor, auto-tunes (lam*, nu*, gamma)
from the theory (Remark 1 -- nothing left to tune), and runs Algorithm 1
against EF21 and DIANA on a heterogeneous logistic-regression problem.
"""

import jax
import jax.numpy as jnp

from repro.core import CompKK, EFBV, run, tune_for
from repro.problems import LogReg, make_synthetic

n, d, steps = 100, 64, 3000

# heterogeneous data split across n workers (Appendix C setup)
A, b = make_synthetic(jax.random.key(0), N=1200, d=d)
prob = LogReg.split(A, b, n=n, mu_reg=0.1)
x_star, f_star = prob.solve()

# the paper's compressor: biased AND random -- outside both classical classes
comp = CompKK(1, d // 2)
print(f"comp-(1, {d // 2}): eta={comp.eta(d):.3f} omega={comp.omega(d):.1f} "
      f"(not contractive: eta^2 + omega = {comp.eta(d)**2 + comp.omega(d):.1f} > 1)")

for mode in ["efbv", "ef21", "diana"]:
    tuning = tune_for(comp, d, n, mode=mode, L=prob.L(), Ltilde=prob.L_tilde())
    algo = EFBV(comp, lam=tuning.lam, nu=tuning.nu)
    _, _, gaps = run(
        algo=algo, grad_fn=prob.grads, x0=jnp.zeros(d), gamma=tuning.gamma,
        steps=steps, key=jax.random.key(1), n=n,
        record=lambda x: prob.f(x) - f_star)
    print(f"{mode:6s} lam={tuning.lam:.4f} nu={tuning.nu:.4f} "
          f"gamma={tuning.gamma:.2e}  f-f* after {steps} rounds: "
          f"{float(gaps[-1]):.3e}")

print("\nEF-BV exploits omega_av = omega/n (independent compressors): larger "
      "nu and gamma than EF21,\nwhile still handling the biased compressor "
      "DIANA's classical analysis does not cover.")
