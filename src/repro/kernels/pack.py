"""Pallas TPU kernel: fused compress-AND-pack for the sparse wire format.

The unfused hot path costs three HBM passes and materializes a dense tensor
the theory says should never exist on the wire:

    d      = block_topk(g - h)        # dense (nb, block) write
    h     <- h + lam * d              # dense read + write
    payload = pack(d)                 # dense read, (values, indices) write

This kernel does all three in ONE pass over (g, h): each grid step loads a
(TILE_NB, block) slab of g and h into VMEM, runs the iterative-max top-kb
selection of block_topk.py on delta = g - h, and emits

    values  (TILE_NB, kb)   -- the kept signed deltas, descending |.|,
    indices (TILE_NB, kb)   -- block-LOCAL int32 column indices,
    h_out   (TILE_NB, block)-- h + lam * d,

so HBM traffic is read(g) + read(h) + write(h_out) + write(payload); the
dense d lives only in VMEM.  Selection order matches jax.lax.top_k exactly
(descending magnitude, ties broken by lowest index), which is what makes the
payload bit-identical to the jnp oracle `BlockTopK.encode` -- the
differential harness in tests/harness.py pins this.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.block_topk import TILE_NB

Array = jax.Array


def _pack_update_kernel(g_ref, h_ref, vals_ref, idx_ref, h_out_ref, *,
                        kb: int, lam: float):
    g = g_ref[...]
    h = h_ref[...]
    # subtract in f32: bit-identical between interpret mode and TPU lowering
    delta = g.astype(jnp.float32) - h.astype(jnp.float32)
    mag = jnp.abs(delta)
    rows, block = mag.shape
    # column indices kept in f32: Mosaic (this jaxlib vintage) implements
    # neither integer reductions nor cumsum; f32 is exact for block < 2**24
    cols = jax.lax.broadcasted_iota(jnp.float32, (rows, block), 1)

    # python-unrolled over the (static, small) kb: payload columns are
    # assembled with one concatenate -- loop-carried dynamic_update_slice has
    # no Mosaic lowering, and the unroll keeps everything elementwise+reduce
    selected = jnp.zeros((rows, block), jnp.bool_)
    v_cols, c_cols = [], []
    for _ in range(kb):
        score = jnp.where(selected, -jnp.inf, mag)
        m = jnp.max(score, axis=1, keepdims=True)
        # m != -inf guards the all-selected row (kb == block); spelled as a
        # compare because isfinite has no Pallas TPU lowering
        is_m = (score == m) & (m != -jnp.inf)
        # exact first-index tie-breaking == jax.lax.top_k's stable order:
        # the smallest column index among the maxima
        cmin = jnp.min(jnp.where(is_m, cols, float(block)), axis=1,
                       keepdims=True)
        first = is_m & (cols == cmin)
        v_cols.append(jnp.sum(jnp.where(first, delta, 0.0), axis=1)[:, None])
        c_cols.append(jnp.max(jnp.where(first, cols, 0.0), axis=1)[:, None])
        selected = selected | first

    vals_ref[...] = jnp.concatenate(v_cols, axis=1).astype(vals_ref.dtype)
    idx_ref[...] = jnp.concatenate(c_cols, axis=1).astype(jnp.int32)
    d = jnp.where(selected, delta, 0.0)
    h_out_ref[...] = (h.astype(jnp.float32) + lam * d).astype(h_out_ref.dtype)


def pack_update_pallas(g2d: Array, h2d: Array, lam: float, kb: int, *,
                       interpret: bool = False):
    """g2d/h2d: (nb, block) with nb % TILE_NB == 0, block % 128 == 0.

    Returns (values (nb, kb), indices (nb, kb) int32, h_new (nb, block)).
    """
    nb, block = g2d.shape
    assert nb % TILE_NB == 0 and block % 128 == 0, (nb, block)
    assert 0 < kb <= block, (kb, block)
    grid = (nb // TILE_NB,)
    slab = pl.BlockSpec((TILE_NB, block), lambda i: (i, 0))
    payload = pl.BlockSpec((TILE_NB, kb), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_pack_update_kernel, kb=kb, lam=float(lam)),
        grid=grid,
        in_specs=[slab, slab],
        out_specs=(payload, payload, slab),
        out_shape=(jax.ShapeDtypeStruct((nb, kb), g2d.dtype),
                   jax.ShapeDtypeStruct((nb, kb), jnp.int32),
                   jax.ShapeDtypeStruct((nb, block), h2d.dtype)),
        interpret=interpret,
    )(g2d, h2d)
