"""End-to-end example: fine-tune a ~100M-parameter LM with EF-BV compressed
gradient aggregation on a data x model mesh, driven by ONE declarative
:class:`repro.core.ExperimentSpec`.

    # few-hundred-step run (~100M params; several hours of CPU -- this is the
    # deployment-shaped entry point; on TPU the same command runs per pod):
    PYTHONPATH=src python examples/train_lm.py

    # quick demo (~8M params, minutes on CPU):
    PYTHONPATH=src python examples/train_lm.py --tiny

Everything routes through the staged fine-tune harness
(repro/train/loop.py::FinetuneLoop, docs/finetuning.md): the spec declares
the EF-BV layer (block-top-k compressor, sparse all-gather wire) and the
harness supplies the four stages -- setup, heterogeneous synthetic LM data,
the compressed train loop, and held-out eval -- plus npz checkpointing.
The custom (non-zoo) model config rides in via ``FinetuneLoop(config=...)``;
the committed zoo specs in examples/specs/ need no config at all.
"""

import argparse
import dataclasses
import math
import os
import sys

sys.path.insert(0, "src")

# force enough XLA host devices for the mesh BEFORE jax initializes
if "XLA_FLAGS" not in os.environ:
    _mesh = "4x1"
    if "--mesh" in sys.argv:
        _mesh = sys.argv[sys.argv.index("--mesh") + 1]
    _n = math.prod(int(x) for x in _mesh.split("x"))
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={_n}"

from repro.models.config import ModelConfig  # noqa: E402


def lm100m() -> ModelConfig:
    """~100M-param llama-style config (qwen2-family reduced)."""
    return ModelConfig(
        name="lm100m", family="dense",
        n_layers=12, d_model=512, n_heads=8, n_kv_heads=2,
        d_ff=2048, vocab=32768, head_dim=64,
        qkv_bias=True, tie_embeddings=True,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true", help="~8M params demo")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--mesh", default="4x1")
    args = ap.parse_args()

    from repro.core import ExperimentSpec
    from repro.core.spec import mesh_worker_count
    from repro.train.loop import FinetuneLoop, FinetuneSettings

    cfg = lm100m()
    steps = args.steps or (300 if not args.tiny else 60)
    if args.tiny:
        cfg = dataclasses.replace(cfg, n_layers=4, d_model=256, d_ff=1024,
                                  vocab=4096, name="lm8m")

    dims = [int(x) for x in args.mesh.split("x")]
    spec = ExperimentSpec(
        compressor="block_topk:1024,64", mode="efbv",
        agg="sparse_allgather", backend="shard_map",
        problem="qwen2-0.5b",   # nearest zoo family; the real config rides
        smoke=True,             # in via FinetuneLoop(config=...) below
        mesh=args.mesh, n=mesh_worker_count(dims),
        d=cfg.d_model * cfg.d_ff, steps=steps, seed=0)
    print(f"[train_lm] spec fingerprint={spec.fingerprint()} "
          f"arch={cfg.name} mesh={args.mesh}")

    loop = FinetuneLoop(
        spec,
        FinetuneSettings(global_batch=16, seq_len=256, lr=1e-3,
                         log_every=10, ckpt_dir="/tmp/lm100m_ckpt",
                         ckpt_every=100),
        config=cfg)
    summary = loop.run()
    print(f"[train_lm] final loss {summary['final_loss']:.4f} "
          f"eval loss {summary['eval_loss']:.4f} "
          f"({summary['steps_per_sec']:.3f} steps/s)")


if __name__ == "__main__":
    main()
