"""Distributed (heterogeneous-data) regularized logistic regression.

The paper's experimental problem (Appendix C):

    f_i(x) = (1/N_i) sum_j log(1 + exp(-b_ij <a_ij, x>)) + (mu/2)||x||^2

with the data split across n workers after shuffling, optional overlap factor
xi (each worker holds xi blocks), and smoothness constants

    L_i = mu + (1/(4 N_i)) sum_j ||a_ij||^2,   Ltilde = sqrt(mean L_i^2).

Also supports the paper's nonconvex variant (Appendix C.3):

    f(x) = logistic loss + lam_nc * sum_j x_j^2 / (1 + x_j^2).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def make_synthetic(
    key: Array, *, N: int, d: int, noise: float = 0.2, scale: float = 1.0
) -> Tuple[Array, Array]:
    """LibSVM-like synthetic binary classification data (A, b)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # feature scales spread over two decades -> heterogeneous L_i like real data
    col_scales = jnp.exp(jax.random.uniform(k1, (d,), minval=-1.5, maxval=1.5))
    A = jax.random.normal(k2, (N, d)) * col_scales * scale
    x_true = jax.random.normal(k3, (d,))
    logits = A @ x_true / jnp.sqrt(d)
    flip = jax.random.uniform(k4, (N,)) < noise
    b = jnp.where(flip, -jnp.sign(logits), jnp.sign(logits))
    b = jnp.where(b == 0, 1.0, b)
    return A, b


@dataclasses.dataclass(frozen=True)
class LogReg:
    """Problem container with per-worker data (n, Ni, d) already split."""

    A: Array  # (n, Ni, d)
    b: Array  # (n, Ni)
    mu_reg: float  # strong-convexity constant (the paper uses 0.1)
    lam_nc: float = 0.0  # nonconvex regularizer weight (Appendix C.3)

    @property
    def n(self) -> int:
        return self.A.shape[0]

    @property
    def d(self) -> int:
        return self.A.shape[2]

    # ---- construction ---------------------------------------------------------

    @staticmethod
    def split(A: Array, b: Array, n: int, mu_reg: float = 0.1, *,
              overlap: int = 1, key: Optional[Array] = None,
              lam_nc: float = 0.0) -> "LogReg":
        """Shuffle + split into n blocks; overlap xi assigns xi consecutive
        blocks to each worker (Appendix C.1)."""
        N, d = A.shape
        if key is not None:
            perm = jax.random.permutation(key, N)
            A, b = A[perm], b[perm]
        Ni = N // n  # drop remainder like the paper stores it at the last node
        blocks_A = A[: Ni * n].reshape(n, Ni, d)
        blocks_b = b[: Ni * n].reshape(n, Ni)
        if overlap == 1:
            return LogReg(blocks_A, blocks_b, mu_reg, lam_nc)
        idx = np.stack([(np.arange(overlap) + i) % n for i in range(n)])  # (n, xi)
        Aw = blocks_A[idx].reshape(n, overlap * Ni, d)
        bw = blocks_b[idx].reshape(n, overlap * Ni)
        return LogReg(Aw, bw, mu_reg, lam_nc)

    # ---- smoothness constants (Appendix C.1) -----------------------------------

    def L_i(self) -> Array:
        return self.mu_reg + jnp.sum(self.A**2, axis=(1, 2)) / (4.0 * self.A.shape[1])

    def L_tilde(self) -> float:
        return float(jnp.sqrt(jnp.mean(self.L_i() ** 2)))

    def L_max(self) -> float:
        return float(jnp.max(self.L_i()))

    def L(self) -> float:
        # the paper sets L = Ltilde in its experiments (Appendix C.1)
        return self.L_tilde()

    # ---- objective / gradients ---------------------------------------------------

    def _loss_one(self, x: Array, A: Array, b: Array) -> Array:
        z = -b * (A @ x)
        # numerically-stable log(1+exp(z))
        loss = jnp.mean(jnp.logaddexp(0.0, z))
        reg = 0.5 * self.mu_reg * jnp.sum(x * x)
        if self.lam_nc:
            reg = reg + self.lam_nc * jnp.sum(x**2 / (1.0 + x**2))
        return loss + reg

    def f(self, x: Array) -> Array:
        return jnp.mean(jax.vmap(lambda A, b: self._loss_one(x, A, b))(self.A, self.b))

    def grads(self, x: Array) -> Array:
        """Per-worker gradients, shape (n, d) -- what EF-BV compresses."""
        return jax.vmap(lambda A, b: jax.grad(self._loss_one)(x, A, b))(self.A, self.b)

    def minibatch_grads(self, key: Array, x: Array, batch: int) -> Array:
        """Per-worker STOCHASTIC gradients, shape (n, d): each worker draws a
        uniform (with replacement) minibatch of ``batch`` samples from its own
        shard, the federated stochastic-gradient regime of run_reference.
        Unbiased: E over the draw equals :meth:`grads`."""
        Ni = self.A.shape[1]
        keys = jax.random.split(key, self.n)

        def one(k, A, b):
            idx = jax.random.randint(k, (batch,), 0, Ni)
            return jax.grad(self._loss_one)(x, A[idx], b[idx])

        return jax.vmap(one)(keys, self.A, self.b)

    def grad(self, x: Array) -> Array:
        return jnp.mean(self.grads(x), axis=0)

    # ---- ground truth --------------------------------------------------------------

    def solve(self, steps: int = 4000) -> Tuple[Array, float]:
        """f* via plain (uncompressed) gradient descent with 1/L stepsize +
        final Nesterov polish; good to ~1e-12 relative on these tiny problems."""
        gamma = 1.0 / self.L_max()
        x = jnp.zeros((self.d,))

        def body(carry, _):
            x, y, tprev = carry
            g = self.grad(y)
            x_new = y - gamma * g
            tnew = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * tprev**2))
            y_new = x_new + (tprev - 1.0) / tnew * (x_new - x)
            return (x_new, y_new, tnew), None

        (x, _, _), _ = jax.lax.scan(body, (x, x, jnp.ones(())), None, length=steps)
        return x, float(self.f(x))
