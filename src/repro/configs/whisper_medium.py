"""whisper-medium [arXiv:2212.04356]: encoder-decoder audio model.

24 encoder + 24 decoder layers, d1024, 16 heads (MHA: kv=16), ff=4096,
vocab 51865.  The conv/mel frontend is a stub per the assignment carve-out:
batches carry (B, 1500, d) precomputed frame embeddings.  Adaptations noted
in DESIGN.md: SwiGLU MLP + RMSNorm in place of GELU/LayerNorm, sinusoidal
positions both sides.  long_500k is skipped for this arch (enc-dec with
cross-attention; see DESIGN §6)."""

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", family="encdec",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab=51865, head_dim=64,
        encoder_layers=24, encoder_frames=1500,
        rope_theta=0.0,  # sinusoidal absolute positions, no RoPE
        frontend="audio",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="encdec",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab=1024, head_dim=32,
        encoder_layers=2, encoder_frames=64,
        rope_theta=0.0, frontend="audio",
    )
