"""The repo-specific rules (docs/static_analysis.md has the catalog).

Each rule encodes one invariant the EF-BV reproduction otherwise enforces
by reviewer folklore:

* ``prng-reuse``                 -- compressor-independence discipline (the
  omega/n variance reduction needs independent draws; a silently reused key
  correlates workers without failing a test), plus the named ``*_FOLD``
  registry of core/efbv.py for stream separation.
* ``low-precision-accumulation`` -- the mamba2 batch-invariance bug class:
  matmuls/reductions over bf16/f16 operands accumulate in bf16 unless
  ``preferred_element_type`` / an f32 upcast is given.
* ``hot-path-ravel``             -- ravel/unravel in kernels/, distributed/,
  train/ is a wasted HBM pass; the pytree-native wire exists to avoid it.
* ``spec-fingerprint-stability`` -- ExperimentSpec/ServeSpec fields must be
  frozen scalars, and every post-v1 field must serialize-to-nothing at its
  default so pre-existing fingerprints stay byte-identical.
* ``pallas-kernel-hygiene``      -- kernels must not close over enclosing
  function state (tracers), must declare in_specs/out_specs, and must not
  build f64 values from python floats.
* ``shard-map-spec-consistency`` -- literal in_specs/out_specs arity vs the
  callee signature; axis names vs the ('pod', 'data', 'model') mesh.
"""

from __future__ import annotations

import ast
import builtins
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.framework import Finding, Module, rule

# --------------------------------------------------------------------------
# shared AST helpers
# --------------------------------------------------------------------------


def _dotted(node: ast.AST) -> Optional[str]:
    """'jax.random.normal' for nested Attributes, 'self.key' etc; None if
    the expression is not a plain dotted name."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _kwarg(call: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _iter_scopes(tree: ast.Module):
    """Yield (scope_node, body) for the module and every function."""
    yield tree, tree.body
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node, node.body


def _scope_nodes(scope: ast.AST):
    """All nodes belonging to ``scope`` itself, not descending into nested
    function/class scopes (those are visited as scopes of their own)."""
    stack = list(ast.iter_child_nodes(scope))
    while stack:
        n = stack.pop()
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.ClassDef)):
            continue
        yield n
        stack.extend(ast.iter_child_nodes(n))


def _stmt_calls(stmt: ast.stmt) -> List[ast.Call]:
    """All calls inside a simple statement, in source order."""
    calls = [n for n in ast.walk(stmt) if isinstance(n, ast.Call)]
    calls.sort(key=lambda c: (c.lineno, c.col_offset))
    return calls


# --------------------------------------------------------------------------
# R1: prng-reuse
# --------------------------------------------------------------------------

_SAMPLERS = frozenset({
    "normal", "uniform", "bernoulli", "randint", "permutation", "choice",
    "bits", "categorical", "gumbel", "exponential", "truncated_normal",
    "laplace", "rademacher", "beta", "gamma", "poisson", "dirichlet",
    "cauchy", "logistic", "maxwell", "multivariate_normal", "orthogonal",
    "t", "loggamma", "chisquare", "geometric", "binomial", "ball",
})
_DERIVERS = frozenset({"key", "PRNGKey", "split", "fold_in", "clone",
                       "wrap_key_data"})
#: fold_in data below this is an index (leaf j, worker i, step t) -- the
#: idiomatic per-element derivation.  At or above it, the literal is a magic
#: stream-separation tag that belongs in core/efbv.py's *_FOLD registry.
_FOLD_LITERAL_FLOOR = 256


def _jr_name(func: ast.expr) -> Optional[str]:
    """The jax.random function name of a call target, or None."""
    if isinstance(func, ast.Attribute) and func.attr in (_SAMPLERS | _DERIVERS):
        base = _dotted(func.value)
        if base and ("random" in base.split(".") or
                     base.split(".")[-1] in ("jr", "jrandom")):
            return func.attr
    return None


def _key_arg(call: ast.Call) -> Optional[ast.expr]:
    if call.args:
        return call.args[0]
    return _kwarg(call, "key")


class _R1State:
    __slots__ = ("status",)

    def __init__(self, status: Optional[Dict[str, Tuple[str, int]]] = None):
        self.status = dict(status or {})  # name -> ("consumed", line)

    def copy(self) -> "_R1State":
        return _R1State(self.status)

    def merge(self, *others: "_R1State") -> None:
        for o in others:
            for name, st in o.status.items():
                if name not in self.status or st[0] == "consumed":
                    self.status[name] = st


def _r1_calls(mod: Module, node: ast.AST, state: _R1State,
              findings: List[Finding], loop_carried: bool) -> None:
    for call in _stmt_calls(node) if isinstance(node, ast.stmt) \
            else sorted((n for n in ast.walk(node)
                         if isinstance(n, ast.Call)),
                        key=lambda c: (c.lineno, c.col_offset)):
        fname = _jr_name(call.func)
        if fname is None:
            continue
        if fname == "fold_in":
            data = call.args[1] if len(call.args) > 1 else _kwarg(call, "data")
            if (isinstance(data, ast.Constant) and type(data.value) is int
                    and data.value >= _FOLD_LITERAL_FLOOR):
                findings.append(mod.finding(
                    "prng-reuse", call,
                    f"literal fold constant {data.value:#x} bypasses the "
                    "registered *_FOLD names (core/efbv.py); give the stream "
                    "a named registry constant"))
            continue  # fold_in derives, it does not consume the base key
        if fname in _SAMPLERS or fname == "split":
            target = _key_arg(call)
            name = _dotted(target) if target is not None else None
            if name is None:
                continue
            prior = state.status.get(name)
            if prior is not None and prior[0] == "consumed":
                where = ("reused across loop iterations"
                         if loop_carried else
                         f"already consumed at line {prior[1]}")
                findings.append(mod.finding(
                    "prng-reuse", call,
                    f"key {name!r} {where} and is consumed again by "
                    f"jax.random.{fname} without an interleaving "
                    "split/fold_in -- correlated draws break the "
                    "compressor-independence the omega/n variance "
                    "reduction relies on"))
            state.status[name] = ("consumed", call.lineno)


def _r1_bind(stmt: ast.stmt, state: _R1State) -> None:
    """Apply a statement's assignment effect on the key-tracking state."""
    targets: List[ast.expr] = []
    value: Optional[ast.expr] = None
    if isinstance(stmt, ast.Assign):
        targets, value = stmt.targets, stmt.value
    elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
        targets, value = [stmt.target], stmt.value
    elif isinstance(stmt, ast.AugAssign):
        targets, value = [stmt.target], None
    if value is None and not targets:
        return
    derives = (isinstance(value, ast.Call)
               and _jr_name(value.func) in _DERIVERS)
    for t in targets:
        names = ([_dotted(e) for e in t.elts]
                 if isinstance(t, (ast.Tuple, ast.List)) else [_dotted(t)])
        for n in names:
            if n is None:
                continue
            if derives:
                state.status.pop(n, None)  # fresh key
            else:
                state.status.pop(n, None)  # rebound to a non-key value


def _terminates(stmts: List[ast.stmt]) -> bool:
    """Does this branch body unconditionally leave the join point?"""
    return bool(stmts) and isinstance(
        stmts[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break))


def _r1_block(mod: Module, stmts: Iterable[ast.stmt], state: _R1State,
              findings: List[Finding], loop_carried: bool = False) -> None:
    for stmt in stmts:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue  # separate scope, scanned on its own
        if isinstance(stmt, ast.If):
            _r1_calls(mod, stmt.test, state, findings, loop_carried)
            b1, b2 = state.copy(), state.copy()
            _r1_block(mod, stmt.body, b1, findings, loop_carried)
            _r1_block(mod, stmt.orelse, b2, findings, loop_carried)
            # a branch ending in return/raise/continue/break never reaches
            # the join: an `if cond: return sampler(key)` guard does NOT
            # poison the fall-through path's use of the key
            live = [b for b, stmts in ((b1, stmt.body), (b2, stmt.orelse))
                    if not _terminates(stmts)]
            if live:
                state.status = live[0].status
                state.merge(*live[1:])
            continue
        if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
            head = stmt.iter if isinstance(stmt, (ast.For, ast.AsyncFor)) \
                else stmt.test
            _r1_calls(mod, head, state, findings, loop_carried)
            if isinstance(stmt, (ast.For, ast.AsyncFor)):
                _r1_bind(ast.Assign(targets=[stmt.target],
                                    value=ast.Constant(value=None)), state)
            # pass 1: findings within a single iteration
            body_state = state.copy()
            _r1_block(mod, stmt.body, body_state, findings, loop_carried)
            # pass 2 (fixpoint trick): a key consumed in iteration 1 and not
            # re-derived before its next consumption fires here -- the
            # loop-carried reuse a single linear pass cannot see
            seen = {(f.line, f.col) for f in findings}
            extra: List[Finding] = []
            tail_state = body_state.copy()
            _r1_block(mod, stmt.body, tail_state, extra, loop_carried=True)
            findings.extend(f for f in extra
                            if (f.line, f.col) not in seen)
            _r1_block(mod, stmt.orelse, tail_state, findings, loop_carried)
            state.merge(tail_state)
            continue
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                _r1_calls(mod, item.context_expr, state, findings,
                          loop_carried)
            _r1_block(mod, stmt.body, state, findings, loop_carried)
            continue
        if isinstance(stmt, ast.Try):
            _r1_block(mod, stmt.body, state, findings, loop_carried)
            for h in stmt.handlers:
                _r1_block(mod, h.body, state.copy(), findings, loop_carried)
            _r1_block(mod, stmt.orelse, state, findings, loop_carried)
            _r1_block(mod, stmt.finalbody, state, findings, loop_carried)
            continue
        _r1_calls(mod, stmt, state, findings, loop_carried)
        _r1_bind(stmt, state)


@rule("prng-reuse",
      "a jax.random key consumed twice without an interleaving split/"
      "fold_in, and literal fold constants bypassing the *_FOLD registry")
def check_prng_reuse(mod: Module) -> List[Finding]:
    findings: List[Finding] = []
    for _scope, body in _iter_scopes(mod.tree):
        _r1_block(mod, body, _R1State(), findings)
    return findings


# --------------------------------------------------------------------------
# R2: low-precision-accumulation
# --------------------------------------------------------------------------

_LOW_DTYPES = {"bfloat16", "float16", "f16", "bf16", "half"}
_HIGH_DTYPES = {"float32", "float64", "f32", "f64", "single", "double"}
_CONTRACTIONS = {"dot", "matmul", "einsum", "tensordot", "vdot", "inner"}
_REDUCTIONS = {"sum", "mean", "cumsum", "nansum", "average"}


def _dtype_class(node: Optional[ast.expr]) -> Optional[str]:
    """'low' / 'high' / None for a dtype-like expression."""
    if node is None:
        return None
    name = None
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        name = node.value
    elif isinstance(node, (ast.Name, ast.Attribute)):
        d = _dotted(node)
        name = d.split(".")[-1] if d else None
    if name in _LOW_DTYPES:
        return "low"
    if name in _HIGH_DTYPES:
        return "high"
    return None


def _is_lowp(e: ast.expr, tainted: Set[str]) -> bool:
    if isinstance(e, ast.Name):
        return e.id in tainted
    if isinstance(e, ast.Attribute):
        return _dotted(e) in tainted
    if isinstance(e, ast.Call):
        if isinstance(e.func, ast.Attribute) and e.func.attr == "astype":
            cls = _dtype_class(e.args[0] if e.args else None)
            if cls == "low":
                return True
            if cls == "high":
                return False
            return False  # dynamic dtype (.astype(x.dtype)): not statically low
        cls = _dtype_class(_kwarg(e, "dtype"))
        if cls == "low":
            return True
        if cls == "high":
            return False
        return False
    if isinstance(e, ast.BinOp):
        return _is_lowp(e.left, tainted) or _is_lowp(e.right, tainted)
    if isinstance(e, ast.UnaryOp):
        return _is_lowp(e.operand, tainted)
    if isinstance(e, ast.Subscript):
        return _is_lowp(e.value, tainted)
    if isinstance(e, (ast.Tuple, ast.List)):
        return any(_is_lowp(x, tainted) for x in e.elts)
    return False


@rule("low-precision-accumulation",
      "matmul/einsum/sum/mean over bf16/f16 operands without "
      "preferred_element_type or an f32 operand upcast")
def check_low_precision(mod: Module) -> List[Finding]:
    findings: List[Finding] = []
    for scope, _body in _iter_scopes(mod.tree):
        if not isinstance(scope, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Module)):
            continue
        events: List[Tuple[int, int, str, ast.AST]] = []
        for n in _scope_nodes(scope):
            if isinstance(n, ast.Assign):
                events.append((n.lineno, n.col_offset, "assign", n))
            elif isinstance(n, ast.BinOp) and isinstance(n.op, ast.MatMult):
                events.append((n.lineno, n.col_offset, "matmul", n))
            elif isinstance(n, ast.Call):
                events.append((n.lineno, n.col_offset, "call", n))
        events.sort(key=lambda e: (e[0], e[1]))
        tainted: Set[str] = set()
        for _line, _col, kind, n in events:
            if kind == "assign":
                names = []
                for t in n.targets:
                    names.extend([_dotted(e) for e in t.elts]
                                 if isinstance(t, (ast.Tuple, ast.List))
                                 else [_dotted(t)])
                low = _is_lowp(n.value, tainted)
                for nm in names:
                    if nm is None:
                        continue
                    (tainted.add if low else tainted.discard)(nm)
                continue
            if kind == "matmul":
                if _is_lowp(n.left, tainted) or _is_lowp(n.right, tainted):
                    findings.append(mod.finding(
                        "low-precision-accumulation", n,
                        "'@' on a bf16/f16 operand accumulates in low "
                        "precision (the mamba2 batch-invariance bug class); "
                        "upcast the operands to f32 or use "
                        "jax.lax.dot_general with preferred_element_type"))
                continue
            call = n
            fname = None
            if isinstance(call.func, ast.Attribute):
                fname = call.func.attr
            elif isinstance(call.func, ast.Name):
                fname = call.func.id
            if fname in _CONTRACTIONS:
                if _kwarg(call, "preferred_element_type") is not None:
                    continue
                operands = [a for a in call.args
                            if not (isinstance(a, ast.Constant)
                                    and isinstance(a.value, str))]
                if any(_is_lowp(a, tainted) for a in operands):
                    findings.append(mod.finding(
                        "low-precision-accumulation", call,
                        f"{fname} over a bf16/f16 operand without "
                        "preferred_element_type accumulates in low "
                        "precision; pass preferred_element_type=jnp.float32 "
                        "or upcast the operands"))
            elif fname in _REDUCTIONS:
                if _dtype_class(_kwarg(call, "dtype")) == "high":
                    continue
                operands = list(call.args)
                if (isinstance(call.func, ast.Attribute)
                        and _dotted(call.func.value) not in
                        ("jnp", "np", "jax.numpy", "numpy")):
                    operands.append(call.func.value)  # x.sum() method form
                if any(_is_lowp(a, tainted) for a in operands):
                    findings.append(mod.finding(
                        "low-precision-accumulation", call,
                        f"{fname} over a bf16/f16 operand accumulates in "
                        "low precision; pass dtype=jnp.float32 or upcast "
                        "the operand first"))
    return findings


# --------------------------------------------------------------------------
# R3: hot-path-ravel
# --------------------------------------------------------------------------

_HOT_DIRS = {"kernels", "distributed", "train"}


@rule("hot-path-ravel",
      "ravel/ravel_pytree/unravel inside kernels/, distributed/, train/ -- "
      "the wasted-HBM-pass class the pytree-native wire eliminates")
def check_hot_path_ravel(mod: Module) -> List[Finding]:
    if not _HOT_DIRS & set(mod.parts):
        return []
    findings: List[Finding] = []
    for n in ast.walk(mod.tree):
        if not isinstance(n, ast.Call):
            continue
        fname = (n.func.attr if isinstance(n.func, ast.Attribute)
                 else n.func.id if isinstance(n.func, ast.Name) else None)
        if fname and "ravel" in fname:
            findings.append(mod.finding(
                "hot-path-ravel", n,
                f"{fname} in a hot path costs a full dense HBM pass per "
                "call; the per-leaf TreeWire codecs exist so payloads never "
                "round-trip through a flat vector"))
    return findings


# --------------------------------------------------------------------------
# R4: spec-fingerprint-stability
# --------------------------------------------------------------------------

#: the spec_version-1 field set: these serialized from PR 1 on, so they are
#: allowed (required, even) to appear in every to_dict() output.  Any field
#: NOT in this set postdates shipped fingerprints and must delete itself
#: from the dict at its default value.
SPEC_V1_FIELDS = frozenset({
    "compressor", "mode", "agg", "wire_dtype", "downlink", "participation",
    "resample", "backend", "problem", "smoke", "mesh", "n", "d", "steps",
    "gamma", "seed",
})
_SPEC_CLASSES = ("ExperimentSpec", "ServeSpec")


def _is_frozen_dataclass(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        if isinstance(dec, ast.Call):
            name = _dotted(dec.func) or ""
            if name.split(".")[-1] == "dataclass":
                kw = _kwarg(dec, "frozen")
                if isinstance(kw, ast.Constant) and kw.value is True:
                    return True
    return False


def _dataclass_fields(cls: ast.ClassDef) -> List[Tuple[str, Optional[ast.expr],
                                                       ast.AST]]:
    out = []
    for stmt in cls.body:
        if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target,
                                                          ast.Name):
            ann = ast.dump(stmt.annotation)
            if "ClassVar" in ann:
                continue
            out.append((stmt.target.id, stmt.value, stmt))
    return out


def _to_dict_deletes(cls: ast.ClassDef) -> Optional[Dict[str, object]]:
    """field -> compared-default for every ``if self.X == v: del d["X"]``
    guard in the class's to_dict; None when the class has no to_dict."""
    fn = next((s for s in cls.body
               if isinstance(s, ast.FunctionDef) and s.name == "to_dict"),
              None)
    if fn is None:
        return None
    deletes: Dict[str, object] = {}
    for node in ast.walk(fn):
        if not isinstance(node, ast.If):
            continue
        t = node.test
        if not (isinstance(t, ast.Compare) and len(t.ops) == 1
                and isinstance(t.ops[0], ast.Eq)
                and isinstance(t.comparators[0], ast.Constant)):
            continue
        lhs = _dotted(t.left)
        if not (lhs and lhs.startswith("self.")):
            continue
        field = lhs[len("self."):]
        for inner in ast.walk(node):
            if isinstance(inner, ast.Delete):
                for tgt in inner.targets:
                    if (isinstance(tgt, ast.Subscript)
                            and isinstance(tgt.slice, ast.Constant)
                            and tgt.slice.value == field):
                        deletes[field] = t.comparators[0].value
    return deletes


@rule("spec-fingerprint-stability",
      "ExperimentSpec/ServeSpec fields must be frozen hashable scalars and "
      "post-v1 fields must serialize-to-nothing at their defaults")
def check_spec_stability(mod: Module) -> List[Finding]:
    findings: List[Finding] = []
    for cls in ast.walk(mod.tree):
        if not (isinstance(cls, ast.ClassDef) and cls.name in _SPEC_CLASSES):
            continue
        if not _is_frozen_dataclass(cls):
            findings.append(mod.finding(
                "spec-fingerprint-stability", cls,
                f"{cls.name} must be @dataclasses.dataclass(frozen=True): "
                "specs are jit-static and fingerprint-hashed"))
        fields = _dataclass_fields(cls)
        for name, default, node in fields:
            if default is None:
                findings.append(mod.finding(
                    "spec-fingerprint-stability", node,
                    f"{cls.name}.{name} has no default; every spec field "
                    "needs a scalar default so old spec files keep loading"))
            elif not (isinstance(default, ast.Constant)
                      and isinstance(default.value,
                                     (str, int, float, bool, type(None)))):
                findings.append(mod.finding(
                    "spec-fingerprint-stability", node,
                    f"{cls.name}.{name} default is not an immutable JSON "
                    "scalar; mutable/computed defaults break hashing and "
                    "lossless serialization"))
        if cls.name != "ExperimentSpec":
            continue
        deletes = _to_dict_deletes(cls)
        if deletes is None:
            findings.append(mod.finding(
                "spec-fingerprint-stability", cls,
                "ExperimentSpec has no to_dict(): the fingerprint "
                "serialization contract cannot be checked"))
            continue
        for name, default, node in fields:
            if name in SPEC_V1_FIELDS:
                continue
            if name not in deletes:
                findings.append(mod.finding(
                    "spec-fingerprint-stability", node,
                    f"field {name!r} postdates spec_version 1 but to_dict() "
                    "never deletes it at its default -- every pre-existing "
                    "fingerprint and BENCH row key would change; add "
                    f"'if self.{name} == <default>: del d[\"{name}\"]'"))
            elif (isinstance(default, ast.Constant)
                  and deletes[name] != default.value):
                findings.append(mod.finding(
                    "spec-fingerprint-stability", node,
                    f"to_dict() drops {name!r} when it equals "
                    f"{deletes[name]!r} but the field default is "
                    f"{default.value!r}; a default-constructed spec would "
                    "serialize the field and shift every fingerprint"))
    return findings


# --------------------------------------------------------------------------
# R5: pallas-kernel-hygiene
# --------------------------------------------------------------------------

_ARRAY_CTORS = {"zeros": 1, "ones": 1, "array": 1, "asarray": 1, "full": 2,
                "arange": 1}
_BUILTINS = frozenset(dir(builtins))


def _is_kernel_def(fn: ast.AST) -> bool:
    if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return False
    if fn.name.endswith("_kernel"):
        return True
    return any(a.arg.endswith("_ref") for a in fn.args.args)


def _local_names(fn: ast.FunctionDef) -> Set[str]:
    names = {a.arg for a in (fn.args.args + fn.args.kwonlyargs
                             + fn.args.posonlyargs)}
    if fn.args.vararg:
        names.add(fn.args.vararg.arg)
    if fn.args.kwarg:
        names.add(fn.args.kwarg.arg)
    for n in ast.walk(fn):
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store):
            names.add(n.id)
        elif isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and n is not fn:
            names.add(n.name)
        elif isinstance(n, ast.comprehension):
            for t in ast.walk(n.target):
                if isinstance(t, ast.Name):
                    names.add(t.id)
    return names


@rule("pallas-kernel-hygiene",
      "kernels must not close over enclosing-function values (tracers), "
      "must declare in_specs/out_specs, and must not widen to f64")
def check_pallas_hygiene(mod: Module) -> List[Finding]:
    if "kernels" not in mod.parts:
        return []
    findings: List[Finding] = []
    module_names = {n.name for n in mod.tree.body
                    if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.ClassDef))}
    for n in mod.tree.body:
        if isinstance(n, (ast.Import, ast.ImportFrom)):
            for alias in n.names:
                module_names.add((alias.asname or alias.name).split(".")[0])
        elif isinstance(n, ast.Assign):
            for t in n.targets:
                if isinstance(t, ast.Name):
                    module_names.add(t.id)

    # (a) closure-over-tracer proxy: a kernel nested in a function must not
    # read names bound by that enclosing function (pass compile-time
    # constants through functools.partial keywords instead)
    for outer in ast.walk(mod.tree):
        if not isinstance(outer, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        outer_locals = _local_names(outer)
        for stmt in ast.walk(outer):
            if stmt is outer or not _is_kernel_def(stmt):
                continue
            if not any(stmt is s or stmt in ast.walk(s)
                       for s in outer.body):
                continue
            kernel_locals = _local_names(stmt)
            for used in ast.walk(stmt):
                if not (isinstance(used, ast.Name)
                        and isinstance(used.ctx, ast.Load)):
                    continue
                nm = used.id
                if (nm in kernel_locals or nm in module_names
                        or nm in _BUILTINS):
                    continue
                if nm in outer_locals:
                    findings.append(mod.finding(
                        "pallas-kernel-hygiene", used,
                        f"kernel {stmt.name!r} closes over {nm!r} from the "
                        "enclosing function -- traced values leak into the "
                        "kernel; bind compile-time constants via "
                        "functools.partial keyword-only params"))

    # (b) every pallas_call declares its memory layout
    for call in ast.walk(mod.tree):
        if not isinstance(call, ast.Call):
            continue
        fname = (call.func.attr if isinstance(call.func, ast.Attribute)
                 else call.func.id if isinstance(call.func, ast.Name)
                 else None)
        if fname != "pallas_call":
            continue
        for req in ("in_specs", "out_specs"):
            if _kwarg(call, req) is None:
                findings.append(mod.finding(
                    "pallas-kernel-hygiene", call,
                    f"pallas_call without {req}: every ref must declare its "
                    "memory space/tiling (BlockSpec) -- implicit ANY specs "
                    "hide VMEM pressure and break the dense-free proofs"))

    # (c) no f64 construction inside kernel bodies
    for fn in ast.walk(mod.tree):
        if not _is_kernel_def(fn):
            continue
        for n in ast.walk(fn):
            if isinstance(n, (ast.Name, ast.Attribute)):
                d = _dotted(n)
                if d and d.split(".")[-1] in ("float64", "f64", "double"):
                    findings.append(mod.finding(
                        "pallas-kernel-hygiene", n,
                        "f64 inside a kernel: TPU has no f64 vector unit "
                        "and interpret mode would silently diverge"))
            elif isinstance(n, ast.Call):
                fname = (n.func.attr if isinstance(n.func, ast.Attribute)
                         else n.func.id if isinstance(n.func, ast.Name)
                         else None)
                if fname not in _ARRAY_CTORS:
                    continue
                dtype_pos = _ARRAY_CTORS[fname]
                has_dtype = (len(n.args) > dtype_pos
                             or _kwarg(n, "dtype") is not None)
                has_float = any(isinstance(a, ast.Constant)
                                and type(a.value) is float
                                for a in ast.walk(n))
                if not has_dtype and has_float:
                    findings.append(mod.finding(
                        "pallas-kernel-hygiene", n,
                        f"{fname} from a python float literal without an "
                        "explicit dtype widens to f64 under x64; pass "
                        "dtype= explicitly"))
    return findings


# --------------------------------------------------------------------------
# R6: shard-map-spec-consistency
# --------------------------------------------------------------------------

#: the repo's mesh axis vocabulary (launch/mesh.py: trailing axes of this
#: tuple; 'model' is the non-worker axis)
MESH_AXES = ("pod", "data", "model")
_COLLECTIVES = {"psum", "pmean", "pmax", "pmin", "all_gather", "axis_index",
                "ppermute", "pshuffle", "all_to_all", "psum_scatter"}


def _spec_strings(node: ast.expr) -> List[ast.Constant]:
    """String constants inside P(...) calls under ``node``."""
    out = []
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            fname = (n.func.id if isinstance(n.func, ast.Name)
                     else n.func.attr if isinstance(n.func, ast.Attribute)
                     else None)
            if fname in ("P", "PartitionSpec"):
                for a in n.args:
                    for c in ast.walk(a):
                        if (isinstance(c, ast.Constant)
                                and isinstance(c.value, str)):
                            out.append(c)
    return out


@rule("shard-map-spec-consistency",
      "literal in_specs/out_specs arity vs the callee signature; P() and "
      "collective axis names vs the ('pod','data','model') mesh")
def check_shard_map_specs(mod: Module) -> List[Finding]:
    findings: List[Finding] = []
    defs = {n.name: n for n in ast.walk(mod.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    for call in ast.walk(mod.tree):
        if not isinstance(call, ast.Call):
            continue
        fname = (call.func.attr if isinstance(call.func, ast.Attribute)
                 else call.func.id if isinstance(call.func, ast.Name)
                 else None)
        if fname != "shard_map":
            continue
        in_specs = _kwarg(call, "in_specs") or (
            call.args[2] if len(call.args) > 2 else None)
        out_specs = _kwarg(call, "out_specs") or (
            call.args[3] if len(call.args) > 3 else None)
        manual = _kwarg(call, "manual_axes")

        # literal axis names must belong to the mesh vocabulary
        literal_axes: Set[str] = set()
        for spec_node in (in_specs, out_specs, manual):
            if spec_node is None:
                continue
            for c in _spec_strings(spec_node):
                literal_axes.add(c.value)
                if c.value not in MESH_AXES:
                    findings.append(mod.finding(
                        "shard-map-spec-consistency", c,
                        f"axis {c.value!r} is not a mesh axis; the device "
                        f"meshes name trailing axes of {MESH_AXES}"))
            if isinstance(spec_node, (ast.Tuple, ast.List)) is False:
                continue
        if isinstance(manual, (ast.Tuple, ast.List)):
            for c in ast.walk(manual):
                if isinstance(c, ast.Constant) and isinstance(c.value, str):
                    literal_axes.add(c.value)

        # arity: literal in_specs tuple vs a same-file callee signature
        callee = None
        if call.args and isinstance(call.args[0], ast.Name):
            callee = defs.get(call.args[0].id)
        if callee is not None and isinstance(in_specs, (ast.Tuple, ast.List)):
            n_specs = len(in_specs.elts)
            total = len(callee.args.args) + len(callee.args.posonlyargs)
            required = total - len(callee.args.defaults)
            if not (required <= n_specs <= total) and not callee.args.vararg:
                findings.append(mod.finding(
                    "shard-map-spec-consistency", in_specs,
                    f"in_specs has {n_specs} entries but callee "
                    f"{callee.name!r} takes "
                    + (f"{required}" if required == total
                       else f"{required}..{total}")
                    + " positional args -- shard_map would fail (or "
                    "silently broadcast) at trace time"))

        # collective axis names inside the callee body
        if callee is None:
            continue
        for n in ast.walk(callee):
            if not isinstance(n, ast.Call):
                continue
            cname = (n.func.attr if isinstance(n.func, ast.Attribute)
                     else n.func.id if isinstance(n.func, ast.Name)
                     else None)
            if cname not in _COLLECTIVES:
                continue
            ax = _kwarg(n, "axis_name")
            if ax is None:
                pos = 0 if cname == "axis_index" else 1
                ax = n.args[pos] if len(n.args) > pos else None
            if not (isinstance(ax, ast.Constant)
                    and isinstance(ax.value, str)):
                continue
            allowed = literal_axes or set(MESH_AXES)
            if ax.value not in allowed:
                findings.append(mod.finding(
                    "shard-map-spec-consistency", ax,
                    f"{cname} over axis {ax.value!r} inside "
                    f"{callee.name!r}, but the shard_map specs only name "
                    f"axes {sorted(allowed)} -- the collective would "
                    "cross an axis the body is not manual over"))
    return findings
